# Repo-level convenience targets.  `make ci` mirrors .github/workflows/ci.yml.

CARGO ?= cargo
MANIFEST := rust/Cargo.toml
BENCH_JSON ?= BENCH_PR4.json
# Hang-proofing: the engine is a barrier machine; a failure-propagation
# regression deadlocks rather than fails.  Bound the test step like CI does
# (no-op where coreutils `timeout` is unavailable).
TIMEOUT := $(shell command -v timeout >/dev/null 2>&1 && echo "timeout 600")

.PHONY: build test fmt-check clippy doc check-xla ci bench-smoke artifacts clean

build:
	$(CARGO) build --release --manifest-path $(MANIFEST)

test:
	$(TIMEOUT) $(CARGO) test -q --manifest-path $(MANIFEST)

fmt-check:
	$(CARGO) fmt --check --manifest-path $(MANIFEST)

clippy:
	$(CARGO) clippy --manifest-path $(MANIFEST) -- -D warnings

# Rustdoc for the public API surface, warnings denied (missing docs on
# the api/session/msg/net/worker/serve modules, broken intra-doc links).
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --manifest-path $(MANIFEST)

# Typecheck the off-by-default XLA bridge against the vendored stubs
# (lib + tests + benches) so the feature-gated code cannot silently rot.
check-xla:
	$(CARGO) check --all-targets --features xla --manifest-path $(MANIFEST)

ci: build test fmt-check clippy doc check-xla

# Quick perf trajectory: spine + serve throughput in smoke mode, numbers
# emitted to $(BENCH_JSON) (spine writes the file with its "spine" and
# "basic" sections, serve merges into it).  Non-gating in CI — the
# asserted floors (recoded spine >= 2x, serve >= 3x, n=1 wire == 0 in
# both modes) exit non-zero on regression so the step's status is still
# informative.
bench-smoke:
	GRAPHD_SMOKE=1 GRAPHD_BENCH_JSON=$(BENCH_JSON) \
		$(CARGO) bench --bench spine_throughput --manifest-path $(MANIFEST)
	GRAPHD_SCALE=0.5 GRAPHD_QUERIES=16 GRAPHD_BENCH_JSON=$(BENCH_JSON) \
		$(CARGO) bench --bench serve_throughput --manifest-path $(MANIFEST)
	@echo "bench numbers -> $(BENCH_JSON)"

# Regenerate the AOT HLO artifacts from the python layer (needs jax).
artifacts:
	python3 python/compile/aot.py --out rust/artifacts

clean:
	$(CARGO) clean --manifest-path $(MANIFEST)
