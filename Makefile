# Repo-level convenience targets.  `make ci` mirrors .github/workflows/ci.yml.

CARGO ?= cargo
MANIFEST := rust/Cargo.toml

.PHONY: build test fmt-check clippy ci artifacts clean

build:
	$(CARGO) build --release --manifest-path $(MANIFEST)

test:
	$(CARGO) test -q --manifest-path $(MANIFEST)

fmt-check:
	$(CARGO) fmt --check --manifest-path $(MANIFEST)

clippy:
	$(CARGO) clippy --manifest-path $(MANIFEST) -- -D warnings

ci: build test fmt-check clippy

# Regenerate the AOT HLO artifacts from the python layer (needs jax).
artifacts:
	python3 python/compile/aot.py --out rust/artifacts

clean:
	$(CARGO) clean --manifest-path $(MANIFEST)
