# Repo-level convenience targets.  `make ci` mirrors .github/workflows/ci.yml.

CARGO ?= cargo
MANIFEST := rust/Cargo.toml

.PHONY: build test fmt-check ci artifacts clean

build:
	$(CARGO) build --release --manifest-path $(MANIFEST)

test:
	$(CARGO) test -q --manifest-path $(MANIFEST)

fmt-check:
	$(CARGO) fmt --check --manifest-path $(MANIFEST)

ci: build test fmt-check

# Regenerate the AOT HLO artifacts from the python layer (needs jax).
artifacts:
	python3 python/compile/aot.py --out rust/artifacts

clean:
	$(CARGO) clean --manifest-path $(MANIFEST)
