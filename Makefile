# Repo-level convenience targets.  `make ci` mirrors .github/workflows/ci.yml.
#
#   make build       release build
#   make test        tier-1 tests (bounded by `timeout` where available)
#   make analyze     repo-native invariant lints (graphd-analyze): poison-
#                    safety, barrier-registration, pool-leak, sleep-slicing,
#                    panic-hygiene, print-hygiene.  Suppress a reviewed site
#                    with a reasoned
#                    pragma: `// analyze:allow(rule-id): why`.  Exit 1 on
#                    findings; `cargo run --bin analyze -- --rules` lists them.
#   make ci          everything CI gates on
#   make trace-smoke end-to-end Chrome-trace export: tiny traced run, then
#                    validate the JSON parses and every span track balances
#   make recover-smoke end-to-end self-healing: inject a U_s I/O fault into
#                    a checkpointed CLI run, assert it auto-resumes (bench
#                    JSON shows recoveries>=1) and the trace shows the
#                    fault / recovery / fast-replay spans
#   make net-smoke   end-to-end TCP transport: run the same PageRank job as
#                    a real 2-process loopback cluster and as the 1-process
#                    sim reference, assert the final vertex values are
#                    bit-identical (Codec wire encoding compared as hex)
#   make bench-smoke quick perf trajectory (non-gating floors)
#   make doc-sync    docs stay contractual: README documents every parsed
#                    -c key, docs/FORMATS.md magic/version constants match
#                    the source (scripts/check_docs.py)
#   make clean       cargo clean + stale bench JSON tmp files + orphaned
#                    CSR materialization partials (*.csr.tmp)

CARGO ?= cargo
MANIFEST := rust/Cargo.toml
BENCH_JSON ?= BENCH_PR4.json
TRACE_JSON ?= /tmp/graphd_trace_smoke.json
RECOVER_TRACE ?= /tmp/graphd_recover_smoke.json
RECOVER_JSON ?= /tmp/graphd_recover_smoke_bench.json
NET_SMOKE_DIR ?= /tmp/graphd_net_smoke
# Hang-proofing: the engine is a barrier machine; a failure-propagation
# regression deadlocks rather than fails.  Bound the test step like CI does
# (no-op where coreutils `timeout` is unavailable).
TIMEOUT := $(shell command -v timeout >/dev/null 2>&1 && echo "timeout 600")

.PHONY: build test analyze fmt-check clippy doc doc-sync check-xla ci trace-smoke recover-smoke net-smoke bench-smoke artifacts clean

build:
	$(CARGO) build --release --manifest-path $(MANIFEST)

test:
	$(TIMEOUT) $(CARGO) test -q --manifest-path $(MANIFEST)

# Static invariant lints over rust/src (the fixture corpus under
# rust/tests/analyze_fixtures is deliberately dirty and is exercised by
# `cargo test` instead).
analyze:
	$(CARGO) run -q --release --manifest-path $(MANIFEST) --bin analyze -- rust/src

fmt-check:
	$(CARGO) fmt --check --manifest-path $(MANIFEST)

clippy:
	$(CARGO) clippy --manifest-path $(MANIFEST) -- -D warnings

# Rustdoc for the public API surface, warnings denied (missing docs on
# the api/session/msg/net/worker/serve modules, broken intra-doc links).
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --manifest-path $(MANIFEST)

# Typecheck the off-by-default XLA bridge against the vendored stubs
# (lib + tests + benches) so the feature-gated code cannot silently rot.
check-xla:
	$(CARGO) check --all-targets --features xla --manifest-path $(MANIFEST)

ci: build test analyze fmt-check clippy doc doc-sync check-xla trace-smoke recover-smoke net-smoke

# Docs-vs-source sync gate: every `-c` key JobConfig::apply parses is
# documented in README (and its table has no phantom rows), every `-c`
# reference in README/DESIGN.md names a real key, and the magic/version
# constants docs/FORMATS.md declares normative match the source.
doc-sync:
	python3 scripts/check_docs.py

# End-to-end flight-recorder smoke: run a tiny traced job through the CLI,
# then check the Chrome-trace export is valid JSON whose B/E span events
# balance on every (pid, tid) track — i.e. Perfetto will actually load it.
trace-smoke: build
	$(TIMEOUT) ./rust/target/release/graphd run --algo hashmin \
		--dataset btc-s --profile test --machines 2 --scale 0.05 \
		--trace $(TRACE_JSON)
	python3 scripts/check_trace.py $(TRACE_JSON)
	rm -f $(TRACE_JSON)

# End-to-end self-healing smoke: a checkpointed 2-machine PageRank with a
# deterministic U_s I/O fault injected at machine 1, superstep 3.  The
# session's retry loop must auto-resume from the durable checkpoint and
# (keep_oms_for_recovery) take the fast message-log replay path.  Asserted
# two ways: the bench JSON records recoveries>=1 and a full superstep
# count, and the Chrome trace contains fault/recovery/replay spans.
recover-smoke: build
	rm -f $(RECOVER_JSON)
	GRAPHD_BENCH_JSON=$(RECOVER_JSON) $(TIMEOUT) ./rust/target/release/graphd run \
		--algo pagerank --dataset btc-s --profile test --machines 2 \
		--scale 0.05 --steps 6 --basic --trace $(RECOVER_TRACE) \
		-c checkpoint_every=2 -c retry=2 -c keep_oms_for_recovery=true \
		-c fault=us_io@m1s3
	python3 scripts/check_trace.py --require fault,recovery,replay $(RECOVER_TRACE)
	python3 scripts/check_recover.py $(RECOVER_JSON) 6
	rm -f $(RECOVER_TRACE) $(RECOVER_JSON)

# End-to-end TCP transport smoke: the same PageRank job as a 1-process sim
# reference and as a real 2-process loopback cluster (rank 0 binds an
# ephemeral port and forks rank 1 via --spawn-peers; each process
# preprocesses the deterministic dataset in its own private workdir and
# runs one machine).  check_transport.py merges the per-machine parts and
# asserts every vertex value is bit-identical to the sim run.
net-smoke: build
	rm -rf $(NET_SMOKE_DIR)
	mkdir -p $(NET_SMOKE_DIR)
	$(TIMEOUT) ./rust/target/release/graphd worker --sim --machines 2 \
		--algo pagerank --dataset btc-s --steps 6 --scale 0.05 \
		--workdir $(NET_SMOKE_DIR)/sim --out $(NET_SMOKE_DIR)/ref.tsv
	$(TIMEOUT) ./rust/target/release/graphd worker --rank 0 --machines 2 \
		--listen 127.0.0.1:0 --spawn-peers \
		--algo pagerank --dataset btc-s --steps 6 --scale 0.05 \
		--workdir $(NET_SMOKE_DIR)/w0 --out $(NET_SMOKE_DIR)/tcp.tsv
	python3 scripts/check_transport.py $(NET_SMOKE_DIR)/ref.tsv \
		$(NET_SMOKE_DIR)/tcp.tsv $(NET_SMOKE_DIR)/tcp.tsv.1
	rm -rf $(NET_SMOKE_DIR)

# Quick perf trajectory: spine + serve throughput in smoke mode, numbers
# emitted to $(BENCH_JSON) (spine writes the file with its "spine" and
# "basic" sections, serve merges into it).  Non-gating in CI — the
# asserted floors (recoded spine >= 2x, serve >= 3x, n=1 wire == 0 in
# both modes) exit non-zero on regression so the step's status is still
# informative.
bench-smoke:
	GRAPHD_SMOKE=1 GRAPHD_BENCH_JSON=$(BENCH_JSON) \
		$(CARGO) bench --bench spine_throughput --manifest-path $(MANIFEST)
	GRAPHD_SCALE=0.5 GRAPHD_QUERIES=16 GRAPHD_BENCH_JSON=$(BENCH_JSON) \
		$(CARGO) bench --bench serve_throughput --manifest-path $(MANIFEST)
	@echo "bench numbers -> $(BENCH_JSON)"

# Regenerate the AOT HLO artifacts from the python layer (needs jax).
artifacts:
	python3 python/compile/aot.py --out rust/artifacts

# `cargo clean` drops all build artifacts (including the analyze bin and
# anything cached for the fixture-driven tests); also sweep stale bench
# JSON scratch files that bench-smoke runs leave at the repo root, and
# any orphaned CSR materialization partials (`<name>.csr.tmp` is renamed
# into place on success, so a survivor is always a crashed write).
clean:
	$(CARGO) clean --manifest-path $(MANIFEST)
	rm -f BENCH_*.json.tmp BENCH_*.json.partial
	find . -name '*.csr.tmp' -type f -delete 2>/dev/null || true
