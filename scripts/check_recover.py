#!/usr/bin/env python3
"""Validate the self-healing smoke run (`make recover-smoke`).

The smoke run injects a deterministic U_s I/O fault into a checkpointed
CLI PageRank job and relies on the session retry loop to auto-resume it.
The CLI merges the job's metrics into the bench JSON under
"cli_run_basic"; this script asserts the run actually recovered:

  * recoveries >= 1          (the retry loop fired at least once)
  * retried_supersteps >= 1  (the resume re-ran work past the checkpoint)
  * supersteps matches --steps if given (the job still ran to completion)

Usage: check_recover.py BENCH.json [expected_supersteps]
"""

import json
import sys


def main(argv: list) -> int:
    if not argv or len(argv) > 2:
        sys.exit(__doc__)
    path = argv[0]
    with open(path) as f:
        doc = json.load(f)
    m = doc.get("cli_run_basic")
    if m is None:
        print(f"{path}: no cli_run_basic section (was GRAPHD_BENCH_JSON set?)", file=sys.stderr)
        return 1
    recoveries = m.get("recoveries", 0)
    retried = m.get("retried_supersteps", 0)
    if recoveries < 1:
        print(f"{path}: recoveries={recoveries}, expected >= 1 — the injected fault did not trigger auto-resume", file=sys.stderr)
        return 1
    if retried < 1:
        print(f"{path}: retried_supersteps={retried}, expected >= 1", file=sys.stderr)
        return 1
    if len(argv) == 2:
        want = int(argv[1])
        got = m.get("supersteps")
        if got != want:
            print(f"{path}: supersteps={got}, expected {want} — recovered run did not complete", file=sys.stderr)
            return 1
    print(f"{path}: recovered ok (recoveries={recoveries}, retried_supersteps={retried}, supersteps={m.get('supersteps')})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
