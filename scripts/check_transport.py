#!/usr/bin/env python3
"""Validate the TCP-transport smoke run (`make net-smoke`).

The smoke run executes the same job twice: once in one process on the
simulator fabric (`graphd worker --sim`, the reference), and once as a
real multi-process loopback TCP cluster (`graphd worker --listen ...
--spawn-peers`).  Each run dumps final vertex values as `id<TAB><hex>`
lines, where <hex> is the value's Codec wire encoding — so equality below
means *bit-identical* values, not equal float formatting.

This script merges the TCP cluster's per-machine part files, sorts by
vertex id, and asserts the result is exactly the reference:

  * same vertex id set (no row lost or duplicated crossing the wire)
  * byte-identical encoded value per id

Usage: check_transport.py REFERENCE.tsv PART.tsv [PART.tsv ...]
"""

import sys


def read_rows(path: str) -> dict:
    rows = {}
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.rstrip("\n")
            if not line:
                continue
            try:
                vid, hexval = line.split("\t")
                vid = int(vid)
            except ValueError:
                print(f"{path}:{ln}: malformed row {line!r}", file=sys.stderr)
                sys.exit(1)
            if vid in rows:
                print(f"{path}:{ln}: duplicate vertex id {vid}", file=sys.stderr)
                sys.exit(1)
            rows[vid] = hexval
    return rows


def main(argv: list) -> int:
    if len(argv) < 2:
        sys.exit(__doc__)
    reference = read_rows(argv[0])
    merged = {}
    for part in argv[1:]:
        for vid, hexval in read_rows(part).items():
            if vid in merged:
                print(f"{part}: vertex {vid} appears in two machine parts", file=sys.stderr)
                return 1
            merged[vid] = hexval
    if not reference:
        print(f"{argv[0]}: reference is empty", file=sys.stderr)
        return 1
    missing = sorted(set(reference) - set(merged))
    extra = sorted(set(merged) - set(reference))
    if missing or extra:
        print(
            f"vertex set mismatch: {len(missing)} missing (e.g. {missing[:5]}), "
            f"{len(extra)} unexpected (e.g. {extra[:5]})",
            file=sys.stderr,
        )
        return 1
    diverged = [vid for vid in reference if reference[vid] != merged[vid]]
    if diverged:
        vid = diverged[0]
        print(
            f"{len(diverged)} of {len(reference)} values diverge from sim; "
            f"first: id {vid} sim={reference[vid]} tcp={merged[vid]}",
            file=sys.stderr,
        )
        return 1
    print(f"transport ok: {len(reference)} vertex values bit-identical across "
          f"{len(argv) - 1} tcp part(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
