#!/usr/bin/env python3
"""Keep the docs contractual (`make doc-sync`).

Three families of assertions, all against the working tree:

  1. Config-key sync: every `-c key=val` the parser accepts
     (`JobConfig::apply` in rust/src/config/mod.rs) appears in README.md,
     and README's "Config keys" table lists exactly the parsed set — no
     phantom rows, no undocumented knobs.
  2. Knob honesty: every `-c key` reference anywhere in README.md or
     DESIGN.md (including the modes-matrix feature rows) names a key the
     parser actually accepts.
  3. Format constants: the magic numbers, versions, and header sizes that
     docs/FORMATS.md declares normative are byte-for-byte the constants
     in rust/src/worker/csr.rs and rust/src/net/frame.rs.

Usage: check_docs.py [repo_root]
"""

import re
import sys


def read(root: str, rel: str) -> str:
    with open(f"{root}/{rel}") as f:
        return f.read()


def parsed_config_keys(config_src: str) -> set:
    """Keys matched by JobConfig::apply — the arms at match-arm depth
    (12 spaces) inside the apply() body; deeper arms are value parses."""
    start = config_src.index("pub fn apply")
    body = config_src[start:]
    end = body.index("\n    }")  # apply() closes at fn-body indent
    return set(re.findall(r'^            "([a-z_]+)" =>', body[:end], re.M))


def table_keys(readme: str) -> set:
    """Keys listed in README's "### Config keys" table."""
    m = re.search(r"### Config keys.*?(?=\n### |\n## )", readme, re.S)
    if not m:
        return set()
    return set(re.findall(r"^\| `([a-z_]+)` \|", m.group(0), re.M))


def check_constant(errors: list, formats: str, src: str, src_rel: str, pattern: str, doc_needle: str, what: str) -> None:
    m = re.search(pattern, src)
    if not m:
        errors.append(f"{src_rel}: cannot find {what} (pattern {pattern!r}) — update check_docs.py if it moved")
        return
    if doc_needle.format(m.group(1)) not in formats:
        errors.append(f"docs/FORMATS.md: {what} drifted — source says {m.group(1)}, doc lacks {doc_needle.format(m.group(1))!r}")


def main(argv: list) -> int:
    if len(argv) > 1:
        sys.exit(__doc__)
    root = argv[0] if argv else "."
    config = read(root, "rust/src/config/mod.rs")
    readme = read(root, "README.md")
    design = read(root, "DESIGN.md")
    formats = read(root, "docs/FORMATS.md")
    csr = read(root, "rust/src/worker/csr.rs")
    frame = read(root, "rust/src/net/frame.rs")
    errors = []

    keys = parsed_config_keys(config)
    if not keys:
        errors.append("rust/src/config/mod.rs: extracted zero config keys — update check_docs.py")
    for k in sorted(keys):
        if f"`{k}`" not in readme:
            errors.append(f"README.md: parsed config key `{k}` is undocumented")
    listed = table_keys(readme)
    if not listed:
        errors.append("README.md: no '### Config keys' table found")
    for k in sorted(listed - keys):
        errors.append(f"README.md: config-key table row `{k}` names a key the parser does not accept")
    for k in sorted(keys - listed):
        errors.append(f"README.md: config-key table is missing parsed key `{k}`")

    for doc_rel, doc in [("README.md", readme), ("DESIGN.md", design)]:
        for k in set(re.findall(r"-c ([a-z_]+)=", doc)) - {"key"}:  # `-c key=val` placeholder
            if k not in keys:
                errors.append(f"{doc_rel}: references `-c {k}=`, which the parser does not accept")

    check_constant(errors, formats, csr, "rust/src/worker/csr.rs",
                   r"pub const CSR_MAGIC: u32 = (0x[0-9a-fA-F_]+);", "`{}`", "CSR magic")
    check_constant(errors, formats, csr, "rust/src/worker/csr.rs",
                   r"pub const CSR_VERSION: u16 = (\d+);", "`u16` = `{}` (`CSR_VERSION`)", "CSR version")
    check_constant(errors, formats, csr, "rust/src/worker/csr.rs",
                   r"pub const CSR_HEADER_LEN: usize = (\d+);", "**{}-byte header** (`CSR_HEADER_LEN`)", "CSR header size")
    check_constant(errors, formats, frame, "rust/src/net/frame.rs",
                   r"pub const MAGIC: u32 = (0x[0-9a-fA-F_]+);", "`{}`", "frame magic")
    check_constant(errors, formats, frame, "rust/src/net/frame.rs",
                   r"pub const HEADER_LEN: usize = (\d+);", "**{}-byte header** (`HEADER_LEN`)", "frame header size")
    if "64 << 20" not in frame or "64 MiB" not in formats:
        errors.append("docs/FORMATS.md / net/frame.rs: MAX_FRAME_LEN (64 MiB) drifted")

    if errors:
        for e in errors:
            print(e, file=sys.stderr)
        return 1
    print(f"doc-sync ok: {len(keys)} config keys documented, format constants match")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
