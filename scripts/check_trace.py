#!/usr/bin/env python3
"""Validate a graphd Chrome-trace export (`make trace-smoke`).

Checks the file is valid JSON in Chrome trace-event "JSON object format"
and that every duration span balances: on each (pid, tid) track the B/E
events nest properly (no E before its B, nothing left open at the end).
That is exactly the property Perfetto / chrome://tracing needs to render
the track, so passing here means the export actually loads.

With `--require name1,name2` the trace must additionally contain at least
one event of each named kind (e.g. `--require fault,recovery,replay` for
`make recover-smoke`: the faulted session's export must show the injected
fault, the auto-resume, and the fast-replay path).

Usage: check_trace.py [--require NAMES] TRACE.json [TRACE2.json ...]
"""

import collections
import json
import sys


def check(path: str, require: list) -> int:
    with open(path) as f:
        doc = json.load(f)
    evs = doc["traceEvents"]
    if not evs:
        print(f"{path}: no trace events", file=sys.stderr)
        return 1
    depth: collections.Counter = collections.Counter()
    last_ts: dict = {}
    names = set()
    for e in evs:
        key = (e["pid"], e["tid"])
        names.add(e.get("name", ""))
        if e["ph"] == "B":
            depth[key] += 1
        elif e["ph"] == "E":
            depth[key] -= 1
            if depth[key] < 0:
                print(f"{path}: E before B on track {key}", file=sys.stderr)
                return 1
        if "ts" in e:
            # Monotone timestamps per track (the exporter emits in
            # ring-buffer order, which is per-thread chronological).
            if e["ts"] < last_ts.get(key, 0):
                print(f"{path}: timestamps go backwards on {key}", file=sys.stderr)
                return 1
            last_ts[key] = e["ts"]
    open_tracks = {k: v for k, v in depth.items() if v}
    if open_tracks:
        print(f"{path}: unbalanced spans {open_tracks}", file=sys.stderr)
        return 1
    missing = [r for r in require if r not in names]
    if missing:
        print(f"{path}: required event kinds missing: {missing}", file=sys.stderr)
        return 1
    extra = f", required kinds present: {require}" if require else ""
    print(f"{path}: {len(evs)} events, {len(depth)} tracks balanced{extra}")
    return 0


if __name__ == "__main__":
    args = sys.argv[1:]
    require: list = []
    if args and args[0] == "--require":
        if len(args) < 2:
            sys.exit(__doc__)
        require = [r for r in args[1].split(",") if r]
        args = args[2:]
    if not args:
        sys.exit(__doc__)
    sys.exit(max(check(p, require) for p in args))
