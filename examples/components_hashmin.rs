//! Connected components with Hash-Min on an undirected social graph,
//! comparing IO-Basic (external merge-sort combining) with IO-Recoded
//! (in-memory A_r/A_s digesting) — §5's headline feature.  Runs through
//! the bench harness, which drives the fluent session API.

use graphd::baselines::Algo;
use graphd::bench::{run_graphd, scale_from_env, use_xla_from_env};
use graphd::config::ClusterProfile;
use graphd::graph::generator::Dataset;
use graphd::graph::reference;
use graphd::util::human_secs;

fn main() {
    let scale = scale_from_env();
    let g = Dataset::FriendsterS.generate_scaled(scale);
    println!(
        "== Hash-Min CC on friendster-s: |V|={} |E|={} ==",
        g.num_vertices(),
        g.num_edges()
    );
    // Number of true components, for the final check.
    let comps = {
        let c = reference::components(&g);
        let mut u: Vec<u32> = c.clone();
        u.sort_unstable();
        u.dedup();
        u.len()
    };
    println!("reference components: {comps}");

    let profile = ClusterProfile::whigh();
    let gd = run_graphd(
        "example_hashmin",
        &g,
        Algo::HashMin,
        &profile,
        use_xla_from_env(),
    )
    .expect("run");

    println!(
        "IO-Basic:   {} supersteps, compute {}",
        gd.basic_metrics.supersteps,
        human_secs(gd.basic_compute)
    );
    println!(
        "IO-Recoding preprocessing: {}",
        human_secs(gd.recoding_compute)
    );
    println!(
        "IO-Recoded: compute {}  (merge-sort eliminated: {:.2}x)",
        human_secs(gd.recoded_compute),
        gd.basic_compute / gd.recoded_compute.max(1e-9)
    );

    match &gd.values {
        graphd::baselines::AlgoValues::Labels(l) => {
            let mut u = l.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), comps, "component count mismatch");
            println!("GraphD found {} components — matches reference", u.len());
        }
        _ => unreachable!(),
    }
}
