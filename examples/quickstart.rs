//! Quickstart: the complete GraphD pipeline — Load, IO-Recoding, Compute —
//! in ~15 lines through the fluent session API.
//!
//! 1. generate a power-law graph with sparse vertex IDs (like real input),
//! 2. one builder → one [`graphd::Session`],
//! 3. `load` → IO-Basic PageRank,
//! 4. `recode` → `Mode::Auto` picks IO-Recoded (+ the AOT Pallas kernels
//!    when `make artifacts` has produced them),
//! 5. print the top-ranked vertices and check both modes agree.
//!
//! Run: `cargo run --release --example quickstart`

use graphd::algos::PageRank;
use graphd::graph::generator;
use graphd::{GraphD, GraphSource, Mode};
use std::sync::Arc;

fn main() -> graphd::Result<()> {
    let wd = std::env::temp_dir().join("graphd_quickstart");
    let _ = std::fs::remove_dir_all(&wd);

    // A small power-law web graph with sparse vertex IDs, like real input.
    let g = generator::rmat(20_000, 200_000, (0.57, 0.19, 0.19), true, 7);
    println!(
        "graph: |V|={} |E|={} max-deg={}",
        g.num_vertices(),
        g.num_edges(),
        g.max_degree()
    );

    // The whole pipeline: build a session, load, run, recode, run again.
    let session = GraphD::builder()
        .machines(4)
        .workdir(&wd)
        .max_supersteps(10)
        .build()?;
    let mut graph = session.load(GraphSource::InMemorySparse(&g, 99))?;
    let basic = graph.run(Arc::new(PageRank::new(10)))?;
    let recoded = graph
        .recode()?
        .job(Arc::new(PageRank::new(10)))
        .mode(Mode::Auto)
        .run()?;

    println!(
        "IO-Basic:   {} supersteps, {:.2}s compute",
        basic.supersteps(),
        basic.metrics.compute_secs
    );
    println!(
        "IO-Recoded: {} supersteps, {:.2}s compute",
        recoded.supersteps(),
        recoded.metrics.compute_secs
    );

    // Top-5 ranks agree between modes.
    let mut ranks = basic.values_by_id();
    ranks.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!("top-5 vertices by PageRank:");
    let rec_ranks: std::collections::HashMap<u32, f32> =
        recoded.values_by_id().into_iter().collect();
    for (id, r) in ranks.iter().take(5) {
        println!("  id {id:>8}  rank {r:.6}  (recoded mode: {:.6})", rec_ranks[id]);
        assert!((r - rec_ranks[id]).abs() < 1e-5);
    }

    let _ = std::fs::remove_dir_all(&wd);
    Ok(())
}
