//! Quickstart: the complete GraphD pipeline on a small graph in ~40 lines.
//!
//! 1. generate a graph and put it on the (simulated) HDFS as text,
//! 2. load it into per-machine stores (state array A + edge stream S^E),
//! 3. run PageRank in IO-Basic mode,
//! 4. ID-recode and run again in IO-Recoded mode (in-memory digesting on
//!    the AOT-compiled Pallas kernels, if `make artifacts` has been run),
//! 5. print the top-ranked vertices.
//!
//! Run: `cargo run --release --example quickstart`

use graphd::algos::PageRank;
use graphd::config::{ClusterProfile, JobConfig, Mode};
use graphd::dfs::Dfs;
use graphd::engine::{load, run, Engine};
use graphd::graph::generator;
use graphd::recode;
use std::sync::Arc;

fn main() -> graphd::Result<()> {
    let wd = std::env::temp_dir().join("graphd_quickstart");
    let _ = std::fs::remove_dir_all(&wd);

    // A small power-law web graph with sparse vertex IDs, like real input.
    let g = generator::rmat(20_000, 200_000, (0.57, 0.19, 0.19), true, 7);
    println!(
        "graph: |V|={} |E|={} max-deg={}",
        g.num_vertices(),
        g.num_edges(),
        g.max_degree()
    );

    let mut cfg = JobConfig::default();
    cfg.workdir = wd.clone();
    cfg.max_supersteps = 10;
    let profile = ClusterProfile::test(4); // 4 simulated machines

    // 1-2: put on DFS (sparse ids), parallel-load into per-machine stores.
    let dfs = Dfs::new(&wd.join("dfs"))?;
    load::put_graph(&dfs, "web.txt", &g, Some(99))?;
    let eng = Engine::new(profile.clone(), cfg.clone())?;
    let stores = load::load_text(&eng, &dfs, "web.txt", false)?;

    // 3: IO-Basic run.
    let basic = run::run_job(&eng, &stores, Arc::new(PageRank::new(10)))?;
    println!(
        "IO-Basic:   {} supersteps, {:.2}s compute",
        basic.supersteps(),
        basic.metrics.compute_secs
    );

    // 4: recode + IO-Recoded run (XLA block kernels when artifacts exist).
    let rec = recode::recode(&eng, &stores, true)?;
    cfg.mode = Mode::Recoded;
    cfg.use_xla = graphd::runtime::KernelSet::default_dir()
        .join("pagerank_update.hlo.txt")
        .exists();
    let eng_rec = Engine::new(profile, cfg)?;
    let recoded = run::run_job(&eng_rec, &rec, Arc::new(PageRank::new(10)))?;
    println!(
        "IO-Recoded: {} supersteps, {:.2}s compute (xla={})",
        recoded.supersteps(),
        recoded.metrics.compute_secs,
        eng_rec.cfg.use_xla
    );

    // 5: top-5 ranks agree between modes.
    let mut ranks = basic.values_by_id();
    ranks.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!("top-5 vertices by PageRank:");
    let rec_ranks: std::collections::HashMap<u32, f32> =
        recoded.values_by_id().into_iter().collect();
    for (id, r) in ranks.iter().take(5) {
        println!("  id {id:>8}  rank {r:.6}  (recoded mode: {:.6})", rec_ranks[id]);
        assert!((r - rec_ranks[id]).abs() < 1e-5);
    }

    let _ = std::fs::remove_dir_all(&wd);
    Ok(())
}
