//! Fault tolerance (§3.4): checkpoint a PageRank job every 3 supersteps,
//! simulate a machine failure, and recover from the latest checkpoint —
//! verifying the recovered run converges to exactly the same ranks as an
//! uninterrupted one.

use graphd::algos::PageRank;
use graphd::config::{ClusterProfile, JobConfig};
use graphd::dfs::Dfs;
use graphd::engine::{load, run, Engine};
use graphd::ft::{self, CheckpointCfg};
use graphd::graph::generator;
use std::sync::Arc;

fn main() -> graphd::Result<()> {
    let wd = std::env::temp_dir().join("graphd_fault_recovery");
    let _ = std::fs::remove_dir_all(&wd);

    let g = generator::rmat(10_000, 120_000, (0.57, 0.19, 0.19), true, 33);
    println!("graph: |V|={} |E|={}", g.num_vertices(), g.num_edges());

    let mut cfg = JobConfig::default();
    cfg.workdir = wd.clone();
    cfg.max_supersteps = 10;
    cfg.keep_oms_for_recovery = true; // message logs for [19]-style recovery
    let eng = Engine::new(ClusterProfile::test(4), cfg)?;
    let dfs = Dfs::new(&wd.join("dfs"))?;
    load::put_graph(&dfs, "g.txt", &g, Some(11))?;
    let stores = load::load_text(&eng, &dfs, "g.txt", false)?;

    // Uninterrupted run (the ground truth).
    let full = run::run_job(&eng, &stores, Arc::new(PageRank::new(10)))?;
    println!("uninterrupted: {} supersteps", full.supersteps());

    // Run with checkpointing every 3 supersteps.
    let ck = CheckpointCfg {
        dir: wd.join("dfs/checkpoints"),
        every: 3,
    };
    let _ = run::run_job_with(&eng, &stores, Arc::new(PageRank::new(10)), Some(ck.clone()), None)?;
    let cks: Vec<u64> = (0..10)
        .filter(|s| ft::latest_checkpoint(&ck.dir, Some(*s)) == Some(*s))
        .collect();
    println!("checkpoints on DFS after supersteps: {cks:?}");

    // 💥 A machine dies at superstep 7. Recover from the latest checkpoint
    // at or before the failure and finish the job.
    let fail_at = 7;
    let restart = ft::latest_checkpoint(&ck.dir, Some(fail_at)).expect("a checkpoint exists");
    println!("failure at superstep {fail_at}; recovering from checkpoint {restart}");
    let recovered = run::run_job_with(
        &eng,
        &stores,
        Arc::new(PageRank::new(10)),
        Some(ck),
        Some(restart),
    )?;
    println!(
        "recovered run: {} total supersteps ({} replayed)",
        recovered.metrics.supersteps,
        recovered.metrics.supersteps - restart - 1
    );

    // Identical results.
    let a = full.values_by_id();
    let b = recovered.values_by_id();
    let mut worst = 0f32;
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.0, y.0);
        worst = worst.max((x.1 - y.1).abs());
    }
    println!("max |rank diff| full vs recovered: {worst:.2e}");
    assert!(worst < 1e-6, "recovery diverged");
    println!("OK — recovery is exact");

    let _ = std::fs::remove_dir_all(&wd);
    Ok(())
}
