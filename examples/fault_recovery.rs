//! Fault tolerance (§3.4): checkpoint a PageRank job every 3 supersteps,
//! simulate a machine failure, and recover from the latest checkpoint —
//! verifying the recovered run converges to exactly the same ranks as an
//! uninterrupted one.  Checkpointing and resume are per-job knobs on the
//! session's [`graphd::JobBuilder`].

use graphd::algos::PageRank;
use graphd::ft::{self, CheckpointCfg};
use graphd::graph::generator;
use graphd::{GraphD, GraphSource};
use std::sync::Arc;

fn main() -> graphd::Result<()> {
    let wd = std::env::temp_dir().join("graphd_fault_recovery");
    let _ = std::fs::remove_dir_all(&wd);

    let g = generator::rmat(10_000, 120_000, (0.57, 0.19, 0.19), true, 33);
    println!("graph: |V|={} |E|={}", g.num_vertices(), g.num_edges());

    let session = GraphD::builder()
        .machines(4)
        .workdir(&wd)
        .max_supersteps(10)
        .keep_oms_for_recovery(true) // message logs for [19]-style recovery
        .build()?;
    let graph = session.load(GraphSource::InMemorySparse(&g, 11))?;

    // Uninterrupted run (the ground truth).
    let full = graph.run(Arc::new(PageRank::new(10)))?;
    println!("uninterrupted: {} supersteps", full.supersteps());

    // Run with checkpointing every 3 supersteps.
    let ck = CheckpointCfg {
        dir: wd.join("dfs/checkpoints"),
        every: 3,
    };
    let _ = graph
        .job(Arc::new(PageRank::new(10)))
        .checkpoint(ck.clone())
        .run()?;
    let cks: Vec<u64> = (0..10)
        .filter(|s| ft::latest_checkpoint(&ck.dir, Some(*s)) == Some(*s))
        .collect();
    println!("checkpoints on DFS after supersteps: {cks:?}");

    // 💥 A machine dies at superstep 7. Recover from the latest checkpoint
    // at or before the failure and finish the job.
    let fail_at = 7;
    let restart = ft::latest_checkpoint(&ck.dir, Some(fail_at)).expect("a checkpoint exists");
    println!("failure at superstep {fail_at}; recovering from checkpoint {restart}");
    let recovered = graph
        .job(Arc::new(PageRank::new(10)))
        .checkpoint(ck)
        .resume(restart)
        .run()?;
    println!(
        "recovered run: {} total supersteps ({} replayed)",
        recovered.metrics.supersteps,
        recovered.metrics.supersteps - restart - 1
    );

    // Identical results.
    let a = full.values_by_id();
    let b = recovered.values_by_id();
    let mut worst = 0f32;
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.0, y.0);
        worst = worst.max((x.1 - y.1).abs());
    }
    println!("max |rank diff| full vs recovered: {worst:.2e}");
    assert!(worst < 1e-6, "recovery diverged");
    println!("OK — recovery is exact");

    let _ = std::fs::remove_dir_all(&wd);
    Ok(())
}
