//! SSSP with sparse frontiers — the workload the paper's `skip()` design
//! targets (§3.2, Tables 7–8).  Shows that per-superstep edge-stream reads
//! track the frontier instead of |E|, and compares against the X-Stream
//! baseline which must stream all edges every superstep.  Runs through
//! the bench harness, which drives the fluent session API.

use graphd::baselines::{self, Algo};
use graphd::bench::{run_graphd, scale_from_env, sssp_source, use_xla_from_env};
use graphd::config::ClusterProfile;
use graphd::graph::generator::Dataset;
use graphd::util::human_secs;

fn main() {
    let scale = scale_from_env();
    let g = Dataset::BtcS.generate_scaled(scale).with_unit_weights();
    let src = sssp_source(&g);
    println!(
        "== SSSP (BFS) on btc-s: |V|={} |E|={} source deg {} ==",
        g.num_vertices(),
        g.num_edges(),
        g.degree(src)
    );
    let profile = ClusterProfile::wpc();
    let algo = Algo::Sssp { source: src };

    let gd = run_graphd("example_sssp", &g, algo, &profile, use_xla_from_env()).expect("run");
    println!(
        "GraphD IO-Basic: {} supersteps, compute {}",
        gd.basic_metrics.supersteps,
        human_secs(gd.basic_compute)
    );

    // Per-superstep I/O: frontier-proportional reads, the rest skipped.
    println!("\nstep  computed  items-read  items-skipped  seeks");
    let mut agg = vec![(0u64, 0u64, 0u64, 0u64); gd.basic_metrics.supersteps as usize];
    for m in &gd.basic_metrics.machines {
        for s in &m.steps {
            let a = &mut agg[s.step as usize];
            a.0 += s.computed_vertices;
            a.1 += s.edge_items_read;
            a.2 += s.edge_items_skipped;
            a.3 += s.seeks;
        }
    }
    for (i, (c, r, sk, se)) in agg.iter().enumerate().take(12) {
        println!("{i:>4}  {c:>8}  {r:>10}  {sk:>13}  {se:>5}");
    }
    if agg.len() > 12 {
        println!("  ... ({} more)", agg.len() - 12);
    }
    let total_read: u64 = agg.iter().map(|a| a.1).sum();
    let total_skip: u64 = agg.iter().map(|a| a.2).sum();
    println!("\ntotal items read {total_read} vs skipped {total_skip}");

    // X-Stream must stream everything, every superstep.
    match baselines::xstream::run(&g, algo, &profile) {
        Ok(xs) => println!(
            "X-Stream compute: {} ({:.1}x GraphD IO-Basic)",
            human_secs(xs.compute_secs),
            xs.compute_secs / gd.basic_compute.max(1e-9)
        ),
        Err(e) => println!("X-Stream: {e}"),
    }
}
