//! End-to-end driver (the repo's headline validation run): a web-scale-
//! shaped workload through the full stack via the bench harness, which
//! drives everything through the fluent session API.
//!
//! * generates the webuk-s analog (~134 K vertices / ~5.5 M edges,
//!   power-law, sparse input IDs),
//! * runs 10 PageRank supersteps on the simulated W^PC cluster in all
//!   three GraphD configurations (IO-Basic, ID-recoding preprocessing,
//!   IO-Recoded with the AOT Pallas kernels on PJRT),
//! * cross-checks every mode against the in-memory reference,
//! * reports the paper-style Load/Compute cells, the Table-4 overlap
//!   split, and the per-machine memory bound.
//!
//! Run: `make artifacts && cargo run --release --example pagerank_web`
//! (env: GRAPHD_SCALE to shrink, GRAPHD_XLA=0 for the scalar path)

use graphd::baselines::Algo;
use graphd::bench::{run_graphd, scale_from_env, use_xla_from_env};
use graphd::config::ClusterProfile;
use graphd::graph::generator::Dataset;
use graphd::graph::reference;
use graphd::util::{human_bytes, human_secs};

fn main() {
    let scale = scale_from_env();
    let ds = Dataset::WebUkS;
    let g = ds.generate_scaled(scale);
    println!(
        "== GraphD end-to-end: PageRank on {} (|V|={}, |E|={}, scale {scale}) ==",
        ds.name(),
        g.num_vertices(),
        g.num_edges()
    );
    let profile = ClusterProfile::wpc();
    println!(
        "cluster: {} machines, net {}/s shared, disk {}/s per machine\n",
        profile.machines,
        human_bytes(profile.net_bytes_per_sec as u64),
        human_bytes(profile.disk_bytes_per_sec.unwrap_or(0.0) as u64),
    );

    let algo = Algo::PageRank { supersteps: 10 };
    let gd = run_graphd("example_pr_web", &g, algo, &profile, use_xla_from_env())
        .expect("end-to-end run");

    println!("IO-Basic:    Load {:>8}  Compute {:>8}", human_secs(gd.basic_load), human_secs(gd.basic_compute));
    println!("IO-Recoding: Load {:>8}  Compute {:>8}", human_secs(gd.basic_load), human_secs(gd.recoding_compute));
    println!("IO-Recoded:  Load {:>8}  Compute {:>8}", human_secs(gd.recoded_load), human_secs(gd.recoded_compute));

    let (bg, bs) = gd.basic_metrics.m_gene_m_send();
    println!("\noverlap (machine 0, IO-Basic): M-Gene {} inside M-Send {}", human_secs(bg), human_secs(bs));
    println!(
        "peak per-machine state: {} (|V|/n = {} vertices)",
        human_bytes(gd.basic_metrics.peak_state_bytes()),
        g.num_vertices() / profile.machines
    );

    // Correctness: engine ranks vs the in-memory reference.
    let want = reference::pagerank(&g, 10);
    match &gd.values {
        graphd::baselines::AlgoValues::Ranks(got) => {
            let mut worst = 0f32;
            for v in 0..want.len() {
                worst = worst.max((got[v] - want[v]).abs() / (1.0 + want[v].abs()));
            }
            println!("\nmax relative error vs in-memory reference: {worst:.2e}");
            assert!(worst < 1e-4, "mode diverged from reference");
            // "loss curve" analog: rank mass per superstep is monotone in
            // convergence; print the L1 distance of ranks to uniform.
            let nv = want.len() as f32;
            let l1: f32 = got.iter().map(|r| (r - 1.0 / nv).abs()).sum();
            println!("final L1(rank, uniform) = {l1:.4} (converged mass spread)");
        }
        _ => unreachable!(),
    }
    println!("\nOK — all layers composed: text load → DSS streams → [recode] → PJRT kernels → results");
}
