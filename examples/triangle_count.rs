//! Triangle counting — the paper's example (§3.1) of a Pregel algorithm
//! whose message volume far exceeds |E| (O(Σd²) ⊇ O(|E|^1.5)), which is
//! why GraphD streams messages on disk instead of holding them in RAM.
//! No combiner applies, so this exercises the sorted-IMS path, and the
//! global count flows through the aggregator.

use graphd::algos::TriangleCount;
use graphd::graph::{generator, reference};
use graphd::{GraphD, GraphSource};
use std::sync::Arc;

fn main() -> graphd::Result<()> {
    let wd = std::env::temp_dir().join("graphd_triangles");
    let _ = std::fs::remove_dir_all(&wd);

    let g = generator::uniform(3_000, 60_000, false, 21);
    let expect = reference::triangles(&g);
    println!(
        "graph: |V|={} |E|={}, expecting {expect} triangles",
        g.num_vertices(),
        g.num_edges()
    );

    let session = GraphD::builder().machines(4).workdir(&wd).build()?;
    let res = session.run(GraphSource::InMemorySparse(&g, 5), Arc::new(TriangleCount))?;

    let count = *res.outputs[0].final_agg;
    let msgs = res.metrics.total_msgs();
    println!(
        "GraphD: {count} triangles in {} supersteps; {msgs} messages (|E|={}; ratio {:.1}x)",
        res.supersteps(),
        g.num_edges(),
        msgs as f64 / g.num_edges() as f64
    );
    assert_eq!(count, expect);
    println!("matches brute-force reference ✓");

    let _ = std::fs::remove_dir_all(&wd);
    Ok(())
}
