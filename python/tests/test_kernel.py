"""Kernel vs pure-jnp reference — the core L1 correctness signal.

Hypothesis sweeps shapes/dtypes/values; every property asserts the Pallas
kernel (interpret mode) matches ref.py to tight tolerance (exact for
min/int paths).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import BLOCK, TILE, ref
from compile.kernels.minrelax import minrelax_block
from compile.kernels.pagerank import pagerank_block

# Valid block sizes: multiples of TILE, plus small blocks (< TILE) where the
# kernel clamps the tile to the block size.
BLOCK_SIZES = st.sampled_from([1, 2, 7, 64, 1000, TILE, 2 * TILE, 4 * TILE])

finite_f32 = st.floats(
    min_value=0.0, max_value=1e6, allow_nan=False, allow_infinity=False, width=32
)


def _assert_allclose(a, b, **kw):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **kw)


# ---------------------------------------------------------------- pagerank
@settings(max_examples=30, deadline=None)
@given(b=BLOCK_SIZES, seed=st.integers(0, 2**31 - 1), n=st.integers(1, 10**9))
def test_pagerank_matches_ref(b, seed, n):
    if b % min(TILE, b) != 0:
        b = (b // TILE) * TILE or 1
    rng = np.random.default_rng(seed)
    sums = jnp.asarray(rng.random(b, dtype=np.float32))
    deg = jnp.asarray(rng.integers(0, 50, b).astype(np.float32))
    inv_n = jnp.asarray([1.0 / n], dtype=jnp.float32)
    val, msg = pagerank_block(sums, deg, inv_n)
    val_r, msg_r = ref.pagerank_block_ref(sums, deg, inv_n)
    _assert_allclose(val, val_r, rtol=1e-6, atol=1e-9)
    _assert_allclose(msg, msg_r, rtol=1e-6, atol=1e-9)


def test_pagerank_sink_emits_zero():
    sums = jnp.asarray([0.5, 0.25], dtype=jnp.float32)
    deg = jnp.asarray([0.0, 5.0], dtype=jnp.float32)
    inv_n = jnp.asarray([0.01], dtype=jnp.float32)
    val, msg = pagerank_block(sums, deg, inv_n)
    assert msg[0] == 0.0
    _assert_allclose(val[0], 0.15 * 0.01 + 0.85 * 0.5, rtol=1e-6)
    _assert_allclose(msg[1], val[1] / 5.0, rtol=1e-6)


def test_pagerank_full_block_shape():
    sums = jnp.zeros((BLOCK,), jnp.float32)
    deg = jnp.ones((BLOCK,), jnp.float32)
    inv_n = jnp.asarray([1e-6], jnp.float32)
    val, msg = pagerank_block(sums, deg, inv_n)
    assert val.shape == (BLOCK,) and msg.shape == (BLOCK,)
    _assert_allclose(val, jnp.full((BLOCK,), 0.15e-6), rtol=1e-6)


def test_pagerank_padding_lanes_are_finite():
    # Rust pads the tail of the last block with sums=0, deg=0; those lanes
    # must stay finite so later reads (even if ignored) can't poison NaNs.
    sums = jnp.zeros((TILE,), jnp.float32)
    deg = jnp.zeros((TILE,), jnp.float32)
    val, msg = pagerank_block(sums, deg, jnp.asarray([0.5], jnp.float32))
    assert bool(jnp.isfinite(val).all()) and bool(jnp.isfinite(msg).all())


# ---------------------------------------------------------------- minrelax
@settings(max_examples=30, deadline=None)
@given(b=BLOCK_SIZES, seed=st.integers(0, 2**31 - 1))
def test_minrelax_f32_matches_ref(b, seed):
    rng = np.random.default_rng(seed)
    cur = rng.random(b, dtype=np.float32) * 100
    # mix of improvements, ties, regressions and "no message" (+inf)
    msg = np.where(
        rng.random(b) < 0.25, np.float32(np.inf), rng.random(b, dtype=np.float32) * 100
    )
    new, chg = minrelax_block(jnp.asarray(cur), jnp.asarray(msg.astype(np.float32)))
    new_r, chg_r = ref.minrelax_block_ref(jnp.asarray(cur), jnp.asarray(msg))
    _assert_allclose(new, new_r)
    _assert_allclose(chg, chg_r)
    # invariants: new <= cur, changed iff strictly smaller
    assert bool(jnp.all(new <= cur))
    np.testing.assert_array_equal(np.asarray(chg) == 1, np.asarray(new) < cur)


@settings(max_examples=30, deadline=None)
@given(b=BLOCK_SIZES, seed=st.integers(0, 2**31 - 1))
def test_minrelax_i32_matches_ref(b, seed):
    rng = np.random.default_rng(seed)
    imax = np.iinfo(np.int32).max
    cur = rng.integers(0, 10**6, b).astype(np.int32)
    msg = np.where(rng.random(b) < 0.25, imax, rng.integers(0, 10**6, b)).astype(
        np.int32
    )
    new, chg = minrelax_block(jnp.asarray(cur), jnp.asarray(msg))
    new_r, chg_r = ref.minrelax_block_ref(jnp.asarray(cur), jnp.asarray(msg))
    np.testing.assert_array_equal(np.asarray(new), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(chg), np.asarray(chg_r))
    assert new.dtype == jnp.int32 and chg.dtype == jnp.int32


def test_minrelax_identity_is_noop():
    cur = jnp.asarray([3.0, 1.5, 0.0], jnp.float32)
    msg = jnp.full((3,), jnp.inf, jnp.float32)
    new, chg = minrelax_block(cur, msg)
    _assert_allclose(new, cur)
    assert int(chg.sum()) == 0


def test_minrelax_full_block():
    cur = jnp.full((BLOCK,), 7, jnp.int32)
    msg = jnp.full((BLOCK,), 3, jnp.int32)
    new, chg = minrelax_block(cur, msg)
    assert int(new[0]) == 3 and int(chg.sum()) == BLOCK
