"""L2 model tests: iterating the block update == textbook power iteration,
and the ARTIFACTS registry is well-formed (shapes the Rust runtime expects).
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import BLOCK


def _block_pagerank(adj: np.ndarray, iters: int) -> np.ndarray:
    """Drive model.pagerank_update the way the Rust engine does (dense toy
    graph, one block), to validate the block update against the oracle."""
    n = adj.shape[0]
    deg = adj.sum(axis=1).astype(np.float32)
    inv_n = jnp.asarray([1.0 / n], jnp.float32)
    val = np.full(n, 1.0 / n, dtype=np.float32)
    msg = np.where(deg > 0, val / np.maximum(deg, 1.0), 0.0).astype(np.float32)
    for _ in range(iters):
        sums = (msg[:, None] * adj).sum(axis=0).astype(np.float32)
        val_j, msg_j = model.pagerank_update(
            jnp.asarray(sums), jnp.asarray(deg), inv_n
        )
        val, msg = np.asarray(val_j), np.asarray(msg_j)
    return val


def test_block_update_matches_dense_oracle():
    rng = np.random.default_rng(7)
    n = 32
    adj = (rng.random((n, n)) < 0.15).astype(np.float32)
    np.fill_diagonal(adj, 0)
    got = _block_pagerank(adj, iters=10)
    want = np.asarray(model.pagerank_dense_ref(jnp.asarray(adj), iters=10))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-8)


def test_pagerank_mass_leaks_only_at_sinks():
    # no sinks -> total mass converges to 1
    n = 16
    adj = np.zeros((n, n), np.float32)
    for i in range(n):
        adj[i, (i + 1) % n] = 1  # ring
    r = _block_pagerank(adj, iters=50)
    np.testing.assert_allclose(r.sum(), 1.0, rtol=1e-4)


def test_artifacts_registry_shapes():
    assert set(model.ARTIFACTS) == {"pagerank_update", "minrelax_f32", "minrelax_i32"}
    for name, (fn, args) in model.ARTIFACTS.items():
        for spec in args:
            assert spec.shape in ((BLOCK,), (1,))
        # lowering must succeed for every artifact
        jax.jit(fn).lower(*args)


def test_minrelax_i32_artifact_dtype():
    _, args = model.ARTIFACTS["minrelax_i32"]
    assert all(a.dtype == jnp.int32 for a in args)
