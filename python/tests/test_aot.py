"""AOT path tests: lowering produces parseable, entry-complete HLO text."""

import jax.numpy as jnp

from compile import aot, model
from compile.kernels import BLOCK


def _text(name: str) -> str:
    return aot.to_hlo_text(aot.lower_artifact(name))


def test_hlo_text_structure_pagerank():
    text = _text("pagerank_update")
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # 3 params: sums, deg, inv_n
    for i in range(3):
        assert f"parameter({i})" in text
    # return_tuple=True -> root is a tuple of the two outputs
    assert "tuple(" in text


def test_hlo_text_structure_minrelax():
    for name, dt in [("minrelax_f32", "f32"), ("minrelax_i32", "s32")]:
        text = _text(name)
        assert text.startswith("HloModule")
        assert f"{dt}[{BLOCK}]" in text, f"{name} missing {dt} block param"
        assert "minimum(" in text


def test_no_custom_calls_in_artifacts():
    # interpret=True must lower pallas to plain HLO: a Mosaic custom-call
    # would be unloadable by the CPU PJRT client.
    for name in model.ARTIFACTS:
        assert "custom-call" not in _text(name), f"{name} contains custom-call"


def test_artifact_ids_fit_text_roundtrip():
    # HLO text must not contain huge instruction ids (the reason we use text
    # interchange at all); smoke: text is ascii and non-trivial.
    for name in model.ARTIFACTS:
        t = _text(name)
        assert len(t) > 200
        t.encode("ascii")
