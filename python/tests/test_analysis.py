"""Static-analysis module tests: I/O accounting, census, roofline sanity."""

from compile import analysis, aot, model
from compile.kernels import BLOCK


def test_io_bytes_pagerank():
    i, o = analysis.artifact_io_bytes("pagerank_update")
    assert i == 4 * BLOCK + 4 * BLOCK + 4  # sums + deg + inv_n
    assert o == 8 * BLOCK


def test_census_counts_ops_and_no_matmuls():
    text = aot.to_hlo_text(aot.lower_artifact("minrelax_f32"))
    census = analysis.op_census(text)
    assert census.get("minimum", 0) >= 1
    assert "dot" not in census
    assert "convolution" not in census


def test_roofline_scales_linearly():
    a = analysis.roofline_mvert_per_sec(10, "pagerank_update")
    b = analysis.roofline_mvert_per_sec(100, "pagerank_update")
    assert abs(b / a - 10.0) < 1e-6
    assert a > 0


def test_vmem_footprint_under_tpu_budget():
    assert analysis.tile_vmem_bytes() < 16 * 1024 * 1024  # 16 MiB VMEM


def test_all_artifacts_analyzable():
    for name in model.ARTIFACTS:
        i, o = analysis.artifact_io_bytes(name)
        assert i > 0 and o > 0
