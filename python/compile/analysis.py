"""L2/L1 static analysis: bytes-moved, op census and roofline estimates for
the AOT artifacts (the paper-side performance accounting of DESIGN.md
§Perf / §Hardware-Adaptation).

Usage::

    cd python && python -m compile.analysis

For each artifact it reports
  * parameter/result bytes per invocation (the HBM traffic bound),
  * the elementwise-op census of the lowered HLO (no dots/convs — the
    kernels are VPU/bandwidth-bound by design),
  * the VMEM footprint of one Pallas tile (3 live tiles x 4 B each), and
  * the bandwidth-roofline throughput at a given memory bandwidth.
"""

import re

from . import aot, model
from .kernels import BLOCK, TILE

#: bytes per element for the dtypes we emit
_DT_BYTES = {"f32": 4, "s32": 4, "pred": 1}


def artifact_io_bytes(name: str) -> tuple[int, int]:
    """(input_bytes, output_bytes) of one artifact invocation."""
    _, args = model.ARTIFACTS[name]
    in_bytes = sum(int(a.dtype.itemsize) * _prod(a.shape) for a in args)
    # outputs: every artifact returns two BLOCK-length arrays
    out_bytes = 2 * 4 * BLOCK
    return in_bytes, out_bytes


def _prod(shape) -> int:
    p = 1
    for s in shape:
        p *= int(s)
    return p


def op_census(hlo_text: str) -> dict[str, int]:
    """Count HLO op kinds in the entry computation (rough but stable)."""
    census: dict[str, int] = {}
    for m in re.finditer(r"=\s*(?:\w+\[[^\]]*\][^ ]*\s+)?(\w+)\(", hlo_text):
        op = m.group(1)
        census[op] = census.get(op, 0) + 1
    return census

def tile_vmem_bytes() -> int:
    """Live VMEM per grid step: 3 operand/result tiles of f32."""
    return 3 * TILE * 4


def roofline_mvert_per_sec(bandwidth_gbps: float, name: str) -> float:
    """Bandwidth-bound throughput bound in Mvertices/s."""
    i, o = artifact_io_bytes(name)
    bytes_per_vertex = (i + o) / BLOCK
    return bandwidth_gbps * 1e9 / bytes_per_vertex / 1e6


def main() -> None:
    for name in model.ARTIFACTS:
        text = aot.to_hlo_text(aot.lower_artifact(name))
        i, o = artifact_io_bytes(name)
        census = op_census(text)
        heavy = {k: v for k, v in census.items() if k in ("dot", "convolution")}
        print(f"== {name} ==")
        print(f"  block {BLOCK} vertices, tile {TILE} (grid {BLOCK // TILE})")
        print(f"  I/O per call: {i} B in, {o} B out ({(i + o) / BLOCK:.1f} B/vertex)")
        print(f"  VMEM per grid step: {tile_vmem_bytes() / 1024:.0f} KiB (3 live tiles)")
        print(f"  op census: {dict(sorted(census.items(), key=lambda kv: -kv[1]))}")
        assert not heavy, "kernels must stay elementwise (VPU-bound)"
        for bw in (10, 100, 900):  # laptop DDR, server DDR, TPU HBM (GB/s)
            print(f"  roofline @ {bw:>3} GB/s: {roofline_mvert_per_sec(bw, name):8.0f} Mvert/s")
    print("\nmeasured (cargo bench ablation_xla, CPU PJRT): ~89 Mvert/s;")
    print("scalar rust fallback: ~330 Mvert/s — both far under the DDR roofline,")
    print("i.e. call/copy overhead-bound at this block size, not bandwidth-bound.")


if __name__ == "__main__":
    main()
