"""AOT compile path: lower the L2 graphs to HLO **text** artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Usage (from ``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Writes one ``<name>.hlo.txt`` per entry in ``model.ARTIFACTS`` plus a
``MANIFEST`` (name, block size, input/output dtypes) the Rust runtime
sanity-checks at load time.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import BLOCK, TILE


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(name: str):
    fn, args = model.ARTIFACTS[name]
    return jax.jit(fn).lower(*args)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated artifact names (default: all)"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = args.only.split(",") if args.only else list(model.ARTIFACTS)
    manifest_lines = [f"block={BLOCK}", f"tile={TILE}"]
    for name in names:
        lowered = lower_artifact(name)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        _, arg_specs = model.ARTIFACTS[name]
        sig = ",".join(f"{s.dtype}[{'x'.join(map(str, s.shape))}]" for s in arg_specs)
        manifest_lines.append(f"artifact={name} args={sig}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "MANIFEST"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {os.path.join(args.out_dir, 'MANIFEST')}")


if __name__ == "__main__":
    main()
