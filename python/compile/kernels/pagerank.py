"""Pallas kernel: PageRank block vertex update.

Given, for one block of vertices on one machine,
  * ``sums``  — combined incoming message values (A_r in the paper, with
                identity element e0 = 0),
  * ``deg``   — out-degrees d(v) as f32,
  * ``inv_n`` — the scalar 1/|V| broadcast as a (1,) array,
compute
  * ``val``   — new PageRank value  0.15/|V| + 0.85 * sums,
  * ``msg``   — outgoing message value val/d(v) (0 for sinks), which Rust
                fans out along the edge stream S^E.

This is the numeric body of a PageRank superstep in [12]'s formulation as
used by GraphD; everything else (streams, combining, routing) is Layer-3.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(inv_n_ref, sums_ref, deg_ref, val_ref, msg_ref):
    inv_n = inv_n_ref[0]
    s = sums_ref[...]
    d = deg_ref[...]
    val = 0.15 * inv_n + 0.85 * s
    val_ref[...] = val
    # Sinks (d == 0) emit no mass; guard the divide so padding lanes with
    # d = 0 stay finite.
    msg_ref[...] = jnp.where(d > 0.0, val / jnp.maximum(d, 1.0), 0.0)


def pagerank_block(sums: jax.Array, deg: jax.Array, inv_n: jax.Array):
    """Run the PageRank update over one block.

    Args:
      sums:  f32[B] combined message sums.
      deg:   f32[B] out-degrees.
      inv_n: f32[1] scalar 1/|V|.

    Returns:
      (val, msg): f32[B] new values and f32[B] outgoing message values.
    """
    (b,) = sums.shape
    from . import TILE

    tile = min(TILE, b)
    assert b % tile == 0, f"block size {b} must be a multiple of tile {tile}"
    grid = (b // tile,)
    out_shape = (
        jax.ShapeDtypeStruct((b,), jnp.float32),
        jax.ShapeDtypeStruct((b,), jnp.float32),
    )
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),     # inv_n: same tiny block every step
            pl.BlockSpec((tile,), lambda i: (i,)),  # sums tile
            pl.BlockSpec((tile,), lambda i: (i,)),  # deg tile
        ],
        out_specs=(
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ),
        out_shape=out_shape,
        interpret=True,
    )(inv_n, sums, deg)
