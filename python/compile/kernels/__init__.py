"""Layer-1 Pallas kernels for GraphD block vertex updates.

The recoded-mode hot path of GraphD digests combined messages into dense
per-machine arrays (A_r).  A superstep's numeric work is therefore a pure
block update over contiguous arrays — exactly the shape Pallas wants.  The
kernels here are lowered (inside the L2 jax functions in ``model.py``) to
HLO text once at build time and executed from Rust via PJRT.

All kernels run with ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls, and interpret-mode lowering produces plain HLO that is
portable to any backend.

Tiling: arrays are processed in blocks of ``BLOCK`` vertices, with a Pallas
grid over ``TILE``-sized tiles.  TILE was swept in the perf pass
(EXPERIMENTS.md §Perf): on CPU-PJRT the per-grid-step overhead of the
interpret lowering dominates, so TILE == BLOCK (grid=1) is fastest; the
VMEM footprint 3 x 65536 x 4 B = 0.75 MiB still sits far below a TPU's
~16 MiB VMEM, so the same BlockSpec remains valid on real hardware (where
smaller tiles + double buffering would be re-enabled).  See DESIGN.md
`Hardware-Adaptation`.
"""

BLOCK = 65536  # vertices per AOT executable invocation (rust pads the tail)
TILE = 65536  # == BLOCK: grid of 1 (see perf note above)

from . import pagerank, minrelax, ref  # noqa: E402,F401
