"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

pytest (and hypothesis sweeps) assert the Pallas kernels match these bitwise
(or to tight float tolerance) across shapes and dtypes.  The Rust scalar
fallback in ``rust/src/runtime`` mirrors the same formulas so all three
implementations can be cross-checked.
"""

import jax.numpy as jnp


def pagerank_block_ref(sums, deg, inv_n):
    """Reference PageRank block update; see kernels.pagerank."""
    val = 0.15 * inv_n[0] + 0.85 * sums
    msg = jnp.where(deg > 0.0, val / jnp.maximum(deg, 1.0), 0.0)
    return val, msg


def minrelax_block_ref(cur, msg):
    """Reference min-relax block update; see kernels.minrelax."""
    new = jnp.minimum(cur, msg)
    changed = (new < cur).astype(jnp.int32)
    return new, changed
