"""Pallas kernel: min-combine block vertex update (Hash-Min / SSSP).

GraphD's recoded mode digests messages with a MIN combiner into A_r
(identity element e0 = +inf / INT_MAX).  The per-superstep vertex update is

    new     = min(cur, combined_msg)
    changed = new < cur          (the vertex is reactivated and must send)

used by both Hash-Min connected components (labels, i32) and SSSP
(distances, f32).  Outgoing per-edge messages (new + w(u,v), or the label
itself) are fanned out by Rust along the edge stream.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cur_ref, msg_ref, new_ref, chg_ref):
    c = cur_ref[...]
    m = msg_ref[...]
    n = jnp.minimum(c, m)
    new_ref[...] = n
    chg_ref[...] = (n < c).astype(jnp.int32)


def minrelax_block(cur: jax.Array, msg: jax.Array):
    """Min-relax one block.

    Args:
      cur: [B] current values (f32 distances or i32 labels).
      msg: [B] combined incoming minima (identity = +inf / INT_MAX).

    Returns:
      (new, changed): [B] updated values, i32[B] 0/1 change mask.
    """
    (b,) = cur.shape
    from . import TILE

    tile = min(TILE, b)
    assert b % tile == 0, f"block size {b} must be a multiple of tile {tile}"
    grid = (b // tile,)
    out_shape = (
        jax.ShapeDtypeStruct((b,), cur.dtype),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    )
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=(
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ),
        out_shape=out_shape,
        interpret=True,
    )(cur, msg)
