"""Layer-2: jax compute graphs for GraphD's per-superstep block updates.

Each function here is a jit-able graph over fixed ``BLOCK``-sized arrays
that calls the Layer-1 Pallas kernels.  ``aot.py`` lowers them once to HLO
text; Rust (``rust/src/runtime``) loads + compiles those artifacts at
startup and executes them on the recoded-mode hot path.  Python is never on
the request path.

A dense whole-graph PageRank (``pagerank_dense_ref``) is also provided as a
model-level oracle: python/tests uses it to validate that iterating the
block update reproduces the textbook power iteration.
"""

import jax
import jax.numpy as jnp

from .kernels import BLOCK, pagerank, minrelax


def pagerank_update(sums, deg, inv_n):
    """One PageRank block update (see kernels.pagerank).

    f32[B] sums, f32[B] deg, f32[1] inv_n -> (f32[B] val, f32[B] msg).
    """
    return pagerank.pagerank_block(sums, deg, inv_n)


def minrelax_f32(cur, msg):
    """SSSP min-relax block update: f32 distances."""
    return minrelax.minrelax_block(cur, msg)


def minrelax_i32(cur, msg):
    """Hash-Min min-relax block update: i32 component labels."""
    return minrelax.minrelax_block(cur, msg)


#: artifact name -> (function, example-argument ShapeDtypeStructs)
ARTIFACTS = {
    "pagerank_update": (
        pagerank_update,
        (
            jax.ShapeDtypeStruct((BLOCK,), jnp.float32),
            jax.ShapeDtypeStruct((BLOCK,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ),
    ),
    "minrelax_f32": (
        minrelax_f32,
        (
            jax.ShapeDtypeStruct((BLOCK,), jnp.float32),
            jax.ShapeDtypeStruct((BLOCK,), jnp.float32),
        ),
    ),
    "minrelax_i32": (
        minrelax_i32,
        (
            jax.ShapeDtypeStruct((BLOCK,), jnp.int32),
            jax.ShapeDtypeStruct((BLOCK,), jnp.int32),
        ),
    ),
}


def pagerank_dense_ref(adj: jnp.ndarray, iters: int) -> jnp.ndarray:
    """Dense power-iteration PageRank oracle over an adjacency matrix.

    ``adj[u, v] = 1`` iff edge u->v.  Matches Pregel's formulation: sinks
    simply leak mass (no redistribution), exactly like the message model.
    """
    n = adj.shape[0]
    deg = adj.sum(axis=1)
    r = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    for _ in range(iters):
        contrib = jnp.where(deg > 0, r / jnp.maximum(deg, 1.0), 0.0)
        sums = contrib @ adj.astype(jnp.float32)
        r = 0.15 / n + 0.85 * sums
    return r
