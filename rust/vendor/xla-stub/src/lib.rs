//! Compile-only stand-in for the `xla` PJRT-bindings crate.
//!
//! Mirrors the subset of the API that `graphd::runtime::pjrt` and the AOT
//! round-trip test use — client/compile/execute plus [`Literal`]
//! marshalling — so `cargo check --features xla` keeps the feature-gated
//! bridge honest on machines (and CI runners) that have neither the real
//! bindings nor a PJRT plugin.  Every entry point that would touch PJRT
//! returns [`stub_err`] at runtime: the feature *compiles* everywhere,
//! *executes* only against the real crate (swap the path dependency in
//! rust/Cargo.toml, see README.md §XLA).

const STUB_MSG: &str = "xla-stub: PJRT runtime not linked — replace the vendored \
     xla-stub/anyhow-stub path dependencies with the real `xla` and `anyhow` \
     crates to execute HLO artifacts";

fn stub_err() -> anyhow::Error {
    anyhow::Error::msg(STUB_MSG)
}

/// Element types a [`Literal`] can be built from (stub: f32/i32, the two
/// the artifacts use).
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Element types a [`Literal`] can be read back as.
pub trait ArrayElement: Copy {}
impl ArrayElement for f32 {}
impl ArrayElement for i32 {}

/// A PJRT CPU client.
pub struct PjRtClient;

impl PjRtClient {
    /// Construct a CPU client.  Stub: always fails.
    pub fn cpu() -> anyhow::Result<Self> {
        Err(stub_err())
    }

    /// Compile a computation for this client.  Stub: always fails.
    pub fn compile(&self, _c: &XlaComputation) -> anyhow::Result<PjRtLoadedExecutable> {
        Err(stub_err())
    }
}

/// A parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file.  Stub: always fails.
    pub fn from_text_file(_path: &str) -> anyhow::Result<Self> {
        Err(stub_err())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_p: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments, yielding per-device, per-output
    /// buffers.  Stub: always fails (unreachable in practice — a stub
    /// executable cannot be constructed).
    pub fn execute<T>(&self, _args: &[T]) -> anyhow::Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err())
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host [`Literal`].  Stub: always fails.
    pub fn to_literal_sync(&self) -> anyhow::Result<Literal> {
        Err(stub_err())
    }
}

/// A host-side literal value.
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal
    }

    /// Destructure a tuple literal.  Stub: always fails.
    pub fn to_tuple(self) -> anyhow::Result<Vec<Literal>> {
        Err(stub_err())
    }

    /// Read the literal back as a host vector.  Stub: always fails.
    pub fn to_vec<T: ArrayElement>(&self) -> anyhow::Result<Vec<T>> {
        Err(stub_err())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_runtime_entry_fails_with_the_stub_message() {
        assert!(format!("{}", PjRtClient::cpu().unwrap_err()).contains("xla-stub"));
        assert!(HloModuleProto::from_text_file("x").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.to_vec::<f32>().is_err());
        assert!(Literal.to_tuple().is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
    }
}
