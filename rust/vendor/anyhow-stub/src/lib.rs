//! Compile-only stand-in for the `anyhow` crate.
//!
//! The offline build carries no external dependencies, but CI still wants
//! `cargo check --features xla` to catch rot in the feature-gated PJRT
//! bridge (`graphd::runtime::pjrt`).  This stub mirrors the minimal
//! `anyhow` surface that code uses — an opaque [`Error`] convertible from
//! any `std::error::Error`, with `{:#}` Display — so the bridge
//! *typechecks* everywhere.  Executing it requires swapping in the real
//! `anyhow` (and `xla`) crates; see the workspace README.
//!
//! Mirrors anyhow's design point: [`Error`] deliberately does **not**
//! implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` impl coherent.

use std::fmt;

/// Opaque error: a rendered message (the stub never carries rich chains).
pub struct Error(String);

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` (anyhow's chain format) and `{}` both print the message.
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error(e.to_string())
    }
}

/// `anyhow::Result`, defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_alternate_agree() {
        let e = Error::msg("boom");
        assert_eq!(format!("{e}"), "boom");
        assert_eq!(format!("{e:#}"), "boom");
        assert_eq!(format!("{e:?}"), "boom");
    }

    #[test]
    fn converts_from_std_errors() {
        fn f() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::Other, "io"))?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "io");
    }
}
