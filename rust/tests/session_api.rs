//! Integration tests for the fluent session API: builder defaults and
//! overrides, `Mode::Auto` resolution with and without HLO artifacts,
//! checkpoint → resume through `JobBuilder`, and the deprecation shims'
//! parity with `Session::run`.

use graphd::algos::PageRank;
use graphd::config::Mode;
use graphd::ft::{self, CheckpointCfg};
use graphd::graph::generator;
use graphd::{GraphD, GraphSource, Xla};
use std::path::PathBuf;
use std::sync::Arc;

fn wd(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "graphd_sessapi_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn builder_defaults_and_job_overrides() {
    let d = wd("defaults");
    let session = GraphD::builder().workdir(&d).build().unwrap();
    // Paper-default tunables and the 4-machine test profile.
    assert_eq!(session.profile().machines, 4);
    assert_eq!(session.config().stream_buf, 64 * 1024);
    assert_eq!(session.config().oms_file_cap, 8 * 1024 * 1024);
    assert_eq!(session.config().merge_k, 1000);
    assert_eq!(session.config().mode, Mode::Basic);

    // A per-job superstep cap overrides the session default (unlimited).
    let g = generator::uniform(100, 500, true, 2);
    let graph = session.load(GraphSource::InMemory(&g)).unwrap();
    let res = graph
        .job(Arc::new(PageRank::new(4)))
        .max_supersteps(4)
        .run()
        .unwrap();
    assert_eq!(res.supersteps(), 4);
    let _ = std::fs::remove_dir_all(&d);
}

#[test]
fn mode_auto_selection_with_and_without_artifacts() {
    let d = wd("auto");
    let arts = d.join("fake_artifacts");
    std::fs::create_dir_all(&arts).unwrap();
    let g = generator::uniform(150, 700, true, 3);

    let session = GraphD::builder()
        .workdir(d.join("sess"))
        .machines(3)
        .max_supersteps(5)
        .artifacts_dir(&arts)
        .build()
        .unwrap();
    let mut graph = session
        .load(GraphSource::InMemorySparse(&g, 17))
        .unwrap();

    // Before recoding: Auto must fall back to IO-Basic.
    let plan = graph.job(Arc::new(PageRank::new(5))).mode(Mode::Auto).plan();
    assert_eq!(plan.mode, Mode::Basic);
    assert!(!plan.use_xla);
    let basic = graph
        .job(Arc::new(PageRank::new(5)))
        .mode(Mode::Auto)
        .run()
        .unwrap();

    // After recoding, no artifacts: Auto picks IO-Recoded, scalar kernels.
    graph.recode().unwrap();
    let plan = graph.job(Arc::new(PageRank::new(5))).mode(Mode::Auto).plan();
    assert_eq!(plan.mode, Mode::Recoded);
    assert!(!plan.artifacts_present);
    assert!(!plan.use_xla);
    let recoded = graph
        .job(Arc::new(PageRank::new(5)))
        .mode(Mode::Auto)
        .run()
        .unwrap();

    // IO-Basic and IO-Recoded agree on the ranks.
    for ((ia, va), (ib, vb)) in basic
        .values_by_id()
        .iter()
        .zip(recoded.values_by_id().iter())
    {
        assert_eq!(ia, ib);
        assert!((va - vb).abs() < 1e-5 * (1.0 + va.abs()), "{ia}: {va} vs {vb}");
    }

    // With an artifact file present, Auto turns the XLA request on (plan
    // only — the fake artifact is not executable) and Off still wins.
    std::fs::write(arts.join("pagerank_update.hlo.txt"), "fake").unwrap();
    let plan = graph.job(Arc::new(PageRank::new(5))).mode(Mode::Auto).plan();
    assert_eq!(plan.mode, Mode::Recoded);
    assert!(plan.artifacts_present);
    assert!(plan.use_xla);
    let plan = graph
        .job(Arc::new(PageRank::new(5)))
        .mode(Mode::Auto)
        .xla(Xla::Off)
        .plan();
    assert!(!plan.use_xla);
    let _ = std::fs::remove_dir_all(&d);
}

#[test]
fn checkpoint_resume_roundtrip_through_job_builder() {
    let d = wd("ckpt");
    let g = generator::uniform(200, 1000, true, 11);
    let session = GraphD::builder()
        .machines(3)
        .workdir(&d)
        .max_supersteps(6)
        .build()
        .unwrap();
    let graph = session
        .load(GraphSource::InMemorySparse(&g, 23))
        .unwrap();

    let full = graph.run(Arc::new(PageRank::new(6))).unwrap();

    let ck = CheckpointCfg {
        dir: d.join("dfs/ck"),
        every: 2,
    };
    graph
        .job(Arc::new(PageRank::new(6)))
        .checkpoint(ck.clone())
        .run()
        .unwrap();
    let restart = ft::latest_checkpoint(&ck.dir, Some(4)).expect("checkpoint exists");
    let resumed = graph
        .job(Arc::new(PageRank::new(6)))
        .checkpoint(ck)
        .resume(restart)
        .run()
        .unwrap();
    assert_eq!(resumed.metrics.supersteps, 6);

    for ((ia, va), (ib, vb)) in full
        .values_by_id()
        .iter()
        .zip(resumed.values_by_id().iter())
    {
        assert_eq!(ia, ib);
        assert!((va - vb).abs() < 1e-6, "{ia}: {va} vs {vb}");
    }
    let _ = std::fs::remove_dir_all(&d);
}

#[test]
fn deprecated_shims_match_session_run() {
    // The old free-function pipeline and the new Session::run must produce
    // identical values_by_id() for the same input.
    let d = wd("shim");
    let g = generator::uniform(180, 900, true, 29);

    // Old API (deprecated shims, kept for out-of-tree code).
    #[allow(deprecated)]
    let old = {
        use graphd::config::{ClusterProfile, JobConfig};
        use graphd::dfs::Dfs;
        use graphd::engine::{load, run, Engine};
        let mut cfg = JobConfig::default();
        cfg.workdir = d.join("old");
        cfg.max_supersteps = 5;
        let eng = Engine::new(ClusterProfile::test(3), cfg).unwrap();
        let dfs = Dfs::new(&d.join("old/dfs")).unwrap();
        load::put_graph(&dfs, "g.txt", &g, Some(7)).unwrap();
        let stores = load::load_text(&eng, &dfs, "g.txt", false).unwrap();
        run::run_job(&eng, &stores, Arc::new(PageRank::new(5)))
            .unwrap()
            .values_by_id()
    };

    // New API.
    let session = GraphD::builder()
        .machines(3)
        .workdir(d.join("new"))
        .max_supersteps(5)
        .build()
        .unwrap();
    let new = session
        .run(GraphSource::InMemorySparse(&g, 7), Arc::new(PageRank::new(5)))
        .unwrap()
        .values_by_id();

    assert_eq!(old.len(), new.len());
    for ((ia, va), (ib, vb)) in old.iter().zip(new.iter()) {
        assert_eq!(ia, ib);
        assert!((va - vb).abs() < 1e-6, "{ia}: {va} vs {vb}");
    }
    let _ = std::fs::remove_dir_all(&d);
}
