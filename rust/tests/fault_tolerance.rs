//! Fault-tolerance integration tests (§3.4): checkpoint + recover must be
//! exact, in both modes, for both incoming representations — driven
//! through the session API's per-job checkpoint/resume knobs.

use graphd::algos::{PageRank, Sssp};
use graphd::config::Mode;
use graphd::ft::{self, CheckpointCfg};
use graphd::graph::generator;
use graphd::{GraphD, GraphSource};
use std::path::PathBuf;
use std::sync::Arc;

fn wd(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "graphd_fttest_{name}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn recovery_is_exact_basic_mode() {
    let d = wd("basic");
    let g = generator::uniform(300, 1500, true, 5);
    let session = GraphD::builder()
        .machines(3)
        .workdir(&d)
        .max_supersteps(8)
        .build()
        .unwrap();
    let graph = session
        .load(GraphSource::InMemorySparse(&g, 3))
        .unwrap();

    let full = graph.run(Arc::new(PageRank::new(8))).unwrap();

    let ck = CheckpointCfg {
        dir: d.join("dfs/ck"),
        every: 3,
    };
    graph
        .job(Arc::new(PageRank::new(8)))
        .checkpoint(ck.clone())
        .run()
        .unwrap();
    let restart = ft::latest_checkpoint(&ck.dir, Some(6)).expect("checkpoint exists");
    assert_eq!(restart, 5);
    let rec = graph
        .job(Arc::new(PageRank::new(8)))
        .checkpoint(ck)
        .resume(restart)
        .run()
        .unwrap();
    assert_eq!(rec.metrics.supersteps, 8);

    for ((ia, va), (ib, vb)) in full.values_by_id().iter().zip(rec.values_by_id().iter()) {
        assert_eq!(ia, ib);
        assert!((va - vb).abs() < 1e-6, "{ia}: {va} vs {vb}");
    }
    let _ = std::fs::remove_dir_all(&d);
}

#[test]
fn recovery_is_exact_recoded_mode_sssp() {
    // Digested (A_r) checkpoints + a halting algorithm: recovery must
    // restore the halted bitmap, or converged vertices would re-send.
    let d = wd("rec");
    let g = generator::uniform(240, 1200, true, 6).with_unit_weights();
    let session = GraphD::builder()
        .machines(4)
        .workdir(&d)
        .mode(Mode::Recoded)
        .build()
        .unwrap();
    let mut graph = session
        .load(GraphSource::InMemorySparse(&g, 8))
        .unwrap();
    graph.recode().unwrap();
    let src = {
        // translate dense 0 -> sparse -> recoded
        let mut ids: Vec<u32> = graph
            .stores()
            .iter()
            .flat_map(|s| s.ids.iter().copied())
            .collect();
        ids.sort_unstable();
        graph.current_id_of(ids[0])
    };

    let full = graph.run(Arc::new(Sssp::new(src))).unwrap();
    let steps = full.metrics.supersteps;
    assert!(steps > 4, "need enough steps to checkpoint, got {steps}");

    let ck = CheckpointCfg {
        dir: d.join("dfs/ck"),
        every: 2,
    };
    graph
        .job(Arc::new(Sssp::new(src)))
        .checkpoint(ck.clone())
        .run()
        .unwrap();
    let restart = ft::latest_checkpoint(&ck.dir, Some(steps - 2)).expect("ckpt");
    let rec = graph
        .job(Arc::new(Sssp::new(src)))
        .checkpoint(ck)
        .resume(restart)
        .run()
        .unwrap();

    for ((ia, va), (ib, vb)) in full.values_by_id().iter().zip(rec.values_by_id().iter()) {
        assert_eq!(ia, ib);
        if va.is_finite() || vb.is_finite() {
            assert!((va - vb).abs() < 1e-4, "{ia}: {va} vs {vb}");
        }
    }
    let _ = std::fs::remove_dir_all(&d);
}

#[test]
fn fast_replay_recovery_matches_fault_free_values() {
    // Acceptance: a faulted, checkpointed run with keep_oms_for_recovery
    // auto-resumes through the fast-replay path (replaying the retained
    // S^I message logs instead of recomputing senders) and produces the
    // same values as a fault-free run.  The replay path is asserted via
    // the trace: Fault, Recovery and Replay events must all appear.
    let d = wd("replay");
    let trace_path = d.join("replay_trace.json");
    let g = generator::uniform(150, 900, true, 31);
    let session = GraphD::builder()
        .machines(2)
        .workdir(&d)
        .max_supersteps(6)
        .keep_oms_for_recovery(true)
        .config("trace", "true")
        .config("trace_path", trace_path.to_str().unwrap())
        .config("checkpoint_every", "2")
        .config("retry", "2")
        .config("fault", "us_io@m1s3")
        .build()
        .unwrap();
    let graph = session.load(GraphSource::InMemorySparse(&g, 3)).unwrap();
    let rec = graph.run(Arc::new(PageRank::new(6))).unwrap();
    assert!(rec.metrics.recoveries >= 1, "fault did not trigger recovery");

    // Fault-free reference in a separate session.
    let d2 = wd("replay_ref");
    let s2 = GraphD::builder()
        .machines(2)
        .workdir(&d2)
        .max_supersteps(6)
        .build()
        .unwrap();
    let g2 = s2.load(GraphSource::InMemorySparse(&g, 3)).unwrap();
    let clean = g2.run(Arc::new(PageRank::new(6))).unwrap();
    for ((ia, va), (ib, vb)) in clean.values_by_id().iter().zip(rec.values_by_id().iter()) {
        assert_eq!(ia, ib);
        assert!((va - vb).abs() < 1e-6, "{ia}: {va} vs {vb}");
    }

    let text = std::fs::read_to_string(&trace_path).expect("trace export");
    for name in ["\"fault\"", "\"recovery\"", "\"replay\""] {
        assert!(text.contains(name), "trace missing {name} events");
    }
    let _ = std::fs::remove_dir_all(&d);
    let _ = std::fs::remove_dir_all(&d2);
}

#[test]
fn replay_manifest_written_and_verifiable() {
    // keep_oms runs append one replay_manifest line per superstep per
    // machine, each naming an S^I file that exists with the recorded size
    // — the substrate the engine's replay-window scan verifies.
    let d = wd("manifest");
    let g = generator::uniform(120, 600, true, 37);
    let session = GraphD::builder()
        .machines(2)
        .workdir(&d)
        .max_supersteps(3)
        .keep_oms_for_recovery(true)
        .build()
        .unwrap();
    session
        .run(GraphSource::InMemory(&g), Arc::new(PageRank::new(3)))
        .unwrap();

    for m in 0..2 {
        let job = d.join(format!("m{m}/basic/job"));
        let text = std::fs::read_to_string(job.join("replay_manifest"))
            .expect("manifest written under keep_oms_for_recovery");
        let mut steps = 0;
        for line in text.lines() {
            let f: Vec<&str> = line.split_whitespace().collect();
            assert_eq!(f.len(), 4, "bad manifest line: {line}");
            let bytes: u64 = f[3].parse().unwrap();
            let si = job.join(f[1]);
            assert_eq!(
                std::fs::metadata(&si).map(|md| md.len()).ok(),
                Some(bytes),
                "manifest size mismatch for {line}"
            );
            steps += 1;
        }
        assert_eq!(steps, 3, "one manifest line per superstep");
    }
    let _ = std::fs::remove_dir_all(&d);
}

#[test]
fn message_logs_retained_for_fast_recovery() {
    // keep_oms_for_recovery: sent OMS files survive on local disk (the
    // [19]-style message-log fast recovery substrate).
    let d = wd("log");
    let g = generator::uniform(120, 600, true, 7);
    let session = GraphD::builder()
        .machines(2)
        .workdir(&d)
        .max_supersteps(3)
        .keep_oms_for_recovery(true)
        .build()
        .unwrap();
    session
        .run(GraphSource::InMemory(&g), Arc::new(PageRank::new(3)))
        .unwrap();

    let mut logged = 0;
    for m in 0..2 {
        for dst in 0..2 {
            let dir = d.join(format!("m{m}/basic/job/oms_{dst}"));
            if let Ok(rd) = std::fs::read_dir(dir) {
                logged += rd.count();
            }
        }
    }
    assert!(logged > 0, "OMS message logs were garbage collected");
    let _ = std::fs::remove_dir_all(&d);
}
