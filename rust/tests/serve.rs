//! Serve-subsystem integration tests: k-lane multi-source correctness
//! against the in-memory oracle, per-lane early termination, and the
//! end-to-end query server over a `query_set` workload.

use graphd::algos::multisource::{MultiSssp, NO_VERTEX};
use graphd::config::Mode;
use graphd::graph::{generator, reference, Graph};
use graphd::serve::{Answer, Query, ServeConfig};
use graphd::{GraphD, GraphSource, Session};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

fn fresh_workdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "graphd_serve_it_{name}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn setup(name: &str, machines: usize) -> Session {
    GraphD::builder()
        .machines(machines)
        .workdir(fresh_workdir(name))
        .oms_file_cap(16 * 1024)
        .build()
        .unwrap()
}

fn cleanup(s: &Session) {
    let _ = std::fs::remove_dir_all(s.workdir());
}

/// A k-lane multi-source run must equal k independent single-source runs
/// against the Dijkstra oracle — BFS flavor (unit weights), dense ids.
#[test]
fn klane_bfs_matches_k_single_source_oracles() {
    let g = generator::uniform(180, 900, true, 61).with_unit_weights();
    let sources = [3u32, 77, 144, 9];

    for mode in [Mode::Basic, Mode::Recoded] {
        let s = setup(&format!("klane_bfs_{mode:?}"), 3);
        let mut graph = s.load(GraphSource::InMemory(&g)).unwrap();
        if mode == Mode::Recoded {
            graph.recode().unwrap();
        }
        let mut cur = [0u32; 4];
        for (l, &src) in sources.iter().enumerate() {
            cur[l] = graph.current_id_of(src);
        }
        let out = graph
            .job(Arc::new(MultiSssp::<4>::new(cur)))
            .mode(mode)
            .run()
            .unwrap();
        let got: HashMap<u32, [f32; 4]> = out.values_by_id().into_iter().collect();
        assert_eq!(got.len(), 180);
        for (l, &src) in sources.iter().enumerate() {
            let want = reference::sssp(&g, src);
            for v in 0..180u32 {
                let gv = got[&v][l];
                if want[v as usize].is_infinite() {
                    assert!(gv.is_infinite(), "{mode:?} lane {l} v={v} should be ∞");
                } else {
                    assert!(
                        (gv - want[v as usize]).abs() < 1e-3,
                        "{mode:?} lane {l} v={v}: got {gv}, want {}",
                        want[v as usize]
                    );
                }
            }
        }
        cleanup(&s);
    }
}

/// Same with real SSSP weights, including an idle lane (`NO_VERTEX`).
#[test]
fn klane_weighted_sssp_matches_oracles_with_idle_lane() {
    let g = generator::random_weights(generator::uniform(150, 700, true, 62), 5);
    let sources = [0u32, 50, NO_VERTEX, 149];

    for mode in [Mode::Basic, Mode::Recoded] {
        let s = setup(&format!("klane_w_{mode:?}"), 4);
        let mut graph = s.load(GraphSource::InMemory(&g)).unwrap();
        if mode == Mode::Recoded {
            graph.recode().unwrap();
        }
        let mut cur = [NO_VERTEX; 4];
        for (l, &src) in sources.iter().enumerate() {
            if src != NO_VERTEX {
                cur[l] = graph.current_id_of(src);
            }
        }
        let out = graph
            .job(Arc::new(MultiSssp::<4>::new(cur)))
            .mode(mode)
            .run()
            .unwrap();
        let got: HashMap<u32, [f32; 4]> = out.values_by_id().into_iter().collect();
        for (l, &src) in sources.iter().enumerate() {
            if src == NO_VERTEX {
                for v in 0..150u32 {
                    assert!(got[&v][l].is_infinite(), "idle lane {l} must stay ∞");
                }
                continue;
            }
            let want = reference::sssp(&g, src);
            for v in 0..150u32 {
                let gv = got[&v][l];
                if want[v as usize].is_infinite() {
                    assert!(gv.is_infinite(), "{mode:?} lane {l} v={v} should be ∞");
                } else {
                    assert!(
                        (gv - want[v as usize]).abs() < 1e-3,
                        "{mode:?} lane {l} v={v}: got {gv}, want {}",
                        want[v as usize]
                    );
                }
            }
        }
        cleanup(&s);
    }
}

/// Lanes that finish at very different depths must coexist: on a chain,
/// a source near the end settles in a few supersteps while a source at
/// the head needs the whole chain — the run takes max, not sum.
#[test]
fn klane_lanes_terminate_at_different_supersteps() {
    let g = generator::chain(120).with_unit_weights();
    let s = setup("klane_depths", 3);
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    let out = graph
        .job(Arc::new(MultiSssp::<2>::new([0, 110])))
        .run()
        .unwrap();
    // lane 0 runs the whole chain (120 supersteps), lane 1 only 10; the
    // shared loop runs to the deepest lane.
    assert_eq!(out.supersteps(), 120);
    let got: HashMap<u32, [f32; 2]> = out.values_by_id().into_iter().collect();
    assert_eq!(got[&119][0], 119.0);
    assert_eq!(got[&119][1], 9.0);
    assert_eq!(got[&115][1], 5.0);
    assert!(got[&50][1].is_infinite(), "chain is directed");
    cleanup(&s);
}

/// Per-lane early termination: a point-to-point query on a long chain
/// must stop almost immediately after its target settles instead of
/// traversing the whole graph.
#[test]
fn point_to_point_pruning_terminates_early() {
    let g = generator::chain(300).with_unit_weights();
    let s = setup("prune", 3);
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();

    // Without a target: the lane floods the whole chain.
    let full = graph
        .job(Arc::new(MultiSssp::<1>::new([0])))
        .run()
        .unwrap();
    assert_eq!(full.supersteps(), 300);

    // With target 12: the bound settles at distance 12 and suppresses the
    // frontier right after.
    let pruned = graph
        .job(Arc::new(MultiSssp::<1>::new([0]).with_targets([12])))
        .run()
        .unwrap();
    assert!(
        pruned.supersteps() <= 15,
        "pruning never fired: {} supersteps",
        pruned.supersteps()
    );
    let got: HashMap<u32, [f32; 1]> = pruned.values_by_id().into_iter().collect();
    assert_eq!(got[&12][0], 12.0, "target distance must still be exact");
    cleanup(&s);
}

/// End-to-end query server over a generated `query_set` workload, checked
/// against the oracle, in both basic and recoded serving modes.
#[test]
fn query_server_answers_query_set_against_oracle() {
    let g = generator::uniform(160, 640, true, 63).with_unit_weights();
    let pairs = generator::query_set(160, 13, 42);

    for recoded in [false, true] {
        let s = setup(&format!("qset_{recoded}"), 3);
        let mut graph = s.load(GraphSource::InMemory(&g)).unwrap();
        if recoded {
            graph.recode().unwrap();
        }
        let mut server = graph.serve(ServeConfig::default().lanes(4)).unwrap();
        server.submit_pairs(&pairs);
        let results = server.run_pending().unwrap();
        assert_eq!(results.len(), pairs.len());

        for (r, &(src, tgt)) in results.iter().zip(pairs.iter()) {
            assert_eq!(r.query, Query::Dist { source: src, target: tgt });
            let want = reference::sssp(&g, src)[tgt as usize];
            match r.answer {
                Answer::Dist(Some(d)) => {
                    assert!(
                        (d - want).abs() < 1e-3,
                        "recoded={recoded} {src}->{tgt}: got {d}, want {want}"
                    );
                }
                Answer::Dist(None) => {
                    assert!(want.is_infinite(), "recoded={recoded} {src}->{tgt} reachable");
                }
                ref a => panic!("unexpected answer {a:?}"),
            }
        }
        // 13 queries at k=4 → 4 batches; metrics must be self-consistent.
        let m = server.metrics();
        assert_eq!(m.queries, 13);
        assert_eq!(m.batches, 4);
        assert_eq!(m.latencies_secs.len(), 13);
        assert!(m.qps() > 0.0);
        assert!(m.edge_items_read > 0);
        cleanup(&s);
    }
}

/// Reachability + reach-count queries against the oracle, on an
/// undirected graph with several components.
#[test]
fn reachability_queries_match_components() {
    // Two disjoint rings → reachability is "same component".
    let mut adj = vec![Vec::new(); 40];
    for i in 0..20u32 {
        adj[i as usize] = vec![(i + 1) % 20, (i + 19) % 20];
        adj[20 + i as usize] = vec![20 + (i + 1) % 20, 20 + (i + 19) % 20];
    }
    let g = Graph::from_adj(adj, false);
    let s = setup("reach", 2);
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    let mut server = graph.serve(ServeConfig::default().lanes(4)).unwrap();
    server.submit(Query::Reach { source: 3, target: 17 }); // same ring
    server.submit(Query::Reach { source: 3, target: 25 }); // other ring
    server.submit(Query::ReachCount { source: 5 });
    server.submit(Query::ReachCount { source: 33 });
    let rs = server.run_pending().unwrap();
    assert_eq!(rs[0].answer, Answer::Reach(true));
    assert_eq!(rs[1].answer, Answer::Reach(false));
    assert_eq!(rs[2].answer, Answer::ReachCount(20));
    assert_eq!(rs[3].answer, Answer::ReachCount(20));
    cleanup(&s);
}

/// The serve path must also work over sparse input IDs: queries are
/// expressed in input space and translated internally.
#[test]
fn serving_sparse_ids_translates_queries() {
    let g = generator::chain(50).with_unit_weights();
    let s = setup("sparse", 3);
    let graph = s.load(GraphSource::InMemorySparse(&g, 31)).unwrap();
    let ids = graph.id_map().unwrap().to_vec(); // dense → sparse input id
    let mut server = graph.serve(ServeConfig::default().lanes(2)).unwrap();
    server.submit(Query::Dist { source: ids[4], target: ids[9] });
    server.submit(Query::Dist { source: ids[9], target: ids[4] });
    let rs = server.run_pending().unwrap();
    assert_eq!(rs[0].answer, Answer::Dist(Some(5.0)));
    assert_eq!(rs[1].answer, Answer::Dist(None)); // directed chain
    cleanup(&s);
}
