//! Integration tests for `graphd::analyze`: each rule fires at the
//! expected `file:line` in the fixture corpus (`tests/analyze_fixtures/`,
//! never compiled — see its README), pragmas suppress, and the real source
//! tree analyzes clean.
//!
//! Cargo runs integration tests with the package root (`rust/`) as the
//! working directory, so `tests/…` and `src` resolve relatively.

use graphd::analyze::{analyze_source, analyze_tree};
use std::path::Path;

fn fixture(rel: &str) -> String {
    let p = Path::new("tests/analyze_fixtures").join(rel);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

/// `(line, rule-id)` pairs of the unsuppressed findings in one fixture.
fn findings(rel: &str) -> Vec<(u32, &'static str)> {
    analyze_source(rel, &fixture(rel))
        .diagnostics
        .iter()
        .map(|d| (d.line, d.rule.id()))
        .collect()
}

#[test]
fn poison_safety_fires_at_expected_lines() {
    assert_eq!(
        findings("worker/poison.rs"),
        vec![(5, "poison-safety"), (9, "poison-safety")]
    );
}

#[test]
fn poison_safety_is_scoped_to_concurrency_dirs() {
    // The same source outside worker/…serve/ is not poison-scoped.
    let rep = analyze_source("util/poison.rs", &fixture("worker/poison.rs"));
    assert!(rep.diagnostics.is_empty(), "{:?}", rep.diagnostics);
}

#[test]
fn barrier_registration_fires_at_expected_lines() {
    assert_eq!(
        findings("worker/barrier.rs"),
        vec![(5, "barrier-registration"), (9, "barrier-registration")]
    );
}

#[test]
fn pool_leak_fires_at_expected_line_only() {
    // The recycled and wire-handoff fns are clean; only the leak fires.
    assert_eq!(findings("worker/pool.rs"), vec![(4, "pool-leak")]);
}

#[test]
fn sleep_slicing_fires_at_expected_line() {
    assert_eq!(findings("worker/sleep.rs"), vec![(4, "sleep-slicing")]);
}

#[test]
fn panic_hygiene_fires_outside_tests_only() {
    assert_eq!(
        findings("worker/panics.rs"),
        vec![(4, "panic-hygiene"), (9, "panic-hygiene")]
    );
}

#[test]
fn print_hygiene_fires_at_expected_lines() {
    assert_eq!(
        findings("worker/prints.rs"),
        vec![(4, "print-hygiene"), (8, "print-hygiene")]
    );
}

#[test]
fn print_hygiene_is_scoped_to_engine_dirs() {
    // The same prints outside worker/engine/net/serve are not findings.
    let rep = analyze_source("util/prints.rs", &fixture("worker/prints.rs"));
    assert!(rep.diagnostics.is_empty(), "{:?}", rep.diagnostics);
}

#[test]
fn pragmas_suppress_and_malformed_pragmas_report() {
    let rep = analyze_source("worker/pragmas.rs", &fixture("worker/pragmas.rs"));
    assert_eq!(rep.suppressed, 2, "{:?}", rep.diagnostics);
    let got: Vec<(u32, &str)> = rep.diagnostics.iter().map(|d| (d.line, d.rule.id())).collect();
    assert_eq!(
        got,
        vec![
            (13, "bad-pragma"),
            (14, "sleep-slicing"),
            (18, "bad-pragma"),
            (19, "sleep-slicing"),
        ]
    );
}

#[test]
fn clean_fixture_is_clean() {
    let rep = analyze_source("clean.rs", &fixture("clean.rs"));
    assert!(rep.diagnostics.is_empty(), "{:?}", rep.diagnostics);
    assert_eq!(rep.suppressed, 0);
}

#[test]
fn fixture_corpus_is_dirty_across_all_rules() {
    let rep = analyze_tree(Path::new("tests/analyze_fixtures")).unwrap();
    // The corpus is exactly the violations asserted file-by-file above —
    // `make analyze` on it must exit nonzero.
    assert_eq!(rep.diagnostics.len(), 14, "{:#?}", rep.diagnostics);
    assert_eq!(rep.suppressed, 2);
    let mut ids: Vec<&str> = rep.diagnostics.iter().map(|d| d.rule.id()).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(
        ids,
        vec![
            "bad-pragma",
            "barrier-registration",
            "panic-hygiene",
            "poison-safety",
            "pool-leak",
            "print-hygiene",
            "sleep-slicing",
        ]
    );
}

#[test]
fn real_tree_is_analyzer_clean() {
    let rep = analyze_tree(Path::new("src")).unwrap();
    let msgs: Vec<String> = rep.diagnostics.iter().map(|d| d.to_string()).collect();
    assert!(
        msgs.is_empty(),
        "source tree is not analyzer-clean:\n{}",
        msgs.join("\n")
    );
    // The tree's accepted violations all carry reasoned pragmas (the
    // centralized std-poison helpers, the sliced-wait helper, the disk
    // model's bounded nap, the baseline simulators, proptest_lite's
    // reporting panic, and the two pooled-constructor handoffs).
    assert!(rep.suppressed >= 8, "suppressed = {}", rep.suppressed);
    assert!(rep.files > 40, "files = {}", rep.files);
}
