// Fixture: pool-leak — a checkout with no recycle or approved handoff.

fn leak(pool: &BufPool) -> usize {
    let b = pool.take();
    b.len()
}

fn recycled(pool: &BufPool) {
    let b = pool.take();
    pool.put(b);
}

fn wire(pool: &BufPool, tx: &mut NetSender) -> Result<()> {
    let b = pool.take();
    tx.send(0, 0, Payload::Data(b))
}
