// Fixture: barrier-registration — barriers built with no JobAbort
// registration in the enclosing fn (the PR 5 deadlock class).

fn build(n: usize) -> Arc<Rendezvous<u64, u64>> {
    Rendezvous::new(n)
}

fn build_sync(n: usize) -> Arc<MachineSync> {
    MachineSync::new(n)
}

fn registered(n: usize, abort: &JobAbort) -> Arc<MachineSync> {
    let ms = MachineSync::new(n);
    abort.register(ms.clone());
    ms
}
