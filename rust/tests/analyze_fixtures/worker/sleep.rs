// Fixture: sleep-slicing — a raw sleep that cannot observe JobAbort.

fn nap() {
    std::thread::sleep(std::time::Duration::from_millis(50));
}
