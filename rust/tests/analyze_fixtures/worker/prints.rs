// print-hygiene fixture: raw prints in an engine module fire; tests don't.

fn loud_failure(unit: &str, machine: usize) {
    eprintln!("[graphd] {unit} of machine {machine} failed");
}

fn loud_progress(step: u64) {
    println!("superstep {step} done");
}

#[cfg(test)]
mod tests {
    fn prints_are_fine_in_tests() {
        println!("assert output freely here");
    }
}
