// Fixture: pragma engine — reasoned pragmas suppress, malformed ones report.

fn sliced_helper() {
    // analyze:allow(sleep-slicing): fixture — pretend this is the sliced helper
    std::thread::sleep(POLL);
}

fn trailing(m: &Mutex<u64>) -> u64 {
    *m.lock().unwrap() // analyze:allow(poison-safety): fixture — single-threaded probe
}

fn reasonless() {
    // analyze:allow(sleep-slicing)
    std::thread::sleep(POLL);
}

fn unknown_id() {
    // analyze:allow(sleep-slicing-typo): misspelled rule id
    std::thread::sleep(POLL);
}
