// Fixture: poison-safety — unwrap on a poisonable wait's Result.
// Never compiled; scanned by tests/analyze.rs.

fn swallow(rv: &Rendezvous<u64, u64>) -> u64 {
    rv.exchange(0, 1, |vs| vs.iter().sum()).unwrap()
}

fn swallow_lock(m: &Mutex<u64>) -> u64 {
    *m.lock().expect("poisoned")
}

fn propagates(ms: &MachineSync) -> Result<()> {
    ms.wait_recv_done(0)?;
    Ok(())
}
