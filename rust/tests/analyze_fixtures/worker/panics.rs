// Fixture: panic-hygiene — stray panics outside #[cfg(test)].

fn unfinished() {
    todo!()
}

fn stray(x: u32) {
    if x > 3 {
        panic!("boom");
    }
}

#[cfg(test)]
mod tests {
    fn fine() {
        panic!("tests may panic");
    }
}
