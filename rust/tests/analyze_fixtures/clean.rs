// Fixture: a clean file — no rule fires, nothing is suppressed.

fn propagate(ms: &MachineSync) -> Result<()> {
    ms.wait_recv_done(0)?;
    Ok(())
}

fn paired(pool: &BufPool) {
    let b = pool.take();
    pool.put(b);
}

fn registered(n: usize, abort: &JobAbort) -> Arc<Rendezvous<u64, u64>> {
    let rv = Rendezvous::new(n);
    abort.register(rv.clone());
    rv
}
