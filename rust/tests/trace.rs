//! End-to-end tests for the flight-recorder tracing spine: ring-buffer
//! semantics, the Chrome-trace export of a real multi-machine job (valid
//! JSON, balanced span pairs, one track per machine×unit), the crash-time
//! flight-recorder dump of an injected failure, and the serve loop's
//! live [`ServeStats`] snapshots.

use graphd::api::{Context, Edge, SumF32, VertexProgram};
use graphd::graph::generator;
use graphd::serve::ServeConfig;
use graphd::trace::{self, EventKind, EventPhase, TraceBuf, TraceConfig, TraceEvent};
use graphd::{Error, GraphD, GraphSource, Query};
use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::Arc;

fn wd(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "graphd_trace_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn ring_keeps_newest_suffix_in_order() {
    let mut b = TraceBuf::new(4);
    for i in 0..10u64 {
        b.push(TraceEvent {
            seq: 0, // stamped by the ring
            ts_us: i,
            phase: EventPhase::Instant,
            kind: EventKind::File,
            arg: i,
        });
    }
    assert_eq!(b.len(), 4);
    assert_eq!(b.dropped(), 6);
    let evs = b.drain();
    let args: Vec<u64> = evs.iter().map(|e| e.arg).collect();
    assert_eq!(args, vec![6, 7, 8, 9], "retained = newest suffix, oldest first");
    let seqs: Vec<u64> = evs.iter().map(|e| e.seq).collect();
    assert_eq!(seqs, vec![6, 7, 8, 9], "seq numbers count all pushes, not slots");
    assert!(b.is_empty(), "drain resets the ring");
}

/// `"key":<int>` out of one exported trace-event line.
fn field_u64(line: &str, key: &str) -> Option<u64> {
    let tag = format!("\"{key}\":");
    let at = line.find(&tag)? + tag.len();
    let digits: String = line[at..].chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

fn ph_of(line: &str) -> Option<char> {
    let at = line.find("\"ph\":\"")? + 6;
    line[at..].chars().next()
}

#[test]
fn traced_job_exports_balanced_chrome_trace() {
    let s = GraphD::builder()
        .machines(2)
        .workdir(wd("export"))
        .max_supersteps(4)
        .build()
        .unwrap();
    let g = generator::uniform(120, 700, true, 7);
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    let path = s.workdir().join("trace_test.json");
    let res = graph
        .job(Arc::new(graphd::algos::PageRank::new(3)))
        .trace(TraceConfig::to(&path))
        .run()
        .unwrap();

    // The new StepMetrics wait counters are live: two machines crossing
    // real rendezvous barriers accumulate nonzero wait.
    assert!(
        res.metrics.barrier_wait_secs() > 0.0,
        "2-machine run must accumulate barrier wait"
    );
    assert!(res.metrics.stall_wait_secs() >= 0.0);

    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.starts_with("{\"traceEvents\":["), "chrome JSON object format");
    assert!(text.trim_end().ends_with("],\"displayTimeUnit\":\"ms\"}"));

    // Replay every duration event: B/E must balance per (pid, tid) track
    // and never go negative — the property Perfetto needs to render.
    let mut depth: HashMap<(u64, u64), i64> = HashMap::new();
    let mut tracks: HashSet<(u64, u64)> = HashSet::new();
    let mut superstep_spans = 0u64;
    for line in text.lines().filter(|l| l.contains("\"ph\":")) {
        let (Some(pid), Some(tid)) = (field_u64(line, "pid"), field_u64(line, "tid")) else {
            panic!("event without pid/tid: {line}");
        };
        tracks.insert((pid, tid));
        match ph_of(line) {
            Some('B') => {
                *depth.entry((pid, tid)).or_default() += 1;
                if line.contains("\"name\":\"superstep\"") {
                    superstep_spans += 1;
                }
            }
            Some('E') => {
                let d = depth.entry((pid, tid)).or_default();
                *d -= 1;
                assert!(*d >= 0, "E before B on track ({pid},{tid}): {line}");
            }
            _ => {} // "i" instants and "M" metadata carry no depth
        }
    }
    assert!(
        depth.values().all(|&d| d == 0),
        "unbalanced span tracks: {depth:?}"
    );
    // Every machine contributes all three unit tracks (U_c=0, U_s=1,
    // U_r=2 per the fixed tid mapping).
    for pid in 0..2u64 {
        for tid in 0..3u64 {
            assert!(tracks.contains(&(pid, tid)), "missing track ({pid},{tid})");
        }
    }
    assert!(superstep_spans >= 2 * 3, "a span per machine per superstep");
    let _ = std::fs::remove_dir_all(s.workdir());
}

/// PageRank-shaped program that panics computing `victim` at `at_step`
/// (the same injection hook as `tests/failure.rs`).
struct PanicAt {
    victim: u32,
    at_step: u64,
}

impl VertexProgram for PanicAt {
    type Value = f32;
    type Msg = f32;
    type Agg = ();
    type Comb = SumF32;

    fn init_value(&self, _id: u32, _deg: u32, nv: u64) -> f32 {
        1.0 / nv as f32
    }

    fn compute(
        &self,
        ctx: &mut Context<'_, f32, ()>,
        id: u32,
        value: &mut f32,
        edges: &[Edge],
        msgs: &[f32],
    ) {
        if ctx.superstep == self.at_step && id == self.victim {
            panic!(
                "injected unit failure: vertex {id} at superstep {}",
                ctx.superstep
            );
        }
        if ctx.superstep > 0 {
            *value = 0.15 / ctx.num_vertices as f32 + 0.85 * msgs.iter().sum::<f32>();
        }
        if !edges.is_empty() {
            let share = *value / edges.len() as f32;
            for e in edges {
                ctx.send(e.nbr, share);
            }
        }
    }
}

#[test]
fn failed_job_dumps_flight_recorder() {
    let s = GraphD::builder()
        .machines(2)
        .workdir(wd("flightrec"))
        .max_supersteps(6)
        .build()
        .unwrap();
    let g = generator::uniform(100, 600, true, 5);
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    let err = graph
        .job(Arc::new(PanicAt {
            victim: 9,
            at_step: 1,
        }))
        .trace(TraceConfig::on())
        .run()
        .unwrap_err();
    let headline = err.to_string();
    assert!(matches!(err, Error::JobFailed { .. }), "{err}");

    // One dump per machine in the session workdir, each headed by the
    // first AbortCause (failing unit + machine + superstep + cause).
    for m in 0..2 {
        let p = s.workdir().join(format!("flightrec_{m}.log"));
        let dump = std::fs::read_to_string(&p)
            .unwrap_or_else(|e| panic!("missing {}: {e}", p.display()));
        assert!(dump.contains("== graphd flight recorder — machine"), "{dump}");
        assert!(dump.contains(&format!("cause: {headline}")), "{dump}");
        assert!(dump.contains("injected unit failure"), "{dump}");
        assert!(dump.contains("-- U_c"), "dump must carry the U_c track:\n{dump}");
        assert!(dump.contains("superstep"), "{dump}");
    }
    // The success-path export did not run.
    assert!(!s.workdir().join("trace.json").exists());
    // The structured diag ring retained the unit-failure line (the same
    // line `worker/sync.rs` used to eprintln raw).
    let diags = trace::recent_diagnostics();
    assert!(
        diags.iter().any(|l| l.contains("failed")),
        "diag ring missing the failure line: {diags:?}"
    );
    let _ = std::fs::remove_dir_all(s.workdir());
}

#[test]
fn serve_emits_live_stats_per_batch() {
    let s = GraphD::builder()
        .machines(2)
        .workdir(wd("serve_stats"))
        .max_supersteps(8)
        .config("trace", "true")
        .build()
        .unwrap();
    let g = generator::chain(24).with_unit_weights();
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    let mut srv = graph.serve(ServeConfig::default().lanes(2)).unwrap();
    for (source, target) in [(0u32, 3u32), (1, 4), (2, 5)] {
        srv.submit(Query::Dist { source, target });
    }
    assert_eq!(srv.stats().queued, 3);
    assert_eq!(srv.stats().in_flight, 0);

    let mut snaps = Vec::new();
    let rs = srv.run_pending_with(|st| snaps.push(st.clone())).unwrap();
    assert_eq!(rs.len(), 3);
    assert_eq!(snaps.len(), 2, "3 queries over 2 lanes = 2 batches");
    assert_eq!(snaps[0].queued, 1, "one query still queued after batch 0");
    let last = snaps.last().unwrap();
    assert_eq!(last.queued, 0);
    assert_eq!(last.in_flight, 0, "in_flight is 0 between batches");
    assert_eq!(last.batches, 2);
    assert_eq!(last.failed_batches, 0);
    assert_eq!(last.queries, 3);
    assert!(last.qps > 0.0);
    assert!(last.p99_secs >= last.p50_secs);
    assert_eq!(last, &srv.stats(), "emitter sees the same snapshot stats() yields");

    // The traced session rewrote the serve track at end of drain.
    let serve_trace = s.workdir().join("trace_serve.json");
    let text = std::fs::read_to_string(&serve_trace).unwrap();
    assert!(text.contains("\"name\":\"serve-batch\""), "{text}");
    let _ = std::fs::remove_dir_all(s.workdir());
}
