//! Cross-module property tests (proptest_lite): engine-vs-reference over
//! random graphs, recoding invariants, and coordinator-level invariants
//! (routing, Lemma-1 balance, message conservation) — all through the
//! session API.

use graphd::algos::{HashMin, PageRank};
use graphd::config::Mode;
use graphd::graph::{generator, reference, Graph};
use graphd::util::proptest_lite::{self, Gen};
use graphd::worker::Partitioning;
use graphd::{GraphD, GraphSource};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

fn wd(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "graphd_prop_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn random_graph(g: &mut Gen, directed: bool) -> Graph {
    let nv = g.usize_in(8, 200);
    let ne = g.usize_in(nv, nv * 6);
    if g.bool(0.5) {
        generator::uniform(nv, ne, directed, g.u64())
    } else {
        generator::rmat(nv, ne, (0.55, 0.2, 0.2), directed, g.u64())
    }
}

#[test]
fn property_pagerank_engine_equals_reference() {
    proptest_lite::run(8, |pg| {
        let g = random_graph(pg, true);
        let machines = 2 + pg.usize_in(0, 3);
        let steps = 2 + pg.usize_in(0, 4) as u64;
        let d = wd(&format!("pr{}", pg.case));
        let session = GraphD::builder()
            .machines(machines)
            .workdir(&d)
            .max_supersteps(steps)
            .oms_file_cap(4096) // tiny ℬ: force many files
            .build()
            .unwrap();
        let graph = session
            .load(GraphSource::InMemorySparse(&g, pg.u64()))
            .unwrap();
        let ids = graph.id_map().unwrap().to_vec();
        let out = graph.run(Arc::new(PageRank::new(steps))).unwrap();
        let want = reference::pagerank(&g, steps);
        let got: HashMap<u32, f32> = out.values_by_id().into_iter().collect();
        let mut ok = true;
        for v in 0..g.num_vertices() {
            let gv = got[&ids[v]];
            if (gv - want[v]).abs() > 1e-4 * (1.0 + want[v].abs()) {
                ok = false;
                break;
            }
        }
        let _ = std::fs::remove_dir_all(&d);
        graphd::prop_assert!(
            pg,
            ok,
            "pagerank mismatch: |V|={} machines={machines} steps={steps}",
            g.num_vertices()
        );
    });
}

#[test]
fn property_recoding_preserves_graph() {
    // After ID recoding, the multiset of (new-id) edges must be the image
    // of the original edges under the old→new bijection.
    proptest_lite::run(8, |pg| {
        let g = random_graph(pg, true);
        let machines = 2 + pg.usize_in(0, 3);
        let d = wd(&format!("rc{}", pg.case));
        let session = GraphD::builder()
            .machines(machines)
            .workdir(&d)
            .build()
            .unwrap();
        let mut graph = session
            .load(GraphSource::InMemorySparse(&g, pg.u64()))
            .unwrap();
        let ids = graph.id_map().unwrap().to_vec();
        graph.recode().unwrap();
        let rec = graph.recoded_stores().unwrap();

        // old -> new map from the recoded stores
        let mut old2new: HashMap<u32, u32> = HashMap::new();
        for s in rec {
            for (pos, &old) in s.ids.iter().enumerate() {
                old2new.insert(old, (pos * machines + s.machine) as u32);
            }
        }
        // expected edge multiset in new-id space
        let mut want: Vec<(u32, u32)> = Vec::new();
        for v in 0..g.num_vertices() as u32 {
            let v_new = old2new[&ids[v as usize]];
            for &u in g.neighbors(v) {
                want.push((v_new, old2new[&ids[u as usize]]));
            }
        }
        want.sort_unstable();
        // actual recoded edge stream
        let mut got: Vec<(u32, u32)> = Vec::new();
        for s in rec {
            let mut cur = graphd::worker::storage::EdgeStreamCursor::open(s, 4096).unwrap();
            let mut edges = Vec::new();
            for pos in 0..s.local_vertices() {
                cur.read_adjacency(s.degs[pos], &mut edges).unwrap();
                let v_new = (pos * machines + s.machine) as u32;
                for e in &edges {
                    got.push((v_new, e.nbr));
                }
            }
        }
        got.sort_unstable();
        let ok = got == want;
        let _ = std::fs::remove_dir_all(&d);
        graphd::prop_assert!(pg, ok, "recoded edges differ: {} vs {}", got.len(), want.len());
    });
}

#[test]
fn property_hashmin_partitions_match_union_find() {
    proptest_lite::run(6, |pg| {
        let g = random_graph(pg, false);
        let machines = 2 + pg.usize_in(0, 2);
        let mode = if pg.bool(0.5) { Mode::Basic } else { Mode::Recoded };
        let d = wd(&format!("hm{}", pg.case));
        let session = GraphD::builder()
            .machines(machines)
            .workdir(&d)
            .mode(mode)
            .build()
            .unwrap();
        let mut graph = session
            .load(GraphSource::InMemorySparse(&g, pg.u64()))
            .unwrap();
        let ids = graph.id_map().unwrap().to_vec();
        if mode == Mode::Recoded {
            graph.recode().unwrap();
        }
        let out = graph.run(Arc::new(HashMin)).unwrap();
        let got: HashMap<u32, i32> = out.values_by_id().into_iter().collect();
        let want = reference::components(&g);
        // same-partition iff same reference label
        let mut label_of: HashMap<i32, u32> = HashMap::new();
        let mut ok = true;
        for v in 0..g.num_vertices() {
            let l = got[&ids[v]];
            match label_of.get(&l) {
                Some(&w) => {
                    if want[v] != w {
                        ok = false;
                        break;
                    }
                }
                None => {
                    label_of.insert(l, want[v]);
                }
            }
        }
        // and distinct got-labels map to distinct reference components
        let mut seen: Vec<u32> = label_of.values().copied().collect();
        seen.sort_unstable();
        let before = seen.len();
        seen.dedup();
        ok &= before == seen.len();
        let _ = std::fs::remove_dir_all(&d);
        graphd::prop_assert!(pg, ok, "components mismatch ({mode:?}, {machines} machines)");
    });
}

#[test]
fn property_hashed_partitioning_is_balanced() {
    // Lemma 1: max |V(W)| < 2|V|/n w.h.p., under the sparse-ID generator.
    proptest_lite::run(40, |pg| {
        let nv = pg.usize_in(500, 4000);
        let n = 2 + pg.usize_in(0, 6);
        let ids = graphd::graph::formats::sparse_ids(nv, pg.u64());
        let mut counts = vec![0usize; n];
        for id in ids {
            counts[Partitioning::Hashed.machine_of(id, n)] += 1;
        }
        let max = *counts.iter().max().unwrap();
        graphd::prop_assert!(
            pg,
            max < 2 * nv / n + 2,
            "imbalance: max {max} vs bound {} (nv={nv}, n={n})",
            2 * nv / n
        );
    });
}

#[test]
fn property_message_count_conserved() {
    // Every message generated is received exactly once: Σ sent == Σ recv
    // across machines and supersteps (no loss, no duplication).
    proptest_lite::run(6, |pg| {
        let g = random_graph(pg, true);
        let machines = 2 + pg.usize_in(0, 3);
        let d = wd(&format!("mc{}", pg.case));
        let session = GraphD::builder()
            .machines(machines)
            .workdir(&d)
            .max_supersteps(3)
            .oms_file_cap(2048)
            .build()
            .unwrap();
        let out = session
            .run(
                GraphSource::InMemorySparse(&g, pg.u64()),
                Arc::new(PageRank::new(3)),
            )
            .unwrap();
        let (mut sent, mut recv) = (0u64, 0u64);
        for m in &out.metrics.machines {
            for s in &m.steps {
                // Wire + fast-path local traffic: conservation holds over
                // the sum (local batches are received like any other).
                sent += s.msgs_sent + s.local_msgs;
                recv += s.msgs_recv;
            }
        }
        let _ = std::fs::remove_dir_all(&d);
        // (PageRank has a SUM combiner: received count may be smaller
        // after combining, but never larger, and never zero when sent>0.)
        graphd::prop_assert!(
            pg,
            recv <= sent && (sent == 0 || recv > 0),
            "conservation violated: sent={sent} recv={recv}"
        );
    });
}
