//! Integration tests for the resident adjacency store
//! (`-c resident=stream|mmap|auto`, DESIGN.md "Resident store"): stream
//! vs mmap bit-identical values in basic and recoded modes at n = 1 and
//! n = 2, residency accounting, the `auto` budget rule, typed rejection
//! of corrupt/truncated CSR files (docs/FORMATS.md §2), cache reuse
//! without re-materialization, and serve warm restarts.

use graphd::algos::{PageRank, Sssp};
use graphd::config::Mode;
use graphd::error::Error;
use graphd::graph::generator;
use graphd::metrics::JobMetrics;
use graphd::worker::csr::{self, CsrMap};
use graphd::worker::storage::MachineStore;
use graphd::{GraphD, GraphSource, Query, Resident, ServeConfig};
use std::path::PathBuf;
use std::sync::Arc;

fn wd(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "graphd_resident_{name}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn mapped_items(m: &JobMetrics) -> u64 {
    m.machines
        .iter()
        .flat_map(|mm| mm.steps.iter())
        .map(|s| s.edge_items_mapped)
        .sum()
}

/// Bit-exact view of f32 results: NaN-safe, no tolerance.
fn bits(vals: &[(u32, f32)]) -> Vec<(u32, u32)> {
    vals.iter().map(|&(id, v)| (id, v.to_bits())).collect()
}

/// The tentpole guarantee: `csr_edges` is byte-identical to `se.bin`, so
/// a mapped run must produce **bit-identical** values to a streamed run —
/// PageRank (order-sensitive float sums) and SSSP, basic and recoded
/// modes, single- and multi-machine.
#[test]
fn stream_vs_mmap_bit_identical_basic_and_recoded() {
    for n in [1usize, 2] {
        let g = generator::uniform(220, 1400, true, 19).with_unit_weights();
        let run = |resident: Resident, name: &str| {
            let d = wd(&format!("ident_{name}_{n}"));
            let session = GraphD::builder()
                .machines(n)
                .workdir(&d)
                .max_supersteps(5)
                .resident(resident)
                .build()
                .unwrap();
            let mut graph = session.load(GraphSource::InMemory(&g)).unwrap();
            let basic_pr = graph.run(Arc::new(PageRank::new(5))).unwrap();
            let basic_sp = graph.run(Arc::new(Sssp::new(0))).unwrap();
            graph.recode().unwrap();
            let src = graph.current_id_of(0);
            let rec_pr = graph
                .job(Arc::new(PageRank::new(5)))
                .mode(Mode::Recoded)
                .run()
                .unwrap();
            let rec_sp = graph
                .job(Arc::new(Sssp::new(src)))
                .mode(Mode::Recoded)
                .run()
                .unwrap();
            let out = (
                bits(&basic_pr.values_by_id()),
                bits(&basic_sp.values_by_id()),
                bits(&rec_pr.values_by_id()),
                bits(&rec_sp.values_by_id()),
                [
                    basic_pr.metrics.clone(),
                    basic_sp.metrics.clone(),
                    rec_pr.metrics.clone(),
                    rec_sp.metrics.clone(),
                ],
            );
            let _ = std::fs::remove_dir_all(&d);
            out
        };

        let stream = run(Resident::Stream, "stream");
        let mmap = run(Resident::Mmap, "mmap");
        assert_eq!(stream.0, mmap.0, "n={n}: basic PageRank diverged");
        assert_eq!(stream.1, mmap.1, "n={n}: basic SSSP diverged");
        assert_eq!(stream.2, mmap.2, "n={n}: recoded PageRank diverged");
        assert_eq!(stream.3, mmap.3, "n={n}: recoded SSSP diverged");

        for m in &stream.4 {
            assert_eq!(mapped_items(m), 0, "stream run must not map");
        }
        for m in &mmap.4 {
            let mapped = mapped_items(m);
            assert!(mapped > 0, "n={n}: mmap run decoded nothing mapped");
            if n == 1 {
                assert_eq!(
                    m.net_wire_bytes, 0,
                    "n=1 residency must not perturb the switch bypass"
                );
            }
        }
    }
}

/// `auto` maps only when the CSR pair fits the budget, and behaves as
/// pure streaming (still correct) when it does not.
#[test]
fn auto_maps_within_budget_and_streams_over_it() {
    let g = generator::uniform(180, 1100, true, 29).with_unit_weights();
    let run = |budget: &str, name: &str| {
        let d = wd(&format!("auto_{name}"));
        let session = GraphD::builder()
            .machines(2)
            .workdir(&d)
            .max_supersteps(4)
            .config("resident", "auto")
            .config("resident_budget", budget)
            .build()
            .unwrap();
        let mut graph = session.load(GraphSource::InMemory(&g)).unwrap();
        graph.recode().unwrap();
        let res = graph
            .job(Arc::new(PageRank::new(4)))
            .mode(Mode::Recoded)
            .run()
            .unwrap();
        let out = (bits(&res.values_by_id()), mapped_items(&res.metrics));
        let _ = std::fs::remove_dir_all(&d);
        out
    };
    let (vals_big, mapped_big) = run("1073741824", "big");
    let (vals_tiny, mapped_tiny) = run("64", "tiny");
    assert!(mapped_big > 0, "a 1 GiB budget must map this tiny graph");
    assert_eq!(mapped_tiny, 0, "a 64-byte budget must fall back to streaming");
    assert_eq!(vals_big, vals_tiny, "auto fallback changed the answer");
}

/// docs/FORMATS.md §2: a corrupt or truncated CSR file is rejected with a
/// typed `Error::CorruptStream` — never UB, never silently wrong
/// adjacency — and strict `mmap` re-materializes it on the next run.
#[test]
fn corrupt_or_truncated_csr_rejected_typed_then_repaired() {
    let d = wd("corrupt");
    let g = generator::uniform(120, 700, true, 37).with_unit_weights();
    let session = GraphD::builder()
        .machines(1)
        .workdir(&d)
        .max_supersteps(3)
        .resident(Resident::Mmap)
        .build()
        .unwrap();
    let mut graph = session.load(GraphSource::InMemory(&g)).unwrap();
    graph.recode().unwrap();
    let reference = bits(
        &graph
            .job(Arc::new(PageRank::new(3)))
            .mode(Mode::Recoded)
            .run()
            .unwrap()
            .values_by_id(),
    );

    let store_dir = d.join("m0").join("rec");
    let store = MachineStore::load(&store_dir).unwrap();

    // Flip a byte inside the csr_edges header: open() must reject, typed.
    let edges = store_dir.join(csr::CSR_EDGES);
    let pristine = std::fs::read(&edges).unwrap();
    let mut bad = pristine.clone();
    bad[2] ^= 0xFF; // inside the magic
    std::fs::write(&edges, &bad).unwrap();
    match CsrMap::open(&store) {
        Err(Error::CorruptStream(msg)) => {
            assert!(msg.contains("magic"), "unexpected cause: {msg}")
        }
        other => panic!("corrupt magic must be CorruptStream, got {other:?}"),
    }

    // Truncate csr_offsets below the header: same typed rejection.
    std::fs::write(&edges, &pristine).unwrap();
    let offsets = store_dir.join(csr::CSR_OFFSETS);
    let full = std::fs::read(&offsets).unwrap();
    std::fs::write(&offsets, &full[..10]).unwrap();
    assert!(
        matches!(CsrMap::open(&store), Err(Error::CorruptStream(_))),
        "truncated header must be CorruptStream"
    );

    // Strict mmap repairs the damage on the next run and still matches.
    let repaired = bits(
        &graph
            .job(Arc::new(PageRank::new(3)))
            .mode(Mode::Recoded)
            .resident(Resident::Mmap)
            .run()
            .unwrap()
            .values_by_id(),
    );
    assert_eq!(repaired, reference);
    assert_eq!(std::fs::read(&offsets).unwrap(), full, "rewrite is exact");
    let _ = std::fs::remove_dir_all(&d);
}

/// Materialization is idempotent and keyed by the header checksum: after
/// a recoded store's CSR pair lands, reloading the stores from local
/// disks and running again maps the **existing** files — `ensure_csr`
/// reports reuse, the bytes on disk are untouched.
#[test]
fn cache_reuse_after_reload_maps_without_rematerializing() {
    let d = wd("reuse");
    let g = generator::uniform(150, 900, true, 43).with_unit_weights();
    let session = GraphD::builder()
        .machines(2)
        .workdir(&d)
        .max_supersteps(3)
        .resident(Resident::Mmap)
        .build()
        .unwrap();
    let mut graph = session.load(GraphSource::InMemory(&g)).unwrap();
    graph.recode().unwrap();
    let first = graph
        .job(Arc::new(PageRank::new(3)))
        .mode(Mode::Recoded)
        .run()
        .unwrap();
    assert!(mapped_items(&first.metrics) > 0);

    // A second "session" over the same disks: reload stores, re-resolve.
    graph.reload_recoded().unwrap();
    for store in graph.recoded_stores().unwrap() {
        assert!(
            !csr::ensure_csr(store).unwrap(),
            "m{}: current CSR pair must be reused, not rewritten",
            store.machine
        );
        let map = CsrMap::open(store).unwrap();
        assert_eq!(map.header().local_vertices, store.local_vertices() as u64);
    }
    let second = graph
        .job(Arc::new(PageRank::new(3)))
        .mode(Mode::Recoded)
        .run()
        .unwrap();
    assert!(mapped_items(&second.metrics) > 0, "reloaded run still maps");
    assert_eq!(
        bits(&first.values_by_id()),
        bits(&second.values_by_id())
    );
    let _ = std::fs::remove_dir_all(&d);
}

/// Warm restart for serving: a second `QueryServer` over the same session
/// graph answers identically to the first — and because the CSR pair is
/// already current, it maps instead of re-materializing (map, don't
/// reload).
#[test]
fn serve_warm_restart_matches_cold_load() {
    let d = wd("serve");
    let g = generator::uniform(160, 1000, true, 47).with_unit_weights();
    let session = GraphD::builder()
        .machines(2)
        .workdir(&d)
        .resident(Resident::Mmap)
        .build()
        .unwrap();
    let mut graph = session.load(GraphSource::InMemory(&g)).unwrap();
    graph.recode().unwrap();

    let queries = [
        Query::Dist { source: 0, target: 90 },
        Query::Reach { source: 3, target: 140 },
        Query::ReachCount { source: 7 },
    ];
    let answers = |graph: &graphd::session::LoadedGraph<'_>| {
        let mut server = graph.serve(ServeConfig::default()).unwrap();
        for q in &queries {
            server.submit(*q);
        }
        server
            .run_pending()
            .unwrap()
            .iter()
            .map(|r| format!("{:?}", r.answer))
            .collect::<Vec<_>>()
    };
    let cold = answers(&graph);

    // The cold batches materialized/used the CSR; a rebuilt server finds
    // it current and reuses it.
    for store in graph.recoded_stores().unwrap() {
        assert!(!csr::ensure_csr(store).unwrap(), "warm server must reuse");
    }
    let warm = answers(&graph);
    assert_eq!(cold, warm, "warm restart changed serve answers");
    let _ = std::fs::remove_dir_all(&d);
}
