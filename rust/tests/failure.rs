//! Failure-injection tests for distributed failure propagation: a unit
//! that dies at any machine, in any mode, must surface as a typed
//! `Error::JobFailed` from `JobBuilder::run` within bounded wall-clock —
//! never a hang.  This is the paper's §6 precondition: recovery can only
//! start once a failure is *observed*.
//!
//! The injection hook is a test-only `VertexProgram` that panics when it
//! computes a chosen vertex at a chosen superstep, killing that vertex's
//! owner machine's U_c mid-pass; the poisoned barriers and abort-aware
//! channel waits must then unwedge every other unit of every machine.

use graphd::api::{Context, Edge, SumF32, VertexProgram};
use graphd::config::Mode;
use graphd::ft::CheckpointCfg;
use graphd::graph::generator;
use graphd::serve::ServeConfig;
use graphd::{Answer, Error, GraphD, GraphSource, Query, Session};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Generous bound for "failed fast, did not hang": the jobs here finish in
/// milliseconds when healthy; CI's per-step timeout is the backstop.
const FAIL_WITHIN: Duration = Duration::from_secs(60);

fn wd(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "graphd_failure_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// PageRank-shaped program (sum combiner, never halts, messages every
/// neighbor) that panics when computing `victim` at superstep `at_step`.
/// `victim` is in the *current* ID space of the job (translate through
/// `LoadedGraph::current_id_of` for recoded runs).
struct PanicAt {
    victim: u32,
    at_step: u64,
}

impl VertexProgram for PanicAt {
    type Value = f32;
    type Msg = f32;
    type Agg = ();
    type Comb = SumF32;

    fn init_value(&self, _id: u32, _deg: u32, nv: u64) -> f32 {
        1.0 / nv as f32
    }

    fn compute(
        &self,
        ctx: &mut Context<'_, f32, ()>,
        id: u32,
        value: &mut f32,
        edges: &[Edge],
        msgs: &[f32],
    ) {
        if ctx.superstep == self.at_step && id == self.victim {
            panic!(
                "injected unit failure: vertex {id} at superstep {}",
                ctx.superstep
            );
        }
        if ctx.superstep > 0 {
            *value = 0.15 / ctx.num_vertices as f32 + 0.85 * msgs.iter().sum::<f32>();
        }
        if !edges.is_empty() {
            let share = *value / edges.len() as f32;
            for e in edges {
                ctx.send(e.nbr, share);
            }
        }
    }
}

fn session(tag: &str, machines: usize) -> Session {
    GraphD::builder()
        .machines(machines)
        .workdir(wd(tag))
        .max_supersteps(6)
        .oms_file_cap(16 * 1024)
        .build()
        .unwrap()
}

fn assert_job_failed(err: Error, elapsed: Duration) {
    assert!(
        elapsed < FAIL_WITHIN,
        "failure took {elapsed:?} to surface — the barriers are wedging"
    );
    match err {
        Error::JobFailed {
            unit, ref cause, ..
        } => {
            assert_eq!(unit, "U_c", "origin unit: {cause}");
            assert!(
                cause.contains("injected unit failure"),
                "cause must be the injected panic, got: {cause}"
            );
        }
        other => panic!("expected Error::JobFailed, got: {other}"),
    }
}

#[test]
fn basic_mode_panic_at_any_machine_surfaces_typed_error() {
    let s = session("basic_any", 4);
    let g = generator::uniform(200, 1200, true, 7);
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    // Four victims spread over the id space: their owners cover several
    // machines, so the dead unit is exercised at more than one position
    // (whichever machine owns the victim, its siblings must unwedge).
    for victim in [0u32, 51, 102, 153] {
        let t = Instant::now();
        let err = graph
            .job(Arc::new(PanicAt { victim, at_step: 1 }))
            .mode(Mode::Basic)
            .run()
            .unwrap_err();
        assert_job_failed(err, t.elapsed());
    }
    let _ = std::fs::remove_dir_all(s.workdir());
}

#[test]
fn recoded_mode_panic_surfaces_typed_error() {
    let s = session("recoded", 4);
    let g = generator::uniform(160, 900, false, 11);
    let mut graph = s.load(GraphSource::InMemory(&g)).unwrap();
    graph.recode().unwrap();
    // Recoded jobs address vertices in the recoded ID space.
    let victim = graph.current_id_of(40);
    let t = Instant::now();
    let err = graph
        .job(Arc::new(PanicAt { victim, at_step: 2 }))
        .mode(Mode::Recoded)
        .run()
        .unwrap_err();
    assert_job_failed(err, t.elapsed());
    let _ = std::fs::remove_dir_all(s.workdir());
}

#[test]
fn panic_at_superstep_zero_does_not_wedge() {
    // The hardest spot: U_c dies before the very first compute_done, so no
    // watermark, no end tags, nothing downstream ever published.
    let s = session("step0", 4);
    let g = generator::uniform(120, 700, true, 3);
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    let t = Instant::now();
    let err = graph
        .job(Arc::new(PanicAt {
            victim: 17,
            at_step: 0,
        }))
        .run()
        .unwrap_err();
    assert_job_failed(err, t.elapsed());
    let _ = std::fs::remove_dir_all(s.workdir());
}

#[test]
fn failed_job_is_rerunnable_on_the_same_graph() {
    // The graph handle survives a failed job: stores are intact, a healthy
    // program runs to completion afterwards.
    let s = session("rerun", 2);
    let g = generator::uniform(100, 500, true, 5);
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    let err = graph
        .job(Arc::new(PanicAt {
            victim: 9,
            at_step: 1,
        }))
        .run()
        .unwrap_err();
    assert!(matches!(err, Error::JobFailed { .. }), "{err}");
    let ok = graph
        .job(Arc::new(graphd::algos::PageRank::new(3)))
        .max_supersteps(3)
        .run()
        .unwrap();
    assert_eq!(ok.supersteps(), 3);
    let _ = std::fs::remove_dir_all(s.workdir());
}

#[test]
fn checkpointed_failure_reports_last_durable_superstep() {
    let s = session("ckpt", 2);
    let g = generator::uniform(100, 600, true, 9);
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    let ckdir = s.workdir().join("dfs").join("failure_ckpt");
    let t = Instant::now();
    let err = graph
        .job(Arc::new(PanicAt {
            victim: 23,
            at_step: 3,
        }))
        .checkpoint(CheckpointCfg::every(&ckdir, 1))
        .run()
        .unwrap_err();
    assert!(t.elapsed() < FAIL_WITHIN);
    match err {
        Error::JobFailed { ref cause, .. } => {
            // every=1 → checkpoints completed after steps 0, 1 and 2; the
            // step-3 failure must point at superstep 2 for recovery.
            assert!(
                cause.contains("last durable checkpoint: superstep 2"),
                "resume hint missing or wrong: {cause}"
            );
            assert!(cause.contains("resume(2)"), "{cause}");
        }
        other => panic!("expected JobFailed, got {other}"),
    }
    assert_eq!(graphd::ft::resume_hint(&ckdir), Some(2));
    let _ = std::fs::remove_dir_all(s.workdir());
}

// ------------------------------------------------------- self-healing (§3.4)

use graphd::algos::PageRank;
use graphd::config::RetryPolicy;
use graphd::worker::fault::{FaultKind, FaultPlan};

/// Fault-free reference values for the 6-step PageRank used by the
/// recovery tests below.
fn clean_ranks(graph: &graphd::LoadedGraph<'_>) -> Vec<(u32, f32)> {
    graph
        .job(Arc::new(PageRank::new(6)))
        .run()
        .unwrap()
        .values_by_id()
}

fn assert_same_ranks(clean: &[(u32, f32)], rec: &[(u32, f32)]) {
    assert_eq!(clean.len(), rec.len());
    for ((ia, va), (ib, vb)) in clean.iter().zip(rec.iter()) {
        assert_eq!(ia, ib);
        assert!((va - vb).abs() < 1e-6, "{ia}: {va} vs {vb}");
    }
}

#[test]
fn injected_us_io_fault_auto_resumes_with_identical_values() {
    // The acceptance scenario: a U_s I/O error on a checkpointed 2-machine
    // PageRank auto-resumes from the last durable checkpoint and finishes
    // with the same values as a fault-free run.
    let s = session("usio_resume", 2);
    let g = generator::uniform(140, 800, true, 13);
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    let clean = clean_ranks(&graph);

    let ckdir = s.workdir().join("dfs").join("usio_ck");
    let t = Instant::now();
    let rec = graph
        .job(Arc::new(PageRank::new(6)))
        .checkpoint(CheckpointCfg::every(&ckdir, 2))
        .retry(RetryPolicy::retries(2))
        .inject_faults(FaultPlan::one(FaultKind::UsIo, 1, 3))
        .run()
        .expect("retryable I/O fault must auto-resume, not surface");
    assert!(t.elapsed() < FAIL_WITHIN);
    assert!(rec.metrics.recoveries >= 1, "no recovery recorded");
    // Failed at superstep 3, durable checkpoint after superstep 1.
    assert!(rec.metrics.retried_supersteps >= 1);
    assert_same_ranks(&clean, &rec.values_by_id());
    let _ = std::fs::remove_dir_all(s.workdir());
}

#[test]
fn ur_io_then_ckpt_write_faults_auto_resume_in_sequence() {
    // Two independent faults across two different units: attempt 1 dies of
    // a U_r I/O error, the resumed attempt 2 dies writing a checkpoint,
    // attempt 3 completes.  Each spec fires exactly once.
    let s = session("urio_ckptw", 2);
    let g = generator::uniform(120, 700, true, 17);
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    let clean = clean_ranks(&graph);

    let ckdir = s.workdir().join("dfs").join("urio_ck");
    let t = Instant::now();
    let rec = graph
        .job(Arc::new(PageRank::new(6)))
        .checkpoint(CheckpointCfg::every(&ckdir, 2))
        .retry(RetryPolicy::retries(2))
        .inject_faults(
            FaultPlan::one(FaultKind::UrIo, 0, 2).and(FaultKind::CkptWrite, 1, 3),
        )
        .run()
        .expect("both faults are retryable within the budget");
    assert!(t.elapsed() < FAIL_WITHIN);
    assert_eq!(rec.metrics.recoveries, 2, "one recovery per fault");
    assert_same_ranks(&clean, &rec.values_by_id());
    let _ = std::fs::remove_dir_all(s.workdir());
}

#[test]
fn transient_net_send_fault_auto_resumes() {
    let s = session("netsend", 2);
    let g = generator::uniform(110, 600, true, 19);
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    let clean = clean_ranks(&graph);

    let ckdir = s.workdir().join("dfs").join("net_ck");
    let t = Instant::now();
    let rec = graph
        .job(Arc::new(PageRank::new(6)))
        .checkpoint(CheckpointCfg::every(&ckdir, 2))
        .retry(RetryPolicy::retries(1))
        .inject_faults(FaultPlan::one(FaultKind::NetSend, 0, 2))
        .run()
        .expect("transient network fault must auto-resume");
    assert!(t.elapsed() < FAIL_WITHIN);
    assert_eq!(rec.metrics.recoveries, 1);
    assert_same_ranks(&clean, &rec.values_by_id());
    let _ = std::fs::remove_dir_all(s.workdir());
}

#[test]
fn retry_exhaustion_surfaces_typed_error() {
    // More faults than retry budget: the second failure must surface as
    // the typed JobFailed (with the exhaustion noted), not retry forever.
    let s = session("exhaust", 2);
    let g = generator::uniform(100, 500, true, 23);
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    let ckdir = s.workdir().join("dfs").join("exhaust_ck");
    let t = Instant::now();
    let err = graph
        .job(Arc::new(PageRank::new(6)))
        .checkpoint(CheckpointCfg::every(&ckdir, 2))
        .retry(RetryPolicy::retries(1))
        .inject_faults(
            FaultPlan::one(FaultKind::UsIo, 1, 2).and(FaultKind::UsIo, 1, 3),
        )
        .run()
        .unwrap_err();
    assert!(t.elapsed() < FAIL_WITHIN);
    match err {
        Error::JobFailed { ref cause, .. } => {
            assert!(cause.contains("injected fault"), "{cause}");
            assert!(
                cause.contains("retries exhausted after 1 recovery attempt"),
                "exhaustion not reported: {cause}"
            );
        }
        other => panic!("expected JobFailed, got {other}"),
    }
    let _ = std::fs::remove_dir_all(s.workdir());
}

#[test]
fn deterministic_panic_is_fatal_on_second_hit() {
    // A program panic is retried once (it could be a flaky machine), but a
    // repeat at the same superstep is deterministic program behaviour —
    // fatal even with retry budget left.
    let s = session("panic_fatal", 2);
    let g = generator::uniform(100, 500, true, 29);
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    let ckdir = s.workdir().join("dfs").join("panic_ck");
    let t = Instant::now();
    let err = graph
        .job(Arc::new(PanicAt {
            victim: 9,
            at_step: 3,
        }))
        .checkpoint(CheckpointCfg::every(&ckdir, 1))
        .retry(RetryPolicy::retries(5))
        .run()
        .unwrap_err();
    assert!(t.elapsed() < FAIL_WITHIN);
    match err {
        Error::JobFailed { ref cause, .. } => {
            assert!(cause.contains("injected unit failure"), "{cause}");
        }
        other => panic!("expected JobFailed, got {other}"),
    }
    let _ = std::fs::remove_dir_all(s.workdir());
}

#[test]
fn serve_transient_batch_failure_recovers_once() {
    // A serve batch that dies of a transient fault is re-run once and
    // answers normally; the retry is isolated to the batch (no failed
    // queries, recovered_batches counts it).
    let s = GraphD::builder()
        .machines(2)
        .workdir(wd("serve_recover"))
        .oms_file_cap(16 * 1024)
        .config("fault", "net_send@m0s1")
        .build()
        .unwrap();
    let g = generator::chain(20).with_unit_weights();
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    let mut srv = graph.serve(ServeConfig::default().lanes(2)).unwrap();
    srv.submit(Query::Dist { source: 0, target: 5 });
    srv.submit(Query::Dist { source: 1, target: 6 });
    let rs = srv.run_pending().unwrap();
    assert_eq!(rs.len(), 2);
    for r in &rs {
        assert!(
            r.error.is_none(),
            "query failed despite batch retry: {:?}",
            r.error
        );
        assert_ne!(r.answer, Answer::Failed);
    }
    assert_eq!(srv.metrics().recovered_batches, 1, "batch retry not counted");
    assert_eq!(srv.metrics().failed_batches, 0);
    let _ = std::fs::remove_dir_all(s.workdir());
}

#[test]
fn serve_failed_batch_fails_queries_not_the_server() {
    let s = session("serve", 2);
    let g = generator::chain(20).with_unit_weights();
    let graph = s.load(GraphSource::InMemory(&g)).unwrap();
    // Mode::Recoded without recode(): every batch job dies with a config
    // error — the batch's queries fail typed, the server keeps serving.
    let mut srv = graph
        .serve(ServeConfig::default().lanes(2).mode(Mode::Recoded))
        .unwrap();
    srv.submit(Query::Dist { source: 0, target: 5 });
    srv.submit(Query::Dist { source: 1, target: 6 });
    srv.submit(Query::ReachCount { source: 0 }); // second batch
    let rs = srv.run_pending().unwrap();
    assert_eq!(rs.len(), 3);
    for r in &rs {
        assert_eq!(r.answer, Answer::Failed);
        assert!(r.error.as_deref().unwrap_or("").contains("recode"));
    }
    assert_eq!(srv.metrics().failed_batches, 2);
    // The server is still alive: later submissions drain too.
    srv.submit(Query::Dist { source: 0, target: 3 });
    let rs = srv.run_pending().unwrap();
    assert_eq!(rs.len(), 1);
    assert_eq!(rs[0].answer, Answer::Failed);
    let _ = std::fs::remove_dir_all(s.workdir());
}
