//! Multi-process TCP transport tests: loopback worker clusters must
//! produce **bit-identical** vertex values to the in-process simulator
//! backend, and cross-process failures must surface as typed
//! `Error::JobFailed` on every survivor within bounded wall-clock.
//!
//! Every test spawns real `graphd worker` processes (the binary under
//! test) on 127.0.0.1.  The equivalence reference is the same binary in
//! `--sim` mode: one process, the modeled switch, all machine parts —
//! byte-for-byte the engine the tier-1 suite already trusts.  Values are
//! compared in their `Codec` wire encoding (hex), so "equal" means equal
//! bits, not equal float formatting.

use std::io::{BufRead, BufReader, Read};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

const BIN: &str = env!("CARGO_BIN_EXE_graphd");
/// Tiny-but-real dataset slice: big enough to exercise multi-batch
/// traffic, small enough for debug-profile worker processes.
const SCALE: &str = "0.03";
/// Per-process wall-clock bound.  Healthy runs take seconds; a transport
/// regression (lost frame, wedged barrier) would otherwise hang the suite.
const DEADLINE: Duration = Duration::from_secs(180);

fn wd(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "graphd_transport_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Child process that is SIGKILLed if the test panics before reaping it —
/// a failed assertion must not leak worker processes into the test host.
struct Worker(Option<Child>);

impl Worker {
    fn wait(&mut self) -> (std::process::ExitStatus, String) {
        let mut c = self.0.take().unwrap();
        let deadline = Instant::now() + DEADLINE;
        let status = loop {
            if let Some(st) = c.try_wait().unwrap() {
                break st;
            }
            if Instant::now() >= deadline {
                let _ = c.kill();
                let _ = c.wait();
                panic!("worker exceeded {DEADLINE:?} deadline");
            }
            std::thread::sleep(Duration::from_millis(25));
        };
        let mut stderr = String::new();
        if let Some(mut e) = c.stderr.take() {
            let _ = e.read_to_string(&mut stderr);
        }
        (status, stderr)
    }

    fn kill(&mut self) {
        if let Some(c) = self.0.as_mut() {
            let _ = c.kill();
            let _ = c.wait();
        }
        self.0 = None;
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Common worker invocation: `graphd worker --rank .. --machines ..` plus
/// the job shape shared by every process of one cluster.
fn worker_cmd(dir: &Path, rank: usize, n: usize, algo: &str, steps: u64, recode: bool, extra: &[&str]) -> Command {
    let mut c = Command::new(BIN);
    c.arg("worker")
        .arg("--rank")
        .arg(rank.to_string())
        .arg("--machines")
        .arg(n.to_string())
        .arg("--algo")
        .arg(algo)
        .arg("--dataset")
        .arg("btc-s")
        .arg("--steps")
        .arg(steps.to_string())
        .arg("--scale")
        .arg(SCALE)
        .arg("--workdir")
        .arg(dir.join(format!("w{rank}")))
        .arg("--out")
        .arg(dir.join(format!("part{rank}")));
    if recode {
        c.arg("--recode");
    }
    c.args(extra);
    c.stdout(Stdio::piped()).stderr(Stdio::piped());
    c
}

/// Spawn rank 0 with `--listen 127.0.0.1:0` and parse the actual bound
/// address off its first stdout line.
fn spawn_leader(dir: &Path, n: usize, algo: &str, steps: u64, recode: bool, extra: &[&str]) -> (Worker, String) {
    let mut cmd = worker_cmd(dir, 0, n, algo, steps, recode, extra);
    cmd.arg("--listen").arg("127.0.0.1:0");
    let mut child = cmd.spawn().unwrap();
    let mut line = String::new();
    BufReader::new(child.stdout.as_mut().unwrap())
        .read_line(&mut line)
        .unwrap();
    let addr = line
        .trim()
        .strip_prefix("listening on ")
        .unwrap_or_else(|| panic!("expected 'listening on ADDR', got {line:?}"))
        .to_string();
    (Worker(Some(child)), addr)
}

fn spawn_follower(dir: &Path, rank: usize, n: usize, algo: &str, steps: u64, recode: bool, addr: &str, extra: &[&str]) -> Worker {
    let mut cmd = worker_cmd(dir, rank, n, algo, steps, recode, extra);
    cmd.arg("--join").arg(addr);
    Worker(Some(cmd.spawn().unwrap()))
}

/// Run the `--sim` reference (whole job, one process) and return its
/// sorted `id<TAB>hex` lines.
fn sim_reference(dir: &Path, n: usize, algo: &str, steps: u64, recode: bool) -> Vec<String> {
    let out = dir.join("ref");
    let mut c = Command::new(BIN);
    c.arg("worker")
        .arg("--sim")
        .arg("--machines")
        .arg(n.to_string())
        .arg("--algo")
        .arg(algo)
        .arg("--dataset")
        .arg("btc-s")
        .arg("--steps")
        .arg(steps.to_string())
        .arg("--scale")
        .arg(SCALE)
        .arg("--workdir")
        .arg(dir.join("wsim"))
        .arg("--out")
        .arg(&out);
    if recode {
        c.arg("--recode");
    }
    let st = c.output().unwrap();
    assert!(
        st.status.success(),
        "sim reference failed: {}",
        String::from_utf8_lossy(&st.stderr)
    );
    read_rows(&[out])
}

/// Read `id<TAB>hex` part files, merge, and sort by vertex id.
fn read_rows(parts: &[PathBuf]) -> Vec<String> {
    let mut rows: Vec<(u32, String)> = Vec::new();
    for p in parts {
        let text = std::fs::read_to_string(p)
            .unwrap_or_else(|e| panic!("missing part file {}: {e}", p.display()));
        for line in text.lines() {
            let id: u32 = line
                .split('\t')
                .next()
                .and_then(|s| s.parse().ok())
                .unwrap_or_else(|| panic!("bad row {line:?} in {}", p.display()));
            rows.push((id, line.to_string()));
        }
    }
    rows.sort_by_key(|(id, _)| *id);
    rows.into_iter().map(|(_, l)| l).collect()
}

/// The tentpole acceptance check: an n-process loopback TCP cluster and
/// the sim backend produce byte-identical final values.
fn equivalence_case(tag: &str, n: usize, algo: &str, recode: bool) {
    let dir = wd(tag);
    let steps = 6;
    let reference = sim_reference(&dir, n, algo, steps, recode);
    assert!(!reference.is_empty(), "sim reference produced no rows");

    let (mut leader, addr) = spawn_leader(&dir, n, algo, steps, recode, &[]);
    let mut followers: Vec<Worker> = (1..n)
        .map(|r| spawn_follower(&dir, r, n, algo, steps, recode, &addr, &[]))
        .collect();
    let (st, err) = leader.wait();
    assert!(st.success(), "leader failed: {err}");
    for (i, f) in followers.iter_mut().enumerate() {
        let (st, err) = f.wait();
        assert!(st.success(), "follower {} failed: {err}", i + 1);
    }

    let parts: Vec<PathBuf> = (0..n).map(|r| dir.join(format!("part{r}"))).collect();
    let merged = read_rows(&parts);
    assert_eq!(
        merged.len(),
        reference.len(),
        "tcp cluster covered a different vertex set than sim"
    );
    assert_eq!(merged, reference, "tcp values diverge from sim values");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tcp_matches_sim_pagerank_basic_n2() {
    equivalence_case("pr_basic_n2", 2, "pagerank", false);
}

#[test]
fn tcp_matches_sim_pagerank_recoded_n3() {
    equivalence_case("pr_rec_n3", 3, "pagerank", true);
}

#[test]
fn tcp_matches_sim_sssp_basic_n2() {
    equivalence_case("sssp_basic_n2", 2, "sssp", false);
}

#[test]
fn tcp_matches_sim_sssp_recoded_n4() {
    equivalence_case("sssp_rec_n4", 4, "sssp", true);
}

/// An injected transient net fault at machine 1 must fail BOTH processes
/// with the *originating* typed cause — the abort latch crossing the
/// control plane, not a local timeout.
#[test]
fn injected_fault_propagates_across_processes() {
    let dir = wd("fault_prop");
    let extra = ["-c", "fault=net_send@m1s2"];
    let (mut leader, addr) = spawn_leader(&dir, 2, "pagerank", 6, false, &extra);
    let mut follower = spawn_follower(&dir, 1, 2, "pagerank", 6, false, &addr, &extra);

    let (st0, err0) = leader.wait();
    let (st1, err1) = follower.wait();
    assert!(!st0.success(), "leader should fail, stderr: {err0}");
    assert!(!st1.success(), "follower should fail, stderr: {err1}");
    for (who, err) in [("leader", &err0), ("follower", &err1)] {
        assert!(
            err.contains("job failed"),
            "{who} missing typed JobFailed: {err}"
        );
        assert!(
            err.contains("transient network send failure"),
            "{who} missing originating cause (machine 1's injected fault): {err}"
        );
        assert!(err.contains("machine 1"), "{who} lost the origin: {err}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A SIGKILLed peer (no goodbye, no abort frame — the OS just closes its
/// sockets) must surface as a typed JobFailed `connection ... lost` on the
/// survivor, within the deadline.  The cause deliberately carries no
/// retryable marker, so the survivor exits promptly instead of burning
/// retries on handshakes the dead peer will never join.
#[test]
fn killed_peer_fails_survivor_with_typed_error() {
    let dir = wd("killed_peer");
    // Enough supersteps that the job is guaranteed to still be running
    // when the kill lands.
    let (mut leader, addr) = spawn_leader(&dir, 2, "pagerank", 5000, false, &[]);
    let mut follower = spawn_follower(&dir, 1, 2, "pagerank", 5000, false, &addr, &[]);

    // Let both processes get through preprocessing and into the superstep
    // loop before the kill (the handshake itself is cross-checked by the
    // equivalence tests).
    std::thread::sleep(Duration::from_secs(5));
    follower.kill();

    let (st, err) = leader.wait();
    assert!(!st.success(), "survivor should fail after peer death: {err}");
    assert!(
        err.contains("job failed"),
        "survivor missing typed JobFailed: {err}"
    );
    assert!(
        err.contains("lost"),
        "survivor missing connection-lost cause: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Auto-resume across processes: the same injected transient fault, but
/// with checkpoints and `retry=2`.  Every process classifies the
/// propagated cause as retryable, re-handshakes under attempt 1, agrees
/// the resume point, and completes — with values still bit-identical to
/// the sim reference.
#[test]
fn retry_resumes_across_processes() {
    let dir = wd("retry_e2e");
    let steps = 6;
    let reference = sim_reference(&dir, 2, "pagerank", steps, false);

    let extra = [
        "-c",
        "fault=net_send@m1s3",
        "-c",
        "checkpoint_every=2",
        "-c",
        "retry=2",
    ];
    let (mut leader, addr) = spawn_leader(&dir, 2, "pagerank", steps, false, &extra);
    let mut follower = spawn_follower(&dir, 1, 2, "pagerank", steps, false, &addr, &extra);
    let (st0, err0) = leader.wait();
    let (st1, err1) = follower.wait();
    assert!(st0.success(), "leader did not recover: {err0}");
    assert!(st1.success(), "follower did not recover: {err1}");

    let merged = read_rows(&[dir.join("part0"), dir.join("part1")]);
    assert_eq!(
        merged, reference,
        "recovered tcp run diverges from sim values"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
