//! Integration test for the AOT bridge: HLO-text artifacts emitted by
//! `python/compile/aot.py` must load, compile and execute on the PJRT CPU
//! client with numerics matching the kernel formulas.
//!
//! Requires `make artifacts` to have run (skips with a message otherwise)
//! and the `xla` cargo feature (the whole file is gated: the offline build
//! has no PJRT runtime to round-trip through).

#![cfg(feature = "xla")]

use graphd::runtime::HloExecutable;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let d = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("pagerank_update.hlo.txt").exists() {
        Some(d)
    } else {
        eprintln!("artifacts/ missing — run `make artifacts` first; skipping");
        None
    }
}

const BLOCK: usize = graphd::runtime::BLOCK;

#[test]
fn pagerank_artifact_roundtrip() {
    let Some(dir) = artifacts_dir() else { return };
    let exe = HloExecutable::load(dir.join("pagerank_update.hlo.txt").to_str().unwrap())
        .expect("load+compile pagerank_update");

    let mut sums = vec![0f32; BLOCK];
    let mut deg = vec![0f32; BLOCK];
    for i in 0..BLOCK {
        sums[i] = (i % 97) as f32 / 97.0;
        deg[i] = (i % 7) as f32; // includes sinks (deg 0)
    }
    let inv_n = [1.0f32 / 1_000_000.0];

    let args = [
        xla::Literal::vec1(&sums),
        xla::Literal::vec1(&deg),
        xla::Literal::vec1(&inv_n),
    ];
    let out = exe.run(&args).expect("execute");
    let parts = out.to_tuple().expect("tuple output");
    assert_eq!(parts.len(), 2);
    let val = parts[0].to_vec::<f32>().unwrap();
    let msg = parts[1].to_vec::<f32>().unwrap();

    for i in (0..BLOCK).step_by(1231) {
        let want_val = 0.15 * inv_n[0] + 0.85 * sums[i];
        let want_msg = if deg[i] > 0.0 { want_val / deg[i] } else { 0.0 };
        assert!((val[i] - want_val).abs() < 1e-6, "val[{i}]");
        assert!((msg[i] - want_msg).abs() < 1e-6, "msg[{i}]");
    }
}

#[test]
fn minrelax_f32_artifact_roundtrip() {
    let Some(dir) = artifacts_dir() else { return };
    let exe = HloExecutable::load(dir.join("minrelax_f32.hlo.txt").to_str().unwrap())
        .expect("load+compile minrelax_f32");

    let mut cur = vec![0f32; BLOCK];
    let mut msg = vec![0f32; BLOCK];
    for i in 0..BLOCK {
        cur[i] = (i % 100) as f32;
        msg[i] = if i % 3 == 0 { f32::INFINITY } else { (i % 50) as f32 };
    }
    let args = [xla::Literal::vec1(&cur), xla::Literal::vec1(&msg)];
    let out = exe.run(&args).expect("execute");
    let parts = out.to_tuple().expect("tuple output");
    let new = parts[0].to_vec::<f32>().unwrap();
    let chg = parts[1].to_vec::<i32>().unwrap();

    for i in (0..BLOCK).step_by(977) {
        let want = cur[i].min(msg[i]);
        assert_eq!(new[i], want, "new[{i}]");
        assert_eq!(chg[i], (want < cur[i]) as i32, "chg[{i}]");
    }
}

#[test]
fn minrelax_i32_artifact_roundtrip() {
    let Some(dir) = artifacts_dir() else { return };
    let exe = HloExecutable::load(dir.join("minrelax_i32.hlo.txt").to_str().unwrap())
        .expect("load+compile minrelax_i32");

    let mut cur = vec![0i32; BLOCK];
    let mut msg = vec![0i32; BLOCK];
    for i in 0..BLOCK {
        cur[i] = (i % 1000) as i32;
        msg[i] = if i % 4 == 0 { i32::MAX } else { (i % 700) as i32 };
    }
    let args = [xla::Literal::vec1(&cur), xla::Literal::vec1(&msg)];
    let out = exe.run(&args).expect("execute");
    let parts = out.to_tuple().expect("tuple output");
    let new = parts[0].to_vec::<i32>().unwrap();
    let chg = parts[1].to_vec::<i32>().unwrap();

    for i in (0..BLOCK).step_by(1013) {
        let want = cur[i].min(msg[i]);
        assert_eq!(new[i], want, "new[{i}]");
        assert_eq!(chg[i], (want < cur[i]) as i32, "chg[{i}]");
    }
}
