//! Integration tests for the zero-copy message spine: the local-delivery
//! fast path in both shapes — the recoded `A_r` fold and the IO-Basic
//! local spill lane — (wire-vs-local byte split, value equivalence with
//! the switch path), pooled buffers + digest-array ping-pong, and
//! checkpoint/resume on the fast-path engine.

use graphd::algos::{PageRank, Sssp};
use graphd::config::Mode;
use graphd::ft::{self, CheckpointCfg};
use graphd::graph::generator;
use graphd::{GraphD, GraphSource};
use std::path::PathBuf;
use std::sync::Arc;

fn wd(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "graphd_spine_{name}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// n = 1 + fast path: *every* message is local, so the job must push zero
/// bytes through the simulated switch — and still compute the right answer.
#[test]
fn single_machine_fastpath_zeroes_wire_bytes() {
    let d = wd("n1");
    let g = generator::uniform(200, 1200, true, 11).with_unit_weights();
    let session = GraphD::builder().machines(1).workdir(&d).build().unwrap();
    let mut graph = session.load(GraphSource::InMemory(&g)).unwrap();
    graph.recode().unwrap();
    let src = graph.current_id_of(0);

    let fast = graph
        .job(Arc::new(Sssp::new(src)))
        .mode(Mode::Recoded)
        .run()
        .unwrap();
    assert_eq!(
        fast.metrics.net_wire_bytes, 0,
        "single-machine fast-path run must not touch the switch"
    );
    assert!(fast.metrics.net_local_bytes > 0, "local traffic is counted");

    // Same job with the fast path off: answers identical (MIN combining is
    // order-free), but everything transits the switch.
    let slow = graph
        .job(Arc::new(Sssp::new(src)))
        .mode(Mode::Recoded)
        .local_fastpath(false)
        .run()
        .unwrap();
    assert!(slow.metrics.net_wire_bytes > 0);
    assert_eq!(slow.metrics.net_local_bytes, 0);
    assert_eq!(fast.values_by_id(), slow.values_by_id());
    let _ = std::fs::remove_dir_all(&d);
}

/// Multi-machine recoded SSSP: the fast path must change only the routing
/// of dst == me traffic, never the results, and must cut wire bytes.
#[test]
fn fastpath_matches_switch_path_multi_machine() {
    let d = wd("multi");
    let g = generator::uniform(300, 2400, true, 23).with_unit_weights();
    let session = GraphD::builder().machines(3).workdir(&d).build().unwrap();
    let mut graph = session.load(GraphSource::InMemorySparse(&g, 5)).unwrap();
    graph.recode().unwrap();
    let src = {
        let mut ids: Vec<u32> = graph
            .stores()
            .iter()
            .flat_map(|s| s.ids.iter().copied())
            .collect();
        ids.sort_unstable();
        graph.current_id_of(ids[0])
    };

    let on = graph
        .job(Arc::new(Sssp::new(src)))
        .mode(Mode::Recoded)
        .run()
        .unwrap();
    let off = graph
        .job(Arc::new(Sssp::new(src)))
        .mode(Mode::Recoded)
        .local_fastpath(false)
        .run()
        .unwrap();

    assert_eq!(on.values_by_id(), off.values_by_id());
    assert!(
        on.metrics.net_wire_bytes < off.metrics.net_wire_bytes,
        "fast path must cut wire bytes: on={} off={}",
        on.metrics.net_wire_bytes,
        off.metrics.net_wire_bytes
    );
    assert!(on.metrics.net_local_bytes > 0);
    // Per-step metrics carry the split too (some step digested locally).
    let local_msgs: u64 = on
        .metrics
        .machines
        .iter()
        .flat_map(|m| m.steps.iter())
        .map(|s| s.local_msgs)
        .sum();
    assert!(local_msgs > 0, "uniform graph must have local edges");
    let _ = std::fs::remove_dir_all(&d);
}

/// IO-Basic at n = 1 with the spill lane: *every* message rides the local
/// spill lane straight into the S^I merge, so the job must push zero
/// bytes through the simulated switch — and still compute the right
/// answer (SSSP min-folds are order-free, so equality is exact).
#[test]
fn basic_mode_n1_spill_lane_zeroes_wire_bytes() {
    let d = wd("basic_n1");
    let g = generator::uniform(200, 1200, true, 11).with_unit_weights();
    let session = GraphD::builder().machines(1).workdir(&d).build().unwrap();
    let graph = session.load(GraphSource::InMemory(&g)).unwrap();

    let fast = graph.run(Arc::new(Sssp::new(0))).unwrap();
    assert_eq!(
        fast.metrics.net_wire_bytes, 0,
        "n=1 IO-Basic with the spill lane must not touch the switch"
    );
    assert!(fast.metrics.net_local_bytes > 0, "local traffic is counted");
    let local_msgs: u64 = fast
        .metrics
        .machines
        .iter()
        .flat_map(|m| m.steps.iter())
        .map(|s| s.local_msgs)
        .sum();
    assert!(local_msgs > 0, "spill-lane messages show up as local");

    let slow = graph
        .job(Arc::new(Sssp::new(0)))
        .local_fastpath(false)
        .run()
        .unwrap();
    assert!(slow.metrics.net_wire_bytes > 0);
    assert_eq!(slow.metrics.net_local_bytes, 0);
    assert_eq!(fast.values_by_id(), slow.values_by_id());
    let _ = std::fs::remove_dir_all(&d);
}

/// Multi-machine IO-Basic SSSP: the spill lane must change only the
/// routing of `dst == me` traffic, never the results (exactly — MIN is
/// order-free), and must cut wire bytes (mirrors the recoded case above).
#[test]
fn basic_mode_spill_lane_matches_switch_path_multi_machine() {
    let d = wd("basic_multi");
    let g = generator::uniform(300, 2400, true, 23).with_unit_weights();
    let session = GraphD::builder().machines(3).workdir(&d).build().unwrap();
    let graph = session.load(GraphSource::InMemory(&g)).unwrap();

    let on = graph.run(Arc::new(Sssp::new(0))).unwrap();
    let off = graph
        .job(Arc::new(Sssp::new(0)))
        .local_fastpath(false)
        .run()
        .unwrap();

    assert_eq!(on.values_by_id(), off.values_by_id());
    assert!(
        on.metrics.net_wire_bytes < off.metrics.net_wire_bytes,
        "spill lane must cut wire bytes: on={} off={}",
        on.metrics.net_wire_bytes,
        off.metrics.net_wire_bytes
    );
    assert!(on.metrics.net_local_bytes > 0);
    let _ = std::fs::remove_dir_all(&d);
}

/// Basic (non-digesting) mode, sum-combining program: with the fast path
/// on, local traffic rides the spill lane raw and is combined during the
/// S^I merge; off, it is pre-send merge-combined and transits the switch.
/// Results must agree to float tolerance (sum order differs), and wire
/// bytes must drop.
#[test]
fn basic_mode_fastpath_value_equivalence() {
    let d = wd("basic");
    let g = generator::uniform(150, 900, true, 31);
    let session = GraphD::builder()
        .machines(2)
        .workdir(&d)
        .max_supersteps(4)
        .build()
        .unwrap();
    let graph = session.load(GraphSource::InMemory(&g)).unwrap();

    let on = graph.run(Arc::new(PageRank::new(4))).unwrap();
    let off = graph
        .job(Arc::new(PageRank::new(4)))
        .local_fastpath(false)
        .run()
        .unwrap();
    for ((ia, va), (ib, vb)) in on.values_by_id().iter().zip(off.values_by_id().iter()) {
        assert_eq!(ia, ib);
        assert!((va - vb).abs() < 1e-6, "{ia}: {va} vs {vb}");
    }
    assert!(on.metrics.net_wire_bytes < off.metrics.net_wire_bytes);
    let _ = std::fs::remove_dir_all(&d);
}

/// Satellite: a resume after a mid-job checkpoint (now synchronized by the
/// dedicated checkpoint barrier) must match the uninterrupted run — with
/// the fast path on, so the checkpointed A_r includes locally-digested
/// messages.
#[test]
fn checkpoint_resume_with_fastpath_matches_uninterrupted() {
    let d = wd("ckpt");
    let g = generator::uniform(240, 1400, true, 17);
    let session = GraphD::builder()
        .machines(2)
        .workdir(&d)
        .max_supersteps(6)
        .build()
        .unwrap();
    let mut graph = session.load(GraphSource::InMemory(&g)).unwrap();
    graph.recode().unwrap();

    let full = graph
        .job(Arc::new(PageRank::new(6)))
        .mode(Mode::Recoded)
        .run()
        .unwrap();
    assert!(full.metrics.net_wire_bytes > 0, "2 machines talk");

    let ck = CheckpointCfg {
        dir: d.join("dfs/ck"),
        every: 2,
    };
    graph
        .job(Arc::new(PageRank::new(6)))
        .mode(Mode::Recoded)
        .checkpoint(ck.clone())
        .run()
        .unwrap();
    let restart = ft::latest_checkpoint(&ck.dir, None).expect("checkpoint written");
    let resumed = graph
        .job(Arc::new(PageRank::new(6)))
        .mode(Mode::Recoded)
        .checkpoint(ck)
        .resume(restart)
        .run()
        .unwrap();
    assert_eq!(resumed.metrics.supersteps, 6);
    for ((ia, va), (ib, vb)) in full
        .values_by_id()
        .iter()
        .zip(resumed.values_by_id().iter())
    {
        assert_eq!(ia, ib);
        assert!((va - vb).abs() < 1e-6, "{ia}: {va} vs {vb}");
    }
    let _ = std::fs::remove_dir_all(&d);
}

/// The job-wide buffer pool is live on the spine: after the first
/// superstep, checkouts hit the shelf instead of allocating.
#[test]
fn buffer_pool_hits_are_reported() {
    let d = wd("pool");
    let g = generator::uniform(200, 2000, true, 41);
    let session = GraphD::builder()
        .machines(2)
        .workdir(&d)
        .max_supersteps(5)
        .build()
        .unwrap();
    let mut graph = session.load(GraphSource::InMemory(&g)).unwrap();
    graph.recode().unwrap();
    let res = graph
        .job(Arc::new(PageRank::new(5)))
        .mode(Mode::Recoded)
        .run()
        .unwrap();
    let pool = res.metrics.pool;
    assert!(
        pool.hits > 0,
        "multi-superstep run must recycle buffers: {pool:?}"
    );
    assert!(pool.hit_rate() > 0.0 && pool.hit_rate() <= 1.0);
    let _ = std::fs::remove_dir_all(&d);
}

/// The digest-array pool ping-pongs the O(|V|/n) A_r shards between U_c
/// and U_r: a multi-superstep digesting run must serve later supersteps'
/// arrays from the pool instead of reallocating, and a basic-mode run
/// must not touch the pool at all.
#[test]
fn digest_pool_reuses_across_supersteps() {
    let d = wd("digestpool");
    let g = generator::uniform(200, 2000, true, 53);
    let session = GraphD::builder()
        .machines(2)
        .workdir(&d)
        .max_supersteps(5)
        .build()
        .unwrap();
    let mut graph = session.load(GraphSource::InMemory(&g)).unwrap();

    // IO-Basic never digests: the pool stays untouched.
    let basic = graph.run(Arc::new(PageRank::new(5))).unwrap();
    assert_eq!(basic.metrics.digest_pool.hits, 0);
    assert_eq!(basic.metrics.digest_pool.misses, 0);

    graph.recode().unwrap();
    let res = graph
        .job(Arc::new(PageRank::new(5)))
        .mode(Mode::Recoded)
        .run()
        .unwrap();
    let dp = res.metrics.digest_pool;
    assert!(
        dp.hits > 0,
        "5 supersteps of digesting must recycle A_r arrays: {dp:?}"
    );
    assert!(
        dp.misses > 0 && dp.misses <= 3 * 2,
        "only the warm-up arrays may allocate (3 per machine): {dp:?}"
    );
    let _ = std::fs::remove_dir_all(&d);
}
