//! End-to-end engine tests: DFS text load → IO-Basic / IO-Recoded runs →
//! compare against single-threaded references.  This exercises the whole
//! §3–§5 machinery through the fluent session API: parallel loading,
//! OMS/IMS streaming, the three units, combiners, ID recoding, and the
//! in-memory digesting path.

use graphd::algos::{HashMin, PageRank, Sssp, TriangleCount};
use graphd::config::Mode;
use graphd::graph::{generator, reference, Graph};
use graphd::{GraphD, GraphSource, Session};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

fn fresh_workdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "graphd_e2e_{name}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn setup(name: &str, machines: usize, mode: Mode) -> Session {
    GraphD::builder()
        .machines(machines)
        .workdir(fresh_workdir(name))
        .mode(mode)
        .oms_file_cap(16 * 1024) // small ℬ to exercise file splitting
        .dfs_block_size(4096)
        .build()
        .unwrap()
}

fn cleanup(s: &Session) {
    let _ = std::fs::remove_dir_all(s.workdir());
}

/// Load `g` through the session (optionally with sparse ids), returning
/// the graph handle and the dense→input id mapping.
fn load_graph<'s>(
    s: &'s Session,
    g: &Graph,
    sparse: bool,
) -> (graphd::LoadedGraph<'s>, Option<Vec<u32>>) {
    let src = if sparse {
        GraphSource::InMemorySparse(g, 77)
    } else {
        GraphSource::InMemory(g)
    };
    let lg = s.load(src).unwrap();
    let ids = lg.id_map().map(<[u32]>::to_vec);
    (lg, ids)
}

#[test]
fn pagerank_basic_matches_reference() {
    let s = setup("pr_basic", 4, Mode::Basic);
    let g = generator::uniform(300, 1500, true, 42);
    let (graph, ids) = load_graph(&s, &g, true);
    let ids = ids.unwrap();

    let out = graph
        .job(Arc::new(PageRank::new(5)))
        .max_supersteps(5)
        .run()
        .unwrap();
    assert_eq!(out.supersteps(), 5);

    let want = reference::pagerank(&g, 5);
    let got: HashMap<u32, f32> = out.values_by_id().into_iter().collect();
    assert_eq!(got.len(), 300);
    for v in 0..300usize {
        let gv = got[&ids[v]];
        assert!(
            (gv - want[v]).abs() < 1e-5 * (1.0 + want[v].abs()),
            "v={v}: got {gv}, want {}",
            want[v]
        );
    }
    cleanup(&s);
}

#[test]
fn pagerank_recoded_matches_reference() {
    let s = setup("pr_rec", 4, Mode::Recoded);
    let g = generator::uniform(250, 1200, true, 43);
    let (mut graph, ids) = load_graph(&s, &g, true);
    let ids = ids.unwrap();

    let out = graph
        .recode()
        .unwrap()
        .job(Arc::new(PageRank::new(6)))
        .max_supersteps(6)
        .run()
        .unwrap();

    let want = reference::pagerank(&g, 6);
    let got: HashMap<u32, f32> = out.values_by_id().into_iter().collect();
    for v in 0..250usize {
        let gv = got[&ids[v]];
        assert!(
            (gv - want[v]).abs() < 1e-5 * (1.0 + want[v].abs()),
            "v={v}: got {gv}, want {}",
            want[v]
        );
    }
    cleanup(&s);
}

#[test]
fn sssp_basic_and_recoded_match_dijkstra() {
    let g = generator::random_weights(generator::uniform(200, 900, true, 44), 9);
    let dist = reference::sssp(&g, 0);

    for mode in [Mode::Basic, Mode::Recoded] {
        let s = setup(&format!("sssp_{mode:?}"), 3, mode);
        let (mut graph, ids) = load_graph(&s, &g, true);
        let ids = ids.unwrap();
        let source_old = ids[0];

        if mode == Mode::Recoded {
            graph.recode().unwrap();
        }
        let source_cur = graph.current_id_of(source_old);

        let out = graph.run(Arc::new(Sssp::new(source_cur))).unwrap();
        let got: HashMap<u32, f32> = out.values_by_id().into_iter().collect();
        for v in 0..200usize {
            let gv = got[&ids[v]];
            if dist[v].is_infinite() {
                assert!(gv.is_infinite(), "v={v} should be unreachable");
            } else {
                assert!((gv - dist[v]).abs() < 1e-3, "v={v}: got {gv}, want {}", dist[v]);
            }
        }
        cleanup(&s);
    }
}

#[test]
fn hashmin_components_both_modes() {
    let g = generator::uniform(240, 500, false, 45);
    let want = reference::components(&g);

    for mode in [Mode::Basic, Mode::Recoded] {
        let s = setup(&format!("hm_{mode:?}"), 4, mode);
        let (mut graph, ids) = load_graph(&s, &g, true);
        let ids = ids.unwrap();
        if mode == Mode::Recoded {
            graph.recode().unwrap();
        }
        let out = graph.run(Arc::new(HashMin)).unwrap();
        let got: HashMap<u32, i32> = out.values_by_id().into_iter().collect();

        // Labels live in the current-ID space; compare *partitions*.
        let mut by_label: HashMap<i32, Vec<u32>> = HashMap::new();
        for v in 0..240usize {
            by_label.entry(got[&ids[v]]).or_default().push(v as u32);
        }
        let mut by_ref: HashMap<u32, Vec<u32>> = HashMap::new();
        for v in 0..240u32 {
            by_ref.entry(want[v as usize]).or_default().push(v);
        }
        let mut parts_got: Vec<Vec<u32>> = by_label.into_values().collect();
        let mut parts_ref: Vec<Vec<u32>> = by_ref.into_values().collect();
        for p in parts_got.iter_mut().chain(parts_ref.iter_mut()) {
            p.sort_unstable();
        }
        parts_got.sort();
        parts_ref.sort();
        assert_eq!(parts_got, parts_ref, "{mode:?}");
        cleanup(&s);
    }
}

#[test]
fn triangle_count_via_aggregator() {
    let g = generator::uniform(120, 700, false, 46);
    let want = reference::triangles(&g);

    let s = setup("tri", 3, Mode::Basic);
    let (graph, _) = load_graph(&s, &g, false);
    let out = graph.run(Arc::new(TriangleCount)).unwrap();
    let got = *out.outputs[0].final_agg;
    assert_eq!(got, want, "triangles");
    // diagnostic per-vertex counts must sum to the same number
    let sum: u64 = out.values_by_id().iter().map(|(_, c)| *c).sum();
    assert_eq!(sum, want);
    cleanup(&s);
}

#[test]
fn bfs_chain_exercises_skip_and_many_supersteps() {
    // Directed chain: one active vertex per superstep — the paper's
    // sparse-workload worst case. skip() must dominate reads.
    let g = generator::chain(400).with_unit_weights();
    let s = setup("chain", 4, Mode::Basic);
    let (graph, ids) = load_graph(&s, &g, true);
    let ids = ids.unwrap();
    let source = ids[0];

    let out = graph.run(Arc::new(Sssp::new(source))).unwrap();
    assert_eq!(out.supersteps(), 400, "chain BFS = |V| supersteps");
    let got: HashMap<u32, f32> = out.values_by_id().into_iter().collect();
    assert_eq!(got[&ids[399]], 399.0);

    // Sparse workload: far more items skipped than read across the job.
    let (read, skipped): (u64, u64) = out
        .metrics
        .machines
        .iter()
        .flat_map(|m| m.steps.iter())
        .fold((0, 0), |(r, s), st| {
            (r + st.edge_items_read, s + st.edge_items_skipped)
        });
    assert!(
        skipped > 10 * read.max(1),
        "skip() ineffective: read={read} skipped={skipped}"
    );
    cleanup(&s);
}

#[test]
fn memory_stays_within_dss_bound() {
    // Lemma 1 + §3.3.3: per-machine state is O(|V|/n), NOT O(|E|/n).
    let g = generator::uniform(400, 8000, true, 47); // avg degree 20
    let s = setup("membound", 4, Mode::Recoded);
    let (mut graph, _) = load_graph(&s, &g, true);
    let out = graph
        .recode()
        .unwrap()
        .job(Arc::new(PageRank::new(3)))
        .max_supersteps(3)
        .run()
        .unwrap();

    let per_vertex_budget = 64; // bytes per local vertex, generous constant
    for m in &out.metrics.machines {
        let local = (400 / 4) + 30; // Lemma-1 slack
        assert!(
            m.peak_state_bytes < (local * per_vertex_budget) as u64,
            "machine {} state {} exceeds O(|V|/n) budget",
            m.machine,
            m.peak_state_bytes
        );
    }
    cleanup(&s);
}

#[test]
fn recoded_xla_block_path_matches_reference() {
    // The full three-layer story: recoded mode + AOT Pallas kernels via
    // PJRT on the block-update hot path.
    if !graphd::runtime::KernelSet::default_dir()
        .join("pagerank_update.hlo.txt")
        .exists()
    {
        eprintln!("artifacts missing; run `make artifacts` — skipping");
        return;
    }
    let g = generator::uniform(300, 1600, true, 48);
    let s = setup("xla", 4, Mode::Recoded);
    let (mut graph, ids) = load_graph(&s, &g, true);
    let ids = ids.unwrap();

    let out = graph
        .recode()
        .unwrap()
        .job(Arc::new(PageRank::new(5)))
        .max_supersteps(5)
        .xla(graphd::Xla::On)
        .run()
        .unwrap();

    let want = reference::pagerank(&g, 5);
    let got: HashMap<u32, f32> = out.values_by_id().into_iter().collect();
    for v in 0..300usize {
        let gv = got[&ids[v]];
        assert!(
            (gv - want[v]).abs() < 1e-5 * (1.0 + want[v].abs()),
            "v={v}: got {gv}, want {}",
            want[v]
        );
    }
    cleanup(&s);
}

#[test]
fn convergent_pagerank_stops_via_aggregator_and_dumps() {
    use graphd::algos::PageRankConverge;
    let s = setup("prconv", 3, Mode::Basic);
    let g = generator::uniform(200, 1200, true, 51);
    let (graph, _) = load_graph(&s, &g, true);

    let out = graph
        .run(Arc::new(PageRankConverge { epsilon: 1e-4 }))
        .unwrap();
    let steps = out.supersteps();
    assert!(steps > 3, "converged suspiciously fast: {steps}");
    assert!(steps < 200, "aggregator never stopped the job");
    // final global delta is below epsilon
    assert!(*out.outputs[0].final_agg < 1e-4 + 1e-6);
    // sanity on the fixpoint: total rank mass ≈ 1 minus sink leakage
    let got: HashMap<u32, f32> = out.values_by_id().into_iter().collect();
    let sum: f32 = got.values().sum();
    assert!((sum - 1.0).abs() < 0.2, "rank mass wildly off: {sum}");

    // results dumped to the DFS as part files (paper's final step)
    graphd::engine::run::dump_results(&out, s.dfs(), "out/pagerank").unwrap();
    for m in 0..3 {
        assert!(s.dfs().exists(&format!("out/pagerank/part-{m:05}")));
    }
    let part0 = String::from_utf8(s.dfs().get("out/pagerank/part-00000").unwrap()).unwrap();
    assert!(part0.lines().next().unwrap().contains('\t'));
    cleanup(&s);
}

#[test]
fn empty_messages_terminate_immediately() {
    // A graph with no edges: every algorithm should stop after superstep 0
    // (no messages, everyone halts / PageRank capped at 1).
    let g = Graph::from_adj(vec![vec![]; 50], false);
    let s = setup("noedges", 2, Mode::Basic);
    let (graph, _) = load_graph(&s, &g, false);
    let out = graph.run(Arc::new(HashMin)).unwrap();
    assert_eq!(out.supersteps(), 1);
    // labels stay = own id
    for (id, lbl) in out.values_by_id() {
        assert_eq!(lbl as u32, id);
    }
    cleanup(&s);
}
