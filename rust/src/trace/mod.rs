//! Flight-recorder tracing spine: structured spans, Chrome-trace export,
//! and crash-time dumps.
//!
//! The paper's headline claim is that GraphD *fully overlaps* computation
//! with communication (§4, Table 4's M-Gene vs M-Send split).  `JobMetrics`
//! can only report that split post hoc; this module records the actual
//! timeline — what U_c, U_s, and U_r of every machine were doing, when —
//! so overlap, barrier stalls, and the seconds before a failure are
//! *inspectable*, not inferred.
//!
//! Zero dependencies per the repo's vendor-everything rule (no `tracing`
//! crate): the layer is three small pieces —
//!
//! * [`TraceBuf`] — a per-thread bounded ring buffer of [`TraceEvent`]s.
//!   Fixed capacity, overwrite-oldest, **no locks on the hot path**: each
//!   unit owns its buffer exclusively ([`UnitTracer`]) and only touches a
//!   `Mutex` when it deposits the drained buffer at unit exit
//!   ([`UnitTracer::finish`]).
//! * [`Tracer`] — the per-job collector. Hands out `UnitTracer`s, gathers
//!   their deposits, and drives the two file consumers:
//!   [`Tracer::export_chrome`] writes a Chrome trace-event JSON
//!   (`trace.json`, loadable in Perfetto / `chrome://tracing`, one track
//!   per machine×unit) and [`Tracer::flight_record`] dumps each unit's
//!   last N events into `flightrec_<machine>.log` when a job fails.
//! * [`diag`] / [`recent_diagnostics`] — the structured sink for the
//!   engine's few human-facing diagnostic lines (batch/unit failures).
//!   Each line is mirrored to stderr for humans *and* retained in a
//!   bounded process-global ring so tests and daemons can assert on it.
//!   This module is the sanctioned print site; the `print-hygiene`
//!   analyzer rule forbids raw `eprintln!`/`println!` elsewhere in
//!   `worker/`, `engine/`, `net/`, and `serve/`.
//!
//! ### Event ordering argument
//!
//! A `TraceBuf` is single-writer: events of one unit are pushed in program
//! order and stamped with a per-buffer sequence number plus a microsecond
//! timestamp from the tracer's shared epoch.  Overwrite-oldest means a
//! buffer always holds a *suffix* of the unit's history (the `dropped`
//! counter says how long a prefix was lost).  The exporter merges deposits
//! per (machine, unit) track by shared-epoch timestamp (sequence number as
//! tie-break — within one buffer the two orders agree, and timestamps stay
//! comparable across the fresh `UnitTracer`s a retry attempt creates), so
//! within a track, ordering is exact; across tracks, the shared epoch makes
//! timestamps comparable (same process — the simulated cluster shares one
//! clock).
//! Because a suffix can open with an `End` whose `Begin` was overwritten
//! (or a failed unit can die inside a span), the exporter *sanitizes*
//! nesting per track: an unmatched `End` is skipped, and any span still
//! open at the end of a track is closed with a synthetic `End` at the
//! track's last timestamp — so the exported JSON always has balanced
//! begin/end pairs.

use std::collections::VecDeque;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Default per-unit ring capacity (events).
pub const DEFAULT_CAPACITY: usize = 4096;
/// Default number of trailing events per unit in a flight-recorder dump.
pub const DEFAULT_FLIGHT_EVENTS: usize = 64;
/// Capacity of the process-global [`diag`] ring.
const DIAG_CAP: usize = 256;

/// Tracing knobs, threaded as `JobConfig::trace` / `JobBuilder::trace`
/// (and `-c trace=true`, `-c trace_path=…`, `-c trace_capacity=…`).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceConfig {
    /// Master switch. Off (the default) makes every tracing call a no-op
    /// branch on an owned `Option` — no locks, no allocation, no I/O.
    pub enabled: bool,
    /// Per-unit ring capacity in events (overwrite-oldest beyond it).
    pub capacity: usize,
    /// Trailing events per unit in a flight-recorder dump.
    pub flight_events: usize,
    /// Chrome-trace output path; `None` means `<workdir>/trace.json`.
    pub path: Option<PathBuf>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            capacity: DEFAULT_CAPACITY,
            flight_events: DEFAULT_FLIGHT_EVENTS,
            path: None,
        }
    }
}

impl TraceConfig {
    /// Enabled with defaults — `TraceConfig::on()` is the one-liner for
    /// `JobBuilder::trace`.
    pub fn on() -> Self {
        Self {
            enabled: true,
            ..Self::default()
        }
    }

    /// Enabled, exporting to `path` instead of `<workdir>/trace.json`.
    pub fn to(path: impl Into<PathBuf>) -> Self {
        Self {
            enabled: true,
            path: Some(path.into()),
            ..Self::default()
        }
    }
}

/// Is the event opening a span, closing it, or a point marker?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventPhase {
    /// Span open (Chrome `"B"`).
    Begin,
    /// Span close (Chrome `"E"`).
    End,
    /// Point event (Chrome `"i"`).
    Instant,
}

/// What the event describes. The `arg` of a [`TraceEvent`] is interpreted
/// per kind (superstep number, byte count, file index, …) — see each
/// variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// One unit executing one superstep; `arg` = absolute superstep.
    Superstep,
    /// Blocked in a `Rendezvous::exchange` barrier; `arg` = superstep.
    Barrier,
    /// Blocked in a `MachineSync` wait (send gating / recv handoff);
    /// `arg` = superstep.
    Stall,
    /// OMS / spill file lifecycle; `arg` = destination machine or file
    /// count, per site.
    File,
    /// Inside `NetSender::send` → `Switch::transmit` (the modeled wire
    /// window); `arg` = payload bytes.
    Transmit,
    /// Pool checkout pressure sample; `arg` = cumulative `BufPool` misses.
    Pool,
    /// Graph loading phase (§3.4); `arg` = machine.
    Load,
    /// ID-recoding phase (§5); `arg` = protocol phase (1–3).
    Recode,
    /// Serve batch admission (`Instant`) or dispatch span; `arg` = batch
    /// or query sequence number.
    ServeBatch,
    /// An injected fault fired (`Instant`, from the fault-injection
    /// harness); `arg` = absolute superstep.
    Fault,
    /// One auto-resume attempt: session-level span around the re-run
    /// (`Begin`/`End`, on the `recover` track) or a per-machine `Instant`
    /// when a machine reloads its checkpoint; `arg` = the superstep
    /// resumed from.
    Recovery,
    /// A superstep took the fast-replay path — incoming messages served
    /// from the retained message logs instead of recomputed senders
    /// (`Instant`); `arg` = absolute superstep.
    Replay,
    /// A transport-level peer connection was established (`Instant`, TCP
    /// backend only — the sim backend has no connections); `arg` = the
    /// peer's machine id.
    Connect,
    /// A control-plane frame was sent or received (`Instant`, TCP backend
    /// only: handshake, barrier report/decision, abort, goodbye); `arg` =
    /// the frame kind's wire byte ([`crate::net::frame::FrameKind`]).
    Control,
}

impl EventKind {
    /// Chrome `"name"` field.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Superstep => "superstep",
            EventKind::Barrier => "barrier",
            EventKind::Stall => "stall",
            EventKind::File => "file",
            EventKind::Transmit => "transmit",
            EventKind::Pool => "pool",
            EventKind::Load => "load",
            EventKind::Recode => "recode",
            EventKind::ServeBatch => "serve-batch",
            EventKind::Fault => "fault",
            EventKind::Recovery => "recovery",
            EventKind::Replay => "replay",
            EventKind::Connect => "connect",
            EventKind::Control => "control",
        }
    }

    /// Chrome `"cat"` (category) field — coarse grouping for trace-viewer
    /// filtering.
    pub fn category(self) -> &'static str {
        match self {
            EventKind::Superstep | EventKind::Load | EventKind::Recode => "phase",
            EventKind::Barrier | EventKind::Stall => "sync",
            EventKind::File | EventKind::Pool => "io",
            EventKind::Transmit | EventKind::Connect | EventKind::Control => "net",
            EventKind::ServeBatch => "serve",
            EventKind::Fault => "fault",
            EventKind::Recovery | EventKind::Replay => "recovery",
        }
    }

    /// Dense index used by the exporter's per-kind depth counters.
    fn idx(self) -> usize {
        match self {
            EventKind::Superstep => 0,
            EventKind::Barrier => 1,
            EventKind::Stall => 2,
            EventKind::File => 3,
            EventKind::Transmit => 4,
            EventKind::Pool => 5,
            EventKind::Load => 6,
            EventKind::Recode => 7,
            EventKind::ServeBatch => 8,
            EventKind::Fault => 9,
            EventKind::Recovery => 10,
            EventKind::Replay => 11,
            EventKind::Connect => 12,
            EventKind::Control => 13,
        }
    }
}

/// Number of [`EventKind`] variants (size of the depth-counter tables).
const NUM_KINDS: usize = 14;

/// One recorded event. 32 bytes, `Copy` — pushing one is a few stores
/// into an owned ring, no allocation.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// Per-buffer sequence number (program order within the unit).
    pub seq: u64,
    /// Microseconds since the tracer's epoch (job start).
    pub ts_us: u64,
    /// Begin / End / Instant.
    pub phase: EventPhase,
    /// What happened.
    pub kind: EventKind,
    /// Kind-specific argument (see [`EventKind`]).
    pub arg: u64,
}

/// Fixed-capacity, overwrite-oldest ring buffer of [`TraceEvent`]s.
///
/// Single-writer by construction (each [`UnitTracer`] owns one); `push`
/// is branch + store, `drain` returns events oldest→newest and resets
/// the ring (sequence numbers keep counting, so multiple drains from the
/// same buffer merge correctly).
#[derive(Debug)]
pub struct TraceBuf {
    events: Vec<TraceEvent>,
    cap: usize,
    /// Overwrite cursor — index of the *oldest* event once full.
    next: usize,
    /// Total events ever pushed (also the next sequence number).
    seq: u64,
    /// Total events overwritten before they could be drained.
    dropped: u64,
}

impl TraceBuf {
    /// A ring holding at most `cap` events (`cap` is clamped to ≥ 1).
    pub fn new(cap: usize) -> Self {
        Self {
            events: Vec::new(),
            cap: cap.max(1),
            next: 0,
            seq: 0,
            dropped: 0,
        }
    }

    /// Record `e`, stamping its sequence number; overwrites the oldest
    /// retained event when full.
    pub fn push(&mut self, mut e: TraceEvent) {
        e.seq = self.seq;
        self.seq += 1;
        if self.events.len() < self.cap {
            self.events.push(e);
        } else {
            self.events[self.next] = e;
            self.next = (self.next + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Events currently retained.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when nothing is retained.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Total events overwritten (lost) so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Take the retained events oldest→newest and reset the ring (the
    /// sequence counter keeps running).
    pub fn drain(&mut self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.events.len());
        out.extend_from_slice(&self.events[self.next..]);
        out.extend_from_slice(&self.events[..self.next]);
        self.events.clear();
        self.next = 0;
        out
    }
}

/// One unit's drained history, as deposited into the [`Tracer`].
#[derive(Debug)]
pub struct UnitTrace {
    /// Machine index (Chrome `pid`).
    pub machine: usize,
    /// Unit label — `"U_c"`, `"U_s"`, `"U_r"`, `"load"`, `"recode"`,
    /// `"serve"` (Chrome `tid` via a fixed mapping).
    pub unit: &'static str,
    /// Events lost to ring overwrite before this deposit.
    pub dropped: u64,
    /// The retained suffix, oldest→newest.
    pub events: Vec<TraceEvent>,
}

/// Per-job trace collector: hands out [`UnitTracer`]s, gathers their
/// deposits, exports Chrome JSON, and writes flight-recorder dumps.
///
/// Shared as `Arc<Tracer>`; the only lock is around the deposit vector,
/// touched once per unit lifetime (plus at export), never per event.
#[derive(Debug)]
pub struct Tracer {
    cfg: TraceConfig,
    epoch: Instant,
    sink: Mutex<Vec<UnitTrace>>,
}

impl Tracer {
    /// A collector for one job; `cfg.enabled == false` makes every handed
    /// out [`UnitTracer`] a no-op.
    pub fn new(cfg: TraceConfig) -> Self {
        Self {
            cfg,
            epoch: Instant::now(),
            sink: Mutex::new(Vec::new()),
        }
    }

    /// Is tracing on?
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// The knobs this tracer was built with.
    pub fn config(&self) -> &TraceConfig {
        &self.cfg
    }

    /// A recorder for one unit of one machine. Disabled tracers hand out
    /// no-op recorders (no ring allocation).
    pub fn unit(self: &Arc<Self>, machine: usize, unit: &'static str) -> UnitTracer {
        if self.cfg.enabled {
            UnitTracer {
                shared: Some(Arc::clone(self)),
                machine,
                unit,
                buf: TraceBuf::new(self.cfg.capacity),
                epoch: self.epoch,
            }
        } else {
            UnitTracer::disabled()
        }
    }

    fn deposit(&self, t: UnitTrace) {
        // Not a poisonable wait: a panicked depositor leaves a plain Vec,
        // safe to keep using for the remaining deposits/export.
        let mut sink = self.sink.lock().unwrap_or_else(|p| p.into_inner());
        sink.push(t);
    }

    /// Deposits grouped into per-(machine, unit) tracks, events merged by
    /// shared-epoch timestamp (sequence number as tie-break).  Timestamps,
    /// not raw sequence numbers, order the merge because a tracer can
    /// outlive one run: auto-resume re-runs a job into the *same* tracer,
    /// and the retry attempt's `UnitTracer`s restart their sequence
    /// numbers at zero while the shared epoch keeps advancing.
    fn tracks(&self) -> Vec<UnitTrace> {
        let mut sink = self.sink.lock().unwrap_or_else(|p| p.into_inner());
        let mut taken = std::mem::take(&mut *sink);
        drop(sink);
        taken.sort_by_key(|t| (t.machine, t.unit));
        let mut tracks: Vec<UnitTrace> = Vec::new();
        for t in taken {
            match tracks.last_mut() {
                Some(last) if last.machine == t.machine && last.unit == t.unit => {
                    last.dropped = last.dropped.max(t.dropped);
                    last.events.extend(t.events);
                }
                _ => tracks.push(t),
            }
        }
        for t in &mut tracks {
            t.events.sort_by_key(|e| (e.ts_us, e.seq));
        }
        tracks
    }

    /// Write the collected events as Chrome trace-event JSON to `path`
    /// (load it in Perfetto or `chrome://tracing`). One track per
    /// machine×unit (`pid` = machine, `tid` = unit); begin/end pairs are
    /// balanced per track by construction (see the module docs' ordering
    /// argument). The deposit sink is consumed.
    pub fn export_chrome(&self, path: &Path) -> std::io::Result<()> {
        let tracks = self.tracks();
        let mut lines: Vec<String> = Vec::new();
        let mut machines_seen: Vec<usize> = Vec::new();
        for t in &tracks {
            let (pid, tid) = (t.machine, tid_of(t.unit));
            if !machines_seen.contains(&pid) {
                machines_seen.push(pid);
                lines.push(format!(
                    "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
                     \"args\":{{\"name\":\"machine {pid}\"}}}}"
                ));
            }
            lines.push(format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\
                 \"args\":{{\"name\":\"{}\"}}}}",
                t.unit
            ));
            if t.dropped > 0 {
                lines.push(format!(
                    "{{\"name\":\"ring-dropped\",\"cat\":\"meta\",\"ph\":\"i\",\"s\":\"t\",\
                     \"ts\":{},\"pid\":{pid},\"tid\":{tid},\"args\":{{\"dropped\":{}}}}}",
                    t.events.first().map_or(0, |e| e.ts_us),
                    t.dropped
                ));
            }
            // Per-kind span depth: skip unmatched Ends (their Begin was
            // overwritten), remember opens so the track can be closed out.
            let mut depth = [0u64; NUM_KINDS];
            let mut last_ts = 0u64;
            for e in &t.events {
                last_ts = last_ts.max(e.ts_us);
                let ph = match e.phase {
                    EventPhase::Begin => {
                        depth[e.kind.idx()] += 1;
                        "B"
                    }
                    EventPhase::End => {
                        if depth[e.kind.idx()] == 0 {
                            continue; // opener lost to ring overwrite
                        }
                        depth[e.kind.idx()] -= 1;
                        "E"
                    }
                    EventPhase::Instant => "i",
                };
                let scope = if e.phase == EventPhase::Instant { ",\"s\":\"t\"" } else { "" };
                lines.push(format!(
                    "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"{ph}\"{scope},\"ts\":{},\
                     \"pid\":{pid},\"tid\":{tid},\"args\":{{\"v\":{},\"seq\":{}}}}}",
                    e.kind.name(),
                    e.kind.category(),
                    e.ts_us,
                    e.arg,
                    e.seq
                ));
            }
            // Synthetic closes for spans open at track end (unit died or
            // the End fell outside the retained suffix).
            for (k, d) in depth.iter().enumerate() {
                for _ in 0..*d {
                    let kind = KIND_BY_IDX[k];
                    lines.push(format!(
                        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"E\",\"ts\":{last_ts},\
                         \"pid\":{pid},\"tid\":{tid},\"args\":{{\"synthetic\":1}}}}",
                        kind.name(),
                        kind.category()
                    ));
                }
            }
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "{{\"traceEvents\":[")?;
        for (i, l) in lines.iter().enumerate() {
            let sep = if i + 1 == lines.len() { "" } else { "," };
            writeln!(f, "{l}{sep}")?;
        }
        writeln!(f, "],\"displayTimeUnit\":\"ms\"}}")?;
        f.flush()
    }

    /// Crash-time dump: write each machine's units' last
    /// `cfg.flight_events` events to `<dir>/flightrec_<machine>.log`,
    /// headed by `headline` (the `Error::JobFailed` display — machine,
    /// unit, superstep, cause of the first `AbortCause`). Returns the
    /// files written. The deposit sink is consumed.
    pub fn flight_record(&self, dir: &Path, headline: &str) -> std::io::Result<Vec<PathBuf>> {
        let tracks = self.tracks();
        let mut files = Vec::new();
        let mut machines: Vec<usize> = tracks.iter().map(|t| t.machine).collect();
        machines.sort_unstable();
        machines.dedup();
        std::fs::create_dir_all(dir)?;
        for m in machines {
            let path = dir.join(format!("flightrec_{m}.log"));
            let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
            writeln!(f, "== graphd flight recorder — machine {m} ==")?;
            writeln!(f, "cause: {headline}")?;
            for t in tracks.iter().filter(|t| t.machine == m) {
                let tail_from = t.events.len().saturating_sub(self.cfg.flight_events);
                writeln!(
                    f,
                    "-- {} (last {} of {} events, {} lost to ring overwrite) --",
                    t.unit,
                    t.events.len() - tail_from,
                    t.dropped + t.events.len() as u64,
                    t.dropped
                )?;
                for e in &t.events[tail_from..] {
                    let ph = match e.phase {
                        EventPhase::Begin => "B",
                        EventPhase::End => "E",
                        EventPhase::Instant => "i",
                    };
                    writeln!(
                        f,
                        "  +{:>10}us {ph} {:<11} arg={}",
                        e.ts_us,
                        e.kind.name(),
                        e.arg
                    )?;
                }
            }
            f.flush()?;
            files.push(path);
        }
        Ok(files)
    }
}

/// All kinds, indexed by [`EventKind::idx`] (for the synthetic-close pass).
const KIND_BY_IDX: [EventKind; NUM_KINDS] = [
    EventKind::Superstep,
    EventKind::Barrier,
    EventKind::Stall,
    EventKind::File,
    EventKind::Transmit,
    EventKind::Pool,
    EventKind::Load,
    EventKind::Recode,
    EventKind::ServeBatch,
    EventKind::Fault,
    EventKind::Recovery,
    EventKind::Replay,
];

/// Fixed unit → Chrome `tid` mapping (one track per machine×unit).
fn tid_of(unit: &str) -> usize {
    match unit {
        "U_c" => 0,
        "U_s" => 1,
        "U_r" => 2,
        "load" => 3,
        "recode" => 4,
        "serve" => 5,
        "recover" => 6,
        _ => 7,
    }
}

/// One unit's lock-free event recorder. Created via [`Tracer::unit`]
/// (or [`UnitTracer::disabled`]); owned by exactly one thread; call
/// [`UnitTracer::finish`] after the unit body returns — including after a
/// caught panic — so the flight recorder sees the final events.
#[derive(Debug)]
pub struct UnitTracer {
    shared: Option<Arc<Tracer>>,
    machine: usize,
    unit: &'static str,
    buf: TraceBuf,
    epoch: Instant,
}

impl UnitTracer {
    /// A recorder that records nothing (the `enabled == false` path).
    pub fn disabled() -> Self {
        Self {
            shared: None,
            machine: 0,
            unit: "",
            buf: TraceBuf::new(1),
            epoch: Instant::now(),
        }
    }

    /// Is this recorder live? (False for [`UnitTracer::disabled`].)
    #[inline]
    pub fn enabled(&self) -> bool {
        self.shared.is_some()
    }

    #[inline]
    fn push(&mut self, phase: EventPhase, kind: EventKind, arg: u64) {
        if self.shared.is_none() {
            return;
        }
        let ts_us = self.epoch.elapsed().as_micros() as u64;
        self.buf.push(TraceEvent {
            seq: 0, // stamped by the ring
            ts_us,
            phase,
            kind,
            arg,
        });
    }

    /// Open a span.
    #[inline]
    pub fn begin(&mut self, kind: EventKind, arg: u64) {
        self.push(EventPhase::Begin, kind, arg);
    }

    /// Close the innermost open span of `kind`.
    #[inline]
    pub fn end(&mut self, kind: EventKind, arg: u64) {
        self.push(EventPhase::End, kind, arg);
    }

    /// Record a point event.
    #[inline]
    pub fn instant(&mut self, kind: EventKind, arg: u64) {
        self.push(EventPhase::Instant, kind, arg);
    }

    /// Deposit the retained events into the shared [`Tracer`]. Call after
    /// the unit body returns (the sites wrap unit bodies in
    /// `JobAbort::guard`, which catches panics, so `finish` runs even for
    /// a dying unit). May be called repeatedly — each call deposits the
    /// events since the last one.
    pub fn finish(&mut self) {
        let Some(shared) = &self.shared else { return };
        if self.buf.is_empty() && self.buf.dropped() == 0 {
            return;
        }
        let t = UnitTrace {
            machine: self.machine,
            unit: self.unit,
            dropped: self.buf.dropped(),
            events: self.buf.drain(),
        };
        shared.deposit(t);
    }
}

/// Process-global bounded ring of structured diagnostic lines (see
/// [`diag`]).
static DIAG: Mutex<VecDeque<String>> = Mutex::new(VecDeque::new());

/// Emit a structured diagnostic: mirrored to stderr as
/// `[graphd::<scope>] <msg>` for humans, and retained (bounded, oldest
/// dropped) for [`recent_diagnostics`] so tests and daemons can assert on
/// engine diagnostics instead of scraping stderr.
///
/// This is the sanctioned print sink for `worker/`, `engine/`, `net/`,
/// and `serve/` — the `print-hygiene` analyzer rule points here.
pub fn diag(scope: &str, msg: &str) {
    eprintln!("[graphd::{scope}] {msg}");
    let mut q = DIAG.lock().unwrap_or_else(|p| p.into_inner());
    if q.len() >= DIAG_CAP {
        q.pop_front();
    }
    q.push_back(format!("[{scope}] {msg}"));
}

/// The most recent [`diag`] lines (oldest first, at most the ring bound).
pub fn recent_diagnostics() -> Vec<String> {
    let q = DIAG.lock().unwrap_or_else(|p| p.into_inner());
    q.iter().cloned().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn export_sanitizes_unmatched_ends_and_open_spans() {
        let tracer = Arc::new(Tracer::new(TraceConfig::on()));
        let mut tr = tracer.unit(0, "U_c");
        // An End with no Begin (opener "lost"), then a Begin never closed.
        tr.end(EventKind::Superstep, 0);
        tr.begin(EventKind::Barrier, 1);
        tr.finish();
        let p = std::env::temp_dir().join(format!("graphd_trace_sanitize_{}", std::process::id()));
        tracer.export_chrome(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        std::fs::remove_file(&p).unwrap();
        // The unmatched superstep End is gone; the barrier span gained a
        // synthetic close — B and E counts balance.
        let b = s.matches("\"ph\":\"B\"").count();
        let e = s.matches("\"ph\":\"E\"").count();
        assert_eq!((b, e), (1, 1), "{s}");
        assert!(s.contains("\"synthetic\":1"), "{s}");
        assert!(!s.contains("\"name\":\"superstep\",\"cat\":\"phase\",\"ph\":\"E\""), "{s}");
    }

    #[test]
    fn disabled_tracer_hands_out_noop_recorders() {
        let tracer = Arc::new(Tracer::new(TraceConfig::default()));
        let mut tr = tracer.unit(3, "U_s");
        assert!(!tr.enabled());
        tr.begin(EventKind::Superstep, 0);
        tr.finish();
        assert!(tracer.tracks().is_empty());
    }

    #[test]
    fn tracks_merge_multiple_deposits_in_seq_order() {
        let tracer = Arc::new(Tracer::new(TraceConfig::on()));
        let mut tr = tracer.unit(1, "U_r");
        tr.push(EventPhase::Instant, EventKind::File, 10);
        tr.finish();
        tr.push(EventPhase::Instant, EventKind::File, 11);
        tr.finish();
        let tracks = tracer.tracks();
        assert_eq!(tracks.len(), 1);
        let seqs: Vec<u64> = tracks[0].events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![0, 1]);
        let args: Vec<u64> = tracks[0].events.iter().map(|e| e.arg).collect();
        assert_eq!(args, vec![10, 11]);
    }

    #[test]
    fn flight_record_tails_and_names_units() {
        let mut cfg = TraceConfig::on();
        cfg.flight_events = 2;
        let tracer = Arc::new(Tracer::new(cfg));
        let mut tr = tracer.unit(2, "U_c");
        for s in 0..5 {
            tr.instant(EventKind::Superstep, s);
        }
        tr.finish();
        let dir = std::env::temp_dir().join(format!("graphd_flightrec_{}", std::process::id()));
        let files = tracer.flight_record(&dir, "U_c of machine 2 failed").unwrap();
        assert_eq!(files.len(), 1);
        let s = std::fs::read_to_string(&files[0]).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        assert!(s.contains("cause: U_c of machine 2 failed"), "{s}");
        assert!(s.contains("-- U_c"), "{s}");
        // Only the 2-event tail appears.
        assert!(s.contains("arg=3") && s.contains("arg=4"), "{s}");
        assert!(!s.contains("arg=0\n"), "{s}");
    }

    #[test]
    fn diag_mirrors_into_bounded_ring() {
        diag("test-scope", "hello ring");
        let got = recent_diagnostics();
        assert!(got.iter().any(|l| l == "[test-scope] hello ring"), "{got:?}");
    }
}
