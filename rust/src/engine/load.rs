//! Parallel graph loading from the simulated HDFS (§3.4).
//!
//! Machine `i` parses the text-file blocks `j ≡ i (mod n)`; each parsed
//! vertex is routed over the (simulated) network to its owner
//! `hash(id)`, which spills the received records to disk, then sorts them
//! by vertex ID and splits them into the state array `A` + edge stream
//! `S^E` — the "received vertices are merge-sorted by vertex ID into S^I,
//! which then gets splitted into A and S^E" path of the paper.

use crate::dfs::Dfs;
use crate::engine::Engine;
use crate::error::{Error, Result};
use crate::graph::formats;
use crate::net::{self, Payload};
use crate::worker::storage::{EdgeStreamWriter, MachineStore};
use crate::worker::sync::JobAbort;
use crate::worker::Partitioning;
use std::sync::atomic::AtomicU64;

/// Wire format of one loading record:
/// `id u32 | deg u32 | deg × (nbr u32 [, w f32])`.
fn encode_vertex(line: &formats::VertexLine, weighted: bool, out: &mut Vec<u8>) {
    out.extend_from_slice(&line.id.to_le_bytes());
    out.extend_from_slice(&(line.nbrs.len() as u32).to_le_bytes());
    for (k, &nbr) in line.nbrs.iter().enumerate() {
        out.extend_from_slice(&nbr.to_le_bytes());
        if weighted {
            let w = line.weights.as_ref().map_or(1.0, |ws| ws[k]);
            out.extend_from_slice(&w.to_le_bytes());
        }
    }
}

/// Load a text graph from `dfs` into per-machine stores.
///
/// Deprecated shim: the session API is the supported entry point —
/// `session.load(GraphSource::Text { .. })` (see [`crate::session`]).
#[deprecated(
    since = "0.2.0",
    note = "use the session API: session.load(GraphSource::Text { name, weighted, directed })"
)]
pub fn load_text(eng: &Engine, dfs: &Dfs, name: &str, weighted: bool) -> Result<Vec<MachineStore>> {
    load_text_impl(eng, dfs, name, weighted)
}

/// Parallel text loading (§3.4): machine `i` parses blocks `j ≡ i (mod n)`
/// into `n` per-machine stores under `<workdir>/m<i>/basic/`.  Returns the
/// stores (state arrays in memory).  [`crate::session::Session::load`] is
/// the public face of this function.
pub(crate) fn load_text_impl(
    eng: &Engine,
    dfs: &Dfs,
    name: &str,
    weighted: bool,
) -> Result<Vec<MachineStore>> {
    let n = eng.profile.machines;
    let nblocks = dfs.num_blocks(name)?;
    // Loading has the same deadlock shape as the superstep loop: a parser
    // that dies (bad input line, DFS error) never sends its LoadEnd tags,
    // wedging every receiver — so the phase gets its own abort latch,
    // observed by the channel waits.
    let abort = JobAbort::new();
    let (endpoints, _switch) = net::build(
        n,
        eng.profile.net_bytes_per_sec,
        eng.profile.latency_us,
        eng.cfg.local_fastpath,
        Some(abort.clone()),
    );
    let part = Partitioning::Hashed;
    let item = if weighted { 8usize } else { 4 };
    let cap = eng.cfg.oms_file_cap.max(64 * 1024);
    // Loading also recycles its wire batches: the parser checks buffers
    // out, the receiving half returns consumed `Payload::Load` blocks.
    let pool = crate::msg::BufPool::new(4 * n + 8);
    // Load-phase tracer: one "load" track per machine, exported to
    // `<workdir>/trace_load.json`; on failure the rings dump beside it.
    let tracer = std::sync::Arc::new(crate::trace::Tracer::new(eng.cfg.trace.clone()));

    let mut results: Vec<Option<Result<MachineStore>>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (i, (sender, receiver)) in endpoints.into_iter().enumerate() {
            let store_dir = eng.store_dir(i, "basic");
            let dfs = dfs.clone();
            let name = name.to_string();
            let disk = eng
                .profile
                .disk_bytes_per_sec
                .map(crate::util::diskio::DiskBw::new);
            let pool = pool.clone();
            let abort = abort.clone();
            let tracer = tracer.clone();
            handles.push(scope.spawn(move || -> Result<MachineStore> {
                let _dg = crate::util::diskio::register(disk.clone());
                // --- parser half (own thread so receive can overlap) ---
                let parser = {
                    let dfs = dfs.clone();
                    let name = name.clone();
                    let mut sender = sender;
                    let pool = pool.clone();
                    let abort = abort.clone();
                    std::thread::spawn(move || -> Result<()> {
                        // guard(): a parser that errors (or panics) before
                        // sending its LoadEnd tags trips the abort so every
                        // blocked receiver unblocks typed.
                        let phase = AtomicU64::new(0);
                        abort.guard(i, "load", &phase, || {
                            let nmach = sender.peers();
                            let mut bufs: Vec<Vec<u8>> =
                                (0..nmach).map(|_| pool.take()).collect();
                            for blk in (i as u64..nblocks).step_by(nmach) {
                                for line in dfs.read_block_lines(&name, blk)? {
                                    let vl = formats::parse_line(&line)?;
                                    let dst = part.machine_of(vl.id, nmach);
                                    encode_vertex(&vl, weighted, &mut bufs[dst]);
                                    if bufs[dst].len() >= cap {
                                        let b =
                                            std::mem::replace(&mut bufs[dst], pool.take());
                                        sender.send(dst, 0, Payload::Load(b))?;
                                    }
                                }
                            }
                            for dst in 0..nmach {
                                let b = std::mem::take(&mut bufs[dst]);
                                if b.is_empty() {
                                    pool.put(b);
                                } else {
                                    sender.send(dst, 0, Payload::Load(b))?;
                                }
                                sender.send(dst, 0, Payload::LoadEnd)?;
                            }
                            Ok(())
                        })
                    })
                };

                // --- receiver half: spill, index, sort, split ---
                let phase = AtomicU64::new(0);
                // Load spans: arg 1 = receive/spill, arg 2 = sort/split.
                let mut tr = tracer.unit(i, "load");
                let out = abort.guard(i, "load", &phase, || {
                    tr.begin(crate::trace::EventKind::Load, 1);
                    let _ = std::fs::remove_dir_all(&store_dir);
                    std::fs::create_dir_all(&store_dir)?;
                    let spill_path = store_dir.join("load_spill");
                    let mut spill = std::io::BufWriter::new(std::fs::File::create(&spill_path)?);
                    // (id, deg, byte offset of adjacency in spill)
                    let mut index: Vec<(u32, u32, u64)> = Vec::new();
                    let mut spill_off = 0u64;
                    let mut ends = 0usize;
                    let nmach = n;
                    while ends < nmach {
                        let b = receiver.recv()?;
                        match b.payload {
                            Payload::LoadEnd => ends += 1,
                            Payload::Load(data) => {
                                let mut off = 0usize;
                                while off < data.len() {
                                    let id = u32::from_le_bytes(data[off..off + 4].try_into().unwrap());
                                    let deg =
                                        u32::from_le_bytes(data[off + 4..off + 8].try_into().unwrap());
                                    let adj_bytes = deg as usize * item;
                                    let adj = &data[off + 8..off + 8 + adj_bytes];
                                    use std::io::Write;
                                    spill.write_all(adj)?;
                                    index.push((id, deg, spill_off));
                                    spill_off += adj_bytes as u64;
                                    off += 8 + adj_bytes;
                                }
                                pool.put(data);
                            }
                            _ => return Err(Error::CorruptStream("data batch during load".into())),
                        }
                    }
                    {
                        use std::io::Write;
                        spill.flush()?;
                    }
                    parser
                        .join()
                        .map_err(|e| Error::WorkerPanic { machine: i, cause: format!("{e:?}") })??;
                    tr.end(crate::trace::EventKind::Load, 1);
                    tr.begin(crate::trace::EventKind::Load, 2);

                    // Sort the state array by vertex ID; S^E follows A's order.
                    index.sort_unstable_by_key(|r| r.0);
                    if let Some(w) = index.windows(2).find(|w| w[0].0 == w[1].0) {
                        return Err(Error::CorruptStream(format!(
                            "duplicate vertex id {} in input",
                            w[0].0
                        )));
                    }
                    let ids: Vec<u32> = index.iter().map(|r| r.0).collect();
                    let degs: Vec<u32> = index.iter().map(|r| r.1).collect();
                    let mut se = EdgeStreamWriter::create(&store_dir, weighted, eng.cfg.stream_buf)?;
                    let spill_file = std::fs::File::open(&spill_path)?;
                    let mut adj_buf = Vec::new();
                    for &(_, deg, off) in &index {
                        let adj_bytes = deg as usize * item;
                        adj_buf.resize(adj_bytes, 0);
                        read_exact_at(&spill_file, &mut adj_buf, off)?;
                        for chunk in adj_buf.chunks_exact(item) {
                            let nbr = u32::from_le_bytes(chunk[..4].try_into().unwrap());
                            let w = if weighted {
                                f32::from_le_bytes(chunk[4..8].try_into().unwrap())
                            } else {
                                1.0
                            };
                            se.push(nbr, w)?;
                        }
                    }
                    se.finish()?;
                    let _ = std::fs::remove_file(&spill_path);

                    let store = MachineStore {
                        dir: store_dir,
                        machine: i,
                        num_machines: nmach,
                        total_vertices: 0, // fixed below
                        weighted,
                        recoded: false,
                        ids,
                        degs,
                    };
                    tr.end(crate::trace::EventKind::Load, 2);
                    Ok(store)
                });
                tr.finish();
                out
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            results[i] = Some(h.join().unwrap_or_else(|e| {
                Err(Error::WorkerPanic { machine: i, cause: format!("{e:?}") })
            }));
        }
    });

    let collected: Result<Vec<MachineStore>> =
        results.into_iter().map(|r| r.unwrap()).collect();
    let mut stores = match collected {
        Ok(s) => s,
        Err(e) => {
            let e = abort.first_cause_or(e);
            if tracer.enabled() {
                let _ = tracer.flight_record(&eng.cfg.workdir, &e.to_string());
            }
            return Err(e);
        }
    };
    if tracer.enabled() {
        tracer.export_chrome(&eng.cfg.workdir.join("trace_load.json"))?;
    }
    let total: u64 = stores.iter().map(|s| s.ids.len() as u64).sum();
    for s in &mut stores {
        s.total_vertices = total;
        s.save()?;
        // Resident store (`-c resident=`): materialize the mmap-able CSR
        // pair beside se.bin at load time, so the first superstep maps
        // instead of paying a materialization stall.  `auto` only writes
        // when the pair fits the budget; reuse is checksum-keyed.
        crate::worker::csr::prepare(s, eng.cfg.resident, eng.cfg.resident_budget)?;
    }
    Ok(stores)
}

fn read_exact_at(f: &std::fs::File, buf: &mut [u8], off: u64) -> Result<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        f.read_exact_at(buf, off)?;
        Ok(())
    }
    #[cfg(not(unix))]
    {
        let mut f2 = f.try_clone()?;
        use std::io::{Seek, SeekFrom};
        f2.seek(SeekFrom::Start(off))?;
        f2.read_exact(buf)?;
        Ok(())
    }
}

/// Reload previously saved stores ("load graph from local disks").
pub fn load_local(eng: &Engine, kind: &str) -> Result<Vec<MachineStore>> {
    (0..eng.profile.machines)
        .map(|i| MachineStore::load(&eng.store_dir(i, kind)))
        .collect()
}

/// Write a [`crate::graph::Graph`] to the dfs as a text file, optionally
/// through a sparse old-ID mapping, and return (name, id mapping used).
pub fn put_graph(
    dfs: &Dfs,
    name: &str,
    g: &crate::graph::Graph,
    sparse_seed: Option<u64>,
) -> Result<Option<Vec<u32>>> {
    let ids = sparse_seed.map(|s| formats::sparse_ids(g.num_vertices(), s));
    let mut buf = Vec::new();
    formats::write_text(g, ids.as_deref(), &mut buf)?;
    dfs.put(name, &buf)?;
    Ok(ids)
}

// `Read` used by the non-unix fallback only.
#[allow(unused_imports)]
use std::io::Read as _;
