//! The GraphD engine facade: load a graph from the (simulated) HDFS into
//! per-machine stores, run vertex programs in IO-Basic or IO-Recoded mode,
//! and gather results + metrics.
//!
//! ```ignore
//! let eng = Engine::new(profile, cfg)?;
//! let stores = eng.load_text(&dfs, "graph.txt", weighted)?;   // "Load"
//! let rec    = recode::recode(&eng, &stores)?;                // "IO-Recoding"
//! let out    = eng.run(&rec, Arc::new(PageRank::new(10)))?;   // "Compute"
//! ```

pub mod load;
pub mod run;

use crate::config::{ClusterProfile, JobConfig};
use crate::error::Result;
use std::path::PathBuf;

pub use load::load_text;
pub use run::{run_job, JobResult};

/// Engine handle: profile + config + working directory.
pub struct Engine {
    pub profile: ClusterProfile,
    pub cfg: JobConfig,
}

impl Engine {
    pub fn new(profile: ClusterProfile, cfg: JobConfig) -> Result<Self> {
        std::fs::create_dir_all(&cfg.workdir)?;
        Ok(Self { profile, cfg })
    }

    /// Per-machine store directory for `store` generation ("basic"/"rec").
    pub fn store_dir(&self, machine: usize, kind: &str) -> PathBuf {
        self.cfg.workdir.join(format!("m{machine}")).join(kind)
    }
}
