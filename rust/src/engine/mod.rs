//! The GraphD engine internals: load a graph from the (simulated) HDFS
//! into per-machine stores, run vertex programs in IO-Basic or IO-Recoded
//! mode, and gather results + metrics.
//!
//! Callers should not wire these pieces by hand any more — the fluent
//! session API ([`crate::session`]) is the single entry point for the
//! Load → IO-Recoding → Compute pipeline:
//!
//! ```ignore
//! let session = GraphD::builder().machines(4).workdir(wd).build()?;
//! let mut graph = session.load(GraphSource::InMemory(&g))?;   // "Load"
//! graph.recode()?;                                            // "IO-Recoding"
//! let out = graph.job(Arc::new(PageRank::new(10)))            // "Compute"
//!     .mode(Mode::Auto)
//!     .run()?;
//! ```
//!
//! The free functions `load::load_text` / `run::run_job` remain as thin
//! deprecated shims so out-of-tree code keeps compiling.

pub mod load;
pub mod run;

use crate::config::{ClusterProfile, JobConfig};
use crate::error::Result;
use std::path::PathBuf;

#[allow(deprecated)]
pub use load::load_text;
#[allow(deprecated)]
pub use run::run_job;
pub use run::JobResult;

/// Engine handle: profile + config + working directory.
pub struct Engine {
    pub profile: ClusterProfile,
    pub cfg: JobConfig,
}

impl Engine {
    pub fn new(profile: ClusterProfile, cfg: JobConfig) -> Result<Self> {
        std::fs::create_dir_all(&cfg.workdir)?;
        Ok(Self { profile, cfg })
    }

    /// Per-machine store directory for `store` generation ("basic"/"rec").
    pub fn store_dir(&self, machine: usize, kind: &str) -> PathBuf {
        self.cfg.workdir.join(format!("m{machine}")).join(kind)
    }
}
