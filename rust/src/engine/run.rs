//! Job runner: spin up `n` machines (threads), run the superstep loop to
//! termination, gather values + metrics.

use crate::api::VertexProgram;
use crate::config::Mode;
use crate::engine::Engine;
use crate::error::{Error, Result};
use crate::metrics::JobMetrics;
use crate::net;
use crate::util::timer::timed;
use crate::worker::storage::MachineStore;
use crate::worker::sync::{AbortCause, BarrierLink, JobAbort, Poisonable, Rendezvous, RvCodec};
use crate::worker::units::{
    decode_uc_decision, decode_uc_report, encode_uc_decision, encode_uc_report,
    read_replay_manifest, run_machine, JobGlobal, MachineOutput, UcDecision, UcReport,
};
use std::sync::Arc;

/// Session-layer hooks into one engine run (auto-resume plumbing).
///
/// `JobBuilder::run`'s retry loop re-invokes [`run_job_with_impl`] once per
/// attempt; these hooks let the attempts share what must be shared (the
/// trace collector, so one export holds the fault, the recovery, and the
/// re-run) and rebuild what must be rebuilt (the abort latch — see
/// [`JobAbort::reset_for_retry`]).  `Default` is the standalone shape: own
/// latch, own tracer, engine-owned trace export.
#[derive(Default)]
pub(crate) struct RunHooks {
    /// Shared trace collector.  When set, the engine deposits into it but
    /// does NOT export/flight-record — the owner (the session retry loop)
    /// drives the consumers once, after the final attempt.
    pub tracer: Option<Arc<crate::trace::Tracer>>,
    /// The abort latch to run under.  Must be untripped: a tripped latch
    /// (and everything registered on it) is single-use, so a retry that
    /// reused one would fail instantly with the previous attempt's cause.
    pub abort: Option<Arc<JobAbort>>,
}

/// Result of one GraphD job.
pub struct JobResult<P: VertexProgram> {
    pub outputs: Vec<MachineOutput<P>>,
    pub metrics: JobMetrics,
}

impl<P: VertexProgram> JobResult<P> {
    /// All (input-space id, value) pairs, sorted by id.
    pub fn values_by_id(&self) -> Vec<(u32, P::Value)> {
        let mut v: Vec<(u32, P::Value)> = self
            .outputs
            .iter()
            .flat_map(|o| o.ids.iter().copied().zip(o.values.iter().copied()))
            .collect();
        v.sort_unstable_by_key(|(id, _)| *id);
        // A vertex id reported by two machines means the partitioner
        // double-assigned it — without this check the duplicate row would
        // silently survive the sort.
        debug_assert!(
            v.windows(2).all(|w| w[0].0 != w[1].0),
            "duplicate vertex id across machine outputs (partitioner bug)"
        );
        v
    }

    pub fn supersteps(&self) -> u64 {
        self.outputs.first().map_or(0, |o| o.supersteps)
    }
}

/// Run `program` over the given per-machine stores.
///
/// Deprecated shim: the fluent session API is the supported entry point —
/// `GraphD::builder()…build()?.load(src)?.run(program)` (see
/// [`crate::session`]).  Kept so out-of-tree callers still compile;
/// behaviour is identical to `Session`/`JobBuilder` runs.
#[deprecated(
    since = "0.2.0",
    note = "use the session API: GraphD::builder()…load(..)?.run(program) or .job(program).run()"
)]
pub fn run_job<P: VertexProgram>(
    eng: &Engine,
    stores: &[MachineStore],
    program: Arc<P>,
) -> Result<JobResult<P>> {
    run_job_with_impl(eng, stores, program, None, None, RunHooks::default())
}

/// Run with optional checkpointing and/or recovery.
///
/// Deprecated shim over the session API: use
/// `graph.job(program).checkpoint(cfg).resume(step).run()` instead.
#[deprecated(
    since = "0.2.0",
    note = "use the session API: graph.job(program).checkpoint(cfg).resume(step).run()"
)]
pub fn run_job_with<P: VertexProgram>(
    eng: &Engine,
    stores: &[MachineStore],
    program: Arc<P>,
    checkpoint: Option<crate::ft::CheckpointCfg>,
    resume: Option<u64>,
) -> Result<JobResult<P>> {
    run_job_with_impl(eng, stores, program, checkpoint, resume, RunHooks::default())
}

/// The actual job driver: spin up `n` machine threads, run the superstep
/// loop to termination, gather values + metrics.  `checkpoint` enables
/// periodic checkpoints (§3.4); `resume = Some(s)` restarts from the
/// completed checkpoint taken after superstep `s`.  Session [`crate::session::JobBuilder`]
/// is the public face of this function.
pub(crate) fn run_job_with_impl<P: VertexProgram>(
    eng: &Engine,
    stores: &[MachineStore],
    program: Arc<P>,
    checkpoint: Option<crate::ft::CheckpointCfg>,
    resume: Option<u64>,
    hooks: RunHooks,
) -> Result<JobResult<P>> {
    let n = eng.profile.machines;
    if stores.len() != n {
        return Err(Error::Config(format!(
            "{} stores for {} machines",
            stores.len(),
            n
        )));
    }
    let total_vertices = stores[0].total_vertices;
    let max_local = stores.iter().map(|s| s.local_vertices()).max().unwrap_or(0);
    let step_base = resume.map_or(0, |s| s + 1);
    let ckpt_dir = checkpoint.as_ref().map(|c| c.dir.clone());
    // Fast recovery (§3.4): when the previous attempt retained its message
    // logs, resume can *replay* the already-received S^I files instead of
    // recomputing the senders.  The window is the largest superstep R such
    // that every machine has verified, contiguous replay coverage of
    // [step_base, R].  Digesting mode folds messages into dense arrays and
    // never materialises S^I, so it always recomputes.
    let digesting = eng.cfg.mode == Mode::Recoded && P::Comb::ENABLED;
    let replay_upto = if resume.is_some() && eng.cfg.keep_oms_for_recovery && !digesting {
        compute_replay_window(stores, step_base)
    } else {
        None
    };
    // Job-wide buffer pool: enough shelf space for every machine's outbox
    // batches plus in-flight wire payloads and stream-writer buffers.
    let pool = crate::msg::BufPool::new(4 * n * n + 4 * n + 16);
    // Digest-array pool: per machine at most three O(|V|/n) arrays are in
    // flight (U_r's A_r, U_c's consumed one, the local shard) — they
    // ping-pong instead of reallocating every superstep.
    let digest_pool = crate::msg::DigestPool::new(3 * n);
    // Failure propagation: the job abort latch poisons every inter-machine
    // barrier (registered here) and every machine's own sync (registered by
    // run_machine), and is polled by the channel/switch waits in `net` —
    // so one dead unit surfaces as Error::JobFailed at every machine
    // instead of wedging the survivors.
    let abort = match hooks.abort {
        Some(a) => {
            if a.aborted() {
                // A tripped latch has already poisoned everything that will
                // ever register on it; running under it would fail with the
                // *previous* attempt's cause.  Retry loops must hand over a
                // fresh latch (JobAbort::reset_for_retry).
                return Err(Error::Other(
                    "engine started with a tripped abort latch; retries must rebuild it \
                     via JobAbort::reset_for_retry"
                        .into(),
                ));
            }
            a
        }
        None => JobAbort::new(),
    };
    let uc_rv: Arc<Rendezvous<UcReport<P::Agg>, UcDecision<P::Agg>>> = Rendezvous::new(n);
    let ur_rv: Arc<Rendezvous<(), ()>> = Rendezvous::new(n);
    let ckpt_rv: Arc<Rendezvous<(), ()>> = Rendezvous::new(n);
    abort.register(uc_rv.clone() as Arc<dyn Poisonable>);
    abort.register(ur_rv.clone() as Arc<dyn Poisonable>);
    abort.register(ckpt_rv.clone() as Arc<dyn Poisonable>);
    // Flight recorder / Chrome-trace collector: disabled configs hand out
    // no-op unit tracers, so the superstep loop pays one branch per event.
    // When the session retry loop supplies a shared tracer, this run only
    // deposits into it — export/flight-record are the owner's job, so the
    // final file holds every attempt on one timeline.
    let owns_trace_outputs = hooks.tracer.is_none();
    let tracer = hooks
        .tracer
        .unwrap_or_else(|| Arc::new(crate::trace::Tracer::new(eng.cfg.trace.clone())));
    let global = JobGlobal {
        program: program.clone(),
        cfg: eng.cfg.clone(),
        n,
        total_vertices,
        max_local,
        checkpoint,
        step_base,
        uc_rv,
        ur_rv,
        ckpt_rv,
        pool: pool.clone(),
        digest_pool: digest_pool.clone(),
        abort: abort.clone(),
        tracer: tracer.clone(),
        replay_upto,
        distributed: false,
    };

    let (endpoints, switch) = net::build(
        n,
        eng.profile.net_bytes_per_sec,
        eng.profile.latency_us,
        eng.cfg.local_fastpath,
        Some(abort.clone()),
    );

    let (compute_secs, outputs) = timed(|| -> Result<Vec<MachineOutput<P>>> {
        let mut results: Vec<Option<Result<MachineOutput<P>>>> = (0..n).map(|_| None).collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (i, (sender, receiver)) in endpoints.into_iter().enumerate() {
                let store = stores[i].clone();
                let global = &global;
                let program = program.clone();
                let eng = &eng;
                let disk = eng
                    .profile
                    .disk_bytes_per_sec
                    .map(crate::util::diskio::DiskBw::new);
                let ckpt_dir = ckpt_dir.clone();
                handles.push(scope.spawn(move || -> Result<MachineOutput<P>> {
                    // Outer guard: catches failures *outside* the unit
                    // loops (job-dir setup, checkpoint reads on resume) so
                    // even a machine that dies before its units start trips
                    // the abort instead of wedging its siblings.  Unit
                    // failures arrive here already converted to JobFailed
                    // and pass through without re-tripping.
                    let beacon = std::sync::atomic::AtomicU64::new(step_base);
                    global.abort.guard(i, "U_c", &beacon, || {
                        if let Some(rs) = resume {
                            // Recovery: reload values/halted/IMS from the
                            // checkpoint; the store (A + S^E) is reloaded
                            // from its durable on-disk form by the caller
                            // already.
                            let dir = ckpt_dir.as_ref().ok_or_else(|| {
                                Error::Config("resume without checkpoint dir".into())
                            })?;
                            let scratch = store.dir.join("recovery");
                            let rec: crate::ft::Recovered<P::Value, P::Msg> =
                                crate::ft::read_machine_checkpoint(dir, rs, i, &scratch)?;
                            // Mark the resume point (and whether a replay
                            // window is armed) on this machine's timeline.
                            let mut rtr = global.tracer.unit(i, "recover");
                            rtr.instant(crate::trace::EventKind::Recovery, rs);
                            if let Some(r) = global.replay_upto {
                                rtr.instant(crate::trace::EventKind::Replay, r);
                            }
                            rtr.finish();
                            return crate::worker::units::run_machine_resumed(
                                global,
                                store,
                                rec.vals,
                                Some(rec.halted),
                                Some(rec.incoming),
                                sender,
                                receiver,
                                disk,
                            );
                        }
                        // Initial values from the program (cheap, O(|V|/n)).
                        let init: Vec<P::Value> = (0..store.local_vertices())
                            .map(|pos| {
                                program.init_value(
                                    store.id_at(pos),
                                    store.degs[pos],
                                    store.total_vertices,
                                )
                            })
                            .collect();
                        run_machine(global, store, init, sender, receiver, disk)
                    })
                }));
            }
            for (i, h) in handles.into_iter().enumerate() {
                results[i] = Some(h.join().unwrap_or_else(|e| {
                    // Residual machine-thread panics (unit panics are
                    // already caught and converted by the abort guards):
                    // trip the latch so surviving machines unblock too.
                    let cause = abort.trip(AbortCause {
                        machine: i,
                        unit: "U_c",
                        superstep: 0,
                        cause: format!("machine thread panicked: {e:?}"),
                    });
                    Err(cause.to_error())
                }));
            }
        });
        results.into_iter().map(|r| r.unwrap()).collect()
    });
    let outputs: Vec<MachineOutput<P>> = match outputs {
        Ok(o) => o,
        Err(e) => {
            let e = abort.first_cause_or(e);
            // Flight recorder: drain every unit's ring into
            // `flightrec_<machine>.log` before surfacing the typed failure,
            // so post-mortems see what each unit was doing when the first
            // cause tripped.  Best-effort — the job error wins.  Skipped
            // under a shared tracer: the retry loop decides whether this
            // failure is final before draining the rings.
            if owns_trace_outputs && tracer.enabled() {
                let _ = tracer.flight_record(&eng.cfg.workdir, &e.to_string());
            }
            return Err(e);
        }
    };
    if owns_trace_outputs && tracer.enabled() {
        let path = eng
            .cfg
            .trace
            .path
            .clone()
            .unwrap_or_else(|| eng.cfg.workdir.join("trace.json"));
        tracer.export_chrome(&path)?;
    }

    let metrics = JobMetrics {
        load_secs: 0.0,
        compute_secs,
        preprocess_secs: 0.0,
        supersteps: step_base + outputs.first().map_or(0, |o| o.supersteps),
        machines: outputs.iter().map(|o| o.metrics.clone()).collect(),
        net_wire_bytes: switch.total_bytes(),
        net_local_bytes: switch.local_bytes(),
        pool: pool.stats(),
        digest_pool: digest_pool.stats(),
        recoveries: 0,
        retried_supersteps: 0,
    };
    Ok(JobResult { outputs, metrics })
}

/// The TCP-transport job driver: this process runs exactly **one** machine
/// (`cfg.transport_rank`); its `n−1` siblings are other OS processes
/// reached through a [`crate::net::tcp::TcpCluster`].  The superstep loop
/// itself is untouched — the same [`run_machine`] body runs over a real
/// socket mesh instead of the modeled switch, and the three inter-machine
/// barriers are built with [`Rendezvous::remote`] so their rounds travel
/// the cluster's control plane.
///
/// `resume` is this process's **local** resume proposal (its latest
/// durable checkpoint); the handshake agrees cluster-wide on the minimum,
/// so the step actually resumed may be earlier (or a fresh start, if any
/// sibling has no usable checkpoint).  `attempt` is the auto-resume retry
/// ordinal — it fences handshake rounds so sockets from a previous
/// attempt cannot corrupt the roster.
pub(crate) fn run_job_distributed<P: VertexProgram>(
    eng: &Engine,
    stores: &[MachineStore],
    program: Arc<P>,
    checkpoint: Option<crate::ft::CheckpointCfg>,
    resume: Option<u64>,
    hooks: RunHooks,
    attempt: u64,
) -> Result<JobResult<P>> {
    let n = eng.profile.machines;
    let rank = eng.cfg.transport_rank;
    if stores.len() != n {
        return Err(Error::Config(format!(
            "{} stores for {} machines",
            stores.len(),
            n
        )));
    }
    if rank >= n {
        return Err(Error::Config(format!(
            "transport_rank {rank} out of range for {n} machines"
        )));
    }
    if eng.cfg.transport_addr.is_empty() {
        return Err(Error::Config(
            "transport=tcp requires transport_addr (the coordinator's host:port)".into(),
        ));
    }
    let total_vertices = stores[0].total_vertices;
    let max_local = stores.iter().map(|s| s.local_vertices()).max().unwrap_or(0);
    let ckpt_dir = checkpoint.as_ref().map(|c| c.dir.clone());
    let abort = match hooks.abort {
        Some(a) => {
            if a.aborted() {
                return Err(Error::Other(
                    "engine started with a tripped abort latch; retries must rebuild it \
                     via JobAbort::reset_for_retry"
                        .into(),
                ));
            }
            a
        }
        None => JobAbort::new(),
    };
    let owns_trace_outputs = hooks.tracer.is_none();
    let tracer = hooks
        .tracer
        .unwrap_or_else(|| Arc::new(crate::trace::Tracer::new(eng.cfg.trace.clone())));
    // One machine's share of buffer shelf space (cf. the 4n²+4n+16 the
    // in-process driver provisions for all n machines): this process's
    // outbox batches plus in-flight wire payloads in both directions.
    let pool = crate::msg::BufPool::new(4 * n + 16);
    let digest_pool = crate::msg::DigestPool::new(3);

    // Connect before building any step-dependent state: the handshake's
    // resume agreement decides step_base for the whole cluster.
    let mut opts = net::tcp::TcpOpts::new(n, rank, eng.cfg.transport_addr.clone());
    opts.resume = resume;
    opts.attempt = attempt;
    opts.local_fast = eng.cfg.local_fastpath;
    let net::Transport {
        endpoints,
        switch,
        cluster,
    } = net::Transport::tcp(opts, pool.clone(), abort.clone(), &tracer)?;
    let cluster = cluster.ok_or_else(|| Error::Other("tcp transport returned no cluster".into()))?;
    let (sender, receiver) = endpoints
        .into_iter()
        .next()
        .ok_or_else(|| Error::Other("tcp transport returned no endpoint".into()))?;
    // The cluster must observe trips (to broadcast the Abort frame and
    // force blocked socket reads out) like any other poisonable.
    abort.register(cluster.clone() as Arc<dyn Poisonable>);

    let agreed = cluster.agreed_resume();
    let step_base = agreed.map_or(0, |s| s + 1);

    // The three inter-machine barriers, spanning processes: U_c's rounds
    // carry report/decision payloads through the program's aggregate codec
    // hooks; U_r and checkpoint are pure synchronization.
    let link: Arc<dyn BarrierLink> = cluster.clone();
    let (enc_t, dec_t, enc_r, dec_r) = (
        program.clone(),
        program.clone(),
        program.clone(),
        program.clone(),
    );
    let uc_codec = RvCodec::<UcReport<P::Agg>, UcDecision<P::Agg>> {
        enc_t: Box::new(move |t| encode_uc_report(&*enc_t, t)),
        dec_t: Box::new(move |b| decode_uc_report(&*dec_t, b)),
        enc_r: Box::new(move |r| encode_uc_decision(&*enc_r, r)),
        dec_r: Box::new(move |b| decode_uc_decision(&*dec_r, b)),
    };
    let uc_rv = Rendezvous::remote(n, rank, net::tcp::BARRIER_UC, link.clone(), uc_codec);
    let ur_rv: Arc<Rendezvous<(), ()>> =
        Rendezvous::remote(n, rank, net::tcp::BARRIER_UR, link.clone(), RvCodec::unit());
    let ckpt_rv: Arc<Rendezvous<(), ()>> =
        Rendezvous::remote(n, rank, net::tcp::BARRIER_CKPT, link, RvCodec::unit());
    abort.register(uc_rv.clone() as Arc<dyn Poisonable>);
    abort.register(ur_rv.clone() as Arc<dyn Poisonable>);
    abort.register(ckpt_rv.clone() as Arc<dyn Poisonable>);

    let global = JobGlobal {
        program: program.clone(),
        cfg: eng.cfg.clone(),
        n,
        total_vertices,
        max_local,
        checkpoint,
        step_base,
        uc_rv,
        ur_rv,
        ckpt_rv,
        pool: pool.clone(),
        digest_pool: digest_pool.clone(),
        abort: abort.clone(),
        tracer: tracer.clone(),
        // Fast replay needs a verified *common* window across every
        // machine's replay manifest; with one private workdir per process
        // there is no way to check the siblings', so distributed resume
        // always recomputes from the checkpoint.
        replay_upto: None,
        distributed: true,
    };

    let store = stores[rank].clone();
    let disk = eng
        .profile
        .disk_bytes_per_sec
        .map(crate::util::diskio::DiskBw::new);
    let (compute_secs, output) = timed(|| -> Result<MachineOutput<P>> {
        let beacon = std::sync::atomic::AtomicU64::new(step_base);
        global.abort.guard(rank, "U_c", &beacon, || {
            if let Some(rs) = agreed {
                let dir = ckpt_dir
                    .as_ref()
                    .ok_or_else(|| Error::Config("resume without checkpoint dir".into()))?;
                let scratch = store.dir.join("recovery");
                let rec: crate::ft::Recovered<P::Value, P::Msg> =
                    crate::ft::read_machine_checkpoint(dir, rs, rank, &scratch)?;
                let mut rtr = global.tracer.unit(rank, "recover");
                rtr.instant(crate::trace::EventKind::Recovery, rs);
                rtr.finish();
                return crate::worker::units::run_machine_resumed(
                    &global,
                    store,
                    rec.vals,
                    Some(rec.halted),
                    Some(rec.incoming),
                    sender,
                    receiver,
                    disk,
                );
            }
            let init: Vec<P::Value> = (0..store.local_vertices())
                .map(|pos| {
                    program.init_value(store.id_at(pos), store.degs[pos], store.total_vertices)
                })
                .collect();
            run_machine(&global, store, init, sender, receiver, disk)
        })
    });
    // Tear the cluster down on every path: joins the socket threads and
    // closes the mesh (idempotent; the failure cause — ours or a remote
    // one — has already crossed the control plane via the poison hook).
    let output = match output {
        Ok(o) => {
            cluster.shutdown();
            o
        }
        Err(e) => {
            let e = abort.first_cause_or(e);
            cluster.shutdown();
            if owns_trace_outputs && tracer.enabled() {
                let _ = tracer.flight_record(&eng.cfg.workdir, &e.to_string());
            }
            return Err(e);
        }
    };
    if owns_trace_outputs && tracer.enabled() {
        let path = eng
            .cfg
            .trace
            .path
            .clone()
            .unwrap_or_else(|| eng.cfg.workdir.join("trace.json"));
        tracer.export_chrome(&path)?;
    }

    let metrics = JobMetrics {
        load_secs: 0.0,
        compute_secs,
        preprocess_secs: 0.0,
        supersteps: step_base + output.supersteps,
        machines: vec![output.metrics.clone()],
        net_wire_bytes: switch.total_bytes(),
        net_local_bytes: switch.local_bytes(),
        pool: pool.stats(),
        digest_pool: digest_pool.stats(),
        recoveries: 0,
        retried_supersteps: 0,
    };
    Ok(JobResult {
        outputs: vec![output],
        metrics,
    })
}

/// Largest superstep `R` (if any) such that every machine's retained
/// `job/replay_manifest` gives verified, contiguous S^I coverage of
/// `[step_base, R]`.
///
/// A manifest line is trusted only if the file it names still exists with
/// the recorded byte size — a torn final append (the writer died mid-line
/// or mid-merge) fails that check and simply ends the window early, falling
/// back to recompute for the tail.  Any machine with no usable manifest
/// disables replay for the whole job: the window must be common, because
/// suppression of re-sends is a *global* decision (a machine replaying
/// superstep `s` sends nothing, so every machine must be replaying `s`).
fn compute_replay_window(stores: &[MachineStore], step_base: u64) -> Option<u64> {
    let mut window: Option<u64> = None;
    for store in stores {
        let job_dir = store.dir.join("job");
        let entries = read_replay_manifest(&job_dir).ok()?;
        let mut covered_upto: Option<u64> = None;
        let mut abs = step_base;
        while let Some((name, _msgs, bytes)) = entries.get(&abs) {
            let ok = std::fs::metadata(job_dir.join(name))
                .map(|m| m.len() == *bytes)
                .unwrap_or(false);
            if !ok {
                break;
            }
            covered_upto = Some(abs);
            abs += 1;
        }
        let r = covered_upto?;
        window = Some(window.map_or(r, |w: u64| w.min(r)));
    }
    window
}

/// Dump job results to the DFS as text part files (the paper's final
/// "results are dumped to HDFS" step): one `part-<machine>` per machine,
/// lines `id<TAB>value`.
pub fn dump_results<P: VertexProgram>(
    res: &JobResult<P>,
    dfs: &crate::dfs::Dfs,
    job_name: &str,
) -> Result<()>
where
    P::Value: std::fmt::Debug,
{
    for out in &res.outputs {
        let mut text = String::new();
        for (id, v) in out.ids.iter().zip(out.values.iter()) {
            text.push_str(&format!("{id}\t{v:?}\n"));
        }
        dfs.put(&format!("{job_name}/part-{:05}", out.machine), text.as_bytes())?;
    }
    Ok(())
}
