//! Fault tolerance (§3.4): superstep checkpointing to the (simulated)
//! HDFS and restart-from-checkpoint recovery.
//!
//! A checkpoint at superstep `s` captures, per machine: the vertex values
//! after computing `s`, the halted bitmap, and the *incoming* messages of
//! superstep `s+1` (the IMS backup of the paper — either the sorted `S^I`
//! file or the digested `A_r` array).  Recovery re-runs the job from
//! `s+1`: vertex states and edge streams reload from the per-machine
//! stores (which the paper backs up to HDFS at job start; our stores are
//! already durable on disk), and the incoming messages are seeded from the
//! checkpoint.
//!
//! The message-log fast-recovery of [19]: `JobConfig::keep_oms_for_recovery`
//! keeps sent OMS files on local disks until the next checkpoint instead of
//! garbage-collecting them, and U_r additionally manifests its merged
//! `si_*` incoming files (`replay_manifest`).  An auto-resumed attempt
//! (see `JobBuilder::run`) replays incoming messages from those logs
//! instead of recomputing the sending supersteps — see DESIGN.md
//! "Recovery".

use crate::error::{Error, Result};
use crate::msg::Codec;
use crate::util::bitset::BitSet;
use crate::worker::units::Incoming;
use std::path::{Path, PathBuf};

/// Checkpoint configuration handed to a job via
/// [`crate::session::JobBuilder::checkpoint`] (or the deprecated
/// `run_job_with` shim).
#[derive(Clone, Debug)]
pub struct CheckpointCfg {
    /// Target directory (a DFS path).
    pub dir: PathBuf,
    /// Checkpoint every `every` supersteps.
    pub every: u64,
}

impl CheckpointCfg {
    /// Checkpoint into `dir` every `every` supersteps.
    pub fn every(dir: impl Into<PathBuf>, every: u64) -> Self {
        Self {
            dir: dir.into(),
            every,
        }
    }
}

fn ckpt_path(dir: &Path, step: u64, machine: usize) -> PathBuf {
    dir.join(format!("ckpt_{step:06}")).join(format!("m{machine}.bin"))
}

fn done_marker(dir: &Path, step: u64) -> PathBuf {
    dir.join(format!("ckpt_{step:06}")).join("DONE")
}

/// Serialize one machine's checkpoint.
pub fn write_machine_checkpoint<V: Codec, M: Codec>(
    dir: &Path,
    step: u64,
    machine: usize,
    vals: &[V],
    halted: &BitSet,
    incoming: &Incoming<M>,
) -> Result<()> {
    let mut out: Vec<u8> = Vec::new();
    out.extend_from_slice(&(vals.len() as u32).to_le_bytes());
    let mut buf = vec![0u8; V::SIZE.max(1)];
    for v in vals {
        v.encode(&mut buf[..V::SIZE]);
        out.extend_from_slice(&buf[..V::SIZE]);
    }
    // halted bitmap, bit-packed
    for pos in 0..vals.len() {
        if pos % 8 == 0 {
            out.push(0);
        }
        if halted.get(pos) {
            let last = out.len() - 1;
            out[last] |= 1 << (pos % 8);
        }
    }
    match incoming {
        Incoming::Sorted { path, msgs } => {
            out.push(0u8);
            out.extend_from_slice(&msgs.to_le_bytes());
            let data = std::fs::read(path)?;
            out.extend_from_slice(&(data.len() as u64).to_le_bytes());
            out.extend_from_slice(&data);
        }
        Incoming::Digested { ar, bits } => {
            out.push(1u8);
            out.extend_from_slice(&(ar.len() as u32).to_le_bytes());
            let mut mb = vec![0u8; M::SIZE.max(1)];
            for m in ar {
                m.encode(&mut mb[..M::SIZE]);
                out.extend_from_slice(&mb[..M::SIZE]);
            }
            for pos in 0..ar.len() {
                if pos % 8 == 0 {
                    out.push(0);
                }
                if bits.get(pos) {
                    let last = out.len() - 1;
                    out[last] |= 1 << (pos % 8);
                }
            }
        }
    }
    let p = ckpt_path(dir, step, machine);
    if let Some(d) = p.parent() {
        std::fs::create_dir_all(d)?;
    }
    // fsync the checkpoint file itself: mark_done's DONE marker promises
    // this data is durable, so the data must hit the platter first.
    let mut f = std::fs::File::create(p)?;
    std::io::Write::write_all(&mut f, &out)?;
    f.sync_all()?;
    Ok(())
}

/// fsync a directory so renames/creates inside it are durable (a file's
/// own fsync does not cover its directory entry).  No-op on non-Unix —
/// opening a directory for sync is a Unix-ism.
fn sync_dir(dir: &Path) -> std::io::Result<()> {
    #[cfg(unix)]
    {
        std::fs::File::open(dir)?.sync_all()?;
    }
    #[cfg(not(unix))]
    {
        let _ = dir;
    }
    Ok(())
}

/// Mark a checkpoint complete once all machines wrote theirs.
///
/// Durability order (the whole point of the marker): the per-machine
/// files were fsynced by [`write_machine_checkpoint`]; this fsyncs the
/// checkpoint *directory* (making those file entries durable), then
/// publishes DONE via write-temp + fsync + rename — atomic on POSIX — and
/// fsyncs the directory again so the rename itself is durable.  A crash
/// at any point leaves either no DONE (checkpoint ignored by
/// [`latest_checkpoint`], which is correct for a torn set) or a DONE that
/// provably covers complete, durable machine files — never a
/// resumable-but-corrupt superstep.
pub fn mark_done(dir: &Path, step: u64) -> Result<()> {
    let done = done_marker(dir, step);
    let ckpt_dir = done.parent().expect("marker has a parent").to_path_buf();
    sync_dir(&ckpt_dir)?;
    let tmp = ckpt_dir.join("DONE.tmp");
    let mut f = std::fs::File::create(&tmp)?;
    std::io::Write::write_all(&mut f, b"ok")?;
    f.sync_all()?;
    std::fs::rename(&tmp, &done)?;
    sync_dir(&ckpt_dir)?;
    Ok(())
}

/// One machine's recovered state.
pub struct Recovered<V, M> {
    pub step: u64,
    pub vals: Vec<V>,
    pub halted: BitSet,
    pub incoming: Incoming<M>,
}

/// Load machine `machine`'s checkpoint at `step` (scratch files go under
/// `scratch` for the Sorted variant).
pub fn read_machine_checkpoint<V: Codec, M: Codec>(
    dir: &Path,
    step: u64,
    machine: usize,
    scratch: &Path,
) -> Result<Recovered<V, M>> {
    let data = std::fs::read(ckpt_path(dir, step, machine))?;
    let bad = || Error::CorruptStream("truncated checkpoint".into());
    let mut off = 0usize;
    let mut take = |n: usize| -> Result<Vec<u8>> {
        if off + n > data.len() {
            return Err(bad());
        }
        let s = data[off..off + n].to_vec();
        off += n;
        Ok(s)
    };
    let nv = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
    let mut vals = Vec::with_capacity(nv);
    for _ in 0..nv {
        vals.push(V::decode(&take(V::SIZE)?));
    }
    let mut halted = BitSet::new(nv);
    let hb = take((nv + 7) / 8)?;
    for pos in 0..nv {
        if hb[pos / 8] >> (pos % 8) & 1 == 1 {
            halted.set(pos, true);
        }
    }
    let kind = take(1)?[0];
    let incoming = match kind {
        0 => {
            let msgs = u64::from_le_bytes(take(8)?.try_into().unwrap());
            let len = u64::from_le_bytes(take(8)?.try_into().unwrap()) as usize;
            let body = take(len)?;
            std::fs::create_dir_all(scratch)?;
            let p = scratch.join(format!("recovered_si_m{machine}"));
            std::fs::write(&p, body)?;
            Incoming::Sorted { path: p, msgs }
        }
        1 => {
            let alen = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
            let mut ar = Vec::with_capacity(alen);
            for _ in 0..alen {
                ar.push(M::decode(&take(M::SIZE)?));
            }
            let mut bits = BitSet::new(alen);
            let bb = take((alen + 7) / 8)?;
            for pos in 0..alen {
                if bb[pos / 8] >> (pos % 8) & 1 == 1 {
                    bits.set(pos, true);
                }
            }
            Incoming::Digested { ar, bits }
        }
        _ => return Err(bad()),
    };
    Ok(Recovered {
        step,
        vals,
        halted,
        incoming,
    })
}

/// The superstep a failed job can resume from: the latest checkpoint in
/// `dir` whose DONE marker landed.  DONE only appears after *every*
/// machine's file went durable (the `ckpt_rv` barrier in the engine — a
/// poisoned barrier round never marks DONE), so a resume from this step
/// can never read a partial checkpoint set.  The session layer folds this
/// into the `cause` of [`crate::error::Error::JobFailed`] when a
/// checkpointed job dies.
pub fn resume_hint(dir: &Path) -> Option<u64> {
    latest_checkpoint(dir, None)
}

/// Latest completed checkpoint at or below `upto` (None = any).
pub fn latest_checkpoint(dir: &Path, upto: Option<u64>) -> Option<u64> {
    let mut best = None;
    let entries = std::fs::read_dir(dir).ok()?;
    for e in entries.flatten() {
        let name = e.file_name().to_string_lossy().into_owned();
        if let Some(s) = name.strip_prefix("ckpt_") {
            if let Ok(step) = s.parse::<u64>() {
                if upto.map_or(true, |u| step <= u) && done_marker(dir, step).exists() {
                    best = Some(best.map_or(step, |b: u64| b.max(step)));
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "graphd_ft_{name}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn digested_checkpoint_roundtrip() {
        let d = tmp("dig");
        let vals = vec![1.0f32, 2.5, -3.0];
        let mut halted = BitSet::new(3);
        halted.set(1, true);
        let mut bits = BitSet::new(3);
        bits.set(0, true);
        bits.set(2, true);
        let inc = Incoming::Digested {
            ar: vec![0.5f32, f32::INFINITY, 7.0],
            bits,
        };
        write_machine_checkpoint(&d, 4, 1, &vals, &halted, &inc).unwrap();
        mark_done(&d, 4).unwrap();
        let r: Recovered<f32, f32> = read_machine_checkpoint(&d, 4, 1, &d.join("scratch")).unwrap();
        assert_eq!(r.vals, vals);
        assert!(r.halted.get(1) && !r.halted.get(0));
        match r.incoming {
            Incoming::Digested { ar, bits } => {
                assert_eq!(ar[0], 0.5);
                assert!(ar[1].is_infinite());
                assert!(bits.get(0) && !bits.get(1) && bits.get(2));
            }
            _ => panic!(),
        }
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn sorted_checkpoint_roundtrip() {
        let d = tmp("sorted");
        let si = d.join("si");
        std::fs::write(&si, [1u8, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        let inc: Incoming<f32> = Incoming::Sorted { path: si, msgs: 1 };
        let halted = BitSet::new(2);
        write_machine_checkpoint(&d, 0, 0, &[9.0f32, 8.0], &halted, &inc).unwrap();
        let r: Recovered<f32, f32> = read_machine_checkpoint(&d, 0, 0, &d.join("s")).unwrap();
        match r.incoming {
            Incoming::Sorted { path, msgs } => {
                assert_eq!(msgs, 1);
                assert_eq!(std::fs::read(path).unwrap(), vec![1, 2, 3, 4, 5, 6, 7, 8]);
            }
            _ => panic!(),
        }
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn mark_done_publishes_atomically() {
        let d = tmp("done");
        let halted = BitSet::new(1);
        let bits = BitSet::new(1);
        let inc: Incoming<f32> = Incoming::Digested { ar: vec![0.0], bits };
        write_machine_checkpoint(&d, 2, 0, &[0f32], &halted, &inc).unwrap();
        // Torn checkpoint (no DONE yet): invisible to resume.
        assert_eq!(latest_checkpoint(&d, None), None);
        mark_done(&d, 2).unwrap();
        assert_eq!(latest_checkpoint(&d, None), Some(2));
        let ckpt = d.join("ckpt_000002");
        assert_eq!(std::fs::read(ckpt.join("DONE")).unwrap(), b"ok");
        assert!(!ckpt.join("DONE.tmp").exists(), "temp marker renamed away");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn latest_checkpoint_respects_done_and_upto() {
        let d = tmp("latest");
        let halted = BitSet::new(1);
        let bits = BitSet::new(1);
        let inc: Incoming<f32> = Incoming::Digested { ar: vec![0.0], bits };
        for s in [2u64, 4, 6] {
            write_machine_checkpoint(&d, s, 0, &[0f32], &halted, &inc).unwrap();
            if s != 6 {
                mark_done(&d, s).unwrap(); // 6 is incomplete
            }
        }
        assert_eq!(latest_checkpoint(&d, None), Some(4));
        assert_eq!(latest_checkpoint(&d, Some(3)), Some(2));
        assert_eq!(latest_checkpoint(&d, Some(1)), None);
        let _ = std::fs::remove_dir_all(&d);
    }
}
