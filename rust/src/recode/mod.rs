//! ID recoding (§5): preprocess a normal (sparse-ID) graph into the
//! recoded form with dense IDs `0..|V|-1` and `hash(v) = id mod n`, so the
//! recoded ID ↔ (machine, position) bijection enables in-memory message
//! digesting/combining.
//!
//! The vertex at position `pos` of machine `i`'s state array gets new ID
//! `n·pos + i`.  Rewriting the neighbor IDs inside every `S^E` takes the
//! paper's 3 supersteps for a directed graph (request → respond → append)
//! and 1 messaging round for an undirected one; all message traffic goes
//! through the same simulated network, and reply records are sorted-spilled
//! and merged exactly like an IMS — the whole preprocessing is itself a
//! normal-mode GraphD job pattern with `O(|V|/n)` memory.

use crate::engine::Engine;
use crate::error::{Error, Result};
use crate::msg::BufPool;
use crate::net::{self, NetReceiver, NetSender, Payload};
use crate::stream::{merge, StreamWriter};
use crate::worker::storage::{item_size, EdgeStreamCursor, EdgeStreamWriter, MachineStore};
use crate::worker::sync::JobAbort;
use crate::worker::Partitioning;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

const BATCH: usize = 256 * 1024;

/// Batched per-destination sender used by every recoding phase.  Batches
/// carry the phase number in the `step` field so receivers can tell a
/// fast neighbor's phase-2 replies from their own pending phase-1 traffic.
/// Wire blocks check out of the shared [`BufPool`] and are recycled by the
/// receiving [`PhaseRx`], so steady-state recoding allocates nothing per
/// exchange — the same discipline as the job-time message spine.
struct PhaseTx {
    sender: NetSender,
    phase: u64,
    bufs: Vec<Vec<u8>>,
    pool: Arc<BufPool>,
}

impl PhaseTx {
    fn new(sender: NetSender, phase: u64, pool: Arc<BufPool>) -> Self {
        let n = sender.peers();
        Self {
            sender,
            phase,
            // analyze:allow(pool-leak): checkouts live in self.bufs for the
            // phase; push() hands full blocks to the wire and finish()
            // recycles or sends the rest — the pairing spans the PhaseTx
            // impl, not this constructor.
            bufs: (0..n).map(|_| pool.take()).collect(),
            pool,
        }
    }

    fn push(&mut self, dst: usize, rec: &[u8]) -> Result<()> {
        let buf = &mut self.bufs[dst];
        buf.extend_from_slice(rec);
        if buf.len() >= BATCH {
            let b = std::mem::replace(buf, self.pool.take());
            self.sender.send(dst, self.phase, Payload::Load(b))?;
        }
        Ok(())
    }

    fn finish(mut self) -> Result<()> {
        for dst in 0..self.bufs.len() {
            let b = std::mem::take(&mut self.bufs[dst]);
            if b.is_empty() {
                self.pool.put(b);
            } else {
                self.sender.send(dst, self.phase, Payload::Load(b))?;
            }
            self.sender.send(dst, self.phase, Payload::LoadEnd)?;
        }
        Ok(())
    }
}

/// Phase-aware receiver: machines drift (one can finish phase p and start
/// sending phase p+1 while a neighbor is still collecting phase-p end
/// tags), so out-of-phase batches are stashed, never dropped.  Consumed
/// wire blocks are recycled into the shared pool.
struct PhaseRx<'a> {
    receiver: &'a NetReceiver,
    stash: std::collections::VecDeque<crate::net::Batch>,
    pool: Arc<BufPool>,
}

impl<'a> PhaseRx<'a> {
    fn new(receiver: &'a NetReceiver, pool: Arc<BufPool>) -> Self {
        Self {
            receiver,
            stash: Default::default(),
            pool,
        }
    }

    /// Receive phase `phase` until `n` end tags, handing batches to `f`
    /// and recycling each block afterwards.
    fn drain_phase(
        &mut self,
        phase: u64,
        n: usize,
        mut f: impl FnMut(&[u8]) -> Result<()>,
    ) -> Result<()> {
        let mut ends = 0;
        while ends < n {
            let b = match self.stash.iter().position(|b| b.step == phase) {
                Some(i) => self.stash.remove(i).unwrap(),
                None => {
                    let b = self.receiver.recv()?;
                    if b.step != phase {
                        debug_assert!(b.step > phase, "batch from completed phase");
                        self.stash.push_back(b);
                        continue;
                    }
                    b
                }
            };
            match b.payload {
                Payload::LoadEnd => ends += 1,
                Payload::Load(data) => {
                    f(&data)?;
                    self.pool.put(data);
                }
                _ => return Err(Error::CorruptStream("unexpected payload in recode".into())),
            }
        }
        Ok(())
    }
}

/// New-ID lookup: old IDs are sorted per machine, so `binary_search` gives
/// the position, hence the new ID `n·pos + i`.
#[inline]
fn new_id_of(ids: &[u32], old: u32, machine: usize, n: usize) -> Result<u32> {
    match ids.binary_search(&old) {
        Ok(pos) => Ok((pos * n + machine) as u32),
        Err(_) => Err(Error::CorruptStream(format!(
            "edge endpoint {old} is not a vertex (machine {machine})"
        ))),
    }
}

/// Run ID recoding over basic stores, producing recoded stores under
/// `<workdir>/m<i>/rec/`.  Directed graphs use the 3-superstep protocol;
/// undirected ones the 1-round shortcut (§5 Preprocessing).
pub fn recode(eng: &Engine, stores: &[MachineStore], directed: bool) -> Result<Vec<MachineStore>> {
    let n = eng.profile.machines;
    let weighted = stores[0].weighted;
    let part = Partitioning::Hashed;
    // request/reply record sizes
    let req_size = if weighted { 12 } else { 8 }; // u_old, v_old [, w]
    let rep_size = if weighted { 12 } else { 8 }; // key, payload [, w]

    // Recoding is itself a distributed message-exchange job, with the same
    // deadlock shape: a machine that errors mid-phase never sends its end
    // tags, wedging every sibling's drain — so preprocessing gets its own
    // abort latch, observed by the channel waits and tripped by any phase
    // thread's failure.
    let abort = JobAbort::new();
    let (endpoints, _switch) = net::build(
        n,
        eng.profile.net_bytes_per_sec,
        eng.profile.latency_us,
        eng.cfg.local_fastpath,
        Some(abort.clone()),
    );
    // One pool for the whole preprocessing: request/reply wire blocks and
    // reply-spill scratch recycle across machines and phases.
    let pool = BufPool::new(4 * n + 8);
    // Recode-phase tracer: one "recode" track per machine, exported to
    // `<workdir>/trace_recode.json`; on failure the rings dump beside it.
    let tracer = std::sync::Arc::new(crate::trace::Tracer::new(eng.cfg.trace.clone()));
    let mut results: Vec<Option<Result<MachineStore>>> = (0..n).map(|_| None).collect();

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (i, (sender, receiver)) in endpoints.into_iter().enumerate() {
            let store = stores[i].clone();
            let rec_dir = eng.store_dir(i, "rec");
            let stream_buf = eng.cfg.stream_buf;
            let merge_k = eng.cfg.merge_k;
            let resident = eng.cfg.resident;
            let resident_budget = eng.cfg.resident_budget;
            let pool = pool.clone();
            let abort = abort.clone();
            let tracer = tracer.clone();
            let disk = eng
                .profile
                .disk_bytes_per_sec
                .map(crate::util::diskio::DiskBw::new);
            handles.push(scope.spawn(move || -> Result<MachineStore> {
                let _dg = crate::util::diskio::register(disk.clone());
                // The beacon tracks the protocol phase (1 = request,
                // 2 = reply/announce, 3 = merge) for failure attribution;
                // guard() trips the shared abort on any error or panic so
                // sibling machines' drains unblock typed.
                let phase = AtomicU64::new(1);
                // Recode spans: arg = protocol phase (1 request,
                // 2 reply/announce, 3 merge), matching the failure beacon.
                let mut tr = tracer.unit(i, "recode");
                let out = abort.guard(i, "recode", &phase, || {
                    tr.begin(crate::trace::EventKind::Recode, 1);
                    let mut rx = PhaseRx::new(&receiver, pool.clone());
                    let _ = std::fs::remove_dir_all(&rec_dir);
                    std::fs::create_dir_all(&rec_dir)?;

                    let reply_spills: Vec<PathBuf>;
                    if directed {
                        // ---- Superstep 1: each v asks owner(u) for new id(u),
                        // for every out-neighbor u.
                        let req_file = rec_dir.join("requests");
                        {
                            let parser = {
                                let store = store.clone();
                                let mut tx = PhaseTx::new(sender.clone(), 1, pool.clone());
                                let abort = abort.clone();
                                std::thread::spawn(move || -> Result<()> {
                                    let ph = AtomicU64::new(1);
                                    abort.guard(i, "recode", &ph, || {
                                        let mut se = EdgeStreamCursor::open(&store, stream_buf)?;
                                        let mut edges = Vec::new();
                                        for pos in 0..store.local_vertices() {
                                            let v_old = store.ids[pos];
                                            se.read_adjacency(store.degs[pos], &mut edges)?;
                                            for e in &edges {
                                                let mut rec = [0u8; 12];
                                                rec[..4].copy_from_slice(&e.nbr.to_le_bytes());
                                                rec[4..8].copy_from_slice(&v_old.to_le_bytes());
                                                if weighted {
                                                    rec[8..12]
                                                        .copy_from_slice(&e.weight.to_le_bytes());
                                                }
                                                tx.push(part.machine_of(e.nbr, n), &rec[..req_size])?;
                                            }
                                        }
                                        tx.finish()
                                    })
                                })
                            };
                            let mut w = StreamWriter::create(&req_file, stream_buf)?;
                            rx.drain_phase(1, n, |data| w.write_all(data))?;
                            w.finish()?;
                            parser.join().map_err(|e| Error::WorkerPanic {
                                machine: i,
                                cause: format!("{e:?}"),
                            })??;
                        }

                        // ---- Superstep 2: u replies (v_old, new_id(u)) to
                        // owner(v_old); replies are sorted-spilled by target pos.
                        phase.store(2, Ordering::Relaxed);
                        tr.end(crate::trace::EventKind::Recode, 1);
                        tr.begin(crate::trace::EventKind::Recode, 2);
                        let spills = {
                            let responder = {
                                let store = store.clone();
                                let mut tx = PhaseTx::new(sender.clone(), 2, pool.clone());
                                let req_file = req_file.clone();
                                let abort = abort.clone();
                                std::thread::spawn(move || -> Result<()> {
                                    let ph = AtomicU64::new(2);
                                    abort.guard(i, "recode", &ph, || {
                                        let mut r = crate::stream::StreamReader::open(
                                            &req_file, stream_buf,
                                        )?;
                                        let mut rec = vec![0u8; req_size];
                                        while r.remaining() >= req_size as u64 {
                                            r.read_exact(&mut rec)?;
                                            let u_old =
                                                u32::from_le_bytes(rec[..4].try_into().unwrap());
                                            let v_old =
                                                u32::from_le_bytes(rec[4..8].try_into().unwrap());
                                            let u_new = new_id_of(&store.ids, u_old, i, n)?;
                                            let mut rep = [0u8; 12];
                                            rep[..4].copy_from_slice(&v_old.to_le_bytes());
                                            rep[4..8].copy_from_slice(&u_new.to_le_bytes());
                                            if weighted {
                                                rep[8..12].copy_from_slice(&rec[8..12]);
                                            }
                                            tx.push(part.machine_of(v_old, n), &rep[..rep_size])?;
                                        }
                                        tx.finish()
                                    })
                                })
                            };
                            let spills =
                                receive_sorted_replies(&mut rx, n, &store, rep_size, &rec_dir)?;
                            responder.join().map_err(|e| Error::WorkerPanic {
                                machine: i,
                                cause: format!("{e:?}"),
                            })??;
                            let _ = std::fs::remove_file(&req_file);
                            spills
                        };
                        reply_spills = spills;
                    } else {
                        // ---- Undirected 1-round: v sends new_id(v) to each
                        // neighbor u (owner(u) records it under u's position).
                        phase.store(2, Ordering::Relaxed);
                        tr.end(crate::trace::EventKind::Recode, 1);
                        tr.begin(crate::trace::EventKind::Recode, 2);
                        let spills = {
                            let announcer = {
                                let store = store.clone();
                                let mut tx = PhaseTx::new(sender.clone(), 2, pool.clone());
                                let abort = abort.clone();
                                std::thread::spawn(move || -> Result<()> {
                                    let ph = AtomicU64::new(2);
                                    abort.guard(i, "recode", &ph, || {
                                        let mut se = EdgeStreamCursor::open(&store, stream_buf)?;
                                        let mut edges = Vec::new();
                                        for pos in 0..store.local_vertices() {
                                            let v_new = (pos * n + i) as u32;
                                            se.read_adjacency(store.degs[pos], &mut edges)?;
                                            for e in &edges {
                                                let mut rec = [0u8; 12];
                                                rec[..4].copy_from_slice(&e.nbr.to_le_bytes());
                                                rec[4..8].copy_from_slice(&v_new.to_le_bytes());
                                                if weighted {
                                                    rec[8..12]
                                                        .copy_from_slice(&e.weight.to_le_bytes());
                                                }
                                                tx.push(part.machine_of(e.nbr, n), &rec[..rep_size])?;
                                            }
                                        }
                                        tx.finish()
                                    })
                                })
                            };
                            let spills =
                                receive_sorted_replies(&mut rx, n, &store, rep_size, &rec_dir)?;
                            announcer.join().map_err(|e| Error::WorkerPanic {
                                machine: i,
                                cause: format!("{e:?}"),
                            })??;
                            spills
                        };
                        reply_spills = spills;
                    }

                    // ---- Superstep 3 / final: merge reply spills by position
                    // and append the recoded adjacency lists to S^E_rec.
                    phase.store(3, Ordering::Relaxed);
                    tr.end(crate::trace::EventKind::Recode, 2);
                    tr.begin(crate::trace::EventKind::Recode, 3);
                    let mut se = EdgeStreamWriter::create(&rec_dir, weighted, stream_buf)?;
                    let mut counts = vec![0u32; store.local_vertices()];
                    merge::merge_streams(
                        &reply_spills,
                        rep_size,
                        merge_k,
                        stream_buf,
                        &rec_dir,
                        |rec| {
                            let pos = u32::from_le_bytes(rec[..4].try_into().unwrap()) as usize;
                            let u_new = u32::from_le_bytes(rec[4..8].try_into().unwrap());
                            let w = if weighted {
                                f32::from_le_bytes(rec[8..12].try_into().unwrap())
                            } else {
                                1.0
                            };
                            counts[pos] += 1;
                            se.push(u_new, w)
                        },
                    )?;
                    se.finish()?;
                    for sp in &reply_spills {
                        let _ = std::fs::remove_file(sp);
                    }
                    if counts != store.degs {
                        return Err(Error::CorruptStream(format!(
                            "recode degree mismatch on machine {i}"
                        )));
                    }

                    let rec_store = MachineStore {
                        dir: rec_dir,
                        machine: i,
                        num_machines: n,
                        total_vertices: store.total_vertices,
                        weighted,
                        recoded: true,
                        ids: store.ids.clone(), // old IDs kept for reporting
                        degs: store.degs.clone(),
                    };
                    rec_store.save()?;
                    // Resident store: materialize the recoded CSR pair
                    // while the recode pass is still warm (checksum-keyed;
                    // `auto` skips it when over budget).
                    crate::worker::csr::prepare(&rec_store, resident, resident_budget)?;
                    tr.end(crate::trace::EventKind::Recode, 3);
                    Ok(rec_store)
                });
                tr.finish();
                out
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            results[i] = Some(h.join().unwrap_or_else(|e| {
                Err(Error::WorkerPanic {
                    machine: i,
                    cause: format!("{e:?}"),
                })
            }));
        }
    });

    let collected: Result<Vec<MachineStore>> =
        results.into_iter().map(|r| r.unwrap()).collect();
    let stores = match collected {
        Ok(s) => s,
        Err(e) => {
            let e = abort.first_cause_or(e);
            if tracer.enabled() {
                let _ = tracer.flight_record(&eng.cfg.workdir, &e.to_string());
            }
            return Err(e);
        }
    };
    if tracer.enabled() {
        tracer.export_chrome(&eng.cfg.workdir.join("trace_recode.json"))?;
    }
    Ok(stores)
}

/// Receive reply records, translate the old target ID into the local array
/// position, sort each batch by position and spill — the IMS pattern.
/// The translation scratch buffer recycles through the phase pool.
fn receive_sorted_replies(
    rx: &mut PhaseRx<'_>,
    n: usize,
    store: &MachineStore,
    rep_size: usize,
    dir: &Path,
) -> Result<Vec<PathBuf>> {
    let mut spills = Vec::new();
    let pool = rx.pool.clone();
    let mut out = pool.take();
    rx.drain_phase(2, n, |data| {
        out.clear();
        out.reserve(data.len());
        for rec in data.chunks_exact(rep_size) {
            let v_old = u32::from_le_bytes(rec[..4].try_into().unwrap());
            let pos = store
                .ids
                .binary_search(&v_old)
                .map_err(|_| Error::CorruptStream(format!("reply for foreign vertex {v_old}")))?
                as u32;
            out.extend_from_slice(&pos.to_le_bytes());
            out.extend_from_slice(&rec[4..]);
        }
        merge::sort_records(&mut out, rep_size);
        let sp = dir.join(format!("reply_spill_{}", spills.len()));
        std::fs::write(&sp, &out)?;
        spills.push(sp);
        Ok(())
    })?;
    pool.put(out);
    Ok(spills)
}

/// Edge-stream byte length sanity helper used in tests.
pub fn se_len_items(store: &MachineStore) -> Result<u64> {
    let md = std::fs::metadata(store.se_path())?;
    Ok(md.len() / item_size(store.weighted) as u64)
}
