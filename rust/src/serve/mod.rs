//! `graphd::serve` — a resident query-serving subsystem with k-lane
//! batched traversals.
//!
//! GraphD's per-job economics are dominated by streaming `S^E` from local
//! disk every superstep (§3–§4).  A query server amortises that cost: it
//! keeps a [`crate::session::LoadedGraph`] resident, admits point-to-point
//! / single-source distance and reachability queries into a queue, and a
//! batch scheduler packs up to `k` pending queries into **one** k-lane
//! multi-source run ([`crate::algos::MultiSssp`]) — one shared superstep
//! loop, one edge-stream pass per superstep, k queries answered.  Lanes
//! settle independently (per-lane early termination via the aggregator
//! bounds), and the run ends through the engine's ordinary termination
//! machinery once every lane is quiet.
//!
//! Entry point is the session API:
//!
//! ```ignore
//! let graph = session.load(GraphSource::InMemory(&g))?;
//! let mut server = graph.serve(ServeConfig::default())?;   // k = 8 lanes
//! server.submit(Query::Dist { source: 3, target: 96 });
//! server.submit(Query::Reach { source: 0, target: 41 });
//! let results = server.run_pending()?;
//! println!("{}", server.metrics().report());
//! ```
//!
//! **Warm restarts (`-c resident=mmap|auto`).**  Serve batches are
//! ordinary jobs, so they inherit the session's adjacency-residency knob:
//! with the resident store on, the first batch materializes the CSR pair
//! once (checksum-keyed, see `docs/FORMATS.md`) and *every* subsequent
//! batch — including a server rebuilt over the same workdir after a
//! restart — maps the existing files instead of re-reading `se.bin`
//! through the buffered cursor.  Map, don't reload: restart cost becomes
//! two `mmap` calls per machine, and the topology's page-cache residency
//! survives the process that died.

use crate::algos::multisource::{MultiSssp, NO_VERTEX};
use crate::config::Mode;
use crate::error::{Error, Result};
use crate::metrics::{JobMetrics, ServeMetrics};
use crate::session::LoadedGraph;
use crate::util::timer::timed;
use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// Lane widths the batch scheduler can dispatch (the k-lane program is
/// monomorphised per width).
pub const LANE_WIDTHS: [usize; 5] = [1, 2, 4, 8, 16];

/// One admitted query, in **input-space** vertex ids.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Query {
    /// Shortest distance from `source` to `target`.
    Dist { source: u32, target: u32 },
    /// Is `target` reachable from `source`?  (Settles on first touch.)
    Reach { source: u32, target: u32 },
    /// Single-source: how many vertices are reachable from `source`
    /// (including itself)?
    ReachCount { source: u32 },
}

impl Query {
    fn source(&self) -> u32 {
        match *self {
            Query::Dist { source, .. }
            | Query::Reach { source, .. }
            | Query::ReachCount { source } => source,
        }
    }

    fn target(&self) -> Option<u32> {
        match *self {
            Query::Dist { target, .. } | Query::Reach { target, .. } => Some(target),
            Query::ReachCount { .. } => None,
        }
    }
}

/// The answer to one query.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Answer {
    /// `None` = unreachable.
    Dist(Option<f32>),
    /// Is the target reachable?
    Reach(bool),
    /// Vertices reachable from the source (including itself).
    ReachCount(u64),
    /// The query referenced a vertex that is not in the graph.
    UnknownVertex(u32),
    /// The batch job running this query died (e.g. a worker failure
    /// surfaced as [`crate::error::Error::JobFailed`]).  The failure is
    /// scoped to the batch: the server stays up and later batches are
    /// served; the cause is in [`QueryResult::error`].
    Failed,
}

/// One served query with its latency accounting.
#[derive(Clone, Debug)]
pub struct QueryResult {
    /// Admission id (returned by [`QueryServer::submit`]).
    pub id: u64,
    /// The query as admitted.
    pub query: Query,
    /// Its answer.
    pub answer: Answer,
    /// Submit → answered wall time (includes queueing behind earlier
    /// batches of the same drain).
    pub latency_secs: f64,
    /// Sequence number of the admission batch that carried it.
    pub batch: u64,
    /// How many queries shared that batch's superstep loop.
    pub lanes_in_batch: usize,
    /// Supersteps the batch ran.
    pub supersteps: u64,
    /// The rendered batch error when `answer` is [`Answer::Failed`].
    pub error: Option<String>,
}

/// Server configuration: lane width k, execution mode, superstep cap.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Queries packed per batch — one of [`LANE_WIDTHS`].
    pub lanes: usize,
    /// Execution mode per batch job ([`Mode::Auto`] picks IO-Recoded when
    /// the graph has been recoded — `MultiSssp` always has a combiner).
    pub mode: Mode,
    /// Per-batch superstep cap (0 = unlimited).
    pub max_supersteps: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            lanes: 8,
            mode: Mode::Auto,
            max_supersteps: 0,
        }
    }
}

impl ServeConfig {
    /// Set the lane width k (one of [`LANE_WIDTHS`]).
    pub fn lanes(mut self, k: usize) -> Self {
        self.lanes = k;
        self
    }

    /// Set the per-batch execution mode.
    pub fn mode(mut self, m: Mode) -> Self {
        self.mode = m;
        self
    }

    /// Set the per-batch superstep cap (0 = unlimited).
    pub fn max_supersteps(mut self, n: u64) -> Self {
        self.max_supersteps = n;
        self
    }
}

struct Pending {
    id: u64,
    query: Query,
    submitted: Instant,
}

/// A query translated into the current ID space, ready for a lane.
struct Prepared {
    query: Query,
    src_cur: u32,
    tgt_cur: u32,
    /// Input-space target id, for result extraction (`NO_VERTEX` = none).
    tgt_input: u32,
    reach: bool,
}

/// A point-in-time introspection snapshot of a [`QueryServer`]
/// ([`QueryServer::stats`]): queue depth, in-flight lanes, and rolling
/// throughput/latency figures from [`ServeMetrics`].  The seed of the
/// ROADMAP's daemon `/stats` endpoint.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeStats {
    /// Queries admitted but not yet packed into a batch.
    pub queued: usize,
    /// Lanes of the batch currently being served (0 between batches).
    pub in_flight: usize,
    /// Admission batches drained so far.
    pub batches: u64,
    /// Batches that failed with a typed engine error.
    pub failed_batches: u64,
    /// Queries answered so far.
    pub queries: u64,
    /// Rolling queries/second over the served wall time.
    pub qps: f64,
    /// Median end-to-end query latency, seconds.
    pub p50_secs: f64,
    /// 99th-percentile end-to-end query latency, seconds.
    pub p99_secs: f64,
}

/// The resident query server: admission queue + batch scheduler over one
/// [`LoadedGraph`].  Build it through [`LoadedGraph::serve`].
pub struct QueryServer<'g, 's> {
    graph: &'g LoadedGraph<'s>,
    cfg: ServeConfig,
    queue: VecDeque<Pending>,
    next_id: u64,
    /// Admission batches drained (every [`QueryResult::batch`] label);
    /// engine batches actually run are counted by `metrics.batches`.
    batches: u64,
    metrics: ServeMetrics,
    /// Lanes of the batch currently dispatched ([`ServeStats::in_flight`]).
    in_flight: usize,
    /// Serve-side tracer (session `-c trace=true`): admission instants and
    /// batch spans on one "serve" track, rewritten to
    /// `<workdir>/trace_serve.json` at the end of every queue drain.
    tracer: Arc<crate::trace::Tracer>,
    tr: crate::trace::UnitTracer,
    trace_out: PathBuf,
}

impl<'g, 's> QueryServer<'g, 's> {
    pub(crate) fn new(graph: &'g LoadedGraph<'s>, cfg: ServeConfig) -> Result<Self> {
        if !LANE_WIDTHS.contains(&cfg.lanes) {
            return Err(Error::Config(format!(
                "ServeConfig.lanes must be one of {LANE_WIDTHS:?}, got {}",
                cfg.lanes
            )));
        }
        let scfg = graph.session_cfg();
        let tracer = Arc::new(crate::trace::Tracer::new(scfg.trace.clone()));
        let tr = tracer.unit(0, "serve");
        let trace_out = scfg.workdir.join("trace_serve.json");
        Ok(Self {
            graph,
            cfg,
            queue: VecDeque::new(),
            next_id: 0,
            batches: 0,
            metrics: ServeMetrics::default(),
            in_flight: 0,
            tracer,
            tr,
            trace_out,
        })
    }

    /// Admit a query; returns its admission id.
    pub fn submit(&mut self, query: Query) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.tr.instant(crate::trace::EventKind::ServeBatch, id);
        self.queue.push_back(Pending {
            id,
            query,
            submitted: Instant::now(),
        });
        id
    }

    /// Admit a set of (source, target) distance queries (the shape
    /// produced by [`crate::graph::generator::query_set`]).
    pub fn submit_pairs(&mut self, pairs: &[(u32, u32)]) -> Vec<u64> {
        pairs
            .iter()
            .map(|&(source, target)| self.submit(Query::Dist { source, target }))
            .collect()
    }

    /// Queries admitted but not yet served.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Serve-mode counters accumulated so far.
    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    /// A point-in-time introspection snapshot: queue depth, in-flight
    /// lanes, and rolling QPS / latency percentiles.  Cheap enough to call
    /// from a status emitter after every batch.
    pub fn stats(&self) -> ServeStats {
        let lat = self.metrics.latency_snapshot();
        ServeStats {
            queued: self.queue.len(),
            in_flight: self.in_flight,
            batches: self.batches,
            failed_batches: self.metrics.failed_batches,
            queries: self.metrics.queries,
            qps: self.metrics.qps(),
            p50_secs: lat.percentile(50.0),
            p99_secs: lat.percentile(99.0),
        }
    }

    /// Drain the admission queue: pack up to `k` queries per batch into
    /// one k-lane run each, until the queue is empty.  Results come back
    /// in admission order within each batch.
    pub fn run_pending(&mut self) -> Result<Vec<QueryResult>> {
        self.run_pending_with(|_| {})
    }

    /// Like [`Self::run_pending`], but calls `emit` with a fresh
    /// [`ServeStats`] snapshot after every drained batch — the serve CLI's
    /// periodic one-line status emitter hooks in here.
    pub fn run_pending_with(
        &mut self,
        mut emit: impl FnMut(&ServeStats),
    ) -> Result<Vec<QueryResult>> {
        let mut results = Vec::new();
        while !self.queue.is_empty() {
            let take = self.cfg.lanes.min(self.queue.len());
            let mut batch = Vec::with_capacity(take);
            for _ in 0..take {
                batch.push(self.queue.pop_front().unwrap());
            }
            let seq = self.batches;
            self.batches += 1;

            // Validate + translate; bad ids are answered without a lane.
            // `slots` keeps every answer in admission order.
            let mut slots: Vec<Option<QueryResult>> = (0..batch.len()).map(|_| None).collect();
            let mut lanes: Vec<(usize, Prepared)> = Vec::with_capacity(batch.len());
            for (i, p) in batch.iter().enumerate() {
                match prepare(self.graph, p.query) {
                    Ok(prep) => lanes.push((i, prep)),
                    Err(bad) => {
                        slots[i] = Some(QueryResult {
                            id: p.id,
                            query: p.query,
                            answer: Answer::UnknownVertex(bad),
                            latency_secs: p.submitted.elapsed().as_secs_f64(),
                            batch: seq,
                            lanes_in_batch: 0,
                            supersteps: 0,
                            error: None,
                        })
                    }
                }
            }

            if !lanes.is_empty() {
                self.in_flight = lanes.len();
                self.tr.begin(crate::trace::EventKind::ServeBatch, seq);
                let preps: Vec<&Prepared> = lanes.iter().map(|(_, p)| p).collect();
                // Batch-level self-healing: a batch that dies of a
                // *retryable* cause (I/O error, transient network fault)
                // is re-run once before its queries are failed — serve
                // batches are stateless traversals over immutable store
                // files, so a clean re-run is always safe.  Deterministic
                // failures (bad program, config) fail straight through.
                let outcome = run_batch_any(self.graph, &self.cfg, &preps).or_else(|e| {
                    if crate::worker::fault::retryable_cause(&e.to_string()) {
                        crate::trace::diag(
                            "serve",
                            &format!("batch {seq} retrying after transient failure: {e}"),
                        );
                        let second = run_batch_any(self.graph, &self.cfg, &preps);
                        if second.is_ok() {
                            self.metrics.recovered_batches += 1;
                        }
                        second
                    } else {
                        Err(e)
                    }
                });
                match outcome {
                    Ok((answers, supersteps, wall, job)) => {
                        self.metrics.record_batch(lanes.len() as u64, wall, &job);
                        for ((i, _), answer) in lanes.iter().zip(answers) {
                            let p = &batch[*i];
                            let latency_secs = p.submitted.elapsed().as_secs_f64();
                            self.metrics.latencies_secs.push(latency_secs);
                            slots[*i] = Some(QueryResult {
                                id: p.id,
                                query: p.query,
                                answer,
                                latency_secs,
                                batch: seq,
                                lanes_in_batch: lanes.len(),
                                supersteps,
                                error: None,
                            });
                        }
                    }
                    Err(e) => {
                        // Failure isolation: the batch's queries fail with
                        // the typed cause, the queue keeps draining, and
                        // the server survives for future submissions.
                        let msg = e.to_string();
                        crate::trace::diag("serve", &format!("batch {seq} failed: {msg}"));
                        self.metrics.failed_batches += 1;
                        for (i, _) in &lanes {
                            let p = &batch[*i];
                            slots[*i] = Some(QueryResult {
                                id: p.id,
                                query: p.query,
                                answer: Answer::Failed,
                                latency_secs: p.submitted.elapsed().as_secs_f64(),
                                batch: seq,
                                lanes_in_batch: lanes.len(),
                                supersteps: 0,
                                error: Some(msg.clone()),
                            });
                        }
                    }
                }
                self.tr.end(crate::trace::EventKind::ServeBatch, seq);
                self.in_flight = 0;
            }
            results.extend(slots.into_iter().flatten());
            emit(&self.stats());
        }
        if self.tracer.enabled() {
            self.tr.finish();
            // Best-effort: the serve track rewrites with the events of this
            // drain; query results never fail on an export error.
            let _ = self.tracer.export_chrome(&self.trace_out);
        }
        Ok(results)
    }
}

/// Translate a query into the current ID space; `Err(id)` = unknown vertex.
fn prepare(graph: &LoadedGraph<'_>, query: Query) -> std::result::Result<Prepared, u32> {
    let src = query.source();
    let src_cur = graph.try_current_id_of(src).ok_or(src)?;
    let (tgt_cur, tgt_input) = match query.target() {
        Some(t) => (graph.try_current_id_of(t).ok_or(t)?, t),
        None => (NO_VERTEX, NO_VERTEX),
    };
    Ok(Prepared {
        query,
        src_cur,
        tgt_cur,
        tgt_input,
        reach: matches!(query, Query::Reach { .. }),
    })
}

type BatchOut = (Vec<Answer>, u64, f64, JobMetrics);

/// Monomorphisation dispatch over the configured lane width.
fn run_batch_any(
    graph: &LoadedGraph<'_>,
    cfg: &ServeConfig,
    preps: &[&Prepared],
) -> Result<BatchOut> {
    match cfg.lanes {
        1 => run_batch::<1>(graph, cfg, preps),
        2 => run_batch::<2>(graph, cfg, preps),
        4 => run_batch::<4>(graph, cfg, preps),
        8 => run_batch::<8>(graph, cfg, preps),
        16 => run_batch::<16>(graph, cfg, preps),
        k => Err(Error::Config(format!("unsupported lane width {k}"))),
    }
}

/// Run one batch as a K-lane multi-source job and extract per-lane answers.
fn run_batch<const K: usize>(
    graph: &LoadedGraph<'_>,
    cfg: &ServeConfig,
    preps: &[&Prepared],
) -> Result<BatchOut> {
    debug_assert!(preps.len() <= K);
    let mut sources = [NO_VERTEX; K];
    let mut targets = [NO_VERTEX; K];
    let mut reach_only = [false; K];
    for (l, p) in preps.iter().enumerate() {
        sources[l] = p.src_cur;
        targets[l] = p.tgt_cur;
        reach_only[l] = p.reach;
    }
    let prog = Arc::new(MultiSssp::<K> {
        sources,
        targets,
        reach_only,
    });
    let (wall, res) = timed(|| {
        graph
            .job(prog)
            .mode(cfg.mode)
            .max_supersteps(cfg.max_supersteps)
            .run()
    });
    let res = res?;

    // Extraction: target rows for Dist/Reach lanes, finite-lane counts for
    // ReachCount lanes — one linear scan over the outputs.
    let mut lanes_at: HashMap<u32, Vec<usize>> = HashMap::new();
    let mut need_counts = false;
    for (l, p) in preps.iter().enumerate() {
        if p.tgt_input == NO_VERTEX {
            need_counts = true;
        } else {
            lanes_at.entry(p.tgt_input).or_default().push(l);
        }
    }
    let mut target_val = vec![f32::INFINITY; preps.len()];
    let mut counts = vec![0u64; preps.len()];
    for out in &res.outputs {
        for (row, &id) in out.ids.iter().enumerate() {
            let v = &out.values[row];
            if need_counts {
                for (l, c) in counts.iter_mut().enumerate() {
                    if v[l].is_finite() {
                        *c += 1;
                    }
                }
            }
            if let Some(ls) = lanes_at.get(&id) {
                for &l in ls {
                    target_val[l] = v[l];
                }
            }
        }
    }
    let answers = preps
        .iter()
        .enumerate()
        .map(|(l, p)| {
            let d = target_val[l];
            match p.query {
                Query::Dist { .. } => Answer::Dist(d.is_finite().then_some(d)),
                Query::Reach { .. } => Answer::Reach(d.is_finite()),
                Query::ReachCount { .. } => Answer::ReachCount(counts[l]),
            }
        })
        .collect();
    Ok((answers, res.supersteps(), wall, res.metrics))
}

/// Parse one line of a query file (the `graphd serve` CLI format):
///
/// ```text
/// dist SRC DST        # shortest distance
/// reach SRC DST       # reachability
/// reachcount SRC      # single-source reachable-vertex count
/// SRC DST             # bare pair = dist
/// SRC                 # bare id   = reachcount
/// ```
///
/// Blank lines and `#` comments yield `Ok(None)`.
pub fn parse_query_line(line: &str) -> Result<Option<Query>> {
    let line = line.split('#').next().unwrap_or("").trim();
    if line.is_empty() {
        return Ok(None);
    }
    let toks: Vec<&str> = line.split_whitespace().collect();
    let bad = || Error::Config(format!("bad query line: '{line}'"));
    let num = |s: &str| s.parse::<u32>().map_err(|_| bad());
    let q = match toks.as_slice() {
        ["dist", s, t] => Query::Dist {
            source: num(s)?,
            target: num(t)?,
        },
        ["reach", s, t] => Query::Reach {
            source: num(s)?,
            target: num(t)?,
        },
        ["reachcount", s] => Query::ReachCount { source: num(s)? },
        [s, t] => Query::Dist {
            source: num(s)?,
            target: num(t)?,
        },
        [s] => Query::ReachCount { source: num(s)? },
        _ => return Err(bad()),
    };
    Ok(Some(q))
}

/// Render one served query as a stable text line (CLI output).
pub fn render_result(r: &QueryResult) -> String {
    let q = match r.query {
        Query::Dist { source, target } => format!("dist {source} {target}"),
        Query::Reach { source, target } => format!("reach {source} {target}"),
        Query::ReachCount { source } => format!("reachcount {source}"),
    };
    let a = match r.answer {
        Answer::Dist(Some(d)) => format!("{d}"),
        Answer::Dist(None) => "unreachable".to_string(),
        Answer::Reach(true) => "yes".to_string(),
        Answer::Reach(false) => "no".to_string(),
        Answer::ReachCount(c) => format!("{c}"),
        Answer::UnknownVertex(v) => format!("unknown vertex {v}"),
        Answer::Failed => match &r.error {
            Some(e) => format!("failed ({e})"),
            None => "failed".to_string(),
        },
    };
    format!(
        "{q} = {a}  ({:.1} ms, batch {} x{}, {} supersteps)",
        r.latency_secs * 1e3,
        r.batch,
        r.lanes_in_batch,
        r.supersteps
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::session::{GraphD, GraphSource};
    use std::path::PathBuf;

    fn wd(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "graphd_serve_test_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn parse_query_lines() {
        assert_eq!(
            parse_query_line("dist 3 9").unwrap(),
            Some(Query::Dist { source: 3, target: 9 })
        );
        assert_eq!(
            parse_query_line("reach 0 5").unwrap(),
            Some(Query::Reach { source: 0, target: 5 })
        );
        assert_eq!(
            parse_query_line("reachcount 7").unwrap(),
            Some(Query::ReachCount { source: 7 })
        );
        assert_eq!(
            parse_query_line("4 8").unwrap(),
            Some(Query::Dist { source: 4, target: 8 })
        );
        assert_eq!(
            parse_query_line("12").unwrap(),
            Some(Query::ReachCount { source: 12 })
        );
        assert_eq!(parse_query_line("").unwrap(), None);
        assert_eq!(parse_query_line("  # a comment").unwrap(), None);
        assert_eq!(
            parse_query_line("3 9 # trailing comment").unwrap(),
            Some(Query::Dist { source: 3, target: 9 })
        );
        assert!(parse_query_line("dist x y").is_err());
        assert!(parse_query_line("frob 1 2 3").is_err());
    }

    #[test]
    fn lane_width_is_validated() {
        let d = wd("lanes");
        let g = generator::chain(20);
        let s = GraphD::builder().workdir(&d).machines(2).build().unwrap();
        let lg = s.load(GraphSource::InMemory(&g)).unwrap();
        assert!(lg.serve(ServeConfig::default().lanes(3)).is_err());
        assert!(lg.serve(ServeConfig::default().lanes(8)).is_ok());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn serves_chain_distances_and_reachability() {
        let d = wd("chain");
        // Directed chain 0→1→…→29: distances are exact, lanes settle at
        // different supersteps (targets at different depths).
        let g = generator::chain(30).with_unit_weights();
        let s = GraphD::builder().workdir(&d).machines(2).build().unwrap();
        let lg = s.load(GraphSource::InMemory(&g)).unwrap();
        let mut srv = lg.serve(ServeConfig::default().lanes(4)).unwrap();

        srv.submit(Query::Dist { source: 0, target: 5 });
        srv.submit(Query::Dist { source: 2, target: 29 });
        srv.submit(Query::Reach { source: 10, target: 3 }); // backwards: no
        srv.submit(Query::ReachCount { source: 25 });
        srv.submit(Query::Dist { source: 7, target: 7 }); // second batch
        assert_eq!(srv.pending(), 5);

        let rs = srv.run_pending().unwrap();
        assert_eq!(srv.pending(), 0);
        assert_eq!(rs.len(), 5);
        assert_eq!(rs[0].answer, Answer::Dist(Some(5.0)));
        assert_eq!(rs[1].answer, Answer::Dist(Some(27.0)));
        assert_eq!(rs[2].answer, Answer::Reach(false));
        assert_eq!(rs[3].answer, Answer::ReachCount(5)); // 25..=29
        assert_eq!(rs[4].answer, Answer::Dist(Some(0.0)));
        assert_eq!(rs[0].batch, 0);
        assert_eq!(rs[0].lanes_in_batch, 4);
        assert_eq!(rs[4].batch, 1);
        assert_eq!(rs[4].lanes_in_batch, 1);
        assert!(rs.iter().all(|r| r.latency_secs >= 0.0));

        let m = srv.metrics();
        assert_eq!(m.queries, 5);
        assert_eq!(m.batches, 2);
        assert_eq!(m.latencies_secs.len(), 5);
        assert!(m.report().contains("queries answered   5"));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn unknown_vertices_answered_without_a_lane() {
        let d = wd("unknown");
        let g = generator::chain(10);
        let s = GraphD::builder().workdir(&d).machines(2).build().unwrap();
        let lg = s.load(GraphSource::InMemory(&g)).unwrap();
        let mut srv = lg.serve(ServeConfig::default().lanes(2)).unwrap();
        srv.submit(Query::Dist { source: 999, target: 3 });
        srv.submit(Query::Dist { source: 0, target: 4 });
        let rs = srv.run_pending().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].answer, Answer::UnknownVertex(999));
        assert_eq!(rs[1].answer, Answer::Dist(Some(4.0)));
        // only the valid query hit the engine
        assert_eq!(srv.metrics().queries, 1);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn render_result_is_stable() {
        let r = QueryResult {
            id: 0,
            query: Query::Dist { source: 1, target: 2 },
            answer: Answer::Dist(None),
            latency_secs: 0.0123,
            batch: 3,
            lanes_in_batch: 8,
            supersteps: 11,
            error: None,
        };
        let s = render_result(&r);
        assert!(s.starts_with("dist 1 2 = unreachable"));
        assert!(s.contains("batch 3 x8"));
    }
}
