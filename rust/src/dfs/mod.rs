//! Simulated HDFS: a shared directory with block-oriented access.
//!
//! The paper's jobs load from / dump to HDFS (§2) and checkpoint to HDFS
//! (§3.4).  We model it as a directory where each file exposes fixed-size
//! blocks; during loading, machine `i` parses blocks `j ≡ i (mod n)` in
//! parallel with the other machines — the line-boundary convention is the
//! standard Hadoop one (skip to the first full line after the block start,
//! read past the block end to finish the last line).

use crate::error::{Error, Result};
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

/// Default block size: 4 MB (scaled-down HDFS 64 MB blocks).
pub const DEFAULT_BLOCK: u64 = 4 * 1024 * 1024;

/// Handle to the simulated DFS rooted at a directory.
#[derive(Clone, Debug)]
pub struct Dfs {
    root: PathBuf,
    block_size: u64,
}

impl Dfs {
    pub fn new(root: &Path) -> Result<Self> {
        std::fs::create_dir_all(root)?;
        Ok(Self {
            root: root.to_path_buf(),
            block_size: DEFAULT_BLOCK,
        })
    }

    pub fn with_block_size(mut self, bs: u64) -> Self {
        self.block_size = bs.max(16);
        self
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn path_of(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }

    /// Store bytes under `name` (replacing any existing file).
    pub fn put(&self, name: &str, data: &[u8]) -> Result<()> {
        let p = self.path_of(name);
        if let Some(d) = p.parent() {
            std::fs::create_dir_all(d)?;
        }
        std::fs::write(p, data)?;
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<Vec<u8>> {
        Ok(std::fs::read(self.path_of(name))?)
    }

    pub fn exists(&self, name: &str) -> bool {
        self.path_of(name).exists()
    }

    pub fn delete(&self, name: &str) -> Result<()> {
        let p = self.path_of(name);
        if p.is_dir() {
            std::fs::remove_dir_all(p)?;
        } else if p.exists() {
            std::fs::remove_file(p)?;
        }
        Ok(())
    }

    pub fn len(&self, name: &str) -> Result<u64> {
        Ok(std::fs::metadata(self.path_of(name))?.len())
    }

    /// Number of blocks of `name`.
    pub fn num_blocks(&self, name: &str) -> Result<u64> {
        let len = self.len(name)?;
        Ok((len + self.block_size - 1) / self.block_size)
    }

    /// Read the *lines* belonging to block `blk` of a text file, using the
    /// Hadoop boundary convention.  Returns complete lines only.
    pub fn read_block_lines(&self, name: &str, blk: u64) -> Result<Vec<String>> {
        let path = self.path_of(name);
        let mut f = std::fs::File::open(&path)?;
        let len = f.metadata()?.len();
        let start = blk * self.block_size;
        let end = ((blk + 1) * self.block_size).min(len);
        if start >= len {
            return Ok(Vec::new());
        }

        // Find the true start: offset 0 starts immediately; otherwise skip
        // to the byte after the first '\n' at/after `start - 1`.
        let mut true_start = start;
        if start > 0 {
            f.seek(SeekFrom::Start(start - 1))?;
            let mut buf = [0u8; 4096];
            let mut off = start - 1;
            'outer: loop {
                let n = f.read(&mut buf)?;
                if n == 0 {
                    return Ok(Vec::new()); // no newline until EOF
                }
                for (i, &b) in buf[..n].iter().enumerate() {
                    if b == b'\n' {
                        true_start = off + i as u64 + 1;
                        break 'outer;
                    }
                }
                off += n as u64;
            }
            if true_start >= end {
                return Ok(Vec::new()); // this block holds no line start
            }
        }

        // Read from true_start past `end` to the newline terminating the
        // last line that *starts* inside the block.
        f.seek(SeekFrom::Start(true_start))?;
        let mut data = Vec::new();
        let mut reader = std::io::BufReader::new(f);
        let mut buf = [0u8; 64 * 1024];
        let mut pos = true_start;
        loop {
            let n = reader.read(&mut buf)?;
            if n == 0 {
                break;
            }
            data.extend_from_slice(&buf[..n]);
            pos += n as u64;
            if pos >= end {
                // Have we got the final newline past the block boundary?
                let boundary = (end - true_start) as usize;
                if data[boundary.saturating_sub(1)..].contains(&b'\n') || pos >= len {
                    break;
                }
            }
        }

        let boundary = (end - true_start) as usize;
        let cut = match data[boundary.saturating_sub(1)..]
            .iter()
            .position(|&b| b == b'\n')
        {
            Some(i) => boundary.saturating_sub(1) + i + 1,
            None => data.len(),
        };
        let text = std::str::from_utf8(&data[..cut])
            .map_err(|e| Error::CorruptStream(format!("non-utf8 dfs block: {e}")))?;
        Ok(text
            .lines()
            .map(str::to_owned)
            .filter(|l| !l.is_empty())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdfs(name: &str, block: u64) -> Dfs {
        let d = std::env::temp_dir().join(format!(
            "graphd_dfs_{name}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        Dfs::new(&d).unwrap().with_block_size(block)
    }

    #[test]
    fn put_get_roundtrip() {
        let dfs = tmpdfs("put", 64);
        dfs.put("a/b.txt", b"hello").unwrap();
        assert_eq!(dfs.get("a/b.txt").unwrap(), b"hello");
        assert!(dfs.exists("a/b.txt"));
        dfs.delete("a/b.txt").unwrap();
        assert!(!dfs.exists("a/b.txt"));
        let _ = std::fs::remove_dir_all(dfs.root());
    }

    #[test]
    fn block_lines_partition_exactly() {
        // Every line must be returned by exactly one block, regardless of
        // where block boundaries fall.
        for block in [8u64, 13, 32, 1000] {
            let dfs = tmpdfs(&format!("part{block}"), block);
            let lines: Vec<String> = (0..200).map(|i| format!("line{i:04}")).collect();
            dfs.put("f.txt", (lines.join("\n") + "\n").as_bytes()).unwrap();
            let nb = dfs.num_blocks("f.txt").unwrap();
            let mut got = Vec::new();
            for b in 0..nb {
                got.extend(dfs.read_block_lines("f.txt", b).unwrap());
            }
            assert_eq!(got, lines, "block={block}");
            let _ = std::fs::remove_dir_all(dfs.root());
        }
    }

    #[test]
    fn block_lines_no_trailing_newline() {
        let dfs = tmpdfs("notrail", 10);
        dfs.put("f.txt", b"aaaa\nbbbb\ncccc").unwrap();
        let nb = dfs.num_blocks("f.txt").unwrap();
        let mut got = Vec::new();
        for b in 0..nb {
            got.extend(dfs.read_block_lines("f.txt", b).unwrap());
        }
        assert_eq!(got, vec!["aaaa", "bbbb", "cccc"]);
        let _ = std::fs::remove_dir_all(dfs.root());
    }

    #[test]
    fn property_block_partition_random_lines() {
        crate::util::proptest_lite::run(15, |g| {
            let block = 4 + g.usize_in(0, 60) as u64;
            let dfs = tmpdfs(&format!("prop{}_{}", g.case, block), block);
            let n = g.usize_in(1, 100);
            let lines: Vec<String> = (0..n)
                .map(|i| format!("{i}:{}", "x".repeat(g.usize_in(0, 20))))
                .collect();
            dfs.put("f.txt", (lines.join("\n") + "\n").as_bytes()).unwrap();
            let nb = dfs.num_blocks("f.txt").unwrap();
            let mut got = Vec::new();
            for b in 0..nb {
                got.extend(dfs.read_block_lines("f.txt", b).unwrap());
            }
            let ok = got == lines;
            let _ = std::fs::remove_dir_all(dfs.root());
            crate::prop_assert!(g, ok, "partition mismatch block={block} n={n}");
        });
    }
}
