//! Buffered sequential stream writer (the write half of §3.2's streaming:
//! an in-memory buffer of `b` bytes flushed in batches, so appends achieve
//! sequential disk bandwidth with negligible memory).

use crate::error::Result;
use crate::msg::BufPool;
use std::fs::File;
use std::io::Write;
use std::path::Path;

/// Buffered appender with byte accounting.
pub struct StreamWriter {
    file: File,
    buf: Vec<u8>,
    written: u64,
    flushes: u64,
}

impl StreamWriter {
    pub fn create(path: &Path, buf_size: usize) -> Result<Self> {
        Self::with_buf(path, Vec::with_capacity(buf_size.max(16)))
    }

    /// Like [`Self::create`] but the in-memory buffer is checked out of
    /// `pool` (recycle it back with [`Self::finish_recycle`]) — the
    /// alloc-free form used by the OMS hot path, where files open and
    /// close once per ≤ℬ bytes.
    pub fn create_pooled(path: &Path, buf_size: usize, pool: &BufPool) -> Result<Self> {
        // analyze:allow(pool-leak): this IS the pooled-checkout constructor
        // the rule whitelists at call sites — the buffer lives in the
        // writer until finish_recycle() returns it to the pool.
        Self::with_buf(path, pool.take_with_capacity(buf_size.max(16)))
    }

    fn with_buf(path: &Path, buf: Vec<u8>) -> Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(Self {
            file: File::create(path)?,
            buf,
            written: 0,
            flushes: 0,
        })
    }

    #[inline]
    pub fn write_all(&mut self, data: &[u8]) -> Result<()> {
        if self.buf.len() + data.len() > self.buf.capacity() {
            self.flush_buf()?;
            if data.len() >= self.buf.capacity() {
                // Oversized record: write through.
                self.file.write_all(data)?;
                crate::util::diskio::charge(data.len());
                self.flushes += 1;
                self.written += data.len() as u64;
                return Ok(());
            }
        }
        self.buf.extend_from_slice(data);
        self.written += data.len() as u64;
        Ok(())
    }

    fn flush_buf(&mut self) -> Result<()> {
        if !self.buf.is_empty() {
            self.file.write_all(&self.buf)?;
            crate::util::diskio::charge(self.buf.len());
            self.buf.clear();
            self.flushes += 1;
        }
        Ok(())
    }

    /// Bytes accepted so far (buffered + flushed).
    pub fn bytes_written(&self) -> u64 {
        self.written
    }

    pub fn flushes(&self) -> u64 {
        self.flushes
    }

    /// Flush and sync-close the stream.
    pub fn finish(mut self) -> Result<u64> {
        self.flush_buf()?;
        self.file.flush()?;
        Ok(self.written)
    }

    /// [`Self::finish`], returning the in-memory buffer to `pool`.
    pub fn finish_recycle(mut self, pool: &BufPool) -> Result<u64> {
        self.flush_buf()?;
        self.file.flush()?;
        pool.put(std::mem::take(&mut self.buf));
        Ok(self.written)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let p = std::env::temp_dir().join(format!("graphd_writer_{}", std::process::id()));
        let mut w = StreamWriter::create(&p, 32).unwrap();
        let data: Vec<u8> = (0..1000).map(|i| (i % 251) as u8).collect();
        for chunk in data.chunks(7) {
            w.write_all(chunk).unwrap();
        }
        assert_eq!(w.bytes_written(), 1000);
        w.finish().unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), data);
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn oversized_record_writes_through() {
        let p = std::env::temp_dir().join(format!("graphd_writer_big_{}", std::process::id()));
        let mut w = StreamWriter::create(&p, 16).unwrap();
        let big = vec![9u8; 100];
        w.write_all(&[1, 2]).unwrap();
        w.write_all(&big).unwrap();
        w.write_all(&[3]).unwrap();
        w.finish().unwrap();
        let got = std::fs::read(&p).unwrap();
        assert_eq!(got.len(), 103);
        assert_eq!(got[0..2], [1, 2]);
        assert_eq!(got[102], 3);
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn pooled_writer_recycles_buffer() {
        let pool = BufPool::new(4);
        let p = std::env::temp_dir().join(format!("graphd_writer_pool_{}", std::process::id()));
        let mut w = StreamWriter::create_pooled(&p, 64, &pool).unwrap();
        w.write_all(&[7u8; 40]).unwrap();
        assert_eq!(w.finish_recycle(&pool).unwrap(), 40);
        assert_eq!(pool.idle(), 1);
        // The next pooled writer reuses the shelved buffer: a pool hit.
        let before = pool.stats().hits;
        let w2 = StreamWriter::create_pooled(&p, 64, &pool).unwrap();
        assert_eq!(pool.stats().hits, before + 1);
        w2.finish_recycle(&pool).unwrap();
        assert_eq!(std::fs::read(&p).unwrap().len(), 0);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn creates_parent_dirs() {
        let p = std::env::temp_dir()
            .join(format!("graphd_writer_dir_{}", std::process::id()))
            .join("a/b/c.bin");
        let w = StreamWriter::create(&p, 16).unwrap();
        w.finish().unwrap();
        assert!(p.exists());
        std::fs::remove_file(&p).unwrap();
    }
}
