//! k-way external merge-sort over fixed-size records (§3.3.1–3.3.2).
//!
//! Records are fixed-size byte strings whose first 4 bytes are the
//! little-endian destination vertex ID (the sort key).  Each input file is
//! already sorted (the receiver sorts every ≤ℬ batch in memory before
//! spilling); this module merges them with a k-way heap using one 64 KB
//! buffer per way — (k+1)·b memory, as in the paper.  With k = 1000 a
//! single pass suffices for any realistic stream; more inputs trigger
//! multi-pass merging.
//!
//! `merge_combine` additionally folds equal-key runs through a combiner —
//! this is exactly the paper's "merge-sort then combine each group into one
//! message" pre-send step of IO-Basic.

use crate::error::Result;
use crate::stream::{reader::StreamReader, writer::StreamWriter};
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::path::{Path, PathBuf};

/// Sort a flat buffer of `rec_size`-byte records in place by leading-u32 key.
///
/// Hot path: 8-byte records (u32 target + 4-byte payload, the common
/// message layout) are reinterpreted as `u64`s whose *low* 32 bits are the
/// LE key, so a plain `sort_unstable` on masked u64s replaces the
/// index-permutation gather (≈3× faster; README.md §Perf).
pub fn sort_records(buf: &mut [u8], rec_size: usize) {
    debug_assert_eq!(buf.len() % rec_size, 0);
    let n = buf.len() / rec_size;
    if n <= 1 {
        return;
    }
    if rec_size == 8 {
        // Copy into aligned u64s (buf may be unaligned), sort by the key
        // half, copy back. LE layout puts the key in the low 32 bits.
        let mut words: Vec<u64> = buf
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        words.sort_unstable_by_key(|&w| w as u32);
        for (c, w) in buf.chunks_exact_mut(8).zip(words) {
            c.copy_from_slice(&w.to_le_bytes());
        }
        return;
    }
    // Generic path: sort an index permutation, then gather.
    let key =
        |i: usize| u32::from_le_bytes(buf[i * rec_size..i * rec_size + 4].try_into().unwrap());
    let mut idx: Vec<u32> = (0..n as u32).collect();
    idx.sort_by_key(|&i| key(i as usize));
    let mut out = vec![0u8; buf.len()];
    for (j, &i) in idx.iter().enumerate() {
        let i = i as usize;
        out[j * rec_size..(j + 1) * rec_size]
            .copy_from_slice(&buf[i * rec_size..(i + 1) * rec_size]);
    }
    buf.copy_from_slice(&out);
}

#[inline]
fn rec_key(rec: &[u8]) -> u32 {
    u32::from_le_bytes(rec[..4].try_into().unwrap())
}

struct Way {
    reader: StreamReader,
    rec: Vec<u8>,
    src: usize,
}

struct HeapEntry {
    key: u32,
    src: usize,
}

impl PartialEq for HeapEntry {
    fn eq(&self, o: &Self) -> bool {
        self.key == o.key && self.src == o.src
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, o: &Self) -> Ordering {
        // Min-heap via reversed compare; tie-break on src for stability.
        (o.key, o.src).cmp(&(self.key, self.src))
    }
}

/// Stream records of all (sorted) `inputs` in global key order into `emit`.
/// Uses at most `k` ways per pass; extra inputs are merged in multiple
/// passes through temporary files in `tmp_dir`.
pub fn merge_streams(
    inputs: &[PathBuf],
    rec_size: usize,
    k: usize,
    buf_size: usize,
    tmp_dir: &Path,
    mut emit: impl FnMut(&[u8]) -> Result<()>,
) -> Result<()> {
    let inputs = multi_pass_reduce(inputs, rec_size, k, buf_size, tmp_dir)?;
    merge_once(&inputs.paths(), rec_size, buf_size, |rec| emit(rec))
}

/// Merge + combine equal-key runs: `combine(acc_payload, payload)` folds the
/// payloads (bytes after the 4-byte key) of records sharing a key, and
/// `emit` receives one combined record per distinct key.
pub fn merge_combine(
    inputs: &[PathBuf],
    rec_size: usize,
    k: usize,
    buf_size: usize,
    tmp_dir: &Path,
    mut combine: impl FnMut(&mut [u8], &[u8]),
    mut emit: impl FnMut(&[u8]) -> Result<()>,
) -> Result<()> {
    let inputs = multi_pass_reduce(inputs, rec_size, k, buf_size, tmp_dir)?;
    let mut acc: Vec<u8> = Vec::new();
    merge_once(&inputs.paths(), rec_size, buf_size, |rec| {
        if acc.is_empty() {
            acc.extend_from_slice(rec);
        } else if rec_key(&acc) == rec_key(rec) {
            let (head, payload) = acc.split_at_mut(4);
            let _ = head;
            combine(payload, &rec[4..]);
        } else {
            emit(&acc)?;
            acc.clear();
            acc.extend_from_slice(rec);
        }
        Ok(())
    })?;
    if !acc.is_empty() {
        emit(&acc)?;
    }
    Ok(())
}

/// Holds reduced input paths plus ownership of temporaries for cleanup.
struct Reduced {
    paths: Vec<PathBuf>,
    temps: Vec<PathBuf>,
}

impl Reduced {
    fn paths(&self) -> Vec<PathBuf> {
        self.paths.clone()
    }
}

impl Drop for Reduced {
    fn drop(&mut self) {
        for t in &self.temps {
            let _ = std::fs::remove_file(t);
        }
    }
}

/// Reduce `inputs` to ≤ k sorted files via intermediate merge passes.
fn multi_pass_reduce(
    inputs: &[PathBuf],
    rec_size: usize,
    k: usize,
    buf_size: usize,
    tmp_dir: &Path,
) -> Result<Reduced> {
    let k = k.max(2);
    let mut cur: Vec<PathBuf> = inputs.to_vec();
    let mut temps: Vec<PathBuf> = Vec::new();
    let mut pass = 0;
    while cur.len() > k {
        std::fs::create_dir_all(tmp_dir)?;
        let mut next: Vec<PathBuf> = Vec::new();
        for (gi, group) in cur.chunks(k).enumerate() {
            let out = tmp_dir.join(format!("merge_p{pass}_{gi}"));
            let mut w = StreamWriter::create(&out, buf_size)?;
            merge_once(group, rec_size, buf_size, |rec| w.write_all(rec))?;
            w.finish()?;
            next.push(out.clone());
            temps.push(out);
        }
        cur = next;
        pass += 1;
    }
    Ok(Reduced { paths: cur, temps })
}

fn merge_once(
    inputs: &[PathBuf],
    rec_size: usize,
    buf_size: usize,
    mut emit: impl FnMut(&[u8]) -> Result<()>,
) -> Result<()> {
    let mut ways: Vec<Way> = Vec::with_capacity(inputs.len());
    let mut heap = BinaryHeap::new();
    for (src, p) in inputs.iter().enumerate() {
        let mut reader = StreamReader::open(p, buf_size)?;
        if reader.remaining() == 0 {
            continue;
        }
        let mut rec = vec![0u8; rec_size];
        reader.read_exact(&mut rec)?;
        heap.push(HeapEntry {
            key: rec_key(&rec),
            src,
        });
        ways.push(Way { reader, rec, src });
        // keep ways indexable by src: fix up ordering below
    }
    // Map src -> way index.
    let mut way_of = vec![usize::MAX; inputs.len()];
    for (wi, w) in ways.iter().enumerate() {
        way_of[w.src] = wi;
    }
    while let Some(HeapEntry { src, .. }) = heap.pop() {
        let wi = way_of[src];
        emit(&ways[wi].rec)?;
        let w = &mut ways[wi];
        if w.reader.remaining() >= rec_size as u64 {
            w.reader.read_exact(&mut w.rec)?;
            heap.push(HeapEntry {
                key: rec_key(&w.rec),
                src,
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmpd(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "graphd_merge_{name}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn write_sorted(dir: &Path, name: &str, recs: &mut Vec<(u32, f32)>) -> PathBuf {
        recs.sort_by_key(|r| r.0);
        let p = dir.join(name);
        let mut w = StreamWriter::create(&p, 4096).unwrap();
        for (k, v) in recs.iter() {
            w.write_all(&k.to_le_bytes()).unwrap();
            w.write_all(&v.to_le_bytes()).unwrap();
        }
        w.finish().unwrap();
        p
    }

    #[test]
    fn sort_records_orders_by_key() {
        let mut buf = Vec::new();
        for k in [5u32, 1, 9, 1, 3] {
            buf.extend_from_slice(&k.to_le_bytes());
            buf.extend_from_slice(&(k as f32).to_le_bytes());
        }
        sort_records(&mut buf, 8);
        let keys: Vec<u32> = buf
            .chunks(8)
            .map(|c| u32::from_le_bytes(c[..4].try_into().unwrap()))
            .collect();
        assert_eq!(keys, vec![1, 1, 3, 5, 9]);
    }

    #[test]
    fn merge_two_files_in_order() {
        let d = tmpd("two");
        let a = write_sorted(&d, "a", &mut vec![(1, 1.0), (3, 3.0), (5, 5.0)]);
        let b = write_sorted(&d, "b", &mut vec![(2, 2.0), (3, 30.0), (6, 6.0)]);
        let mut keys = Vec::new();
        merge_streams(&[a, b], 8, 1000, 4096, &d, |rec| {
            keys.push(rec_key(rec));
            Ok(())
        })
        .unwrap();
        assert_eq!(keys, vec![1, 2, 3, 3, 5, 6]);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn merge_combine_sums_groups() {
        let d = tmpd("comb");
        let a = write_sorted(&d, "a", &mut vec![(1, 1.0), (3, 3.0), (3, 4.0)]);
        let b = write_sorted(&d, "b", &mut vec![(3, 30.0), (7, 7.0)]);
        let mut out: Vec<(u32, f32)> = Vec::new();
        merge_combine(
            &[a, b],
            8,
            1000,
            4096,
            &d,
            |acc, pay| {
                let a = f32::from_le_bytes(acc[..4].try_into().unwrap());
                let b = f32::from_le_bytes(pay[..4].try_into().unwrap());
                acc[..4].copy_from_slice(&(a + b).to_le_bytes());
            },
            |rec| {
                out.push((
                    rec_key(rec),
                    f32::from_le_bytes(rec[4..8].try_into().unwrap()),
                ));
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(out, vec![(1, 1.0), (3, 37.0), (7, 7.0)]);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn multi_pass_merge_small_k() {
        let d = tmpd("multipass");
        let mut rng = Rng::new(11);
        let mut all: Vec<u32> = Vec::new();
        let mut files = Vec::new();
        for fi in 0..9 {
            let mut recs: Vec<(u32, f32)> = (0..50)
                .map(|_| (rng.below(10_000) as u32, 1.0f32))
                .collect();
            all.extend(recs.iter().map(|r| r.0));
            files.push(write_sorted(&d, &format!("f{fi}"), &mut recs));
        }
        all.sort_unstable();
        let mut got = Vec::new();
        // k = 3 forces ceil(log3 9) = 2 reduce passes
        merge_streams(&files, 8, 3, 4096, &d, |rec| {
            got.push(rec_key(rec));
            Ok(())
        })
        .unwrap();
        assert_eq!(got, all);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn merge_handles_empty_inputs() {
        let d = tmpd("empty");
        let a = write_sorted(&d, "a", &mut vec![]);
        let b = write_sorted(&d, "b", &mut vec![(2, 2.0)]);
        let mut got = Vec::new();
        merge_streams(&[a, b], 8, 1000, 4096, &d, |rec| {
            got.push(rec_key(rec));
            Ok(())
        })
        .unwrap();
        assert_eq!(got, vec![2]);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn property_merge_equals_global_sort() {
        crate::util::proptest_lite::run(25, |g| {
            let d = tmpd(&format!("prop{}", g.case));
            let nfiles = g.usize_in(1, 8);
            let mut all: Vec<u32> = Vec::new();
            let mut files = Vec::new();
            for fi in 0..nfiles {
                let n = g.usize_in(0, 200);
                let mut recs: Vec<(u32, f32)> =
                    (0..n).map(|_| (g.u32_below(500), 0.0f32)).collect();
                all.extend(recs.iter().map(|r| r.0));
                files.push(write_sorted(&d, &format!("f{fi}"), &mut recs));
            }
            all.sort_unstable();
            let mut got = Vec::new();
            merge_streams(&files, 8, 4, 256, &d, |rec| {
                got.push(rec_key(rec));
                Ok(())
            })
            .unwrap();
            let _ = std::fs::remove_dir_all(&d);
            crate::prop_assert!(g, got == all, "merge mismatch: {} vs {}", got.len(), all.len());
        });
    }
}
