//! Splittable stream — the OMS structure of §3.3.1.
//!
//! A long stream of records broken into files `F_1, F_2, …`, each at most
//! ℬ bytes (or a single record if that record alone exceeds ℬ).  The
//! computing unit appends at the tail while the sending unit concurrently
//! fetches *fully written* files from the head; a sent file is garbage
//! collected (unless kept for fault recovery).  The paper's `no_w` / `no_s`
//! counters are `files_closed` / `files_taken` here.

use crate::error::Result;
use crate::msg::BufPool;
use crate::stream::writer::StreamWriter;
use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};

struct Tail {
    writer: Option<StreamWriter>,
    file_idx: u64,
    cur_bytes: usize,
}

struct Shared {
    /// Closed, fully-written files ready for the sender: (index, path, bytes).
    ready: VecDeque<(u64, PathBuf, u64)>,
    /// Total files closed so far (`no_w`).
    files_closed: u64,
    /// Files taken by the sender (`no_s`).
    files_taken: u64,
    /// Appender called `finalize()` — no more files will appear.
    finalized: bool,
    total_bytes: u64,
}

/// An OMS: concurrent append (tail) + fetch (head) over ≤ℬ-byte files.
pub struct SplittableStream {
    dir: PathBuf,
    cap: usize,
    tail: Mutex<Tail>,
    shared: Mutex<Shared>,
    cond: Condvar,
    buf_size: usize,
    /// Recycles per-file write buffers (OMS files open/close once per ≤ℬ
    /// bytes — with the pool that costs no allocation in steady state).
    pool: Option<Arc<BufPool>>,
}

impl SplittableStream {
    /// Create an empty splittable stream storing its files under `dir`.
    pub fn create(dir: &Path, cap: usize, buf_size: usize) -> Result<Arc<Self>> {
        Self::create_impl(dir, cap, buf_size, None)
    }

    /// [`Self::create`] with write buffers checked out of `pool`.
    pub fn create_pooled(
        dir: &Path,
        cap: usize,
        buf_size: usize,
        pool: Arc<BufPool>,
    ) -> Result<Arc<Self>> {
        Self::create_impl(dir, cap, buf_size, Some(pool))
    }

    fn create_impl(
        dir: &Path,
        cap: usize,
        buf_size: usize,
        pool: Option<Arc<BufPool>>,
    ) -> Result<Arc<Self>> {
        std::fs::create_dir_all(dir)?;
        Ok(Arc::new(Self {
            dir: dir.to_path_buf(),
            cap,
            tail: Mutex::new(Tail {
                writer: None,
                file_idx: 0,
                cur_bytes: 0,
            }),
            shared: Mutex::new(Shared {
                ready: VecDeque::new(),
                files_closed: 0,
                files_taken: 0,
                finalized: false,
                total_bytes: 0,
            }),
            cond: Condvar::new(),
            buf_size,
            pool,
        }))
    }

    fn file_path(&self, idx: u64) -> PathBuf {
        self.dir.join(format!("f{idx:06}"))
    }

    fn new_writer(&self, idx: u64) -> Result<StreamWriter> {
        match &self.pool {
            Some(p) => StreamWriter::create_pooled(&self.file_path(idx), self.buf_size, p),
            None => StreamWriter::create(&self.file_path(idx), self.buf_size),
        }
    }

    fn finish_writer(&self, w: StreamWriter) -> Result<u64> {
        match &self.pool {
            Some(p) => w.finish_recycle(p),
            None => w.finish(),
        }
    }

    /// Append one record.  If the current file would exceed ℬ, it is closed
    /// (becoming fetchable) and a new file started.  A record larger than ℬ
    /// gets a file of its own (paper: "contains only one data item whose
    /// size is larger than ℬ").
    pub fn append(&self, record: &[u8]) -> Result<()> {
        let mut t = self.tail.lock().unwrap();
        if t.writer.is_some() && t.cur_bytes + record.len() > self.cap {
            self.close_current(&mut t)?;
        }
        if t.writer.is_none() {
            let idx = t.file_idx;
            t.writer = Some(self.new_writer(idx)?);
            t.cur_bytes = 0;
        }
        t.writer.as_mut().unwrap().write_all(record)?;
        t.cur_bytes += record.len();
        Ok(())
    }

    fn close_current(&self, t: &mut Tail) -> Result<()> {
        if let Some(w) = t.writer.take() {
            let bytes = self.finish_writer(w)?;
            let idx = t.file_idx;
            t.file_idx += 1;
            t.cur_bytes = 0;
            let mut s = self.shared.lock().unwrap();
            s.ready.push_back((idx, self.file_path(idx), bytes));
            s.files_closed += 1;
            s.total_bytes += bytes;
            drop(s);
            self.cond.notify_all();
        }
        Ok(())
    }

    /// Append many fixed-size records under one lock (the hot-path form:
    /// one mutex acquisition and one buffered write per *batch* instead of
    /// per record).  Splits at record boundaries so files stay ≤ ℬ.
    pub fn append_records(&self, data: &[u8], rec_size: usize) -> Result<()> {
        debug_assert_eq!(data.len() % rec_size, 0);
        if data.is_empty() {
            return Ok(());
        }
        let mut t = self.tail.lock().unwrap();
        let mut off = 0usize;
        while off < data.len() {
            if t.writer.is_some() && t.cur_bytes + rec_size > self.cap {
                self.close_current(&mut t)?;
            }
            if t.writer.is_none() {
                let idx = t.file_idx;
                t.writer = Some(self.new_writer(idx)?);
                t.cur_bytes = 0;
            }
            // Fill the current file up to its cap in one write.
            let room = (self.cap - t.cur_bytes) / rec_size * rec_size;
            let take = room.min(data.len() - off).max(rec_size);
            t.writer.as_mut().unwrap().write_all(&data[off..off + take])?;
            t.cur_bytes += take;
            off += take;
        }
        Ok(())
    }

    /// Close the in-progress file (if any) *without* finalizing the stream,
    /// and return the total number of closed files — the superstep
    /// watermark: every file with index < watermark belongs to supersteps
    /// ≤ the current one.  This is what lets U_c append superstep-(i+1)
    /// files to an OMS while U_s is still draining superstep-i files (§4).
    pub fn close_current_file(&self) -> Result<u64> {
        let mut t = self.tail.lock().unwrap();
        self.close_current(&mut t)?;
        Ok(self.shared.lock().unwrap().files_closed)
    }

    /// Like [`Self::try_take_next`] but only files with index < `upto`.
    pub fn try_take_next_upto(&self, upto: u64) -> Option<(u64, PathBuf, u64)> {
        let mut s = self.shared.lock().unwrap();
        if s.ready.front().is_some_and(|f| f.0 < upto) {
            s.files_taken += 1;
            s.ready.pop_front()
        } else {
            None
        }
    }

    /// Return a taken file to the head of the queue (used by the sender
    /// when a concurrently-published watermark reveals the file belongs to
    /// the *next* superstep).
    pub fn put_back(&self, idx: u64, path: PathBuf, bytes: u64) {
        let mut s = self.shared.lock().unwrap();
        debug_assert!(s.ready.front().map_or(true, |f| f.0 > idx));
        s.ready.push_front((idx, path, bytes));
        s.files_taken -= 1;
    }

    /// Like [`Self::try_take_all`] but only files with index < `upto`.
    pub fn try_take_all_upto(&self, upto: u64) -> Vec<(u64, PathBuf, u64)> {
        let mut s = self.shared.lock().unwrap();
        let mut out = Vec::new();
        while s.ready.front().is_some_and(|f| f.0 < upto) {
            out.push(s.ready.pop_front().unwrap());
            s.files_taken += 1;
        }
        out
    }

    /// Close the in-progress file (if any) and mark the stream complete:
    /// after this, `take_next` drains the queue and then returns `None`.
    pub fn finalize(&self) -> Result<()> {
        let mut t = self.tail.lock().unwrap();
        self.close_current(&mut t)?;
        let mut s = self.shared.lock().unwrap();
        s.finalized = true;
        drop(s);
        self.cond.notify_all();
        Ok(())
    }

    /// Re-open for a new superstep after a `finalize` + full drain.
    pub fn reset(&self) {
        let mut s = self.shared.lock().unwrap();
        debug_assert!(s.ready.is_empty());
        s.finalized = false;
    }

    /// Non-blocking fetch of the next fully-written file, if any.
    pub fn try_take_next(&self) -> Option<(u64, PathBuf, u64)> {
        let mut s = self.shared.lock().unwrap();
        let f = s.ready.pop_front();
        if f.is_some() {
            s.files_taken += 1;
        }
        f
    }

    /// Take *all* currently ready files (the combiner path merges every
    /// pending file of an OMS in one batch — §3.3.1 "Sending Strategies").
    pub fn try_take_all(&self) -> Vec<(u64, PathBuf, u64)> {
        let mut s = self.shared.lock().unwrap();
        let out: Vec<_> = s.ready.drain(..).collect();
        s.files_taken += out.len() as u64;
        out
    }

    /// Number of files ready to send right now.
    pub fn ready_count(&self) -> usize {
        self.shared.lock().unwrap().ready.len()
    }

    /// True once finalized and fully drained.
    pub fn exhausted(&self) -> bool {
        let s = self.shared.lock().unwrap();
        s.finalized && s.ready.is_empty()
    }

    pub fn is_finalized(&self) -> bool {
        self.shared.lock().unwrap().finalized
    }

    /// (files_closed, files_taken, total_bytes) — the paper's (no_w, no_s).
    pub fn stats(&self) -> (u64, u64, u64) {
        let s = self.shared.lock().unwrap();
        (s.files_closed, s.files_taken, s.total_bytes)
    }

    /// Delete a consumed file (GC). With fault-recovery logging enabled the
    /// worker defers this until the next checkpoint (§3.4).
    pub fn gc_file(path: &Path) {
        let _ = std::fs::remove_file(path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "graphd_split_{name}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn splits_at_cap() {
        let d = tmpdir("cap");
        let s = SplittableStream::create(&d, 100, 64).unwrap();
        // 30-byte records: 3 fit in 90 < 100, 4th would make 120 -> split
        for _ in 0..7 {
            s.append(&[1u8; 30]).unwrap();
        }
        s.finalize().unwrap();
        let files: Vec<_> = std::iter::from_fn(|| s.try_take_next()).collect();
        assert_eq!(files.len(), 3, "7*30 bytes at cap 100 -> 90+90+30");
        assert_eq!(files[0].2, 90);
        assert_eq!(files[1].2, 90);
        assert_eq!(files[2].2, 30);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn oversized_record_gets_own_file() {
        let d = tmpdir("big");
        let s = SplittableStream::create(&d, 64, 64).unwrap();
        s.append(&[1u8; 10]).unwrap();
        s.append(&[2u8; 500]).unwrap(); // > cap
        s.append(&[3u8; 10]).unwrap();
        s.finalize().unwrap();
        let files = s.try_take_all();
        assert_eq!(files.len(), 3);
        assert_eq!(files[1].2, 500);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn concurrent_append_and_fetch() {
        let d = tmpdir("conc");
        let s = SplittableStream::create(&d, 256, 64).unwrap();
        let s2 = s.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..1000u32 {
                s2.append(&i.to_le_bytes()).unwrap();
            }
            s2.finalize().unwrap();
        });
        // Consumer: poll until exhausted, verifying record order across files.
        let mut next = 0u32;
        loop {
            if let Some((_, path, _)) = s.try_take_next() {
                let data = std::fs::read(&path).unwrap();
                for c in data.chunks(4) {
                    assert_eq!(u32::from_le_bytes(c.try_into().unwrap()), next);
                    next += 1;
                }
                SplittableStream::gc_file(&path);
            } else if s.exhausted() {
                break;
            } else {
                std::thread::yield_now();
            }
        }
        producer.join().unwrap();
        assert_eq!(next, 1000);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn stats_track_now_nos() {
        let d = tmpdir("stats");
        let s = SplittableStream::create(&d, 8, 64).unwrap();
        for i in 0..4u32 {
            s.append(&i.to_le_bytes()).unwrap(); // 2 records per file
        }
        s.finalize().unwrap();
        assert_eq!(s.stats().0, 2); // no_w = 2 files closed
        s.try_take_next().unwrap();
        assert_eq!(s.stats().1, 1); // no_s = 1
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn watermark_separates_supersteps() {
        let d = tmpdir("wm");
        let s = SplittableStream::create(&d, 8, 64).unwrap();
        // step 0: two files
        for i in 0..4u32 {
            s.append(&i.to_le_bytes()).unwrap();
        }
        let wm0 = s.close_current_file().unwrap();
        assert_eq!(wm0, 2);
        // step 1 already appending
        s.append(&9u32.to_le_bytes()).unwrap();
        s.append(&10u32.to_le_bytes()).unwrap();
        s.append(&11u32.to_le_bytes()).unwrap(); // closes f2 at 8 bytes
        // sender drains only step-0 files
        let step0: Vec<_> = std::iter::from_fn(|| s.try_take_next_upto(wm0)).collect();
        assert_eq!(step0.len(), 2);
        assert!(s.try_take_next_upto(wm0).is_none(), "f2 is step-1");
        let wm1 = s.close_current_file().unwrap();
        assert_eq!(s.try_take_all_upto(wm1).len(), 2);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn pooled_stream_recycles_file_buffers() {
        let d = tmpdir("pooled");
        let pool = BufPool::new(8);
        let s = SplittableStream::create_pooled(&d, 8, 64, pool.clone()).unwrap();
        for i in 0..8u32 {
            s.append(&i.to_le_bytes()).unwrap(); // 2 records per file
        }
        s.finalize().unwrap();
        // 4 files closed; after the first, every writer buffer is a reuse.
        assert!(pool.stats().hits >= 3, "stats: {:?}", pool.stats());
        let files = s.try_take_all();
        assert_eq!(files.len(), 4);
        assert_eq!(std::fs::read(&files[1].1).unwrap().len(), 8);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn reset_allows_reuse() {
        let d = tmpdir("reset");
        let s = SplittableStream::create(&d, 8, 64).unwrap();
        s.append(&[0u8; 4]).unwrap();
        s.finalize().unwrap();
        assert!(s.try_take_next().is_some());
        assert!(s.exhausted());
        s.reset();
        assert!(!s.exhausted());
        s.append(&[1u8; 4]).unwrap();
        s.finalize().unwrap();
        assert_eq!(s.try_take_all().len(), 1);
        let _ = std::fs::remove_dir_all(&d);
    }
}
