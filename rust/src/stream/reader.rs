//! Buffered stream reader with the paper's `skip()` (§3.2).
//!
//! A stream is read through an in-memory buffer `B` of `b` bytes; each
//! refill is one random disk read whose cost is amortized over `b` bytes,
//! so reads are effectively sequential.  `skip(k)` advances the read
//! position; if the target stays inside `B` no I/O happens, otherwise one
//! `seek` + refill is issued.  Worst case total cost == streaming the whole
//! file once; sparse workloads skip most of it with few random reads.

use crate::error::{Error, Result};
use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;

/// Sequential reader with buffer-aware skipping and I/O accounting.
pub struct StreamReader {
    file: File,
    buf: Vec<u8>,
    /// Valid bytes in `buf`.
    filled: usize,
    /// Next unread offset within `buf`.
    pos: usize,
    /// Stream offset of `buf[0]`.
    base: u64,
    len: u64,
    // --- I/O accounting (drives the metrics tables) ---
    refills: u64,
    seeks: u64,
    bytes_read: u64,
}

impl StreamReader {
    pub fn open(path: &Path, buf_size: usize) -> Result<Self> {
        let file = File::open(path)?;
        let len = file.metadata()?.len();
        Ok(Self {
            file,
            buf: vec![0; buf_size.max(16)],
            filled: 0,
            pos: 0,
            base: 0,
            len,
            refills: 0,
            seeks: 0,
            bytes_read: 0,
        })
    }

    /// Total length of the underlying stream in bytes.
    pub fn len(&self) -> u64 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Current read offset in the stream.
    pub fn offset(&self) -> u64 {
        self.base + self.pos as u64
    }

    /// Bytes remaining from the current position to EOF.
    pub fn remaining(&self) -> u64 {
        self.len - self.offset()
    }

    fn refill(&mut self) -> Result<()> {
        self.base += self.filled as u64;
        debug_assert_eq!(self.base, self.offset() - self.pos as u64);
        self.pos = 0;
        self.filled = 0;
        while self.filled < self.buf.len() {
            let n = self.file.read(&mut self.buf[self.filled..])?;
            if n == 0 {
                break;
            }
            self.filled += n;
        }
        self.refills += 1;
        self.bytes_read += self.filled as u64;
        crate::util::diskio::charge(self.filled);
        Ok(())
    }

    /// Read exactly `out.len()` bytes; errors on EOF.
    pub fn read_exact(&mut self, out: &mut [u8]) -> Result<()> {
        let mut done = 0;
        while done < out.len() {
            if self.pos == self.filled {
                if self.offset() >= self.len {
                    return Err(Error::CorruptStream(format!(
                        "unexpected EOF at {} (want {} more bytes)",
                        self.offset(),
                        out.len() - done
                    )));
                }
                self.refill()?;
                if self.filled == 0 {
                    return Err(Error::CorruptStream("short read".into()));
                }
            }
            let n = (out.len() - done).min(self.filled - self.pos);
            out[done..done + n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
            self.pos += n;
            done += n;
        }
        Ok(())
    }

    /// The paper's `skip`: advance `nbytes` forward.  If the target is
    /// still inside the buffer this is free; otherwise one seek + refill.
    pub fn skip_bytes(&mut self, nbytes: u64) -> Result<()> {
        let target_in_buf = self.pos as u64 + nbytes;
        if target_in_buf <= self.filled as u64 {
            // Still inside B — no disk access.
            self.pos = target_in_buf as usize;
            return Ok(());
        }
        // Past the end of B: seek the file forward to the target and refill.
        let target = self.base + target_in_buf;
        if target > self.len {
            return Err(Error::CorruptStream(format!(
                "skip past EOF: to {target}, len {}",
                self.len
            )));
        }
        self.file.seek(SeekFrom::Start(target))?;
        self.seeks += 1;
        self.base = target;
        self.pos = 0;
        self.filled = 0;
        Ok(())
    }

    /// Number of buffer refills (≈ sequential batched reads) so far.
    pub fn refills(&self) -> u64 {
        self.refills
    }

    /// Number of random seeks caused by long skips.
    pub fn seeks(&self) -> u64 {
        self.seeks
    }

    pub fn bytes_read(&self) -> u64 {
        self.bytes_read
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::writer::StreamWriter;

    fn tmpfile(name: &str, data: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("graphd_reader_{name}_{}", std::process::id()));
        let mut w = StreamWriter::create(&p, 64).unwrap();
        w.write_all(data).unwrap();
        w.finish().unwrap();
        p
    }

    #[test]
    fn sequential_read_roundtrip() {
        let data: Vec<u8> = (0..10_000u32).flat_map(|i| i.to_le_bytes()).collect();
        let p = tmpfile("seq", &data);
        let mut r = StreamReader::open(&p, 256).unwrap();
        let mut buf = [0u8; 4];
        for i in 0..10_000u32 {
            r.read_exact(&mut buf).unwrap();
            assert_eq!(u32::from_le_bytes(buf), i);
        }
        assert_eq!(r.remaining(), 0);
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn skip_within_buffer_is_free() {
        let data = vec![7u8; 4096];
        let p = tmpfile("free", &data);
        let mut r = StreamReader::open(&p, 4096).unwrap();
        let mut b = [0u8; 1];
        r.read_exact(&mut b).unwrap(); // forces first refill
        let seeks0 = r.seeks();
        r.skip_bytes(1000).unwrap();
        r.skip_bytes(2000).unwrap();
        assert_eq!(r.seeks(), seeks0, "in-buffer skips must not seek");
        r.read_exact(&mut b).unwrap();
        assert_eq!(r.offset(), 3002);
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn long_skip_costs_one_seek() {
        let data: Vec<u8> = (0..100_000u32).flat_map(|i| (i as u8).to_le_bytes()).collect();
        let p = tmpfile("long", &data);
        let mut r = StreamReader::open(&p, 1024).unwrap();
        let mut b = [0u8; 1];
        r.read_exact(&mut b).unwrap();
        r.skip_bytes(50_000).unwrap();
        assert_eq!(r.seeks(), 1);
        r.read_exact(&mut b).unwrap();
        assert_eq!(b[0], data[50_001]);
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn skip_past_eof_errors() {
        let p = tmpfile("eof", &[0u8; 100]);
        let mut r = StreamReader::open(&p, 16).unwrap();
        assert!(r.skip_bytes(101).is_err());
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn skip_to_exact_eof_ok() {
        let p = tmpfile("exact", &[1u8; 64]);
        let mut r = StreamReader::open(&p, 16).unwrap();
        r.skip_bytes(64).unwrap();
        assert_eq!(r.remaining(), 0);
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn interleaved_read_skip_matches_offsets() {
        let data: Vec<u8> = (0..=255u8).cycle().take(65536).collect();
        let p = tmpfile("mix", &data);
        let mut r = StreamReader::open(&p, 777).unwrap(); // odd buffer size
        let mut off = 0usize;
        let mut rng = crate::util::rng::Rng::new(5);
        let mut buf = [0u8; 3];
        while off + 10 < data.len() {
            if rng.chance(0.5) {
                r.read_exact(&mut buf).unwrap();
                assert_eq!(buf[..], data[off..off + 3]);
                off += 3;
            } else {
                let k = rng.below(2000) as usize;
                let k = k.min(data.len() - off - 4);
                r.skip_bytes(k as u64).unwrap();
                off += k;
            }
            assert_eq!(r.offset(), off as u64);
        }
        std::fs::remove_file(p).unwrap();
    }
}
