//! Disk-stream substrate (§3 of the paper).
//!
//! * [`reader::StreamReader`] — buffered sequential reads with the paper's
//!   `skip(num_items)` (§3.2): skipping within the 64 KB buffer costs
//!   nothing; a longer skip costs exactly one random read.  This is what
//!   makes sparse computation workloads cheap.
//! * [`writer::StreamWriter`] — buffered sequential appends.
//! * [`splittable::SplittableStream`] — an OMS (§3.3.1): a long stream
//!   broken into ≤ℬ-byte files so the sender can ship fully-written files
//!   from the head while computation appends at the tail.
//! * [`merge`] — k-way external merge-sort (k = 1000) used to combine OMS
//!   files before sending and to build the sorted IMS (§3.3.1–3.3.2).
//!   The same sorted-run format backs the local spill lane's `lsp_*`
//!   files (`dst == me` traffic in the sorted-`S^I` modes), which U_r
//!   feeds into the `S^I` merge alongside the remote spills.

pub mod merge;
pub mod reader;
pub mod splittable;
pub mod writer;

pub use reader::StreamReader;
pub use splittable::SplittableStream;
pub use writer::StreamWriter;

/// Paper default in-memory stream buffer `b` = 64 KB.
pub const DEFAULT_BUF: usize = 64 * 1024;

/// Paper default OMS file cap `ℬ` = 8 MB.
pub const DEFAULT_FILE_CAP: usize = 8 * 1024 * 1024;

/// Paper default merge-sort fan-in `k` = 1000.
pub const DEFAULT_MERGE_K: usize = 1000;
