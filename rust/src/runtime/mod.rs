//! Runtime bridge: load AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the PJRT CPU client via the
//! `xla` crate.
//!
//! The recoded-mode hot path calls [`KernelSet`] for block vertex updates
//! (PageRank, min-relax).  Every kernel also has a scalar Rust fallback
//! with bit-identical semantics — used when artifacts are absent, by the
//! `use_xla=false` ablation, and as a correctness oracle in tests.
//!
//! The PJRT path is behind the `xla` cargo feature.  The feature compiles
//! everywhere — offline builds link the compile-only stubs under
//! `rust/vendor/` (CI's `cargo check --features xla` keeps this bridge
//! from rotting), and loading an artifact against the stubs fails with a
//! typed [`crate::error::Error::Xla`]; executing for real requires the
//! actual `xla`/`anyhow` crates plus a PJRT plugin (see README.md).
//! Without the feature [`KernelSet::load`] yields an empty
//! set and every update runs on the scalar path — numerics are identical,
//! so callers and tests need no gating.
//!
//! Artifacts operate on fixed [`BLOCK`]-sized arrays; inputs are padded and
//! outputs truncated here, so callers never see the block size.

use crate::error::Result;
use std::path::{Path, PathBuf};

/// Block size baked into the AOT artifacts (mirrors python `kernels.BLOCK`).
pub const BLOCK: usize = 65536;

#[cfg(feature = "xla")]
pub use pjrt::HloExecutable;

/// Artifact files a [`KernelSet`] looks for.
pub const ARTIFACT_NAMES: [&str; 3] = ["pagerank_update", "minrelax_f32", "minrelax_i32"];

/// Does `dir` contain at least one AOT artifact?  A pure file check, usable
/// regardless of whether the PJRT runtime is compiled in — the session's
/// `Mode::Auto`/`Xla::Auto` detection relies on it.
pub fn artifacts_present(dir: &Path) -> bool {
    ARTIFACT_NAMES
        .iter()
        .any(|n| dir.join(format!("{n}.hlo.txt")).exists())
}

/// Is the PJRT execution path compiled into this binary?
pub const fn xla_runtime_available() -> bool {
    cfg!(feature = "xla")
}

/// The loaded kernel set used by the engine's block updates.
pub struct KernelSet {
    #[cfg(feature = "xla")]
    pagerank: Option<pjrt::HloExecutable>,
    #[cfg(feature = "xla")]
    minrelax_f32: Option<pjrt::HloExecutable>,
    #[cfg(feature = "xla")]
    minrelax_i32: Option<pjrt::HloExecutable>,
    /// Force the scalar fallback even when artifacts are loaded.
    pub force_native: bool,
}

impl KernelSet {
    /// Load all artifacts from `dir`.  Missing files are tolerated (the
    /// corresponding kernel falls back to scalar Rust); a present-but-
    /// corrupt artifact is an error.  Without the `xla` feature this always
    /// yields an empty (scalar-only) set.
    pub fn load(dir: &Path) -> Result<Self> {
        #[cfg(feature = "xla")]
        {
            let load_one = |name: &str| -> Result<Option<pjrt::HloExecutable>> {
                let p: PathBuf = dir.join(format!("{name}.hlo.txt"));
                if !p.exists() {
                    return Ok(None);
                }
                pjrt::HloExecutable::load(p.to_str().unwrap())
                    .map(Some)
                    .map_err(pjrt::xla_err)
            };
            Ok(Self {
                pagerank: load_one("pagerank_update")?,
                minrelax_f32: load_one("minrelax_f32")?,
                minrelax_i32: load_one("minrelax_i32")?,
                force_native: false,
            })
        }
        #[cfg(not(feature = "xla"))]
        {
            let _ = dir;
            Ok(Self { force_native: false })
        }
    }

    /// A kernel set with no artifacts: everything runs on the scalar path.
    pub fn native_only() -> Self {
        Self {
            #[cfg(feature = "xla")]
            pagerank: None,
            #[cfg(feature = "xla")]
            minrelax_f32: None,
            #[cfg(feature = "xla")]
            minrelax_i32: None,
            force_native: true,
        }
    }

    /// Default artifacts directory (repo `artifacts/`, or `$GRAPHD_ARTIFACTS`).
    pub fn default_dir() -> PathBuf {
        std::env::var("GRAPHD_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    pub fn has_xla(&self) -> bool {
        #[cfg(feature = "xla")]
        {
            !self.force_native
                && (self.pagerank.is_some()
                    || self.minrelax_f32.is_some()
                    || self.minrelax_i32.is_some())
        }
        #[cfg(not(feature = "xla"))]
        {
            false
        }
    }

    /// PageRank block update over `sums`/`deg` (combined message sums and
    /// out-degrees): returns `(val, msg)` per vertex.
    pub fn pagerank_update(
        &self,
        sums: &[f32],
        deg: &[f32],
        inv_n: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        debug_assert_eq!(sums.len(), deg.len());
        #[cfg(feature = "xla")]
        if let (Some(exe), false) = (&self.pagerank, self.force_native) {
            return pjrt::pagerank_blocks(exe, sums, deg, inv_n);
        }
        // Scalar fallback: the exact formulas of kernels/pagerank.py.
        let mut val = Vec::with_capacity(sums.len());
        let mut msg = Vec::with_capacity(sums.len());
        for i in 0..sums.len() {
            let v = 0.15 * inv_n + 0.85 * sums[i];
            val.push(v);
            msg.push(if deg[i] > 0.0 { v / deg[i].max(1.0) } else { 0.0 });
        }
        Ok((val, msg))
    }

    /// f32 min-relax block update: `(new, changed)` per vertex.
    pub fn minrelax_f32(&self, cur: &[f32], msg: &[f32]) -> Result<(Vec<f32>, Vec<i32>)> {
        debug_assert_eq!(cur.len(), msg.len());
        #[cfg(feature = "xla")]
        if let (Some(exe), false) = (&self.minrelax_f32, self.force_native) {
            return pjrt::run_minrelax_blocks(exe, cur, msg, f32::INFINITY);
        }
        Ok(native_minrelax(cur, msg))
    }

    /// i32 min-relax block update: `(new, changed)` per vertex.
    pub fn minrelax_i32(&self, cur: &[i32], msg: &[i32]) -> Result<(Vec<i32>, Vec<i32>)> {
        debug_assert_eq!(cur.len(), msg.len());
        #[cfg(feature = "xla")]
        if let (Some(exe), false) = (&self.minrelax_i32, self.force_native) {
            return pjrt::run_minrelax_blocks(exe, cur, msg, i32::MAX);
        }
        Ok(native_minrelax(cur, msg))
    }
}

fn native_minrelax<T: PartialOrd + Copy>(cur: &[T], msg: &[T]) -> (Vec<T>, Vec<i32>) {
    let mut new = Vec::with_capacity(cur.len());
    let mut chg = Vec::with_capacity(cur.len());
    for i in 0..cur.len() {
        let n = if msg[i] < cur[i] { msg[i] } else { cur[i] };
        chg.push((msg[i] < cur[i]) as i32);
        new.push(n);
    }
    (new, chg)
}

/// PJRT execution of the HLO-text artifacts (needs the `xla` crate).
#[cfg(feature = "xla")]
mod pjrt {
    use super::BLOCK;
    use crate::error::{Error, Result};

    /// One compiled HLO artifact.
    pub struct HloExecutable {
        #[allow(dead_code)]
        client: xla::PjRtClient,
        exe: xla::PjRtLoadedExecutable,
    }

    impl HloExecutable {
        /// Load `path` (HLO text emitted by jax lowering) and compile it on
        /// a CPU PJRT client.
        pub fn load(path: &str) -> anyhow::Result<Self> {
            let client = xla::PjRtClient::cpu()?;
            let proto = xla::HloModuleProto::from_text_file(path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            Ok(Self { client, exe })
        }

        /// Execute with literal inputs; artifacts are lowered with
        /// `return_tuple=True`, so the result is always a tuple literal.
        pub fn run(&self, args: &[xla::Literal]) -> anyhow::Result<xla::Literal> {
            let out = self.exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
            Ok(out)
        }
    }

    pub fn xla_err(e: anyhow::Error) -> Error {
        Error::Xla(format!("{e:#}"))
    }

    /// Pad/execute/truncate the pagerank artifact over arbitrary lengths.
    pub fn pagerank_blocks(
        exe: &HloExecutable,
        sums: &[f32],
        deg: &[f32],
        inv_n: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let n = sums.len();
        let mut val = Vec::with_capacity(n);
        let mut msg = Vec::with_capacity(n);
        let mut sums_blk = vec![0f32; BLOCK];
        let mut deg_blk = vec![0f32; BLOCK];
        for start in (0..n).step_by(BLOCK) {
            let len = (n - start).min(BLOCK);
            sums_blk[..len].copy_from_slice(&sums[start..start + len]);
            sums_blk[len..].fill(0.0);
            deg_blk[..len].copy_from_slice(&deg[start..start + len]);
            deg_blk[len..].fill(0.0);
            let args = [
                xla::Literal::vec1(&sums_blk),
                xla::Literal::vec1(&deg_blk),
                xla::Literal::vec1(&[inv_n]),
            ];
            let out = exe.run(&args).map_err(xla_err)?;
            let parts = out.to_tuple().map_err(|e| xla_err(e.into()))?;
            let v = parts[0].to_vec::<f32>().map_err(|e| xla_err(e.into()))?;
            let m = parts[1].to_vec::<f32>().map_err(|e| xla_err(e.into()))?;
            val.extend_from_slice(&v[..len]);
            msg.extend_from_slice(&m[..len]);
        }
        Ok((val, msg))
    }

    /// Pad/execute/truncate a minrelax artifact over arbitrary lengths.
    pub fn run_minrelax_blocks<T>(
        exe: &HloExecutable,
        cur: &[T],
        msg: &[T],
        pad: T,
    ) -> Result<(Vec<T>, Vec<i32>)>
    where
        T: xla::NativeType + xla::ArrayElement + Copy,
    {
        let n = cur.len();
        let mut new = Vec::with_capacity(n);
        let mut chg = Vec::with_capacity(n);
        let mut cur_blk = vec![pad; BLOCK];
        let mut msg_blk = vec![pad; BLOCK];
        for start in (0..n).step_by(BLOCK) {
            let len = (n - start).min(BLOCK);
            cur_blk[..len].copy_from_slice(&cur[start..start + len]);
            cur_blk[len..].fill(pad);
            msg_blk[..len].copy_from_slice(&msg[start..start + len]);
            msg_blk[len..].fill(pad);
            let args = [xla::Literal::vec1(&cur_blk), xla::Literal::vec1(&msg_blk)];
            let out = exe.run(&args).map_err(xla_err)?;
            let parts = out.to_tuple().map_err(|e| xla_err(e.into()))?;
            let nv = parts[0].to_vec::<T>().map_err(|e| xla_err(e.into()))?;
            let cv = parts[1].to_vec::<i32>().map_err(|e| xla_err(e.into()))?;
            new.extend_from_slice(&nv[..len]);
            chg.extend_from_slice(&cv[..len]);
        }
        Ok((new, chg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_pagerank_formula() {
        let ks = KernelSet::native_only();
        let (val, msg) = ks
            .pagerank_update(&[0.0, 1.0, 0.5], &[2.0, 0.0, 5.0], 0.01)
            .unwrap();
        assert!((val[0] - 0.0015).abs() < 1e-7);
        assert!((val[1] - 0.8515).abs() < 1e-7);
        assert_eq!(msg[1], 0.0); // sink
        assert!((msg[2] - val[2] / 5.0).abs() < 1e-7);
    }

    #[test]
    fn native_minrelax_semantics() {
        let ks = KernelSet::native_only();
        let (new, chg) = ks
            .minrelax_f32(&[3.0, 1.0, f32::INFINITY], &[2.0, f32::INFINITY, 7.0])
            .unwrap();
        assert_eq!(new, vec![2.0, 1.0, 7.0]);
        assert_eq!(chg, vec![1, 0, 1]);
        let (ni, ci) = ks.minrelax_i32(&[5, 5], &[i32::MAX, 4]).unwrap();
        assert_eq!(ni, vec![5, 4]);
        assert_eq!(ci, vec![0, 1]);
    }

    #[test]
    fn artifacts_present_is_a_pure_file_check() {
        let d = std::env::temp_dir().join(format!(
            "graphd_artifacts_probe_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        assert!(!artifacts_present(&d));
        std::fs::write(d.join("pagerank_update.hlo.txt"), "hlo").unwrap();
        assert!(artifacts_present(&d));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn xla_matches_native_when_artifacts_present() {
        let dir = KernelSet::default_dir();
        if !dir.join("pagerank_update.hlo.txt").exists() {
            eprintln!("no artifacts; skipping parity test");
            return;
        }
        let xla_ks = KernelSet::load(&dir).unwrap();
        let nat = KernelSet::native_only();
        // Non-multiple-of-BLOCK length exercises padding.
        let n = BLOCK + 777;
        let sums: Vec<f32> = (0..n).map(|i| (i % 89) as f32 / 89.0).collect();
        let deg: Vec<f32> = (0..n).map(|i| (i % 6) as f32).collect();
        let (v1, m1) = xla_ks.pagerank_update(&sums, &deg, 1e-5).unwrap();
        let (v2, m2) = nat.pagerank_update(&sums, &deg, 1e-5).unwrap();
        for i in 0..n {
            assert!((v1[i] - v2[i]).abs() < 1e-6, "val[{i}]");
            assert!((m1[i] - m2[i]).abs() < 1e-6, "msg[{i}]");
        }

        let cur: Vec<f32> = (0..n).map(|i| (i % 103) as f32).collect();
        let msg: Vec<f32> = (0..n)
            .map(|i| if i % 3 == 0 { f32::INFINITY } else { (i % 47) as f32 })
            .collect();
        let a = xla_ks.minrelax_f32(&cur, &msg).unwrap();
        let b = nat.minrelax_f32(&cur, &msg).unwrap();
        assert_eq!(a, b);
    }
}
