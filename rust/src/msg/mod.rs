//! Fixed-size codecs for vertex values and messages.
//!
//! The paper assumes constant-size vertex-ID / value / adjacency / message
//! types (§3.1) — so do we: every message on a stream or wire is
//! `4 bytes target-id (LE u32) + Codec::SIZE bytes payload`, which lets the
//! merge-sort and the in-memory A_r/A_s paths index records directly.

/// A fixed-size binary-encodable value.
pub trait Codec: Sized + Copy + Send + Sync + 'static {
    const SIZE: usize;
    fn encode(&self, out: &mut [u8]);
    fn decode(buf: &[u8]) -> Self;
}

impl Codec for u32 {
    const SIZE: usize = 4;
    fn encode(&self, out: &mut [u8]) {
        out[..4].copy_from_slice(&self.to_le_bytes());
    }
    fn decode(buf: &[u8]) -> Self {
        u32::from_le_bytes(buf[..4].try_into().unwrap())
    }
}

impl Codec for i32 {
    const SIZE: usize = 4;
    fn encode(&self, out: &mut [u8]) {
        out[..4].copy_from_slice(&self.to_le_bytes());
    }
    fn decode(buf: &[u8]) -> Self {
        i32::from_le_bytes(buf[..4].try_into().unwrap())
    }
}

impl Codec for u64 {
    const SIZE: usize = 8;
    fn encode(&self, out: &mut [u8]) {
        out[..8].copy_from_slice(&self.to_le_bytes());
    }
    fn decode(buf: &[u8]) -> Self {
        u64::from_le_bytes(buf[..8].try_into().unwrap())
    }
}

impl Codec for f32 {
    const SIZE: usize = 4;
    fn encode(&self, out: &mut [u8]) {
        out[..4].copy_from_slice(&self.to_le_bytes());
    }
    fn decode(buf: &[u8]) -> Self {
        f32::from_le_bytes(buf[..4].try_into().unwrap())
    }
}

impl Codec for f64 {
    const SIZE: usize = 8;
    fn encode(&self, out: &mut [u8]) {
        out[..8].copy_from_slice(&self.to_le_bytes());
    }
    fn decode(buf: &[u8]) -> Self {
        f64::from_le_bytes(buf[..8].try_into().unwrap())
    }
}

impl Codec for () {
    const SIZE: usize = 0;
    fn encode(&self, _out: &mut [u8]) {}
    fn decode(_buf: &[u8]) -> Self {}
}

/// K-lane f32 records (the serve subsystem's batched traversals): lane
/// values concatenated LE, still a constant-size record per §3.1.
impl<const K: usize> Codec for [f32; K] {
    const SIZE: usize = 4 * K;
    fn encode(&self, out: &mut [u8]) {
        for (i, x) in self.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&x.to_le_bytes());
        }
    }
    fn decode(buf: &[u8]) -> Self {
        let mut a = [0.0f32; K];
        for (i, x) in a.iter_mut().enumerate() {
            *x = f32::from_le_bytes(buf[4 * i..4 * i + 4].try_into().unwrap());
        }
        a
    }
}

impl<A: Codec, B: Codec> Codec for (A, B) {
    const SIZE: usize = A::SIZE + B::SIZE;
    fn encode(&self, out: &mut [u8]) {
        self.0.encode(&mut out[..A::SIZE]);
        self.1.encode(&mut out[A::SIZE..]);
    }
    fn decode(buf: &[u8]) -> Self {
        (A::decode(&buf[..A::SIZE]), B::decode(&buf[A::SIZE..]))
    }
}

/// Encode one on-wire/on-disk message record: `target | payload`.
#[inline]
pub fn encode_msg<M: Codec>(target: u32, msg: &M, out: &mut Vec<u8>) {
    let start = out.len();
    out.resize(start + 4 + M::SIZE, 0);
    out[start..start + 4].copy_from_slice(&target.to_le_bytes());
    msg.encode(&mut out[start + 4..]);
}

/// Size of a message record for payload type `M`.
#[inline]
pub const fn msg_rec_size<M: Codec>() -> usize {
    4 + M::SIZE
}

/// Decode the target id of a message record.
#[inline]
pub fn rec_target(rec: &[u8]) -> u32 {
    u32::from_le_bytes(rec[..4].try_into().unwrap())
}

/// Decode the payload of a message record.
#[inline]
pub fn rec_payload<M: Codec>(rec: &[u8]) -> M {
    M::decode(&rec[4..])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Codec + PartialEq + std::fmt::Debug>(v: T) {
        let mut buf = vec![0u8; T::SIZE];
        v.encode(&mut buf);
        assert_eq!(T::decode(&buf), v);
    }

    #[test]
    fn scalar_roundtrips() {
        roundtrip(42u32);
        roundtrip(-7i32);
        roundtrip(1u64 << 40);
        roundtrip(3.25f32);
        roundtrip(-2.5e300f64);
        roundtrip(());
        roundtrip((17u32, 2.5f32));
    }

    #[test]
    fn lane_array_roundtrips() {
        roundtrip([1.5f32, f32::INFINITY, -0.25, 4096.0]);
        roundtrip([0.0f32; 8]);
        assert_eq!(<[f32; 8]>::SIZE, 32);
        assert_eq!(msg_rec_size::<[f32; 4]>(), 20);
    }

    #[test]
    fn msg_record_layout() {
        let mut buf = Vec::new();
        encode_msg(9u32, &1.5f32, &mut buf);
        assert_eq!(buf.len(), msg_rec_size::<f32>());
        assert_eq!(rec_target(&buf), 9);
        assert_eq!(rec_payload::<f32>(&buf), 1.5);
    }

    #[test]
    fn pair_layout_is_concatenation() {
        let mut buf = vec![0u8; 8];
        (0xAABBCCDDu32, 1.0f32).encode(&mut buf);
        assert_eq!(u32::from_le_bytes(buf[..4].try_into().unwrap()), 0xAABBCCDD);
        assert_eq!(f32::from_le_bytes(buf[4..].try_into().unwrap()), 1.0);
    }
}
