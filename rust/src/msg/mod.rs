//! Fixed-size codecs for vertex values and messages, plus the two shared
//! pools behind the zero-copy message spine: the byte-buffer pool
//! ([`BufPool`]) and the typed digest-array pool ([`DigestPool`]).
//!
//! The paper assumes constant-size vertex-ID / value / adjacency / message
//! types (§3.1) — so do we: every message on a stream or wire is
//! `4 bytes target-id (LE u32) + Codec::SIZE bytes payload`, which lets the
//! merge-sort and the in-memory A_r/A_s paths index records directly.
//!
//! See `DESIGN.md` (repo root) for where each pool sits on the spine.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Checkout/recycle pool of `Vec<u8>` blocks — the allocation spine of the
/// message path.  One pool is shared by a whole job: U_c's outbox batches,
/// U_s's OMS file reads and combined send batches, `Payload::Data` blocks
/// on the (simulated) wire, OMS/stream writer buffers, and U_r's
/// spill/digest buffers all check blocks out and recycle them, so the
/// steady state allocates nothing per batch.  Buffers keep their grown
/// capacity across checkouts, which is what retires the alloc-per-batch
/// pattern: after warm-up every checkout is a pool hit.
pub struct BufPool {
    shelf: Mutex<Vec<Vec<u8>>>,
    /// Maximum buffers retained; overflow is dropped (freed) on `put`.
    max_retained: usize,
    /// Buffers whose capacity exceeds this are freed instead of shelved,
    /// bounding the pool's resident memory at
    /// `max_retained × max_buf_bytes` (outsized one-off batches must not
    /// pin their capacity for the whole job).
    max_buf_bytes: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Pool counters (`hits` = checkouts served from the shelf).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Checkouts served from the shelf (no allocation).
    pub hits: u64,
    /// Checkouts that had to allocate a fresh buffer.
    pub misses: u64,
}

impl PoolStats {
    /// Fraction of checkouts served without an allocation.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Default per-buffer retention cap: 2× the paper's ℬ (an OMS file plus
/// slack), so file-read and wire-batch buffers recycle but a pathological
/// batch doesn't pin its capacity.
pub const DEFAULT_MAX_BUF_BYTES: usize = 16 * 1024 * 1024;

impl BufPool {
    /// A pool retaining at most `max_retained` buffers of at most
    /// [`DEFAULT_MAX_BUF_BYTES`] capacity each.
    pub fn new(max_retained: usize) -> Arc<Self> {
        Self::bounded(max_retained, DEFAULT_MAX_BUF_BYTES)
    }

    /// A pool with an explicit per-buffer capacity retention cap.
    pub fn bounded(max_retained: usize, max_buf_bytes: usize) -> Arc<Self> {
        Arc::new(Self {
            shelf: Mutex::new(Vec::with_capacity(max_retained.min(64))),
            max_retained,
            max_buf_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        })
    }

    /// Check out an empty buffer (recycled capacity when available).
    pub fn take(&self) -> Vec<u8> {
        match self.shelf.lock().unwrap().pop() {
            Some(buf) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        }
    }

    /// Check out an empty buffer with at least `cap` bytes of capacity.
    pub fn take_with_capacity(&self, cap: usize) -> Vec<u8> {
        let mut buf = self.take();
        buf.reserve(cap);
        buf
    }

    /// Recycle a buffer (cleared; capacity kept).  Buffers beyond the
    /// retention caps (count or per-buffer capacity) are dropped instead
    /// of shelved.
    pub fn put(&self, mut buf: Vec<u8>) {
        if buf.capacity() == 0 || buf.capacity() > self.max_buf_bytes {
            return;
        }
        buf.clear();
        let mut shelf = self.shelf.lock().unwrap();
        if shelf.len() < self.max_retained {
            shelf.push(buf);
        }
    }

    /// Buffers currently shelved.
    pub fn idle(&self) -> usize {
        self.shelf.lock().unwrap().len()
    }

    /// Hit/miss counters since the pool was created.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

/// Checkout/recycle pool of typed digest arrays (`Vec<M>`) — the ping-pong
/// shards behind recoded digesting.  Each superstep needs `O(|V|/n)`-sized
/// message arrays: U_r's `A_r`, and (with the local fast path) U_c's
/// [`crate::worker::units::LocalDigest`] shard.  Both travel between units
/// inside [`crate::worker::units::Incoming::Digested`] /
/// `LocalDigest` and are recycled here once consumed, so after the first
/// two supersteps the arrays ping-pong between U_c and U_r instead of
/// being reallocated per step.
///
/// `take` hands out an array of exactly `len` elements, every slot reset
/// to the caller's `fill` value (the combiner identity `e0`, §5) — the
/// reset is required because the XLA block-update kernels read *all*
/// positions of `A_r`, not only the touched ones.
pub struct DigestPool<M> {
    shelf: Mutex<Vec<Vec<M>>>,
    /// Maximum arrays retained; overflow is dropped (freed) on `put`.
    max_retained: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<M: Copy + Send + 'static> DigestPool<M> {
    /// A pool retaining at most `max_retained` arrays.
    pub fn new(max_retained: usize) -> Arc<Self> {
        Arc::new(Self {
            shelf: Mutex::new(Vec::with_capacity(max_retained.min(64))),
            max_retained,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        })
    }

    /// Check out an array of `len` elements, all equal to `fill`
    /// (recycled capacity when available).
    pub fn take(&self, len: usize, fill: M) -> Vec<M> {
        match self.shelf.lock().unwrap().pop() {
            Some(mut v) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                v.clear();
                v.resize(len, fill);
                v
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                vec![fill; len]
            }
        }
    }

    /// Recycle an array (its length is irrelevant; `take` resizes).
    /// Zero-capacity arrays and overflow beyond the retention cap are
    /// dropped instead of shelved.
    pub fn put(&self, v: Vec<M>) {
        if v.capacity() == 0 {
            return;
        }
        let mut shelf = self.shelf.lock().unwrap();
        if shelf.len() < self.max_retained {
            shelf.push(v);
        }
    }

    /// Arrays currently shelved.
    pub fn idle(&self) -> usize {
        self.shelf.lock().unwrap().len()
    }

    /// Hit/miss counters since the pool was created.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

/// A fixed-size binary-encodable value.
pub trait Codec: Sized + Copy + Send + Sync + 'static {
    /// Encoded size in bytes (a compile-time constant, §3.1).
    const SIZE: usize;
    /// Write the value into `out[..Self::SIZE]` (little-endian).
    fn encode(&self, out: &mut [u8]);
    /// Read a value back from `buf[..Self::SIZE]`.
    fn decode(buf: &[u8]) -> Self;
}

impl Codec for u32 {
    const SIZE: usize = 4;
    fn encode(&self, out: &mut [u8]) {
        out[..4].copy_from_slice(&self.to_le_bytes());
    }
    fn decode(buf: &[u8]) -> Self {
        u32::from_le_bytes(buf[..4].try_into().unwrap())
    }
}

impl Codec for i32 {
    const SIZE: usize = 4;
    fn encode(&self, out: &mut [u8]) {
        out[..4].copy_from_slice(&self.to_le_bytes());
    }
    fn decode(buf: &[u8]) -> Self {
        i32::from_le_bytes(buf[..4].try_into().unwrap())
    }
}

impl Codec for u64 {
    const SIZE: usize = 8;
    fn encode(&self, out: &mut [u8]) {
        out[..8].copy_from_slice(&self.to_le_bytes());
    }
    fn decode(buf: &[u8]) -> Self {
        u64::from_le_bytes(buf[..8].try_into().unwrap())
    }
}

impl Codec for f32 {
    const SIZE: usize = 4;
    fn encode(&self, out: &mut [u8]) {
        out[..4].copy_from_slice(&self.to_le_bytes());
    }
    fn decode(buf: &[u8]) -> Self {
        f32::from_le_bytes(buf[..4].try_into().unwrap())
    }
}

impl Codec for f64 {
    const SIZE: usize = 8;
    fn encode(&self, out: &mut [u8]) {
        out[..8].copy_from_slice(&self.to_le_bytes());
    }
    fn decode(buf: &[u8]) -> Self {
        f64::from_le_bytes(buf[..8].try_into().unwrap())
    }
}

impl Codec for () {
    const SIZE: usize = 0;
    fn encode(&self, _out: &mut [u8]) {}
    fn decode(_buf: &[u8]) -> Self {}
}

/// K-lane f32 records (the serve subsystem's batched traversals): lane
/// values concatenated LE, still a constant-size record per §3.1.
impl<const K: usize> Codec for [f32; K] {
    const SIZE: usize = 4 * K;
    fn encode(&self, out: &mut [u8]) {
        for (i, x) in self.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&x.to_le_bytes());
        }
    }
    fn decode(buf: &[u8]) -> Self {
        let mut a = [0.0f32; K];
        for (i, x) in a.iter_mut().enumerate() {
            *x = f32::from_le_bytes(buf[4 * i..4 * i + 4].try_into().unwrap());
        }
        a
    }
}

impl<A: Codec, B: Codec> Codec for (A, B) {
    const SIZE: usize = A::SIZE + B::SIZE;
    fn encode(&self, out: &mut [u8]) {
        self.0.encode(&mut out[..A::SIZE]);
        self.1.encode(&mut out[A::SIZE..]);
    }
    fn decode(buf: &[u8]) -> Self {
        (A::decode(&buf[..A::SIZE]), B::decode(&buf[A::SIZE..]))
    }
}

/// Encode one on-wire/on-disk message record: `target | payload`.
#[inline]
pub fn encode_msg<M: Codec>(target: u32, msg: &M, out: &mut Vec<u8>) {
    let start = out.len();
    out.resize(start + 4 + M::SIZE, 0);
    out[start..start + 4].copy_from_slice(&target.to_le_bytes());
    msg.encode(&mut out[start + 4..]);
}

/// Size of a message record for payload type `M`.
#[inline]
pub const fn msg_rec_size<M: Codec>() -> usize {
    4 + M::SIZE
}

/// Decode the target id of a message record.
#[inline]
pub fn rec_target(rec: &[u8]) -> u32 {
    u32::from_le_bytes(rec[..4].try_into().unwrap())
}

/// Decode the payload of a message record.
#[inline]
pub fn rec_payload<M: Codec>(rec: &[u8]) -> M {
    M::decode(&rec[4..])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Codec + PartialEq + std::fmt::Debug>(v: T) {
        let mut buf = vec![0u8; T::SIZE];
        v.encode(&mut buf);
        assert_eq!(T::decode(&buf), v);
    }

    #[test]
    fn scalar_roundtrips() {
        roundtrip(42u32);
        roundtrip(-7i32);
        roundtrip(1u64 << 40);
        roundtrip(3.25f32);
        roundtrip(-2.5e300f64);
        roundtrip(());
        roundtrip((17u32, 2.5f32));
    }

    #[test]
    fn lane_array_roundtrips() {
        roundtrip([1.5f32, f32::INFINITY, -0.25, 4096.0]);
        roundtrip([0.0f32; 8]);
        assert_eq!(<[f32; 8]>::SIZE, 32);
        assert_eq!(msg_rec_size::<[f32; 4]>(), 20);
    }

    #[test]
    fn msg_record_layout() {
        let mut buf = Vec::new();
        encode_msg(9u32, &1.5f32, &mut buf);
        assert_eq!(buf.len(), msg_rec_size::<f32>());
        assert_eq!(rec_target(&buf), 9);
        assert_eq!(rec_payload::<f32>(&buf), 1.5);
    }

    #[test]
    fn buf_pool_recycles_and_counts() {
        let pool = BufPool::new(2);
        let a = pool.take(); // miss (empty pool)
        assert_eq!(pool.stats(), PoolStats { hits: 0, misses: 1 });
        assert!(a.is_empty());
        let mut b = pool.take_with_capacity(100); // miss
        b.extend_from_slice(&[1, 2, 3]);
        pool.put(b);
        assert_eq!(pool.idle(), 1);
        let c = pool.take(); // hit, cleared, capacity kept
        assert!(c.is_empty());
        assert!(c.capacity() >= 100);
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (1, 2));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn buf_pool_respects_retention_cap_and_drops_empty() {
        let pool = BufPool::new(1);
        pool.put(Vec::with_capacity(8));
        pool.put(Vec::with_capacity(8)); // beyond count cap: dropped
        assert_eq!(pool.idle(), 1);
        pool.put(Vec::new()); // zero-capacity: not worth shelving
        assert_eq!(pool.idle(), 1);
        assert_eq!(PoolStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn buf_pool_drops_oversized_buffers() {
        let pool = BufPool::bounded(4, 64);
        pool.put(Vec::with_capacity(32)); // within the byte cap: shelved
        pool.put(Vec::with_capacity(1024)); // oversized: freed, not pinned
        assert_eq!(pool.idle(), 1);
        assert!(pool.take().capacity() < 1024);
    }

    #[test]
    fn digest_pool_recycles_and_resets() {
        let pool: Arc<DigestPool<f32>> = DigestPool::new(2);
        let mut a = pool.take(4, f32::INFINITY); // miss
        assert_eq!(a, vec![f32::INFINITY; 4]);
        a[2] = 1.5; // dirty it, then recycle
        pool.put(a);
        assert_eq!(pool.idle(), 1);
        // Hit: different length, every slot reset to the new fill.
        let b = pool.take(6, 0.0f32);
        assert_eq!(b, vec![0.0; 6]);
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        // Retention cap + zero-capacity drop mirror BufPool.
        pool.put(Vec::with_capacity(1));
        pool.put(Vec::with_capacity(1));
        pool.put(Vec::with_capacity(1)); // beyond cap: dropped
        assert_eq!(pool.idle(), 2);
        pool.put(Vec::new());
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn pair_layout_is_concatenation() {
        let mut buf = vec![0u8; 8];
        (0xAABBCCDDu32, 1.0f32).encode(&mut buf);
        assert_eq!(u32::from_le_bytes(buf[..4].try_into().unwrap()), 0xAABBCCDD);
        assert_eq!(f32::from_le_bytes(buf[4..].try_into().unwrap()), 1.0);
    }
}
