//! The vertex-centric programming API (Pregel semantics, §2.1).
//!
//! A [`VertexProgram`] specifies the behaviour of one generic vertex:
//! `compute(msgs)` may update the vertex value, send messages, and vote to
//! halt.  An optional [`Combiner`] declares how messages to the same target
//! fold together (enabling IO-Basic's pre-send combining and the entire
//! recoded mode, §5).  An optional aggregator (the `Agg` associated type +
//! `merge_agg`) provides Pregel's global communication.
//!
//! Programs may additionally implement [`VertexProgram::block_update`]: a
//! vectorized whole-block form of `compute` used on the recoded-mode hot
//! path, where it runs on the AOT-compiled XLA kernels (see
//! [`crate::runtime::KernelSet`]).  The per-vertex `compute` remains the
//! semantic ground truth; tests assert both paths agree.

use crate::msg::Codec;
use crate::runtime::KernelSet;
use crate::util::bitset::BitSet;

/// One adjacency-list item as handed to `compute` (weight = 1.0 on
/// unweighted graphs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    /// Neighbor vertex ID (current ID space).
    pub nbr: u32,
    /// Edge weight (1.0 on unweighted graphs).
    pub weight: f32,
}

/// Message combiner (§2.1): fold messages targeted at the same vertex.
/// `identity()` is the paper's `e0` (§5): `combine(e0, m) == m`.
///
/// Combiners are **statically dispatched**: every hot loop of the engine
/// (the `A_s`/`A_r` digest loops, pre-send merge-sort combining, the local
/// delivery fast path) is monomorphized over a `C: Combiner<M>`, so
/// `combine` compiles to straight-line code — no virtual call per record.
/// Programs without a combiner use [`NoCombiner`] (`ENABLED = false`),
/// which lets the compiler drop the combining branches entirely.
pub trait Combiner<M: Codec>: Send + Sync + Default + 'static {
    /// `false` only for [`NoCombiner`]; a compile-time constant so the
    /// monomorphized engine code can eliminate dead combining paths.
    const ENABLED: bool = true;
    /// Fold `m` into the accumulator `acc`.
    fn combine(&self, acc: &mut M, m: &M);
    /// The fold identity `e0`: `combine(e0, m) == m`.
    fn identity(&self) -> M;
}

/// The absent-combiner slot for programs that do not combine.  Its methods
/// are never called: engine paths are guarded by [`Combiner::ENABLED`].
#[derive(Clone, Copy, Debug, Default)]
pub struct NoCombiner;
impl<M: Codec> Combiner<M> for NoCombiner {
    const ENABLED: bool = false;
    fn combine(&self, _acc: &mut M, _m: &M) {}
    fn identity(&self) -> M {
        unreachable!("NoCombiner::identity — combining path taken without a combiner")
    }
}

/// Sum combiner for f32 messages (PageRank).
#[derive(Clone, Copy, Debug, Default)]
pub struct SumF32;
impl Combiner<f32> for SumF32 {
    #[inline(always)]
    fn combine(&self, acc: &mut f32, m: &f32) {
        *acc += *m;
    }
    #[inline(always)]
    fn identity(&self) -> f32 {
        0.0
    }
}

/// Min combiner for f32 messages (SSSP).
#[derive(Clone, Copy, Debug, Default)]
pub struct MinF32;
impl Combiner<f32> for MinF32 {
    #[inline(always)]
    fn combine(&self, acc: &mut f32, m: &f32) {
        if *m < *acc {
            *acc = *m;
        }
    }
    #[inline(always)]
    fn identity(&self) -> f32 {
        f32::INFINITY
    }
}

/// Min combiner for i32 messages (Hash-Min labels).
#[derive(Clone, Copy, Debug, Default)]
pub struct MinI32;
impl Combiner<i32> for MinI32 {
    #[inline(always)]
    fn combine(&self, acc: &mut i32, m: &i32) {
        if *m < *acc {
            *acc = *m;
        }
    }
    #[inline(always)]
    fn identity(&self) -> i32 {
        i32::MAX
    }
}

/// Element-wise MIN combiner over K-lane f32 messages (k-lane batched
/// traversals, `crate::serve`).  Each lane folds independently, so one
/// combined record carries K queries' frontier data — this is what makes
/// the recoded in-memory `A_s`/`A_r` path (§5) apply unchanged to batches.
#[derive(Clone, Copy, Debug, Default)]
pub struct MinLanes<const K: usize>;
impl<const K: usize> Combiner<[f32; K]> for MinLanes<K> {
    /// Branch-free element-wise min over a fixed-width pair of lanes: the
    /// loop bound is the const generic K, so it fully unrolls (and
    /// auto-vectorizes) under monomorphization — one serve batch combine
    /// is a handful of SIMD min ops, not K dispatched calls.
    #[inline(always)]
    fn combine(&self, acc: &mut [f32; K], m: &[f32; K]) {
        for (a, b) in acc.iter_mut().zip(m.iter()) {
            *a = if *b < *a { *b } else { *a };
        }
    }
    #[inline(always)]
    fn identity(&self) -> [f32; K] {
        [f32::INFINITY; K]
    }
}

/// Context passed to `compute`: superstep info + message emission +
/// aggregation + halt control for the current vertex.
pub struct Context<'a, M: Codec, A> {
    /// Current superstep (0-based; the paper's Step 1 is superstep 0).
    pub superstep: u64,
    /// Total number of vertices |V|.
    pub num_vertices: u64,
    /// Global aggregate from the previous superstep.
    pub global_agg: &'a A,
    /// This machine's partial aggregate for the current superstep.
    pub local_agg: &'a mut A,
    pub(crate) send_fn: &'a mut dyn FnMut(u32, M),
    pub(crate) halt: bool,
    pub(crate) sent: u64,
}

impl<'a, M: Codec, A> Context<'a, M, A> {
    /// A context for one vertex of one superstep; `send_fn` receives every
    /// emitted `(target, msg)` pair.
    pub fn new(
        superstep: u64,
        num_vertices: u64,
        global_agg: &'a A,
        local_agg: &'a mut A,
        send_fn: &'a mut dyn FnMut(u32, M),
    ) -> Self {
        Self {
            superstep,
            num_vertices,
            global_agg,
            local_agg,
            send_fn,
            halt: false,
            sent: 0,
        }
    }

    /// Send `msg` to vertex `target` (delivered next superstep).
    #[inline]
    pub fn send(&mut self, target: u32, msg: M) {
        self.sent += 1;
        (self.send_fn)(target, msg);
    }

    /// Vote to halt: deactivate this vertex until a message reactivates it.
    #[inline]
    pub fn vote_to_halt(&mut self) {
        self.halt = true;
    }

    /// Reset per-vertex flags when a context is reused across vertices.
    pub fn reset_vertex(&mut self) {
        self.halt = false;
    }

    /// Messages emitted through this context so far.
    pub fn msgs_sent(&self) -> u64 {
        self.sent
    }
}

/// Whole-block context for the vectorized recoded-mode path.  Arrays are
/// indexed by position in the machine's state array `A`; `sums[p]` is the
/// combined incoming message (`identity` when none — the paper's
/// `A_r[pos] = e0` convention).
pub struct BlockCtx<'a, P: VertexProgram + ?Sized> {
    /// Current superstep (0-based).
    pub superstep: u64,
    /// Total number of vertices |V|.
    pub num_vertices: u64,
    /// The machine's vertex-value array `A`, indexed by position.
    pub vals: &'a mut [P::Value],
    /// Out-degrees, aligned with `vals`.
    pub degs: &'a [u32],
    /// The digested incoming-message array `A_r`.
    pub sums: &'a [P::Msg],
    /// Whether each vertex was halted coming into this superstep.
    pub halted: &'a mut BitSet,
    /// Out: message base per vertex (`Some` ⇒ fan out along Γ(v) via
    /// [`VertexProgram::emit`]); pre-filled with `None`.
    pub out_base: &'a mut [Option<P::Msg>],
    /// Global aggregate from the previous superstep.
    pub global_agg: &'a P::Agg,
    /// Machine-local aggregate contribution for this superstep.
    pub local_agg: &'a mut P::Agg,
}

/// A Pregel vertex program.
pub trait VertexProgram: Send + Sync + 'static {
    /// Vertex value `a(v)`.
    type Value: Codec + PartialEq + std::fmt::Debug;
    /// Message type.
    type Msg: Codec + PartialEq + std::fmt::Debug;
    /// Aggregator partial value (use `()` when unused).
    type Agg: Clone + Default + Send + Sync + 'static;
    /// Statically-dispatched message combiner ([`NoCombiner`] = none).
    /// A real combiner enables IO-Basic's pre-send combining, recoded
    /// mode's in-memory `A_s`/`A_r` digesting, and the local-delivery
    /// fast path; the engine's per-record loops are monomorphized over
    /// this type so `combine` inlines.
    type Comb: Combiner<Self::Msg>;

    /// Initial vertex value at load time.
    fn init_value(&self, id: u32, deg: u32, num_vertices: u64) -> Self::Value;

    /// Is the vertex active in superstep 0?  (Pregel: all active; SSSP
    /// activates only the source.)
    fn initially_active(&self, _id: u32) -> bool {
        true
    }

    /// The vertex-centric kernel (§2.1).  `edges` is Γ(v) streamed from
    /// `S^E`; `msgs` the combined/raw incoming messages.
    fn compute(
        &self,
        ctx: &mut Context<'_, Self::Msg, Self::Agg>,
        id: u32,
        value: &mut Self::Value,
        edges: &[Edge],
        msgs: &[Self::Msg],
    );

    /// The typed combiner instance (`None` when [`Self::Comb`] is
    /// [`NoCombiner`]).  Introspection only — engine hot paths instantiate
    /// `Self::Comb` directly and branch on [`Combiner::ENABLED`].
    fn combiner(&self) -> Option<Self::Comb> {
        if <Self::Comb as Combiner<Self::Msg>>::ENABLED {
            Some(Self::Comb::default())
        } else {
            None
        }
    }

    /// Monotone-workload skip hook: called for a *halted* vertex whose only
    /// stimulus this superstep is `msgs`.  Return `false` when the messages
    /// provably cannot change the vertex (i.e. `compute` would neither
    /// mutate `value`, nor send, nor touch the aggregator); the engine then
    /// leaves the vertex halted and skips its adjacency read entirely
    /// (§3.2's `skip()`).  This is what keeps sparse skipping firing
    /// *per lane* in k-lane multi-source runs: a vertex touched only by
    /// non-improving lanes never streams its edges.  Default `true`
    /// (always recompute) is safe for every program.
    fn reactivates(&self, _value: &Self::Value, _msgs: &[Self::Msg]) -> bool {
        true
    }

    /// Merge another machine's aggregate into `a`.
    fn merge_agg(&self, _a: &mut Self::Agg, _b: &Self::Agg) {}

    /// Wire-encode an aggregate for the distributed (TCP-transport)
    /// control barrier.  The default writes nothing, which round-trips
    /// correctly for `Agg = ()` — programs with a real aggregator must
    /// override both this and [`Self::decode_agg`] to run under
    /// `transport=tcp` (under the sim transport aggregates never leave
    /// the process and these hooks are unused).
    fn encode_agg(&self, _agg: &Self::Agg, _out: &mut Vec<u8>) {}

    /// Inverse of [`Self::encode_agg`]; the default yields
    /// `Agg::default()`.
    fn decode_agg(&self, _bytes: &[u8]) -> Self::Agg {
        Self::Agg::default()
    }

    /// Vectorized whole-block update (recoded mode).  Return `true` if the
    /// block was handled (the engine then fans out `out_base` along the
    /// edge stream via [`Self::emit`]); `false` falls back to per-vertex
    /// `compute`.  Implementations run on [`KernelSet`] — the XLA hot path.
    fn block_update(&self, _kern: &KernelSet, _b: &mut BlockCtx<'_, Self>) -> crate::Result<bool>
    where
        Self: Sized,
    {
        Ok(false)
    }

    /// Fan one vertex's message base out along its adjacency list
    /// (block-update path only).  Default: same message to every neighbor.
    fn emit(&self, base: &Self::Msg, edges: &[Edge], send: &mut dyn FnMut(u32, Self::Msg)) {
        for e in edges {
            send(e.nbr, *base);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combiners_fold_correctly() {
        let mut a = 1.5f32;
        SumF32.combine(&mut a, &2.5);
        assert_eq!(a, 4.0);
        assert_eq!(SumF32.identity(), 0.0);

        let mut m = 5.0f32;
        MinF32.combine(&mut m, &7.0);
        assert_eq!(m, 5.0);
        MinF32.combine(&mut m, &2.0);
        assert_eq!(m, 2.0);
        assert_eq!(MinF32.identity(), f32::INFINITY);

        let mut i = 9i32;
        MinI32.combine(&mut i, &3);
        assert_eq!(i, 3);
        assert_eq!(MinI32.identity(), i32::MAX);
    }

    #[test]
    fn combiner_identity_law() {
        // combine(e0, m) == m for all three built-ins
        for m in [0.0f32, -1.5, 1e20] {
            let mut a = SumF32.identity();
            SumF32.combine(&mut a, &m);
            assert_eq!(a, m);
            let mut b = MinF32.identity();
            MinF32.combine(&mut b, &m);
            assert_eq!(b, m);
        }
        let mut c = MinI32.identity();
        MinI32.combine(&mut c, &42);
        assert_eq!(c, 42);
    }

    #[test]
    fn min_lanes_folds_elementwise() {
        let comb = MinLanes::<3>;
        let mut acc = comb.identity();
        assert_eq!(acc, [f32::INFINITY; 3]);
        comb.combine(&mut acc, &[2.0, f32::INFINITY, 5.0]);
        comb.combine(&mut acc, &[3.0, 1.0, f32::INFINITY]);
        assert_eq!(acc, [2.0, 1.0, 5.0]);
        // identity law per lane
        let mut b = comb.identity();
        comb.combine(&mut b, &[0.5, -1.0, 7.0]);
        assert_eq!(b, [0.5, -1.0, 7.0]);
    }

    #[test]
    fn combiner_slot_enabled_flag() {
        assert!(<SumF32 as Combiner<f32>>::ENABLED);
        assert!(<MinLanes<4> as Combiner<[f32; 4]>>::ENABLED);
        assert!(!<NoCombiner as Combiner<f32>>::ENABLED);
        // NoCombiner::combine is a no-op (it is never reached for folding).
        let mut x = 1.5f32;
        NoCombiner.combine(&mut x, &9.0);
        assert_eq!(x, 1.5);
    }

    #[test]
    fn context_send_and_halt() {
        let mut collected: Vec<(u32, f32)> = Vec::new();
        let mut send = |t: u32, m: f32| collected.push((t, m));
        let mut local = ();
        let mut ctx: Context<'_, f32, ()> = Context::new(3, 100, &(), &mut local, &mut send);
        ctx.send(7, 0.5);
        ctx.send(9, 1.5);
        assert_eq!(ctx.msgs_sent(), 2);
        assert!(!ctx.halt);
        ctx.vote_to_halt();
        assert!(ctx.halt);
        drop(ctx);
        assert_eq!(collected, vec![(7, 0.5), (9, 1.5)]);
    }
}
