//! Single-threaded in-memory reference implementations — the correctness
//! oracles for every engine mode and baseline system.

use super::Graph;

/// Pregel-style PageRank: `supersteps` compute steps (step 0 distributes
/// the initial rank), sinks leak mass — matches `algos::PageRank` exactly.
pub fn pagerank(g: &Graph, supersteps: u64) -> Vec<f32> {
    let n = g.num_vertices();
    let nv = n as f32;
    let mut rank = vec![1.0 / nv; n];
    // Messages sent at step s are consumed at step s+1; steps 1..supersteps
    // perform updates (identical to the vertex program).
    let mut inbox = vec![0.0f32; n];
    for step in 0..supersteps {
        if step > 0 {
            for v in 0..n {
                rank[v] = 0.15 / nv + 0.85 * inbox[v];
            }
        }
        inbox.iter_mut().for_each(|x| *x = 0.0);
        for v in 0..n as u32 {
            let d = g.degree(v);
            if d > 0 {
                let share = rank[v as usize] / d as f32;
                for &u in g.neighbors(v) {
                    inbox[u as usize] += share;
                }
            }
        }
    }
    rank
}

/// Dijkstra SSSP (f64 accumulation, then f32 — tight enough for test
/// tolerance against the message-passing engine).
pub fn sssp(g: &Graph, source: u32) -> Vec<f32> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let n = g.num_vertices();
    let mut dist = vec![f32::INFINITY; n];
    dist[source as usize] = 0.0;
    let mut heap: BinaryHeap<Reverse<(u64, u32)>> = BinaryHeap::new();
    // f32 distances are totally ordered here (no NaN); encode via bits.
    let key = |d: f32| (d.to_bits() as u64, 0u32).0;
    heap.push(Reverse((key(0.0), source)));
    while let Some(Reverse((k, v))) = heap.pop() {
        if k > key(dist[v as usize]) {
            continue;
        }
        let ws = g.weights_of(v);
        for (i, &u) in g.neighbors(v).iter().enumerate() {
            let w = ws.map_or(1.0, |ws| ws[i]);
            let nd = dist[v as usize] + w;
            if nd < dist[u as usize] {
                dist[u as usize] = nd;
                heap.push(Reverse((key(nd), u)));
            }
        }
    }
    dist
}

/// Connected components via union-find; labels = min vertex id per
/// component (the Hash-Min fixpoint).
pub fn components(g: &Graph) -> Vec<u32> {
    let n = g.num_vertices();
    let mut parent: Vec<u32> = (0..n as u32).collect();
    fn find(p: &mut [u32], x: u32) -> u32 {
        let mut r = x;
        while p[r as usize] != r {
            r = p[r as usize];
        }
        let mut c = x;
        while p[c as usize] != r {
            let nx = p[c as usize];
            p[c as usize] = r;
            c = nx;
        }
        r
    }
    for v in 0..n as u32 {
        for &u in g.neighbors(v) {
            let (a, b) = (find(&mut parent, v), find(&mut parent, u));
            if a != b {
                parent[a.max(b) as usize] = a.min(b);
            }
        }
    }
    (0..n as u32).map(|v| find(&mut parent, v)).collect()
}

/// Brute-force triangle count (each triangle counted once).
pub fn triangles(g: &Graph) -> u64 {
    let n = g.num_vertices() as u32;
    let mut count = 0u64;
    for v in 0..n {
        let nb: Vec<u32> = g.neighbors(v).iter().copied().filter(|&u| u > v).collect();
        for (i, &u) in nb.iter().enumerate() {
            for &w in &nb[i + 1..] {
                if g.neighbors(u).binary_search(&w).is_ok() {
                    count += 1;
                }
            }
        }
    }
    count
}

/// Number of supersteps Hash-Min needs (label propagation rounds + the
/// final quiescent detection round) — used to pre-size bench runs.
pub fn hashmin_rounds(g: &Graph) -> u64 {
    let n = g.num_vertices();
    let mut label: Vec<u32> = (0..n as u32).collect();
    let mut rounds = 0u64;
    loop {
        let mut changed = false;
        let mut next = label.clone();
        for v in 0..n as u32 {
            for &u in g.neighbors(v) {
                if label[v as usize] < next[u as usize] {
                    next[u as usize] = label[v as usize];
                    changed = true;
                }
            }
        }
        label = next;
        rounds += 1;
        if !changed {
            break;
        }
    }
    rounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;

    #[test]
    fn pagerank_mass_conservation_on_ring() {
        let g = generator::ring(10);
        let r = pagerank(&g, 30);
        let total: f32 = r.iter().sum();
        assert!((total - 1.0).abs() < 1e-3, "total={total}");
        // symmetric graph -> uniform ranks
        for &x in &r {
            assert!((x - 0.1).abs() < 1e-4);
        }
    }

    #[test]
    fn sssp_on_chain() {
        let g = generator::chain(6).with_unit_weights();
        let d = sssp(&g, 0);
        for (i, &x) in d.iter().enumerate() {
            assert_eq!(x, i as f32);
        }
        let d2 = sssp(&g, 3);
        assert!(d2[0].is_infinite()); // chain is directed
        assert_eq!(d2[5], 2.0);
    }

    #[test]
    fn components_two_rings() {
        let mut adj = vec![Vec::new(); 8];
        for i in 0..4u32 {
            adj[i as usize] = vec![(i + 1) % 4, (i + 3) % 4];
            adj[4 + i as usize] = vec![4 + (i + 1) % 4, 4 + (i + 3) % 4];
        }
        let g = Graph::from_adj(adj, false);
        let c = components(&g);
        assert_eq!(&c[..4], &[0, 0, 0, 0]);
        assert_eq!(&c[4..], &[4, 4, 4, 4]);
    }

    #[test]
    fn triangles_on_k4() {
        let adj = vec![vec![1, 2, 3], vec![0, 2, 3], vec![0, 1, 3], vec![0, 1, 2]];
        let g = Graph::from_adj(adj, false);
        assert_eq!(triangles(&g), 4);
    }

    #[test]
    fn hashmin_rounds_bounded_by_diameter() {
        let g = generator::ring(16);
        let r = hashmin_rounds(&g);
        assert!(r <= 10, "r={r}");
    }
}
