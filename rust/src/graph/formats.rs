//! On-disk graph formats.
//!
//! * **Text** (HDFS input, §2): one vertex per line,
//!   `id \t nbr1 nbr2 …` or `id \t nbr1:w1 nbr2:w2 …` for weighted graphs.
//!   Vertex IDs may be *sparse* (the paper's normal mode never assumes
//!   dense IDs) — [`sparse_ids`] fabricates such IDs so the ID-recoding
//!   preprocessing (§5) has real work to do.
//! * **Binary per-machine state/edge files** are written by the engine
//!   itself (see `worker::storage`), not here.

use super::{Graph, VertexId};
use crate::error::{Error, Result};
use crate::util::rng::Rng;
use std::io::Write;
use std::path::Path;

/// Generate a sparse, increasing old-ID assignment for `nv` vertices
/// (dense id -> old id), with pseudo-random gaps (like the paper's Figure 1
/// example IDs 2, 22, 32, 42…).
pub fn sparse_ids(nv: usize, seed: u64) -> Vec<VertexId> {
    let mut rng = Rng::new(seed);
    let mut ids = Vec::with_capacity(nv);
    let mut cur: u64 = 2;
    for _ in 0..nv {
        ids.push(cur as VertexId);
        cur += 1 + rng.below(15);
    }
    assert!(cur < u32::MAX as u64, "sparse id overflow");
    ids
}

/// One parsed vertex line.
#[derive(Clone, Debug, PartialEq)]
pub struct VertexLine {
    pub id: VertexId,
    pub nbrs: Vec<VertexId>,
    pub weights: Option<Vec<f32>>,
}

/// Serialize a graph as text, mapping dense ids through `old_ids`
/// (`None` keeps dense ids).  Returns the number of lines written.
pub fn write_text(
    g: &Graph,
    old_ids: Option<&[VertexId]>,
    out: &mut impl Write,
) -> Result<usize> {
    let map = |v: VertexId| old_ids.map_or(v, |m| m[v as usize]);
    let mut lines = 0;
    let mut buf = String::new();
    for v in 0..g.num_vertices() as u32 {
        buf.clear();
        buf.push_str(&map(v).to_string());
        buf.push('\t');
        let ws = g.weights_of(v);
        for (i, &n) in g.neighbors(v).iter().enumerate() {
            if i > 0 {
                buf.push(' ');
            }
            buf.push_str(&map(n).to_string());
            if let Some(ws) = ws {
                buf.push(':');
                // Display for f32 is shortest round-trip: parsing recovers
                // the exact bits, keeping loaded graphs == generated graphs.
                buf.push_str(&format!("{}", ws[i]));
            }
        }
        buf.push('\n');
        out.write_all(buf.as_bytes())?;
        lines += 1;
    }
    Ok(lines)
}

/// Parse one text line.
pub fn parse_line(line: &str) -> Result<VertexLine> {
    let bad = || Error::CorruptStream(format!("bad vertex line: {line:?}"));
    let mut parts = line.splitn(2, '\t');
    let id: VertexId = parts.next().ok_or_else(bad)?.trim().parse().map_err(|_| bad())?;
    let rest = parts.next().unwrap_or("").trim();
    let mut nbrs = Vec::new();
    let mut weights: Option<Vec<f32>> = None;
    for tok in rest.split_whitespace() {
        if let Some((n, w)) = tok.split_once(':') {
            let n: VertexId = n.parse().map_err(|_| bad())?;
            let w: f32 = w.parse().map_err(|_| bad())?;
            nbrs.push(n);
            weights.get_or_insert_with(Vec::new).push(w);
        } else {
            nbrs.push(tok.parse().map_err(|_| bad())?);
        }
    }
    if let Some(ws) = &weights {
        if ws.len() != nbrs.len() {
            return Err(bad());
        }
    }
    Ok(VertexLine { id, nbrs, weights })
}

/// Write a graph to a text file on the local filesystem.
pub fn write_text_file(
    g: &Graph,
    old_ids: Option<&[VertexId]>,
    path: &Path,
) -> Result<usize> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    let n = write_text(g, old_ids, &mut f)?;
    f.flush()?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;

    #[test]
    fn text_roundtrip_unweighted() {
        let g = generator::uniform(30, 80, true, 1);
        let mut buf = Vec::new();
        write_text(&g, None, &mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        for (v, line) in text.lines().enumerate() {
            let vl = parse_line(line).unwrap();
            assert_eq!(vl.id, v as u32);
            assert_eq!(vl.nbrs, g.neighbors(v as u32));
            assert!(vl.weights.is_none());
        }
    }

    #[test]
    fn text_roundtrip_weighted() {
        let g = generator::random_weights(generator::uniform(10, 30, true, 2), 3);
        let mut buf = Vec::new();
        write_text(&g, None, &mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        for (v, line) in text.lines().enumerate() {
            let vl = parse_line(line).unwrap();
            let ws = vl.weights.unwrap();
            for (i, w) in ws.iter().enumerate() {
                assert!((w - g.weights_of(v as u32).unwrap()[i]).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn sparse_ids_strictly_increasing() {
        let ids = sparse_ids(1000, 7);
        for w in ids.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(ids[999] > 999, "ids should be sparse");
    }

    #[test]
    fn sparse_id_mapping_applied() {
        let g = generator::chain(4);
        let ids = vec![5u32, 17, 40, 99];
        let mut buf = Vec::new();
        write_text(&g, Some(&ids), &mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(parse_line(lines[0]).unwrap().id, 5);
        assert_eq!(parse_line(lines[0]).unwrap().nbrs, vec![17]);
        assert_eq!(parse_line(lines[3]).unwrap().nbrs, Vec::<u32>::new());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_line("notanum\t1 2").is_err());
        assert!(parse_line("3\t1:x").is_err());
        assert!(parse_line("").is_err());
        // isolated vertex is fine
        assert_eq!(parse_line("7\t").unwrap().nbrs, Vec::<u32>::new());
    }
}
