//! Synthetic graph generators + the five paper-analog dataset presets.
//!
//! The paper evaluates on WebUK, ClueWeb, Twitter, Friendster and BTC
//! (Table 1) — hundreds of GB we cannot ship.  Per the substitution rule we
//! generate scaled-down graphs with the same *shape*: power-law web graphs
//! (R-MAT), a heavy-tailed social graph (max-degree hubs like Twitter's
//! 780 K-follower accounts), an undirected social graph, and a low-degree
//! RDF-like graph with extreme hubs (BTC's max degree is 348× its average).

use super::{Graph, VertexId};
use crate::util::rng::Rng;

/// Uniform (Erdős–Rényi-ish) directed multigraph-free graph.
pub fn uniform(nv: usize, ne: usize, directed: bool, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let mut adj: Vec<Vec<VertexId>> = vec![Vec::new(); nv];
    let mut added = 0usize;
    while added < ne {
        let u = rng.below(nv as u64) as usize;
        let v = rng.below(nv as u64) as u32;
        if v as usize == u {
            continue;
        }
        adj[u].push(v);
        if !directed {
            adj[v as usize].push(u as u32);
        }
        added += 1;
    }
    sort_dedup(&mut adj);
    Graph::from_adj(adj, directed)
}

/// R-MAT generator (Chakrabarti et al.): recursive quadrant sampling gives
/// a power-law degree distribution like web/social graphs.
pub fn rmat(
    nv: usize,
    ne: usize,
    (a, b, c): (f64, f64, f64),
    directed: bool,
    seed: u64,
) -> Graph {
    let scale = (usize::BITS - (nv.max(2) - 1).leading_zeros()) as usize;
    let side = 1usize << scale;
    let mut rng = Rng::new(seed);
    let mut adj: Vec<Vec<VertexId>> = vec![Vec::new(); nv];
    let mut added = 0usize;
    let mut attempts = 0usize;
    while added < ne && attempts < ne * 20 {
        attempts += 1;
        let (mut x, mut y) = (0usize, 0usize);
        let mut half = side / 2;
        while half > 0 {
            let r = rng.f64();
            if r < a {
                // top-left
            } else if r < a + b {
                x += half;
            } else if r < a + b + c {
                y += half;
            } else {
                x += half;
                y += half;
            }
            half /= 2;
        }
        if x >= nv || y >= nv || x == y {
            continue;
        }
        adj[x].push(y as u32);
        if !directed {
            adj[y].push(x as u32);
        }
        added += 1;
    }
    sort_dedup(&mut adj);
    Graph::from_adj(adj, directed)
}

/// A graph with `hubs` very-high-degree vertices plus uniform background —
/// models BTC/Twitter-style extreme-skew degree distributions.
pub fn hub_graph(
    nv: usize,
    ne_background: usize,
    hubs: usize,
    hub_degree: usize,
    directed: bool,
    seed: u64,
) -> Graph {
    let mut rng = Rng::new(seed);
    let mut adj: Vec<Vec<VertexId>> = vec![Vec::new(); nv];
    for h in 0..hubs {
        let hub = rng.below(nv as u64) as usize;
        for _ in 0..hub_degree {
            let v = rng.below(nv as u64) as u32;
            if v as usize == hub {
                continue;
            }
            adj[hub].push(v);
            if !directed {
                adj[v as usize].push(hub as u32);
            }
        }
        let _ = h;
    }
    let mut added = 0usize;
    while added < ne_background {
        let u = rng.below(nv as u64) as usize;
        let v = rng.below(nv as u64) as u32;
        if v as usize == u {
            continue;
        }
        adj[u].push(v);
        if !directed {
            adj[v as usize].push(u as u32);
        }
        added += 1;
    }
    sort_dedup(&mut adj);
    Graph::from_adj(adj, directed)
}

/// Directed chain 0→1→…→n−1: the worst case for superstep count (BFS runs
/// n supersteps) — exercises sparse-workload skipping.
pub fn chain(nv: usize) -> Graph {
    let adj = (0..nv)
        .map(|i| if i + 1 < nv { vec![(i + 1) as u32] } else { vec![] })
        .collect();
    Graph::from_adj(adj, true)
}

/// Undirected ring.
pub fn ring(nv: usize) -> Graph {
    let adj = (0..nv)
        .map(|i| {
            vec![
                ((i + 1) % nv) as u32,
                ((i + nv - 1) % nv) as u32,
            ]
        })
        .collect();
    Graph::from_adj(adj, false)
}

/// Complete binary tree (directed parent→child).
pub fn binary_tree(nv: usize) -> Graph {
    let adj = (0..nv)
        .map(|i| {
            let mut l = Vec::new();
            if 2 * i + 1 < nv {
                l.push((2 * i + 1) as u32);
            }
            if 2 * i + 2 < nv {
                l.push((2 * i + 2) as u32);
            }
            l
        })
        .collect();
    Graph::from_adj(adj, true)
}

fn sort_dedup(adj: &mut [Vec<VertexId>]) {
    for l in adj.iter_mut() {
        l.sort_unstable();
        l.dedup();
    }
}

/// Attach pseudo-random edge weights in `[1, 10)` for SSSP workloads.
pub fn random_weights(g: Graph, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let ne = g.num_edges();
    let w = (0..ne).map(|_| 1.0 + 9.0 * rng.f32()).collect();
    g.with_weights(w)
}

/// Deterministic query workload for the serve subsystem: `q` pseudo-random
/// (source, target) pairs over vertex ids `[0, nv)` with `source != target`
/// (pairs may repeat when `q` approaches `nv²`).  Same `(nv, q, seed)`
/// always yields the same pairs — the serve bench, tests, and CLI demo all
/// draw from here.
pub fn query_set(nv: usize, q: usize, seed: u64) -> Vec<(u32, u32)> {
    assert!(nv >= 2, "query_set needs at least 2 vertices");
    let mut rng = Rng::new(seed ^ 0x5e7_9e4e5); // decouple from graph seeds
    (0..q)
        .map(|_| loop {
            let s = rng.below(nv as u64) as u32;
            let t = rng.below(nv as u64) as u32;
            if s != t {
                return (s, t);
            }
        })
        .collect()
}

/// The five scaled-down paper analogs (Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataset {
    /// WebUK analog: directed power-law web graph.
    WebUkS,
    /// ClueWeb analog: the largest directed web graph in the suite.
    ClueWebS,
    /// Twitter analog: directed social graph with extreme-degree hubs.
    TwitterS,
    /// Friendster analog: undirected social graph.
    FriendsterS,
    /// BTC analog: undirected, low average degree, enormous max degree.
    BtcS,
}

impl Dataset {
    pub fn all() -> [Dataset; 5] {
        [
            Dataset::WebUkS,
            Dataset::ClueWebS,
            Dataset::TwitterS,
            Dataset::FriendsterS,
            Dataset::BtcS,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dataset::WebUkS => "webuk-s",
            Dataset::ClueWebS => "clueweb-s",
            Dataset::TwitterS => "twitter-s",
            Dataset::FriendsterS => "friendster-s",
            Dataset::BtcS => "btc-s",
        }
    }

    pub fn directed(&self) -> bool {
        matches!(self, Dataset::WebUkS | Dataset::ClueWebS | Dataset::TwitterS)
    }

    /// Generate the preset at its default scale (deterministic).
    pub fn generate(&self) -> Graph {
        self.generate_scaled(1.0)
    }

    /// Generate with a size multiplier (benches use < 1 for smoke runs).
    pub fn generate_scaled(&self, f: f64) -> Graph {
        let s = |x: usize| ((x as f64 * f) as usize).max(16);
        match self {
            // WebUK: |V|=134M, |E|=5.5B, deg 41 -> scaled ~1/1000.
            Dataset::WebUkS => rmat(s(134_000), s(5_500_000), (0.57, 0.19, 0.19), true, 101),
            // ClueWeb: |V|=978M, |E|=42.6B -> the big one, ~1/1400.
            Dataset::ClueWebS => rmat(s(1_000_000), s(30_000_000), (0.57, 0.19, 0.19), true, 102),
            // Twitter: |V|=52.6M, |E|=2.0B, max-deg 780K -> hubs + rmat bg.
            Dataset::TwitterS => hub_graph(s(53_000), s(1_900_000), 12, s(7_800), true, 103),
            // Friendster: |V|=65.6M, |E|=3.6B(u) -> undirected.
            Dataset::FriendsterS => uniform(s(66_000), s(1_200_000), false, 104),
            // BTC: |V|=165M, |E|=773M, avg deg 4.7, max-deg 1.64M.
            Dataset::BtcS => hub_graph(s(165_000), s(300_000), 4, s(16_000), false, 105),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_respects_counts() {
        let g = uniform(100, 500, true, 1);
        assert_eq!(g.num_vertices(), 100);
        // dedup may remove a few duplicates
        assert!(g.num_edges() > 400 && g.num_edges() <= 500);
        for v in 0..100u32 {
            for &n in g.neighbors(v) {
                assert!(n < 100 && n != v);
            }
        }
    }

    #[test]
    fn undirected_is_symmetric() {
        let g = uniform(60, 200, false, 2);
        for v in 0..60u32 {
            for &n in g.neighbors(v) {
                assert!(g.neighbors(n).contains(&v), "missing back-edge {n}->{v}");
            }
        }
    }

    #[test]
    fn rmat_is_skewed() {
        let g = rmat(1024, 8192, (0.57, 0.19, 0.19), true, 3);
        // power-law-ish: max degree far above average
        assert!(g.max_degree() as f64 > 4.0 * g.avg_degree());
    }

    #[test]
    fn chain_and_tree_shapes() {
        let c = chain(10);
        assert_eq!(c.num_edges(), 9);
        assert_eq!(c.neighbors(3), &[4]);
        let t = binary_tree(7);
        assert_eq!(t.neighbors(0), &[1, 2]);
        assert_eq!(t.neighbors(2), &[5, 6]);
        let r = ring(5);
        assert_eq!(r.degree(0), 2);
    }

    #[test]
    fn generators_are_deterministic() {
        let a = uniform(50, 100, true, 9);
        let b = uniform(50, 100, true, 9);
        for v in 0..50u32 {
            assert_eq!(a.neighbors(v), b.neighbors(v));
        }
    }

    #[test]
    fn dataset_presets_smoke() {
        for d in Dataset::all() {
            let g = d.generate_scaled(0.01);
            assert!(g.num_vertices() > 0, "{}", d.name());
            assert!(g.num_edges() > 0, "{}", d.name());
            assert_eq!(g.directed, d.directed(), "{}", d.name());
        }
    }

    #[test]
    fn hub_graph_has_extreme_max_degree() {
        let g = hub_graph(2000, 2000, 3, 500, false, 7);
        assert!(g.max_degree() >= 400);
        assert!(g.max_degree() as f64 > 20.0 * g.avg_degree());
    }

    #[test]
    fn query_set_is_deterministic_and_valid() {
        let a = query_set(50, 40, 9);
        let b = query_set(50, 40, 9);
        assert_eq!(a, b);
        assert_eq!(a.len(), 40);
        for &(s, t) in &a {
            assert!(s < 50 && t < 50 && s != t);
        }
        // different seeds give different workloads
        assert_ne!(query_set(50, 40, 9), query_set(50, 40, 10));
    }

    #[test]
    fn random_weights_in_range() {
        let g = random_weights(uniform(50, 100, true, 4), 5);
        for v in 0..50u32 {
            for &w in g.weights_of(v).unwrap() {
                assert!((1.0..10.0).contains(&w));
            }
        }
    }
}
