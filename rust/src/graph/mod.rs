//! Graph types: CSR in-memory representation (used by generators, loaders
//! and the in-memory baseline) plus dataset statistics.
//!
//! GraphD itself never holds a whole graph in memory — workers stream
//! `S^E` from disk — but generators/baselines and reference implementations
//! need a materialized form.

pub mod formats;
pub mod generator;
pub mod reference;

/// Vertex identifier.  The paper allows arbitrary ID types; we fix u32
/// (graphs here are ≤ 2^32 vertices) — recoded mode requires dense
/// `0..|V|-1` IDs anyway (§5).
pub type VertexId = u32;

/// In-memory CSR graph.
#[derive(Clone, Debug)]
pub struct Graph {
    pub directed: bool,
    /// Edge weights present? (SSSP streams 8-byte adjacency items, others 4.)
    pub weighted: bool,
    offsets: Vec<u64>,
    nbrs: Vec<VertexId>,
    weights: Option<Vec<f32>>,
}

impl Graph {
    /// Build from an adjacency-list vector (index = vertex id).
    pub fn from_adj(adj: Vec<Vec<VertexId>>, directed: bool) -> Self {
        let mut offsets = Vec::with_capacity(adj.len() + 1);
        offsets.push(0u64);
        let mut nbrs = Vec::new();
        for list in &adj {
            nbrs.extend_from_slice(list);
            offsets.push(nbrs.len() as u64);
        }
        Self {
            directed,
            weighted: false,
            offsets,
            nbrs,
            weights: None,
        }
    }

    /// Attach unit weights (turns the graph into a weighted one for SSSP).
    pub fn with_unit_weights(mut self) -> Self {
        self.weights = Some(vec![1.0; self.nbrs.len()]);
        self.weighted = true;
        self
    }

    /// Attach the given weights (len must equal edge count).
    pub fn with_weights(mut self, w: Vec<f32>) -> Self {
        assert_eq!(w.len(), self.nbrs.len());
        self.weights = Some(w);
        self.weighted = true;
        self
    }

    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of adjacency items (directed edge count; undirected graphs
    /// store both directions, as the paper's Γ(v) does).
    pub fn num_edges(&self) -> usize {
        self.nbrs.len()
    }

    #[inline]
    pub fn degree(&self, v: VertexId) -> u32 {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as u32
    }

    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        let (a, b) = (
            self.offsets[v as usize] as usize,
            self.offsets[v as usize + 1] as usize,
        );
        &self.nbrs[a..b]
    }

    #[inline]
    pub fn weights_of(&self, v: VertexId) -> Option<&[f32]> {
        self.weights.as_ref().map(|w| {
            let (a, b) = (
                self.offsets[v as usize] as usize,
                self.offsets[v as usize + 1] as usize,
            );
            &w[a..b]
        })
    }

    pub fn max_degree(&self) -> u32 {
        (0..self.num_vertices() as u32)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    pub fn avg_degree(&self) -> f64 {
        if self.num_vertices() == 0 {
            0.0
        } else {
            self.num_edges() as f64 / self.num_vertices() as f64
        }
    }

    /// Table-1-style stats row.
    pub fn stats(&self) -> GraphStats {
        GraphStats {
            directed: self.directed,
            nv: self.num_vertices() as u64,
            ne: self.num_edges() as u64,
            avg_deg: self.avg_degree(),
            max_deg: self.max_degree(),
        }
    }
}

/// Summary statistics (paper Table 1).
#[derive(Clone, Copy, Debug)]
pub struct GraphStats {
    pub directed: bool,
    pub nv: u64,
    pub ne: u64,
    pub avg_deg: f64,
    pub max_deg: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Graph {
        Graph::from_adj(vec![vec![1, 2], vec![2], vec![], vec![0]], true)
    }

    #[test]
    fn csr_accessors() {
        let g = toy();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(2), &[] as &[u32]);
        assert_eq!(g.degree(3), 1);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn weights_align() {
        let g = toy().with_weights(vec![0.1, 0.2, 0.3, 0.4]);
        assert!(g.weighted);
        assert_eq!(g.weights_of(0).unwrap(), &[0.1, 0.2]);
        assert_eq!(g.weights_of(3).unwrap(), &[0.4]);
    }

    #[test]
    fn stats_row() {
        let s = toy().stats();
        assert_eq!(s.nv, 4);
        assert_eq!(s.ne, 4);
        assert!((s.avg_deg - 1.0).abs() < 1e-9);
    }
}
