//! Benchmark harness: runs every system on a dataset/algorithm/profile
//! combination and renders paper-style table rows (Tables 2–8).
//!
//! GraphD rows run through the *real* engine (simulated network + disks);
//! baselines run their cost models over the same substrates.  Values are
//! cross-checked between systems so a table row is also a correctness
//! assertion.

use crate::algos::{HashMin, PageRank, Sssp};
use crate::baselines::{self, Algo, AlgoValues, BaselineRun};
use crate::config::{ClusterProfile, Mode};
use crate::error::{Error, Result};
use crate::graph::generator::Dataset;
use crate::graph::Graph;
use crate::metrics::{Cell, JobMetrics, Table};
use crate::session::{GraphD, GraphSource, LoadedGraph};
use crate::worker::{MachineStore, Partitioning};
use std::path::PathBuf;
use std::sync::Arc;

/// One rendered table row.
#[derive(Clone, Debug)]
pub struct Row {
    pub system: String,
    pub preprocess: Cell,
    pub load: Cell,
    pub compute: Cell,
}

/// Everything measured for one GraphD dataset×algo combo (feeds Table 4).
pub struct GraphDRuns {
    pub basic_load: f64,
    pub basic_compute: f64,
    pub basic_metrics: JobMetrics,
    pub recoding_compute: f64,
    pub recoded_load: f64,
    pub recoded_compute: f64,
    pub recoded_metrics: JobMetrics,
    pub values: AlgoValues,
}

/// Scale factor for dataset presets (`GRAPHD_SCALE`, default 1.0; the
/// quick CI smoke uses ~0.05).
pub fn scale_from_env() -> f64 {
    std::env::var("GRAPHD_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// Which systems to include (`GRAPHD_SYSTEMS=graphd,pregel+,...`).
pub fn systems_from_env() -> Option<Vec<String>> {
    std::env::var("GRAPHD_SYSTEMS")
        .ok()
        .map(|s| s.split(',').map(|x| x.trim().to_lowercase()).collect())
}

/// `GRAPHD_XLA=0` disables the XLA block path in bench runs.
pub fn use_xla_from_env() -> bool {
    std::env::var("GRAPHD_XLA").map_or(true, |v| v != "0")
}

/// `GRAPHD_SMOKE=1` shrinks bench workloads to CI-smoke size.
pub fn smoke_from_env() -> bool {
    std::env::var("GRAPHD_SMOKE").map_or(false, |v| v == "1")
}

/// Bench-JSON sink (`GRAPHD_BENCH_JSON=path`): benches emit their numbers
/// as one section of a shared JSON object (e.g. `BENCH_PR3.json`) so future
/// PRs have a perf trajectory to compare against.
pub fn bench_json_path() -> Option<String> {
    std::env::var("GRAPHD_BENCH_JSON").ok().filter(|s| !s.is_empty())
}

/// Write `path` fresh as `{"<section>": <body>}`.  `body` must be a JSON
/// object/value rendered by the caller.
pub fn bench_json_write(path: &str, section: &str, body: &str) -> std::io::Result<()> {
    std::fs::write(path, format!("{{\"{section}\": {body}}}\n"))
}

/// Merge `"<section>": <body>` into the JSON object at `path` (replacing
/// an existing entry for the same section, else appending before the final
/// `}`); falls back to a fresh write when the file is missing or not an
/// object.
pub fn bench_json_merge(path: &str, section: &str, body: &str) -> std::io::Result<()> {
    let existing = std::fs::read_to_string(path).unwrap_or_default();
    let trimmed = json_remove_section(existing.trim_end(), section);
    let trimmed = trimmed.trim_end();
    if let Some(head) = trimmed.strip_suffix('}') {
        if trimmed.starts_with('{') {
            let sep = if head.trim_end().ends_with('{') { "" } else { ", " };
            return std::fs::write(path, format!("{head}{sep}\"{section}\": {body}}}\n"));
        }
    }
    bench_json_write(path, section, body)
}

/// Drop a `"<section>": <value>` entry (and one adjacent comma) from a
/// flat bench-JSON object, so re-running a bench replaces its section
/// instead of appending a duplicate key.  Values are brace-balanced
/// scalars/objects without embedded braces in strings — which is all the
/// bench emitters produce.
fn json_remove_section(text: &str, section: &str) -> String {
    let needle = format!("\"{section}\":");
    let Some(start) = text.find(&needle) else {
        return text.to_string();
    };
    let bytes = text.as_bytes();
    let mut end = start + needle.len();
    let mut depth = 0i32;
    while end < bytes.len() {
        match bytes[end] {
            b'{' | b'[' => depth += 1,
            b'}' | b']' if depth > 0 => depth -= 1,
            b'}' | b']' | b',' if depth == 0 => break,
            _ => {}
        }
        end += 1;
    }
    // Swallow one separating comma (trailing, else the one leading in).
    let mut head = text[..start].trim_end().to_string();
    let mut tail = text[end..].trim_start().to_string();
    if let Some(t) = tail.strip_prefix(',') {
        tail = t.trim_start().to_string();
    } else if head.ends_with(',') {
        head.pop();
        head = head.trim_end().to_string();
    }
    format!("{head}{tail}")
}

fn workdir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("graphd_bench_{tag}_{}", std::process::id()))
}

/// Pick the SSSP source: highest-out-degree vertex (reaches a large
/// fraction of the graph, like the paper's chosen sources).
pub fn sssp_source(g: &Graph) -> u32 {
    (0..g.num_vertices() as u32)
        .max_by_key(|&v| g.degree(v))
        .unwrap_or(0)
}

/// Run both GraphD modes over `g` (text-loaded through the simulated DFS
/// with sparse input IDs, like real inputs).
pub fn run_graphd(
    tag: &str,
    g: &Graph,
    algo: Algo,
    profile: &ClusterProfile,
    use_xla: bool,
) -> Result<GraphDRuns> {
    run_graphd_cfg(tag, g, algo, profile, use_xla, &[])
}

/// [`run_graphd`] with raw `key=value` config overrides (the CLI's `-c`
/// flags), threaded through the session builder.
pub fn run_graphd_cfg(
    tag: &str,
    g: &Graph,
    algo: Algo,
    profile: &ClusterProfile,
    use_xla: bool,
    overrides: &[(String, String)],
) -> Result<GraphDRuns> {
    let wd = workdir(tag);
    let _ = std::fs::remove_dir_all(&wd);
    let mut b = GraphD::builder()
        .profile(profile.clone())
        .workdir(&wd)
        .use_xla(use_xla);
    if let Algo::PageRank { supersteps } = algo {
        b = b.max_supersteps(supersteps);
    }
    for (k, v) in overrides {
        b = b.config(k, v);
    }
    let session = b.build()?;

    // ---- Load + IO-Basic ----
    let mut graph = session.load(GraphSource::InMemorySparse(g, 4242))?;
    let basic_load = graph.load_secs;
    let (basic_compute, basic_out) = run_algo(&graph, Mode::Basic, algo)?;

    // ---- IO-Recoding (preprocessing) ----
    graph.recode()?;
    let recoding_compute = graph.recode_secs.unwrap_or(0.0);

    // ---- IO-Recoded (reload from local disks, then compute) ----
    let recoded_load = graph.reload_recoded()?;
    let (recoded_compute, rec_out) = run_algo(&graph, Mode::Recoded, algo)?;

    // Cross-check both modes produced equivalent results.
    check_equivalent(&basic_out.0, &rec_out.0, algo)?;

    let out = GraphDRuns {
        basic_load,
        basic_compute,
        basic_metrics: basic_out.1,
        recoding_compute,
        recoded_load,
        recoded_compute,
        recoded_metrics: rec_out.1,
        values: basic_out.0,
    };
    let _ = std::fs::remove_dir_all(&wd);
    Ok(out)
}

/// IO-Basic-only variant of [`run_graphd_cfg`]: load + one Basic compute,
/// no recoding and no Recoded re-run.  Used by `graphd run --basic` —
/// notably the recovery smoke run, where the back-to-back Recoded job
/// would overwrite the faulted Basic session's trace export.  The recoded
/// fields of the returned [`GraphDRuns`] mirror the basic run (timings 0).
pub fn run_graphd_basic_cfg(
    tag: &str,
    g: &Graph,
    algo: Algo,
    profile: &ClusterProfile,
    use_xla: bool,
    overrides: &[(String, String)],
) -> Result<GraphDRuns> {
    let wd = workdir(tag);
    let _ = std::fs::remove_dir_all(&wd);
    let mut b = GraphD::builder()
        .profile(profile.clone())
        .workdir(&wd)
        .use_xla(use_xla);
    if let Algo::PageRank { supersteps } = algo {
        b = b.max_supersteps(supersteps);
    }
    for (k, v) in overrides {
        b = b.config(k, v);
    }
    let session = b.build()?;
    let graph = session.load(GraphSource::InMemorySparse(g, 4242))?;
    let basic_load = graph.load_secs;
    let (basic_compute, basic_out) = run_algo(&graph, Mode::Basic, algo)?;
    let out = GraphDRuns {
        basic_load,
        basic_compute,
        basic_metrics: basic_out.1.clone(),
        recoding_compute: 0.0,
        recoded_load: 0.0,
        recoded_compute: 0.0,
        recoded_metrics: basic_out.1,
        values: basic_out.0,
    };
    let _ = std::fs::remove_dir_all(&wd);
    Ok(out)
}

type AlgoOut = (AlgoValues, JobMetrics);

fn run_algo(graph: &LoadedGraph<'_>, mode: Mode, algo: Algo) -> Result<(f64, AlgoOut)> {
    Ok(match algo {
        Algo::PageRank { supersteps } => {
            let res = graph
                .job(Arc::new(PageRank::new(supersteps)))
                .mode(mode)
                .run()?;
            let vals = AlgoValues::Ranks(by_id_f32(res.values_by_id()));
            (res.metrics.compute_secs, (vals, res.metrics))
        }
        Algo::HashMin => {
            let res = graph.job(Arc::new(HashMin)).mode(mode).run()?;
            let vals = AlgoValues::Labels(
                res.values_by_id().into_iter().map(|(_, l)| l as u32).collect(),
            );
            (res.metrics.compute_secs, (vals, res.metrics))
        }
        Algo::Sssp { source } => {
            // `source` is a dense generator ID; inputs carry sparse IDs
            // (dense → sparse is order-preserving since sparse_ids is
            // increasing), and recoded jobs need a second translation.
            let src_sparse = nth_sparse_id(graph.stores(), source);
            let src_cur = match mode {
                Mode::Recoded => graph.current_id_of(src_sparse),
                _ => src_sparse,
            };
            let res = graph.job(Arc::new(Sssp::new(src_cur))).mode(mode).run()?;
            let vals = AlgoValues::Dists(by_id_f32(res.values_by_id()));
            (res.metrics.compute_secs, (vals, res.metrics))
        }
    })
}

/// All stores' ids merged ascending == sparse ids in dense order; pick the
/// `dense`-th.
fn nth_sparse_id(stores: &[MachineStore], dense: u32) -> u32 {
    let mut ids: Vec<u32> = stores.iter().flat_map(|s| s.ids.iter().copied()).collect();
    ids.sort_unstable();
    ids[dense as usize]
}

/// Old (sparse) id → recoded id, per §5's bijection.
#[deprecated(
    since = "0.2.0",
    note = "use the session API: LoadedGraph::current_id_of(old) after recode()"
)]
pub fn translate_to_recoded(rec_stores: &[MachineStore], old: u32) -> u32 {
    let n = rec_stores.len();
    let m = Partitioning::Hashed.machine_of(old, n);
    let pos = rec_stores[m]
        .ids
        .binary_search(&old)
        .expect("vertex must exist");
    (pos * n + m) as u32
}

fn by_id_f32(v: Vec<(u32, f32)>) -> Vec<f32> {
    v.into_iter().map(|(_, x)| x).collect()
}

/// Equivalence between two runs of (possibly) different systems/modes.
pub fn check_equivalent(a: &AlgoValues, b: &AlgoValues, algo: Algo) -> Result<()> {
    let fail =
        |msg: String| Err(Error::Other(format!("result mismatch ({}): {msg}", algo.name())));
    match (a, b) {
        (AlgoValues::Ranks(x), AlgoValues::Ranks(y))
        | (AlgoValues::Dists(x), AlgoValues::Dists(y)) => {
            if x.len() != y.len() {
                return fail(format!("length {} vs {}", x.len(), y.len()));
            }
            for i in 0..x.len() {
                let (xi, yi) = (x[i], y[i]);
                if xi.is_infinite() && yi.is_infinite() {
                    continue;
                }
                if (xi - yi).abs() > 1e-4 * (1.0 + xi.abs()) {
                    return fail(format!("value {i}: {xi} vs {yi}"));
                }
            }
            Ok(())
        }
        (AlgoValues::Labels(x), AlgoValues::Labels(y)) => {
            // labels are ID-space dependent; compare partitions
            if partition_sig(x) != partition_sig(y) {
                return fail("component partitions differ".into());
            }
            Ok(())
        }
        _ => fail("kind".into()),
    }
}

/// Canonical partition signature: map each label to the smallest member
/// index of its group.
fn partition_sig(labels: &[u32]) -> Vec<u32> {
    use std::collections::HashMap;
    let mut first: HashMap<u32, u32> = HashMap::new();
    let mut sig = Vec::with_capacity(labels.len());
    for (i, &l) in labels.iter().enumerate() {
        let f = *first.entry(l).or_insert(i as u32);
        sig.push(f);
    }
    sig
}

/// Baseline systems included in the paper's tables.
pub const BASELINE_SYSTEMS: [&str; 5] = ["pregel+", "pregelix", "haloop", "graphchi", "x-stream"];

fn run_baseline(
    system: &str,
    g: &Graph,
    algo: Algo,
    profile: &ClusterProfile,
) -> Result<BaselineRun> {
    match system {
        "pregel+" => baselines::inmem::run(g, algo, profile),
        "pregelix" => baselines::pregelix::run(g, algo, profile),
        "haloop" => baselines::haloop::run(g, algo, profile),
        "graphchi" => baselines::graphchi::run(g, algo, profile),
        "x-stream" => baselines::xstream::run(g, algo, profile),
        other => Err(Error::Config(format!("unknown system {other}"))),
    }
}

/// Produce one full table column-block (GraphD modes + baselines) for a
/// dataset × algorithm on a profile.  Also cross-checks all values.
pub fn bench_combo(
    ds: Dataset,
    algo: Algo,
    profile: &ClusterProfile,
    scale: f64,
    use_xla: bool,
) -> Result<(Vec<Row>, GraphDRuns)> {
    let mut g = ds.generate_scaled(scale);
    if matches!(algo, Algo::Sssp { .. }) {
        g = g.with_unit_weights();
    }
    let algo = match algo {
        Algo::Sssp { .. } => Algo::Sssp {
            source: sssp_source(&g),
        },
        a => a,
    };
    let filter = systems_from_env();
    let included = |name: &str| filter.as_ref().map_or(true, |f| f.iter().any(|x| x == name));

    let mut rows = Vec::new();
    let tag = format!("{}_{}_{}", ds.name(), algo.name(), profile.name);
    let gd = run_graphd(&tag, &g, algo, profile, use_xla)?;
    rows.push(Row {
        system: "IO-Basic".into(),
        preprocess: Cell::NA,
        load: Cell::Secs(gd.basic_load),
        compute: Cell::Secs(gd.basic_compute),
    });
    rows.push(Row {
        system: "IO-Recoding".into(),
        preprocess: Cell::NA,
        load: Cell::Secs(gd.basic_load),
        compute: Cell::Secs(gd.recoding_compute),
    });
    rows.push(Row {
        system: "IO-Recoded".into(),
        preprocess: Cell::Text("ID-Recoding".into()),
        load: Cell::Secs(gd.recoded_load),
        compute: Cell::Secs(gd.recoded_compute),
    });

    for sys in BASELINE_SYSTEMS {
        if !included(sys) {
            continue;
        }
        match run_baseline(sys, &g, algo, profile) {
            Ok(b) => {
                check_equivalent(&gd.values, &b.values, algo)?;
                rows.push(Row {
                    system: display_name(sys).into(),
                    preprocess: if b.preprocess_secs > 0.0 {
                        Cell::Secs(b.preprocess_secs)
                    } else {
                        Cell::NA
                    },
                    load: if b.load_secs > 0.0 {
                        Cell::Secs(b.load_secs)
                    } else {
                        Cell::NA
                    },
                    compute: Cell::Secs(b.compute_secs),
                });
            }
            Err(Error::InsufficientMemory { .. }) => rows.push(Row {
                system: display_name(sys).into(),
                preprocess: Cell::NA,
                load: Cell::Text("Insufficient Main Memories".into()),
                compute: Cell::NA,
            }),
            Err(Error::InsufficientDisk { .. }) => rows.push(Row {
                system: display_name(sys).into(),
                preprocess: Cell::NA,
                load: Cell::Text("Insufficient Disk Space".into()),
                compute: Cell::NA,
            }),
            Err(e) => return Err(e),
        }
    }
    Ok((rows, gd))
}

fn display_name(sys: &str) -> &'static str {
    match sys {
        "pregel+" => "Pregel+",
        "pregelix" => "Pregelix",
        "haloop" => "HaLoop",
        "graphchi" => "GraphChi",
        "x-stream" => "X-Stream",
        _ => "?",
    }
}

/// Render a full paper-style table for several dataset × algo combos.
pub fn render_table(
    title: &str,
    combos: &[(Dataset, Algo)],
    profile: &ClusterProfile,
    scale: f64,
) -> Result<String> {
    let mut out = String::new();
    for (ds, algo) in combos {
        let (rows, gd) = bench_combo(*ds, *algo, profile, scale, use_xla_from_env())?;
        let mut t = Table::new(
            &format!(
                "{title} — {} ({}, {} supersteps)",
                ds.name(),
                algo.name(),
                gd.basic_metrics.supersteps
            ),
            &["Preprocess", "Load", "Compute"],
        );
        for r in rows {
            t.row(&r.system, vec![r.preprocess, r.load, r.compute]);
        }
        out.push_str(&t.render());
        // Table-4 style overlap summary for this combo.
        let (bg, bs) = gd.basic_metrics.m_gene_m_send();
        let (rg, rs) = gd.recoded_metrics.m_gene_m_send();
        out.push_str(&format!(
            "  overlap (machine 0): IO-Basic M-Gene {:.2}s / M-Send {:.2}s; IO-Recoded {:.2}s / {:.2}s\n\n",
            bg, bs, rg, rs
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_sig_invariant_to_relabeling() {
        let a = partition_sig(&[5, 5, 9, 9, 5]);
        let b = partition_sig(&[1, 1, 0, 0, 1]);
        assert_eq!(a, b);
        let c = partition_sig(&[1, 2, 0, 0, 1]);
        assert_ne!(a, c);
    }

    #[test]
    fn bench_combo_smoke_tiny() {
        // End-to-end harness smoke on a tiny scale + test profile.
        let profile = ClusterProfile::test(2);
        let (rows, gd) = bench_combo(Dataset::BtcS, Algo::HashMin, &profile, 0.02, false).unwrap();
        assert!(rows.iter().any(|r| r.system == "IO-Basic"));
        assert!(rows.iter().any(|r| r.system == "Pregel+"));
        assert!(gd.basic_compute >= 0.0);
    }

    #[test]
    fn sssp_source_picks_high_degree() {
        let g = crate::graph::generator::hub_graph(100, 50, 1, 40, false, 3);
        let s = sssp_source(&g);
        assert!(g.degree(s) >= 30);
    }

    #[test]
    fn bench_json_write_then_merge() {
        let p = std::env::temp_dir().join(format!("graphd_bench_json_{}", std::process::id()));
        let p = p.to_str().unwrap();
        bench_json_write(p, "spine", "{\"msgs_per_sec\": 10.5}").unwrap();
        bench_json_merge(p, "serve", "{\"qps\": 3.0}").unwrap();
        let s = std::fs::read_to_string(p).unwrap();
        assert_eq!(
            s.trim(),
            "{\"spine\": {\"msgs_per_sec\": 10.5}, \"serve\": {\"qps\": 3.0}}"
        );
        // Re-merging the same section replaces it (no duplicate keys).
        bench_json_merge(p, "serve", "{\"qps\": 4.5}").unwrap();
        let s = std::fs::read_to_string(p).unwrap();
        assert_eq!(
            s.trim(),
            "{\"spine\": {\"msgs_per_sec\": 10.5}, \"serve\": {\"qps\": 4.5}}"
        );
        bench_json_merge(p, "spine", "{\"msgs_per_sec\": 11.0}").unwrap();
        let s = std::fs::read_to_string(p).unwrap();
        assert_eq!(
            s.trim(),
            "{\"serve\": {\"qps\": 4.5}, \"spine\": {\"msgs_per_sec\": 11.0}}"
        );
        // Merging into a missing file degrades to a fresh write.
        std::fs::remove_file(p).unwrap();
        bench_json_merge(p, "serve", "1").unwrap();
        assert_eq!(std::fs::read_to_string(p).unwrap().trim(), "{\"serve\": 1}");
        std::fs::remove_file(p).unwrap();
    }
}
