//! Per-machine persistent store: vertex-state array `A` + edge stream `S^E`.
//!
//! Layout under `<workdir>/m<i>/<store>/`:
//! * `meta`    — text key=val: counts, flags;
//! * `ids.bin` — sorted current-space vertex IDs (LE u32), absent when the
//!   store is recoded (IDs are implicit: `id = pos·n + i`, §5);
//! * `degs.bin`— degrees (LE u32), aligned with `ids.bin`;
//! * `se.bin`  — the edge stream: adjacency lists concatenated in `A`
//!   order; 4 bytes/item unweighted, 8 bytes (nbr + f32 weight) weighted.
//!
//! Only ids/degs are loaded to RAM for a job (`O(|V|/n)`); `se.bin` is
//! always streamed.

use crate::api::Edge;
use crate::error::{Error, Result};
use crate::stream::{StreamReader, StreamWriter};
use std::path::{Path, PathBuf};

/// Adjacency item byte width.
pub const fn item_size(weighted: bool) -> usize {
    if weighted {
        8
    } else {
        4
    }
}

/// Metadata + in-memory state array of one machine's graph partition.
#[derive(Clone, Debug)]
pub struct MachineStore {
    /// Store directory (`<workdir>/m<i>/<store>/`).
    pub dir: PathBuf,
    /// This machine's index.
    pub machine: usize,
    /// Cluster size n.
    pub num_machines: usize,
    /// Total vertices across the cluster.
    pub total_vertices: u64,
    /// Does `se.bin` carry per-edge weights?
    pub weighted: bool,
    /// Dense recoded IDs? (implicit `pos·n + i`.)
    pub recoded: bool,
    /// Sorted current-space IDs.  For a recoded store this instead holds
    /// the *old* IDs (kept for reporting results in the input ID space);
    /// it may be empty if the input was already dense.
    pub ids: Vec<u32>,
    /// Out-degrees, aligned with positions (and `ids` when present).
    pub degs: Vec<u32>,
}

impl MachineStore {
    /// Path of the edge stream `S^E`.
    pub fn se_path(&self) -> PathBuf {
        self.dir.join("se.bin")
    }

    /// Vertices assigned to this machine, |V(W)|.
    pub fn local_vertices(&self) -> usize {
        self.degs.len()
    }

    /// Current-space ID of the vertex at `pos`.
    #[inline]
    pub fn id_at(&self, pos: usize) -> u32 {
        if self.recoded {
            (pos * self.num_machines + self.machine) as u32
        } else {
            self.ids[pos]
        }
    }

    /// ID to report results under: the original input-space ID.
    #[inline]
    pub fn display_id_at(&self, pos: usize) -> u32 {
        if self.ids.is_empty() {
            self.id_at(pos)
        } else {
            self.ids[pos]
        }
    }

    /// In-memory bytes of the state array (the O(|V|/n) budget).
    pub fn state_bytes(&self) -> u64 {
        (self.ids.len() * 4 + self.degs.len() * 4) as u64
    }

    /// Persist `meta` + `ids.bin` + `degs.bin` (se.bin is written by
    /// [`EdgeStreamWriter`]).
    pub fn save(&self) -> Result<()> {
        std::fs::create_dir_all(&self.dir)?;
        let meta = format!(
            "machine={}\nnum_machines={}\ntotal_vertices={}\nweighted={}\nrecoded={}\nlocal={}\n",
            self.machine,
            self.num_machines,
            self.total_vertices,
            self.weighted,
            self.recoded,
            self.degs.len()
        );
        std::fs::write(self.dir.join("meta"), meta)?;
        write_u32s(&self.dir.join("degs.bin"), &self.degs)?;
        if !self.ids.is_empty() {
            write_u32s(&self.dir.join("ids.bin"), &self.ids)?;
        }
        Ok(())
    }

    /// Load a previously saved store ("loading from local disks", §3.2).
    pub fn load(dir: &Path) -> Result<Self> {
        let meta = std::fs::read_to_string(dir.join("meta"))?;
        let get = |k: &str| -> Result<String> {
            meta.lines()
                .find_map(|l| l.strip_prefix(&format!("{k}=")))
                .map(str::to_string)
                .ok_or_else(|| Error::CorruptStream(format!("meta missing {k}")))
        };
        let parse_err = |k: &str| Error::CorruptStream(format!("bad meta field {k}"));
        let machine: usize = get("machine")?.parse().map_err(|_| parse_err("machine"))?;
        let num_machines: usize = get("num_machines")?
            .parse()
            .map_err(|_| parse_err("num_machines"))?;
        let total_vertices: u64 = get("total_vertices")?
            .parse()
            .map_err(|_| parse_err("total_vertices"))?;
        let weighted: bool = get("weighted")?.parse().map_err(|_| parse_err("weighted"))?;
        let recoded: bool = get("recoded")?.parse().map_err(|_| parse_err("recoded"))?;
        let degs = read_u32s(&dir.join("degs.bin"))?;
        let ids = if dir.join("ids.bin").exists() {
            read_u32s(&dir.join("ids.bin"))?
        } else if recoded {
            Vec::new()
        } else {
            return Err(Error::CorruptStream("non-recoded store missing ids.bin".into()));
        };
        if !recoded && ids.len() != degs.len() {
            return Err(Error::CorruptStream("ids/degs length mismatch".into()));
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            machine,
            num_machines,
            total_vertices,
            weighted,
            recoded,
            ids,
            degs,
        })
    }
}

fn write_u32s(path: &Path, xs: &[u32]) -> Result<()> {
    let mut w = StreamWriter::create(path, 64 * 1024)?;
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    w.finish()?;
    Ok(())
}

fn read_u32s(path: &Path) -> Result<Vec<u32>> {
    let mut r = StreamReader::open(path, 64 * 1024)?;
    let n = (r.len() / 4) as usize;
    let mut out = Vec::with_capacity(n);
    let mut buf = [0u8; 4];
    for _ in 0..n {
        r.read_exact(&mut buf)?;
        out.push(u32::from_le_bytes(buf));
    }
    Ok(out)
}

/// Sequential writer for `se.bin` (adjacency lists in A order).
pub struct EdgeStreamWriter {
    w: StreamWriter,
    weighted: bool,
    items: u64,
}

impl EdgeStreamWriter {
    /// Start writing `se.bin` under `store_dir`.
    pub fn create(store_dir: &Path, weighted: bool, buf: usize) -> Result<Self> {
        Ok(Self {
            w: StreamWriter::create(&store_dir.join("se.bin"), buf)?,
            weighted,
            items: 0,
        })
    }

    /// Append one adjacency item (weight ignored on unweighted stores).
    #[inline]
    pub fn push(&mut self, nbr: u32, weight: f32) -> Result<()> {
        self.w.write_all(&nbr.to_le_bytes())?;
        if self.weighted {
            self.w.write_all(&weight.to_le_bytes())?;
        }
        self.items += 1;
        Ok(())
    }

    /// Items written so far.
    pub fn items(&self) -> u64 {
        self.items
    }

    /// Flush and close; returns the item count.
    pub fn finish(self) -> Result<u64> {
        self.w.finish()?;
        Ok(self.items)
    }
}

/// Streaming cursor over `se.bin`: read Γ(v) for computed vertices, skip
/// over inactive runs (the §3.2 algorithm; the skip lands in the reader's
/// buffer for short runs and costs one seek otherwise).
pub struct EdgeStreamCursor {
    r: StreamReader,
    weighted: bool,
    pending_skip_items: u64,
    items_read: u64,
    items_skipped: u64,
    /// Reusable scratch for whole-adjacency reads: one `read_exact` per
    /// vertex instead of one per item (message-spine hot path).
    scratch: Vec<u8>,
}

impl EdgeStreamCursor {
    /// Open the store's `S^E` with a `buf`-byte read buffer.
    pub fn open(store: &MachineStore, buf: usize) -> Result<Self> {
        Ok(Self {
            r: StreamReader::open(&store.se_path(), buf)?,
            weighted: store.weighted,
            pending_skip_items: 0,
            items_read: 0,
            items_skipped: 0,
            scratch: Vec::new(),
        })
    }

    /// Note that the next `deg` items belong to a vertex that will not
    /// compute — accumulate them into one lazy skip.
    #[inline]
    pub fn defer_skip(&mut self, deg: u32) {
        self.pending_skip_items += deg as u64;
    }

    fn flush_skip(&mut self) -> Result<()> {
        if self.pending_skip_items > 0 {
            let bytes = self.pending_skip_items * item_size(self.weighted) as u64;
            self.r.skip_bytes(bytes)?;
            self.items_skipped += self.pending_skip_items;
            self.pending_skip_items = 0;
        }
        Ok(())
    }

    /// Read the next `deg` items into `out` (cleared first): the whole
    /// adjacency list in one buffered read, then a decode sweep.
    pub fn read_adjacency(&mut self, deg: u32, out: &mut Vec<Edge>) -> Result<()> {
        self.flush_skip()?;
        out.clear();
        out.reserve(deg as usize);
        let isz = item_size(self.weighted);
        self.scratch.resize(deg as usize * isz, 0);
        self.r.read_exact(&mut self.scratch)?;
        if self.weighted {
            for item in self.scratch.chunks_exact(8) {
                out.push(Edge {
                    nbr: u32::from_le_bytes(item[..4].try_into().unwrap()),
                    weight: f32::from_le_bytes(item[4..8].try_into().unwrap()),
                });
            }
        } else {
            for item in self.scratch.chunks_exact(4) {
                out.push(Edge {
                    nbr: u32::from_le_bytes(item.try_into().unwrap()),
                    weight: 1.0,
                });
            }
        }
        self.items_read += deg as u64;
        Ok(())
    }

    /// (items_read, items_skipped, seeks)
    pub fn io_stats(&self) -> (u64, u64, u64) {
        (self.items_read, self.items_skipped, self.r.seeks())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "graphd_store_{name}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_store(dir: &Path, weighted: bool) -> MachineStore {
        let store = MachineStore {
            dir: dir.to_path_buf(),
            machine: 1,
            num_machines: 4,
            total_vertices: 12,
            weighted,
            recoded: false,
            ids: vec![2, 22, 32],
            degs: vec![2, 3, 1],
        };
        store.save().unwrap();
        let mut w = EdgeStreamWriter::create(dir, weighted, 64).unwrap();
        for (i, nbr) in [(0u32, 5u32), (1, 6), (2, 7), (3, 8), (4, 9), (5, 10)] {
            w.push(nbr, i as f32 + 0.5).unwrap();
        }
        w.finish().unwrap();
        store
    }

    #[test]
    fn save_load_roundtrip() {
        let d = tmp("roundtrip");
        let s = sample_store(&d, false);
        let l = MachineStore::load(&d).unwrap();
        assert_eq!(l.ids, s.ids);
        assert_eq!(l.degs, s.degs);
        assert_eq!(l.total_vertices, 12);
        assert_eq!(l.machine, 1);
        assert!(!l.recoded);
        assert_eq!(l.id_at(1), 22);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn recoded_store_implicit_ids() {
        let d = tmp("recoded");
        let mut s = sample_store(&d, false);
        s.recoded = true;
        s.ids.clear();
        s.save().unwrap();
        let l = MachineStore::load(&d).unwrap();
        assert!(l.recoded);
        // pos·n + i with n=4, i=1
        assert_eq!(l.id_at(0), 1);
        assert_eq!(l.id_at(2), 9);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn cursor_reads_and_skips() {
        let d = tmp("cursor");
        let s = sample_store(&d, false);
        let mut c = EdgeStreamCursor::open(&s, 8).unwrap(); // tiny buffer
        let mut edges = Vec::new();
        // read vertex 0 (deg 2): items 5,6
        c.read_adjacency(2, &mut edges).unwrap();
        assert_eq!(edges[0].nbr, 5);
        assert_eq!(edges[1].nbr, 6);
        assert_eq!(edges[1].weight, 1.0);
        // skip vertex 1 (deg 3), read vertex 2 (deg 1): item 10
        c.defer_skip(3);
        c.read_adjacency(1, &mut edges).unwrap();
        assert_eq!(edges[0].nbr, 10);
        let (read, skipped, _) = c.io_stats();
        assert_eq!((read, skipped), (3, 3));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn cursor_weighted_items() {
        let d = tmp("weighted");
        let s = sample_store(&d, true);
        let mut c = EdgeStreamCursor::open(&s, 64).unwrap();
        let mut edges = Vec::new();
        c.read_adjacency(2, &mut edges).unwrap();
        assert_eq!(edges[0], Edge { nbr: 5, weight: 0.5 });
        assert_eq!(edges[1], Edge { nbr: 6, weight: 1.5 });
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn trailing_skip_without_read_ok() {
        let d = tmp("trail");
        let s = sample_store(&d, false);
        let mut c = EdgeStreamCursor::open(&s, 8).unwrap();
        c.defer_skip(6); // whole stream skipped, never flushed — fine
        let (r, sk, _) = c.io_stats();
        assert_eq!((r, sk), (0, 0)); // lazy: nothing actually happened
        let _ = std::fs::remove_dir_all(&d);
    }
}
