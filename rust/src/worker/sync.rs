//! Synchronization plumbing for the parallel framework (§4).
//!
//! Within a machine, the three units coordinate through a single
//! mutex+condvar over step counters ([`MachineSync`]):
//!
//! * `compute_done` — U_c finished generating superstep-s messages, so U_s
//!   may emit end tags for s once the OMS watermarks are drained;
//! * `recv_done`    — U_r received *all* superstep-s messages addressed to
//!   this machine (n end tags), so U_c may compute superstep s+1;
//! * `send_allowed` — the receiving units of all machines synchronized for
//!   superstep s−1, so U_s may start transmitting superstep-s messages
//!   (the paper's rule that step-(i+1) traffic must not delay step-i);
//! * `decided`      — U_c's global control sync for superstep s completed
//!   (carries the job-continue verdict, letting U_s/U_r terminate).
//!
//! Between machines, compute units and receiving units each synchronize
//! through a [`Rendezvous`] barrier (the paper's two independent
//! synchronizations: aggregator/control among U_c's — early; transmission
//! completion among U_r's — late).

use std::sync::{Arc, Condvar, Mutex};

/// Per-machine unit coordination state.
#[derive(Debug)]
pub struct MachineSync {
    state: Mutex<State>,
    cond: Condvar,
}

#[derive(Debug)]
struct State {
    compute_done: i64,
    recv_done: i64,
    send_allowed: i64,
    /// Per-step job-continue verdicts: `verdicts[s]` answers "does the job
    /// continue past superstep s?".  Stored per step — U_c can race one
    /// superstep ahead of U_s/U_r, so "latest verdict" would let a unit
    /// skip its final superstep (a real bug this representation fixes).
    verdicts: Vec<bool>,
    /// Per-destination OMS file watermarks, one entry pushed per superstep:
    /// `watermarks[dst][s]` = first file index NOT belonging to steps ≤ s.
    watermarks: Vec<Vec<u64>>,
    /// A unit died with an error; waiting units panic instead of
    /// deadlocking (the error itself is propagated by the joiner).
    failed: Option<String>,
}

impl MachineSync {
    /// Fresh coordination state for one machine of an `n`-machine job.
    pub fn new(num_machines: usize) -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new(State {
                compute_done: -1,
                recv_done: -1,
                send_allowed: 0, // superstep-0 sending needs no prior sync
                verdicts: Vec::new(),
                watermarks: vec![Vec::new(); num_machines],
                failed: None,
            }),
            cond: Condvar::new(),
        })
    }

    fn update(&self, f: impl FnOnce(&mut State)) {
        let mut st = self.state.lock().unwrap();
        f(&mut st);
        self.cond.notify_all();
    }

    fn wait_until<T>(&self, mut pred: impl FnMut(&State) -> Option<T>) -> T {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(cause) = &st.failed {
                panic!("sibling unit failed: {cause}");
            }
            if let Some(v) = pred(&st) {
                return v;
            }
            st = self.cond.wait(st).unwrap();
        }
    }

    /// Poison the machine: a unit died; wake all waiters so they panic
    /// instead of deadlocking.
    pub fn fail(&self, cause: String) {
        self.update(|st| st.failed = Some(cause));
    }

    // ---- U_c side ----

    /// U_c finished superstep `s`; publish the per-OMS watermarks captured
    /// at finalize time.
    pub fn set_compute_done(&self, s: u64, marks: Vec<u64>) {
        self.update(|st| {
            st.compute_done = s as i64;
            for (dst, m) in marks.into_iter().enumerate() {
                debug_assert_eq!(st.watermarks[dst].len(), s as usize);
                st.watermarks[dst].push(m);
            }
        });
    }

    /// Publish the global control decision for superstep `s`.
    pub fn set_decided(&self, s: u64, continues: bool) {
        self.update(|st| {
            debug_assert_eq!(st.verdicts.len(), s as usize, "decision out of order");
            st.verdicts.push(continues);
        });
    }

    /// U_c blocks until all superstep-`s` messages for this machine arrived.
    pub fn wait_recv_done(&self, s: u64) {
        self.wait_until(|st| (st.recv_done >= s as i64).then_some(()));
    }

    // ---- U_s side ----

    /// U_s blocks until it may transmit superstep-`s` messages.
    pub fn wait_send_allowed(&self, s: u64) {
        self.wait_until(|st| (st.send_allowed >= s as i64).then_some(()));
    }

    /// U_s blocks until U_c finished superstep `s`, returning the OMS
    /// watermarks for `s` (so it can tell step-s files from step-(s+1)).
    pub fn wait_compute_done(&self, s: u64) -> Vec<u64> {
        self.wait_until(|st| {
            (st.compute_done >= s as i64)
                .then(|| st.watermarks.iter().map(|w| w[s as usize]).collect())
        })
    }

    /// Watermark for one destination, if already published.
    pub fn try_watermark(&self, dst: usize, s: u64) -> Option<u64> {
        let st = self.state.lock().unwrap();
        st.watermarks[dst].get(s as usize).copied()
    }

    /// Sleep until new OMS files may exist (notified on every publish);
    /// bounded wait keeps the sender responsive to progress it can't
    /// observe through this condvar (file closes inside SplittableStream).
    /// Panics when the machine is poisoned — the sender's scan loop polls
    /// through here, so this is where it observes a dead sibling instead
    /// of spinning forever on a step that will never complete.
    pub fn idle_wait(&self) {
        let st = self.state.lock().unwrap();
        if let Some(cause) = &st.failed {
            panic!("sibling unit failed: {cause}");
        }
        let _ = self
            .cond
            .wait_timeout(st, std::time::Duration::from_micros(500))
            .unwrap();
    }

    /// Wake any unit in `idle_wait` (U_c calls this after closing OMS files).
    pub fn kick(&self) {
        self.cond.notify_all();
    }

    // ---- U_r side ----

    /// U_r finished receiving superstep `s` for this machine.
    pub fn set_recv_done(&self, s: u64) {
        self.update(|st| st.recv_done = s as i64);
    }

    /// U_r (after the inter-machine barrier) allows superstep-`s` sending.
    pub fn set_send_allowed(&self, s: u64) {
        self.update(|st| st.send_allowed = st.send_allowed.max(s as i64));
    }

    /// Block until the control decision for superstep `s` is published;
    /// returns whether the job continues *past superstep s* (the verdict
    /// for exactly step `s`, even if later steps were already decided).
    pub fn wait_decided(&self, s: u64) -> bool {
        self.wait_until(|st| st.verdicts.get(s as usize).copied())
    }
}

/// Reusable N-party barrier with a leader section: all parties deposit,
/// one (the last to arrive) runs `leader` over the deposits, then everyone
/// observes the result.  (std's Barrier has no deposit/result phase.)
pub struct Rendezvous<T, R> {
    n: usize,
    state: Mutex<RvState<T, R>>,
    cond: Condvar,
}

struct RvState<T, R> {
    round: u64,
    deposits: Vec<Option<T>>,
    result: Option<R>,
    left: usize,
}

impl<T, R: Clone> Rendezvous<T, R> {
    /// An `n`-party barrier.
    pub fn new(n: usize) -> Arc<Self> {
        Arc::new(Self {
            n,
            state: Mutex::new(RvState {
                round: 0,
                deposits: (0..n).map(|_| None).collect(),
                result: None,
                left: 0,
            }),
            cond: Condvar::new(),
        })
    }

    /// Deposit `value` for `who`, run `leader` once all `n` deposited, and
    /// return the (cloned) leader result to every party.
    pub fn exchange(&self, who: usize, value: T, leader: impl FnOnce(Vec<T>) -> R) -> R {
        let mut st = self.state.lock().unwrap();
        // Wait for the previous round's stragglers to pick up their result.
        while st.left > 0 {
            st = self.cond.wait(st).unwrap();
        }
        let round = st.round;
        debug_assert!(st.deposits[who].is_none(), "double deposit by {who}");
        st.deposits[who] = Some(value);
        let arrived = st.deposits.iter().filter(|d| d.is_some()).count();
        if arrived == self.n {
            let vals: Vec<T> = st.deposits.iter_mut().map(|d| d.take().unwrap()).collect();
            let r = leader(vals);
            st.result = Some(r.clone());
            st.left = self.n - 1;
            st.round += 1;
            self.cond.notify_all();
            return r;
        }
        loop {
            st = self.cond.wait(st).unwrap();
            if st.round > round {
                let r = st.result.as_ref().unwrap().clone();
                st.left -= 1;
                if st.left == 0 {
                    st.result = None;
                    self.cond.notify_all();
                }
                return r;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn machine_sync_step_counters() {
        let ms = MachineSync::new(2);
        let ms2 = ms.clone();
        let t = std::thread::spawn(move || {
            ms2.wait_recv_done(0);
            ms2.wait_send_allowed(1);
            true
        });
        ms.set_recv_done(0);
        ms.set_send_allowed(1);
        assert!(t.join().unwrap());
    }

    #[test]
    fn watermarks_per_step() {
        let ms = MachineSync::new(3);
        ms.set_compute_done(0, vec![2, 0, 1]);
        let m = ms.wait_compute_done(0);
        assert_eq!(m, vec![2, 0, 1]);
        assert_eq!(ms.try_watermark(0, 0), Some(2));
        assert_eq!(ms.try_watermark(0, 1), None);
        ms.set_compute_done(1, vec![5, 1, 1]);
        assert_eq!(ms.wait_compute_done(1), vec![5, 1, 1]);
    }

    #[test]
    fn decided_carries_verdict() {
        let ms = MachineSync::new(1);
        ms.set_decided(0, true);
        assert!(ms.wait_decided(0));
        ms.set_decided(1, false);
        assert!(!ms.wait_decided(1));
    }

    #[test]
    fn rendezvous_sums_and_broadcasts() {
        let rv: Arc<Rendezvous<u64, u64>> = Rendezvous::new(4);
        let total = AtomicU64::new(0);
        std::thread::scope(|s| {
            for who in 0..4 {
                let rv = rv.clone();
                let total = &total;
                s.spawn(move || {
                    let r = rv.exchange(who, who as u64 + 1, |vs| vs.iter().sum());
                    total.fetch_add(r, Ordering::SeqCst);
                });
            }
        });
        // each of 4 parties sees 1+2+3+4 = 10
        assert_eq!(total.load(Ordering::SeqCst), 40);
    }

    #[test]
    fn rendezvous_multiple_rounds() {
        let rv: Arc<Rendezvous<u64, u64>> = Rendezvous::new(3);
        std::thread::scope(|s| {
            for who in 0..3 {
                let rv = rv.clone();
                s.spawn(move || {
                    for round in 0..50u64 {
                        let r = rv.exchange(who, round, |vs| {
                            assert!(vs.iter().all(|&v| v == round));
                            round * 3
                        });
                        assert_eq!(r, round * 3);
                    }
                });
            }
        });
    }
}
