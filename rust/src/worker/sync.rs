//! Synchronization plumbing for the parallel framework (§4).
//!
//! Within a machine, the three units coordinate through a single
//! mutex+condvar over step counters ([`MachineSync`]):
//!
//! * `compute_done` — U_c finished generating superstep-s messages, so U_s
//!   may emit end tags for s once the OMS watermarks are drained;
//! * `recv_done`    — U_r received *all* superstep-s messages addressed to
//!   this machine (n end tags), so U_c may compute superstep s+1;
//! * `send_allowed` — the receiving units of all machines synchronized for
//!   superstep s−1, so U_s may start transmitting superstep-s messages
//!   (the paper's rule that step-(i+1) traffic must not delay step-i);
//! * `decided`      — U_c's global control sync for superstep s completed
//!   (carries the job-continue verdict, letting U_s/U_r terminate).
//!
//! Between machines, compute units and receiving units each synchronize
//! through a [`Rendezvous`] barrier (the paper's two independent
//! synchronizations: aggregator/control among U_c's — early; transmission
//! completion among U_r's — late).
//!
//! **Failure propagation.**  Every blocking primitive in this module is
//! *poisonable*: the first unit to die anywhere in the job trips the shared
//! [`JobAbort`], which broadcasts the [`AbortCause`] to every registered
//! [`MachineSync`] and [`Rendezvous`] (and is polled by the channel waits
//! in [`crate::net`]).  All current **and future** waiters unblock with a
//! typed [`crate::error::Error::JobFailed`] instead of wedging — the
//! observability §6's recovery story presumes (see `DESIGN.md`,
//! "Failure propagation").

use crate::error::{Error, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

// ---------------------------------------------------------------------------
// std-poison policy.  The engine distinguishes two poisons:
//
// * *Job* poison — a unit died; broadcast via [`JobAbort`] and surfaced as a
//   typed `Error::JobFailed` by every wait in this module.  Swallowing that
//   `Result` is a bug (`analyze` rule `poison-safety`).
// * *std* poison — a thread panicked while holding one of the runtime's
//   short internal `Mutex`es.  Every unit body runs under
//   [`JobAbort::guard`], which has already caught that panic and tripped
//   the job abort; the unwrap-panic the poison causes in a sibling is then
//   caught by *that* sibling's guard, so it can only echo an
//   already-reported failure — never wedge the job.  (The one closure that
//   runs user-adjacent code under a lock, `Rendezvous::exchange`'s leader
//   merge, itself executes inside a guard and follows the same path.)
//
// The three helpers below centralize every std-poison unwrap in the runtime
// so the sites stay auditable here, instead of scattering `.lock().unwrap()`
// through the hot paths where `analyze` could not tell a reviewed unwrap
// from a new one.

/// Lock one of the runtime's internal mutexes, treating std poison per the
/// policy note above.
pub(crate) fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // analyze:allow(poison-safety): std poison means a sibling panicked
    // under this short internal lock; JobAbort::guard already caught that
    // panic and tripped the abort, so this cascade echoes a reported
    // failure rather than wedging (see the std-poison policy note).
    m.lock().unwrap()
}

/// [`Condvar::wait`] with the same std-poison policy as [`lock_clean`].
pub(crate) fn wait_clean<'a, T>(cond: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    // analyze:allow(poison-safety): same std-poison policy as lock_clean —
    // the guard-caught panic that poisons this condvar has already tripped
    // the job abort.
    cond.wait(g).unwrap()
}

/// [`Condvar::wait_timeout`] with the same std-poison policy as
/// [`lock_clean`]; the timeout flag is dropped because every caller re-checks
/// its predicate (and the abort latch) on wake anyway.
pub(crate) fn wait_timeout_clean<'a, T>(
    cond: &Condvar,
    g: MutexGuard<'a, T>,
    dur: Duration,
) -> MutexGuard<'a, T> {
    // analyze:allow(poison-safety): same std-poison policy as lock_clean —
    // the guard-caught panic that poisons this condvar has already tripped
    // the job abort.
    cond.wait_timeout(g, dur).unwrap().0
}

/// Why a job died: filled in exactly once by the first failing unit and
/// broadcast through [`JobAbort`] to every barrier and channel wait.
#[derive(Clone, Debug)]
pub struct AbortCause {
    /// Machine index of the failing unit.
    pub machine: usize,
    /// Which unit died: `"U_c"`, `"U_s"`, `"U_r"`, `"load"`, `"recode"`.
    pub unit: &'static str,
    /// Superstep (or preprocessing phase) the unit was executing.
    pub superstep: u64,
    /// The underlying failure, rendered.
    pub cause: String,
}

impl AbortCause {
    /// The typed error every poisoned wait surfaces.
    pub fn to_error(&self) -> Error {
        Error::JobFailed {
            machine: self.machine,
            unit: self.unit,
            superstep: self.superstep,
            cause: self.cause.clone(),
        }
    }
}

/// Error payload of a poisoned [`Rendezvous::exchange`].
#[derive(Clone, Debug)]
pub struct Poisoned(
    /// The broadcast abort cause.
    pub Arc<AbortCause>,
);

impl From<Poisoned> for Error {
    fn from(p: Poisoned) -> Self {
        p.0.to_error()
    }
}

/// Anything that can be unblocked with a cause when the job aborts.
pub trait Poisonable: Send + Sync {
    /// Wake all current and future waiters with `cause`.  Idempotent: the
    /// first cause wins, later poisons are no-ops.
    fn poison(&self, cause: Arc<AbortCause>);
}

/// The job-wide abort latch: one per job, shared by every machine.
///
/// The first failing unit calls [`JobAbort::trip`]; every registered
/// [`Poisonable`] (each machine's [`MachineSync`], the inter-machine
/// [`Rendezvous`] barriers) is poisoned, and the flag is polled by the
/// channel/switch waits in [`crate::net`].  Trips after the first keep the
/// original cause — every machine reports the same failure origin.
pub struct JobAbort {
    tripped: AtomicBool,
    cause: Mutex<Option<Arc<AbortCause>>>,
    listeners: Mutex<Vec<Arc<dyn Poisonable>>>,
}

impl JobAbort {
    /// A fresh, untripped latch.
    pub fn new() -> Arc<Self> {
        Arc::new(Self {
            tripped: AtomicBool::new(false),
            cause: Mutex::new(None),
            listeners: Mutex::new(Vec::new()),
        })
    }

    /// Register a barrier/sync for poisoning.  If the latch already
    /// tripped, the listener is poisoned immediately (registration race:
    /// a machine may start after a sibling died).
    pub fn register(&self, l: Arc<dyn Poisonable>) {
        lock_clean(&self.listeners).push(l.clone());
        if let Some(c) = lock_clean(&self.cause).clone() {
            l.poison(c);
        }
    }

    /// Record `cause` (first trip wins) and poison every registered
    /// listener.  Returns the *winning* cause — the one every wait in the
    /// job will report, which may be an earlier trip from another machine.
    pub fn trip(&self, cause: AbortCause) -> Arc<AbortCause> {
        let winner = {
            let mut c = lock_clean(&self.cause);
            match &*c {
                Some(existing) => existing.clone(),
                None => {
                    let a = Arc::new(cause);
                    *c = Some(a.clone());
                    a
                }
            }
        };
        self.tripped.store(true, Ordering::Release);
        let listeners: Vec<Arc<dyn Poisonable>> =
            lock_clean(&self.listeners).clone();
        for l in listeners {
            l.poison(winner.clone());
        }
        winner
    }

    /// Has any unit tripped the latch?  (Polled by the channel waits.)
    pub fn aborted(&self) -> bool {
        self.tripped.load(Ordering::Acquire)
    }

    /// The recorded cause, if tripped.
    pub fn cause(&self) -> Option<Arc<AbortCause>> {
        lock_clean(&self.cause).clone()
    }

    /// The typed error for the recorded *first* cause, or `fallback` when
    /// the latch never tripped.  The per-phase drivers (run/load/recode)
    /// report through this so a propagated echo from whichever machine
    /// happened to be joined first never shadows the failure origin.
    pub fn first_cause_or(&self, fallback: Error) -> Error {
        match self.cause() {
            Some(c) => c.to_error(),
            None => fallback,
        }
    }

    /// Run one unit's body with full failure capture: panics are caught
    /// and converted, any first-order error trips the latch (a propagated
    /// [`Error::JobFailed`] is someone else's abort echoing back — it is
    /// returned as-is, without re-tripping).  `superstep` is the unit's
    /// progress beacon, read at failure time for the [`AbortCause`].
    pub fn guard<T>(
        &self,
        machine: usize,
        unit: &'static str,
        superstep: &AtomicU64,
        f: impl FnOnce() -> Result<T>,
    ) -> Result<T> {
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)).unwrap_or_else(|p| {
            Err(Error::WorkerPanic {
                machine,
                cause: format!("{unit} panicked: {}", panic_message(&p)),
            })
        });
        match r {
            Ok(v) => Ok(v),
            Err(e @ Error::JobFailed { .. }) => Err(e),
            Err(e) => {
                crate::trace::diag("worker", &format!("{unit} of machine {machine} failed: {e}"));
                let winner = self.trip(AbortCause {
                    machine,
                    unit,
                    superstep: superstep.load(Ordering::Relaxed),
                    cause: e.to_string(),
                });
                Err(winner.to_error())
            }
        }
    }

    /// The abort seam for auto-resume: a **fresh, untripped latch** for the
    /// retry attempt.
    ///
    /// A tripped `JobAbort` — and everything registered on it — is
    /// single-use by design: `trip` is first-cause-wins and `poison` is
    /// sticky, so reusing the latch (or any `Rendezvous`/`MachineSync`
    /// registered on it) would make every wait of the retry fail instantly
    /// with the *previous* attempt's cause.  The retry must rebuild its
    /// barriers and syncs from scratch and register them on the latch this
    /// returns; the engine enforces the seam by refusing a caller-supplied
    /// latch that has already tripped.  (The `barrier-registration`
    /// analyzer rule's single-job pairing argument stays intact: each
    /// attempt is a whole new latch + listener set, never a reused one.)
    pub fn reset_for_retry(&self) -> Arc<JobAbort> {
        debug_assert!(
            self.aborted(),
            "reset_for_retry is for replacing a tripped latch between attempts"
        );
        JobAbort::new()
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Per-machine unit coordination state.
#[derive(Debug)]
pub struct MachineSync {
    state: Mutex<State>,
    cond: Condvar,
}

#[derive(Debug)]
struct State {
    compute_done: i64,
    recv_done: i64,
    send_allowed: i64,
    /// Per-step job-continue verdicts: `verdicts[s]` answers "does the job
    /// continue past superstep s?".  Stored per step — U_c can race one
    /// superstep ahead of U_s/U_r, so "latest verdict" would let a unit
    /// skip its final superstep (a real bug this representation fixes).
    verdicts: Vec<bool>,
    /// Per-destination OMS file watermarks, one entry pushed per superstep:
    /// `watermarks[dst][s]` = first file index NOT belonging to steps ≤ s.
    watermarks: Vec<Vec<u64>>,
    /// A unit died somewhere in the job; waiting units return the typed
    /// error instead of deadlocking.
    failed: Option<Arc<AbortCause>>,
}

impl MachineSync {
    /// Fresh coordination state for one machine of an `n`-machine job.
    pub fn new(num_machines: usize) -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new(State {
                compute_done: -1,
                recv_done: -1,
                send_allowed: 0, // superstep-0 sending needs no prior sync
                verdicts: Vec::new(),
                watermarks: vec![Vec::new(); num_machines],
                failed: None,
            }),
            cond: Condvar::new(),
        })
    }

    fn update(&self, f: impl FnOnce(&mut State)) {
        let mut st = lock_clean(&self.state);
        f(&mut st);
        self.cond.notify_all();
    }

    fn wait_until<T>(&self, mut pred: impl FnMut(&State) -> Option<T>) -> Result<T> {
        let mut st = lock_clean(&self.state);
        loop {
            if let Some(cause) = &st.failed {
                return Err(cause.to_error());
            }
            if let Some(v) = pred(&st) {
                return Ok(v);
            }
            st = wait_clean(&self.cond, st);
        }
    }

    /// Poison the machine: a unit died somewhere in the job; wake all
    /// waiters so they surface the typed error instead of deadlocking.
    /// First cause wins (idempotent).
    pub fn fail(&self, cause: Arc<AbortCause>) {
        self.update(|st| {
            if st.failed.is_none() {
                st.failed = Some(cause);
            }
        });
    }

    // ---- U_c side ----

    /// U_c finished superstep `s`; publish the per-OMS watermarks captured
    /// at finalize time.
    pub fn set_compute_done(&self, s: u64, marks: Vec<u64>) {
        self.update(|st| {
            st.compute_done = s as i64;
            for (dst, m) in marks.into_iter().enumerate() {
                debug_assert_eq!(st.watermarks[dst].len(), s as usize);
                st.watermarks[dst].push(m);
            }
        });
    }

    /// Publish the global control decision for superstep `s`.
    pub fn set_decided(&self, s: u64, continues: bool) {
        self.update(|st| {
            debug_assert_eq!(st.verdicts.len(), s as usize, "decision out of order");
            st.verdicts.push(continues);
        });
    }

    /// U_c blocks until all superstep-`s` messages for this machine arrived.
    pub fn wait_recv_done(&self, s: u64) -> Result<()> {
        self.wait_until(|st| (st.recv_done >= s as i64).then_some(()))
    }

    // ---- U_s side ----

    /// U_s blocks until it may transmit superstep-`s` messages.
    pub fn wait_send_allowed(&self, s: u64) -> Result<()> {
        self.wait_until(|st| (st.send_allowed >= s as i64).then_some(()))
    }

    /// U_s blocks until U_c finished superstep `s`, returning the OMS
    /// watermarks for `s` (so it can tell step-s files from step-(s+1)).
    pub fn wait_compute_done(&self, s: u64) -> Result<Vec<u64>> {
        self.wait_until(|st| {
            (st.compute_done >= s as i64)
                .then(|| st.watermarks.iter().map(|w| w[s as usize]).collect())
        })
    }

    /// Watermark for one destination, if already published.
    pub fn try_watermark(&self, dst: usize, s: u64) -> Option<u64> {
        let st = lock_clean(&self.state);
        st.watermarks[dst].get(s as usize).copied()
    }

    /// Sleep until new OMS files may exist (notified on every publish);
    /// bounded wait keeps the sender responsive to progress it can't
    /// observe through this condvar (file closes inside SplittableStream).
    /// Errors when the machine is poisoned — the sender's scan loop polls
    /// through here, so this is where it observes a dead sibling instead
    /// of spinning forever on a step that will never complete.  The poison
    /// flag is checked on entry **and** after the timed wait: a poison that
    /// lands while the sender sleeps must not buy it another scan pass over
    /// a step that will never finish.
    pub fn idle_wait(&self) -> Result<()> {
        let st = lock_clean(&self.state);
        if let Some(cause) = &st.failed {
            return Err(cause.to_error());
        }
        let st = wait_timeout_clean(&self.cond, st, Duration::from_micros(500));
        if let Some(cause) = &st.failed {
            return Err(cause.to_error());
        }
        Ok(())
    }

    /// Wake any unit in `idle_wait` (U_c calls this after closing OMS files).
    pub fn kick(&self) {
        self.cond.notify_all();
    }

    // ---- U_r side ----

    /// U_r finished receiving superstep `s` for this machine.
    pub fn set_recv_done(&self, s: u64) {
        self.update(|st| st.recv_done = s as i64);
    }

    /// U_r (after the inter-machine barrier) allows superstep-`s` sending.
    pub fn set_send_allowed(&self, s: u64) {
        self.update(|st| st.send_allowed = st.send_allowed.max(s as i64));
    }

    /// Block until the control decision for superstep `s` is published;
    /// returns whether the job continues *past superstep s* (the verdict
    /// for exactly step `s`, even if later steps were already decided).
    pub fn wait_decided(&self, s: u64) -> Result<bool> {
        self.wait_until(|st| st.verdicts.get(s as usize).copied())
    }
}

impl Poisonable for MachineSync {
    fn poison(&self, cause: Arc<AbortCause>) {
        self.fail(cause);
    }
}

/// The cross-process side of a distributed [`Rendezvous`]: a control-plane
/// carrier for barrier rounds, implemented by
/// [`crate::net::tcp::TcpCluster`].  Rounds are keyed by `(bid, seq)` —
/// the barrier's fixed id plus its per-round sequence number — so reports
/// from different barriers (or late frames from a previous round) can
/// never be confused.
///
/// Followers call `send_report`/`recv_decision`; the leader (rank 0) calls
/// `recv_reports`/`send_decision`.  Every receive blocks until the round
/// completes, observing the job's abort latch, and returns the typed abort
/// error once it trips — an implementation must never wedge on a dead
/// peer.
pub trait BarrierLink: Send + Sync {
    /// Follower → leader: deposit this rank's encoded value for the round.
    fn send_report(&self, bid: u8, seq: u64, payload: Vec<u8>) -> Result<()>;
    /// Leader: block until all `n−1` follower reports for the round have
    /// arrived; returns them ordered by rank (index 0 = rank 1).
    fn recv_reports(&self, bid: u8, seq: u64) -> Result<Vec<Vec<u8>>>;
    /// Leader → all followers: broadcast the encoded leader result.
    fn send_decision(&self, bid: u8, seq: u64, payload: Vec<u8>) -> Result<()>;
    /// Follower: block until the round's decision arrives.
    fn recv_decision(&self, bid: u8, seq: u64) -> Result<Vec<u8>>;
}

/// Wire codec for one distributed [`Rendezvous`]: how to encode/decode the
/// deposit type `T` and the leader-result type `R`.  Boxed closures rather
/// than a trait so `units.rs` can capture the vertex program's aggregator
/// codec hooks ([`crate::api::VertexProgram::encode_agg`]) without new
/// generic plumbing.
pub struct RvCodec<T, R> {
    /// Encode a deposit.
    pub enc_t: Box<dyn Fn(&T) -> Vec<u8> + Send + Sync>,
    /// Decode a deposit.
    pub dec_t: Box<dyn Fn(&[u8]) -> T + Send + Sync>,
    /// Encode a leader result.
    pub enc_r: Box<dyn Fn(&R) -> Vec<u8> + Send + Sync>,
    /// Decode a leader result.
    pub dec_r: Box<dyn Fn(&[u8]) -> R + Send + Sync>,
}

impl RvCodec<(), ()> {
    /// The codec for pure-synchronization barriers (`T = R = ()`), whose
    /// payloads are empty.
    pub fn unit() -> Self {
        RvCodec {
            enc_t: Box::new(|_| Vec::new()),
            dec_t: Box::new(|_| ()),
            enc_r: Box::new(|_| Vec::new()),
            dec_r: Box::new(|_| ()),
        }
    }
}

/// The distributed half of a [`Rendezvous`]: which rank this process is,
/// the barrier's wire id, the control-plane carrier, and the codec.
struct RemoteEdge<T, R> {
    rank: usize,
    bid: u8,
    link: Arc<dyn BarrierLink>,
    codec: RvCodec<T, R>,
}

/// Reusable N-party barrier with a leader section: all parties deposit,
/// one (the last to arrive) runs `leader` over the deposits, then everyone
/// observes the result.  (std's Barrier has no deposit/result phase.)
///
/// The barrier is *poisonable*: once any party (or the job's [`JobAbort`])
/// calls [`Rendezvous::poison`], every current and future
/// [`Rendezvous::exchange`] returns `Err(Poisoned)` with the cause — this
/// is what converts "a sibling machine died mid-superstep" from a
/// permanent wedge into a typed error at every surviving machine.
///
/// A barrier built with [`Rendezvous::remote`] spans *processes*: exactly
/// one party is local (this process's rank) and the other `n−1` deposits
/// travel a [`BarrierLink`].  The exchange contract is identical — same
/// leader-section semantics (the leader closure runs on rank 0, over
/// deposits ordered by rank), same poisoned-error path — which is what
/// lets `worker/units.rs` run unmodified on both transports.
pub struct Rendezvous<T, R> {
    n: usize,
    state: Mutex<RvState<T, R>>,
    cond: Condvar,
    remote: Option<RemoteEdge<T, R>>,
}

struct RvState<T, R> {
    round: u64,
    deposits: Vec<Option<T>>,
    result: Option<R>,
    left: usize,
    poisoned: Option<Arc<AbortCause>>,
}

impl<T, R: Clone> Rendezvous<T, R> {
    /// An `n`-party barrier (all parties are threads in this process).
    pub fn new(n: usize) -> Arc<Self> {
        Self::build(n, None)
    }

    /// An `n`-party barrier spanning processes: this process deposits as
    /// party `rank`, the other `n−1` deposits travel `link` as rounds of
    /// barrier `bid` (encoded via `codec`).  The leader closure runs on
    /// rank 0 over all `n` deposits ordered by rank.  Register the result
    /// on the job's [`JobAbort`] like any local barrier — poison makes the
    /// *local* party's future exchanges fail fast, while in-flight link
    /// waits observe the latch through the link itself.
    pub fn remote(
        n: usize,
        rank: usize,
        bid: u8,
        link: Arc<dyn BarrierLink>,
        codec: RvCodec<T, R>,
    ) -> Arc<Self> {
        Self::build(
            n,
            Some(RemoteEdge {
                rank,
                bid,
                link,
                codec,
            }),
        )
    }

    fn build(n: usize, remote: Option<RemoteEdge<T, R>>) -> Arc<Self> {
        Arc::new(Self {
            n,
            state: Mutex::new(RvState {
                round: 0,
                deposits: (0..n).map(|_| None).collect(),
                result: None,
                left: 0,
                poisoned: None,
            }),
            cond: Condvar::new(),
            remote,
        })
    }

    /// Poison the barrier with `cause`: all current and future parties
    /// unblock with `Err(Poisoned)`.  First cause wins (idempotent).
    pub fn poison(&self, cause: Arc<AbortCause>) {
        let mut st = lock_clean(&self.state);
        if st.poisoned.is_none() {
            st.poisoned = Some(cause);
        }
        self.cond.notify_all();
    }

    /// Deposit `value` for `who`, run `leader` once all `n` deposited, and
    /// return the (cloned) leader result to every party — or
    /// `Err(Poisoned)` if the barrier was poisoned before, while, or after
    /// this party arrived (a dead sibling can never complete the round).
    pub fn exchange(
        &self,
        who: usize,
        value: T,
        leader: impl FnOnce(Vec<T>) -> R,
    ) -> std::result::Result<R, Poisoned> {
        if self.remote.is_some() {
            return self.exchange_remote(value, leader);
        }
        let mut st = lock_clean(&self.state);
        // Wait for the previous round's stragglers to pick up their result.
        loop {
            if let Some(c) = &st.poisoned {
                return Err(Poisoned(c.clone()));
            }
            if st.left == 0 {
                break;
            }
            st = wait_clean(&self.cond, st);
        }
        let round = st.round;
        debug_assert!(st.deposits[who].is_none(), "double deposit by {who}");
        st.deposits[who] = Some(value);
        let arrived = st.deposits.iter().filter(|d| d.is_some()).count();
        if arrived == self.n {
            let vals: Vec<T> = st.deposits.iter_mut().map(|d| d.take().unwrap()).collect();
            let r = leader(vals);
            st.result = Some(r.clone());
            st.left = self.n - 1;
            st.round += 1;
            self.cond.notify_all();
            return Ok(r);
        }
        loop {
            st = wait_clean(&self.cond, st);
            if let Some(c) = &st.poisoned {
                return Err(Poisoned(c.clone()));
            }
            if st.round > round {
                let r = st.result.as_ref().unwrap().clone();
                st.left -= 1;
                if st.left == 0 {
                    st.result = None;
                    self.cond.notify_all();
                }
                return Ok(r);
            }
        }
    }

    /// The distributed exchange path: one local party, `n−1` remote ones
    /// over the [`BarrierLink`].  `state.round` still advances per
    /// exchange — it is the round's wire sequence number, so both sides of
    /// every link wait agree on which round a frame belongs to.
    fn exchange_remote(
        &self,
        value: T,
        leader: impl FnOnce(Vec<T>) -> R,
    ) -> std::result::Result<R, Poisoned> {
        let edge = self.remote.as_ref().unwrap();
        let seq = {
            let mut st = lock_clean(&self.state);
            if let Some(c) = &st.poisoned {
                return Err(Poisoned(c.clone()));
            }
            let s = st.round;
            st.round += 1;
            s
        };
        // A link error means the cluster already tripped the job abort (a
        // BarrierLink must not wedge); reconstruct the broadcast cause so
        // exchange's error contract matches the local path.
        let fail = |e: Error| match e {
            Error::JobFailed {
                machine,
                unit,
                superstep,
                cause,
            } => Poisoned(Arc::new(AbortCause {
                machine,
                unit,
                superstep,
                cause,
            })),
            other => Poisoned(Arc::new(AbortCause {
                machine: edge.rank,
                unit: "net",
                superstep: seq,
                cause: other.to_string(),
            })),
        };
        if edge.rank == 0 {
            let reports = edge.link.recv_reports(edge.bid, seq).map_err(fail)?;
            debug_assert_eq!(reports.len(), self.n - 1, "short barrier round");
            let mut vals = Vec::with_capacity(self.n);
            vals.push(value);
            for r in &reports {
                vals.push((edge.codec.dec_t)(r));
            }
            let out = leader(vals);
            edge.link
                .send_decision(edge.bid, seq, (edge.codec.enc_r)(&out))
                .map_err(fail)?;
            Ok(out)
        } else {
            edge.link
                .send_report(edge.bid, seq, (edge.codec.enc_t)(&value))
                .map_err(fail)?;
            let d = edge.link.recv_decision(edge.bid, seq).map_err(fail)?;
            Ok((edge.codec.dec_r)(&d))
        }
    }
}

impl<T: Send, R: Send + Clone> Poisonable for Rendezvous<T, R> {
    fn poison(&self, cause: Arc<AbortCause>) {
        Rendezvous::poison(self, cause);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn machine_sync_step_counters() {
        let ms = MachineSync::new(2);
        let ms2 = ms.clone();
        let t = std::thread::spawn(move || {
            ms2.wait_recv_done(0).unwrap();
            ms2.wait_send_allowed(1).unwrap();
            true
        });
        ms.set_recv_done(0);
        ms.set_send_allowed(1);
        assert!(t.join().unwrap());
    }

    #[test]
    fn watermarks_per_step() {
        let ms = MachineSync::new(3);
        ms.set_compute_done(0, vec![2, 0, 1]);
        let m = ms.wait_compute_done(0).unwrap();
        assert_eq!(m, vec![2, 0, 1]);
        assert_eq!(ms.try_watermark(0, 0), Some(2));
        assert_eq!(ms.try_watermark(0, 1), None);
        ms.set_compute_done(1, vec![5, 1, 1]);
        assert_eq!(ms.wait_compute_done(1).unwrap(), vec![5, 1, 1]);
    }

    #[test]
    fn decided_carries_verdict() {
        let ms = MachineSync::new(1);
        ms.set_decided(0, true);
        assert!(ms.wait_decided(0).unwrap());
        ms.set_decided(1, false);
        assert!(!ms.wait_decided(1).unwrap());
    }

    #[test]
    fn reset_for_retry_hands_out_fresh_untripped_latch() {
        let abort = JobAbort::new();
        let ms = Arc::new(MachineSync::new(1));
        abort.register(ms.clone());
        abort.trip(AbortCause {
            machine: 0,
            unit: "U_s",
            superstep: 3,
            cause: "I/O error: injected".into(),
        });
        assert!(abort.aborted());
        // The old latch's listeners are poisoned for good…
        assert!(ms.wait_send_allowed(0).is_err());
        // …but the retry latch starts clean, with no listeners or cause.
        let retry = abort.reset_for_retry();
        assert!(!retry.aborted());
        assert!(retry.cause().is_none());
        let ms2 = Arc::new(MachineSync::new(1));
        retry.register(ms2.clone());
        ms2.set_send_allowed(0);
        assert!(ms2.wait_send_allowed(0).is_ok());
    }

    #[test]
    fn rendezvous_sums_and_broadcasts() {
        let rv: Arc<Rendezvous<u64, u64>> = Rendezvous::new(4);
        let total = AtomicU64::new(0);
        std::thread::scope(|s| {
            for who in 0..4 {
                let rv = rv.clone();
                let total = &total;
                s.spawn(move || {
                    let r = rv.exchange(who, who as u64 + 1, |vs| vs.iter().sum()).unwrap();
                    total.fetch_add(r, Ordering::SeqCst);
                });
            }
        });
        // each of 4 parties sees 1+2+3+4 = 10
        assert_eq!(total.load(Ordering::SeqCst), 40);
    }

    #[test]
    fn rendezvous_multiple_rounds() {
        let rv: Arc<Rendezvous<u64, u64>> = Rendezvous::new(3);
        std::thread::scope(|s| {
            for who in 0..3 {
                let rv = rv.clone();
                s.spawn(move || {
                    for round in 0..50u64 {
                        let r = rv
                            .exchange(who, round, |vs| {
                                assert!(vs.iter().all(|&v| v == round));
                                round * 3
                            })
                            .unwrap();
                        assert_eq!(r, round * 3);
                    }
                });
            }
        });
    }

    /// In-process [`BarrierLink`] stub: one shared hub, one handle per
    /// rank — the trait-level contract (ordering by rank, keying by
    /// `(bid, seq)`) exercised without sockets.
    struct Hub {
        n: usize,
        state: Mutex<HubState>,
        cond: Condvar,
    }
    #[derive(Default)]
    struct HubState {
        reports: std::collections::HashMap<(u8, u64), Vec<Option<Vec<u8>>>>,
        decisions: std::collections::HashMap<(u8, u64), Vec<u8>>,
    }
    struct HubLink {
        rank: usize,
        hub: Arc<Hub>,
    }
    impl BarrierLink for HubLink {
        fn send_report(&self, bid: u8, seq: u64, payload: Vec<u8>) -> Result<()> {
            let mut st = lock_clean(&self.hub.state);
            let slot = st
                .reports
                .entry((bid, seq))
                .or_insert_with(|| vec![None; self.hub.n - 1]);
            slot[self.rank - 1] = Some(payload);
            self.hub.cond.notify_all();
            Ok(())
        }
        fn recv_reports(&self, bid: u8, seq: u64) -> Result<Vec<Vec<u8>>> {
            let mut st = lock_clean(&self.hub.state);
            loop {
                let full = st
                    .reports
                    .get(&(bid, seq))
                    .is_some_and(|v| v.iter().all(|p| p.is_some()));
                if full {
                    let v = st.reports.remove(&(bid, seq)).unwrap();
                    return Ok(v.into_iter().map(|p| p.unwrap()).collect());
                }
                st = wait_clean(&self.hub.cond, st);
            }
        }
        fn send_decision(&self, bid: u8, seq: u64, payload: Vec<u8>) -> Result<()> {
            let mut st = lock_clean(&self.hub.state);
            st.decisions.insert((bid, seq), payload);
            self.hub.cond.notify_all();
            Ok(())
        }
        fn recv_decision(&self, bid: u8, seq: u64) -> Result<Vec<u8>> {
            let mut st = lock_clean(&self.hub.state);
            loop {
                if let Some(d) = st.decisions.get(&(bid, seq)) {
                    return Ok(d.clone());
                }
                st = wait_clean(&self.hub.cond, st);
            }
        }
    }

    #[test]
    fn remote_rendezvous_matches_local_contract() {
        let n = 3;
        let hub = Arc::new(Hub {
            n,
            state: Mutex::new(HubState::default()),
            cond: Condvar::new(),
        });
        let codec = || RvCodec::<u64, u64> {
            enc_t: Box::new(|v| v.to_le_bytes().to_vec()),
            dec_t: Box::new(|b| u64::from_le_bytes(b.try_into().unwrap())),
            enc_r: Box::new(|v| v.to_le_bytes().to_vec()),
            dec_r: Box::new(|b| u64::from_le_bytes(b.try_into().unwrap())),
        };
        std::thread::scope(|s| {
            for rank in 0..n {
                let link = Arc::new(HubLink {
                    rank,
                    hub: hub.clone(),
                });
                let rv = Rendezvous::remote(n, rank, 1, link, codec());
                s.spawn(move || {
                    for round in 0..20u64 {
                        let r = rv
                            .exchange(rank, round * 10 + rank as u64, |vs| {
                                // Leader section runs on rank 0 only, over
                                // deposits ordered by rank.
                                assert_eq!(vs, vec![round * 10, round * 10 + 1, round * 10 + 2]);
                                vs.iter().sum::<u64>()
                            })
                            .unwrap();
                        assert_eq!(r, round * 30 + 3);
                    }
                });
            }
        });
    }

    #[test]
    fn remote_rendezvous_poison_fails_fast() {
        let hub = Arc::new(Hub {
            n: 2,
            state: Mutex::new(HubState::default()),
            cond: Condvar::new(),
        });
        let link = Arc::new(HubLink { rank: 1, hub });
        let rv: Arc<Rendezvous<(), ()>> = Rendezvous::remote(2, 1, 2, link, RvCodec::unit());
        rv.poison(cause("remote dead"));
        let err = rv.exchange(1, (), |_| ()).unwrap_err();
        assert_eq!(err.0.cause, "remote dead");
    }

    fn cause(tag: &str) -> Arc<AbortCause> {
        Arc::new(AbortCause {
            machine: 2,
            unit: "U_c",
            superstep: 7,
            cause: tag.to_string(),
        })
    }

    #[test]
    fn rendezvous_poison_before_arrival() {
        let rv: Arc<Rendezvous<u64, u64>> = Rendezvous::new(3);
        rv.poison(cause("pre"));
        // Every party that arrives after the poison errors immediately.
        for who in 0..3 {
            let err = rv.exchange(who, 0, |_| 0).unwrap_err();
            assert_eq!(err.0.cause, "pre");
            assert_eq!(err.0.machine, 2);
        }
    }

    #[test]
    fn rendezvous_poison_unblocks_waiting_party() {
        let rv: Arc<Rendezvous<u64, u64>> = Rendezvous::new(2);
        let rv2 = rv.clone();
        let t = std::thread::spawn(move || rv2.exchange(0, 1, |_| 0));
        // Give the party time to block, then poison instead of arriving.
        std::thread::sleep(std::time::Duration::from_millis(20));
        rv.poison(cause("mid"));
        let err = t.join().unwrap().unwrap_err();
        assert_eq!(err.0.cause, "mid");
        // And the barrier stays dead for later rounds.
        assert!(rv.exchange(1, 9, |_| 0).is_err());
    }

    #[test]
    fn rendezvous_poison_after_completed_round() {
        let rv: Arc<Rendezvous<u64, u64>> = Rendezvous::new(2);
        std::thread::scope(|s| {
            for who in 0..2 {
                let rv = rv.clone();
                s.spawn(move || {
                    assert_eq!(rv.exchange(who, 1, |vs| vs.iter().sum()).unwrap(), 2);
                });
            }
        });
        // A poison landing after a clean round still kills future rounds.
        rv.poison(cause("post"));
        let err = rv.exchange(0, 1, |_| 0u64).unwrap_err();
        assert_eq!(err.0.cause, "post");
        assert_eq!(err.0.superstep, 7);
    }

    #[test]
    fn rendezvous_first_poison_wins() {
        let rv: Arc<Rendezvous<u64, u64>> = Rendezvous::new(2);
        rv.poison(cause("first"));
        rv.poison(cause("second"));
        let err = rv.exchange(0, 0, |_| 0).unwrap_err();
        assert_eq!(err.0.cause, "first");
    }

    #[test]
    fn machine_sync_poison_unblocks_and_sticks() {
        let ms = MachineSync::new(2);
        let ms2 = ms.clone();
        let t = std::thread::spawn(move || ms2.wait_recv_done(0));
        std::thread::sleep(std::time::Duration::from_millis(20));
        ms.fail(cause("dead sibling"));
        let err = t.join().unwrap().unwrap_err();
        assert!(matches!(err, crate::error::Error::JobFailed { machine: 2, .. }));
        // idle_wait observes the poison too (entry check).
        assert!(ms.idle_wait().is_err());
        // Future waits fail as well, even for already-published steps.
        ms.set_recv_done(3);
        assert!(ms.wait_recv_done(3).is_err());
    }

    #[test]
    fn idle_wait_observes_poison_after_timeout() {
        // Poison lands while the sender sleeps inside idle_wait: the
        // post-timeout re-check must surface it on that same call.
        let ms = MachineSync::new(1);
        let ms2 = ms.clone();
        let t = std::thread::spawn(move || -> crate::error::Result<()> {
            // Loop like the sender's scan loop does; the poison must break
            // us out with an error, not let us spin.
            loop {
                ms2.idle_wait()?;
            }
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        ms.fail(cause("late"));
        assert!(t.join().unwrap().is_err());
    }

    #[test]
    fn job_abort_trips_once_and_poisons_registered() {
        let abort = JobAbort::new();
        let rv: Arc<Rendezvous<u64, u64>> = Rendezvous::new(2);
        let ms = MachineSync::new(1);
        abort.register(rv.clone());
        abort.register(ms.clone());
        assert!(!abort.aborted());
        let w = abort.trip(AbortCause {
            machine: 0,
            unit: "U_r",
            superstep: 3,
            cause: "io".into(),
        });
        assert_eq!(w.cause, "io");
        assert!(abort.aborted());
        // Both listeners are poisoned with the tripped cause.
        assert!(rv.exchange(0, 0, |_| 0).is_err());
        assert!(ms.wait_recv_done(0).is_err());
        // Second trip keeps the first cause.
        let w2 = abort.trip(AbortCause {
            machine: 1,
            unit: "U_s",
            superstep: 4,
            cause: "later".into(),
        });
        assert_eq!(w2.cause, "io");
        // Late registration is poisoned immediately.
        let late = MachineSync::new(1);
        abort.register(late.clone());
        assert!(late.wait_recv_done(0).is_err());
    }
}
