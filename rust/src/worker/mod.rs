//! Per-machine worker runtime: on-disk stores, the three parallel units
//! (`U_c` compute / `U_s` send / `U_r` receive, §4) and the superstep loop.
//!
//! Submodules:
//! * [`storage`] — the machine's persistent state: vertex-state array `A`
//!   (ids/degrees, kept in memory during jobs) + the edge stream `S^E`.
//! * [`sync`] — the condition-variable plumbing between units and the
//!   global barriers between machines.
//! * [`units`] — the unit bodies and the per-machine job driver.
//! * [`fault`] — deterministic fault injection for recovery testing.
//! * [`csr`] — the resident adjacency store: the graph materialized as
//!   mmap-able CSR files (`-c resident=`, semi-external-memory mode).

pub mod csr;
pub mod fault;
pub mod storage;
pub mod sync;
pub mod units;

pub use storage::{EdgeStreamWriter, MachineStore};

/// Vertex-to-machine partitioning.
///
/// Normal mode hashes arbitrary (possibly sparse) IDs with a Fibonacci
/// multiplicative hash; recoded mode *must* use `id mod n` so that machine
/// and array position are computable from the ID alone (§5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partitioning {
    /// Fibonacci multiplicative hash over arbitrary (sparse) input IDs.
    Hashed,
    /// `id mod n` over dense recoded IDs (§5).
    Modulo,
}

impl Partitioning {
    /// Which machine owns vertex `id` in an `n`-machine cluster.
    #[inline]
    pub fn machine_of(&self, id: u32, n: usize) -> usize {
        match self {
            Partitioning::Hashed => {
                ((id as u64).wrapping_mul(11400714819323198485) >> 33) as usize % n
            }
            Partitioning::Modulo => id as usize % n,
        }
    }

    /// Position of a recoded vertex in its machine's state array A (§5):
    /// `pos = id / n` (valid for `Modulo` only).
    #[inline]
    pub fn position_of(&self, id: u32, n: usize) -> usize {
        debug_assert_eq!(*self, Partitioning::Modulo);
        id as usize / n
    }

    /// Recoded ID of the vertex at `pos` on machine `i`: `n·pos + i` (§5).
    #[inline]
    pub fn id_at(&self, pos: usize, machine: usize, n: usize) -> u32 {
        debug_assert_eq!(*self, Partitioning::Modulo);
        (pos * n + machine) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modulo_bijection() {
        let n = 5;
        let p = Partitioning::Modulo;
        for id in 0..1000u32 {
            let m = p.machine_of(id, n);
            let pos = p.position_of(id, n);
            assert_eq!(p.id_at(pos, m, n), id);
        }
    }

    #[test]
    fn hashed_is_reasonably_balanced() {
        let n = 8;
        let p = Partitioning::Hashed;
        let mut counts = vec![0usize; n];
        // sparse ids with regular stride — the case plain modulo handles badly
        for i in 0..10_000u32 {
            counts[p.machine_of(i * 16 + 2, n)] += 1;
        }
        let (mn, mx) = (
            *counts.iter().min().unwrap(),
            *counts.iter().max().unwrap(),
        );
        // Lemma 1: max load < 2·|V|/n with overwhelming probability
        assert!(mx < 2 * 10_000 / n, "max={mx}");
        assert!(mn > 0);
    }
}
