//! The three parallel units per machine (§4) and the per-machine job driver.
//!
//! * **U_c** (compute): streams `S^E` + the incoming messages of the
//!   previous superstep, calls `compute()` (or the vectorized
//!   `block_update` on the XLA kernels in recoded mode), and appends raw
//!   outgoing messages to one OMS per destination machine.  It synchronizes
//!   aggregator/control data with the other compute units *early* — right
//!   after computation — so superstep i+1 can start while superstep-i
//!   messages are still in flight.
//! * **U_s** (send): ring-scans the OMSs (§3.3.1 "Sending Strategies"),
//!   ships fully-written files.  With a combiner it combines all pending
//!   files of an OMS before sending: by external merge-sort in IO-Basic,
//!   or through the in-memory array `A_s` in recoded mode (§5 — the
//!   recoded-ID bijection makes the target slot `id / n`, eliminating the
//!   merge-sort entirely).  Once U_c finished the superstep and an OMS is
//!   drained it emits that destination's end tag.  It must not transmit
//!   superstep-(i+1) messages before every machine received all
//!   superstep-i messages.
//! * **U_r** (receive): counts end tags (n per superstep); spills sorted
//!   batches and merges them into `S^I` (IO-Basic) or combines messages
//!   directly into the in-memory array `A_r` (recoded, §5), then
//!   synchronizes with the other receiving units and unblocks sending of
//!   the next superstep.
//!
//! **The zero-copy message spine.**  Four properties keep the per-record
//! cost of this path minimal: (1) every combining loop is monomorphized
//! over the program's [`Combiner`] type, so folds inline (no virtual call
//! per record); (2) every byte buffer — outbox batches, OMS file
//! reads/writes, wire payloads, U_r spill/digest — is checked out of the
//! job's [`BufPool`] and recycled, and the `O(|V|/n)` digest *message*
//! arrays ping-pong through the job's [`DigestPool`], so steady state
//! allocates nothing per batch and no message array per superstep (the
//! 32×-smaller received bitmaps are still fresh each step — see ROADMAP);
//! (3) in recoded digesting mode,
//! messages whose destination is the sending machine bypass the simulated
//! switch and are folded straight into the machine's own `A_r` shard
//! ([`LocalDigest`]) without ever being encoded to an OMS file; (4) in the
//! sorted-`S^I` modes (IO-Basic, recoded-without-combiner), the same
//! `dst == me` traffic takes the **local spill lane** ([`LocalSpill`]):
//! U_c sorts and spills it to local files directly, and U_r merges those
//! files with the remote spills into `S^I` — no OMS file, no encode →
//! wire → decode round trip, no switch transit.  Exactly the saving the
//! O(|V|/n) analysis permits, now in every execution mode (see
//! `DESIGN.md`).

use crate::api::{BlockCtx, Combiner, Context, Edge, VertexProgram};
use crate::config::{JobConfig, Mode};
use crate::error::{Error, Result};
use crate::metrics::{MachineMetrics, StepMetrics};
use crate::msg::{encode_msg, msg_rec_size, rec_payload, rec_target, BufPool, Codec, DigestPool};
use crate::net::{NetReceiver, NetSender, Payload};
use crate::runtime::KernelSet;
use crate::stream::{merge, SplittableStream, StreamReader, StreamWriter};
use crate::trace::{EventKind, UnitTracer};
use crate::util::bitset::BitSet;
use crate::util::diskio::read_file_into;
use crate::util::timer::Stopwatch;
use crate::worker::csr::{Adjacency, CsrMap};
use crate::worker::fault::{FaultKind, FaultPlan};
use crate::worker::storage::MachineStore;
use crate::worker::sync::{lock_clean, wait_clean, JobAbort, MachineSync, Rendezvous};
use crate::worker::Partitioning;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Messages of one finished superstep, handed from U_r to U_c.
pub enum Incoming<M> {
    /// IO-Basic: a single sorted message stream `S^I` on disk.
    Sorted {
        /// Path of the merged `S^I` file.
        path: PathBuf,
        /// Message records in the stream.
        msgs: u64,
    },
    /// Recoded: combined messages in memory (`A_r`), plus a received
    /// bitmap (strictly more precise than the paper's `A_r[pos] != e0`
    /// convention; same asymptotic memory).
    Digested {
        /// The combined message array, one slot per local position.
        ar: Vec<M>,
        /// Which positions actually received a message.
        bits: BitSet,
    },
}

/// Step-keyed blocking handoff queue between units (one deposit per step;
/// `take` blocks until that step's entry arrives).
pub struct StepQueue<T> {
    q: Mutex<VecDeque<(u64, T)>>,
    cond: Condvar,
}

impl<T: Send> StepQueue<T> {
    /// An empty queue.
    pub fn new() -> Arc<Self> {
        Arc::new(Self {
            q: Mutex::new(VecDeque::new()),
            cond: Condvar::new(),
        })
    }

    /// Deposit `item` for `step` (exactly one deposit per step).
    pub fn put(&self, step: u64, item: T) {
        lock_clean(&self.q).push_back((step, item));
        self.cond.notify_all();
    }

    /// Block until the deposit for `step` arrives, then consume it.
    pub fn take(&self, step: u64) -> T {
        let mut q = lock_clean(&self.q);
        loop {
            if let Some(pos) = q.iter().position(|(s, _)| *s == step) {
                return q.remove(pos).unwrap().1;
            }
            q = wait_clean(&self.cond, q);
        }
    }

    /// Run `f` over the queued entry for `step` without consuming it
    /// (used by synchronous checkpointing).  The entry must be present.
    pub fn peek_with<R>(&self, step: u64, f: impl FnOnce(&T) -> R) -> R {
        let q = lock_clean(&self.q);
        let (_, item) = q
            .iter()
            .find(|(s, _)| *s == step)
            .expect("peek_with: step not queued");
        f(item)
    }
}

/// Step-ordered handoff U_r → U_c.
pub type IncomingQueue<M> = StepQueue<Incoming<M>>;

/// One superstep's locally-digested messages: `dst == me` messages folded
/// by U_c straight into the machine's own `A_r` shard (positions of *this*
/// machine's vertices), bypassing OMS files and the switch entirely.
pub struct LocalDigest<M> {
    /// The machine's own `A_r` shard (one slot per local position),
    /// checked out of the job's [`DigestPool`] and recycled by U_r.
    pub ar: Vec<M>,
    /// Which positions the fold actually touched.
    pub bits: BitSet,
    /// Positions touched this superstep, in first-touch order — U_r folds
    /// only these, so a sparse frontier costs O(touched), not O(|V|/n).
    pub touched: Vec<u32>,
    /// Messages folded into the shard.
    pub msgs: u64,
}

/// Step-ordered typed handoff of [`LocalDigest`]s U_c → U_r (the
/// local-delivery fast path's replacement for the OMS → switch → wire
/// route).  U_c deposits exactly one digest per superstep *before*
/// publishing `compute_done`, and U_r folds it into `A_r` after the `n`
/// end tags — by which point the deposit is guaranteed present (the
/// machine's own end tag is only sent after `compute_done`).
pub type LocalShard<M> = StepQueue<LocalDigest<M>>;

/// One superstep's local spill lane output (IO-Basic / non-digesting
/// recoded): `dst == me` messages that U_c sorted and spilled straight to
/// local files, bypassing the Outbox's OMS, U_s, and the switch entirely.
/// U_r merges these files together with the remote spills into `S^I`.
pub struct LocalSpill {
    /// Sorted spill files (each ≤ℬ of records), in write order.
    pub paths: Vec<PathBuf>,
    /// Message records across the files.
    pub msgs: u64,
}

/// Step-ordered handoff of [`LocalSpill`]s U_c → U_r — the sorted-`S^I`
/// modes' counterpart of [`LocalShard`], with the same ordering argument:
/// U_c deposits before publishing `compute_done`, and U_r only looks after
/// the `n` end tags (our own end tag is sent after `compute_done`).
pub type SpillLane = StepQueue<LocalSpill>;

/// Is the digesting local fast path on for this job?  Requires recoded
/// digesting (positions are computable from IDs), the fast path enabled,
/// and the real OMS path (the stall ablation measures stalls unmodified).
fn local_digest_active<P: VertexProgram>(cfg: &JobConfig) -> bool {
    cfg.mode == Mode::Recoded && P::Comb::ENABLED && cfg.local_fastpath && !cfg.disable_oms
}

/// Is the IO-Basic local spill lane on for this job?  Active in exactly
/// the modes that build a sorted `S^I` (everything [`local_digest_active`]
/// does not cover), under the same `local_fastpath` knob and the same
/// real-OMS requirement.  At most one of the two lanes is live per job.
fn local_spill_active<P: VertexProgram>(cfg: &JobConfig) -> bool {
    !(cfg.mode == Mode::Recoded && P::Comb::ENABLED) && cfg.local_fastpath && !cfg.disable_oms
}

/// Global (inter-machine) control report deposited by each U_c per step.
pub struct UcReport<A> {
    /// Messages this machine emitted (wire + local).
    pub msgs_sent: u64,
    /// Vertices still active after the superstep.
    pub active: u64,
    /// This machine's aggregator contribution.
    pub agg: A,
}

/// Leader verdict broadcast back to every U_c.
#[derive(Clone)]
pub struct UcDecision<A> {
    /// Does the job continue past this superstep?
    pub continues: bool,
    /// The globally merged aggregate.
    pub agg: Arc<A>,
}

/// Wire-encode a [`UcReport`] for the distributed U_c barrier:
/// `msgs_sent` + `active` as u64 LE, then the program's aggregate encoding
/// ([`VertexProgram::encode_agg`]).
pub fn encode_uc_report<P: VertexProgram>(p: &P, r: &UcReport<P::Agg>) -> Vec<u8> {
    let mut out = Vec::with_capacity(16);
    out.extend_from_slice(&r.msgs_sent.to_le_bytes());
    out.extend_from_slice(&r.active.to_le_bytes());
    p.encode_agg(&r.agg, &mut out);
    out
}

/// Inverse of [`encode_uc_report`].  Tolerant of short input (zero-fills):
/// barrier payloads only arrive through the framed control plane, so a
/// short buffer means a program whose `encode_agg`/`decode_agg` disagree —
/// degrade to defaults rather than panic inside a barrier.
pub fn decode_uc_report<P: VertexProgram>(p: &P, b: &[u8]) -> UcReport<P::Agg> {
    let word = |at: usize| {
        let mut w = [0u8; 8];
        let end = (at + 8).min(b.len());
        if at < end {
            w[..end - at].copy_from_slice(&b[at..end]);
        }
        u64::from_le_bytes(w)
    };
    UcReport {
        msgs_sent: word(0),
        active: word(8),
        agg: p.decode_agg(b.get(16..).unwrap_or(&[])),
    }
}

/// Wire-encode a [`UcDecision`]: `continues` as one byte, then the
/// program's aggregate encoding.
pub fn encode_uc_decision<P: VertexProgram>(p: &P, d: &UcDecision<P::Agg>) -> Vec<u8> {
    let mut out = Vec::with_capacity(1);
    out.push(d.continues as u8);
    p.encode_agg(&d.agg, &mut out);
    out
}

/// Inverse of [`encode_uc_decision`]; tolerant like [`decode_uc_report`].
pub fn decode_uc_decision<P: VertexProgram>(p: &P, b: &[u8]) -> UcDecision<P::Agg> {
    UcDecision {
        continues: b.first().copied().unwrap_or(0) != 0,
        agg: Arc::new(p.decode_agg(b.get(1..).unwrap_or(&[]))),
    }
}

/// Everything shared across the machines of one job.
pub struct JobGlobal<P: VertexProgram> {
    /// The vertex program.
    pub program: Arc<P>,
    /// Job tunables (mode, ℬ, b, fast-path knob, …).
    pub cfg: JobConfig,
    /// Number of machines.
    pub n: usize,
    /// Total vertices |V| across the cluster.
    pub total_vertices: u64,
    /// max over machines of |V(W)| — sizes A_s (§5). Note recoded IDs are
    /// `n·pos + i`, so with uneven partitions they range up to
    /// `n·max_local`, not |V|.
    pub max_local: usize,
    /// Checkpointing (§3.4): dir + cadence, None = disabled.
    pub checkpoint: Option<crate::ft::CheckpointCfg>,
    /// Absolute superstep number of local step 0 (0 for fresh jobs,
    /// `ckpt_step + 1` when resuming).
    pub step_base: u64,
    /// The early aggregator/control barrier among compute units.
    pub uc_rv: Arc<Rendezvous<UcReport<P::Agg>, UcDecision<P::Agg>>>,
    /// The late transmission-completion barrier among receiving units.
    pub ur_rv: Arc<Rendezvous<(), ()>>,
    /// Checkpoint barrier: no machine may publish the DONE marker before
    /// every machine's checkpoint file is durable (§3.4).
    pub ckpt_rv: Arc<Rendezvous<(), ()>>,
    /// Job-wide byte-buffer pool: outbox batches, OMS file reads/writes,
    /// wire payloads, and U_r spill/digest buffers all recycle through it.
    pub pool: Arc<BufPool>,
    /// Job-wide digest-array pool: U_r's `A_r` and U_c's [`LocalDigest`]
    /// shard ping-pong through it instead of reallocating `O(|V|/n)`
    /// arrays every superstep.
    pub digest_pool: Arc<DigestPool<P::Msg>>,
    /// The job-wide abort latch: the first failing unit anywhere trips it,
    /// poisoning every machine's [`MachineSync`], all three [`Rendezvous`]
    /// barriers, and the channel waits in [`crate::net`] — converting every
    /// "sibling died" scenario from deadlock to a typed
    /// [`Error::JobFailed`].
    pub abort: Arc<JobAbort>,
    /// The job-wide flight recorder / Chrome-trace collector.  Disabled
    /// tracers hand out no-op [`UnitTracer`]s, so the hot path pays one
    /// branch per event when tracing is off.
    pub tracer: Arc<crate::trace::Tracer>,
    /// Fast-recovery replay window (§3.4): `Some(R)` means every machine
    /// has the previous attempt's merged S^I files for absolute supersteps
    /// `[step_base, R]` (verified against `replay_manifest` by the engine).
    /// U_c then *replays* those incoming files instead of recomputing their
    /// senders: sends for `abs ≤ R` are discarded (counted but not
    /// materialised — every machine suppresses identically, so the
    /// continue/halt decisions replay exactly), and checkpoints inside the
    /// window are skipped (the original attempt already made them durable,
    /// or deliberately didn't).  `None` = plain recompute resume.
    pub replay_upto: Option<u64>,
    /// True under the TCP transport, where this process runs exactly one
    /// machine and its siblings live in other processes.  Changes only
    /// cross-machine bookkeeping conventions (e.g. every process owns its
    /// private checkpoint dir and writes its own DONE marker, instead of
    /// machine 0 marking for the whole cluster).
    pub distributed: bool,
}

/// Per-machine output returned by [`run_machine`].
pub struct MachineOutput<P: VertexProgram> {
    /// Which machine produced this output.
    pub machine: usize,
    /// Input-space vertex IDs, aligned with `values`.
    pub ids: Vec<u32>,
    /// Final vertex values.
    pub values: Vec<P::Value>,
    /// Per-superstep counters for this machine.
    pub metrics: MachineMetrics,
    /// Supersteps this machine ran.
    pub supersteps: u64,
    /// Globally merged aggregate of the final superstep.
    pub final_agg: Arc<P::Agg>,
}

/// Shared, step-indexed metrics sink written by all three units.
#[derive(Clone)]
pub struct MetricsSink(Arc<Mutex<Vec<StepMetrics>>>);

impl MetricsSink {
    /// An empty sink.
    pub fn new() -> Self {
        Self(Arc::new(Mutex::new(Vec::new())))
    }

    /// Run `f` over the (lazily created) entry for `step`.
    pub fn with_step(&self, step: u64, f: impl FnOnce(&mut StepMetrics)) {
        let mut v = lock_clean(&self.0);
        while v.len() <= step as usize {
            let s = v.len() as u64;
            v.push(StepMetrics {
                step: s,
                ..Default::default()
            });
        }
        f(&mut v[step as usize]);
    }

    /// Clone out all per-step entries recorded so far.
    pub fn snapshot(&self) -> Vec<StepMetrics> {
        lock_clean(&self.0).clone()
    }
}

/// Name of the per-machine fast-recovery manifest inside a job dir.
const REPLAY_MANIFEST: &str = "replay_manifest";

/// Append one superstep's merged S^I to `<job_dir>/replay_manifest` as a
/// line `"<abs-superstep> <file-name> <msgs> <bytes>"`.  The byte size lets
/// a later resume verify the file survived intact; a line torn by a crash
/// mid-append fails parsing and just ends the replay window early.
fn append_replay_manifest(
    job_dir: &std::path::Path,
    abs: u64,
    si: &std::path::Path,
    msgs: u64,
) -> Result<()> {
    use std::io::Write;
    let bytes = std::fs::metadata(si)?.len();
    let name = si
        .file_name()
        .and_then(|s| s.to_str())
        .ok_or_else(|| Error::CorruptStream("non-utf8 S^I file name".into()))?;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(job_dir.join(REPLAY_MANIFEST))?;
    writeln!(f, "{abs} {name} {msgs} {bytes}")?;
    Ok(())
}

/// Parse `<dir>/replay_manifest` into `abs superstep → (S^I file name,
/// message count, byte size)`.  Malformed lines (torn final append) are
/// skipped, not errors — the engine's contiguity walk treats the missing
/// entry as the end of the replay window.
pub(crate) fn read_replay_manifest(
    dir: &std::path::Path,
) -> Result<std::collections::HashMap<u64, (String, u64, u64)>> {
    let text = std::fs::read_to_string(dir.join(REPLAY_MANIFEST))?;
    let mut map = std::collections::HashMap::new();
    for line in text.lines() {
        let mut it = line.split_whitespace();
        let (Some(a), Some(name), Some(m), Some(b)) = (it.next(), it.next(), it.next(), it.next())
        else {
            continue;
        };
        let (Ok(a), Ok(m), Ok(b)) = (a.parse::<u64>(), m.parse::<u64>(), b.parse::<u64>()) else {
            continue;
        };
        map.insert(a, (name.to_string(), m, b));
    }
    Ok(map)
}

/// Run one machine's full job: spawns U_s and U_r, runs U_c inline, joins.
pub fn run_machine<P: VertexProgram>(
    global: &JobGlobal<P>,
    store: MachineStore,
    init_values: Vec<P::Value>,
    sender: NetSender,
    receiver: NetReceiver,
    disk: Option<std::sync::Arc<crate::util::diskio::DiskBw>>,
) -> Result<MachineOutput<P>> {
    run_machine_resumed(global, store, init_values, None, None, sender, receiver, disk)
}

/// Like [`run_machine`] but optionally seeded from a checkpoint: the
/// halted bitmap and the incoming messages of the first local superstep.
#[allow(clippy::too_many_arguments)]
pub fn run_machine_resumed<P: VertexProgram>(
    global: &JobGlobal<P>,
    store: MachineStore,
    init_values: Vec<P::Value>,
    init_halted: Option<BitSet>,
    init_incoming: Option<Incoming<P::Msg>>,
    sender: NetSender,
    receiver: NetReceiver,
    disk: Option<std::sync::Arc<crate::util::diskio::DiskBw>>,
) -> Result<MachineOutput<P>> {
    let me = store.machine;
    let n = global.n;
    let msync = MachineSync::new(n);
    // Every machine's sync is poisoned when any unit of any machine trips
    // the job abort; register() also handles the race where a sibling died
    // before this machine even started.
    global.abort.register(msync.clone());
    let incoming: Arc<IncomingQueue<P::Msg>> = IncomingQueue::new();
    let sink = MetricsSink::new();
    // The fast path's U_c → U_r handoff lane, when active: the digesting
    // shard in recoded-combining mode, the spill lane in sorted-S^I modes.
    let local_shard: Option<Arc<LocalShard<P::Msg>>> =
        local_digest_active::<P>(&global.cfg).then(LocalShard::new);
    let local_spill: Option<Arc<SpillLane>> =
        local_spill_active::<P>(&global.cfg).then(SpillLane::new);

    // One OMS per destination machine, living for the whole job; file
    // write buffers recycle through the job pool.
    let job_dir = store.dir.join("job");
    let replay_dir = store.dir.join("replay");
    let _ = std::fs::remove_dir_all(&replay_dir);
    if global.replay_upto.is_some() {
        // Fast recovery: the engine verified the previous attempt's merged
        // S^I files against its replay_manifest, so park that job dir aside
        // instead of wiping it — U_c replays incoming from `replay/` while
        // this attempt's fresh `job/` fills with new OMS/S^I files.
        std::fs::rename(&job_dir, &replay_dir)?;
    } else {
        let _ = std::fs::remove_dir_all(&job_dir);
    }
    std::fs::create_dir_all(&job_dir)?;
    let mut oms = Vec::with_capacity(n);
    for d in 0..n {
        oms.push(SplittableStream::create_pooled(
            &job_dir.join(format!("oms_{d}")),
            global.cfg.oms_file_cap,
            global.cfg.stream_buf,
            global.pool.clone(),
        )?);
    }
    let oms = Arc::new(oms);

    // Per-unit progress beacons: each unit publishes the superstep it is
    // executing so a failure can be attributed to the step it happened in
    // (the `superstep` field of [`Error::JobFailed`]).
    let us_step = Arc::new(AtomicU64::new(0));
    let ur_step = Arc::new(AtomicU64::new(0));
    let uc_step = Arc::new(AtomicU64::new(0));

    std::thread::scope(|scope| -> Result<MachineOutput<P>> {
        let us_handle = {
            let oms = oms.clone();
            let msync = msync.clone();
            let sink = sink.clone();
            let sender = sender.clone();
            let job_dir = job_dir.clone();
            let disk = disk.clone();
            let beacon = us_step.clone();
            let mut tr = global.tracer.unit(me, "U_s");
            scope.spawn(move || {
                let _dg = crate::util::diskio::register(disk);
                // guard(): catches panics, trips the job abort on any
                // first-order failure (poisoning every machine), and lets a
                // propagated JobFailed pass through untouched.  U_c may be
                // blocked on this machine's sync and every peer at a
                // barrier or channel — all of them unblock typed.
                let r = global.abort.guard(me, "U_s", &beacon, || {
                    sender_unit(global, me, oms, msync, sender, job_dir, sink, &beacon, &mut tr)
                });
                // Deposit the ring *after* the guard so the flight recorder
                // sees the events leading up to a panic, not an empty ring.
                tr.finish();
                r
            })
        };
        let ur_handle = {
            let msync = msync.clone();
            let incoming = incoming.clone();
            let sink = sink.clone();
            let local = store.local_vertices();
            let job_dir = job_dir.clone();
            let disk = disk.clone();
            let shard = local_shard.clone();
            let spill = local_spill.clone();
            let beacon = ur_step.clone();
            let mut tr = global.tracer.unit(me, "U_r");
            scope.spawn(move || {
                let _dg = crate::util::diskio::register(disk);
                let r = global.abort.guard(me, "U_r", &beacon, || {
                    receiver_unit(
                        global, me, local, receiver, msync, incoming, shard, spill, job_dir,
                        sink, &beacon, &mut tr,
                    )
                });
                tr.finish();
                r
            })
        };

        let uc_out = {
            let _dg = crate::util::diskio::register(disk.clone());
            let mut tr = global.tracer.unit(me, "U_c");
            // Same guard inline: a panic in `program.compute` (or any U_c
            // error) trips the abort before we block joining the siblings
            // below — without it the scope join itself would deadlock on
            // the blocked U_s/U_r threads.
            let r = global.abort.guard(me, "U_c", &uc_step, || {
                compute_unit(
                    global, store, init_values, init_halted, init_incoming, oms,
                    msync.clone(), incoming, local_shard, local_spill, sender, &sink,
                    &uc_step, &mut tr,
                )
            });
            tr.finish();
            r
        };

        // Join both siblings, then report U_c's error ahead of the
        // siblings' (all three carry the same propagated first cause).
        let us_res = us_handle.join();
        let ur_res = ur_handle.join();
        let (ids, values, peak_state, supersteps, final_agg) = uc_out?;
        us_res.map_err(|e| Error::WorkerPanic {
            machine: me,
            cause: format!("U_s: {e:?}"),
        })??;
        ur_res.map_err(|e| Error::WorkerPanic {
            machine: me,
            cause: format!("U_r: {e:?}"),
        })??;
        let metrics = MachineMetrics {
            machine: me,
            steps: sink.snapshot(),
            peak_state_bytes: peak_state,
        };
        Ok(MachineOutput {
            machine: me,
            ids,
            values,
            metrics,
            supersteps,
            final_agg,
        })
    })
}

// --------------------------------------------------------------------- U_s

/// One taken OMS file: (index, path, bytes).
pub type TakenFile = (u64, PathBuf, u64);

#[allow(clippy::too_many_arguments)]
fn sender_unit<P: VertexProgram>(
    global: &JobGlobal<P>,
    me: usize,
    oms: Arc<Vec<Arc<SplittableStream>>>,
    msync: Arc<MachineSync>,
    mut sender: NetSender,
    job_dir: PathBuf,
    sink: MetricsSink,
    beacon: &AtomicU64,
    tr: &mut UnitTracer,
) -> Result<()> {
    let n = global.n;
    let rec_size = msg_rec_size::<P::Msg>();
    // Monomorphized combiner: the per-record folds below compile to
    // straight-line code, no virtual dispatch.
    let comb = P::Comb::default();
    let combining = P::Comb::ENABLED;
    let recoded_as = global.cfg.mode == Mode::Recoded && combining;
    let pool = &*global.pool;
    let tmp = job_dir.join("us_tmp");

    // A_s (§5): one slot per position of the destination machine; bounded
    // by max |V(W)| (Lemma 1: < 2|V|/n w.h.p.). Reused across OMSs/steps.
    let as_cap = global.max_local + 1;
    let mut a_s: Vec<P::Msg> = if recoded_as {
        vec![comb.identity(); as_cap]
    } else {
        Vec::new()
    };
    let mut as_touched: Vec<u32> = Vec::new();
    let mut as_bits = BitSet::new(if recoded_as { as_cap } else { 0 });

    // Files this unit has taken per destination; step drained towards dst
    // when sent_files[dst] == watermark[dst][step].
    let mut sent_files = vec![0u64; n];

    let mut step: u64 = 0;
    loop {
        // Beacons carry *absolute* supersteps so resumed jobs attribute
        // failures in the same space as the checkpoints they resume from.
        let abs = global.step_base + step;
        beacon.store(abs, Ordering::Relaxed);
        tr.begin(EventKind::Superstep, abs);
        tr.begin(EventKind::Stall, abs);
        let t0 = Instant::now();
        let allowed = msync.wait_send_allowed(step);
        let waited = t0.elapsed().as_secs_f64();
        tr.end(EventKind::Stall, abs);
        sink.with_step(step, |m| m.stall_wait_secs += waited);
        allowed?;
        // Fault injection (deterministic): fire at step entry, before any
        // file is taken from an OMS, so the failed attempt leaves every
        // retained log intact for fast replay.
        if let Some(fp) = &global.cfg.fault {
            for kind in [FaultKind::UsIo, FaultKind::NetSend] {
                if fp.fire(kind, me, abs) {
                    tr.instant(EventKind::Fault, abs);
                    return Err(FaultPlan::error(kind, me, abs));
                }
            }
        }
        let mut sw = Stopwatch::new();
        let mut marks: Option<Vec<u64>> = None;
        let mut end_sent = vec![false; n];
        let mut ends_left = n;
        let mut p = me; // ring position; per-machine start offset (§3.3.1)

        while ends_left > 0 {
            if marks.is_none() {
                marks = (0..n)
                    .map(|d| msync.try_watermark(d, step))
                    .collect::<Option<Vec<u64>>>();
            }
            let mut progressed = false;
            for off in 0..n {
                let j = (p + off) % n;
                if end_sent[j] {
                    continue;
                }
                let upto = marks.as_ref().map_or(u64::MAX, |m| m[j]);
                // Fast-path traffic to self never pays simulated wire time;
                // account it as local, not sent (§ local-delivery).
                let local = sender.local_fast() && j == me;
                if combining {
                    let files = oms[j].try_take_all_upto(upto);
                    if files.is_empty() {
                        continue;
                    }
                    // Guard the unknown-watermark race: files closed after
                    // U_c finished this step belong to the next superstep.
                    let files = put_back_overshoot(files, &msync, j, step, &oms[j]);
                    if files.is_empty() {
                        continue;
                    }
                    sent_files[j] += files.len() as u64;
                    sw.start();
                    let batch = if recoded_as {
                        combine_in_memory::<P::Msg, P::Comb>(
                            &files, &comb, n, &mut a_s, &mut as_touched, &mut as_bits, pool,
                        )?
                    } else {
                        combine_by_mergesort::<P::Msg, P::Comb>(
                            &files, &comb, global.cfg.merge_k, global.cfg.stream_buf, &tmp, pool,
                        )?
                    };
                    let (nbytes, nmsgs) = (batch.len() as u64, (batch.len() / rec_size) as u64);
                    tr.begin(EventKind::Transmit, nbytes);
                    sender.send(j, step, Payload::Data(batch))?;
                    tr.end(EventKind::Transmit, nbytes);
                    sw.stop();
                    sink.with_step(step, |m| {
                        if local {
                            m.local_bytes += nbytes;
                            m.local_msgs += nmsgs;
                        } else {
                            m.bytes_sent += nbytes;
                            m.msgs_sent += nmsgs;
                        }
                    });
                    for (_, path, _) in &files {
                        gc(path, &global.cfg);
                    }
                    progressed = true;
                    p = (j + 1) % n;
                    break;
                } else if let Some((idx, path, bytes)) = oms[j].try_take_next_upto(upto) {
                    if overshoots(idx, &msync, j, step) {
                        oms[j].put_back(idx, path, bytes);
                        continue;
                    }
                    sent_files[j] += 1;
                    sw.start();
                    let mut data = pool.take();
                    read_file_into(&path, &mut data)?;
                    let (nbytes, nmsgs) = (data.len() as u64, (data.len() / rec_size) as u64);
                    tr.begin(EventKind::Transmit, nbytes);
                    sender.send(j, step, Payload::Data(data))?;
                    tr.end(EventKind::Transmit, nbytes);
                    sw.stop();
                    sink.with_step(step, |m| {
                        if local {
                            m.local_bytes += nbytes;
                            m.local_msgs += nmsgs;
                        } else {
                            m.bytes_sent += nbytes;
                            m.msgs_sent += nmsgs;
                        }
                    });
                    gc(&path, &global.cfg);
                    progressed = true;
                    p = (j + 1) % n;
                    break;
                }
            }
            if !progressed {
                if let Some(m) = &marks {
                    for j in 0..n {
                        if !end_sent[j] && sent_files[j] == m[j] {
                            sw.time(|| sender.send(j, step, Payload::End))?;
                            end_sent[j] = true;
                            ends_left -= 1;
                        }
                    }
                    if ends_left == 0 {
                        break;
                    }
                }
                msync.idle_wait()?;
            }
        }
        sink.with_step(step, |m| m.m_send_secs += sw.secs());
        let cont = msync.wait_decided(step)?;
        tr.end(EventKind::Superstep, abs);
        if !cont {
            return Ok(());
        }
        step += 1;
    }
}

fn overshoots(idx: u64, msync: &MachineSync, dst: usize, step: u64) -> bool {
    matches!(msync.try_watermark(dst, step), Some(m) if idx >= m)
}

fn put_back_overshoot(
    files: Vec<TakenFile>,
    msync: &MachineSync,
    dst: usize,
    step: u64,
    oms: &SplittableStream,
) -> Vec<TakenFile> {
    match msync.try_watermark(dst, step) {
        Some(m) => {
            let mut keep = Vec::with_capacity(files.len());
            let mut back = Vec::new();
            for f in files {
                if f.0 >= m {
                    back.push(f);
                } else {
                    keep.push(f);
                }
            }
            // Put back in reverse so push_front restores ascending order.
            for f in back.into_iter().rev() {
                oms.put_back(f.0, f.1, f.2);
            }
            keep
        }
        None => files,
    }
}

fn gc(path: &std::path::Path, cfg: &JobConfig) {
    if !cfg.keep_oms_for_recovery {
        SplittableStream::gc_file(path);
    }
}

/// Recoded-mode in-memory combining (§5): fold every message of the taken
/// files into `A_s[target / n]`, then emit one record per touched slot.
///
/// Monomorphized over `C: Combiner<M>` — the per-record fold in this loop
/// is the hottest code in the crate and inlines to straight-line code.
/// File reads and the output batch check buffers out of `pool`; the
/// returned batch is recycled by the receiving machine after digesting.
pub fn combine_in_memory<M: Codec, C: Combiner<M>>(
    files: &[TakenFile],
    comb: &C,
    n: usize,
    a_s: &mut [M],
    touched: &mut Vec<u32>,
    bits: &mut BitSet,
    pool: &BufPool,
) -> Result<Vec<u8>> {
    let rec_size = msg_rec_size::<M>();
    let mut data = pool.take();
    for (_, path, _) in files {
        read_file_into(path, &mut data)?;
        for rec in data.chunks_exact(rec_size) {
            let target = rec_target(rec);
            let pos = target as usize / n;
            if pos >= a_s.len() {
                return Err(Error::CorruptStream(format!(
                    "A_s overflow: target {target} pos {pos} cap {} file {path:?} len {}",
                    a_s.len(),
                    data.len()
                )));
            }
            let m = rec_payload::<M>(rec);
            if bits.get(pos) {
                comb.combine(&mut a_s[pos], &m);
            } else {
                a_s[pos] = m;
                bits.set(pos, true);
                touched.push(target);
            }
        }
    }
    pool.put(data);
    // Deterministic output order helps tests; sort cost is per-send-batch.
    touched.sort_unstable();
    let mut out = pool.take_with_capacity(touched.len() * rec_size);
    for &t in touched.iter() {
        let pos = t as usize / n;
        encode_msg(t, &a_s[pos], &mut out);
        a_s[pos] = comb.identity(); // reset for the next batch (§5)
        bits.set(pos, false);
    }
    touched.clear();
    Ok(out)
}

/// The decode → combine → encode payload fold used wherever a merge
/// combines equal-key record runs (U_s's pre-send combining and U_r's
/// spill-lane `S^I` merge share it, so the two paths cannot diverge).
fn payload_fold<M: Codec, C: Combiner<M>>(comb: &C) -> impl FnMut(&mut [u8], &[u8]) + '_ {
    move |acc, pay| {
        let mut a = M::decode(acc);
        let b = M::decode(pay);
        comb.combine(&mut a, &b);
        a.encode(acc);
    }
}

/// IO-Basic pre-send combining: in-memory sort of each ≤ℬ file, k-way
/// merge, one combining pass (§3.3.1).  Monomorphized over the combiner
/// like [`combine_in_memory`]; scratch and output buffers are pooled.
pub fn combine_by_mergesort<M: Codec, C: Combiner<M>>(
    files: &[TakenFile],
    comb: &C,
    merge_k: usize,
    buf: usize,
    tmp: &std::path::Path,
    pool: &BufPool,
) -> Result<Vec<u8>> {
    let rec_size = msg_rec_size::<M>();
    std::fs::create_dir_all(tmp)?;
    let mut sorted_paths = Vec::with_capacity(files.len());
    let mut data = pool.take();
    for (i, (_, path, _)) in files.iter().enumerate() {
        read_file_into(path, &mut data)?;
        merge::sort_records(&mut data, rec_size);
        let sp = tmp.join(format!("sorted_{i}"));
        std::fs::write(&sp, &data)?;
        crate::util::diskio::charge(data.len());
        sorted_paths.push(sp);
    }
    pool.put(data);
    let mut out = pool.take();
    merge::merge_combine(
        &sorted_paths,
        rec_size,
        merge_k,
        buf,
        tmp,
        payload_fold::<M, C>(comb),
        |rec| {
            out.extend_from_slice(rec);
            Ok(())
        },
    )?;
    for p in sorted_paths {
        let _ = std::fs::remove_file(p);
    }
    Ok(out)
}

// --------------------------------------------------------------------- U_r

#[allow(clippy::too_many_arguments)]
fn receiver_unit<P: VertexProgram>(
    global: &JobGlobal<P>,
    me: usize,
    local_vertices: usize,
    receiver: NetReceiver,
    msync: Arc<MachineSync>,
    incoming: Arc<IncomingQueue<P::Msg>>,
    local_shard: Option<Arc<LocalShard<P::Msg>>>,
    local_spill: Option<Arc<SpillLane>>,
    job_dir: PathBuf,
    sink: MetricsSink,
    beacon: &AtomicU64,
    tr: &mut UnitTracer,
) -> Result<()> {
    let n = global.n;
    let rec_size = msg_rec_size::<P::Msg>();
    // Monomorphized digest fold — the U_r hot loop.
    let comb = P::Comb::default();
    let recoded_digest = global.cfg.mode == Mode::Recoded && P::Comb::ENABLED;
    let pool = &*global.pool;
    let part = Partitioning::Modulo;

    let mut step: u64 = 0;
    loop {
        // Absolute superstep, like the U_s/U_c beacons.
        let abs = global.step_base + step;
        beacon.store(abs, Ordering::Relaxed);
        tr.begin(EventKind::Superstep, abs);
        // Fault injection (deterministic): fire at step entry, before any
        // batch is received or spilled.
        if let Some(fp) = &global.cfg.fault {
            if fp.fire(FaultKind::UrIo, me, abs) {
                tr.instant(EventKind::Fault, abs);
                return Err(FaultPlan::error(FaultKind::UrIo, me, abs));
            }
        }
        let mut ends = 0usize;
        let mut msgs_recv = 0u64;
        let mut spills: Vec<PathBuf> = Vec::new();
        let mut ar: Vec<P::Msg> = Vec::new();
        let mut bits = BitSet::new(local_vertices);
        if recoded_digest {
            // Pooled: after the first couple of supersteps this is a
            // recycled array, not a fresh O(|V|/n) allocation.
            ar = global.digest_pool.take(local_vertices, comb.identity());
        }

        while ends < n {
            let b = receiver.recv()?;
            debug_assert_eq!(b.step, step, "out-of-step batch from {}", b.src);
            match b.payload {
                Payload::End => ends += 1,
                Payload::Data(mut data) => {
                    debug_assert_eq!(data.len() % rec_size, 0);
                    msgs_recv += (data.len() / rec_size) as u64;
                    if recoded_digest {
                        // §5: combine each message into A_r[pos] in memory.
                        for rec in data.chunks_exact(rec_size) {
                            let pos = part.position_of(rec_target(rec), n);
                            let m = rec_payload::<P::Msg>(rec);
                            if bits.get(pos) {
                                comb.combine(&mut ar[pos], &m);
                            } else {
                                ar[pos] = m;
                                bits.set(pos, true);
                            }
                        }
                    } else {
                        // §3.3.2: sort the batch, spill to disk.
                        let sp = job_dir.join(format!("imsp_{step}_{}", spills.len()));
                        write_sorted_spill(&sp, &mut data, rec_size)?;
                        spills.push(sp);
                    }
                    // Wire payloads recycle into the job pool either way.
                    pool.put(data);
                }
                Payload::Load(_) | Payload::LoadEnd => {
                    return Err(Error::CorruptStream("load batch during superstep".into()))
                }
            }
        }

        // Fold in the locally-digested shard (fast path): U_c deposited it
        // before `compute_done`, so it is guaranteed present by now.  Only
        // touched positions fold — O(frontier), not O(|V|/n).
        if let Some(shard) = &local_shard {
            let ld = shard.take(step);
            msgs_recv += ld.msgs;
            for &p in &ld.touched {
                let pos = p as usize;
                if bits.get(pos) {
                    comb.combine(&mut ar[pos], &ld.ar[pos]);
                } else {
                    ar[pos] = ld.ar[pos];
                    bits.set(pos, true);
                }
            }
            // The shard's array ping-pongs back through the pool.
            global.digest_pool.put(ld.ar);
        }

        // Local spill lane (sorted-S^I modes): U_c deposited its sorted
        // `lsp_*` files before `compute_done`, so — by the same end-tag
        // argument as the digest shard — the deposit is present by now.
        // The files merge into S^I exactly like remote spills.
        let mut local_paths: Vec<PathBuf> = Vec::new();
        if let Some(lane) = &local_spill {
            let ls = lane.take(step);
            msgs_recv += ls.msgs;
            local_paths = ls.paths;
        }

        let inc = if recoded_digest {
            Incoming::Digested { ar, bits }
        } else {
            let si = job_dir.join(format!("si_{step}"));
            let mut w = StreamWriter::create(&si, global.cfg.stream_buf)?;
            let all_spills: Vec<PathBuf> = spills
                .iter()
                .chain(local_paths.iter())
                .cloned()
                .collect();
            if P::Comb::ENABLED && local_spill.is_some() {
                // Combine equal-key runs while building S^I: local spill
                // records arrive raw (the lane skips U_s's pre-send
                // combining), and equal targets from different machines'
                // batches fold here too — S^I stays O(distinct targets),
                // not O(messages).  Monomorphized like every other fold.
                // Gated on the lane so `local_fastpath(false)` restores
                // the pre-fast-path routing bit-for-bit.
                merge::merge_combine(
                    &all_spills,
                    rec_size,
                    global.cfg.merge_k,
                    global.cfg.stream_buf,
                    &job_dir,
                    payload_fold::<P::Msg, P::Comb>(&comb),
                    |rec| w.write_all(rec),
                )?;
            } else {
                merge::merge_streams(
                    &all_spills,
                    rec_size,
                    global.cfg.merge_k,
                    global.cfg.stream_buf,
                    &job_dir,
                    |rec| w.write_all(rec),
                )?;
            }
            w.finish()?;
            for sp in &spills {
                let _ = std::fs::remove_file(sp);
            }
            // Retained for fast recovery when `keep_oms_for_recovery` is
            // set: record this superstep's merged S^I in the replay
            // manifest so a resumed attempt can replay it instead of
            // recomputing the senders (§3.4).  Skipped while this attempt
            // is itself replaying (`abs ≤ R`): its S^I files for those
            // steps are empty placeholders, not real message logs.
            if global.cfg.keep_oms_for_recovery
                && global.replay_upto.map_or(true, |r| abs > r)
            {
                append_replay_manifest(&job_dir, abs, &si, msgs_recv)?;
            }
            if !global.cfg.keep_oms_for_recovery {
                for sp in &local_paths {
                    let _ = std::fs::remove_file(sp);
                }
            }
            Incoming::Sorted {
                path: si,
                msgs: msgs_recv,
            }
        };
        sink.with_step(step, |m| m.msgs_recv += msgs_recv);
        incoming.put(step, inc);
        msync.set_recv_done(step);

        // Synchronize with the receiving units of all machines, then allow
        // next-superstep transmission (§4).
        tr.begin(EventKind::Barrier, abs);
        let t0 = Instant::now();
        let rv = global.ur_rv.exchange(me, (), |_| ());
        let waited = t0.elapsed().as_secs_f64();
        tr.end(EventKind::Barrier, abs);
        sink.with_step(step, |m| m.barrier_wait_secs += waited);
        rv?;
        msync.set_send_allowed(step + 1);

        let cont = msync.wait_decided(step)?;
        tr.end(EventKind::Superstep, abs);
        if !cont {
            return Ok(());
        }
        step += 1;
    }
}

// --------------------------------------------------------------------- U_c

type UcResult<P> = Result<(
    Vec<u32>,
    Vec<<P as VertexProgram>::Value>,
    u64,
    u64,
    Arc<<P as VertexProgram>::Agg>,
)>;

/// Cursor over the sorted incoming stream `S^I`, advanced in lockstep with
/// the A-order vertex scan.
struct MsgCursor<M: Codec> {
    reader: Option<StreamReader>,
    next: Option<(u32, M)>,
    rec: Vec<u8>,
}

impl<M: Codec> MsgCursor<M> {
    fn open(path: &std::path::Path, buf: usize) -> Result<Self> {
        let reader = StreamReader::open(path, buf)?;
        let mut c = Self {
            reader: Some(reader),
            next: None,
            rec: vec![0u8; msg_rec_size::<M>()],
        };
        c.advance()?;
        Ok(c)
    }

    fn empty() -> Self {
        Self {
            reader: None,
            next: None,
            rec: Vec::new(),
        }
    }

    fn advance(&mut self) -> Result<()> {
        self.next = None;
        if let Some(r) = &mut self.reader {
            if r.remaining() >= self.rec.len() as u64 {
                r.read_exact(&mut self.rec)?;
                self.next = Some((rec_target(&self.rec), rec_payload::<M>(&self.rec)));
            }
        }
        Ok(())
    }

    fn peek_target(&self) -> Option<u32> {
        self.next.as_ref().map(|(t, _)| *t)
    }

    fn take_for(&mut self, id: u32, out: &mut Vec<M>) -> Result<()> {
        while let Some((t, m)) = &self.next {
            if *t != id {
                debug_assert!(*t > id, "S^I unsorted or vertex ids out of order");
                break;
            }
            out.push(*m);
            self.advance()?;
        }
        Ok(())
    }
}

/// Outgoing-message sink for one superstep of U_c: raw OMS appends, or
/// bounded in-memory buffers + synchronous (stalling) sends when the
/// `disable_oms` ablation is active.  Monomorphized over the program's
/// combiner so the local fast path's fold inlines; all byte buffers
/// recycle through the job pool.
struct Outbox<'a, M: Codec, C: Combiner<M>> {
    part: Partitioning,
    n: usize,
    me: usize,
    rec_size: usize,
    disable_oms: bool,
    cap: usize,
    step: u64,
    stall_bufs: Vec<Vec<u8>>,
    stall_sender: &'a mut NetSender,
    oms: &'a [Arc<SplittableStream>],
    /// Per-destination append batches: amortizes the OMS mutex + buffered
    /// write over ~BATCH bytes of records (see README.md §Perf).
    batch: Vec<Vec<u8>>,
    /// All messages emitted this superstep (wire + local) — feeds the
    /// global continue decision, so locally-digested messages still keep
    /// the job alive.
    msgs_sent: u64,
    comb: C,
    /// Local-delivery fast path (digesting mode): messages to this
    /// machine's own vertices fold straight into the local `A_r` shard —
    /// no encode, no OMS file, no switch.
    local: Option<LocalDigest<M>>,
    /// Local spill lane (sorted-S^I modes): messages to this machine's own
    /// vertices are encoded into a pooled buffer and sorted-spilled to
    /// `lsp_*` files at ℬ boundaries — no OMS file, no switch, no
    /// encode → wire → decode round trip, no U_r re-sort.
    spill: Option<SpillState>,
    /// A synchronous-send failure (stall ablation) deferred out of the
    /// infallible `send` hot path; surfaced by [`Outbox::flush_stall`] at
    /// end of superstep.  Once set, further stall records are dropped —
    /// the superstep is already doomed.
    net_err: Option<Error>,
    /// Fast-recovery replay (§3.4): this superstep's messages were already
    /// received by every machine in a previous attempt, so sends are
    /// *counted* (the continue/halt decision must replay exactly) but not
    /// materialised — no OMS append, no local lane, no wire traffic.
    discard: bool,
    pool: &'a BufPool,
}

/// The Outbox's local spill lane state (see [`LocalSpill`]).
struct SpillState {
    dir: PathBuf,
    /// Spill-file size bound — the same ℬ the OMS files use.
    cap: usize,
    buf: Vec<u8>,
    paths: Vec<PathBuf>,
    msgs: u64,
    /// A flush failure deferred out of the infallible `send` hot path;
    /// surfaced by [`Outbox::take_spill`] at end of superstep.
    err: Option<Error>,
}

impl SpillState {
    /// Sort the pending records and write them out as one spill file.
    fn flush(&mut self, rec_size: usize, step: u64) -> Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        let path = self.dir.join(format!("lsp_{step}_{}", self.paths.len()));
        write_sorted_spill(&path, &mut self.buf, rec_size)?;
        self.paths.push(path);
        self.buf.clear();
        Ok(())
    }
}

/// Sort one batch of records and persist it as a spill file, charging the
/// simulated disk — shared by U_r's received-batch spills (`imsp_*`) and
/// the local spill lane (`lsp_*`).
fn write_sorted_spill(path: &std::path::Path, data: &mut Vec<u8>, rec_size: usize) -> Result<()> {
    merge::sort_records(data, rec_size);
    std::fs::write(path, &data[..])?;
    crate::util::diskio::charge(data.len());
    Ok(())
}

/// Outbox per-destination batch size before an OMS append (bytes).
const OUTBOX_BATCH: usize = 8 * 1024;

impl<'a, M: Codec, C: Combiner<M>> Outbox<'a, M, C> {
    #[inline]
    fn send(&mut self, target: u32, m: M) {
        self.msgs_sent += 1;
        if self.discard {
            return;
        }
        let dst = self.part.machine_of(target, self.n);
        if dst == self.me {
            if let Some(ld) = &mut self.local {
                // Zero-copy local delivery: fold into our own A_r shard.
                let pos = self.part.position_of(target, self.n);
                assert!(
                    pos < ld.ar.len(),
                    "local A_r overflow: target {target} pos {pos} cap {}",
                    ld.ar.len()
                );
                if ld.bits.get(pos) {
                    self.comb.combine(&mut ld.ar[pos], &m);
                } else {
                    ld.ar[pos] = m;
                    ld.bits.set(pos, true);
                    ld.touched.push(pos as u32);
                }
                ld.msgs += 1;
                return;
            }
            if let Some(sp) = &mut self.spill {
                // Local spill lane: encode once into the lane buffer;
                // sorted spill files go straight to U_r's S^I merge.
                // A flush failure is deferred (not panicked) so the I/O
                // error propagates through `take_spill`; once it is set
                // the superstep is doomed, so further records are dropped
                // instead of growing the buffer without bound.
                if sp.err.is_some() {
                    return;
                }
                encode_msg(target, &m, &mut sp.buf);
                sp.msgs += 1;
                if sp.buf.len() + self.rec_size > sp.cap {
                    if let Err(e) = sp.flush(self.rec_size, self.step) {
                        sp.err = Some(e);
                        sp.buf.clear();
                    }
                }
                return;
            }
        }
        if self.disable_oms {
            if self.net_err.is_some() {
                return;
            }
            let buf = &mut self.stall_bufs[dst];
            encode_msg(target, &m, buf);
            if buf.len() + self.rec_size > self.cap {
                let batch = std::mem::replace(buf, self.pool.take());
                // Synchronous send: U_c blocks for the simulated
                // transmission — the stall the paper's OMS design avoids.
                // A hung-up peer's error is deferred to flush_stall.
                if let Err(e) = self.stall_sender.send(dst, self.step, Payload::Data(batch)) {
                    self.net_err = Some(e);
                }
            }
        } else {
            let buf = &mut self.batch[dst];
            encode_msg(target, &m, buf);
            if buf.len() >= OUTBOX_BATCH {
                self.oms[dst]
                    .append_records(buf, self.rec_size)
                    .expect("oms append");
                buf.clear();
            }
        }
    }

    /// Flush remaining batches (end of superstep, before finalize) and
    /// recycle the batch buffers.
    fn flush_batches(&mut self) -> Result<()> {
        if !self.disable_oms {
            for dst in 0..self.n {
                let buf = &mut self.batch[dst];
                if !buf.is_empty() {
                    self.oms[dst].append_records(buf, self.rec_size)?;
                }
                self.pool.put(std::mem::take(buf));
            }
        }
        Ok(())
    }

    /// Flush the stall-mode buffers and surface any deferred send error.
    fn flush_stall(&mut self) -> Result<()> {
        if self.disable_oms {
            for dst in 0..self.n {
                let buf = std::mem::take(&mut self.stall_bufs[dst]);
                if buf.is_empty() || self.net_err.is_some() {
                    self.pool.put(buf);
                } else if let Err(e) = self.stall_sender.send(dst, self.step, Payload::Data(buf)) {
                    self.net_err = Some(e);
                }
            }
        }
        match self.net_err.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Close out the local spill lane for this superstep: spill the final
    /// partial buffer, recycle it, and hand the file set back for the
    /// U_c → U_r deposit.
    fn take_spill(&mut self) -> Result<Option<LocalSpill>> {
        match self.spill.take() {
            None => Ok(None),
            Some(mut sp) => {
                if let Some(e) = sp.err.take() {
                    // The superstep is failing: gc the spill files that
                    // did land and recycle the buffer before surfacing.
                    for p in &sp.paths {
                        let _ = std::fs::remove_file(p);
                    }
                    self.pool.put(sp.buf);
                    return Err(e);
                }
                sp.flush(self.rec_size, self.step)?;
                self.pool.put(sp.buf);
                Ok(Some(LocalSpill {
                    paths: sp.paths,
                    msgs: sp.msgs,
                }))
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn compute_unit<P: VertexProgram>(
    global: &JobGlobal<P>,
    store: MachineStore,
    mut vals: Vec<P::Value>,
    init_halted: Option<BitSet>,
    mut init_incoming: Option<Incoming<P::Msg>>,
    oms: Arc<Vec<Arc<SplittableStream>>>,
    msync: Arc<MachineSync>,
    incoming: Arc<IncomingQueue<P::Msg>>,
    local_shard: Option<Arc<LocalShard<P::Msg>>>,
    local_spill: Option<Arc<SpillLane>>,
    mut stall_sender: NetSender,
    sink: &MetricsSink,
    beacon: &AtomicU64,
    tr: &mut UnitTracer,
) -> UcResult<P> {
    let n = global.n;
    let me = store.machine;
    let program = &*global.program;
    let cfg = &global.cfg;
    let pool = &*global.pool;
    let comb = P::Comb::default();
    let local = store.local_vertices();
    let part = if store.recoded {
        Partitioning::Modulo
    } else {
        Partitioning::Hashed
    };
    let rec_size = msg_rec_size::<P::Msg>();
    // Each U_c owns its kernel set: xla handles are not Send.
    let kern = if cfg.use_xla {
        let dir = cfg
            .artifacts_dir
            .clone()
            .unwrap_or_else(KernelSet::default_dir);
        KernelSet::load(&dir)?
    } else {
        KernelSet::native_only()
    };

    let mut halted = match init_halted {
        Some(h) => h,
        None => {
            let mut h = BitSet::new(local);
            for pos in 0..local {
                if !program.initially_active(store.id_at(pos)) {
                    h.set(pos, true);
                }
            }
            h
        }
    };

    // Peak in-memory state accounting (the O(|V|/n) bound).
    let as_cap = global.max_local + 1;
    let digesting = cfg.mode == Mode::Recoded && P::Comb::ENABLED;
    let fast_digest = local_shard.is_some();
    let fast_spill = local_spill.is_some();
    let job_dir = store.dir.join("job");
    let peak_state = (vals.len() * P::Value::SIZE) as u64
        + store.state_bytes()
        + (local as u64 / 8)
        + if digesting {
            // A_r (U_r) + A_s (U_s) message arrays, plus the fast path's
            // local shard when active.
            ((local + as_cap + if fast_digest { local } else { 0 }) * P::Msg::SIZE) as u64
        } else {
            0
        };

    // Fast recovery (§3.4): the failed attempt's merged S^I files, keyed by
    // the absolute superstep that generated them, parked in `replay/` by
    // [`run_machine_resumed`].  The engine verified contiguous coverage of
    // [step_base, R] on every machine before arming the window.
    let replay_dir = store.dir.join("replay");
    let replay_manifest = match global.replay_upto {
        Some(_) => Some(read_replay_manifest(&replay_dir)?),
        None => None,
    };

    // Resident adjacency (semi-external-memory mode, `-c resident=`):
    // resolved once before the superstep loop — `mmap` materializes the
    // CSR pair if missing and maps it strictly, `auto` maps only when the
    // pair fits the budget, `stream` keeps the §3 cursor.  The mapping
    // lives for the whole job, so every superstep reuses the same
    // page-cache-backed pages (and emits zero seeks).
    let csr: Option<CsrMap> = crate::worker::csr::open_resident(&store, cfg)?;
    if let Some(m) = &csr {
        // Two File instants: the mapped byte count (map event) and the
        // madvise hints already issued by CsrMap::open (advise event).
        tr.instant(EventKind::File, m.total_bytes());
        tr.instant(EventKind::File, m.header().checksum());
    }

    let mut global_agg: Arc<P::Agg> = Arc::new(P::Agg::default());
    let mut step: u64 = 0;
    let supersteps;
    loop {
        let abs_step = global.step_base + step;
        beacon.store(abs_step, Ordering::Relaxed);
        tr.begin(EventKind::Superstep, abs_step);
        // Replaying = this superstep's *incoming* (generated at abs_step-1)
        // comes from the retained logs; suppressed = this superstep's
        // *outgoing* (generated at abs_step) is already in those logs, so
        // sends are counted but discarded.  The last replayed superstep
        // (abs_step = R+1) consumes logged incoming while generating fresh
        // outgoing — the seam between replay and normal execution.
        let replaying =
            matches!(global.replay_upto, Some(r) if step > 0 && abs_step - 1 <= r);
        let suppress = matches!(global.replay_upto, Some(r) if abs_step <= r);
        let inc: Option<Incoming<P::Msg>> = if step == 0 {
            // fresh job: no messages; resumed job: the checkpointed IMS
            init_incoming.take()
        } else if replaying {
            // Fast replay: skip the recv wait entirely — the messages were
            // received and merged by the failed attempt.  U_r still runs
            // (its deposit for this step is an unused empty placeholder),
            // so the barrier structure is unchanged.
            let (name, msgs, _bytes) = replay_manifest
                .as_ref()
                .and_then(|m| m.get(&(abs_step - 1)))
                .expect("replay window verified by the engine")
                .clone();
            tr.instant(EventKind::Replay, abs_step);
            Some(Incoming::Sorted {
                path: replay_dir.join(name),
                msgs,
            })
        } else {
            // (incoming.take can only block if the deposit is missing, and
            // wait_recv_done returning Ok guarantees it was made — so the
            // StepQueue itself needs no poisoning.)
            tr.begin(EventKind::Stall, abs_step);
            let t0 = Instant::now();
            let recv = msync.wait_recv_done(step - 1);
            let waited = t0.elapsed().as_secs_f64();
            tr.end(EventKind::Stall, abs_step);
            sink.with_step(step, |m| m.stall_wait_secs += waited);
            recv?;
            Some(incoming.take(step - 1))
        };

        let mut sw = Stopwatch::new();
        sw.start();
        let mut local_agg = P::Agg::default();
        let mut computed = 0u64;
        let mut out: Outbox<'_, P::Msg, P::Comb> = Outbox {
            part,
            n,
            me,
            rec_size,
            disable_oms: cfg.disable_oms,
            cap: cfg.oms_file_cap,
            step,
            stall_bufs: if cfg.disable_oms {
                (0..n).map(|_| pool.take()).collect()
            } else {
                Vec::new()
            },
            stall_sender: &mut stall_sender,
            oms: &oms,
            batch: if cfg.disable_oms {
                Vec::new()
            } else {
                (0..n)
                    .map(|_| pool.take_with_capacity(OUTBOX_BATCH + 64))
                    .collect()
            },
            msgs_sent: 0,
            net_err: None,
            discard: suppress,
            comb: P::Comb::default(),
            local: fast_digest.then(|| LocalDigest {
                ar: global.digest_pool.take(local, comb.identity()),
                bits: BitSet::new(local),
                touched: Vec::new(),
                msgs: 0,
            }),
            spill: fast_spill.then(|| SpillState {
                dir: job_dir.clone(),
                cap: cfg.oms_file_cap,
                buf: pool.take(),
                paths: Vec::new(),
                msgs: 0,
                err: None,
            }),
            pool,
        };

        if digesting {
            let (sums, bits) = match inc {
                Some(Incoming::Digested { ar, bits }) => (ar, bits),
                None => (global.digest_pool.take(local, comb.identity()), BitSet::new(local)),
                Some(Incoming::Sorted { .. }) => {
                    return Err(Error::Other("sorted incoming in recoded mode".into()))
                }
            };
            recoded_pass::<P>(
                program, &kern, &store, csr.as_ref(), cfg, abs_step, global.total_vertices,
                &global_agg, &mut local_agg, &mut vals, &mut halted, &sums, bits, &mut out,
                &mut computed, sink,
            )?;
            // A_r consumed: ping-pong it back for a later superstep.
            global.digest_pool.put(sums);
        } else {
            let mut cursor = match inc {
                Some(Incoming::Sorted { path, .. }) => MsgCursor::open(&path, cfg.stream_buf)?,
                None => MsgCursor::empty(),
                Some(Incoming::Digested { .. }) => {
                    return Err(Error::Other("digested incoming in basic mode".into()))
                }
            };
            per_vertex_pass::<P>(
                program, &store, csr.as_ref(), cfg, abs_step, global.total_vertices,
                &global_agg, &mut local_agg, &mut vals, &mut halted, &mut cursor, &mut out,
                &mut computed, sink,
            )?;
        }

        let msgs_sent = out.msgs_sent;
        out.flush_batches()?;
        out.flush_stall()?;
        let local_digest = out.local.take();
        let spill_out = out.take_spill()?;
        drop(out);

        // Hand the locally-digested shard / spill files to U_r *before*
        // publishing compute_done: our own end tag (which U_r counts) can
        // only be sent after the watermark below, so U_r never misses the
        // deposit.
        if let Some(ld) = local_digest {
            sink.with_step(step, |m| {
                m.local_msgs += ld.msgs;
                m.local_bytes += ld.msgs * rec_size as u64;
            });
            local_shard
                .as_ref()
                .expect("local digest without a shard lane")
                .put(step, ld);
        }
        if let Some(ls) = spill_out {
            sink.with_step(step, |m| {
                m.local_msgs += ls.msgs;
                m.local_bytes += ls.msgs * rec_size as u64;
            });
            local_spill
                .as_ref()
                .expect("local spill without a lane")
                .put(step, ls);
        }

        // Finalize this superstep's OMS files; publish watermarks.
        let mut marks = Vec::with_capacity(n);
        for d in 0..n {
            marks.push(oms[d].close_current_file()?);
        }
        // One file/pool pulse per superstep: the max OMS watermark and the
        // pool's cumulative allocation misses (checkout pressure).
        tr.instant(EventKind::File, marks.iter().copied().max().unwrap_or(0));
        tr.instant(EventKind::Pool, global.pool.stats().misses);
        sw.stop();
        let active_after = (local - halted.count_ones()) as u64;
        sink.with_step(step, |m| {
            m.m_gene_secs += sw.secs();
            m.computed_vertices += computed;
            m.active_after = active_after;
            m.oms_files = marks.iter().copied().max().unwrap_or(0);
        });
        msync.set_compute_done(step, marks);
        msync.kick();

        // Early global control/aggregator sync among U_c's (§4).
        let max_steps = cfg.max_supersteps;
        let abs_step2 = abs_step;
        let program2 = global.program.clone();
        tr.begin(EventKind::Barrier, abs_step);
        let rv_t0 = Instant::now();
        let decision = global.uc_rv.exchange(
            me,
            UcReport {
                msgs_sent,
                active: active_after,
                agg: local_agg,
            },
            move |reports| {
                let mut it = reports.into_iter();
                let first = it.next().unwrap();
                let mut agg = first.agg;
                let mut sent = first.msgs_sent;
                let mut active = first.active;
                for r in it {
                    program2.merge_agg(&mut agg, &r.agg);
                    sent += r.msgs_sent;
                    active += r.active;
                }
                let continues = (sent > 0 || active > 0)
                    && (max_steps == 0 || abs_step2 + 1 < max_steps);
                UcDecision {
                    continues,
                    agg: Arc::new(agg),
                }
            },
        );
        let rv_waited = rv_t0.elapsed().as_secs_f64();
        tr.end(EventKind::Barrier, abs_step);
        sink.with_step(step, |m| m.barrier_wait_secs += rv_waited);
        let decision = decision?;
        global_agg = decision.agg.clone();
        msync.set_decided(step, decision.continues);

        // Synchronous checkpoint (§3.4): after deciding step s, persist
        // values + halted + the incoming messages of step s+1.
        if let Some(ck) = &global.checkpoint {
            // Skipped while replaying (abs_step ≤ R): the incoming deposit
            // for step s+1 is an empty placeholder, not the real IMS — and
            // every durable checkpoint inside the window was already made
            // by the original attempt.  All machines share one window, so
            // the ckpt barrier is skipped consistently.
            let in_replay_window = global.replay_upto.map_or(false, |r| abs_step <= r);
            if decision.continues
                && ck.every > 0
                && (abs_step + 1) % ck.every == 0
                && !in_replay_window
            {
                tr.begin(EventKind::Stall, abs_step);
                let t0 = Instant::now();
                let recv = msync.wait_recv_done(step);
                let waited = t0.elapsed().as_secs_f64();
                tr.end(EventKind::Stall, abs_step);
                sink.with_step(step, |m| m.stall_wait_secs += waited);
                recv?;
                // Fault injection: a checkpoint-write failure, fired before
                // any byte lands — the previous DONE checkpoint stays the
                // durable resume point.
                if let Some(fp) = &global.cfg.fault {
                    if fp.fire(FaultKind::CkptWrite, me, abs_step) {
                        tr.instant(EventKind::Fault, abs_step);
                        return Err(FaultPlan::error(FaultKind::CkptWrite, me, abs_step));
                    }
                }
                incoming.peek_with(step, |inc| {
                    crate::ft::write_machine_checkpoint(
                        &ck.dir, abs_step, me, &vals, &halted, inc,
                    )
                })?;
                // Dedicated checkpoint barrier: the DONE marker may only
                // appear once every machine's file is durable — a resume
                // from a marked checkpoint can then never read a partial
                // set.  Poisoned = a sibling died before its file landed;
                // this checkpoint must then never be marked DONE.
                tr.begin(EventKind::Barrier, abs_step);
                let t0 = Instant::now();
                let rv = global.ckpt_rv.exchange(me, (), |_| ());
                let waited = t0.elapsed().as_secs_f64();
                tr.end(EventKind::Barrier, abs_step);
                sink.with_step(step, |m| m.barrier_wait_secs += waited);
                rv?;
                // Distributed: checkpoint dirs are per-process, so every
                // machine marks its own (the barrier above still guarantees
                // cluster-wide durability before any DONE appears).
                if me == 0 || global.distributed {
                    crate::ft::mark_done(&ck.dir, abs_step)?;
                }
            }
        }

        tr.end(EventKind::Superstep, abs_step);
        if !decision.continues {
            supersteps = step + 1;
            break;
        }
        step += 1;
    }

    // Report results under input-space (old) IDs.
    let ids = (0..local).map(|p| store.display_id_at(p)).collect();
    Ok((ids, vals, peak_state, supersteps, global_agg))
}

/// Per-vertex pass over A + S^E (+ sorted S^I): IO-Basic and the
/// non-combining recoded fallback.
#[allow(clippy::too_many_arguments)]
fn per_vertex_pass<P: VertexProgram>(
    program: &P,
    store: &MachineStore,
    csr: Option<&CsrMap>,
    cfg: &JobConfig,
    step: u64,
    nv: u64,
    global_agg: &P::Agg,
    local_agg: &mut P::Agg,
    vals: &mut [P::Value],
    halted: &mut BitSet,
    cursor: &mut MsgCursor<P::Msg>,
    out: &mut Outbox<'_, P::Msg, P::Comb>,
    computed: &mut u64,
    sink: &MetricsSink,
) -> Result<()> {
    let local = store.local_vertices();
    let mut se = Adjacency::open(store, csr, cfg.stream_buf)?;
    let mut edges: Vec<Edge> = Vec::new();
    let mut msgs: Vec<P::Msg> = Vec::new();

    for pos in 0..local {
        let id = store.id_at(pos);
        let has_msg = cursor.peek_target() == Some(id);
        let active = !halted.get(pos);
        if !active && !has_msg {
            se.defer_skip(store.degs[pos]);
            continue;
        }
        msgs.clear();
        if has_msg {
            cursor.take_for(id, &mut msgs)?;
            // A halted vertex only reactivates if the program says the
            // messages can change it (per-lane sparse skipping; default
            // is always-reactivate).
            if !active && !program.reactivates(&vals[pos], &msgs) {
                se.defer_skip(store.degs[pos]);
                continue;
            }
            halted.set(pos, false); // message reactivates a halted vertex
        }
        se.read_adjacency(store.degs[pos], &mut edges)?;
        *computed += 1;

        let halt_flag;
        {
            let mut send_fn = |t: u32, m: P::Msg| out.send(t, m);
            let mut ctx: Context<'_, P::Msg, P::Agg> =
                Context::new(step, nv, global_agg, local_agg, &mut send_fn);
            program.compute(&mut ctx, id, &mut vals[pos], &edges, &msgs);
            halt_flag = ctx.halt;
        }
        if halt_flag {
            halted.set(pos, true);
        }
    }
    let st = se.io_stats();
    sink.with_step(step, |m| {
        m.edge_items_read += st.read;
        m.edge_items_skipped += st.skipped;
        m.edge_items_mapped += st.mapped;
        m.seeks += st.seeks;
    });
    Ok(())
}

/// Recoded-mode pass fed by the digested A_r: vectorized block update (XLA
/// kernels) with scalar per-vertex fallback.  `sums` is borrowed so the
/// caller can recycle the array through the job's [`DigestPool`] after the
/// pass.
#[allow(clippy::too_many_arguments)]
fn recoded_pass<P: VertexProgram>(
    program: &P,
    kern: &KernelSet,
    store: &MachineStore,
    csr: Option<&CsrMap>,
    cfg: &JobConfig,
    step: u64,
    nv: u64,
    global_agg: &P::Agg,
    local_agg: &mut P::Agg,
    vals: &mut Vec<P::Value>,
    halted: &mut BitSet,
    sums: &[P::Msg],
    bits: BitSet,
    out: &mut Outbox<'_, P::Msg, P::Comb>,
    computed: &mut u64,
    sink: &MetricsSink,
) -> Result<()> {
    let local = store.local_vertices();
    let mut out_base: Vec<Option<P::Msg>> = vec![None; local];
    let handled = {
        let mut bctx = BlockCtx::<P> {
            superstep: step,
            num_vertices: nv,
            vals,
            degs: &store.degs,
            sums,
            halted,
            out_base: &mut out_base,
            global_agg,
            local_agg,
        };
        program.block_update(kern, &mut bctx)?
    };

    let mut se = Adjacency::open(store, csr, cfg.stream_buf)?;
    let mut edges: Vec<Edge> = Vec::new();
    if handled {
        // Fan message bases out along S^E, skipping silent vertices.
        for pos in 0..local {
            match &out_base[pos] {
                None => se.defer_skip(store.degs[pos]),
                Some(base) => {
                    *computed += 1;
                    se.read_adjacency(store.degs[pos], &mut edges)?;
                    let mut send_fn = |t: u32, m: P::Msg| out.send(t, m);
                    program.emit(base, &edges, &mut send_fn);
                }
            }
        }
    } else {
        // Scalar fallback: synthesize per-vertex messages from A_r.
        let mut msgs: Vec<P::Msg> = Vec::new();
        for pos in 0..local {
            let has_msg = bits.get(pos);
            let active = !halted.get(pos);
            if !active && !has_msg {
                se.defer_skip(store.degs[pos]);
                continue;
            }
            msgs.clear();
            if has_msg {
                // Same per-lane reactivation gate as the basic path: a
                // digested message that cannot change the vertex leaves it
                // halted and its adjacency unread.
                if !active && !program.reactivates(&vals[pos], &sums[pos..pos + 1]) {
                    se.defer_skip(store.degs[pos]);
                    continue;
                }
                msgs.push(sums[pos]);
                halted.set(pos, false);
            }
            se.read_adjacency(store.degs[pos], &mut edges)?;
            *computed += 1;
            let id = store.id_at(pos);
            let halt_flag;
            {
                let mut send_fn = |t: u32, m: P::Msg| out.send(t, m);
                let mut ctx: Context<'_, P::Msg, P::Agg> =
                    Context::new(step, nv, global_agg, local_agg, &mut send_fn);
                program.compute(&mut ctx, id, &mut vals[pos], &edges, &msgs);
                halt_flag = ctx.halt;
            }
            if halt_flag {
                halted.set(pos, true);
            }
        }
    }
    let st = se.io_stats();
    sink.with_step(step, |m| {
        m.edge_items_read += st.read;
        m.edge_items_skipped += st.skipped;
        m.edge_items_mapped += st.mapped;
        m.seeks += st.seeks;
    });
    Ok(())
}

#[cfg(test)]
mod spine_equivalence {
    //! Property tests: the three combining paths — in-memory `A_s`
    //! digesting, external merge-sort combining, and the local-delivery
    //! fast fold — must produce identical digested `A_r` contents for any
    //! message set (PageRank-sum and SSSP-min combiners).

    use super::*;
    use crate::api::{MinF32, SumF32};
    use crate::util::proptest_lite;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "graphd_spine_eq_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// Encode `msgs` into OMS-style files under `dir` (ascending indices).
    fn write_files(dir: &PathBuf, msgs: &[(u32, f32)], nfiles: usize) -> Vec<TakenFile> {
        std::fs::create_dir_all(dir).unwrap();
        let chunk = (msgs.len() / nfiles.max(1) + 1).max(1);
        let mut files = Vec::new();
        for (i, ch) in msgs.chunks(chunk).enumerate() {
            let mut buf = Vec::new();
            for &(t, v) in ch {
                encode_msg(t, &v, &mut buf);
            }
            let p = dir.join(format!("f{i}"));
            std::fs::write(&p, &buf).unwrap();
            files.push((i as u64, p, buf.len() as u64));
        }
        files
    }

    /// U_r's digest fold over a combined wire batch.
    fn digest<C: Combiner<f32>>(
        batch: &[u8],
        comb: &C,
        n: usize,
        local: usize,
    ) -> (Vec<f32>, BitSet) {
        let rec_size = msg_rec_size::<f32>();
        let mut ar = vec![comb.identity(); local];
        let mut bits = BitSet::new(local);
        for rec in batch.chunks_exact(rec_size) {
            let pos = rec_target(rec) as usize / n;
            let m = rec_payload::<f32>(rec);
            if bits.get(pos) {
                comb.combine(&mut ar[pos], &m);
            } else {
                ar[pos] = m;
                bits.set(pos, true);
            }
        }
        (ar, bits)
    }

    /// The Outbox local fast path's fold, straight from raw messages.
    fn local_fold<C: Combiner<f32>>(
        msgs: &[(u32, f32)],
        comb: &C,
        n: usize,
        local: usize,
    ) -> (Vec<f32>, BitSet) {
        let mut ar = vec![comb.identity(); local];
        let mut bits = BitSet::new(local);
        for &(t, v) in msgs {
            let pos = t as usize / n;
            if bits.get(pos) {
                comb.combine(&mut ar[pos], &v);
            } else {
                ar[pos] = v;
                bits.set(pos, true);
            }
        }
        (ar, bits)
    }

    fn check_equivalence<C: Combiner<f32>>(comb: C, tag: &str) {
        proptest_lite::run(40, |g| {
            let n = g.usize_in(1, 5);
            let j = g.usize_in(0, n); // destination machine
            let local = g.usize_in(1, 60);
            let nmsgs = g.usize_in(0, 400);
            // Integer-valued payloads keep f32 sums exact regardless of
            // fold order, so equality below can be strict.
            let msgs: Vec<(u32, f32)> = (0..nmsgs)
                .map(|_| {
                    let pos = g.usize_in(0, local);
                    ((pos * n + j) as u32, g.u32_below(1000) as f32)
                })
                .collect();
            let dir = tmp(&format!("{tag}{}", g.case));
            let pool = BufPool::new(8);

            let files = write_files(&dir.join("mem"), &msgs, 4);
            let mut a_s = vec![comb.identity(); local + 1];
            let mut touched = Vec::new();
            let mut as_bits = BitSet::new(local + 1);
            let mem = combine_in_memory::<f32, C>(
                &files, &comb, n, &mut a_s, &mut touched, &mut as_bits, &pool,
            )
            .unwrap();

            let files2 = write_files(&dir.join("srt"), &msgs, 3);
            let srt = combine_by_mergesort::<f32, C>(
                &files2, &comb, 4, 256, &dir.join("tmp"), &pool,
            )
            .unwrap();

            let (ar_mem, bits_mem) = digest(&mem, &comb, n, local);
            let (ar_srt, bits_srt) = digest(&srt, &comb, n, local);
            let (ar_loc, bits_loc) = local_fold(&msgs, &comb, n, local);
            let _ = std::fs::remove_dir_all(&dir);

            for pos in 0..local {
                crate::prop_assert!(
                    g,
                    bits_mem.get(pos) == bits_loc.get(pos)
                        && bits_srt.get(pos) == bits_loc.get(pos),
                    "presence mismatch at pos {pos} (n={n}, j={j})"
                );
                if bits_loc.get(pos) {
                    crate::prop_assert!(
                        g,
                        ar_mem[pos] == ar_loc[pos] && ar_srt[pos] == ar_loc[pos],
                        "A_r mismatch at pos {pos}: mem {} srt {} local {}",
                        ar_mem[pos],
                        ar_srt[pos],
                        ar_loc[pos]
                    );
                }
            }
        });
    }

    #[test]
    fn pagerank_sum_combiner_paths_agree() {
        check_equivalence(SumF32, "sum");
    }

    #[test]
    fn sssp_min_combiner_paths_agree() {
        check_equivalence(MinF32, "min");
    }

    #[test]
    fn local_shard_hands_off_in_step_order() {
        let shard: Arc<LocalShard<f32>> = LocalShard::new();
        for step in [1u64, 0, 2] {
            shard.put(
                step,
                LocalDigest {
                    ar: vec![step as f32],
                    bits: BitSet::new(1),
                    touched: Vec::new(),
                    msgs: step,
                },
            );
        }
        // Takes are by step, independent of deposit order.
        assert_eq!(shard.take(0).msgs, 0);
        assert_eq!(shard.take(2).ar, vec![2.0]);
        assert_eq!(shard.take(1).msgs, 1);
    }
}
