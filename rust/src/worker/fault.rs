//! Deterministic fault injection for the recovery layer (§3.4).
//!
//! A [`FaultPlan`] is a small set of typed faults, each pinned to a
//! (kind, machine, superstep) coordinate.  The units consult the plan at
//! fixed, deterministic points of every superstep (see [`FaultKind`] for
//! where each kind fires) and surface the injected failure as the same
//! typed error a real one would produce — an `Error::Io` for the disk
//! faults, a transient send failure for the network fault — so the whole
//! propagation path (abort latch → poisoned barriers → typed
//! `Error::JobFailed` → auto-resume) is exercised end to end, not mocked.
//!
//! Each fault in a plan fires **once per plan**, not once per attempt:
//! the fired flags are shared across clones (`Arc<AtomicBool>`), so the
//! plan threaded through `JobConfig` keeps its state when the session
//! layer re-runs the job from a checkpoint.  Without that, a retry would
//! re-inject the same fault at the same superstep and the job could never
//! complete — the plan is a fault *budget*, spent exactly once.
//!
//! CLI: `-c fault=us_io@m1s3` (multiple faults `;`-separated); API:
//! `JobBuilder::inject_faults(FaultPlan::one(..))`.

use crate::error::{Error, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// What kind of failure to inject, and (implicitly) where it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// U_s I/O error: fires when the sender starts processing the
    /// superstep's OMS files (config name `us_io`).
    UsIo,
    /// U_r I/O error: fires when the receiver starts the superstep's
    /// receive loop (config name `ur_io`).
    UrIo,
    /// Transient `net::Switch` send failure: fires at the same sender
    /// point as `UsIo` but surfaces as a transient network error, not an
    /// I/O error (config name `net_send`).
    NetSend,
    /// Checkpoint-write failure: fires inside U_c's checkpoint block,
    /// before the state is serialized (config name `ckpt_write`).
    CkptWrite,
}

impl FaultKind {
    /// The config-string name (`-c fault=<name>@m<machine>s<superstep>`).
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::UsIo => "us_io",
            FaultKind::UrIo => "ur_io",
            FaultKind::NetSend => "net_send",
            FaultKind::CkptWrite => "ckpt_write",
        }
    }

    fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "us_io" => FaultKind::UsIo,
            "ur_io" => FaultKind::UrIo,
            "net_send" => FaultKind::NetSend,
            "ckpt_write" => FaultKind::CkptWrite,
            _ => return None,
        })
    }
}

/// One planned fault: fire `kind` on `machine` at absolute superstep
/// `superstep`, once.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    /// What to inject (also determines which unit consults the spec).
    pub kind: FaultKind,
    /// Machine the fault fires on.
    pub machine: usize,
    /// Absolute superstep (`step_base + step`), so a fault pinned to step
    /// 3 means the same thing in a fresh run and a resumed one.
    pub superstep: u64,
    /// Shared across clones: the fault fires once per *plan*, not once
    /// per attempt (see the module docs).
    fired: Arc<AtomicBool>,
}

impl FaultSpec {
    fn new(kind: FaultKind, machine: usize, superstep: u64) -> Self {
        Self {
            kind,
            machine,
            superstep,
            fired: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Has this fault already fired?
    pub fn fired(&self) -> bool {
        self.fired.load(Ordering::Acquire)
    }
}

/// A deterministic set of one-shot faults (see the module docs).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    specs: Vec<FaultSpec>,
}

impl FaultPlan {
    /// A plan with a single fault.
    pub fn one(kind: FaultKind, machine: usize, superstep: u64) -> Self {
        Self {
            specs: vec![FaultSpec::new(kind, machine, superstep)],
        }
    }

    /// Add another fault to the plan (builder-style).
    pub fn and(mut self, kind: FaultKind, machine: usize, superstep: u64) -> Self {
        self.specs.push(FaultSpec::new(kind, machine, superstep));
        self
    }

    /// Parse the CLI form: `kind@m<machine>s<superstep>`, multiple faults
    /// separated by `;` — e.g. `-c fault=us_io@m1s3;net_send@m0s2`.
    pub fn parse(s: &str) -> Result<Self> {
        let bad = || Error::Config(format!(
            "bad fault spec '{s}' (want kind@m<machine>s<superstep>, kinds: \
             us_io | ur_io | net_send | ckpt_write)"
        ));
        let mut plan = FaultPlan::default();
        for part in s.split(';').filter(|p| !p.trim().is_empty()) {
            let (kind, at) = part.trim().split_once('@').ok_or_else(bad)?;
            let kind = FaultKind::parse(kind).ok_or_else(bad)?;
            let at = at.strip_prefix('m').ok_or_else(bad)?;
            let (machine, superstep) = at.split_once('s').ok_or_else(bad)?;
            let machine = machine.parse().map_err(|_| bad())?;
            let superstep = superstep.parse().map_err(|_| bad())?;
            plan.specs.push(FaultSpec::new(kind, machine, superstep));
        }
        if plan.specs.is_empty() {
            return Err(bad());
        }
        Ok(plan)
    }

    /// The planned faults (fired or not).
    pub fn specs(&self) -> &[FaultSpec] {
        &self.specs
    }

    /// Does a fault of `kind` fire now, at (machine, superstep)?  The
    /// first matching unfired spec is atomically marked fired; later calls
    /// (and later attempts) see `false`.
    pub fn fire(&self, kind: FaultKind, machine: usize, superstep: u64) -> bool {
        self.specs.iter().any(|f| {
            f.kind == kind
                && f.machine == machine
                && f.superstep == superstep
                && f.fired
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
        })
    }

    /// The typed error an injected fault surfaces — shaped like the real
    /// failure it simulates, with an "injected fault" marker in the text.
    pub fn error(kind: FaultKind, machine: usize, superstep: u64) -> Error {
        let io = |what: &str| {
            Error::Io(std::io::Error::new(
                std::io::ErrorKind::Other,
                format!("injected fault: {what} (machine {machine}, superstep {superstep})"),
            ))
        };
        match kind {
            FaultKind::UsIo => io("U_s I/O error"),
            FaultKind::UrIo => io("U_r I/O error"),
            FaultKind::CkptWrite => io("checkpoint write error"),
            FaultKind::NetSend => Error::Other(format!(
                "injected fault: transient network send failure \
                 (machine {machine}, superstep {superstep})"
            )),
        }
    }
}

/// Is a rendered `JobFailed` cause *retryable* — worth re-running from the
/// last durable checkpoint?  I/O errors and transient network failures
/// are (the machine/disk/switch may be healthy again); everything else —
/// config errors, corrupt streams — is deterministic and fatal.  Panics
/// are classified separately by the session retry loop (retryable once,
/// fatal when the program panics at the same superstep twice).
pub fn retryable_cause(cause: &str) -> bool {
    cause.contains("I/O error") || cause.contains("transient")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_and_errors() {
        let p = FaultPlan::parse("us_io@m1s3").unwrap();
        assert_eq!(p.specs().len(), 1);
        assert_eq!(p.specs()[0].kind, FaultKind::UsIo);
        assert_eq!(p.specs()[0].machine, 1);
        assert_eq!(p.specs()[0].superstep, 3);

        let p = FaultPlan::parse("net_send@m0s2;ckpt_write@m2s5").unwrap();
        assert_eq!(p.specs().len(), 2);
        assert_eq!(p.specs()[1].kind, FaultKind::CkptWrite);

        for bad in ["", "weird@m0s1", "us_io@x0s1", "us_io@m0", "us_io@m0sx"] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn fires_once_even_across_clones() {
        let p = FaultPlan::one(FaultKind::UsIo, 1, 3);
        let p2 = p.clone(); // the retry attempt's view
        assert!(!p.fire(FaultKind::UsIo, 0, 3), "wrong machine");
        assert!(!p.fire(FaultKind::UsIo, 1, 2), "wrong superstep");
        assert!(!p.fire(FaultKind::NetSend, 1, 3), "wrong kind");
        assert!(p.fire(FaultKind::UsIo, 1, 3), "first hit fires");
        assert!(!p.fire(FaultKind::UsIo, 1, 3), "one-shot");
        assert!(!p2.fire(FaultKind::UsIo, 1, 3), "clones share the budget");
        assert!(p2.specs()[0].fired());
    }

    #[test]
    fn errors_are_typed_and_marked() {
        let e = FaultPlan::error(FaultKind::UsIo, 1, 3);
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("injected fault"));
        assert!(retryable_cause(&e.to_string()), "{e}");

        let e = FaultPlan::error(FaultKind::NetSend, 0, 2);
        assert!(matches!(e, Error::Other(_)));
        assert!(retryable_cause(&e.to_string()), "{e}");
    }

    #[test]
    fn retryable_classification() {
        assert!(retryable_cause("I/O error: disk on fire"));
        assert!(retryable_cause("transient network send failure"));
        assert!(!retryable_cause("bad value 'x' for 'mode'"));
        assert!(!retryable_cause("corrupt stream: short read"));
        assert!(!retryable_cause("U_c panicked: boom"), "panics classified by the loop");
    }
}
