//! Resident adjacency store: the recoded/basic graph materialized as flat
//! mmap-able CSR files (semi-external-memory mode, `-c resident=`).
//!
//! GraphD's §3 streaming design re-reads `se.bin` every superstep to keep
//! O(|V|/n) heap.  GraphMP's semi-external design (PAPERS.md) instead keeps
//! topology memory-mapped: adjacency becomes an O(1) zero-copy slice and
//! the OS page cache does the streaming.  This module materializes a
//! store's edge stream as two flat files next to it —
//!
//! * `csr_offsets` — header + `(local+1)` LE u64 *item*-offset prefix sums
//!   of the degree array (byte offset = item offset × item size);
//! * `csr_edges`   — header + a payload **byte-identical to `se.bin`**
//!   (LE u32 neighbor, + LE f32 weight when weighted),
//!
//! each headed by the 64-byte versioned header specified normatively in
//! `docs/FORMATS.md` (magic [`CSR_MAGIC`], version, role, counts, and an
//! FNV-1a-64 header checksum that doubles as the cache key).  Because the
//! edges payload is byte-identical to `se.bin`, the mapped decode path is
//! bit-identical to [`EdgeStreamCursor`] by construction.
//!
//! The heap story: a `PROT_READ`/`MAP_SHARED` mapping is page cache, not
//! heap ([`crate::util::mmap`]), so `resident=mmap` preserves the paper's
//! O(|V|/n) bound while letting hot edges live in memory.  Mapped reads
//! deliberately bypass `util::diskio::charge` — the whole point of the
//! mode is that steady-state reads are page-cache hits, so the simulated
//! streaming-disk model does not apply to them.
//!
//! Materialization is atomic (PR 8 idiom): write `<name>.csr.tmp`, fsync
//! the file, rename into place, fsync the directory — a torn
//! materialization is never mapped, and `make clean` sweeps stale
//! `*.csr.tmp` partials.

use crate::api::Edge;
use crate::config::{JobConfig, Resident};
use crate::error::{Error, Result};
use crate::util::mmap::{Advice, Mmap};
use crate::worker::storage::{item_size, EdgeStreamCursor, MachineStore};
use std::io::Write;
use std::path::Path;

/// CSR file magic: `"GDC1"` as LE u32 (mirrors the frame magic `GDF1`).
pub const CSR_MAGIC: u32 = 0x4744_4331;
/// Current CSR header version.  Readers reject other versions; format
/// evolution rules live in `docs/FORMATS.md`.
pub const CSR_VERSION: u16 = 1;
/// Fixed header length in bytes (payload starts at this offset).
pub const CSR_HEADER_LEN: usize = 64;
/// File name of the offsets array within a store directory.
pub const CSR_OFFSETS: &str = "csr_offsets";
/// File name of the edges payload within a store directory.
pub const CSR_EDGES: &str = "csr_edges";

/// `role` byte: this file is the offsets array.
const ROLE_OFFSETS: u8 = 0;
/// `role` byte: this file is the edges payload.
const ROLE_EDGES: u8 = 1;
/// `flags` bit 0: items carry a weight (8 bytes/item instead of 4).
const FLAG_WEIGHTED: u8 = 1;

/// FNV-1a-64 over `bytes` (offset basis 0xcbf29ce484222325, prime
/// 0x100000001b3) — the header checksum / cache-key hash.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Decoded 64-byte CSR file header (layout: `docs/FORMATS.md`).
///
/// The on-disk checksum is FNV-1a-64 over header bytes 0..48 with the
/// checksum field zeroed; it both detects header corruption and keys
/// cache-dir reuse (same counts/flags/partition → same checksum).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CsrHeader {
    /// `ROLE_OFFSETS` (0) or `ROLE_EDGES` (1).
    pub role: u8,
    /// Items carry weights (8 bytes/item).
    pub weighted: bool,
    /// Vertices on this machine, |V(W)|.
    pub local_vertices: u64,
    /// Adjacency items on this machine (Σ degs).
    pub items: u64,
    /// Total vertices across the cluster.
    pub total_vertices: u64,
    /// This machine's index.
    pub machine: u32,
    /// Cluster size n.
    pub num_machines: u32,
    /// Payload bytes following the header.
    pub payload_len: u64,
}

impl CsrHeader {
    /// Encode as the 64-byte on-disk header, checksum filled in.
    pub fn encode(&self) -> [u8; CSR_HEADER_LEN] {
        let mut h = [0u8; CSR_HEADER_LEN];
        h[0..4].copy_from_slice(&CSR_MAGIC.to_le_bytes());
        h[4..6].copy_from_slice(&CSR_VERSION.to_le_bytes());
        h[6] = self.role;
        h[7] = if self.weighted { FLAG_WEIGHTED } else { 0 };
        h[8..16].copy_from_slice(&self.local_vertices.to_le_bytes());
        h[16..24].copy_from_slice(&self.items.to_le_bytes());
        h[24..32].copy_from_slice(&self.total_vertices.to_le_bytes());
        h[32..36].copy_from_slice(&self.machine.to_le_bytes());
        h[36..40].copy_from_slice(&self.num_machines.to_le_bytes());
        h[40..48].copy_from_slice(&self.payload_len.to_le_bytes());
        let sum = fnv1a64(&h[0..48]);
        h[48..56].copy_from_slice(&sum.to_le_bytes());
        // 56..64 reserved, zero.
        h
    }

    /// The header checksum (also the cache key for reuse decisions).
    pub fn checksum(&self) -> u64 {
        let h = self.encode();
        u64::from_le_bytes(h[48..56].try_into().unwrap())
    }

    /// Decode and validate a 64-byte header read from `what` (used in
    /// error messages).  Bad magic, unknown version, unknown role, a
    /// checksum mismatch, or non-zero reserved bytes are all typed
    /// [`Error::CorruptStream`] — never UB, never a panic.
    pub fn decode(h: &[u8], what: &str) -> Result<CsrHeader> {
        let corrupt = |msg: String| Error::CorruptStream(format!("{what}: {msg}"));
        if h.len() < CSR_HEADER_LEN {
            return Err(corrupt(format!(
                "truncated header ({} < {CSR_HEADER_LEN} bytes)",
                h.len()
            )));
        }
        let magic = u32::from_le_bytes(h[0..4].try_into().unwrap());
        if magic != CSR_MAGIC {
            return Err(corrupt(format!("bad magic {magic:#010x} (want {CSR_MAGIC:#010x})")));
        }
        let version = u16::from_le_bytes(h[4..6].try_into().unwrap());
        if version != CSR_VERSION {
            return Err(corrupt(format!("unsupported version {version} (have {CSR_VERSION})")));
        }
        let role = h[6];
        if role != ROLE_OFFSETS && role != ROLE_EDGES {
            return Err(corrupt(format!("unknown role byte {role}")));
        }
        let flags = h[7];
        if flags & !FLAG_WEIGHTED != 0 {
            return Err(corrupt(format!("unknown flag bits {flags:#04x}")));
        }
        let stored = u64::from_le_bytes(h[48..56].try_into().unwrap());
        let computed = fnv1a64(&h[0..48]);
        if stored != computed {
            return Err(corrupt(format!(
                "header checksum mismatch (stored {stored:#018x}, computed {computed:#018x})"
            )));
        }
        if h[56..64] != [0u8; 8] {
            return Err(corrupt("reserved header bytes not zero".into()));
        }
        Ok(CsrHeader {
            role,
            weighted: flags & FLAG_WEIGHTED != 0,
            local_vertices: u64::from_le_bytes(h[8..16].try_into().unwrap()),
            items: u64::from_le_bytes(h[16..24].try_into().unwrap()),
            total_vertices: u64::from_le_bytes(h[24..32].try_into().unwrap()),
            machine: u32::from_le_bytes(h[32..36].try_into().unwrap()),
            num_machines: u32::from_le_bytes(h[36..40].try_into().unwrap()),
            payload_len: u64::from_le_bytes(h[40..48].try_into().unwrap()),
        })
    }
}

/// The pair of headers a store's CSR files must carry (offsets, edges),
/// derived from the store's in-memory meta.
fn expected_headers(store: &MachineStore) -> (CsrHeader, CsrHeader) {
    let items: u64 = store.degs.iter().map(|&d| d as u64).sum();
    let local = store.local_vertices() as u64;
    let base = CsrHeader {
        role: ROLE_OFFSETS,
        weighted: store.weighted,
        local_vertices: local,
        items,
        total_vertices: store.total_vertices,
        machine: store.machine as u32,
        num_machines: store.num_machines as u32,
        payload_len: (local + 1) * 8,
    };
    let edges = CsrHeader {
        role: ROLE_EDGES,
        payload_len: items * item_size(store.weighted) as u64,
        ..base
    };
    (base, edges)
}

/// Total on-disk bytes of a store's CSR pair (headers + payloads) — the
/// quantity `resident=auto` compares against `resident_budget` *before*
/// materializing anything.
pub fn expected_bytes(store: &MachineStore) -> u64 {
    let (o, e) = expected_headers(store);
    2 * CSR_HEADER_LEN as u64 + o.payload_len + e.payload_len
}

/// fsync a directory so a preceding rename is durable (no-op off unix,
/// same idiom as the checkpoint DONE protocol).
fn sync_dir(dir: &Path) -> Result<()> {
    #[cfg(unix)]
    {
        std::fs::File::open(dir)?.sync_all()?;
    }
    #[cfg(not(unix))]
    {
        let _ = dir;
    }
    Ok(())
}

/// Atomically publish `header + payload` as `dir/name`: write
/// `name.csr.tmp`, fsync, rename over `name`, fsync the directory.
fn write_csr_file(dir: &Path, name: &str, header: &CsrHeader, payload: &[u8]) -> Result<()> {
    let tmp = dir.join(format!("{name}.csr.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&header.encode())?;
        f.write_all(payload)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, dir.join(name))?;
    sync_dir(dir)
}

/// Does `dir/name` already hold a valid CSR file with exactly `want`'s
/// header (checksum-keyed reuse)?  Any read error, decode error, header
/// mismatch, or payload-length-vs-file-size mismatch → false.
fn file_is_current(dir: &Path, name: &str, want: &CsrHeader) -> bool {
    let path = dir.join(name);
    let Ok(meta) = std::fs::metadata(&path) else {
        return false;
    };
    if meta.len() != CSR_HEADER_LEN as u64 + want.payload_len {
        return false;
    }
    let mut head = [0u8; CSR_HEADER_LEN];
    let ok = std::fs::File::open(&path)
        .and_then(|mut f| std::io::Read::read_exact(&mut f, &mut head))
        .is_ok();
    if !ok {
        return false;
    }
    matches!(CsrHeader::decode(&head, name), Ok(h) if h == *want)
}

/// Materialize the store's CSR pair (`csr_offsets` + `csr_edges`) next to
/// `se.bin`, reusing existing files whose headers already match
/// (checksum-keyed cache).  Returns `true` when files were (re)written,
/// `false` when both were reused.  Idempotent; safe to call from load,
/// recode, and compute.
pub fn ensure_csr(store: &MachineStore) -> Result<bool> {
    let (want_off, want_edg) = expected_headers(store);
    if file_is_current(&store.dir, CSR_OFFSETS, &want_off)
        && file_is_current(&store.dir, CSR_EDGES, &want_edg)
    {
        return Ok(false);
    }

    // Offsets payload: (local+1) LE u64 item-offset prefix sums of degs.
    let mut offsets = Vec::with_capacity((store.local_vertices() + 1) * 8);
    let mut run: u64 = 0;
    offsets.extend_from_slice(&run.to_le_bytes());
    for &d in &store.degs {
        run += d as u64;
        offsets.extend_from_slice(&run.to_le_bytes());
    }

    // Edges payload: byte-identical to se.bin (that identity is what makes
    // the mapped decode bit-identical to the streaming cursor).
    let edges = std::fs::read(store.se_path())?;
    if edges.len() as u64 != want_edg.payload_len {
        return Err(Error::CorruptStream(format!(
            "se.bin length {} != expected {} (Σdeg × item size)",
            edges.len(),
            want_edg.payload_len
        )));
    }

    write_csr_file(&store.dir, CSR_OFFSETS, &want_off, &offsets)?;
    write_csr_file(&store.dir, CSR_EDGES, &want_edg, &edges)?;
    Ok(true)
}

/// A validated, mapped CSR pair for one store: offsets + edges files each
/// mapped read-only, headers checked against the store's meta on open.
pub struct CsrMap {
    offsets: Mmap,
    edges: Mmap,
    header: CsrHeader,
    isz: usize,
}

impl CsrMap {
    /// Map and validate the store's CSR pair.  Corrupt or stale files are
    /// a typed [`Error::CorruptStream`]; the caller decides whether that
    /// is fatal (`resident=mmap`) or a fallback to streaming (`auto`).
    /// Issues `MADV_SEQUENTIAL`/`MADV_WILLNEED` on the edges mapping.
    pub fn open(store: &MachineStore) -> Result<CsrMap> {
        let (want_off, want_edg) = expected_headers(store);
        let offsets = Self::open_one(&store.dir, CSR_OFFSETS, &want_off)?;
        let edges = Self::open_one(&store.dir, CSR_EDGES, &want_edg)?;
        edges.advise(Advice::Sequential);
        edges.advise(Advice::WillNeed);
        offsets.advise(Advice::WillNeed);
        Ok(CsrMap {
            offsets,
            edges,
            header: want_edg,
            isz: item_size(store.weighted),
        })
    }

    fn open_one(dir: &Path, name: &str, want: &CsrHeader) -> Result<Mmap> {
        let map = Mmap::map_file(&dir.join(name))?;
        let got = CsrHeader::decode(map.as_slice(), name)?;
        if got != *want {
            return Err(Error::CorruptStream(format!(
                "{name}: header does not match store meta (stale cache? key {:#018x} vs {:#018x})",
                got.checksum(),
                want.checksum()
            )));
        }
        let have = map.len() as u64;
        let need = CSR_HEADER_LEN as u64 + want.payload_len;
        if have != need {
            return Err(Error::CorruptStream(format!(
                "{name}: file is {have} bytes, header promises {need}"
            )));
        }
        Ok(map)
    }

    /// The edges-file header (counts, flags, checksum/cache key).
    pub fn header(&self) -> &CsrHeader {
        &self.header
    }

    /// Total mapped bytes across both files (the `auto` budget quantity).
    pub fn total_bytes(&self) -> u64 {
        (self.offsets.len() + self.edges.len()) as u64
    }

    /// True when backed by real mappings (false only on the non-unix
    /// heap-buffer fallback of [`crate::util::mmap`]).
    pub fn is_real_mapping(&self) -> bool {
        self.edges.is_real_mapping()
    }

    /// Item-offset bounds `[start, end)` of the adjacency list at `pos`,
    /// from the offsets array (O(1) random access).
    pub fn item_bounds(&self, pos: usize) -> Result<(u64, u64)> {
        let payload = &self.offsets.as_slice()[CSR_HEADER_LEN..];
        let need = (pos + 2) * 8;
        if need > payload.len() {
            return Err(Error::CorruptStream(format!(
                "csr_offsets: vertex pos {pos} out of range"
            )));
        }
        let at = |i: usize| u64::from_le_bytes(payload[i * 8..i * 8 + 8].try_into().unwrap());
        Ok((at(pos), at(pos + 1)))
    }

    /// Zero-copy byte slice of `n` adjacency items starting at item
    /// `start` — the mapped replacement for a buffered `read_exact`.
    pub fn item_slice(&self, start: u64, n: u64) -> Result<&[u8]> {
        let payload = &self.edges.as_slice()[CSR_HEADER_LEN..];
        let a = start as usize * self.isz;
        let b = (start + n) as usize * self.isz;
        payload.get(a..b).ok_or_else(|| {
            Error::CorruptStream(format!(
                "csr_edges: items {start}..{} out of range ({} items total)",
                start + n,
                self.header.items
            ))
        })
    }

    /// Sequential cursor over the mapped edges, [`EdgeStreamCursor`]
    /// semantics (one pass in A order, lazy skips).
    pub fn cursor(&self) -> CsrCursor<'_> {
        CsrCursor {
            map: self,
            pos: 0,
            pending_skip: 0,
            items_read: 0,
            items_skipped: 0,
        }
    }
}

impl std::fmt::Debug for CsrMap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CsrMap")
            .field("items", &self.header.items)
            .field("bytes", &self.total_bytes())
            .field("key", &format_args!("{:#018x}", self.header.checksum()))
            .finish()
    }
}

/// Sequential cursor over a [`CsrMap`]: drop-in for [`EdgeStreamCursor`]
/// (`defer_skip` / `read_adjacency` / `io_stats`), but a skip is a pointer
/// bump and a read is a zero-copy slice decode — no buffered I/O, no
/// seeks.
pub struct CsrCursor<'a> {
    map: &'a CsrMap,
    /// Current item position in the edges payload.
    pos: u64,
    pending_skip: u64,
    items_read: u64,
    items_skipped: u64,
}

impl CsrCursor<'_> {
    /// Note that the next `deg` items belong to a vertex that will not
    /// compute (lazy, same contract as the streaming cursor).
    #[inline]
    pub fn defer_skip(&mut self, deg: u32) {
        self.pending_skip += deg as u64;
    }

    /// Decode the next `deg` items into `out` (cleared first) straight
    /// from the mapping.
    pub fn read_adjacency(&mut self, deg: u32, out: &mut Vec<Edge>) -> Result<()> {
        if self.pending_skip > 0 {
            self.pos += self.pending_skip;
            self.items_skipped += self.pending_skip;
            self.pending_skip = 0;
        }
        let bytes = self.map.item_slice(self.pos, deg as u64)?;
        out.clear();
        out.reserve(deg as usize);
        if self.map.header.weighted {
            for item in bytes.chunks_exact(8) {
                out.push(Edge {
                    nbr: u32::from_le_bytes(item[..4].try_into().unwrap()),
                    weight: f32::from_le_bytes(item[4..8].try_into().unwrap()),
                });
            }
        } else {
            for item in bytes.chunks_exact(4) {
                out.push(Edge {
                    nbr: u32::from_le_bytes(item.try_into().unwrap()),
                    weight: 1.0,
                });
            }
        }
        self.pos += deg as u64;
        self.items_read += deg as u64;
        Ok(())
    }

    /// `(items_read, items_skipped)` — mapped reads never seek.
    pub fn io_stats(&self) -> (u64, u64) {
        (self.items_read, self.items_skipped)
    }
}

/// Adjacency I/O statistics of one pass, mode-agnostic:
/// `read`/`skipped` count items in both modes, `seeks` is only non-zero
/// when streaming, `mapped` is only non-zero when resident (and then
/// equals `read`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdjStats {
    /// Adjacency items decoded.
    pub read: u64,
    /// Adjacency items skipped over.
    pub skipped: u64,
    /// Seeks issued by the streaming reader (0 when mapped).
    pub seeks: u64,
    /// Items decoded from a mapping (0 when streaming).
    pub mapped: u64,
}

/// One superstep's adjacency source: the §3 streaming cursor or a cursor
/// over the resident mapping — same `defer_skip`/`read_adjacency` calls,
/// so the per-vertex pass bodies are mode-blind.
pub enum Adjacency<'a> {
    /// Buffered sequential reads of `se.bin` (charges the simulated disk).
    Stream(EdgeStreamCursor),
    /// Zero-copy decode from the mapped `csr_edges` payload.
    Mapped(CsrCursor<'a>),
}

impl<'a> Adjacency<'a> {
    /// Open the pass's adjacency source: a cursor over `csr` when the
    /// resident map is present, else the streaming cursor.
    pub fn open(store: &MachineStore, csr: Option<&'a CsrMap>, stream_buf: usize) -> Result<Self> {
        Ok(match csr {
            Some(m) => Adjacency::Mapped(m.cursor()),
            None => Adjacency::Stream(EdgeStreamCursor::open(store, stream_buf)?),
        })
    }

    /// See [`EdgeStreamCursor::defer_skip`].
    #[inline]
    pub fn defer_skip(&mut self, deg: u32) {
        match self {
            Adjacency::Stream(c) => c.defer_skip(deg),
            Adjacency::Mapped(c) => c.defer_skip(deg),
        }
    }

    /// See [`EdgeStreamCursor::read_adjacency`].
    #[inline]
    pub fn read_adjacency(&mut self, deg: u32, out: &mut Vec<Edge>) -> Result<()> {
        match self {
            Adjacency::Stream(c) => c.read_adjacency(deg, out),
            Adjacency::Mapped(c) => c.read_adjacency(deg, out),
        }
    }

    /// This pass's I/O counters.
    pub fn io_stats(&self) -> AdjStats {
        match self {
            Adjacency::Stream(c) => {
                let (read, skipped, seeks) = c.io_stats();
                AdjStats { read, skipped, seeks, mapped: 0 }
            }
            Adjacency::Mapped(c) => {
                let (read, skipped) = c.io_stats();
                AdjStats { read, skipped, seeks: 0, mapped: read }
            }
        }
    }
}

/// Materialize the CSR pair for `store` if `resident` calls for it:
/// `stream` → never; `mmap` → always (errors are fatal); `auto` → only
/// when [`expected_bytes`] fits `budget` (else stay streaming).  Returns
/// whether files were (re)written.
pub fn prepare(store: &MachineStore, resident: Resident, budget: u64) -> Result<bool> {
    match resident {
        Resident::Stream => Ok(false),
        Resident::Mmap => ensure_csr(store),
        Resident::Auto => {
            if expected_bytes(store) <= budget {
                ensure_csr(store)
            } else {
                Ok(false)
            }
        }
    }
}

/// Resolve the job's residency for one store, called once per U_c before
/// the superstep loop: `None` = stream, `Some(map)` = read adjacency from
/// the mapping.  `mmap` is strict (missing files are materialized, corrupt
/// ones are a typed error); `auto` falls back to streaming on oversized,
/// missing-with-oversized, or invalid CSR files.
pub fn open_resident(store: &MachineStore, cfg: &JobConfig) -> Result<Option<CsrMap>> {
    match cfg.resident {
        Resident::Stream => Ok(None),
        Resident::Mmap => {
            ensure_csr(store)?;
            Ok(Some(CsrMap::open(store)?))
        }
        Resident::Auto => {
            if expected_bytes(store) > cfg.resident_budget {
                return Ok(None);
            }
            match ensure_csr(store).and_then(|_| CsrMap::open(store)) {
                Ok(m) => Ok(Some(m)),
                Err(_) => Ok(None),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::worker::storage::EdgeStreamWriter;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "graphd_csr_{name}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_store(dir: &Path, weighted: bool) -> MachineStore {
        let store = MachineStore {
            dir: dir.to_path_buf(),
            machine: 1,
            num_machines: 4,
            total_vertices: 12,
            weighted,
            recoded: false,
            ids: vec![2, 22, 32],
            degs: vec![2, 3, 1],
        };
        store.save().unwrap();
        let mut w = EdgeStreamWriter::create(dir, weighted, 64).unwrap();
        for (i, nbr) in [(0u32, 5u32), (1, 6), (2, 7), (3, 8), (4, 9), (5, 10)] {
            w.push(nbr, i as f32 + 0.5).unwrap();
        }
        w.finish().unwrap();
        store
    }

    #[test]
    fn header_roundtrip_and_checksum() {
        let (off, edg) = {
            let d = tmp("hdr");
            let s = sample_store(&d, true);
            let pair = expected_headers(&s);
            let _ = std::fs::remove_dir_all(&d);
            pair
        };
        for h in [off, edg] {
            let bytes = h.encode();
            let back = CsrHeader::decode(&bytes, "t").unwrap();
            assert_eq!(back, h);
        }
        assert_ne!(off.checksum(), edg.checksum(), "role is part of the key");
    }

    #[test]
    fn materialize_map_and_decode_matches_stream() {
        for weighted in [false, true] {
            let d = tmp(if weighted { "mat_w" } else { "mat_u" });
            let s = sample_store(&d, weighted);
            assert!(ensure_csr(&s).unwrap(), "first call materializes");
            assert!(!ensure_csr(&s).unwrap(), "second call reuses");
            let m = CsrMap::open(&s).unwrap();
            assert_eq!(m.header().items, 6);
            assert_eq!(m.item_bounds(0).unwrap(), (0, 2));
            assert_eq!(m.item_bounds(2).unwrap(), (5, 6));

            // Same read/skip schedule through both cursors → same edges.
            let mut se = EdgeStreamCursor::open(&s, 8).unwrap();
            let mut cc = m.cursor();
            let (mut a, mut b) = (Vec::new(), Vec::new());
            se.read_adjacency(2, &mut a).unwrap();
            cc.read_adjacency(2, &mut b).unwrap();
            assert_eq!(a, b);
            se.defer_skip(3);
            cc.defer_skip(3);
            se.read_adjacency(1, &mut a).unwrap();
            cc.read_adjacency(1, &mut b).unwrap();
            assert_eq!(a, b);
            let (read, skipped) = cc.io_stats();
            assert_eq!((read, skipped), (3, 3));
            let _ = std::fs::remove_dir_all(&d);
        }
    }

    #[test]
    fn stale_cache_rematerializes() {
        let d = tmp("stale");
        let mut s = sample_store(&d, false);
        assert!(ensure_csr(&s).unwrap());
        // Same dir, different partition meta → stale key → rewrite.
        s.total_vertices = 99;
        assert!(ensure_csr(&s).unwrap(), "stale header must not be reused");
        assert!(!ensure_csr(&s).unwrap());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn corrupt_magic_rejected_typed() {
        let d = tmp("magic");
        let s = sample_store(&d, false);
        ensure_csr(&s).unwrap();
        let p = d.join(CSR_EDGES);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[0] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        match CsrMap::open(&s) {
            Err(Error::CorruptStream(msg)) => assert!(msg.contains("magic"), "{msg}"),
            other => panic!("want CorruptStream, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn flipped_count_fails_checksum() {
        let d = tmp("sum");
        let s = sample_store(&d, false);
        ensure_csr(&s).unwrap();
        let p = d.join(CSR_OFFSETS);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[8] ^= 0x01; // local_vertices LSB
        std::fs::write(&p, &bytes).unwrap();
        match CsrMap::open(&s) {
            Err(Error::CorruptStream(msg)) => assert!(msg.contains("checksum"), "{msg}"),
            other => panic!("want CorruptStream, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn truncated_payload_rejected_typed() {
        let d = tmp("trunc");
        let s = sample_store(&d, false);
        ensure_csr(&s).unwrap();
        let p = d.join(CSR_EDGES);
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 3]).unwrap();
        assert!(matches!(CsrMap::open(&s), Err(Error::CorruptStream(_))));
        // And ensure_csr treats it as stale, repairing in place.
        assert!(ensure_csr(&s).unwrap());
        assert!(CsrMap::open(&s).is_ok());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn truncated_header_rejected_typed() {
        let d = tmp("thdr");
        let s = sample_store(&d, false);
        ensure_csr(&s).unwrap();
        let p = d.join(CSR_OFFSETS);
        std::fs::write(&p, &std::fs::read(&p).unwrap()[..CSR_HEADER_LEN - 10]).unwrap();
        assert!(matches!(CsrMap::open(&s), Err(Error::CorruptStream(_))));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn auto_respects_budget() {
        let d = tmp("auto");
        let s = sample_store(&d, false);
        let mut cfg = JobConfig {
            resident: Resident::Auto,
            resident_budget: 16, // far below two headers
            ..JobConfig::default()
        };
        assert!(open_resident(&s, &cfg).unwrap().is_none());
        assert!(!d.join(CSR_EDGES).exists(), "over budget: nothing materialized");
        cfg.resident_budget = 1 << 30;
        assert!(open_resident(&s, &cfg).unwrap().is_some());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn strict_mmap_surfaces_corruption_auto_falls_back() {
        let d = tmp("strict");
        let s = sample_store(&d, false);
        ensure_csr(&s).unwrap();
        // Corrupt the offsets header checksum bytes directly.
        let p = d.join(CSR_OFFSETS);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[50] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();

        let mut cfg = JobConfig {
            resident: Resident::Auto,
            ..JobConfig::default()
        };
        // Auto repairs (ensure_csr sees a stale file and rewrites) — it
        // only falls back when the repair itself fails.
        assert!(open_resident(&s, &cfg).unwrap().is_some());

        // Now remove se.bin so repair *can't* succeed, and re-corrupt.
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[50] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        std::fs::remove_file(s.se_path()).unwrap();
        cfg.resident = Resident::Mmap;
        assert!(open_resident(&s, &cfg).is_err(), "mmap mode is strict");
        cfg.resident = Resident::Auto;
        assert!(open_resident(&s, &cfg).unwrap().is_none(), "auto falls back");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn no_tmp_partials_left_behind() {
        let d = tmp("tmpclean");
        let s = sample_store(&d, false);
        ensure_csr(&s).unwrap();
        let leftovers: Vec<_> = std::fs::read_dir(&d)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".csr.tmp"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(&d);
    }
}
