//! Cluster profiles and job configuration.
//!
//! The paper evaluates on two clusters: `W^PC` (16 commodity PCs, 8 GB RAM,
//! slow unmanaged Gigabit switch) and `W^high` (15 servers, 48 GB RAM, fast
//! switch).  We simulate both with scaled-down profiles: `n` worker threads,
//! a token-bucket shared switch at a configurable rate, and per-machine
//! RAM/disk *budgets* that the systems' feasibility checks compare against
//! (reproducing the "Insufficient Main Memories / Disk Space" entries).

use crate::error::{Error, Result};
use crate::worker::fault::FaultPlan;
use std::path::PathBuf;
use std::time::Duration;

/// Simulated cluster profile.
#[derive(Clone, Debug)]
pub struct ClusterProfile {
    pub name: String,
    /// Number of simulated machines (worker threads).
    pub machines: usize,
    /// Shared-switch bandwidth in bytes/sec (all pairs contend, §1).
    pub net_bytes_per_sec: f64,
    /// Per-machine simulated disk streaming bandwidth in bytes/sec
    /// (`None` = unthrottled, use real disk speed).
    pub disk_bytes_per_sec: Option<f64>,
    /// Per-machine RAM budget for feasibility accounting (bytes).
    pub ram_budget: u64,
    /// Per-machine disk budget for feasibility accounting (bytes).
    pub disk_budget: u64,
    /// Disk budget of the one big-disk machine single-PC systems may use
    /// (the paper's 2 TB node in W^high; == disk_budget on W^PC).
    pub disk_budget_big: u64,
    /// Fixed per-message-batch network latency (simulates switch/NIC
    /// per-batch overhead), in microseconds.
    pub latency_us: u64,
}

impl ClusterProfile {
    /// `W^PC`: commodity PCs on a slow unmanaged Gigabit switch.  Scaled
    /// ~1/1000 from the paper's testbed (see README.md substitutions);
    /// network deliberately slower than local disk streaming.
    pub fn wpc() -> Self {
        Self {
            name: "wpc".into(),
            machines: 8,
            // Slow unmanaged switch: ~48 MB/s shared by all pairs — each
            // machine's share (~6 MB/s) is far below its disk, so OMS
            // streaming hides completely inside transmission (§3.3.1).
            net_bytes_per_sec: 48.0 * 1024.0 * 1024.0,
            disk_bytes_per_sec: Some(96.0 * 1024.0 * 1024.0),
            ram_budget: 8 * 1024 * 1024,
            disk_budget: 128 * 1024 * 1024,
            disk_budget_big: 128 * 1024 * 1024,
            latency_us: 300,
        }
    }

    /// `W^high`: servers with plenty of RAM on a fast switch.
    pub fn whigh() -> Self {
        Self {
            name: "whigh".into(),
            machines: 8,
            // Fast switch (~80 MB/s per machine when all transmit) with a
            // slower disk share — merge-sort is no longer hidden inside
            // transmission, so IO-Recoded wins big (Table 3).
            net_bytes_per_sec: 640.0 * 1024.0 * 1024.0,
            disk_bytes_per_sec: Some(64.0 * 1024.0 * 1024.0),
            ram_budget: 40 * 1024 * 1024,
            disk_budget: 150 * 1024 * 1024,
            disk_budget_big: 2 * 1024 * 1024 * 1024,
            latency_us: 80,
        }
    }

    /// A fast profile for unit/integration tests: tiny latency, high rate.
    pub fn test(machines: usize) -> Self {
        Self {
            name: "test".into(),
            machines,
            net_bytes_per_sec: 4.0 * 1024.0 * 1024.0 * 1024.0,
            disk_bytes_per_sec: None,
            ram_budget: u64::MAX,
            disk_budget: u64::MAX,
            disk_budget_big: u64::MAX,
            latency_us: 0,
        }
    }

    pub fn by_name(name: &str, machines: Option<usize>) -> Result<Self> {
        let mut p = match name {
            "wpc" => Self::wpc(),
            "whigh" => Self::whigh(),
            "test" => Self::test(machines.unwrap_or(4)),
            other => return Err(Error::Config(format!("unknown profile '{other}'"))),
        };
        if let Some(m) = machines {
            p.machines = m;
        }
        Ok(p)
    }
}

/// Execution mode of GraphD (§5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// IO-Basic: OMS merge-sort combining, disk-resident IMS.
    Basic,
    /// IO-Recoded: dense IDs; in-memory A_r/A_s digesting (needs combiner).
    Recoded,
    /// Resolved by the session layer before a job starts: picks IO-Recoded
    /// (+XLA kernels when artifacts are present) when the program has a
    /// combiner and the graph has been ID-recoded, else IO-Basic.  The raw
    /// engine treats an unresolved `Auto` as `Basic`.
    Auto,
}

impl std::fmt::Display for Mode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Mode::Basic => write!(f, "IO-Basic"),
            Mode::Recoded => write!(f, "IO-Recoded"),
            Mode::Auto => write!(f, "Auto"),
        }
    }
}

/// Adjacency residency mode (`-c resident=`, `JobBuilder::resident`): how
/// U_c reads the edge stream `S^E`.
///
/// `Stream` is the paper's §3 design (buffered sequential re-read each
/// superstep, O(|V|/n) heap).  `Mmap` is the semi-external-memory mode:
/// the store is materialized as flat CSR files (`csr_offsets`/`csr_edges`,
/// see `docs/FORMATS.md`) and mapped read-only, so adjacency is an O(1)
/// zero-copy slice and the OS page cache does the streaming — still
/// O(|V|/n) *heap*, because a read-only file mapping is page cache, not
/// heap.  `Auto` picks `Mmap` when the CSR pair fits
/// [`JobConfig::resident_budget`], else falls back to `Stream`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Resident {
    /// §3 streaming: re-read `se.bin` through the buffered
    /// [`EdgeStreamCursor`](crate::worker::storage::EdgeStreamCursor)
    /// every superstep (the default).
    Stream,
    /// Semi-external: mmap the materialized CSR files.  Strict — missing
    /// files are materialized, corrupt ones are a typed error.
    Mmap,
    /// `Mmap` when the CSR pair fits the budget (and is valid or
    /// materializable), else `Stream`.
    Auto,
}

impl std::fmt::Display for Resident {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Resident::Stream => write!(f, "stream"),
            Resident::Mmap => write!(f, "mmap"),
            Resident::Auto => write!(f, "auto"),
        }
    }
}

/// Auto-resume policy for `JobBuilder::run` (§3.4): how many times a
/// *retryable* failure (I/O error, transient network fault, first panic)
/// may be retried from the last durable checkpoint, and the base of the
/// exponential backoff between attempts (`backoff * 2^attempt`).
///
/// The default is **zero retries** — failures surface immediately as
/// typed `Error::JobFailed`, exactly as before the recovery layer existed;
/// auto-resume is opt-in (`-c retry=N[:backoff_ms]`, `JobBuilder::retry`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Maximum retry attempts after the initial run (0 = never retry).
    pub max_retries: u32,
    /// Base backoff before the first retry; doubles per attempt.
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_retries: 0,
            backoff: Duration::from_millis(50),
        }
    }
}

impl RetryPolicy {
    /// `max_retries` retries with the default backoff.
    pub fn retries(max_retries: u32) -> Self {
        Self {
            max_retries,
            ..Self::default()
        }
    }

    /// Parse the CLI form `N` or `N:BACKOFF_MS` (e.g. `-c retry=2:10`).
    pub fn parse(s: &str) -> Result<Self> {
        let bad = || Error::Config(format!("bad value '{s}' for 'retry' (want N or N:BACKOFF_MS)"));
        let (n, ms) = match s.split_once(':') {
            Some((n, ms)) => (n, Some(ms)),
            None => (s, None),
        };
        let max_retries = n.parse().map_err(|_| bad())?;
        let backoff = match ms {
            Some(ms) => Duration::from_millis(ms.parse().map_err(|_| bad())?),
            None => Self::default().backoff,
        };
        Ok(Self { max_retries, backoff })
    }
}

/// Per-job tunables (paper defaults: b = 64 KB, ℬ = 8 MB, k = 1000).
#[derive(Clone, Debug)]
pub struct JobConfig {
    /// Working directory root; each machine gets `<root>/m<i>/`.
    pub workdir: PathBuf,
    /// Stream in-memory buffer size b (bytes).
    pub stream_buf: usize,
    /// Splittable-stream file cap ℬ (bytes).
    pub oms_file_cap: usize,
    /// Merge-sort fan-in k.
    pub merge_k: usize,
    /// Maximum supersteps (0 = unlimited).
    pub max_supersteps: u64,
    /// Execution mode.
    pub mode: Mode,
    /// Use the XLA block-update kernels when the algorithm provides them
    /// (recoded mode only); `false` falls back to scalar Rust.
    pub use_xla: bool,
    /// Keep OMS files until the next checkpoint (fault tolerance, §3.4).
    /// Besides retaining the raw OMS/`lsp_*` logs, this makes U_r keep a
    /// manifest of its merged `si_*` incoming files so an auto-resumed
    /// attempt can *replay* messages from the logs instead of recomputing
    /// the sending supersteps (fast recovery).  CLI:
    /// `-c keep_oms_for_recovery=true`.
    pub keep_oms_for_recovery: bool,
    /// Checkpoint every k supersteps (0 = no checkpointing).
    pub checkpoint_every: u64,
    /// Auto-resume policy (see [`RetryPolicy`]; default: no retries).
    pub retry: RetryPolicy,
    /// Deterministic fault injection for recovery testing (`None` = no
    /// faults).  CLI: `-c fault=us_io@m1s3` — see
    /// [`crate::worker::fault::FaultPlan`].
    pub fault: Option<FaultPlan>,
    /// If set, sending stalls computation when the in-memory buffer fills
    /// instead of spilling to OMSs (the "no-OMS" design the paper argues
    /// against; used by `ablation_oms`).
    pub disable_oms: bool,
    /// Local-delivery fast path (default on), governing **every** mode:
    /// batches whose destination is the sending machine bypass the
    /// simulated switch entirely, and messages to local vertices skip the
    /// OMS files — folded straight into the machine's own `A_r` shard in
    /// recoded digesting mode, or sorted-spilled through the local spill
    /// lane and merged into `S^I` in the sorted-stream modes (IO-Basic,
    /// recoded without a combiner).  At n=1 every message is local, so
    /// `net_wire_bytes == 0` in both mode families.  `false` restores the
    /// pre-fast-path routing (every batch through switch + OMS), which the
    /// `spine_throughput` bench uses as its baseline.
    pub local_fastpath: bool,
    /// Directory holding the AOT `*.hlo.txt` artifacts for the XLA block
    /// path (`None` = [`crate::runtime::KernelSet::default_dir`]).
    pub artifacts_dir: Option<PathBuf>,
    /// Flight-recorder tracing (see [`crate::trace`]): off by default.
    /// When enabled, every unit records spans into per-thread ring
    /// buffers; a finished job exports Chrome-trace JSON
    /// (`trace.path`, default `<workdir>/trace.json`) and a failed one
    /// dumps `flightrec_<machine>.log` files beside it.  CLI:
    /// `-c trace=true`, `-c trace_path=…`, `-c trace_capacity=…`.
    pub trace: crate::trace::TraceConfig,
    /// Transport backend (see [`crate::net::TransportKind`]): `sim` (the
    /// default in-process simulator) or `tcp` (this process runs *one*
    /// machine, `transport_rank`, and exchanges framed batches with its
    /// peer processes over real sockets).  CLI: `-c transport=sim|tcp`.
    pub transport: crate::net::TransportKind,
    /// Coordinator (rank 0) control-plane address for `transport=tcp`
    /// (`host:port`); empty under `sim`.  CLI: `-c transport_addr=…`.
    pub transport_addr: String,
    /// Which machine this process runs under `transport=tcp`.  CLI:
    /// `-c transport_rank=R`.
    pub transport_rank: usize,
    /// Adjacency residency (see [`Resident`]): `stream` (default), `mmap`,
    /// or `auto`.  CLI: `-c resident=stream|mmap|auto`.
    pub resident: Resident,
    /// Byte budget `resident=auto` compares the CSR pair against before
    /// choosing the mapped path (default 1 GiB).  CLI:
    /// `-c resident_budget=BYTES`.
    pub resident_budget: u64,
}

impl Default for JobConfig {
    fn default() -> Self {
        Self {
            workdir: std::env::temp_dir().join("graphd"),
            stream_buf: 64 * 1024,
            oms_file_cap: 8 * 1024 * 1024,
            merge_k: 1000,
            max_supersteps: 0,
            mode: Mode::Basic,
            use_xla: false,
            keep_oms_for_recovery: false,
            checkpoint_every: 0,
            retry: RetryPolicy::default(),
            fault: None,
            disable_oms: false,
            local_fastpath: true,
            artifacts_dir: None,
            trace: crate::trace::TraceConfig::default(),
            transport: crate::net::TransportKind::Sim,
            transport_addr: String::new(),
            transport_rank: 0,
            resident: Resident::Stream,
            resident_budget: 1 << 30,
        }
    }
}

impl JobConfig {
    /// Parse `key=value` overrides (the CLI's `-c key=val` flags).
    pub fn apply(&mut self, key: &str, val: &str) -> Result<()> {
        let bad = |k: &str, v: &str| Error::Config(format!("bad value '{v}' for '{k}'"));
        match key {
            "workdir" => self.workdir = PathBuf::from(val),
            "stream_buf" => self.stream_buf = val.parse().map_err(|_| bad(key, val))?,
            "oms_file_cap" => self.oms_file_cap = val.parse().map_err(|_| bad(key, val))?,
            "merge_k" => self.merge_k = val.parse().map_err(|_| bad(key, val))?,
            "max_supersteps" => {
                self.max_supersteps = val.parse().map_err(|_| bad(key, val))?
            }
            "mode" => {
                self.mode = match val {
                    "basic" => Mode::Basic,
                    "recoded" => Mode::Recoded,
                    "auto" => Mode::Auto,
                    _ => return Err(bad(key, val)),
                }
            }
            "use_xla" => self.use_xla = val.parse().map_err(|_| bad(key, val))?,
            "artifacts_dir" => self.artifacts_dir = Some(PathBuf::from(val)),
            "disable_oms" => self.disable_oms = val.parse().map_err(|_| bad(key, val))?,
            "local_fastpath" => {
                self.local_fastpath = val.parse().map_err(|_| bad(key, val))?
            }
            "checkpoint_every" => {
                self.checkpoint_every = val.parse().map_err(|_| bad(key, val))?
            }
            "keep_oms_for_recovery" => {
                self.keep_oms_for_recovery = val.parse().map_err(|_| bad(key, val))?
            }
            "retry" => self.retry = RetryPolicy::parse(val)?,
            "fault" => self.fault = Some(FaultPlan::parse(val)?),
            "trace" => self.trace.enabled = val.parse().map_err(|_| bad(key, val))?,
            "trace_path" => {
                // A path implies intent to trace.
                self.trace.enabled = true;
                self.trace.path = Some(PathBuf::from(val));
            }
            "trace_capacity" => {
                self.trace.capacity = val.parse().map_err(|_| bad(key, val))?
            }
            "transport" => self.transport = crate::net::TransportKind::parse(val)?,
            "transport_addr" => self.transport_addr = val.to_string(),
            "transport_rank" => {
                self.transport_rank = val.parse().map_err(|_| bad(key, val))?
            }
            "resident" => {
                self.resident = match val {
                    "stream" => Resident::Stream,
                    "mmap" => Resident::Mmap,
                    "auto" => Resident::Auto,
                    _ => return Err(bad(key, val)),
                }
            }
            "resident_budget" => {
                self.resident_budget = val.parse().map_err(|_| bad(key, val))?
            }
            _ => return Err(Error::Config(format!("unknown config key '{key}'"))),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_have_expected_ordering() {
        let pc = ClusterProfile::wpc();
        let hi = ClusterProfile::whigh();
        assert!(hi.net_bytes_per_sec > pc.net_bytes_per_sec);
        assert!(hi.ram_budget > pc.ram_budget);
    }

    #[test]
    fn by_name_and_machine_override() {
        let p = ClusterProfile::by_name("wpc", Some(4)).unwrap();
        assert_eq!(p.machines, 4);
        assert!(ClusterProfile::by_name("nope", None).is_err());
    }

    #[test]
    fn job_config_apply() {
        let mut c = JobConfig::default();
        c.apply("mode", "recoded").unwrap();
        assert_eq!(c.mode, Mode::Recoded);
        c.apply("oms_file_cap", "65536").unwrap();
        assert_eq!(c.oms_file_cap, 65536);
        assert!(c.local_fastpath, "fast path defaults on");
        c.apply("local_fastpath", "false").unwrap();
        assert!(!c.local_fastpath);
        assert!(c.apply("mode", "weird").is_err());
        assert!(c.apply("nope", "1").is_err());
    }

    #[test]
    fn job_config_recovery_keys() {
        let mut c = JobConfig::default();
        assert_eq!(c.retry, RetryPolicy::default());
        assert_eq!(c.retry.max_retries, 0, "auto-resume is opt-in");
        assert!(c.fault.is_none());

        c.apply("retry", "3").unwrap();
        assert_eq!(c.retry.max_retries, 3);
        assert_eq!(c.retry.backoff, Duration::from_millis(50));
        c.apply("retry", "2:10").unwrap();
        assert_eq!(c.retry, RetryPolicy { max_retries: 2, backoff: Duration::from_millis(10) });
        assert!(c.apply("retry", "x").is_err());
        assert!(c.apply("retry", "2:x").is_err());

        c.apply("keep_oms_for_recovery", "true").unwrap();
        assert!(c.keep_oms_for_recovery);

        c.apply("fault", "us_io@m1s3;net_send@m0s2").unwrap();
        assert_eq!(c.fault.as_ref().unwrap().specs().len(), 2);
        assert!(c.apply("fault", "bogus").is_err());
    }

    #[test]
    fn job_config_trace_keys() {
        let mut c = JobConfig::default();
        assert!(!c.trace.enabled, "tracing defaults off");
        c.apply("trace", "true").unwrap();
        assert!(c.trace.enabled);
        c.apply("trace_capacity", "128").unwrap();
        assert_eq!(c.trace.capacity, 128);
        let mut c2 = JobConfig::default();
        c2.apply("trace_path", "/tmp/t.json").unwrap();
        assert!(c2.trace.enabled, "trace_path implies enabled");
        assert_eq!(c2.trace.path.as_deref(), Some(std::path::Path::new("/tmp/t.json")));
        assert!(c.apply("trace", "weird").is_err());
    }

    #[test]
    fn job_config_transport_keys() {
        use crate::net::TransportKind;
        let mut c = JobConfig::default();
        assert_eq!(c.transport, TransportKind::Sim, "sim is the default");
        assert!(c.transport_addr.is_empty());
        assert_eq!(c.transport_rank, 0);
        c.apply("transport", "tcp").unwrap();
        assert_eq!(c.transport, TransportKind::Tcp);
        c.apply("transport_addr", "127.0.0.1:7700").unwrap();
        assert_eq!(c.transport_addr, "127.0.0.1:7700");
        c.apply("transport_rank", "2").unwrap();
        assert_eq!(c.transport_rank, 2);
        assert!(c.apply("transport", "udp").is_err());
        assert!(c.apply("transport_rank", "x").is_err());
    }

    #[test]
    fn job_config_resident_keys() {
        let mut c = JobConfig::default();
        assert_eq!(c.resident, Resident::Stream, "streaming is the default");
        assert_eq!(c.resident_budget, 1 << 30);
        c.apply("resident", "mmap").unwrap();
        assert_eq!(c.resident, Resident::Mmap);
        c.apply("resident", "auto").unwrap();
        assert_eq!(c.resident, Resident::Auto);
        c.apply("resident", "stream").unwrap();
        assert_eq!(c.resident, Resident::Stream);
        c.apply("resident_budget", "65536").unwrap();
        assert_eq!(c.resident_budget, 65536);
        assert!(c.apply("resident", "disk").is_err());
        assert!(c.apply("resident_budget", "big").is_err());
        assert_eq!(Resident::Mmap.to_string(), "mmap");
    }

    #[test]
    fn default_matches_paper_constants() {
        let c = JobConfig::default();
        assert_eq!(c.stream_buf, 64 * 1024); // b = 64 KB
        assert_eq!(c.oms_file_cap, 8 * 1024 * 1024); // ℬ = 8 MB
        assert_eq!(c.merge_k, 1000); // k = 1000
    }
}
