//! `graphd-analyze` — repo-native invariant lints (see `graphd::analyze`).
//!
//! ```text
//! analyze [ROOT...]        lint the tree(s); default root: rust/src (or src)
//! analyze --rules          print the rule table and exit
//! ```
//!
//! Exit codes: `0` clean, `1` violations found, `2` usage or I/O error.
//! Suppressions are explicit and reasoned — `// analyze:allow(rule-id): why`
//! — so every accepted violation documents itself (`bad-pragma` reports
//! reasonless or misspelled ones).

use graphd::analyze::{analyze_tree, Rule};
use std::path::PathBuf;
use std::process::ExitCode;

fn usage() {
    eprintln!("usage: analyze [ROOT...]   (default root: rust/src, falling back to src)");
    eprintln!("       analyze --rules     print the rule table");
}

fn print_rules() {
    // The pragma needle is split so the analyzer's own self-scan never
    // parses this help string as a (malformed) suppression.
    println!(
        "graphd-analyze rules (suppress with `// analyze:{}(rule-id): reason`):",
        "allow"
    );
    for r in Rule::all() {
        println!("  {:<21} {}", r.id(), r.describe());
    }
}

fn main() -> ExitCode {
    let mut roots: Vec<PathBuf> = Vec::new();
    for a in std::env::args().skip(1) {
        match a.as_str() {
            "--help" | "-h" => {
                usage();
                return ExitCode::SUCCESS;
            }
            "--rules" => {
                print_rules();
                return ExitCode::SUCCESS;
            }
            _ if a.starts_with('-') => {
                eprintln!("analyze: unknown flag `{a}`");
                usage();
                return ExitCode::from(2);
            }
            _ => roots.push(PathBuf::from(a)),
        }
    }
    if roots.is_empty() {
        // `make analyze` runs from the repo root; `cargo run` from rust/.
        for cand in ["rust/src", "src"] {
            if PathBuf::from(cand).is_dir() {
                roots.push(PathBuf::from(cand));
                break;
            }
        }
    }
    if roots.is_empty() {
        eprintln!("analyze: no root given and neither rust/src nor src exists");
        return ExitCode::from(2);
    }

    let (mut files, mut violations, mut suppressed) = (0usize, 0usize, 0usize);
    for root in &roots {
        match analyze_tree(root) {
            Ok(rep) => {
                for d in &rep.diagnostics {
                    println!("{d}");
                }
                files += rep.files;
                violations += rep.diagnostics.len();
                suppressed += rep.suppressed;
            }
            Err(e) => {
                eprintln!("analyze: {}: {e}", root.display());
                return ExitCode::from(2);
            }
        }
    }
    eprintln!(
        "graphd-analyze: {files} file(s) scanned, {violations} violation(s), \
         {suppressed} reasoned suppression(s)"
    );
    if violations > 0 {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
