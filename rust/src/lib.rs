//! # GraphD — out-of-core distributed Pregel in a small cluster
//!
//! Reproduction of *"Efficient Processing of Very Large Graphs in a Small
//! Cluster"* (Yan, Huang, Cheng, Wu, 2016) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's system: the distributed
//!   semi-streaming (DSS) engine.  Per machine, the vertex-state array `A`
//!   lives in memory (`O(|V|/n)`), while the edge stream `S^E`, the incoming
//!   message stream `S^I` and one outgoing message stream (OMS) per peer
//!   are *streamed on local disk*.  Three units per machine — compute
//!   [`worker`] `U_c`, send `U_s`, receive `U_r` — run in parallel and
//!   overlap disk streaming with (simulated) network transmission (§4).
//! * **Layer 2/1 (python/compile)** — block vertex updates (PageRank,
//!   min-relax) written as Pallas kernels inside jax functions and
//!   AOT-lowered to HLO text at build time.
//! * **Runtime bridge** ([`runtime`]) — loads `artifacts/*.hlo.txt` via the
//!   `xla` crate (PJRT CPU, behind the `xla` cargo feature) and executes
//!   them on the recoded-mode hot path; python never runs at job time.
//!
//! The supported entry point is the fluent [`session`] API:
//!
//! ```ignore
//! let session = GraphD::builder().machines(4).workdir(wd).build()?;
//! let mut graph = session.load(GraphSource::InMemory(&g))?;
//! let basic = graph.run(Arc::new(PageRank::new(10)))?;
//! let recoded = graph.recode()?.job(Arc::new(PageRank::new(10))).mode(Mode::Auto).run()?;
//! ```
//!
//! See the top-level `README.md` for the quickstart and the experiment
//! index (tables are reproduced by `rust/benches/` and `graphd table`),
//! `DESIGN.md` for the paper-to-code architecture guide — which paper
//! section maps to which module, and where the message spine's pools and
//! fast paths sit — and `docs/FORMATS.md` for the normative specification
//! of every on-disk artifact (recoded stores, CSR resident files,
//! checkpoints + the DONE protocol, replay manifests, wire frames).

// CI runs `cargo clippy -- -D warnings`.  The engine's idiom is explicit
// position loops over parallel arrays (A, degs, lanes, …) where the index
// *is* the datum (§5 recoded ids are `pos·n + i`), so the index-style
// lints are noise here; correctness lints stay fatal.
#![allow(unknown_lints)] // lint set varies across clippy versions
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::manual_div_ceil)]
#![allow(clippy::type_complexity)]

// The crate's public API surface (the modules users program against plus
// the engine layers DESIGN.md documents) warns on undocumented public
// items; CI runs `cargo doc --no-deps` with warnings denied.
pub mod algos;
#[warn(missing_docs)]
pub mod analyze;
#[warn(missing_docs)]
pub mod api;
pub mod baselines;
pub mod bench;
pub mod config;
pub mod dfs;
pub mod engine;
pub mod error;
pub mod ft;
pub mod graph;
pub mod metrics;
#[warn(missing_docs)]
pub mod msg;
#[warn(missing_docs)]
pub mod net;
pub mod recode;
pub mod runtime;
#[warn(missing_docs)]
pub mod serve;
#[warn(missing_docs)]
pub mod session;
pub mod stream;
#[warn(missing_docs)]
pub mod trace;
pub mod util;
#[warn(missing_docs)]
pub mod worker;

pub use config::{Mode, Resident};
pub use error::{Error, Result};
pub use serve::{Answer, Query, QueryResult, QueryServer, ServeConfig, ServeStats};
pub use session::{GraphD, GraphSource, JobBuilder, JobPlan, LoadedGraph, Session, Xla};
pub use trace::TraceConfig;
