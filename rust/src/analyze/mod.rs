//! `graphd-analyze` — repo-native invariant lints for the GraphD tree.
//!
//! GraphD's performance story (fully overlapping computation with
//! communication, §3–§4) rests on hand-rolled concurrency: poisonable
//! [`crate::worker::sync::Rendezvous`]/[`crate::worker::sync::MachineSync`]
//! barriers, the [`crate::worker::sync::JobAbort`] latch, abort-aware
//! [`crate::net`] waits, and checkout/recycle [`crate::msg::BufPool`]/
//! [`crate::msg::DigestPool`] buffers.  PR 5 exists because one missed
//! barrier registration deadlocked the whole cluster on failure.  This
//! module turns those conventions into machine-checked rules: a
//! zero-dependency scanner (a hand-rolled lexer, per the repo's
//! vendor-everything rule) walks `rust/src/**/*.rs` and emits typed
//! `file:line` diagnostics for the six rules documented in [`Rule`].
//!
//! Run it via `make analyze` (part of `make ci`) or directly:
//!
//! ```text
//! cargo run --bin analyze -- rust/src          # lint the tree (exit 1 on findings)
//! cargo run --bin analyze -- --rules           # print the rule table
//! ```
//!
//! # Suppressions
//!
//! Every accepted violation must carry an explicit, reasoned pragma in a
//! plain `//` comment — the reason is mandatory, so each suppression
//! documents *why* the invariant holds at that site:
//!
//! ```text
//! // analyze:allow(sleep-slicing): bounded ≤10ms settle in a simulator with no abort latch
//! std::thread::sleep(poll);
//! ```
//!
//! A trailing pragma on the offending line suppresses that line; a
//! standalone pragma line suppresses the statement that follows it.  A
//! pragma with an unknown rule-id or without a `: reason` suppresses
//! nothing and is itself reported (as `bad-pragma`).

mod lexer;
mod rules;

use std::fmt;
use std::path::Path;

/// The invariant rules the analyzer enforces (see `DESIGN.md`,
/// "Invariants & static analysis", for the full rationale of each).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// `.unwrap()`/`.expect()` on a poisonable wait's `Result` inside
    /// `worker/`, `engine/`, `net/`, `recode/`, `serve/` — `Poisoned`/
    /// abort must propagate as [`crate::error::Error::JobFailed`].
    PoisonSafety,
    /// `Rendezvous::new`/`MachineSync::new` without a `JobAbort`
    /// registration in the enclosing fn (the PR 5 deadlock class).
    BarrierRegistration,
    /// A `BufPool`/`DigestPool` checkout with no lexical recycle or
    /// approved handoff (`LocalShard`/`SpillLane`/wire payload).
    PoolLeak,
    /// Raw `thread::sleep` outside the sliced-wait helpers — a sleeping
    /// unit cannot observe `JobAbort`.
    SleepSlicing,
    /// `todo!`/`unimplemented!`/stray `panic!` outside `#[cfg(test)]`.
    PanicHygiene,
    /// Raw `eprintln!`/`println!` in `worker/`, `engine/`, `net/`,
    /// `serve/` outside tests — diagnostics must route through
    /// [`crate::trace::diag`] so tests can assert on them.
    PrintHygiene,
    /// A malformed suppression: unknown rule-id or missing `: reason`.
    BadPragma,
}

impl Rule {
    /// The stable rule-id used in diagnostics and `analyze:allow(..)`.
    pub fn id(&self) -> &'static str {
        match self {
            Rule::PoisonSafety => "poison-safety",
            Rule::BarrierRegistration => "barrier-registration",
            Rule::PoolLeak => "pool-leak",
            Rule::SleepSlicing => "sleep-slicing",
            Rule::PanicHygiene => "panic-hygiene",
            Rule::PrintHygiene => "print-hygiene",
            Rule::BadPragma => "bad-pragma",
        }
    }

    /// Parse a *suppressible* rule-id (`bad-pragma` is not suppressible).
    pub fn from_id(s: &str) -> Option<Rule> {
        match s {
            "poison-safety" => Some(Rule::PoisonSafety),
            "barrier-registration" => Some(Rule::BarrierRegistration),
            "pool-leak" => Some(Rule::PoolLeak),
            "sleep-slicing" => Some(Rule::SleepSlicing),
            "panic-hygiene" => Some(Rule::PanicHygiene),
            "print-hygiene" => Some(Rule::PrintHygiene),
            _ => None,
        }
    }

    /// Every suppressible rule, for `--rules` output and docs.
    pub fn all() -> &'static [Rule] {
        &[
            Rule::PoisonSafety,
            Rule::BarrierRegistration,
            Rule::PoolLeak,
            Rule::SleepSlicing,
            Rule::PanicHygiene,
            Rule::PrintHygiene,
        ]
    }

    /// One-line description, for `--rules` output.
    pub fn describe(&self) -> &'static str {
        match self {
            Rule::PoisonSafety => {
                "no .unwrap()/.expect() on poisonable waits (Rendezvous::exchange, \
                 MachineSync waits, NetSender::send/NetReceiver::recv, Mutex/Condvar) \
                 in worker/, engine/, net/, recode/, serve/"
            }
            Rule::BarrierRegistration => {
                "every Rendezvous::new/MachineSync::new pairs with a JobAbort \
                 registration in the enclosing fn"
            }
            Rule::PoolLeak => {
                "every BufPool/DigestPool checkout pairs with .put()/finish_recycle/\
                 create_pooled or a LocalShard/SpillLane/wire handoff"
            }
            Rule::SleepSlicing => {
                "no raw thread::sleep outside the sliced-wait helpers (sleeps must \
                 observe JobAbort in <=ABORT_POLL slices)"
            }
            Rule::PanicHygiene => {
                "no todo!/unimplemented!/stray panic! outside #[cfg(test)]"
            }
            Rule::PrintHygiene => {
                "no raw eprintln!/println! in worker/, engine/, net/, serve/ \
                 outside tests (route diagnostics through trace::diag)"
            }
            Rule::BadPragma => "malformed analyze:allow pragma",
        }
    }
}

/// One finding, addressed `file:line` with its rule and message.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Path relative to the scanned root, `/`-separated.
    pub file: String,
    /// 1-based source line.
    pub line: u32,
    /// The violated rule.
    pub rule: Rule,
    /// Human-readable explanation with the repair direction.
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule.id(), self.msg)
    }
}

/// Result of analyzing one file.
#[derive(Debug, Default)]
pub struct FileReport {
    /// Unsuppressed findings (including any `bad-pragma`s).
    pub diagnostics: Vec<Diagnostic>,
    /// Findings silenced by a valid, reasoned pragma.
    pub suppressed: usize,
}

/// Result of analyzing a directory tree.
#[derive(Debug, Default)]
pub struct TreeReport {
    /// `.rs` files scanned.
    pub files: usize,
    /// Unsuppressed findings across all files, in path order.
    pub diagnostics: Vec<Diagnostic>,
    /// Findings silenced by valid pragmas across all files.
    pub suppressed: usize,
}

/// A parsed `// analyze:allow(rule-id): reason` pragma.
struct Pragma {
    line: u32,
    rule: Option<Rule>,
    raw_id: String,
    reason_ok: bool,
    /// Inclusive 1-based line range this pragma suppresses.
    window: (u32, u32),
}

/// Extract pragmas from raw source lines.  Pragmas live in plain `//`
/// comments only — doc comments (`///`, `//!`) are ignored so rustdoc
/// examples of the syntax never act as live suppressions.
fn scan_pragmas(src: &str) -> Vec<Pragma> {
    const NEEDLE: &str = "analyze:allow(";
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (idx, l) in lines.iter().enumerate() {
        let Some(cpos) = l.find("//") else { continue };
        let comment = &l[cpos..];
        if comment.starts_with("///") || comment.starts_with("//!") {
            continue;
        }
        let Some(p) = comment.find(NEEDLE) else { continue };
        let rest = &comment[p + NEEDLE.len()..];
        let line = (idx + 1) as u32;
        let (raw_id, reason_ok) = match rest.find(')') {
            None => (rest.trim().to_string(), false),
            Some(close) => {
                let after = rest[close + 1..].trim_start();
                let ok = after.starts_with(':')
                    && !after[1..].trim().is_empty();
                (rest[..close].trim().to_string(), ok)
            }
        };
        let has_code_before = !l[..cpos].trim().is_empty();
        let window = if has_code_before {
            (line, line)
        } else {
            statement_window(&lines, idx)
        };
        out.push(Pragma {
            line,
            rule: Rule::from_id(&raw_id),
            raw_id,
            reason_ok,
            window,
        });
    }
    out
}

/// The statement following a standalone pragma line: from the next
/// non-blank, non-comment line through the first line whose code part
/// contains `;`, `{` or `}` (capped at 10 lines — statements in this tree
/// are short, and an unbounded window would hide later violations).
fn statement_window(lines: &[&str], pragma_idx: usize) -> (u32, u32) {
    let mut s = pragma_idx + 1;
    while s < lines.len() {
        let t = lines[s].trim();
        if !t.is_empty() && !t.starts_with("//") {
            break;
        }
        s += 1;
    }
    let mut e = s;
    while e < lines.len() && e - s < 9 {
        let code = lines[e].split("//").next().unwrap_or("");
        if code.contains(';') || code.contains('{') || code.contains('}') {
            break;
        }
        e += 1;
    }
    ((s + 1) as u32, (e + 1).min(lines.len()) as u32)
}

/// Analyze one file's source.  `rel_path` is the path relative to the
/// scanned root with `/` separators — rule scoping (e.g. `poison-safety`'s
/// `worker/`…`serve/` restriction) matches against it.
pub fn analyze_source(rel_path: &str, src: &str) -> FileReport {
    let toks = lexer::lex(src);
    let ctx = rules::Ctx::new(&toks);
    let found = rules::run_all(rel_path, &ctx);
    let pragmas = scan_pragmas(src);

    let mut report = FileReport::default();
    for d in found {
        let suppressed = pragmas.iter().any(|p| {
            p.reason_ok
                && p.rule == Some(d.rule)
                && p.window.0 <= d.line
                && d.line <= p.window.1
        });
        if suppressed {
            report.suppressed += 1;
        } else {
            report.diagnostics.push(d);
        }
    }
    for p in &pragmas {
        let msg = if p.rule.is_none() {
            format!(
                "unknown rule-id `{}` in analyze:allow — known: {}",
                p.raw_id,
                Rule::all()
                    .iter()
                    .map(|r| r.id())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        } else if !p.reason_ok {
            format!(
                "analyze:allow({}) without `: reason` — every suppression must say why",
                p.raw_id
            )
        } else {
            continue;
        };
        report.diagnostics.push(Diagnostic {
            file: rel_path.to_string(),
            line: p.line,
            rule: Rule::BadPragma,
            msg,
        });
    }
    report.diagnostics.sort_by_key(|d| (d.line, d.rule));
    report
}

/// Analyze every `.rs` file under `root` (recursively, path-sorted).
pub fn analyze_tree(root: &Path) -> std::io::Result<TreeReport> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut report = TreeReport::default();
    for f in files {
        let src = std::fs::read_to_string(&f)?;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(&f)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let fr = analyze_source(&rel, &src);
        report.files += 1;
        report.suppressed += fr.suppressed;
        report.diagnostics.extend(fr.diagnostics);
    }
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trailing_pragma_suppresses_its_line() {
        let src = "fn f(m: &Mutex<u32>) -> u32 {\n    \
                   *m.lock().unwrap() // analyze:allow(poison-safety): test double, single thread\n\
                   }\n";
        let r = analyze_source("worker/x.rs", src);
        assert!(r.diagnostics.is_empty(), "{:?}", r.diagnostics);
        assert_eq!(r.suppressed, 1);
    }

    #[test]
    fn standalone_pragma_covers_following_statement() {
        let src = "fn f() {\n    // analyze:allow(sleep-slicing): bounded settle, no latch\n    \
                   std::thread::sleep(\n        poll,\n    );\n}\n";
        let r = analyze_source("a.rs", src);
        assert!(r.diagnostics.is_empty(), "{:?}", r.diagnostics);
        assert_eq!(r.suppressed, 1);
    }

    #[test]
    fn reasonless_pragma_reports_and_does_not_suppress() {
        // The needle is split so scanning *this* file never sees a
        // malformed pragma in the test string.
        let src = format!(
            "fn f() {{\n    // analyze:{}(sleep-slicing)\n    std::thread::sleep(poll);\n}}\n",
            "allow"
        );
        let r = analyze_source("a.rs", &src);
        let rules: Vec<Rule> = r.diagnostics.iter().map(|d| d.rule).collect();
        assert!(rules.contains(&Rule::BadPragma));
        assert!(rules.contains(&Rule::SleepSlicing));
        assert_eq!(r.suppressed, 0);
    }

    #[test]
    fn unknown_rule_id_is_reported() {
        // Needle split: same self-scan consideration as above.
        let src = format!("// analyze:{}(no-such-rule): whatever\nfn f() {{}}\n", "allow");
        let r = analyze_source("a.rs", &src);
        assert_eq!(r.diagnostics.len(), 1);
        assert_eq!(r.diagnostics[0].rule, Rule::BadPragma);
    }

    #[test]
    fn doc_comment_examples_are_inert() {
        let src = "/// // analyze:allow(sleep-slicing): doc example\nfn f() {}\n";
        let r = analyze_source("a.rs", src);
        assert!(r.diagnostics.is_empty());
    }

    #[test]
    fn pragma_for_wrong_rule_does_not_suppress() {
        let src = "fn f() {\n    // analyze:allow(panic-hygiene): wrong rule\n    \
                   std::thread::sleep(poll);\n}\n";
        let r = analyze_source("a.rs", src);
        assert_eq!(r.diagnostics.len(), 1);
        assert_eq!(r.diagnostics[0].rule, Rule::SleepSlicing);
    }
}
