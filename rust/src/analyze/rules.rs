//! The six invariant rules, evaluated over the token stream.
//!
//! Each rule encodes a convention PRs 3–5 established by hand (see
//! `DESIGN.md`, "Invariants & static analysis"):
//!
//! * `poison-safety` — the `Result`s of the poisonable waits
//!   ([`crate::worker::sync::Rendezvous::exchange`], the
//!   [`crate::worker::sync::MachineSync`] waits, `NetSender::send` /
//!   `NetReceiver::recv`, and std `Mutex`/`Condvar` waits) must propagate,
//!   not be `.unwrap()`/`.expect()`ed, inside the concurrency-bearing
//!   modules (`worker/`, `engine/`, `net/`, `recode/`, `serve/`).
//! * `barrier-registration` — every `Rendezvous::new`/`MachineSync::new`
//!   must be paired with a `JobAbort` registration in the enclosing
//!   function (the exact PR 5 deadlock class).
//! * `pool-leak` — every `BufPool`/`DigestPool` checkout must be lexically
//!   paired with a recycle (`.put`, `finish_recycle`, `create_pooled`) or
//!   an approved handoff (`LocalShard`/`SpillLane`, or the wire via
//!   `Payload::Data`/`Payload::Load`, whose receiver recycles).
//! * `sleep-slicing` — no raw `thread::sleep` outside the sliced-wait
//!   helpers (a sleeping unit cannot observe `JobAbort`).
//! * `panic-hygiene` — no `todo!`/`unimplemented!`/stray `panic!` outside
//!   `#[cfg(test)]` (typed errors carry machine/unit/superstep; panics
//!   lose that and lean on `catch_unwind`).
//! * `print-hygiene` — no raw `eprintln!`/`println!` in `worker/`,
//!   `engine/`, `net/`, `serve/` outside tests: diagnostics route through
//!   [`crate::trace::diag`], which mirrors to stderr *and* a bounded ring
//!   tests can assert on.  (`trace/` itself is the sanctioned sink, and
//!   the CLI at the src root stays free to print.)
//!
//! All rules skip `#[cfg(test)]` regions: test code asserting on these
//! `Result`s via unwrap *is* the idiom there.

use super::lexer::{Kind, Tok};
use super::{Diagnostic, Rule};

/// Directories (relative to the scanned root) where `poison-safety`
/// applies: the modules that participate in job-abort propagation.
const POISON_SCOPE: &[&str] = &["worker/", "engine/", "net/", "recode/", "serve/"];

/// Directories where `print-hygiene` applies: the engine modules whose
/// diagnostics must flow through `trace::diag`.  Narrower than
/// [`POISON_SCOPE`]: `recode/` has no diagnostics, and `trace/` (the sink)
/// plus the CLI at the src root are exempt by construction.
const PRINT_SCOPE: &[&str] = &["worker/", "engine/", "net/", "serve/"];

/// Callees whose `Result` carries poison/abort and must propagate.
const POISON_CALLEES: &[&str] = &[
    "exchange",
    "wait_recv_done",
    "wait_send_allowed",
    "wait_compute_done",
    "wait_decided",
    "idle_wait",
    "send",
    "recv",
    "lock",
    "wait",
    "wait_timeout",
];

/// Token-stream context shared by the rule passes: which tokens sit in
/// `#[cfg(test)]`/`#[test]` items, and the function spans for the
/// lexical-pairing rules.
pub struct Ctx<'a> {
    toks: &'a [Tok],
    in_test: Vec<bool>,
    /// `(body_open, body_close)` token indices of every `fn` body,
    /// including nested ones.
    fns: Vec<(usize, usize)>,
}

impl<'a> Ctx<'a> {
    /// Precompute test regions and fn spans for `toks`.
    pub fn new(toks: &'a [Tok]) -> Self {
        Self {
            in_test: test_mask(toks),
            fns: fn_spans(toks),
            toks,
        }
    }

    /// The *outermost* fn body containing token `i`, if any.
    fn enclosing_fn(&self, i: usize) -> Option<(usize, usize)> {
        self.fns
            .iter()
            .filter(|&&(o, c)| o <= i && i <= c)
            .min_by_key(|&&(o, _)| o)
            .copied()
    }
}

/// Find the matching `close` for the `open` delimiter at `open_idx`.
/// Returns the last token index if unbalanced (forgiving, like the lexer).
fn match_delim(toks: &[Tok], open_idx: usize, open: char, close: char) -> usize {
    let mut depth = 0usize;
    for (j, t) in toks.iter().enumerate().skip(open_idx) {
        if t.is_punct(open) {
            depth += 1;
        } else if t.is_punct(close) {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
    }
    toks.len().saturating_sub(1)
}

/// Mark every token inside a `#[cfg(test)]`- or `#[test]`-attributed item.
fn test_mask(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i + 1 < toks.len() {
        if !(toks[i].is_punct('#') && toks[i + 1].is_punct('[')) {
            i += 1;
            continue;
        }
        let close = match_delim(toks, i + 1, '[', ']');
        let inner = &toks[i + 2..close];
        let has = |s: &str| inner.iter().any(|t| t.is_ident(s));
        // `#[test]`, `#[cfg(test)]`, `#[cfg(any(test, …))]` — but not
        // `#[cfg(not(test))]`, which means the opposite.
        let is_test = match inner.first() {
            Some(t) if t.is_ident("test") && inner.len() == 1 => true,
            Some(t) if t.is_ident("cfg") => has("test") && !has("not"),
            _ => false,
        };
        if !is_test {
            i = close + 1;
            continue;
        }
        // Skip any stacked attributes, then mark the item body.
        let mut j = close + 1;
        while j + 1 < toks.len() && toks[j].is_punct('#') && toks[j + 1].is_punct('[') {
            j = match_delim(toks, j + 1, '[', ']') + 1;
        }
        let mut pd = 0usize;
        while j < toks.len() {
            let t = &toks[j];
            if t.is_punct('(') {
                pd += 1;
            } else if t.is_punct(')') {
                pd = pd.saturating_sub(1);
            } else if pd == 0 && t.is_punct(';') {
                break; // item without a body (e.g. `#[cfg(test)] mod t;`)
            } else if pd == 0 && t.is_punct('{') {
                let end = match_delim(toks, j, '{', '}');
                for m in mask.iter_mut().take(end + 1).skip(i) {
                    *m = true;
                }
                j = end;
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

/// Collect `(body_open, body_close)` for every `fn` item (incl. nested).
fn fn_spans(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i + 1 < toks.len() {
        // `fn name…` — skip fn-pointer types (`fn(` with no name).
        if toks[i].is_ident("fn") && toks[i + 1].kind == Kind::Ident {
            let mut j = i + 2;
            let mut pd = 0usize;
            while j < toks.len() {
                let t = &toks[j];
                if t.is_punct('(') {
                    pd += 1;
                } else if t.is_punct(')') {
                    pd = pd.saturating_sub(1);
                } else if pd == 0 && t.is_punct(';') {
                    break; // trait method declaration — no body
                } else if pd == 0 && t.is_punct('{') {
                    spans.push((j, match_delim(toks, j, '{', '}')));
                    break;
                }
                j += 1;
            }
        }
        i += 1;
    }
    spans
}

/// Does the span contain a `.name(` method call?
fn span_has_method(toks: &[Tok], span: (usize, usize), name: &str) -> bool {
    (span.0..span.1).any(|k| {
        toks[k].is_punct('.')
            && toks.get(k + 1).is_some_and(|t| t.is_ident(name))
            && toks.get(k + 2).is_some_and(|t| t.is_punct('('))
    })
}

/// Does the span mention identifier `name` at all?
fn span_has_ident(toks: &[Tok], span: (usize, usize), name: &str) -> bool {
    (span.0..span.1).any(|k| toks[k].is_ident(name))
}

/// Run every rule over `toks` for the file at `rel` (path relative to the
/// scanned root, `/`-separated).
pub fn run_all(rel: &str, ctx: &Ctx<'_>) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    poison_safety(rel, ctx, &mut out);
    barrier_registration(rel, ctx, &mut out);
    pool_leak(rel, ctx, &mut out);
    sleep_slicing(rel, ctx, &mut out);
    panic_hygiene(rel, ctx, &mut out);
    print_hygiene(rel, ctx, &mut out);
    out.sort_by_key(|d| (d.line, d.rule.id()));
    out
}

/// `poison-safety`: `.unwrap()`/`.expect(…)` on a watched callee's Result.
fn poison_safety(rel: &str, ctx: &Ctx<'_>, out: &mut Vec<Diagnostic>) {
    if !POISON_SCOPE.iter().any(|p| rel.starts_with(p)) {
        return;
    }
    let toks = ctx.toks;
    for i in 1..toks.len() {
        if ctx.in_test[i]
            || toks[i].kind != Kind::Ident
            || !POISON_CALLEES.contains(&toks[i].text.as_str())
        {
            continue;
        }
        // Method or path call only: `.callee(` / `::callee(`.
        if !(toks[i - 1].is_punct('.') || toks[i - 1].is_punct(':')) {
            continue;
        }
        if !toks.get(i + 1).is_some_and(|t| t.is_punct('(')) {
            continue;
        }
        let close = match_delim(toks, i + 1, '(', ')');
        let (Some(dot), Some(m), Some(paren)) =
            (toks.get(close + 1), toks.get(close + 2), toks.get(close + 3))
        else {
            continue;
        };
        if dot.is_punct('.')
            && (m.is_ident("unwrap") || m.is_ident("expect"))
            && paren.is_punct('(')
        {
            out.push(Diagnostic {
                file: rel.to_string(),
                line: m.line,
                rule: Rule::PoisonSafety,
                msg: format!(
                    "`.{}()` on the Result of `{}` swallows poison/abort — propagate with \
                     `?` so `Error::JobFailed` reaches the driver",
                    m.text, toks[i].text
                ),
            });
        }
    }
}

/// `barrier-registration`: `Rendezvous::new`/`MachineSync::new` without a
/// `.register(` in the enclosing fn.
fn barrier_registration(rel: &str, ctx: &Ctx<'_>, out: &mut Vec<Diagnostic>) {
    let toks = ctx.toks;
    for i in 0..toks.len() {
        if ctx.in_test[i] {
            continue;
        }
        let ty = &toks[i];
        if !(ty.is_ident("Rendezvous") || ty.is_ident("MachineSync")) {
            continue;
        }
        let qualified = toks.get(i + 1).is_some_and(|t| t.is_punct(':'))
            && toks.get(i + 2).is_some_and(|t| t.is_punct(':'))
            && toks.get(i + 3).is_some_and(|t| t.is_ident("new"))
            && toks.get(i + 4).is_some_and(|t| t.is_punct('('));
        if !qualified {
            continue;
        }
        let registered = ctx
            .enclosing_fn(i)
            .is_some_and(|span| span_has_method(toks, span, "register"));
        if !registered {
            out.push(Diagnostic {
                file: rel.to_string(),
                line: ty.line,
                rule: Rule::BarrierRegistration,
                msg: format!(
                    "`{}::new` with no `JobAbort::register` in the enclosing fn — an \
                     unregistered barrier wedges every sibling when a unit dies (the \
                     PR 5 deadlock class)",
                    ty.text
                ),
            });
        }
    }
}

/// `pool-leak`: `<…pool>.take(…)`/`.take_with_capacity(…)` in a fn with no
/// recycle or approved handoff.
fn pool_leak(rel: &str, ctx: &Ctx<'_>, out: &mut Vec<Diagnostic>) {
    let toks = ctx.toks;
    for i in 1..toks.len() {
        if ctx.in_test[i] || !toks[i].is_punct('.') {
            continue;
        }
        let recv_is_pool = toks[i - 1].kind == Kind::Ident && toks[i - 1].text.contains("pool");
        let call = toks.get(i + 1).is_some_and(|t| {
            t.is_ident("take") || t.is_ident("take_with_capacity")
        }) && toks.get(i + 2).is_some_and(|t| t.is_punct('('));
        if !(recv_is_pool && call) {
            continue;
        }
        let paired = ctx.enclosing_fn(i).is_some_and(|span| {
            span_has_method(toks, span, "put")
                || span_has_ident(toks, span, "finish_recycle")
                || span_has_ident(toks, span, "create_pooled")
                || span_has_ident(toks, span, "LocalShard")
                || span_has_ident(toks, span, "SpillLane")
                || wire_handoff(toks, span)
        });
        if !paired {
            out.push(Diagnostic {
                file: rel.to_string(),
                line: toks[i + 1].line,
                rule: Rule::PoolLeak,
                msg: "pool checkout with no recycle/handoff in the enclosing fn — pair it \
                      with `.put(..)`/`finish_recycle`, or hand the buffer off via \
                      LocalShard/SpillLane/`Payload::{Data,Load}`"
                    .to_string(),
            });
        }
    }
}

/// `Payload::Data(` / `Payload::Load(` — ownership moves onto the wire and
/// the receiving unit recycles the block (the spine's documented protocol).
fn wire_handoff(toks: &[Tok], span: (usize, usize)) -> bool {
    (span.0..span.1).any(|k| {
        toks[k].is_ident("Payload")
            && toks.get(k + 1).is_some_and(|t| t.is_punct(':'))
            && toks.get(k + 2).is_some_and(|t| t.is_punct(':'))
            && toks
                .get(k + 3)
                .is_some_and(|t| t.is_ident("Data") || t.is_ident("Load"))
    })
}

/// `sleep-slicing`: raw `thread::sleep(...)` outside the sliced helpers.
fn sleep_slicing(rel: &str, ctx: &Ctx<'_>, out: &mut Vec<Diagnostic>) {
    let toks = ctx.toks;
    for i in 3..toks.len() {
        if ctx.in_test[i] {
            continue;
        }
        if toks[i].is_ident("sleep")
            && toks.get(i + 1).is_some_and(|t| t.is_punct('('))
            && toks[i - 1].is_punct(':')
            && toks[i - 2].is_punct(':')
            && toks[i - 3].is_ident("thread")
        {
            out.push(Diagnostic {
                file: rel.to_string(),
                line: toks[i].line,
                rule: Rule::SleepSlicing,
                msg: "raw `thread::sleep` cannot observe `JobAbort` — slice the wait \
                      (bounded ≤ABORT_POLL chunks that re-check the flag) or use a \
                      poisonable primitive"
                    .to_string(),
            });
        }
    }
}

/// `panic-hygiene`: `todo!`/`unimplemented!`/`panic!` outside tests.
fn panic_hygiene(rel: &str, ctx: &Ctx<'_>, out: &mut Vec<Diagnostic>) {
    let toks = ctx.toks;
    for i in 0..toks.len() {
        if ctx.in_test[i] {
            continue;
        }
        let t = &toks[i];
        let is_macro = (t.is_ident("panic") || t.is_ident("todo") || t.is_ident("unimplemented"))
            && toks.get(i + 1).is_some_and(|n| n.is_punct('!'));
        if is_macro {
            out.push(Diagnostic {
                file: rel.to_string(),
                line: t.line,
                rule: Rule::PanicHygiene,
                msg: format!(
                    "`{}!` outside #[cfg(test)] — return a typed `Error` instead: panics \
                     lose the machine/unit/superstep attribution `JobFailed` carries",
                    t.text
                ),
            });
        }
    }
}

/// `print-hygiene`: raw `eprintln!`/`println!` in the engine modules.
fn print_hygiene(rel: &str, ctx: &Ctx<'_>, out: &mut Vec<Diagnostic>) {
    if !PRINT_SCOPE.iter().any(|p| rel.starts_with(p)) {
        return;
    }
    let toks = ctx.toks;
    for i in 0..toks.len() {
        if ctx.in_test[i] {
            continue;
        }
        let t = &toks[i];
        let is_print = (t.is_ident("eprintln") || t.is_ident("println"))
            && toks.get(i + 1).is_some_and(|n| n.is_punct('!'));
        if is_print {
            out.push(Diagnostic {
                file: rel.to_string(),
                line: t.line,
                rule: Rule::PrintHygiene,
                msg: format!(
                    "raw `{}!` in an engine module — route it through `trace::diag` so \
                     tests can assert on it (the stderr mirror is kept)",
                    t.text
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::lexer::lex;

    fn diags(rel: &str, src: &str) -> Vec<Diagnostic> {
        let toks = lex(src);
        let ctx = Ctx::new(&toks);
        run_all(rel, &ctx)
    }

    #[test]
    fn poison_safety_scoped_to_watched_dirs() {
        let src = "fn f(m: &Mutex<u32>) -> u32 { *m.lock().unwrap() }";
        assert_eq!(diags("worker/x.rs", src).len(), 1);
        assert_eq!(diags("util/x.rs", src).len(), 0);
    }

    #[test]
    fn poison_safety_spares_propagation_and_tests() {
        let ok = "fn f(ms: &MachineSync) -> Result<()> { ms.wait_recv_done(0)?; Ok(()) }";
        assert!(diags("worker/x.rs", ok).is_empty());
        let test = "#[cfg(test)]\nmod t { fn f(r: &R) { r.exchange(0, 1, |v| v).unwrap(); } }";
        assert!(diags("worker/x.rs", test).is_empty());
    }

    #[test]
    fn unregistered_barrier_fires_registered_does_not() {
        let bad = "fn f(n: usize) { let rv = Rendezvous::new(n); }";
        let d = diags("a.rs", bad);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, Rule::BarrierRegistration);
        let good = "fn f(n: usize, a: &JobAbort) { let rv = Rendezvous::new(n); \
                    a.register(rv.clone()); }";
        assert!(diags("a.rs", good).is_empty());
    }

    #[test]
    fn pool_take_needs_put_or_handoff() {
        let bad = "fn f(pool: &BufPool) -> usize { let b = pool.take(); b.len() }";
        assert_eq!(diags("a.rs", bad).len(), 1);
        let put = "fn f(pool: &BufPool) { let b = pool.take(); pool.put(b); }";
        assert!(diags("a.rs", put).is_empty());
        let wire = "fn f(pool: &BufPool, tx: &mut NetSender) -> Result<()> { \
                    let b = pool.take(); tx.send(0, 0, Payload::Data(b)) }";
        assert!(diags("a.rs", wire).is_empty());
        // `std::mem::take` and iterator `.take(n)` never match: the
        // receiver must be a *pool*.
        let non_pool = "fn f(v: &mut Vec<u8>) { let b = std::mem::take(v); drop(b); }";
        assert!(diags("a.rs", non_pool).is_empty());
    }

    #[test]
    fn sleeps_and_panics_fire_outside_tests_only() {
        let src = "fn f() { std::thread::sleep(D); }\nfn g() { todo!() }\n\
                   #[cfg(test)]\nmod t { fn h() { std::thread::sleep(D); panic!(); } }";
        let d = diags("a.rs", src);
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].rule, Rule::SleepSlicing);
        assert_eq!(d[1].rule, Rule::PanicHygiene);
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let src = "#[cfg(not(test))]\nfn f() { panic!(\"x\") }";
        assert_eq!(diags("a.rs", src).len(), 1);
    }

    #[test]
    fn prints_fire_in_engine_modules_only() {
        let src = "fn f() { eprintln!(\"x\"); }\nfn g() { println!(\"y\"); }";
        let d = diags("worker/x.rs", src);
        assert_eq!(d.len(), 2);
        assert!(d.iter().all(|d| d.rule == Rule::PrintHygiene));
        // Out of scope: the CLI, util, and the trace sink itself.
        assert!(diags("util/x.rs", src).is_empty());
        assert!(diags("trace/mod.rs", src).is_empty());
        // Test code prints freely.
        let test = "#[cfg(test)]\nmod t { fn f() { println!(\"ok\"); } }";
        assert!(diags("serve/x.rs", test).is_empty());
    }
}
