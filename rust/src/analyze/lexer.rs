//! Hand-rolled Rust token scanner for the invariant analyzer.
//!
//! This is **not** a full Rust lexer — it is the minimal scanner the
//! [`super::rules`] passes need: identifiers, single-char punctuation, and
//! opaque literals, with comments and string/char literals stripped so the
//! rules can never match text inside them.  It follows the repo's
//! vendor-everything rule (zero dependencies, no `syn`/`proc-macro2`), and
//! it is deliberately forgiving: on malformed input it produces *some*
//! token stream rather than erroring, because a lint must never block the
//! build on code rustc itself will reject moments later.
//!
//! Handled explicitly (each has a unit test below):
//! * line comments (where `analyze:allow` pragmas live — collected by
//!   [`super::scan_pragmas`] from the raw text, not from tokens) and
//!   nested block comments;
//! * string literals with escapes, byte strings, raw (byte) strings with
//!   any number of `#`s, raw identifiers (`r#type`);
//! * char literals vs. lifetimes (`'a'` is a literal, `'a` is not).

/// What a token is, at the granularity the rules care about.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Identifier or keyword (`fn`, `unwrap`, `Rendezvous`, …).
    Ident,
    /// A single punctuation character (`.`, `(`, `{`, `!`, `:`, …).
    Punct,
    /// An opaque literal: string/char/number/lifetime.  Never matched by
    /// name; only present so neighbourhood checks stay aligned.
    Lit,
}

/// One scanned token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    /// Token class.
    pub kind: Kind,
    /// Source text (single char for [`Kind::Punct`]).
    pub text: String,
    /// 1-based line the token starts on.
    pub line: u32,
}

impl Tok {
    /// Is this the punctuation character `c`?
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == Kind::Punct && self.text.len() == 1 && self.text.starts_with(c)
    }

    /// Is this exactly the identifier `s`?
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == Kind::Ident && self.text == s
    }
}

/// Scan `src` into tokens, stripping comments and collapsing literals.
pub fn lex(src: &str) -> Vec<Tok> {
    let b: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut line: u32 = 1;
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        if c == '/' && i + 1 < b.len() && b[i + 1] == '/' {
            while i < b.len() && b[i] != '\n' {
                i += 1;
            }
            continue;
        }
        if c == '/' && i + 1 < b.len() && b[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < b.len() && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < b.len() && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        if c == '"' {
            let start_line = line;
            i = skip_string(&b, i, &mut line);
            toks.push(lit(start_line));
            continue;
        }
        if c == '\'' {
            let start_line = line;
            i = skip_char_or_lifetime(&b, i, &mut line);
            toks.push(lit(start_line));
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            let s: String = b[start..i].iter().collect();
            // String-ish prefixes: r"", r#""#, br"", b"", b''  — and raw
            // identifiers (r#type), which stay identifiers.
            if (s == "r" || s == "br") && i < b.len() && (b[i] == '"' || b[i] == '#') {
                let mut j = i;
                while j < b.len() && b[j] == '#' {
                    j += 1;
                }
                if j < b.len() && b[j] == '"' {
                    let start_line = line;
                    i = skip_raw_string(&b, i, &mut line);
                    toks.push(lit(start_line));
                    continue;
                }
                if s == "r" && j < b.len() && (b[j].is_alphabetic() || b[j] == '_') {
                    // raw identifier r#type: token is the bare name.
                    let mut k = j;
                    while k < b.len() && (b[k].is_alphanumeric() || b[k] == '_') {
                        k += 1;
                    }
                    toks.push(Tok {
                        kind: Kind::Ident,
                        text: b[j..k].iter().collect(),
                        line,
                    });
                    i = k;
                    continue;
                }
                // Lone `r`/`br` before stray hashes: fall through as ident.
            } else if s == "b" && i < b.len() && b[i] == '"' {
                let start_line = line;
                i = skip_string(&b, i, &mut line);
                toks.push(lit(start_line));
                continue;
            } else if s == "b" && i < b.len() && b[i] == '\'' {
                let start_line = line;
                i = skip_char_or_lifetime(&b, i, &mut line);
                toks.push(lit(start_line));
                continue;
            }
            toks.push(Tok {
                kind: Kind::Ident,
                text: s,
                line,
            });
            continue;
        }
        if c.is_ascii_digit() {
            // Numbers (incl. hex/suffixes); `.` is left out so ranges and
            // method calls after numbers stay separate tokens.
            while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            toks.push(lit(line));
            continue;
        }
        toks.push(Tok {
            kind: Kind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }
    toks
}

fn lit(line: u32) -> Tok {
    Tok {
        kind: Kind::Lit,
        text: String::new(),
        line,
    }
}

/// `i` points at the opening `"`; returns the index just past the close.
fn skip_string(b: &[char], mut i: usize, line: &mut u32) -> usize {
    i += 1;
    while i < b.len() {
        match b[i] {
            '\\' => {
                if i + 1 < b.len() && b[i + 1] == '\n' {
                    *line += 1;
                }
                i += 2;
            }
            '"' => return i + 1,
            '\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// `i` points at the first `#` or the `"` after an `r`/`br` prefix.
fn skip_raw_string(b: &[char], mut i: usize, line: &mut u32) -> usize {
    let mut hashes = 0usize;
    while i < b.len() && b[i] == '#' {
        hashes += 1;
        i += 1;
    }
    debug_assert!(i < b.len() && b[i] == '"');
    i += 1;
    while i < b.len() {
        if b[i] == '\n' {
            *line += 1;
        } else if b[i] == '"' && b[i + 1..].iter().take(hashes).filter(|&&h| h == '#').count() == hashes
        {
            return i + 1 + hashes;
        }
        i += 1;
    }
    i
}

/// `i` points at a `'`: either a char literal (`'x'`, `'\n'`, `'\u{1F}'`)
/// or a lifetime (`'a`, `'static`, `'_`).
fn skip_char_or_lifetime(b: &[char], i: usize, line: &mut u32) -> usize {
    if i + 1 < b.len() && b[i + 1] == '\\' {
        // Escaped char literal: scan to the closing quote.
        let mut j = i + 2;
        while j < b.len() && b[j] != '\'' {
            if b[j] == '\n' {
                *line += 1;
            }
            j += 1;
        }
        return (j + 1).min(b.len());
    }
    if i + 2 < b.len() && b[i + 2] == '\'' {
        return i + 3; // plain 'x'
    }
    // Lifetime: consume the label.
    let mut j = i + 1;
    while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
        j += 1;
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_and_strings_are_stripped() {
        let src = r##"
            // unwrap() in a line comment
            /* unwrap() in /* a nested */ block */
            let s = "rv.exchange(0).unwrap()";
            let r = r#"lock().unwrap()"#;
            call();
        "##;
        let ids = idents(src);
        assert!(ids.contains(&"call".to_string()));
        assert!(!ids.contains(&"unwrap".to_string()));
        assert!(!ids.contains(&"exchange".to_string()));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let src = "fn f<'a>(x: &'a str) -> char { let c = 'x'; let n = '\\n'; c }";
        let ids = idents(src);
        assert_eq!(
            ids,
            vec!["fn", "f", "x", "str", "char", "let", "c", "let", "n", "c"]
        );
    }

    #[test]
    fn raw_identifiers_keep_their_name() {
        let ids = idents("let r#type = 1;");
        assert!(ids.contains(&"type".to_string()));
    }

    #[test]
    fn line_numbers_track_newlines_everywhere() {
        let src = "a\n\"two\nline\"\nb /* c\nd */ e";
        let toks = lex(src);
        let a = toks.iter().find(|t| t.is_ident("a")).unwrap();
        let b = toks.iter().find(|t| t.is_ident("b")).unwrap();
        let e = toks.iter().find(|t| t.is_ident("e")).unwrap();
        assert_eq!(a.line, 1);
        assert_eq!(b.line, 4);
        assert_eq!(e.line, 5);
    }

    #[test]
    fn method_chain_tokens_align() {
        let toks = lex("self.state.lock().unwrap();");
        let texts: Vec<&str> = toks
            .iter()
            .map(|t| if t.kind == Kind::Lit { "<lit>" } else { t.text.as_str() })
            .collect();
        assert_eq!(
            texts,
            vec!["self", ".", "state", ".", "lock", "(", ")", ".", "unwrap", "(", ")", ";"]
        );
    }
}
