//! GraphD command-line launcher.
//!
//! ```text
//! graphd gen   --dataset webuk-s [--scale 1.0] [--out PATH]
//! graphd run   --algo pagerank|hashmin|sssp --dataset NAME
//!              [--profile wpc|whigh|test] [--steps 10] [--machines N]
//!              [--scale F] [--basic] [--trace [PATH]] [-c key=val ...]
//! graphd serve --dataset NAME [--queries FILE|-] [--gen Q] [--seed S]
//!              [--lanes 8] [--basic] [--profile NAME] [--machines N]
//!              [--scale F] [--trace] [-c key=val ...]
//! graphd table --id 2|3|5|6|7|8 [--scale F]
//! graphd worker --rank R --machines N (--listen ADDR | --join ADDR | --sim)
//!               [--spawn-peers] [--algo pagerank|sssp|hashmin] [--dataset NAME]
//!               [--steps S] [--scale F] [--recode] [--out PATH]
//!               [--workdir PATH] [-c key=val ...]
//! graphd info
//! ```
//!
//! `worker` is one machine process of a TCP-transport job: rank 0 binds the
//! coordinator address (`--listen`, `host:0` picks a port) and prints
//! `listening on <addr>`; followers `--join` that address.  Every process
//! generates and preprocesses the same deterministic dataset locally, runs
//! only its own machine's superstep loop, and writes its partition's final
//! values as `id<TAB><hex>` lines (`--out`).  `--sim` instead runs the whole
//! job in this one process on the simulator fabric and writes *all*
//! machines' values — the bit-exact reference the transport tests diff
//! against.  `--spawn-peers` makes rank 0 fork ranks `1..N` itself.
//!
//! Every subcommand forwards `-c key=val` pairs to
//! `graphd::config::JobConfig::apply`; README's "Config keys" table lists
//! them all.  The
//! headline knob for `run`/`serve` is `-c resident=stream|mmap|auto`: it
//! switches U_c from re-streaming `se.bin` every superstep to reading
//! adjacency from the mmap'd CSR resident store (semi-external-memory
//! mode — `graphd run --algo pagerank --dataset btc-s -c resident=mmap`),
//! with `-c resident_budget=BYTES` bounding what `auto` will map.
//!
//! (Hand-rolled argument parsing: the offline crate registry has no clap.)

use graphd::baselines::Algo;
use graphd::bench;
use graphd::config::ClusterProfile;
use graphd::graph::formats;
use graphd::graph::generator::{self, Dataset};
use graphd::metrics::{Cell, Table};
use graphd::serve::{self, Query, ServeConfig};
use graphd::{GraphD, GraphSource};
use std::collections::HashMap;

/// Parse `--flag [value]` and `-c key=val` arguments.  A `--flag` followed
/// by another flag (or by nothing) is a *boolean* flag: it maps to an empty
/// string and does **not** swallow the next token.
fn parse_flags(args: &[String]) -> (HashMap<String, String>, Vec<(String, String)>) {
    let mut flags = HashMap::new();
    let mut cfgs = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if a == "-c" {
            if let Some(kv) = args.get(i + 1) {
                if let Some((k, v)) = kv.split_once('=') {
                    cfgs.push((k.to_string(), v.to_string()));
                }
            }
            i += 2;
        } else if let Some(name) = a.strip_prefix("--") {
            match args.get(i + 1) {
                Some(next) if !next.starts_with("--") && next != "-c" => {
                    flags.insert(name.to_string(), next.clone());
                    i += 2;
                }
                _ => {
                    flags.insert(name.to_string(), String::new());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    (flags, cfgs)
}

fn dataset_by_name(name: &str) -> Option<Dataset> {
    Dataset::all().into_iter().find(|d| d.name() == name)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { &args[..] } else { &args[1..] };
    let (flags, cfgs) = parse_flags(rest);
    let scale: f64 = flags
        .get("scale")
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(bench::scale_from_env);

    let result = match cmd {
        "gen" => cmd_gen(&flags, scale),
        "run" => cmd_run(&flags, &cfgs, scale),
        "serve" => cmd_serve(&flags, &cfgs, scale),
        "table" => cmd_table(&flags, scale),
        "worker" => cmd_worker(&flags, &cfgs, scale),
        "info" => {
            cmd_info();
            Ok(())
        }
        _ => {
            eprintln!(
                "usage: graphd <gen|run|serve|table|worker|info> [flags]\n  \
                 see module docs of rust/src/main.rs"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_gen(flags: &HashMap<String, String>, scale: f64) -> graphd::Result<()> {
    let name = flags
        .get("dataset")
        .map(String::as_str)
        .unwrap_or("webuk-s");
    let ds = dataset_by_name(name)
        .ok_or_else(|| graphd::Error::Config(format!("unknown dataset {name}")))?;
    let g = ds.generate_scaled(scale);
    let s = g.stats();
    eprintln!(
        "{}: |V|={} |E|={} avg-deg {:.2} max-deg {}",
        ds.name(),
        s.nv,
        s.ne,
        s.avg_deg,
        s.max_deg
    );
    if let Some(out) = flags.get("out") {
        let n = formats::write_text_file(&g, None, std::path::Path::new(out))?;
        eprintln!("wrote {n} vertex lines to {out}");
    }
    Ok(())
}

fn cmd_run(
    flags: &HashMap<String, String>,
    cfgs: &[(String, String)],
    scale: f64,
) -> graphd::Result<()> {
    let ds = dataset_by_name(flags.get("dataset").map(String::as_str).unwrap_or("btc-s"))
        .ok_or_else(|| graphd::Error::Config("unknown dataset".into()))?;
    let profile = ClusterProfile::by_name(
        flags.get("profile").map(String::as_str).unwrap_or("wpc"),
        flags.get("machines").and_then(|m| m.parse().ok()),
    )?;
    let steps: u64 = flags.get("steps").and_then(|s| s.parse().ok()).unwrap_or(10);
    let mut g = ds.generate_scaled(scale);
    let algo = match flags.get("algo").map(String::as_str).unwrap_or("pagerank") {
        "pagerank" => Algo::PageRank { supersteps: steps },
        "hashmin" => Algo::HashMin,
        "sssp" => {
            g = g.with_unit_weights();
            Algo::Sssp {
                source: bench::sssp_source(&g),
            }
        }
        other => return Err(graphd::Error::Config(format!("unknown algo {other}"))),
    };

    // `--trace [PATH]` turns on the flight-recorder span layer and routes
    // the Chrome-trace export to PATH (default `trace.json` in the current
    // directory) — the bench workdir is deleted after the run, so the
    // export must land outside it.  The harness runs the IO-Basic and
    // IO-Recoded jobs back to back; each export rewrites PATH, so the file
    // left behind covers the *last* job (IO-Recoded).
    let mut cfgs = cfgs.to_vec();
    if let Some(path) = flags.get("trace") {
        let path = if path.is_empty() { "trace.json" } else { path.as_str() };
        cfgs.push(("trace".into(), "true".into()));
        cfgs.push(("trace_path".into(), path.to_string()));
        eprintln!("tracing supersteps to {path} (load https://ui.perfetto.dev)");
    }

    // `--basic`: IO-Basic only — no recoding, no Recoded re-run.  The
    // recovery smoke run uses this so the trace export left behind is the
    // faulted Basic session's, not a clean follow-up job's.
    let basic_only = flags.contains_key("basic");
    let gd = if basic_only {
        bench::run_graphd_basic_cfg("cli", &g, algo, &profile, bench::use_xla_from_env(), &cfgs)?
    } else {
        bench::run_graphd_cfg("cli", &g, algo, &profile, bench::use_xla_from_env(), &cfgs)?
    };
    if let Some(json) = bench::bench_json_path() {
        bench::bench_json_merge(&json, "cli_run_basic", &gd.basic_metrics.to_json())?;
        if !basic_only {
            bench::bench_json_merge(&json, "cli_run_recoded", &gd.recoded_metrics.to_json())?;
        }
    }
    let mut t = Table::new(
        &format!("{} / {} on {}", ds.name(), algo.name(), profile.name),
        &["Preprocess", "Load", "Compute"],
    );
    t.row(
        "IO-Basic",
        vec![
            Cell::NA,
            Cell::Secs(gd.basic_load),
            Cell::Secs(gd.basic_compute),
        ],
    );
    if !basic_only {
        t.row(
            "IO-Recoding",
            vec![
                Cell::NA,
                Cell::Secs(gd.basic_load),
                Cell::Secs(gd.recoding_compute),
            ],
        );
        t.row(
            "IO-Recoded",
            vec![
                Cell::Text("ID-Recoding".into()),
                Cell::Secs(gd.recoded_load),
                Cell::Secs(gd.recoded_compute),
            ],
        );
    }
    println!("{}", t.render());
    Ok(())
}

/// `graphd serve`: build a query server from a session over a generated
/// dataset and answer a query file (or a generated `query_set` workload)
/// through k-lane batched traversals.
fn cmd_serve(
    flags: &HashMap<String, String>,
    cfgs: &[(String, String)],
    scale: f64,
) -> graphd::Result<()> {
    let ds = dataset_by_name(flags.get("dataset").map(String::as_str).unwrap_or("btc-s"))
        .ok_or_else(|| graphd::Error::Config("unknown dataset".into()))?;
    let profile = ClusterProfile::by_name(
        flags.get("profile").map(String::as_str).unwrap_or("test"),
        flags.get("machines").and_then(|m| m.parse().ok()),
    )?;
    let lanes: usize = flags.get("lanes").and_then(|s| s.parse().ok()).unwrap_or(8);
    let steps: u64 = flags.get("steps").and_then(|s| s.parse().ok()).unwrap_or(0);
    let seed: u64 = flags.get("seed").and_then(|s| s.parse().ok()).unwrap_or(7);
    let g = ds.generate_scaled(scale);

    // Workload: an explicit query file ('-' = stdin), or a deterministic
    // generated set (`--gen Q`; also the default, with Q = 16).
    let queries: Vec<Query> = if let Some(path) = flags.get("queries") {
        let text = if path == "-" {
            use std::io::Read;
            let mut buf = String::new();
            std::io::stdin().read_to_string(&mut buf)?;
            buf
        } else {
            std::fs::read_to_string(path)?
        };
        let mut qs = Vec::new();
        for line in text.lines() {
            if let Some(q) = serve::parse_query_line(line)? {
                qs.push(q);
            }
        }
        qs
    } else {
        let q: usize = flags.get("gen").and_then(|s| s.parse().ok()).unwrap_or(16);
        generator::query_set(g.num_vertices(), q, seed)
            .into_iter()
            .map(|(source, target)| Query::Dist { source, target })
            .collect()
    };

    let mut b = GraphD::builder()
        .profile(profile)
        .use_xla(bench::use_xla_from_env());
    // `--trace` turns on the span layer for the serve session: batch spans
    // land in `<workdir>/trace_serve.json` (and the load/recode phases in
    // their own files next to it), so the workdir is kept after the run.
    let traced = flags.contains_key("trace");
    if traced {
        b = b.config("trace", "true");
    }
    for (k, v) in cfgs {
        b = b.config(k, v);
    }
    let session = b.build()?;
    let mut graph = session.load(GraphSource::InMemory(&g))?;
    if !flags.contains_key("basic") {
        graph.recode()?; // serve from the §5 in-memory digesting path
    }
    let mut server = graph
        .serve(ServeConfig::default().lanes(lanes).max_supersteps(steps))?;
    eprintln!(
        "{}: |V|={} |E|={}, {} queries, k={} lanes{}",
        ds.name(),
        g.num_vertices(),
        g.num_edges(),
        queries.len(),
        lanes,
        if graph.is_recoded() { ", recoded" } else { "" }
    );
    for q in queries {
        server.submit(q);
    }
    // One status line per drained batch: live introspection of the lane
    // scheduler without attaching a debugger to the serve loop.
    let results = server.run_pending_with(|st| {
        eprintln!(
            "serve: queued={} in-flight={} batches={} failed={} queries={} \
             qps={:.1} p50={:.1}ms p99={:.1}ms",
            st.queued,
            st.in_flight,
            st.batches,
            st.failed_batches,
            st.queries,
            st.qps,
            st.p50_secs * 1e3,
            st.p99_secs * 1e3,
        );
    })?;
    for r in &results {
        println!("{}", serve::render_result(r));
    }
    println!("{}", server.metrics().report());
    if let Some(json) = bench::bench_json_path() {
        bench::bench_json_merge(&json, "cli_serve", &server.metrics().to_json())?;
    }
    if traced {
        eprintln!(
            "trace: {} (load https://ui.perfetto.dev)",
            session.workdir().join("trace_serve.json").display()
        );
    } else {
        let _ = std::fs::remove_dir_all(session.workdir());
    }
    Ok(())
}

fn cmd_table(flags: &HashMap<String, String>, scale: f64) -> graphd::Result<()> {
    let id = flags.get("id").map(String::as_str).unwrap_or("5");
    let pr = |steps: u64| Algo::PageRank { supersteps: steps };
    let (title, combos, profile): (&str, Vec<(Dataset, Algo)>, ClusterProfile) = match id {
        "2" => (
            "Table 2 — PageRank on W^PC",
            vec![
                (Dataset::WebUkS, pr(10)),
                (Dataset::ClueWebS, pr(5)),
                (Dataset::TwitterS, pr(10)),
            ],
            ClusterProfile::wpc(),
        ),
        "3" => (
            "Table 3 — PageRank on W^high",
            vec![
                (Dataset::WebUkS, pr(10)),
                (Dataset::ClueWebS, pr(5)),
                (Dataset::TwitterS, pr(10)),
            ],
            ClusterProfile::whigh(),
        ),
        "5" => (
            "Table 5 — Hash-Min on W^PC",
            vec![
                (Dataset::BtcS, Algo::HashMin),
                (Dataset::FriendsterS, Algo::HashMin),
            ],
            ClusterProfile::wpc(),
        ),
        "6" => (
            "Table 6 — Hash-Min on W^high",
            vec![
                (Dataset::BtcS, Algo::HashMin),
                (Dataset::FriendsterS, Algo::HashMin),
            ],
            ClusterProfile::whigh(),
        ),
        "7" => (
            "Table 7 — SSSP on W^PC",
            vec![
                (Dataset::BtcS, Algo::Sssp { source: 0 }),
                (Dataset::FriendsterS, Algo::Sssp { source: 0 }),
                (Dataset::WebUkS, Algo::Sssp { source: 0 }),
                (Dataset::TwitterS, Algo::Sssp { source: 0 }),
            ],
            ClusterProfile::wpc(),
        ),
        "8" => (
            "Table 8 — SSSP on W^high",
            vec![
                (Dataset::BtcS, Algo::Sssp { source: 0 }),
                (Dataset::FriendsterS, Algo::Sssp { source: 0 }),
                (Dataset::WebUkS, Algo::Sssp { source: 0 }),
                (Dataset::TwitterS, Algo::Sssp { source: 0 }),
            ],
            ClusterProfile::whigh(),
        ),
        other => {
            return Err(graphd::Error::Config(format!(
                "table {other}: 1 and 4 are `cargo bench` targets; 2/3/5/6/7/8 run here"
            )))
        }
    };
    let out = bench::render_table(title, &combos, &profile, scale)?;
    println!("{out}");
    Ok(())
}

/// Run one job on a loaded graph and render the final vertex values as
/// `(id, hex-of-Codec-bytes)` rows — the wire encoding is the comparison
/// unit of the transport equivalence tests, so "bit-identical" means
/// exactly that (no float formatting in the loop).
fn worker_job<P: graphd::api::VertexProgram>(
    graph: &graphd::LoadedGraph<'_>,
    program: P,
) -> graphd::Result<Vec<(u32, String)>> {
    use graphd::msg::Codec;
    let res = graph.job(std::sync::Arc::new(program)).run()?;
    let mut rows = Vec::new();
    for (id, v) in res.values_by_id() {
        let mut buf = vec![0u8; <P::Value as Codec>::SIZE];
        v.encode(&mut buf);
        let hex: String = buf.iter().map(|b| format!("{b:02x}")).collect();
        rows.push((id, hex));
    }
    Ok(rows)
}

/// `graphd worker`: one machine process of a TCP-transport job (or, with
/// `--sim`, the whole job in-process as the equivalence reference).
fn cmd_worker(
    flags: &HashMap<String, String>,
    cfgs: &[(String, String)],
    scale: f64,
) -> graphd::Result<()> {
    let sim = flags.contains_key("sim");
    let n: usize = flags
        .get("machines")
        .and_then(|m| m.parse().ok())
        .unwrap_or(2);
    let rank: usize = flags.get("rank").and_then(|r| r.parse().ok()).unwrap_or(0);
    if !sim && rank >= n {
        return Err(graphd::Error::Config(format!(
            "--rank {rank} out of range for --machines {n}"
        )));
    }
    let ds = dataset_by_name(flags.get("dataset").map(String::as_str).unwrap_or("btc-s"))
        .ok_or_else(|| graphd::Error::Config("unknown dataset".into()))?;
    let steps: u64 = flags.get("steps").and_then(|s| s.parse().ok()).unwrap_or(10);
    let mut g = ds.generate_scaled(scale);
    let algo = flags.get("algo").map(String::as_str).unwrap_or("pagerank");
    if algo == "sssp" {
        g = g.with_unit_weights();
    }

    // Rank 0 binds the coordinator address first and announces the actual
    // one (--listen host:0 picks a free port), so launchers can parse it
    // and hand it to the followers before the handshake window closes.
    let addr = if sim {
        String::new()
    } else if rank == 0 {
        let listen = flags
            .get("listen")
            .filter(|a| !a.is_empty())
            .cloned()
            .unwrap_or_else(|| "127.0.0.1:0".to_string());
        let actual = graphd::net::tcp::leader_bind(&listen)?;
        println!("listening on {actual}");
        use std::io::Write;
        let _ = std::io::stdout().flush();
        actual
    } else {
        flags
            .get("join")
            .filter(|a| !a.is_empty())
            .cloned()
            .ok_or_else(|| graphd::Error::Config("worker rank > 0 needs --join ADDR".into()))?
    };

    // --spawn-peers: rank 0 forks ranks 1..n of the same job.  Children
    // write their own parts; this process fails if any child does.
    let mut children = Vec::new();
    if !sim && rank == 0 && flags.contains_key("spawn-peers") {
        let exe = std::env::current_exe()?;
        for peer in 1..n {
            let mut c = std::process::Command::new(&exe);
            c.arg("worker")
                .arg("--rank")
                .arg(peer.to_string())
                .arg("--machines")
                .arg(n.to_string())
                .arg("--join")
                .arg(&addr)
                .arg("--algo")
                .arg(algo)
                .arg("--dataset")
                .arg(ds.name())
                .arg("--steps")
                .arg(steps.to_string())
                .arg("--scale")
                .arg(scale.to_string());
            if flags.contains_key("recode") {
                c.arg("--recode");
            }
            if let Some(out) = flags.get("out") {
                c.arg("--out").arg(format!("{out}.{peer}"));
            }
            for (k, v) in cfgs {
                c.arg("-c").arg(format!("{k}={v}"));
            }
            children.push(c.spawn()?);
        }
    }

    // Private workdir per process: distributed machines must not share
    // scratch or checkpoint directories.
    let (workdir, ephemeral) = match flags.get("workdir") {
        Some(w) => (std::path::PathBuf::from(w), false),
        None => (
            std::env::temp_dir().join(format!(
                "graphd_worker_{}_{rank}",
                std::process::id()
            )),
            true,
        ),
    };
    let profile = ClusterProfile::by_name("test", Some(n))?;
    let mut b = GraphD::builder().profile(profile).workdir(&workdir);
    if !sim {
        b = b
            .config("transport", "tcp")
            .config("transport_addr", &addr)
            .config("transport_rank", &rank.to_string());
    }
    for (k, v) in cfgs {
        b = b.config(k, v);
    }
    let session = b.build()?;
    let mut graph = session.load(GraphSource::InMemory(&g))?;
    if flags.contains_key("recode") {
        graph.recode()?;
    }
    let rows = match algo {
        "pagerank" => worker_job(&graph, graphd::algos::PageRank::new(steps))?,
        "sssp" => worker_job(&graph, graphd::algos::Sssp::new(bench::sssp_source(&g)))?,
        "hashmin" => worker_job(&graph, graphd::algos::HashMin)?,
        other => return Err(graphd::Error::Config(format!("unknown algo {other}"))),
    };

    let mut text = String::new();
    for (id, hex) in &rows {
        text.push_str(&format!("{id}\t{hex}\n"));
    }
    match flags.get("out") {
        Some(out) => std::fs::write(out, text)?,
        None => print!("{text}"),
    }
    eprintln!(
        "worker {}: {} vertices done",
        if sim { "sim".to_string() } else { rank.to_string() },
        rows.len()
    );

    let mut failed = Vec::new();
    for (i, mut c) in children.into_iter().enumerate() {
        if !c.wait()?.success() {
            failed.push(i + 1);
        }
    }
    if ephemeral {
        let _ = std::fs::remove_dir_all(&workdir);
    }
    if !failed.is_empty() {
        return Err(graphd::Error::Other(format!(
            "worker peer process(es) {failed:?} exited with failure"
        )));
    }
    Ok(())
}

fn cmd_info() {
    println!("GraphD reproduction — three-layer Rust + JAX + Pallas build");
    println!("profiles:");
    for p in [ClusterProfile::wpc(), ClusterProfile::whigh()] {
        println!(
            "  {:6} {} machines, net {}/s shared, disk {}/s, ram {}, disk budget {}",
            p.name,
            p.machines,
            graphd::util::human_bytes(p.net_bytes_per_sec as u64),
            graphd::util::human_bytes(p.disk_bytes_per_sec.unwrap_or(0.0) as u64),
            graphd::util::human_bytes(p.ram_budget),
            graphd::util::human_bytes(p.disk_budget),
        );
    }
    println!("datasets:");
    for d in Dataset::all() {
        println!("  {}", d.name());
    }
    let dir = graphd::runtime::KernelSet::default_dir();
    println!(
        "artifacts: {} ({})",
        dir.display(),
        if dir.join("MANIFEST").exists() {
            "present"
        } else {
            "missing — run `make artifacts`"
        }
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_flags_values_and_configs() {
        let (flags, cfgs) = parse_flags(&sv(&[
            "--dataset", "btc-s", "--steps", "5", "-c", "mode=recoded",
        ]));
        assert_eq!(flags["dataset"], "btc-s");
        assert_eq!(flags["steps"], "5");
        assert_eq!(cfgs, vec![("mode".to_string(), "recoded".to_string())]);
    }

    #[test]
    fn parse_flags_boolean_does_not_swallow_next_flag() {
        // Regression: `--verbose --dataset btc-s` used to record
        // verbose="--dataset" and drop the dataset flag entirely.
        let (flags, _) = parse_flags(&sv(&["--verbose", "--dataset", "btc-s"]));
        assert_eq!(flags["verbose"], "");
        assert_eq!(flags["dataset"], "btc-s");
    }

    #[test]
    fn parse_flags_trailing_boolean_and_c_boundary() {
        let (flags, cfgs) = parse_flags(&sv(&["--dry-run", "-c", "merge_k=10", "--force"]));
        assert_eq!(flags["dry-run"], "");
        assert_eq!(flags["force"], "");
        assert_eq!(cfgs, vec![("merge_k".to_string(), "10".to_string())]);
    }
}
