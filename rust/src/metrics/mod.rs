//! Per-superstep / per-job metrics and paper-style table rendering.
//!
//! Table 4 of the paper splits superstep time into message *generation*
//! (U_c's vertex-centric computation, which includes edge/OMS streaming)
//! and message *sending* (U_s's transmission window) — we account both,
//! plus the I/O counters that justify the skip() design (Tables 7–8).

use crate::util::human_secs;

/// Counters for one superstep on one machine.
#[derive(Clone, Debug, Default)]
pub struct StepMetrics {
    pub step: u64,
    /// U_c time spent generating messages (vertex-centric computation).
    pub m_gene_secs: f64,
    /// U_s active transmission time.
    pub m_send_secs: f64,
    /// Messages/bytes that crossed the (simulated) wire.
    pub msgs_sent: u64,
    pub bytes_sent: u64,
    /// Messages/bytes delivered machine-locally through the fast path —
    /// zero simulated wire time, and zero OMS disk traffic in *both*
    /// shapes: the recoded digest shard and the IO-Basic local spill lane.
    /// Split out from `msgs_sent`/`bytes_sent` so the O(|V|/n)-permitted
    /// saving is visible per superstep in every mode.
    pub local_msgs: u64,
    /// Bytes counterpart of [`Self::local_msgs`].
    pub local_bytes: u64,
    /// Message records U_r received (wire + local lanes).
    pub msgs_recv: u64,
    /// Vertices on which compute()/block update ran.
    pub computed_vertices: u64,
    /// Active vertices after the superstep.
    pub active_after: u64,
    /// Adjacency items actually read from S^E.
    pub edge_items_read: u64,
    /// Adjacency items skipped via skip().
    pub edge_items_skipped: u64,
    /// Random seeks incurred by skip().
    pub seeks: u64,
    /// OMS files closed this superstep.
    pub oms_files: u64,
}

/// Whole-job metrics for one machine.
#[derive(Clone, Debug, Default)]
pub struct MachineMetrics {
    pub machine: usize,
    pub steps: Vec<StepMetrics>,
    /// Peak bytes of in-memory vertex state (A + A_r + A_s).
    pub peak_state_bytes: u64,
}

impl MachineMetrics {
    pub fn total_m_gene(&self) -> f64 {
        self.steps.iter().map(|s| s.m_gene_secs).sum()
    }
    pub fn total_m_send(&self) -> f64 {
        self.steps.iter().map(|s| s.m_send_secs).sum()
    }
    pub fn total_msgs_sent(&self) -> u64 {
        self.steps.iter().map(|s| s.msgs_sent + s.local_msgs).sum()
    }
    /// Messages delivered locally (fast path) across all supersteps.
    pub fn total_local_msgs(&self) -> u64 {
        self.steps.iter().map(|s| s.local_msgs).sum()
    }
}

/// Aggregated job result timings (one table cell each).
#[derive(Clone, Debug, Default)]
pub struct JobMetrics {
    /// Graph loading wall time (the tables' "Load" column).
    pub load_secs: f64,
    /// Iterative computation wall time (the "Compute" column).
    pub compute_secs: f64,
    /// Preprocessing (ID recoding / sharding) wall time, if any.
    pub preprocess_secs: f64,
    pub supersteps: u64,
    pub machines: Vec<MachineMetrics>,
    /// Bytes that transited the shared switch during the job.
    pub net_wire_bytes: u64,
    /// Bytes delivered machine-locally, bypassing the switch (fast path).
    pub net_local_bytes: u64,
    /// Job-wide [`crate::msg::BufPool`] counters (message-spine buffers).
    pub pool: crate::msg::PoolStats,
    /// Job-wide [`crate::msg::DigestPool`] counters (the ping-pong A_r /
    /// local-shard arrays of recoded digesting).  `hits > 0` on any
    /// multi-superstep digesting run means the O(|V|/n) arrays recycled
    /// instead of reallocating.
    pub digest_pool: crate::msg::PoolStats,
}

impl JobMetrics {
    /// Machine-0 totals, as reported in the paper's Table 4.
    pub fn m_gene_m_send(&self) -> (f64, f64) {
        match self.machines.first() {
            Some(m) => (m.total_m_gene(), m.total_m_send()),
            None => (0.0, 0.0),
        }
    }

    pub fn total_msgs(&self) -> u64 {
        self.machines.iter().map(|m| m.total_msgs_sent()).sum()
    }

    pub fn peak_state_bytes(&self) -> u64 {
        self.machines
            .iter()
            .map(|m| m.peak_state_bytes)
            .max()
            .unwrap_or(0)
    }
}

/// Serve-mode counters (the `crate::serve` query server): how many
/// queries were answered, at what rate, and the per-query latency
/// distribution.  Rendered as a self-describing text report so bench
/// output explains itself.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// Queries answered (excluding rejected/unknown-vertex queries).
    pub queries: u64,
    /// Shared superstep-loop batches run.
    pub batches: u64,
    /// Batches whose job died (`Answer::Failed` queries): the failure is
    /// isolated to the batch, the server keeps serving.
    pub failed_batches: u64,
    /// Total serving wall time across batches (seconds).
    pub wall_secs: f64,
    /// Supersteps summed over batches.
    pub supersteps: u64,
    /// Adjacency items streamed from `S^E`, summed over machines/batches —
    /// the I/O the k-lane batching amortises.
    pub edge_items_read: u64,
    /// Bytes through the shared switch, summed over batches.
    pub wire_bytes: u64,
    /// Bytes delivered machine-locally (fast path), summed over batches.
    pub local_bytes: u64,
    /// Per-query latency samples (submit → answered), seconds.
    pub latencies_secs: Vec<f64>,
}

impl ServeMetrics {
    /// Fold one batch's accounting in.
    pub fn record_batch(&mut self, queries: u64, wall_secs: f64, job: &JobMetrics) {
        self.queries += queries;
        self.batches += 1;
        self.wall_secs += wall_secs;
        self.supersteps += job.supersteps;
        self.edge_items_read += job
            .machines
            .iter()
            .flat_map(|m| m.steps.iter())
            .map(|s| s.edge_items_read)
            .sum::<u64>();
        self.wire_bytes += job.net_wire_bytes;
        self.local_bytes += job.net_local_bytes;
    }

    /// Queries per second of serving wall time.
    pub fn qps(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.queries as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// Latency percentile in seconds (`p` in [0, 100]); 0.0 when empty.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        percentile(&self.latencies_secs, p)
    }

    /// The self-describing text report (bench + CLI output).
    pub fn report(&self) -> String {
        // One sort serves all three percentiles.
        let mut sorted = self.latencies_secs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        format!(
            "== Serve metrics ==\n\
             queries answered   {}\n\
             batches            {}\n\
             failed batches     {}\n\
             supersteps         {}\n\
             edge items read    {}\n\
             wire bytes         {}\n\
             local bytes        {}\n\
             wall time          {}\n\
             throughput         {:.2} queries/s\n\
             latency p50        {}\n\
             latency p95        {}\n\
             latency p99        {}\n",
            self.queries,
            self.batches,
            self.failed_batches,
            self.supersteps,
            self.edge_items_read,
            self.wire_bytes,
            self.local_bytes,
            human_secs(self.wall_secs),
            self.qps(),
            human_secs(percentile_sorted(&sorted, 50.0)),
            human_secs(percentile_sorted(&sorted, 95.0)),
            human_secs(percentile_sorted(&sorted, 99.0)),
        )
    }
}

/// Nearest-rank percentile over unsorted samples (`p` in [0, 100]).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    let mut sorted: Vec<f64> = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    percentile_sorted(&sorted, p)
}

/// Nearest-rank percentile over already-sorted samples.
fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// A rendered table cell: a time, a qualitative refusal, or N/A.
#[derive(Clone, Debug)]
pub enum Cell {
    Secs(f64),
    Text(String),
    NA,
}

impl std::fmt::Display for Cell {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Cell::Secs(s) => write!(f, "{}", human_secs(*s)),
            Cell::Text(t) => write!(f, "{t}"),
            Cell::NA => write!(f, "-"),
        }
    }
}

/// Fixed-width ASCII table renderer for the bench harnesses.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<(String, Vec<Cell>)>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, name: &str, cells: Vec<Cell>) {
        self.rows.push((name.to_string(), cells));
    }

    pub fn render(&self) -> String {
        let mut widths = vec![self
            .rows
            .iter()
            .map(|(n, _)| n.len())
            .chain(std::iter::once(12))
            .max()
            .unwrap_or(12)];
        for (i, h) in self.headers.iter().enumerate() {
            let w = self
                .rows
                .iter()
                .map(|(_, cs)| cs.get(i).map_or(1, |c| c.to_string().len()))
                .chain(std::iter::once(h.len()))
                .max()
                .unwrap_or(h.len());
            widths.push(w);
        }
        let mut out = format!("== {} ==\n", self.title);
        out.push_str(&format!("{:w$}", "", w = widths[0]));
        for (i, h) in self.headers.iter().enumerate() {
            out.push_str(&format!("  {:>w$}", h, w = widths[i + 1]));
        }
        out.push('\n');
        for (name, cells) in &self.rows {
            out.push_str(&format!("{:w$}", name, w = widths[0]));
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!("  {:>w$}", c.to_string(), w = widths[i + 1]));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Load", "Compute"]);
        t.row("IO-Basic", vec![Cell::Secs(628.9), Cell::Secs(1189.0)]);
        t.row(
            "Pregel+",
            vec![Cell::Text("Insufficient Main Memories".into()), Cell::NA],
        );
        let s = t.render();
        assert!(s.contains("IO-Basic"));
        assert!(s.contains("1189 s"));
        assert!(s.contains("Insufficient Main Memories"));
        // all data lines share the same width
        let lines: Vec<&str> = s.lines().skip(1).collect();
        assert!(lines.len() >= 3);
    }

    #[test]
    fn job_metrics_totals() {
        let mut jm = JobMetrics::default();
        jm.machines.push(MachineMetrics {
            machine: 0,
            steps: vec![
                StepMetrics {
                    m_gene_secs: 1.0,
                    m_send_secs: 4.0,
                    msgs_sent: 10,
                    ..Default::default()
                },
                StepMetrics {
                    m_gene_secs: 2.0,
                    m_send_secs: 5.0,
                    msgs_sent: 20,
                    ..Default::default()
                },
            ],
            peak_state_bytes: 1000,
        });
        let (g, s) = jm.m_gene_m_send();
        assert_eq!((g, s), (3.0, 9.0));
        assert_eq!(jm.total_msgs(), 30);
        assert_eq!(jm.peak_state_bytes(), 1000);
    }

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[3.0], 99.0), 3.0);
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0]; // sorted: 1 2 3 4 5
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 95.0), 5.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn serve_metrics_accumulate_and_report() {
        let mut sm = ServeMetrics::default();
        let jm = JobMetrics {
            supersteps: 4,
            machines: vec![MachineMetrics {
                machine: 0,
                steps: vec![StepMetrics {
                    edge_items_read: 100,
                    ..Default::default()
                }],
                peak_state_bytes: 0,
            }],
            ..Default::default()
        };
        sm.record_batch(8, 2.0, &jm);
        sm.record_batch(4, 1.0, &jm);
        sm.latencies_secs.extend([0.5, 1.0, 2.0]);
        assert_eq!(sm.queries, 12);
        assert_eq!(sm.batches, 2);
        assert_eq!(sm.supersteps, 8);
        assert_eq!(sm.edge_items_read, 200);
        assert!((sm.qps() - 4.0).abs() < 1e-9);
        assert_eq!(sm.latency_percentile(50.0), 1.0);
        let r = sm.report();
        assert!(r.contains("queries answered"));
        assert!(r.contains("queries/s"));
        assert!(r.contains("latency p99"));
    }
}
