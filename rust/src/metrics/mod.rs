//! Per-superstep / per-job metrics and paper-style table rendering.
//!
//! Table 4 of the paper splits superstep time into message *generation*
//! (U_c's vertex-centric computation, which includes edge/OMS streaming)
//! and message *sending* (U_s's transmission window) — we account both,
//! plus the I/O counters that justify the skip() design (Tables 7–8).

use crate::util::human_secs;

/// Counters for one superstep on one machine.
#[derive(Clone, Debug, Default)]
pub struct StepMetrics {
    pub step: u64,
    /// U_c time spent generating messages (vertex-centric computation).
    pub m_gene_secs: f64,
    /// U_s active transmission time.
    pub m_send_secs: f64,
    /// Messages/bytes that crossed the (simulated) wire.
    pub msgs_sent: u64,
    pub bytes_sent: u64,
    /// Messages/bytes delivered machine-locally through the fast path —
    /// zero simulated wire time, and zero OMS disk traffic in *both*
    /// shapes: the recoded digest shard and the IO-Basic local spill lane.
    /// Split out from `msgs_sent`/`bytes_sent` so the O(|V|/n)-permitted
    /// saving is visible per superstep in every mode.
    pub local_msgs: u64,
    /// Bytes counterpart of [`Self::local_msgs`].
    pub local_bytes: u64,
    /// Message records U_r received (wire + local lanes).
    pub msgs_recv: u64,
    /// Vertices on which compute()/block update ran.
    pub computed_vertices: u64,
    /// Active vertices after the superstep.
    pub active_after: u64,
    /// Adjacency items actually read from S^E.
    pub edge_items_read: u64,
    /// Adjacency items skipped via skip().
    pub edge_items_skipped: u64,
    /// Adjacency items decoded from the mmap'd resident store
    /// (`-c resident=mmap|auto`): equals [`Self::edge_items_read`] when
    /// the superstep ran mapped, 0 when it streamed `se.bin` — so the
    /// counter doubles as a per-step residency flag.
    pub edge_items_mapped: u64,
    /// Random seeks incurred by skip() (always 0 on a mapped superstep).
    pub seeks: u64,
    /// OMS files closed this superstep.
    pub oms_files: u64,
    /// Wall time this machine's units spent blocked in `Rendezvous`
    /// barriers (`uc_rv`/`ur_rv`/`ckpt_rv` exchanges) this superstep.
    /// Near-zero barrier wait on a balanced multi-machine run is the
    /// measurable form of the paper's "fully overlaps computation with
    /// communication" claim; a large value names the straggler step.
    pub barrier_wait_secs: f64,
    /// Wall time spent blocked in `MachineSync` waits — U_c waiting for
    /// U_r's handoff (`wait_recv_done`) and U_s waiting for the send
    /// gate (`wait_send_allowed`). The intra-machine counterpart of
    /// [`Self::barrier_wait_secs`]: this is pipeline stall, not cluster
    /// skew.
    pub stall_wait_secs: f64,
}

/// Whole-job metrics for one machine.
#[derive(Clone, Debug, Default)]
pub struct MachineMetrics {
    pub machine: usize,
    pub steps: Vec<StepMetrics>,
    /// Peak bytes of in-memory vertex state (A + A_r + A_s).
    pub peak_state_bytes: u64,
}

impl MachineMetrics {
    pub fn total_m_gene(&self) -> f64 {
        self.steps.iter().map(|s| s.m_gene_secs).sum()
    }
    pub fn total_m_send(&self) -> f64 {
        self.steps.iter().map(|s| s.m_send_secs).sum()
    }
    pub fn total_msgs_sent(&self) -> u64 {
        self.steps.iter().map(|s| s.msgs_sent + s.local_msgs).sum()
    }
    /// Messages delivered locally (fast path) across all supersteps.
    pub fn total_local_msgs(&self) -> u64 {
        self.steps.iter().map(|s| s.local_msgs).sum()
    }
    /// Barrier wait across all supersteps (see
    /// [`StepMetrics::barrier_wait_secs`]).
    pub fn total_barrier_wait(&self) -> f64 {
        self.steps.iter().map(|s| s.barrier_wait_secs).sum()
    }
    /// `MachineSync` stall wait across all supersteps (see
    /// [`StepMetrics::stall_wait_secs`]).
    pub fn total_stall_wait(&self) -> f64 {
        self.steps.iter().map(|s| s.stall_wait_secs).sum()
    }
}

/// Aggregated job result timings (one table cell each).
#[derive(Clone, Debug, Default)]
pub struct JobMetrics {
    /// Graph loading wall time (the tables' "Load" column).
    pub load_secs: f64,
    /// Iterative computation wall time (the "Compute" column).
    pub compute_secs: f64,
    /// Preprocessing (ID recoding / sharding) wall time, if any.
    pub preprocess_secs: f64,
    pub supersteps: u64,
    pub machines: Vec<MachineMetrics>,
    /// Bytes that transited the shared switch during the job.
    pub net_wire_bytes: u64,
    /// Bytes delivered machine-locally, bypassing the switch (fast path).
    pub net_local_bytes: u64,
    /// Job-wide [`crate::msg::BufPool`] counters (message-spine buffers).
    pub pool: crate::msg::PoolStats,
    /// Job-wide [`crate::msg::DigestPool`] counters (the ping-pong A_r /
    /// local-shard arrays of recoded digesting).  `hits > 0` on any
    /// multi-superstep digesting run means the O(|V|/n) arrays recycled
    /// instead of reallocating.
    pub digest_pool: crate::msg::PoolStats,
    /// Auto-resume attempts that led to this result (0 on a fault-free
    /// run): how many times `JobBuilder::run` reloaded the last durable
    /// checkpoint and re-ran after a retryable failure (§3.4).
    pub recoveries: u64,
    /// Supersteps re-run across all recoveries — the failure superstep
    /// minus the resumed-from checkpoint, summed per retry.  The paper's
    /// recovery cost; fast-replay makes these cheaper, not fewer.
    pub retried_supersteps: u64,
}

impl JobMetrics {
    /// Machine-0 totals, as reported in the paper's Table 4.
    pub fn m_gene_m_send(&self) -> (f64, f64) {
        match self.machines.first() {
            Some(m) => (m.total_m_gene(), m.total_m_send()),
            None => (0.0, 0.0),
        }
    }

    pub fn total_msgs(&self) -> u64 {
        self.machines.iter().map(|m| m.total_msgs_sent()).sum()
    }

    pub fn peak_state_bytes(&self) -> u64 {
        self.machines
            .iter()
            .map(|m| m.peak_state_bytes)
            .max()
            .unwrap_or(0)
    }

    /// Barrier wait summed over every machine and superstep.
    pub fn barrier_wait_secs(&self) -> f64 {
        self.machines.iter().map(|m| m.total_barrier_wait()).sum()
    }

    /// `MachineSync` stall wait summed over every machine and superstep.
    pub fn stall_wait_secs(&self) -> f64 {
        self.machines.iter().map(|m| m.total_stall_wait()).sum()
    }

    /// Machine-readable form for the `bench::bench_json_*` writers and
    /// the CLI's `GRAPHD_BENCH_JSON` emission. Flat JSON object; schema
    /// (all numbers):
    ///
    /// ```json
    /// {"load_secs": f, "compute_secs": f, "preprocess_secs": f,
    ///  "supersteps": n, "machines": n,
    ///  "net_wire_bytes": n, "net_local_bytes": n,
    ///  "total_msgs": n, "peak_state_bytes": n,
    ///  "m_gene_secs": f, "m_send_secs": f,
    ///  "barrier_wait_secs": f, "stall_wait_secs": f,
    ///  "pool_hits": n, "pool_misses": n,
    ///  "digest_pool_hits": n, "digest_pool_misses": n,
    ///  "recoveries": n, "retried_supersteps": n}
    /// ```
    ///
    /// `m_gene_secs`/`m_send_secs` are the machine-0 Table-4 totals
    /// ([`Self::m_gene_m_send`]); the wait counters are job-wide sums.
    pub fn to_json(&self) -> String {
        let (g, s) = self.m_gene_m_send();
        format!(
            "{{\"load_secs\": {}, \"compute_secs\": {}, \"preprocess_secs\": {}, \
             \"supersteps\": {}, \"machines\": {}, \
             \"net_wire_bytes\": {}, \"net_local_bytes\": {}, \
             \"total_msgs\": {}, \"peak_state_bytes\": {}, \
             \"m_gene_secs\": {}, \"m_send_secs\": {}, \
             \"barrier_wait_secs\": {}, \"stall_wait_secs\": {}, \
             \"pool_hits\": {}, \"pool_misses\": {}, \
             \"digest_pool_hits\": {}, \"digest_pool_misses\": {}, \
             \"recoveries\": {}, \"retried_supersteps\": {}}}",
            json_f64(self.load_secs),
            json_f64(self.compute_secs),
            json_f64(self.preprocess_secs),
            self.supersteps,
            self.machines.len(),
            self.net_wire_bytes,
            self.net_local_bytes,
            self.total_msgs(),
            self.peak_state_bytes(),
            json_f64(g),
            json_f64(s),
            json_f64(self.barrier_wait_secs()),
            json_f64(self.stall_wait_secs()),
            self.pool.hits,
            self.pool.misses,
            self.digest_pool.hits,
            self.digest_pool.misses,
            self.recoveries,
            self.retried_supersteps,
        )
    }
}

/// Render an `f64` as a JSON number (JSON has no NaN/∞ — they collapse
/// to 0, which no metric legitimately produces as NaN anyway).
fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "0".to_string()
    }
}

/// Serve-mode counters (the `crate::serve` query server): how many
/// queries were answered, at what rate, and the per-query latency
/// distribution.  Rendered as a self-describing text report so bench
/// output explains itself.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// Queries answered (excluding rejected/unknown-vertex queries).
    pub queries: u64,
    /// Shared superstep-loop batches run.
    pub batches: u64,
    /// Batches whose job died (`Answer::Failed` queries): the failure is
    /// isolated to the batch, the server keeps serving.
    pub failed_batches: u64,
    /// Batches whose first run failed with a *retryable* cause but whose
    /// one in-place retry succeeded — the queries got answers, not
    /// `Answer::Failed`, and `failed_batches` was not bumped.
    pub recovered_batches: u64,
    /// Total serving wall time across batches (seconds).
    pub wall_secs: f64,
    /// Supersteps summed over batches.
    pub supersteps: u64,
    /// Adjacency items streamed from `S^E`, summed over machines/batches —
    /// the I/O the k-lane batching amortises.
    pub edge_items_read: u64,
    /// Bytes through the shared switch, summed over batches.
    pub wire_bytes: u64,
    /// Bytes delivered machine-locally (fast path), summed over batches.
    pub local_bytes: u64,
    /// Per-query latency samples (submit → answered), seconds.
    pub latencies_secs: Vec<f64>,
}

impl ServeMetrics {
    /// Fold one batch's accounting in.
    pub fn record_batch(&mut self, queries: u64, wall_secs: f64, job: &JobMetrics) {
        self.queries += queries;
        self.batches += 1;
        self.wall_secs += wall_secs;
        self.supersteps += job.supersteps;
        self.edge_items_read += job
            .machines
            .iter()
            .flat_map(|m| m.steps.iter())
            .map(|s| s.edge_items_read)
            .sum::<u64>();
        self.wire_bytes += job.net_wire_bytes;
        self.local_bytes += job.net_local_bytes;
    }

    /// Queries per second of serving wall time.
    pub fn qps(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.queries as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// Latency percentile in seconds (`p` in [0, 100]); 0.0 when empty.
    /// For several percentiles over the same samples, take one
    /// [`Self::latency_snapshot`] and query it instead — this sorts per
    /// call.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        self.latency_snapshot().percentile(p)
    }

    /// Sort the latency samples once; the snapshot answers any number of
    /// percentile queries without re-sorting (used by [`Self::report`],
    /// [`Self::to_json`], and the serve `stats()` path).
    pub fn latency_snapshot(&self) -> LatencySnapshot {
        LatencySnapshot::new(&self.latencies_secs)
    }

    /// The self-describing text report (bench + CLI output).
    pub fn report(&self) -> String {
        // One sort serves all three percentiles.
        let lat = self.latency_snapshot();
        format!(
            "== Serve metrics ==\n\
             queries answered   {}\n\
             batches            {}\n\
             failed batches     {}\n\
             recovered batches  {}\n\
             supersteps         {}\n\
             edge items read    {}\n\
             wire bytes         {}\n\
             local bytes        {}\n\
             wall time          {}\n\
             throughput         {:.2} queries/s\n\
             latency p50        {}\n\
             latency p95        {}\n\
             latency p99        {}\n",
            self.queries,
            self.batches,
            self.failed_batches,
            self.recovered_batches,
            self.supersteps,
            self.edge_items_read,
            self.wire_bytes,
            self.local_bytes,
            human_secs(self.wall_secs),
            self.qps(),
            human_secs(lat.percentile(50.0)),
            human_secs(lat.percentile(95.0)),
            human_secs(lat.percentile(99.0)),
        )
    }

    /// Machine-readable form for the `bench::bench_json_*` writers and
    /// the CLI's `GRAPHD_BENCH_JSON` emission. Flat JSON object; schema
    /// (all numbers):
    ///
    /// ```json
    /// {"queries": n, "batches": n, "failed_batches": n,
    ///  "recovered_batches": n, "supersteps": n,
    ///  "edge_items_read": n, "wire_bytes": n, "local_bytes": n,
    ///  "wall_secs": f, "qps": f,
    ///  "p50_secs": f, "p95_secs": f, "p99_secs": f}
    /// ```
    pub fn to_json(&self) -> String {
        let lat = self.latency_snapshot();
        format!(
            "{{\"queries\": {}, \"batches\": {}, \"failed_batches\": {}, \
             \"recovered_batches\": {}, \
             \"supersteps\": {}, \"edge_items_read\": {}, \
             \"wire_bytes\": {}, \"local_bytes\": {}, \
             \"wall_secs\": {}, \"qps\": {}, \
             \"p50_secs\": {}, \"p95_secs\": {}, \"p99_secs\": {}}}",
            self.queries,
            self.batches,
            self.failed_batches,
            self.recovered_batches,
            self.supersteps,
            self.edge_items_read,
            self.wire_bytes,
            self.local_bytes,
            json_f64(self.wall_secs),
            json_f64(self.qps()),
            json_f64(lat.percentile(50.0)),
            json_f64(lat.percentile(95.0)),
            json_f64(lat.percentile(99.0)),
        )
    }
}

/// Sorted-once percentile snapshot: the single place latency samples get
/// sorted. [`ServeMetrics::report`], [`ServeMetrics::latency_percentile`],
/// and the serve `stats()` snapshot all query one of these instead of
/// each keeping a private sort (the pre-PR 7 duplication).
#[derive(Clone, Debug)]
pub struct LatencySnapshot {
    sorted: Vec<f64>,
}

impl LatencySnapshot {
    /// Sort `samples` once (NaNs order as equal — no metric emits them).
    pub fn new(samples: &[f64]) -> Self {
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        Self { sorted }
    }

    /// Nearest-rank percentile (`p` in [0, 100]); 0.0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.sorted.len() as f64).ceil() as usize;
        self.sorted[rank.clamp(1, self.sorted.len()) - 1]
    }

    /// Number of samples behind the snapshot.
    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// True when there are no samples.
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }
}

/// Nearest-rank percentile over unsorted samples (`p` in [0, 100]).
/// One-shot convenience over [`LatencySnapshot`].
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    LatencySnapshot::new(samples).percentile(p)
}

/// A rendered table cell: a time, a qualitative refusal, or N/A.
#[derive(Clone, Debug)]
pub enum Cell {
    Secs(f64),
    Text(String),
    NA,
}

impl std::fmt::Display for Cell {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Cell::Secs(s) => write!(f, "{}", human_secs(*s)),
            Cell::Text(t) => write!(f, "{t}"),
            Cell::NA => write!(f, "-"),
        }
    }
}

/// Fixed-width ASCII table renderer for the bench harnesses.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<(String, Vec<Cell>)>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, name: &str, cells: Vec<Cell>) {
        self.rows.push((name.to_string(), cells));
    }

    pub fn render(&self) -> String {
        let mut widths = vec![self
            .rows
            .iter()
            .map(|(n, _)| n.len())
            .chain(std::iter::once(12))
            .max()
            .unwrap_or(12)];
        for (i, h) in self.headers.iter().enumerate() {
            let w = self
                .rows
                .iter()
                .map(|(_, cs)| cs.get(i).map_or(1, |c| c.to_string().len()))
                .chain(std::iter::once(h.len()))
                .max()
                .unwrap_or(h.len());
            widths.push(w);
        }
        let mut out = format!("== {} ==\n", self.title);
        out.push_str(&format!("{:w$}", "", w = widths[0]));
        for (i, h) in self.headers.iter().enumerate() {
            out.push_str(&format!("  {:>w$}", h, w = widths[i + 1]));
        }
        out.push('\n');
        for (name, cells) in &self.rows {
            out.push_str(&format!("{:w$}", name, w = widths[0]));
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!("  {:>w$}", c.to_string(), w = widths[i + 1]));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Load", "Compute"]);
        t.row("IO-Basic", vec![Cell::Secs(628.9), Cell::Secs(1189.0)]);
        t.row(
            "Pregel+",
            vec![Cell::Text("Insufficient Main Memories".into()), Cell::NA],
        );
        let s = t.render();
        assert!(s.contains("IO-Basic"));
        assert!(s.contains("1189 s"));
        assert!(s.contains("Insufficient Main Memories"));
        // all data lines share the same width
        let lines: Vec<&str> = s.lines().skip(1).collect();
        assert!(lines.len() >= 3);
    }

    #[test]
    fn job_metrics_totals() {
        let mut jm = JobMetrics::default();
        jm.machines.push(MachineMetrics {
            machine: 0,
            steps: vec![
                StepMetrics {
                    m_gene_secs: 1.0,
                    m_send_secs: 4.0,
                    msgs_sent: 10,
                    ..Default::default()
                },
                StepMetrics {
                    m_gene_secs: 2.0,
                    m_send_secs: 5.0,
                    msgs_sent: 20,
                    barrier_wait_secs: 0.25,
                    stall_wait_secs: 0.5,
                    ..Default::default()
                },
            ],
            peak_state_bytes: 1000,
        });
        let (g, s) = jm.m_gene_m_send();
        assert_eq!((g, s), (3.0, 9.0));
        assert_eq!(jm.total_msgs(), 30);
        assert_eq!(jm.peak_state_bytes(), 1000);
        assert_eq!(jm.barrier_wait_secs(), 0.25);
        assert_eq!(jm.stall_wait_secs(), 0.5);
    }

    #[test]
    fn job_and_serve_json_are_flat_objects() {
        let jm = JobMetrics {
            supersteps: 3,
            net_wire_bytes: 64,
            recoveries: 1,
            retried_supersteps: 2,
            ..Default::default()
        };
        let j = jm.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'), "{j}");
        assert!(j.contains("\"supersteps\": 3"), "{j}");
        assert!(j.contains("\"net_wire_bytes\": 64"), "{j}");
        assert!(j.contains("\"barrier_wait_secs\": 0"), "{j}");
        assert!(j.contains("\"recoveries\": 1"), "{j}");
        assert!(j.contains("\"retried_supersteps\": 2"), "{j}");
        let sm = ServeMetrics {
            queries: 5,
            wall_secs: 2.5,
            recovered_batches: 1,
            latencies_secs: vec![0.5, 1.0],
            ..Default::default()
        };
        let s = sm.to_json();
        assert!(s.contains("\"queries\": 5"), "{s}");
        assert!(s.contains("\"recovered_batches\": 1"), "{s}");
        assert!(s.contains("\"qps\": 2"), "{s}");
        assert!(s.contains("\"p99_secs\": 1"), "{s}");
    }

    #[test]
    fn latency_snapshot_sorts_once_and_matches_percentile() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        let snap = LatencySnapshot::new(&xs);
        assert_eq!(snap.len(), 5);
        for p in [0.0, 50.0, 95.0, 100.0] {
            assert_eq!(snap.percentile(p), percentile(&xs, p));
        }
        assert_eq!(LatencySnapshot::new(&[]).percentile(50.0), 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[3.0], 99.0), 3.0);
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0]; // sorted: 1 2 3 4 5
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 95.0), 5.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn serve_metrics_accumulate_and_report() {
        let mut sm = ServeMetrics::default();
        let jm = JobMetrics {
            supersteps: 4,
            machines: vec![MachineMetrics {
                machine: 0,
                steps: vec![StepMetrics {
                    edge_items_read: 100,
                    ..Default::default()
                }],
                peak_state_bytes: 0,
            }],
            ..Default::default()
        };
        sm.record_batch(8, 2.0, &jm);
        sm.record_batch(4, 1.0, &jm);
        sm.latencies_secs.extend([0.5, 1.0, 2.0]);
        assert_eq!(sm.queries, 12);
        assert_eq!(sm.batches, 2);
        assert_eq!(sm.supersteps, 8);
        assert_eq!(sm.edge_items_read, 200);
        assert!((sm.qps() - 4.0).abs() < 1e-9);
        assert_eq!(sm.latency_percentile(50.0), 1.0);
        let r = sm.report();
        assert!(r.contains("queries answered"));
        assert!(r.contains("queries/s"));
        assert!(r.contains("latency p99"));
    }
}
