//! The paper's evaluation algorithms as [`crate::api::VertexProgram`]s:
//!
//! * [`pagerank::PageRank`] — §2.1's running example; SUM combiner; dense
//!   workload every superstep (Tables 2–4).
//! * [`hashmin::HashMin`] — connected components of [23]; MIN combiner;
//!   workload turns sparse as labels converge (Tables 5–6).
//! * [`sssp::Sssp`] — single-source shortest paths (BFS with unit
//!   weights); MIN combiner; sparse frontier every superstep — the
//!   hardest case for out-of-core systems (Tables 7–8).
//! * [`triangle::TriangleCount`] — the O(|E|^1.5)-message algorithm of
//!   [13] §3.1; *no* combiner (exercises the sorted-IMS path) and a global
//!   SUM aggregator.
//! * [`multisource::MultiSssp`] — K-lane multi-source BFS/SSSP with
//!   per-lane targets and early termination; element-wise MIN combiner.
//!   The vertex program behind the [`crate::serve`] query server.
//!
//! PageRank/Hash-Min/SSSP also implement `block_update`, the vectorized
//! form executed on the AOT-compiled Pallas kernels in recoded mode.

pub mod hashmin;
pub mod multisource;
pub mod pagerank;
pub mod sssp;
pub mod triangle;

pub use hashmin::HashMin;
pub use multisource::{LaneBounds, MultiSssp, NO_VERTEX};
pub use pagerank::{PageRank, PageRankConverge};
pub use sssp::Sssp;
pub use triangle::TriangleCount;
