//! Hash-Min connected components ([23], §6 "Performance of Hash-Min"):
//! every vertex repeatedly adopts the minimum label seen; labels converge
//! to the minimum vertex ID of each component.

use crate::api::{BlockCtx, Context, Edge, MinI32, VertexProgram};
use crate::runtime::KernelSet;

/// Hash-Min over an undirected graph.  MIN combiner, i32 labels
/// (current-ID space — components are invariant under relabeling).
pub struct HashMin;

impl VertexProgram for HashMin {
    type Value = i32;
    type Msg = i32;
    type Agg = ();
    type Comb = MinI32;

    fn init_value(&self, id: u32, _deg: u32, _nv: u64) -> i32 {
        id as i32
    }

    fn compute(
        &self,
        ctx: &mut Context<'_, i32, ()>,
        _id: u32,
        value: &mut i32,
        edges: &[Edge],
        msgs: &[i32],
    ) {
        if ctx.superstep == 0 {
            // Announce own label.
            for e in edges {
                ctx.send(e.nbr, *value);
            }
        } else {
            let best = msgs.iter().copied().min().unwrap_or(i32::MAX);
            if best < *value {
                *value = best;
                for e in edges {
                    ctx.send(e.nbr, best);
                }
            }
        }
        ctx.vote_to_halt();
    }

    /// Monotone: only a strictly smaller label changes a halted vertex.
    fn reactivates(&self, value: &i32, msgs: &[i32]) -> bool {
        msgs.iter().any(|m| m < value)
    }

    fn block_update(&self, kern: &KernelSet, b: &mut BlockCtx<'_, Self>) -> crate::Result<bool> {
        let local = b.vals.len();
        if b.superstep == 0 {
            for pos in 0..local {
                if b.degs[pos] > 0 {
                    b.out_base[pos] = Some(b.vals[pos]);
                }
            }
            b.halted.set_all();
            return Ok(true);
        }
        let (new, chg) = kern.minrelax_i32(b.vals, b.sums)?;
        b.vals.copy_from_slice(&new);
        for pos in 0..local {
            if chg[pos] != 0 {
                b.out_base[pos] = Some(new[pos]);
            }
        }
        b.halted.set_all();
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_compute(
        p: &HashMin,
        step: u64,
        val: &mut i32,
        edges: &[Edge],
        msgs: &[i32],
    ) -> Vec<(u32, i32)> {
        let mut sent = Vec::new();
        let mut send = |t: u32, m: i32| sent.push((t, m));
        let mut la = ();
        let mut ctx: Context<'_, i32, ()> = Context::new(step, 10, &(), &mut la, &mut send);
        p.compute(&mut ctx, 0, val, edges, msgs);
        assert!(ctx.halt);
        sent
    }

    #[test]
    fn announces_then_adopts_min() {
        let p = HashMin;
        let mut val = 7i32;
        let edges = [Edge { nbr: 3, weight: 1.0 }];
        assert_eq!(run_compute(&p, 0, &mut val, &edges, &[]), vec![(3, 7)]);
        // better label arrives
        assert_eq!(run_compute(&p, 1, &mut val, &edges, &[2, 5]), vec![(3, 2)]);
        assert_eq!(val, 2);
        // worse label: silent
        assert!(run_compute(&p, 2, &mut val, &edges, &[4]).is_empty());
        assert_eq!(val, 2);
    }

    #[test]
    fn block_update_step0_announces_nonisolated() {
        use crate::util::bitset::BitSet;
        let p = HashMin;
        let kern = KernelSet::native_only();
        let mut vals = vec![0i32, 1, 2];
        let degs = [1u32, 0, 2];
        let sums = vec![i32::MAX; 3];
        let mut halted = BitSet::new(3);
        let mut out = vec![None; 3];
        let mut la = ();
        let mut b = BlockCtx::<HashMin> {
            superstep: 0,
            num_vertices: 3,
            vals: &mut vals,
            degs: &degs,
            sums: &sums,
            halted: &mut halted,
            out_base: &mut out,
            global_agg: &(),
            local_agg: &mut la,
        };
        assert!(p.block_update(&kern, &mut b).unwrap());
        assert_eq!(out, vec![Some(0), None, Some(2)]);
    }
}
