//! PageRank (§2.1): `a(v) = 0.15/|V| + 0.85·Σ msgs`, messages `a(v)/d(v)`.
//! Runs a fixed number of supersteps (the paper uses 10, 5 on ClueWeb).

use crate::api::{BlockCtx, Context, Edge, SumF32, VertexProgram};
use crate::runtime::KernelSet;

/// Fixed-iteration PageRank with SUM combiner + XLA block update.
pub struct PageRank {
    /// Total supersteps to run (compute steps; set engine
    /// `max_supersteps` to the same value).
    pub supersteps: u64,
}

impl PageRank {
    pub fn new(supersteps: u64) -> Self {
        Self { supersteps }
    }
}

impl VertexProgram for PageRank {
    type Value = f32;
    type Msg = f32;
    type Agg = ();
    type Comb = SumF32;

    fn init_value(&self, _id: u32, _deg: u32, nv: u64) -> f32 {
        1.0 / nv as f32
    }

    fn compute(
        &self,
        ctx: &mut Context<'_, f32, ()>,
        _id: u32,
        value: &mut f32,
        edges: &[Edge],
        msgs: &[f32],
    ) {
        if ctx.superstep > 0 {
            let sum: f32 = msgs.iter().sum();
            *value = 0.15 / ctx.num_vertices as f32 + 0.85 * sum;
        }
        if !edges.is_empty() {
            let share = *value / edges.len() as f32;
            for e in edges {
                ctx.send(e.nbr, share);
            }
        }
        // Never votes halt: termination is the superstep cap, as in the
        // paper's fixed-iteration runs.
    }

    fn block_update(&self, kern: &KernelSet, b: &mut BlockCtx<'_, Self>) -> crate::Result<bool> {
        let local = b.vals.len();
        if b.superstep == 0 {
            // Distribute the initial rank; values were set by init_value.
            for pos in 0..local {
                let d = b.degs[pos];
                b.out_base[pos] = (d > 0).then(|| b.vals[pos] / d as f32);
            }
            return Ok(true);
        }
        // sums == A_r with identity 0 where nothing was received — exactly
        // the kernel's contract. This is the XLA hot path.
        let degs_f: Vec<f32> = b.degs.iter().map(|&d| d as f32).collect();
        let inv_n = 1.0 / b.num_vertices as f32;
        let (vals, msg) = kern.pagerank_update(b.sums, &degs_f, inv_n)?;
        b.vals.copy_from_slice(&vals);
        for pos in 0..local {
            b.out_base[pos] = (b.degs[pos] > 0).then(|| msg[pos]);
        }
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_matches_formula() {
        let pr = PageRank::new(10);
        let mut sent: Vec<(u32, f32)> = Vec::new();
        let mut val = 0.5f32;
        let halted;
        {
            let mut send = |t: u32, m: f32| sent.push((t, m));
            let mut la = ();
            let mut ctx: Context<'_, f32, ()> = Context::new(3, 100, &(), &mut la, &mut send);
            let edges = [Edge { nbr: 7, weight: 1.0 }, Edge { nbr: 9, weight: 1.0 }];
            pr.compute(&mut ctx, 1, &mut val, &edges, &[0.1, 0.2]);
            halted = ctx.halt;
        }
        let want = 0.15 / 100.0 + 0.85 * 0.3;
        assert!((val - want).abs() < 1e-6);
        assert_eq!(sent.len(), 2);
        assert!((sent[0].1 - want / 2.0).abs() < 1e-6);
        assert!(!halted);
    }

    #[test]
    fn step0_distributes_initial_rank() {
        let pr = PageRank::new(10);
        let mut sent: Vec<(u32, f32)> = Vec::new();
        let mut val = pr.init_value(0, 1, 4);
        {
            let mut send = |t: u32, m: f32| sent.push((t, m));
            let mut la = ();
            let mut ctx: Context<'_, f32, ()> = Context::new(0, 4, &(), &mut la, &mut send);
            pr.compute(&mut ctx, 0, &mut val, &[Edge { nbr: 1, weight: 1.0 }], &[]);
        }
        assert_eq!(val, 0.25);
        assert_eq!(sent, vec![(1, 0.25)]);
    }

    #[test]
    fn block_update_matches_compute() {
        use crate::util::bitset::BitSet;
        let pr = PageRank::new(10);
        let kern = KernelSet::native_only();
        let n = 6usize;
        let mut vals = vec![1.0 / n as f32; n];
        let degs = vec![2u32, 0, 1, 3, 1, 2];
        let sums = vec![0.0f32, 0.1, 0.2, 0.0, 0.3, 0.05];
        let mut halted = BitSet::new(n);
        let mut out = vec![None; n];
        let mut la = ();
        let mut b = BlockCtx::<PageRank> {
            superstep: 2,
            num_vertices: n as u64,
            vals: &mut vals,
            degs: &degs,
            sums: &sums,
            halted: &mut halted,
            out_base: &mut out,
            global_agg: &(),
            local_agg: &mut la,
        };
        assert!(pr.block_update(&kern, &mut b).unwrap());
        for pos in 0..n {
            let want = 0.15 / 6.0 + 0.85 * sums[pos];
            assert!((vals[pos] - want).abs() < 1e-6, "pos {pos}");
            match out[pos] {
                Some(m) => assert!((m - want / degs[pos] as f32).abs() < 1e-6),
                None => assert_eq!(degs[pos], 0),
            }
        }
    }
}

/// PageRank variant that terminates by *convergence* instead of a fixed
/// superstep count, using Pregel's aggregator (§2.1): each vertex
/// aggregates |Δa(v)|; when the global L1 delta of a superstep falls below
/// `epsilon`, every vertex votes to halt and (with no messages pending)
/// the job stops.  Exercises the aggregator broadcast path end-to-end.
pub struct PageRankConverge {
    pub epsilon: f32,
}

impl VertexProgram for PageRankConverge {
    type Value = f32;
    type Msg = f32;
    /// Σ |Δ rank| of the previous superstep.
    type Agg = f32;
    type Comb = SumF32;

    fn init_value(&self, _id: u32, _deg: u32, nv: u64) -> f32 {
        1.0 / nv as f32
    }

    fn compute(
        &self,
        ctx: &mut Context<'_, f32, f32>,
        _id: u32,
        value: &mut f32,
        edges: &[Edge],
        msgs: &[f32],
    ) {
        if ctx.superstep > 0 {
            let sum: f32 = msgs.iter().sum();
            let new = 0.15 / ctx.num_vertices as f32 + 0.85 * sum;
            *ctx.local_agg += (new - *value).abs();
            *value = new;
            // Converged globally in the previous superstep? Stop sending.
            if ctx.superstep >= 2 && *ctx.global_agg < self.epsilon {
                ctx.vote_to_halt();
                return;
            }
        }
        if !edges.is_empty() {
            let share = *value / edges.len() as f32;
            for e in edges {
                ctx.send(e.nbr, share);
            }
        }
    }

    fn merge_agg(&self, a: &mut f32, b: &f32) {
        *a += *b;
    }
}

#[cfg(test)]
mod converge_tests {
    use super::*;

    #[test]
    fn halts_once_global_delta_small() {
        let p = PageRankConverge { epsilon: 1e-3 };
        let mut sent: Vec<(u32, f32)> = Vec::new();
        let mut val = 0.25f32;
        let halted;
        {
            let mut send = |t: u32, m: f32| sent.push((t, m));
            let mut la = 0.0f32;
            let global = 1e-6f32; // already converged
            let mut ctx: Context<'_, f32, f32> =
                Context::new(3, 4, &global, &mut la, &mut send);
            p.compute(
                &mut ctx,
                0,
                &mut val,
                &[Edge { nbr: 1, weight: 1.0 }],
                &[0.25],
            );
            halted = ctx.halt;
        }
        assert!(halted);
        assert!(sent.is_empty());
    }

    #[test]
    fn keeps_running_while_delta_large() {
        let p = PageRankConverge { epsilon: 1e-6 };
        let mut sent: Vec<(u32, f32)> = Vec::new();
        let mut val = 0.25f32;
        {
            let mut send = |t: u32, m: f32| sent.push((t, m));
            let mut la = 0.0f32;
            let global = 0.5f32; // far from converged
            let mut ctx: Context<'_, f32, f32> =
                Context::new(3, 4, &global, &mut la, &mut send);
            p.compute(
                &mut ctx,
                0,
                &mut val,
                &[Edge { nbr: 1, weight: 1.0 }],
                &[0.1],
            );
            assert!(!ctx.halt);
            assert!(la > 0.0, "delta aggregated");
        }
        assert_eq!(sent.len(), 1);
    }
}
