//! Triangle counting ([13], discussed in §3.1): to confirm a triangle
//! `△ v1 v2 v3` with `v1 < v2 < v3`, `v1` sends `v2` a message asking
//! whether `v3 ∈ Γ(v2)`.  Message volume is O(Σ d(v)²) ⊇ O(|E|^1.5) —
//! the paper's example of |M| ≫ |E|.  No combiner applies (each query is
//! distinct), so this exercises the sorted-IMS path; the count is
//! accumulated through the global aggregator.

use crate::api::{Context, Edge, NoCombiner, VertexProgram};

/// Undirected triangle counting with a SUM aggregator.
pub struct TriangleCount;

impl VertexProgram for TriangleCount {
    type Value = u64; // per-vertex confirmed count (diagnostic)
    type Msg = u32; // the candidate third vertex v3
    type Agg = u64; // global triangle count
    type Comb = NoCombiner; // each membership query is distinct

    fn init_value(&self, _id: u32, _deg: u32, _nv: u64) -> u64 {
        0
    }

    fn compute(
        &self,
        ctx: &mut Context<'_, u32, u64>,
        id: u32,
        value: &mut u64,
        edges: &[Edge],
        msgs: &[u32],
    ) {
        match ctx.superstep {
            0 => {
                // Query: for each neighbor pair (u, w) with id < u < w,
                // ask u whether w ∈ Γ(u).
                let mut nbrs: Vec<u32> =
                    edges.iter().map(|e| e.nbr).filter(|&u| u > id).collect();
                nbrs.sort_unstable();
                for (k, &u) in nbrs.iter().enumerate() {
                    for &w in &nbrs[k + 1..] {
                        ctx.send(u, w);
                    }
                }
            }
            1 => {
                // Answer: membership test against own adjacency list.
                let mut nbrs: Vec<u32> = edges.iter().map(|e| e.nbr).collect();
                nbrs.sort_unstable();
                let mut hits = 0u64;
                for &w in msgs {
                    if nbrs.binary_search(&w).is_ok() {
                        hits += 1;
                    }
                }
                *value += hits;
                *ctx.local_agg += hits;
            }
            _ => {}
        }
        ctx.vote_to_halt();
    }

    fn merge_agg(&self, a: &mut u64, b: &u64) {
        *a += *b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn edges_of(nbrs: &[u32]) -> Vec<Edge> {
        nbrs.iter().map(|&n| Edge { nbr: n, weight: 1.0 }).collect()
    }

    #[test]
    fn step0_emits_ordered_pairs() {
        let p = TriangleCount;
        let mut sent = Vec::new();
        let mut send = |t: u32, m: u32| sent.push((t, m));
        let mut la = 0u64;
        let mut ctx: Context<'_, u32, u64> = Context::new(0, 10, &0, &mut la, &mut send);
        let mut v = 0u64;
        // vertex 1 with neighbors {0, 2, 3, 4}: pairs above 1: (2,3),(2,4),(3,4)
        p.compute(&mut ctx, 1, &mut v, &edges_of(&[0, 2, 3, 4]), &[]);
        assert_eq!(sent, vec![(2, 3), (2, 4), (3, 4)]);
    }

    #[test]
    fn step1_counts_hits_into_aggregator() {
        let p = TriangleCount;
        let mut sent = Vec::new();
        let mut send = |t: u32, m: u32| sent.push((t, m));
        let mut la = 0u64;
        let mut ctx: Context<'_, u32, u64> = Context::new(1, 10, &0, &mut la, &mut send);
        let mut v = 0u64;
        // Γ(2) = {1, 3, 5}; queries {3, 4, 5} -> hits 3 and 5
        p.compute(&mut ctx, 2, &mut v, &edges_of(&[1, 3, 5]), &[3, 4, 5]);
        assert_eq!(v, 2);
        assert_eq!(la, 2);
        assert!(sent.is_empty());
    }

    #[test]
    fn merge_agg_sums() {
        let p = TriangleCount;
        let mut a = 3u64;
        p.merge_agg(&mut a, &4);
        assert_eq!(a, 7);
    }
}
