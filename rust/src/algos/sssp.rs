//! Single-source shortest paths (§6 "Performance of SSSP").  With unit
//! edge weights this is BFS — the paper's hardest workload for out-of-core
//! systems because every superstep touches only the frontier.

use crate::api::{BlockCtx, Context, Edge, MinF32, VertexProgram};
use crate::runtime::KernelSet;

/// SSSP from `source` (current-ID space).  MIN combiner; vertices halt
/// every superstep and are reactivated by shorter-distance messages.
pub struct Sssp {
    pub source: u32,
}

impl Sssp {
    pub fn new(source: u32) -> Self {
        Self { source }
    }
}

impl VertexProgram for Sssp {
    type Value = f32;
    type Msg = f32;
    type Agg = ();
    type Comb = MinF32;

    fn init_value(&self, id: u32, _deg: u32, _nv: u64) -> f32 {
        if id == self.source {
            0.0
        } else {
            f32::INFINITY
        }
    }

    fn initially_active(&self, id: u32) -> bool {
        id == self.source
    }

    fn compute(
        &self,
        ctx: &mut Context<'_, f32, ()>,
        _id: u32,
        value: &mut f32,
        edges: &[Edge],
        msgs: &[f32],
    ) {
        let best = msgs.iter().copied().fold(f32::INFINITY, f32::min);
        let improved = best < *value;
        if improved {
            *value = best;
        }
        // Relax out-edges on first activation (superstep 0, source) or on
        // any improvement.
        if ctx.superstep == 0 || improved {
            for e in edges {
                ctx.send(e.nbr, *value + e.weight);
            }
        }
        ctx.vote_to_halt();
    }

    /// Monotone: a halted vertex only changes if some message beats its
    /// tentative distance — otherwise the engine may skip it (and its
    /// adjacency read) outright.
    fn reactivates(&self, value: &f32, msgs: &[f32]) -> bool {
        msgs.iter().any(|m| m < value)
    }

    fn block_update(&self, kern: &KernelSet, b: &mut BlockCtx<'_, Self>) -> crate::Result<bool> {
        let local = b.vals.len();
        if b.superstep == 0 {
            for pos in 0..local {
                // Only the source emits; everyone is halted afterwards.
                if b.vals[pos] == 0.0 && !b.halted.get(pos) {
                    b.out_base[pos] = Some(0.0);
                }
            }
            b.halted.set_all();
            return Ok(true);
        }
        let (new, chg) = kern.minrelax_f32(b.vals, b.sums)?;
        b.vals.copy_from_slice(&new);
        for pos in 0..local {
            if chg[pos] != 0 {
                b.out_base[pos] = Some(new[pos]);
            }
        }
        b.halted.set_all();
        Ok(true)
    }

    /// Relaxation adds the edge weight at fan-out time.
    fn emit(&self, base: &f32, edges: &[Edge], send: &mut dyn FnMut(u32, f32)) {
        for e in edges {
            send(e.nbr, *base + e.weight);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn source_relaxes_at_step0() {
        let p = Sssp::new(3);
        assert!(p.initially_active(3));
        assert!(!p.initially_active(4));
        assert_eq!(p.init_value(3, 0, 10), 0.0);
        assert!(p.init_value(4, 0, 10).is_infinite());

        let mut sent = Vec::new();
        let mut val = 0.0f32;
        let halted;
        {
            let mut send = |t: u32, m: f32| sent.push((t, m));
            let mut la = ();
            let mut ctx: Context<'_, f32, ()> = Context::new(0, 10, &(), &mut la, &mut send);
            p.compute(
                &mut ctx,
                3,
                &mut val,
                &[Edge { nbr: 5, weight: 2.0 }],
                &[],
            );
            halted = ctx.halt;
        }
        assert_eq!(sent, vec![(5, 2.0)]);
        assert!(halted);
    }

    #[test]
    fn improvement_propagates_regression_does_not() {
        let p = Sssp::new(0);
        let mut sent = Vec::new();
        let mut send = |t: u32, m: f32| sent.push((t, m));
        let mut la = ();
        let mut ctx: Context<'_, f32, ()> = Context::new(2, 10, &(), &mut la, &mut send);
        let mut val = 5.0f32;
        let edges = [Edge { nbr: 9, weight: 1.5 }];
        p.compute(&mut ctx, 4, &mut val, &edges, &[7.0]); // worse
        assert_eq!(val, 5.0);
        assert!(sent.is_empty());
        let mut send2 = |t: u32, m: f32| sent.push((t, m));
        let mut la2 = ();
        let mut ctx2: Context<'_, f32, ()> = Context::new(2, 10, &(), &mut la2, &mut send2);
        p.compute(&mut ctx2, 4, &mut val, &edges, &[3.0]); // better
        assert_eq!(val, 3.0);
        assert_eq!(sent, vec![(9, 4.5)]);
    }

    #[test]
    fn emit_adds_weight() {
        let p = Sssp::new(0);
        let mut sent = Vec::new();
        let mut send = |t: u32, m: f32| sent.push((t, m));
        p.emit(
            &2.0,
            &[
                Edge { nbr: 1, weight: 1.0 },
                Edge { nbr: 2, weight: 0.5 },
            ],
            &mut send,
        );
        assert_eq!(sent, vec![(1, 3.0), (2, 2.5)]);
    }
}
