//! K-lane multi-source distance/reachability traversals — the vertex
//! program behind the `crate::serve` query server.
//!
//! One run answers up to K point-to-point / single-source queries in a
//! *single* superstep loop: `Value = [f32; K]` holds one tentative
//! distance per lane, messages are K-lane records folded by the
//! element-wise MIN combiner ([`crate::api::MinLanes`]).  Because the
//! combiner applies, the recoded in-memory `A_s`/`A_r` digesting path
//! (§5) works unchanged — the batched run streams `S^E` *once* per
//! superstep no matter how many lanes are live, which is exactly the I/O
//! amortisation the paper's economics reward.
//!
//! **Per-lane early termination.**  The aggregator carries one pruning
//! bound per lane: the best distance observed so far at that lane's
//! target (−∞ for reachability lanes once the target is touched, ∞ for
//! lanes without a target).  A vertex suppresses lane-ℓ messages whose
//! distance is ≥ the bound — with non-negative edge weights no suffix
//! path can then improve the target, so the lane's frontier collapses as
//! soon as its query is settled while other lanes keep running.  When
//! every lane has settled no messages remain and the engine's ordinary
//! termination (via the existing aggregator/sync machinery) ends the run.

use crate::api::{Context, Edge, MinLanes, VertexProgram};

/// Sentinel for "no vertex" in `sources`/`targets` (no real vertex id is
/// `u32::MAX` — graphs are loaded from dense or sparse u32 ids below it).
pub const NO_VERTEX: u32 = u32::MAX;

/// Per-lane aggregator state: the message-suppression bound of each lane
/// (see module docs).  Merged by element-wise MIN; computing vertices fold
/// the previous global bound back in, so the bound is carried forward
/// across supersteps (MIN-merge is idempotent, making the fold safe).
#[derive(Clone, Debug)]
pub struct LaneBounds<const K: usize>(pub [f32; K]);

impl<const K: usize> Default for LaneBounds<K> {
    fn default() -> Self {
        Self([f32::INFINITY; K])
    }
}

/// K-lane multi-source SSSP/BFS (unit weights make it BFS).  Lanes run
/// independently under one superstep loop; unused lanes (`NO_VERTEX`
/// source) never activate anything and cost nothing but record width.
#[derive(Clone, Debug)]
pub struct MultiSssp<const K: usize> {
    /// Per-lane source vertex (current-ID space); `NO_VERTEX` = idle lane.
    pub sources: [u32; K],
    /// Per-lane target for point-to-point pruning; `NO_VERTEX` = none
    /// (single-source lane, runs to natural quiescence).
    pub targets: [u32; K],
    /// Reachability-only lanes settle the moment the target is first
    /// touched (bound drops to −∞) instead of waiting for the exact
    /// distance to converge.
    pub reach_only: [bool; K],
}

impl<const K: usize> MultiSssp<K> {
    /// Single-source distance lanes (no targets, no pruning).
    pub fn new(sources: [u32; K]) -> Self {
        Self {
            sources,
            targets: [NO_VERTEX; K],
            reach_only: [false; K],
        }
    }

    /// Point-to-point lanes: prune each lane against its target.
    pub fn with_targets(mut self, targets: [u32; K]) -> Self {
        self.targets = targets;
        self
    }

    /// Mark lanes as reachability-only (early-exit on first touch).
    pub fn with_reach_only(mut self, reach_only: [bool; K]) -> Self {
        self.reach_only = reach_only;
        self
    }
}

impl<const K: usize> VertexProgram for MultiSssp<K> {
    type Value = [f32; K];
    type Msg = [f32; K];
    type Agg = LaneBounds<K>;
    type Comb = MinLanes<K>;

    fn init_value(&self, id: u32, _deg: u32, _nv: u64) -> [f32; K] {
        let mut v = [f32::INFINITY; K];
        for l in 0..K {
            if self.sources[l] == id {
                v[l] = 0.0;
            }
        }
        v
    }

    fn initially_active(&self, id: u32) -> bool {
        self.sources.contains(&id)
    }

    fn compute(
        &self,
        ctx: &mut Context<'_, [f32; K], LaneBounds<K>>,
        id: u32,
        value: &mut [f32; K],
        edges: &[Edge],
        msgs: &[[f32; K]],
    ) {
        // Carry the global bounds forward: every computing vertex folds the
        // previous superstep's global into this superstep's local (MIN is
        // idempotent, so repeated folds across vertices are harmless).
        for l in 0..K {
            if ctx.global_agg.0[l] < ctx.local_agg.0[l] {
                ctx.local_agg.0[l] = ctx.global_agg.0[l];
            }
        }
        let mut improved = [false; K];
        for m in msgs {
            for l in 0..K {
                if m[l] < value[l] {
                    value[l] = m[l];
                    improved[l] = true;
                }
            }
        }
        if ctx.superstep == 0 {
            // Sources relax on first activation (value already 0 from init).
            for l in 0..K {
                if self.sources[l] == id {
                    improved[l] = true;
                }
            }
        }
        // Target bookkeeping: tighten this lane's bound.  Reach-only lanes
        // drop it to −∞, silencing the whole lane from the next superstep.
        for l in 0..K {
            if self.targets[l] == id && value[l] < f32::INFINITY {
                let b = if self.reach_only[l] {
                    f32::NEG_INFINITY
                } else {
                    value[l]
                };
                if b < ctx.local_agg.0[l] {
                    ctx.local_agg.0[l] = b;
                }
            }
        }
        let mut base = [f32::INFINITY; K];
        let mut any = false;
        for l in 0..K {
            // Suppress lanes at/beyond the bound: with weights ≥ 0 no path
            // through this vertex can improve the lane's target anymore.
            let bound = ctx.global_agg.0[l].min(ctx.local_agg.0[l]);
            if improved[l] && value[l] < bound {
                base[l] = value[l];
                any = true;
            }
        }
        if any {
            for e in edges {
                let mut m = [f32::INFINITY; K];
                for l in 0..K {
                    m[l] = base[l] + e.weight; // ∞ + w = ∞ for silent lanes
                }
                ctx.send(e.nbr, m);
            }
        }
        ctx.vote_to_halt();
    }

    fn merge_agg(&self, a: &mut LaneBounds<K>, b: &LaneBounds<K>) {
        for l in 0..K {
            if b.0[l] < a.0[l] {
                a.0[l] = b.0[l];
            }
        }
    }

    /// A halted vertex only reactivates if some lane actually improves —
    /// this keeps §3.2's `skip()` firing per lane: vertices touched only by
    /// settled/stale lanes never stream their adjacency.
    fn reactivates(&self, value: &[f32; K], msgs: &[[f32; K]]) -> bool {
        msgs.iter().any(|m| (0..K).any(|l| m[l] < value[l]))
    }

    /// Relaxation adds the edge weight per live lane at fan-out time.
    fn emit(&self, base: &[f32; K], edges: &[Edge], send: &mut dyn FnMut(u32, [f32; K])) {
        for e in edges {
            let mut m = [f32::INFINITY; K];
            for l in 0..K {
                m[l] = base[l] + e.weight;
            }
            send(e.nbr, m);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const INF: f32 = f32::INFINITY;

    fn ctx_run<const K: usize>(
        p: &MultiSssp<K>,
        step: u64,
        global: &LaneBounds<K>,
        local: &mut LaneBounds<K>,
        id: u32,
        value: &mut [f32; K],
        edges: &[Edge],
        msgs: &[[f32; K]],
    ) -> Vec<(u32, [f32; K])> {
        let mut sent = Vec::new();
        let mut send = |t: u32, m: [f32; K]| sent.push((t, m));
        let mut ctx: Context<'_, [f32; K], LaneBounds<K>> =
            Context::new(step, 10, global, local, &mut send);
        p.compute(&mut ctx, id, value, edges, msgs);
        assert!(ctx.halt, "multi-source vertices always vote to halt");
        sent
    }

    #[test]
    fn lanes_init_and_activate_independently() {
        let p = MultiSssp::<3>::new([2, 5, NO_VERTEX]);
        assert_eq!(p.init_value(2, 0, 10), [0.0, INF, INF]);
        assert_eq!(p.init_value(5, 0, 10), [INF, 0.0, INF]);
        assert_eq!(p.init_value(7, 0, 10), [INF, INF, INF]);
        assert!(p.initially_active(2) && p.initially_active(5));
        assert!(!p.initially_active(7));
    }

    #[test]
    fn sources_relax_their_own_lane_only() {
        let p = MultiSssp::<2>::new([0, 3]);
        let g = LaneBounds::default();
        let mut l = LaneBounds::default();
        let mut v = p.init_value(0, 1, 10);
        let edges = [Edge { nbr: 1, weight: 2.0 }];
        let sent = ctx_run(&p, 0, &g, &mut l, 0, &mut v, &edges, &[]);
        assert_eq!(sent, vec![(1, [2.0, INF])]);
    }

    #[test]
    fn improvement_propagates_per_lane() {
        let p = MultiSssp::<2>::new([0, 3]);
        let g = LaneBounds::default();
        let mut l = LaneBounds::default();
        let mut v = [5.0, 1.0];
        // lane 0 improves (4 < 5); lane 1 regresses (2 > 1) and stays quiet
        let sent = ctx_run(
            &p,
            2,
            &g,
            &mut l,
            7,
            &mut v,
            &[Edge { nbr: 9, weight: 1.0 }],
            &[[4.0, 2.0]],
        );
        assert_eq!(v, [4.0, 1.0]);
        assert_eq!(sent, vec![(9, [5.0, INF])]);
    }

    #[test]
    fn target_settles_lane_and_suppresses_messages() {
        let p = MultiSssp::<2>::new([0, 3]).with_targets([7, NO_VERTEX]);
        let g = LaneBounds::default();
        let mut l = LaneBounds::default();
        let mut v = [INF, INF];
        // the target itself improves: bound tightens to its distance and its
        // own relaxation is suppressed (no suffix path can beat it)
        let sent = ctx_run(
            &p,
            3,
            &g,
            &mut l,
            7,
            &mut v,
            &[Edge { nbr: 9, weight: 1.0 }],
            &[[6.0, INF]],
        );
        assert_eq!(l.0[0], 6.0, "bound records the target's distance");
        assert!(sent.is_empty(), "target must not relay its own lane");

        // another vertex at/beyond the (now global) bound stays silent too
        let g2 = LaneBounds([6.0, INF]);
        let mut l2 = LaneBounds::default();
        let mut v2 = [INF, INF];
        let sent2 = ctx_run(
            &p,
            4,
            &g2,
            &mut l2,
            1,
            &mut v2,
            &[Edge { nbr: 2, weight: 1.0 }],
            &[[6.5, INF]],
        );
        assert!(sent2.is_empty());
        // ...but an improvement strictly inside the bound still propagates
        let mut l3 = LaneBounds::default();
        let mut v3 = [INF, INF];
        let sent3 = ctx_run(
            &p,
            4,
            &g2,
            &mut l3,
            1,
            &mut v3,
            &[Edge { nbr: 2, weight: 1.0 }],
            &[[4.0, INF]],
        );
        assert_eq!(sent3, vec![(2, [5.0, INF])]);
    }

    #[test]
    fn reach_only_lane_goes_fully_silent_once_touched() {
        let p = MultiSssp::<1>::new([0])
            .with_targets([7])
            .with_reach_only([true]);
        let g = LaneBounds::default();
        let mut l = LaneBounds::default();
        let mut v = [INF];
        ctx_run(&p, 2, &g, &mut l, 7, &mut v, &[], &[[3.0]]);
        assert_eq!(l.0[0], f32::NEG_INFINITY);
        // with the −∞ bound global, even a big improvement stays silent
        let g2 = LaneBounds([f32::NEG_INFINITY]);
        let mut l2 = LaneBounds::default();
        let mut v2 = [INF];
        let sent = ctx_run(
            &p,
            3,
            &g2,
            &mut l2,
            1,
            &mut v2,
            &[Edge { nbr: 2, weight: 1.0 }],
            &[[0.5]],
        );
        assert!(sent.is_empty());
    }

    #[test]
    fn computing_vertices_carry_the_global_bound_forward() {
        let p = MultiSssp::<2>::new([0, 3]).with_targets([7, 8]);
        let g = LaneBounds([4.0, INF]);
        let mut l = LaneBounds::default();
        let mut v = [INF, INF];
        ctx_run(&p, 5, &g, &mut l, 1, &mut v, &[], &[[9.0, 9.0]]);
        assert_eq!(l.0[0], 4.0, "global bound folded into the local agg");
    }

    #[test]
    fn reactivates_only_on_lane_improvement() {
        let p = MultiSssp::<2>::new([0, 3]);
        assert!(p.reactivates(&[5.0, 1.0], &[[6.0, 0.5]]));
        assert!(!p.reactivates(&[5.0, 1.0], &[[6.0, 1.5]]));
        assert!(!p.reactivates(&[5.0, 1.0], &[[INF, INF]]));
    }

    #[test]
    fn merge_agg_is_elementwise_min() {
        let p = MultiSssp::<3>::new([0, 1, 2]);
        let mut a = LaneBounds([3.0, INF, 1.0]);
        p.merge_agg(&mut a, &LaneBounds([5.0, 2.0, f32::NEG_INFINITY]));
        assert_eq!(a.0, [3.0, 2.0, f32::NEG_INFINITY]);
    }

    #[test]
    fn emit_adds_weight_per_live_lane() {
        let p = MultiSssp::<2>::new([0, 3]);
        let mut sent = Vec::new();
        let mut send = |t: u32, m: [f32; 2]| sent.push((t, m));
        let edges = [Edge { nbr: 4, weight: 0.5 }];
        p.emit(&[2.0, INF], &edges, &mut send);
        assert_eq!(sent, vec![(4, [2.5, INF])]);
    }
}
