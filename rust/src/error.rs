//! Crate-wide error type (hand-rolled Display/Error impls: the offline
//! build carries no external dependencies).

use std::fmt;

/// Errors surfaced by GraphD jobs and substrates.
#[derive(Debug)]
pub enum Error {
    Io(std::io::Error),

    /// An in-memory system refused to run: the estimated footprint exceeds
    /// the per-machine RAM budget of the cluster profile (reproduces the
    /// paper's "Insufficient Main Memories" table entries).
    InsufficientMemory { need_mb: f64, budget_mb: f64 },

    /// An out-of-core system refused to run: its on-disk working set
    /// exceeds the disk budget (the paper's "Insufficient Disk Space").
    InsufficientDisk { need_mb: f64, budget_mb: f64 },

    CorruptStream(String),

    Config(String),

    Xla(String),

    WorkerPanic { machine: usize, cause: String },

    /// A distributed job died: one unit failed (panic or I/O error) and the
    /// failure was propagated through the poisoned barriers and channel
    /// waits to every machine, so the job surfaces this typed error instead
    /// of deadlocking at `Rendezvous`/`recv` (paper §6, Fault Tolerance: a
    /// failure must be *observed* before recovery can start).  `machine`,
    /// `unit` and `superstep` identify the **first** failing unit — every
    /// machine of the job reports the same origin, not its own echo.
    JobFailed {
        /// Machine index of the first failing unit.
        machine: usize,
        /// Which unit died: `"U_c"`, `"U_s"`, `"U_r"`, `"load"`, `"recode"`.
        unit: &'static str,
        /// Superstep (or preprocessing phase) that unit was executing.
        superstep: u64,
        /// The underlying failure, rendered.  When checkpointing was
        /// enabled, the session layer appends the last durable superstep
        /// usable with `JobBuilder::resume`.
        cause: String,
    },

    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "I/O error: {e}"),
            Error::InsufficientMemory { need_mb, budget_mb } => write!(
                f,
                "insufficient main memories: need {need_mb:.1} MB/machine, budget {budget_mb:.1} MB"
            ),
            Error::InsufficientDisk { need_mb, budget_mb } => write!(
                f,
                "insufficient disk space: need {need_mb:.1} MB, budget {budget_mb:.1} MB"
            ),
            Error::CorruptStream(s) => write!(f, "corrupt stream: {s}"),
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::Xla(s) => write!(f, "xla runtime error: {s}"),
            Error::WorkerPanic { machine, cause } => {
                write!(f, "worker {machine} panicked: {cause}")
            }
            Error::JobFailed {
                machine,
                unit,
                superstep,
                cause,
            } => write!(
                f,
                "job failed: {unit} of machine {machine} died at superstep {superstep}: {cause}"
            ),
            Error::Other(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<anyhow::Error> for Error {
    fn from(e: anyhow::Error) -> Self {
        Error::Other(format!("{e:#}"))
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_seed_messages() {
        let e = Error::InsufficientMemory { need_mb: 12.0, budget_mb: 8.0 };
        assert_eq!(
            e.to_string(),
            "insufficient main memories: need 12.0 MB/machine, budget 8.0 MB"
        );
        let e = Error::WorkerPanic { machine: 3, cause: "boom".into() };
        assert_eq!(e.to_string(), "worker 3 panicked: boom");
        let io = Error::from(std::io::Error::new(std::io::ErrorKind::Other, "x"));
        assert!(io.to_string().starts_with("I/O error:"));
    }

    #[test]
    fn job_failed_display_names_origin() {
        let e = Error::JobFailed {
            machine: 2,
            unit: "U_r",
            superstep: 7,
            cause: "disk full".into(),
        };
        assert_eq!(
            e.to_string(),
            "job failed: U_r of machine 2 died at superstep 7: disk full"
        );
    }
}
