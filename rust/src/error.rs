//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by GraphD jobs and substrates.
#[derive(Error, Debug)]
pub enum Error {
    #[error("I/O error: {0}")]
    Io(#[from] std::io::Error),

    /// An in-memory system refused to run: the estimated footprint exceeds
    /// the per-machine RAM budget of the cluster profile (reproduces the
    /// paper's "Insufficient Main Memories" table entries).
    #[error("insufficient main memories: need {need_mb:.1} MB/machine, budget {budget_mb:.1} MB")]
    InsufficientMemory { need_mb: f64, budget_mb: f64 },

    /// An out-of-core system refused to run: its on-disk working set
    /// exceeds the disk budget (the paper's "Insufficient Disk Space").
    #[error("insufficient disk space: need {need_mb:.1} MB, budget {budget_mb:.1} MB")]
    InsufficientDisk { need_mb: f64, budget_mb: f64 },

    #[error("corrupt stream: {0}")]
    CorruptStream(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("xla runtime error: {0}")]
    Xla(String),

    #[error("worker {machine} panicked: {cause}")]
    WorkerPanic { machine: usize, cause: String },

    #[error("{0}")]
    Other(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl From<anyhow::Error> for Error {
    fn from(e: anyhow::Error) -> Self {
        Error::Other(format!("{e:#}"))
    }
}
