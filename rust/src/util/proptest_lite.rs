//! `proptest_lite` — a tiny property-testing harness (the offline registry
//! has no `proptest`).  Runs a property over many seeded random cases and,
//! on failure, retries with "smaller" cases derived from the failing seed
//! to report a minimal-ish reproduction.
//!
//! Usage:
//! ```ignore
//! proptest_lite::run(100, |g| {
//!     let v = g.vec_u32(0..500, 0..1000);
//!     let prop = check(&v);
//!     prop_assert!(g, prop, "check failed for {v:?}");
//! });
//! ```

use super::rng::Rng;

/// Per-case generator handle: wraps the PRNG plus a size budget so retries
/// can shrink input magnitude.
pub struct Gen {
    pub rng: Rng,
    /// Multiplier in (0, 1]; shrink passes lower it to produce smaller cases.
    pub size: f64,
    pub case: u64,
    failed: Option<String>,
}

impl Gen {
    /// Integer in `lo..hi` scaled by the shrink budget.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        let span = ((hi - lo) as f64 * self.size).max(1.0) as u64;
        lo + self.rng.below(span) as usize
    }

    pub fn u32_below(&mut self, bound: u32) -> u32 {
        self.rng.below(bound as u64) as u32
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Vec of u32 with length in `len_range` and values below `val_bound`.
    pub fn vec_u32(&mut self, len_lo: usize, len_hi: usize, val_bound: u32) -> Vec<u32> {
        let n = self.usize_in(len_lo, len_hi.max(len_lo + 1));
        (0..n).map(|_| self.u32_below(val_bound)).collect()
    }

    /// Record a failure (used by `prop_assert!`).
    pub fn fail(&mut self, msg: String) {
        if self.failed.is_none() {
            self.failed = Some(msg);
        }
    }
}

/// Assert inside a property; records the message instead of panicking so the
/// harness can shrink.
#[macro_export]
macro_rules! prop_assert {
    ($g:expr, $cond:expr, $($fmt:tt)+) => {
        if !$cond {
            $g.fail(format!($($fmt)+));
            return;
        }
    };
}

/// Run `prop` over `cases` seeded random cases.  Panics with the seed and
/// message of the smallest failing case found.
pub fn run(cases: u64, mut prop: impl FnMut(&mut Gen)) {
    // Honor an env override so failures can be replayed exactly.
    let base: u64 = std::env::var("PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);

    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        if let Some(msg) = run_one(seed, case, 1.0, &mut prop) {
            // Shrink: try the same seed with smaller size budgets.
            let mut best = (1.0f64, msg);
            for &size in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                if let Some(m) = run_one(seed, case, size, &mut prop) {
                    best = (size, m);
                }
            }
            // analyze:allow(panic-hygiene): property-failure reporting IS
            // this harness's contract — it only ever runs inside #[test]
            // fns, where the panic drives the libtest failure path with the
            // seed/size needed to replay the case.
            panic!(
                "proptest_lite: case {case} failed (seed={seed:#x}, size={}):\n{}",
                best.0, best.1
            );
        }
    }
}

fn run_one(
    seed: u64,
    case: u64,
    size: f64,
    prop: &mut impl FnMut(&mut Gen),
) -> Option<String> {
    let mut g = Gen {
        rng: Rng::new(seed),
        size,
        case,
        failed: None,
    };
    prop(&mut g);
    g.failed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        run(50, |g| {
            let _ = g.u64();
            n += 1;
        });
        assert!(n >= 50);
    }

    #[test]
    #[should_panic(expected = "proptest_lite")]
    fn failing_property_panics_with_seed() {
        run(50, |g| {
            let v = g.usize_in(0, 1000);
            prop_assert!(g, v < 990, "v too large: {v}");
        });
    }

    #[test]
    fn sizes_shrink_inputs() {
        let mut g = Gen {
            rng: Rng::new(1),
            size: 0.01,
            case: 0,
            failed: None,
        };
        for _ in 0..100 {
            assert!(g.usize_in(0, 1000) <= 10);
        }
    }
}
