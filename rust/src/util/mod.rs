//! Small shared utilities: deterministic PRNG, timers, size formatting,
//! bitsets, a hand-rolled read-only `mmap` binding, and an in-repo
//! property-testing helper (`proptest_lite`).

pub mod bitset;
pub mod diskio;
pub mod mmap;
pub mod proptest_lite;
pub mod rng;
pub mod timer;

/// Format a byte count as a human-readable string.
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format seconds the way the paper's tables do ("1189 s", "1.74 s").
pub fn human_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0} s")
    } else if s >= 10.0 {
        format!("{s:.1} s")
    } else {
        format!("{s:.2} s")
    }
}

/// Integer ceiling division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(17), "17 B");
        assert_eq!(human_bytes(64 * 1024), "64.0 KB");
        assert_eq!(human_bytes(8 * 1024 * 1024), "8.0 MB");
    }

    #[test]
    fn human_secs_paper_style() {
        assert_eq!(human_secs(1189.4), "1189 s");
        assert_eq!(human_secs(81.72), "81.7 s");
        assert_eq!(human_secs(1.7449), "1.74 s");
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 8), 0);
        assert_eq!(ceil_div(1, 8), 1);
        assert_eq!(ceil_div(8, 8), 1);
        assert_eq!(ceil_div(9, 8), 2);
    }
}
