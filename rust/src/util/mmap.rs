//! Hand-rolled read-only memory mapping (vendor-everything rule: no
//! `memmap2`).
//!
//! The resident adjacency store ([`crate::worker::csr`]) maps its flat CSR
//! files so U_c reads adjacency as an O(1) zero-copy slice and the OS page
//! cache does the streaming.  Crucially for the paper's O(|V|/n) claim,
//! a `MAP_SHARED`/`PROT_READ` file mapping is **not heap**: the pages are
//! clean page-cache pages the kernel can drop under pressure, so the
//! per-machine state-array budget is unchanged.
//!
//! On unix this is a direct `extern "C"` binding to `mmap`/`munmap`/
//! `madvise` (the only three calls we need).  On non-unix targets the
//! fallback reads the file into a heap `Vec<u8>` — correctness is
//! preserved but the page-cache property (and the "not heap" argument)
//! is lost; `Mmap::is_real_mapping` reports which one you got.

use std::fs::File;
use std::io;
use std::path::Path;

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_SHARED: c_int = 1;
    /// `mmap` error return: `(void *)-1`, not null.
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;
    pub const MADV_SEQUENTIAL: c_int = 2;
    pub const MADV_WILLNEED: c_int = 3;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
    }
}

/// Access-pattern hint forwarded to `madvise` (no-op where unsupported).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Advice {
    /// Expect sequential reads: aggressive read-ahead, early drop-behind.
    Sequential,
    /// Expect access soon: start faulting pages in now.
    WillNeed,
}

/// A read-only mapping of one whole file.
///
/// Unix: a `PROT_READ`/`MAP_SHARED` mapping, unmapped on drop.  Non-unix:
/// the file's bytes in a heap buffer (see module docs).  Zero-length files
/// are represented without any `mmap` call (mapping 0 bytes is EINVAL).
pub struct Mmap {
    #[cfg(unix)]
    ptr: *mut std::os::raw::c_void,
    #[cfg(unix)]
    len: usize,
    #[cfg(not(unix))]
    buf: Vec<u8>,
}

// SAFETY: the mapping is read-only (PROT_READ) and file-backed; no &mut
// access is ever handed out, so sharing across threads is sound.
#[cfg(unix)]
unsafe impl Send for Mmap {}
#[cfg(unix)]
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `path` read-only in its entirety.
    pub fn map_file(path: &Path) -> io::Result<Mmap> {
        let f = File::open(path)?;
        let len = f.metadata()?.len();
        if len > usize::MAX as u64 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "file too large to map on this target",
            ));
        }
        Self::map_open(&f, len as usize)
    }

    #[cfg(unix)]
    fn map_open(f: &File, len: usize) -> io::Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            return Ok(Mmap {
                ptr: std::ptr::null_mut(),
                len: 0,
            });
        }
        // SAFETY: fd is a valid open file of at least `len` bytes; a
        // PROT_READ/MAP_SHARED mapping of it has no aliasing hazards.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_SHARED,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::MAP_FAILED {
            return Err(io::Error::last_os_error());
        }
        Ok(Mmap { ptr, len })
    }

    #[cfg(not(unix))]
    fn map_open(f: &File, len: usize) -> io::Result<Mmap> {
        use std::io::Read;
        let mut buf = Vec::with_capacity(len);
        let mut f = f;
        f.read_to_end(&mut buf)?;
        Ok(Mmap { buf })
    }

    /// The mapped bytes.
    pub fn as_slice(&self) -> &[u8] {
        #[cfg(unix)]
        {
            if self.len == 0 {
                return &[];
            }
            // SAFETY: ptr/len describe a live PROT_READ mapping owned by
            // self; the slice's lifetime is tied to &self, and munmap only
            // runs in Drop.
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
        #[cfg(not(unix))]
        {
            &self.buf
        }
    }

    /// Mapping length in bytes.
    pub fn len(&self) -> usize {
        #[cfg(unix)]
        {
            self.len
        }
        #[cfg(not(unix))]
        {
            self.buf.len()
        }
    }

    /// True when nothing is mapped (zero-length file).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when this is a real `mmap` (page-cache-backed), false on the
    /// non-unix heap-buffer fallback.
    pub fn is_real_mapping(&self) -> bool {
        cfg!(unix) && !self.is_empty()
    }

    /// Forward an access-pattern hint to the kernel.  Returns whether the
    /// hint was actually issued (false on the fallback, empty mappings,
    /// or an `madvise` error — hints are best-effort by contract).
    pub fn advise(&self, advice: Advice) -> bool {
        #[cfg(unix)]
        {
            if self.len == 0 {
                return false;
            }
            let adv = match advice {
                Advice::Sequential => sys::MADV_SEQUENTIAL,
                Advice::WillNeed => sys::MADV_WILLNEED,
            };
            // SAFETY: ptr/len describe a live mapping owned by self.
            unsafe { sys::madvise(self.ptr, self.len, adv) == 0 }
        }
        #[cfg(not(unix))]
        {
            let _ = advice;
            false
        }
    }
}

#[cfg(unix)]
impl Drop for Mmap {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: ptr/len came from a successful mmap and are unmapped
            // exactly once.
            unsafe {
                sys::munmap(self.ptr, self.len);
            }
        }
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mmap")
            .field("len", &self.len())
            .field("real", &self.is_real_mapping())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "graphd_mmap_{name}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    #[test]
    fn maps_whole_file() {
        let p = tmp("whole");
        let data: Vec<u8> = (0..=255u8).cycle().take(5000).collect();
        std::fs::write(&p, &data).unwrap();
        let m = Mmap::map_file(&p).unwrap();
        assert_eq!(m.len(), data.len());
        assert_eq!(m.as_slice(), &data[..]);
        assert!(!m.is_empty());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn zero_length_file_maps_empty() {
        let p = tmp("empty");
        std::fs::write(&p, b"").unwrap();
        let m = Mmap::map_file(&p).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.as_slice(), &[] as &[u8]);
        assert!(!m.is_real_mapping());
        assert!(!m.advise(Advice::Sequential));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn missing_file_is_io_error() {
        let p = tmp("missing_never_written");
        assert!(Mmap::map_file(&p).is_err());
    }

    #[test]
    fn advise_is_best_effort_ok() {
        let p = tmp("advise");
        std::fs::write(&p, vec![7u8; 4096]).unwrap();
        let m = Mmap::map_file(&p).unwrap();
        // On unix both hints should succeed on a live mapping; on the
        // fallback they report false.  Either way: no panic, no UB.
        let a = m.advise(Advice::Sequential);
        let b = m.advise(Advice::WillNeed);
        assert_eq!(a, m.is_real_mapping());
        assert_eq!(b, m.is_real_mapping());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn slices_survive_shared_reads() {
        let p = tmp("shared");
        let data: Vec<u8> = (0u32..1024).flat_map(|x| x.to_le_bytes()).collect();
        std::fs::write(&p, &data).unwrap();
        let m = std::sync::Arc::new(Mmap::map_file(&p).unwrap());
        let mut handles = Vec::new();
        for t in 0..4u32 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                let s = m.as_slice();
                let i = (t as usize * 100) * 4;
                u32::from_le_bytes(s[i..i + 4].try_into().unwrap())
            }));
        }
        for (t, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), t as u32 * 100);
        }
        let _ = std::fs::remove_file(&p);
    }
}
