//! Simulated per-machine disk bandwidth.
//!
//! The paper's central quantitative claim is an *ordering*: local disk
//! streaming bandwidth ≫ per-machine share of a commodity switch (§3.3.1).
//! Real disks on this testbed are far faster than our scaled-down network
//! model, which would make out-of-core cost invisible; instead every
//! simulated machine owns a [`DiskBw`] token bucket and all stream I/O on
//! its threads is charged against it (threads register via [`register`]).
//!
//! A `None` registration (the default, used by unit tests) means
//! unthrottled real-disk speed.

use std::cell::RefCell;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Shared-per-machine disk bandwidth bucket: concurrent readers/writers on
/// the same simulated machine contend, like a single spindle/SSD channel.
pub struct DiskBw {
    rate: f64,
    next_free: Mutex<Instant>,
    bytes: Mutex<u64>,
}

impl DiskBw {
    pub fn new(bytes_per_sec: f64) -> Arc<Self> {
        Arc::new(Self {
            rate: bytes_per_sec.max(1.0),
            next_free: Mutex::new(Instant::now()),
            bytes: Mutex::new(0),
        })
    }

    /// Block for the simulated time of moving `bytes` to/from this disk.
    pub fn charge(&self, bytes: usize) {
        if bytes == 0 {
            return;
        }
        let dur = Duration::from_secs_f64(bytes as f64 / self.rate);
        let until = {
            let mut nf = self.next_free.lock().unwrap();
            let start = (*nf).max(Instant::now());
            *nf = start + dur;
            *nf
        };
        *self.bytes.lock().unwrap() += bytes as u64;
        let now = Instant::now();
        if until > now {
            // analyze:allow(sleep-slicing): single-transfer nap, bounded by
            // one block's simulated disk time (≤ℬ bytes / rate); the abort
            // latch is observed at the next poisonable wait, and a 10ms
            // poll quantum on every stream read would dominate the disk
            // model's hot path.
            std::thread::sleep(until - now);
        }
    }

    pub fn total_bytes(&self) -> u64 {
        *self.bytes.lock().unwrap()
    }
}

thread_local! {
    static CURRENT: RefCell<Option<Arc<DiskBw>>> = const { RefCell::new(None) };
}

/// Install `bw` as this thread's disk (returns a guard restoring the
/// previous registration on drop).
pub fn register(bw: Option<Arc<DiskBw>>) -> Guard {
    let prev = CURRENT.with(|c| c.replace(bw));
    Guard { prev }
}

/// Charge `bytes` against the registered disk, if any.
#[inline]
pub fn charge(bytes: usize) {
    CURRENT.with(|c| {
        if let Some(bw) = c.borrow().as_ref() {
            bw.charge(bytes);
        }
    });
}

/// Read a whole file into `buf` (cleared first, capacity reused) and
/// charge the registered disk — the pooled-buffer replacement for
/// `std::fs::read` on the message spine's hot paths.
pub fn read_file_into(path: &std::path::Path, buf: &mut Vec<u8>) -> crate::error::Result<usize> {
    use std::io::Read;
    buf.clear();
    let mut f = std::fs::File::open(path)?;
    let len = f.metadata()?.len() as usize;
    buf.reserve(len);
    f.read_to_end(buf)?;
    charge(buf.len());
    Ok(buf.len())
}

/// Restores the previous registration on drop.
pub struct Guard {
    prev: Option<Arc<DiskBw>>,
}

impl Drop for Guard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CURRENT.with(|c| *c.borrow_mut() = prev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unregistered_is_free() {
        let t = Instant::now();
        charge(100 << 20);
        assert!(t.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn registered_throttles() {
        let bw = DiskBw::new(10.0 * 1024.0 * 1024.0);
        let _g = register(Some(bw.clone()));
        let t = Instant::now();
        charge(1024 * 1024);
        assert!(t.elapsed() >= Duration::from_millis(90), "{:?}", t.elapsed());
        assert_eq!(bw.total_bytes(), 1024 * 1024);
    }

    #[test]
    fn guard_restores() {
        let bw = DiskBw::new(1e12);
        {
            let _g = register(Some(bw.clone()));
            charge(10);
        }
        assert_eq!(bw.total_bytes(), 10);
        charge(100); // unregistered again — not counted
        assert_eq!(bw.total_bytes(), 10);
    }

    #[test]
    fn contending_threads_serialize() {
        let bw = DiskBw::new(10.0 * 1024.0 * 1024.0);
        let t = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..2 {
                let bw = bw.clone();
                s.spawn(move || {
                    let _g = register(Some(bw));
                    charge(512 * 1024);
                });
            }
        });
        assert!(t.elapsed() >= Duration::from_millis(85), "{:?}", t.elapsed());
    }
}
