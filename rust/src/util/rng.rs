//! Deterministic PRNG (splitmix64 seeding a xoshiro256**) — the offline
//! registry has no `rand`, and determinism across runs matters for the
//! benchmark tables anyway.

/// xoshiro256** seeded via splitmix64. Not cryptographic; fast and
/// statistically fine for graph generation and property tests.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`; `bound` must be > 0.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here;
        // use widening multiply to avoid modulo bias in the common case.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_in_unit_interval_and_spread() {
        let mut r = Rng::new(9);
        let mut lo = 0usize;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            if x < 0.5 {
                lo += 1;
            }
        }
        assert!((4_000..6_000).contains(&lo), "badly skewed: {lo}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
