//! Compact bitset for per-vertex flags (active map etc.) — keeps the
//! per-machine vertex state within the paper's O(|V|/n) budget.

/// Fixed-capacity bitset over `u64` words.
#[derive(Clone, Debug)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    pub fn new(len: usize) -> Self {
        Self {
            words: vec![0; (len + 63) / 64],
            len,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Grow capacity to at least `len` bits (new bits are 0).
    pub fn grow(&mut self, len: usize) {
        if len > self.len {
            self.words.resize((len + 63) / 64, 0);
            self.len = len;
        }
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let (w, b) = (i / 64, i % 64);
        if v {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    pub fn set_all(&mut self) {
        self.words.fill(!0);
        // mask tail bits beyond len
        let tail = self.len % 64;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }

    /// Iterate indices of set bits.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut b = BitSet::new(130);
        b.set(0, true);
        b.set(64, true);
        b.set(129, true);
        assert!(b.get(0) && b.get(64) && b.get(129));
        assert!(!b.get(1) && !b.get(128));
        assert_eq!(b.count_ones(), 3);
        b.set(64, false);
        assert_eq!(b.count_ones(), 2);
    }

    #[test]
    fn set_all_masks_tail() {
        let mut b = BitSet::new(70);
        b.set_all();
        assert_eq!(b.count_ones(), 70);
    }

    #[test]
    fn iter_ones_matches() {
        let mut b = BitSet::new(200);
        let idx = [0usize, 3, 63, 64, 65, 127, 199];
        for &i in &idx {
            b.set(i, true);
        }
        assert_eq!(b.iter_ones().collect::<Vec<_>>(), idx);
    }

    #[test]
    fn grow_preserves_bits() {
        let mut b = BitSet::new(10);
        b.set(9, true);
        b.grow(100);
        assert!(b.get(9));
        assert!(!b.get(99));
        assert_eq!(b.len(), 100);
    }
}
