//! Wall-clock timing helpers used by the per-superstep metrics (the paper's
//! M-Send vs M-Gene accounting in Table 4 needs accumulated spans).

use std::time::{Duration, Instant};

/// A resumable stopwatch accumulating total elapsed time over many spans.
#[derive(Debug, Default)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "stopwatch already running");
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t) = self.started.take() {
            self.total += t.elapsed();
        }
    }

    /// Total accumulated time (includes the running span, if any).
    pub fn total(&self) -> Duration {
        match self.started {
            Some(t) => self.total + t.elapsed(),
            None => self.total,
        }
    }

    pub fn secs(&self) -> f64 {
        self.total().as_secs_f64()
    }

    /// Time a closure, accumulating its duration.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }
}

/// Measure a closure once, returning (seconds, output).
pub fn timed<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t = Instant::now();
    let out = f();
    (t.elapsed().as_secs_f64(), out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_spans() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(sw.secs() >= 0.009, "got {}", sw.secs());
    }

    #[test]
    fn timed_returns_output() {
        let (s, v) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
