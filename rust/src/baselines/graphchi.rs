//! GraphChi analog: single-PC out-of-core processing with shards.
//!
//! Cost structure (§2.2, §6): (a) an expensive *sharding* preprocessing
//! pass (sort all edges by destination interval); (b) every iteration
//! loads whole shards — vertices **and all their adjacent edges** — into
//! memory and writes updated values back, *even if only one vertex in a
//! shard is active* ("selective scheduling … is ineffective"); (c) one
//! machine's disk does all the work.

use super::{adj_bytes, trace, Algo, BaselineRun, STATE_BYTES};
use crate::config::ClusterProfile;
use crate::error::{Error, Result};
use crate::graph::Graph;
use crate::util::diskio::DiskBw;
use crate::util::timer::timed;

/// Disk working set: input text + sorted shards + per-iteration writes.
pub fn disk_need(g: &Graph, algo: Algo) -> u64 {
    3 * adj_bytes(g, algo)
}

pub fn run(g: &Graph, algo: Algo, profile: &ClusterProfile) -> Result<BaselineRun> {
    let need = disk_need(g, algo);
    // single-PC systems get the big-disk machine (the paper's 2 TB node)
    if need > profile.disk_budget_big {
        return Err(Error::InsufficientDisk {
            need_mb: need as f64 / (1024.0 * 1024.0),
            budget_mb: profile.disk_budget_big as f64 / (1024.0 * 1024.0),
        });
    }
    let disk = profile.disk_bytes_per_sec.map(DiskBw::new);
    let charge = |b: u64| {
        if let Some(d) = &disk {
            d.charge(b as usize);
        }
    };

    let adj = adj_bytes(g, algo);
    let v_bytes = g.num_vertices() as u64 * STATE_BYTES;
    let text = adj * 3 / 2;

    // Sharding: read the text input, sort edges by destination (two
    // external passes), write shard files.
    let (preprocess_secs, ()) = timed(|| charge(text + 2 * adj + adj));

    let (values, steps) = trace(g, algo);
    // Each iteration: read every shard (edges + vertex values), write
    // updated vertex values and edge data back — independent of frontier
    // size (the paper's sparse-workload complaint).
    let (compute_secs, ()) = timed(|| {
        for _ in &steps {
            charge(adj + v_bytes); // load shards
            charge(adj / 2 + v_bytes); // write-back
        }
    });

    Ok(BaselineRun {
        system: "GraphChi",
        preprocess_secs,
        load_secs: 0.0, // rescans from its own disk; no separate load phase
        compute_secs,
        supersteps: steps.len() as u64,
        values,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;

    #[test]
    fn refuses_on_small_disk() {
        let g = generator::uniform(100, 2000, true, 1);
        let mut p = ClusterProfile::test(1);
        p.disk_budget_big = 1024;
        let err = run(&g, Algo::PageRank { supersteps: 2 }, &p).unwrap_err();
        assert!(matches!(err, Error::InsufficientDisk { .. }));
    }

    #[test]
    fn iteration_cost_is_frontier_independent() {
        // Same graph: SSSP (tiny frontiers) must pay as much per superstep
        // as PageRank (full frontier) — modulo item size.
        let g = generator::uniform(300, 3000, true, 2).with_unit_weights();
        let mut p = ClusterProfile::test(1);
        p.disk_bytes_per_sec = Some(200.0 * 1024.0 * 1024.0);
        let pr = run(&g, Algo::PageRank { supersteps: 5 }, &p).unwrap();
        let ss = run(&g, Algo::Sssp { source: 0 }, &p).unwrap();
        let pr_per_step = pr.compute_secs / pr.supersteps as f64;
        let ss_per_step = ss.compute_secs / ss.supersteps as f64;
        // SSSP items are 2x bigger, so per-step cost is >= PageRank's.
        assert!(
            ss_per_step > 0.9 * pr_per_step,
            "sparse steps unrealistically cheap: {ss_per_step} vs {pr_per_step}"
        );
    }

    #[test]
    fn values_match_reference() {
        let g = generator::uniform(80, 300, false, 3);
        let p = ClusterProfile::test(1);
        let out = run(&g, Algo::HashMin, &p).unwrap();
        match out.values {
            super::super::AlgoValues::Labels(l) => {
                assert_eq!(l, crate::graph::reference::components(&g));
            }
            _ => panic!(),
        }
        assert!(out.preprocess_secs >= 0.0);
    }
}
