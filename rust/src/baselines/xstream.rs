//! X-Stream analog: single-PC edge-centric scatter-gather streaming.
//!
//! Cost structure (§2.2, §6): no preprocessing, but every iteration
//! streams **all** edges from disk (scatter), writes an update for every
//! generated message, and streams the updates back (gather).  "X-Stream is
//! inefficient for graphs whose structure requires a large number of
//! iterations" — SSSP/BFS with hundreds of supersteps is its worst case,
//! which Tables 7–8 show as `> 24 hr`.

use super::{adj_bytes, trace, Algo, BaselineRun, MSG_BYTES, STATE_BYTES};
use crate::config::ClusterProfile;
use crate::error::{Error, Result};
use crate::graph::Graph;
use crate::util::diskio::DiskBw;
use crate::util::timer::timed;

pub fn disk_need(g: &Graph, algo: Algo) -> u64 {
    // edges + an updates file up to one record per edge
    adj_bytes(g, algo) + g.num_edges() as u64 * MSG_BYTES
}

pub fn run(g: &Graph, algo: Algo, profile: &ClusterProfile) -> Result<BaselineRun> {
    let need = disk_need(g, algo);
    // single-PC: runs on the big-disk machine
    if need > profile.disk_budget_big {
        return Err(Error::InsufficientDisk {
            need_mb: need as f64 / (1024.0 * 1024.0),
            budget_mb: profile.disk_budget_big as f64 / (1024.0 * 1024.0),
        });
    }
    let disk = profile.disk_bytes_per_sec.map(DiskBw::new);
    let charge = |b: u64| {
        if let Some(d) = &disk {
            d.charge(b as usize);
        }
    };

    let adj = adj_bytes(g, algo);
    let v_bytes = g.num_vertices() as u64 * STATE_BYTES;
    let (values, steps) = trace(g, algo);
    let (compute_secs, ()) = timed(|| {
        for st in &steps {
            // scatter: stream ALL edges + vertex states, write updates
            charge(adj + v_bytes + st.msgs * MSG_BYTES);
            // gather: stream updates, apply to vertices
            charge(st.msgs * MSG_BYTES + v_bytes);
        }
    });

    Ok(BaselineRun {
        system: "X-Stream",
        preprocess_secs: 0.0,
        load_secs: 0.0,
        compute_secs,
        supersteps: steps.len() as u64,
        values,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;

    #[test]
    fn many_superstep_jobs_pay_full_scans() {
        // chain BFS: |V| supersteps, each streaming all edges — per-step
        // cost must not shrink with the (tiny) frontier.
        let g = generator::chain(100).with_unit_weights();
        let mut p = ClusterProfile::test(1);
        p.disk_bytes_per_sec = Some(100.0 * 1024.0 * 1024.0);
        let out = run(&g, Algo::Sssp { source: 0 }, &p).unwrap();
        assert_eq!(out.supersteps, 101);
        // 101 steps × full edge scan ≥ 101 × adj bytes on one disk
        let min_bytes = 101 * adj_bytes(&g, Algo::Sssp { source: 0 });
        let min_secs = min_bytes as f64 / (100.0 * 1024.0 * 1024.0);
        assert!(out.compute_secs >= 0.5 * min_secs);
    }

    #[test]
    fn refuses_on_tiny_disk() {
        let g = generator::uniform(100, 1000, true, 1);
        let mut p = ClusterProfile::test(1);
        p.disk_budget_big = 100;
        assert!(matches!(
            run(&g, Algo::PageRank { supersteps: 1 }, &p),
            Err(Error::InsufficientDisk { .. })
        ));
    }

    #[test]
    fn values_match_reference() {
        let g = generator::uniform(60, 300, true, 2);
        let out = run(&g, Algo::PageRank { supersteps: 4 }, &ClusterProfile::test(1)).unwrap();
        match out.values {
            super::super::AlgoValues::Ranks(r) => {
                let want = crate::graph::reference::pagerank(&g, 4);
                for v in 0..60 {
                    assert!((r[v] - want[v]).abs() < 1e-6);
                }
            }
            _ => panic!(),
        }
    }
}
