//! Pregelix analog: Pregel-as-dataflow on a general-purpose engine.
//!
//! Cost structure (§1, §2.2, §6): the Pregel semantics are compiled to
//! relational operators, so **every superstep** performs an
//! external-memory *sort* of the message relation, a *join* with the
//! vertex relation (full scan of states + adjacency) and a *group-by* —
//! even when a combiner applies.  On top of that the dataflow engine has a
//! fixed per-superstep overhead (the paper measured ≥ 35 s on W^PC and
//! 3–4 s on W^high; we scale it through the profile latency).

use super::{adj_bytes, trace, Algo, BaselineRun, MSG_BYTES, STATE_BYTES};
use crate::config::ClusterProfile;
use crate::error::{Error, Result};
use crate::graph::Graph;
use crate::net::Switch;
use crate::util::diskio::DiskBw;
use crate::util::timer::timed;
use std::sync::Arc;

/// Fixed dataflow overhead per superstep, scaled from the profile's batch
/// latency (paper: 35 s on W^PC, 3–4 s on W^high; ×1/100 testbed scale).
pub fn step_overhead_secs(profile: &ClusterProfile) -> f64 {
    profile.latency_us as f64 * 1e-6 * 1000.0
}

pub fn disk_need_per_machine(g: &Graph, algo: Algo, n: usize) -> u64 {
    // vertex+edge relations, message runs, sort temporaries
    (2 * adj_bytes(g, algo) + 2 * g.num_edges() as u64 * MSG_BYTES) / n as u64
}

pub fn run(g: &Graph, algo: Algo, profile: &ClusterProfile) -> Result<BaselineRun> {
    let n = profile.machines;
    let need = disk_need_per_machine(g, algo, n);
    if need > profile.disk_budget {
        return Err(Error::InsufficientDisk {
            need_mb: need as f64 / (1024.0 * 1024.0),
            budget_mb: profile.disk_budget as f64 / (1024.0 * 1024.0),
        });
    }

    let text = adj_bytes(g, algo) * 3 / 2;
    let (load_secs, ()) = timed(|| {
        super::inmem::charge_disks_parallel(profile, text / n as u64);
    });

    let (values, steps) = trace(g, algo);
    let adj = adj_bytes(g, algo);
    let v_bytes = g.num_vertices() as u64 * STATE_BYTES;
    let switch = Switch::new(profile.net_bytes_per_sec, profile.latency_us);
    let overhead = step_overhead_secs(profile);
    let disks: Vec<Option<Arc<DiskBw>>> = (0..n)
        .map(|_| profile.disk_bytes_per_sec.map(DiskBw::new))
        .collect();

    let (compute_secs, ()) = timed(|| {
        for st in &steps {
            let msg_bytes = st.msgs * MSG_BYTES;
            std::thread::scope(|s| {
                for d in disks.iter() {
                    let switch = switch.clone();
                    let d = d.clone();
                    s.spawn(move || {
                        let per = |b: u64| (b / n as u64) as usize;
                        // shuffle messages over the network
                        switch.transmit(per(msg_bytes * (n as u64 - 1) / n as u64));
                        if let Some(d) = d {
                            // external sort of the message relation: write
                            // runs + read them back
                            d.charge(per(2 * msg_bytes));
                            // join: scan vertex + edge relations
                            d.charge(per(v_bytes + adj));
                            // group-by output + new vertex relation
                            d.charge(per(v_bytes + msg_bytes / 2));
                        }
                    });
                }
            });
            // analyze:allow(sleep-slicing): baseline simulator — models
            // Pregelix's fixed per-superstep framework overhead; baselines
            // run standalone with no JobAbort latch to observe.
            std::thread::sleep(std::time::Duration::from_secs_f64(overhead));
        }
    });

    Ok(BaselineRun {
        system: "Pregelix",
        preprocess_secs: 0.0,
        load_secs,
        compute_secs,
        supersteps: steps.len() as u64,
        values,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;

    #[test]
    fn per_step_fixed_overhead_dominates_sparse_jobs() {
        // Many near-empty supersteps: compute time ≈ steps × overhead,
        // reproducing the paper's WebUK-SSSP pathology (665 × 35 s).
        let g = generator::chain(15).with_unit_weights();
        let mut p = ClusterProfile::test(2);
        p.latency_us = 100; // → 0.1 s fixed overhead per superstep
        let out = run(&g, Algo::Sssp { source: 0 }, &p).unwrap();
        let want = out.supersteps as f64 * step_overhead_secs(&p);
        assert!(
            out.compute_secs >= 0.8 * want,
            "{} < {}",
            out.compute_secs,
            want
        );
    }

    #[test]
    fn values_match_reference() {
        let g = generator::uniform(70, 280, true, 9);
        let out = run(
            &g,
            Algo::PageRank { supersteps: 3 },
            &ClusterProfile::test(2),
        )
        .unwrap();
        match out.values {
            super::super::AlgoValues::Ranks(r) => {
                let want = crate::graph::reference::pagerank(&g, 3);
                for v in 0..70 {
                    assert!((r[v] - want[v]).abs() < 1e-6);
                }
            }
            _ => panic!(),
        }
    }

    #[test]
    fn disk_feasibility_check() {
        let g = generator::uniform(100, 3000, true, 1);
        let mut p = ClusterProfile::test(2);
        p.disk_budget = 512;
        assert!(matches!(
            run(&g, Algo::PageRank { supersteps: 1 }, &p),
            Err(Error::InsufficientDisk { .. })
        ));
    }
}
