//! Pregel+ analog: a distributed **in-memory** Pregel.
//!
//! Everything (states, adjacency lists, messages) lives in RAM, so there is
//! no disk cost at all — but (a) it *refuses to run* when the estimated
//! per-machine footprint exceeds the profile's RAM budget (the tables'
//! "Insufficient Main Memories" entries), and (b) message transmission
//! starts only **after** vertex computation finishes (§6: "in Pregel+'s
//! implementation, message transmission starts after computation
//! finishes"), so computation and communication do not overlap.

use super::{adj_bytes, trace, Algo, BaselineRun, MSG_BYTES, STATE_BYTES};
use crate::config::ClusterProfile;
use crate::error::{Error, Result};
use crate::graph::Graph;
use crate::net::Switch;
use crate::util::timer::timed;

/// Estimated per-machine footprint in bytes (states + adjacency + message
/// buffers on both sender and receiver side).
pub fn footprint_per_machine(g: &Graph, algo: Algo, n: usize) -> u64 {
    let v = g.num_vertices() as u64;
    let adj = adj_bytes(g, algo);
    // Message buffers: with a combiner at most one message per (vertex,
    // peer) is buffered, but the generation-side buffer still holds up to
    // the per-superstep message volume before combining kicks in; Pregel+
    // budgets for one message per edge.
    let msgs = g.num_edges() as u64 * MSG_BYTES;
    (STATE_BYTES * v + adj + msgs) / n as u64
}

/// Run the in-memory baseline.
pub fn run(g: &Graph, algo: Algo, profile: &ClusterProfile) -> Result<BaselineRun> {
    let n = profile.machines;
    let need = footprint_per_machine(g, algo, n);
    if need > profile.ram_budget {
        return Err(Error::InsufficientMemory {
            need_mb: need as f64 / (1024.0 * 1024.0),
            budget_mb: profile.ram_budget as f64 / (1024.0 * 1024.0),
        });
    }

    // Load: each machine reads its text partition from (local) DFS.
    let text_bytes = adj_bytes(g, algo) * 3 / 2; // text ≈ 1.5× binary
    let (load_secs, ()) = timed(|| {
        charge_disks_parallel(profile, text_bytes / n as u64);
    });

    // Compute: exact results via the shared tracer; per superstep, pay the
    // (non-overlapped) network transmission of combined cross messages.
    let (values, steps) = trace(g, algo);
    let switch = Switch::new(profile.net_bytes_per_sec, profile.latency_us);
    let nv = g.num_vertices() as u64;
    let (compute_secs, ()) = timed(|| {
        for st in &steps {
            // combiner: at most one message per (target, source machine)
            let combined = st.msgs.min(nv * n as u64);
            let cross = combined * MSG_BYTES * (n as u64 - 1) / n as u64;
            std::thread::scope(|s| {
                for _ in 0..n {
                    let switch = switch.clone();
                    let per_machine = (cross / n as u64) as usize;
                    s.spawn(move || switch.transmit(per_machine));
                }
            });
        }
    });

    Ok(BaselineRun {
        system: "Pregel+",
        preprocess_secs: 0.0,
        load_secs,
        compute_secs,
        supersteps: steps.len() as u64,
        values,
    })
}

/// Charge `bytes` on every machine's disk concurrently (parallel load).
pub(crate) fn charge_disks_parallel(profile: &ClusterProfile, bytes: u64) {
    let Some(rate) = profile.disk_bytes_per_sec else {
        return;
    };
    std::thread::scope(|s| {
        for _ in 0..profile.machines {
            s.spawn(move || {
                let bw = crate::util::diskio::DiskBw::new(rate);
                bw.charge(bytes as usize);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;

    fn tiny_profile(ram: u64) -> ClusterProfile {
        let mut p = ClusterProfile::test(4);
        p.ram_budget = ram;
        p.net_bytes_per_sec = 1e12;
        p
    }

    #[test]
    fn refuses_when_over_budget() {
        let g = generator::uniform(200, 2000, true, 1);
        let err = run(&g, Algo::PageRank { supersteps: 2 }, &tiny_profile(64)).unwrap_err();
        assert!(matches!(err, Error::InsufficientMemory { .. }), "{err}");
    }

    #[test]
    fn runs_and_matches_reference_when_it_fits() {
        let g = generator::uniform(100, 400, true, 2);
        let out = run(&g, Algo::PageRank { supersteps: 3 }, &tiny_profile(u64::MAX)).unwrap();
        match out.values {
            super::super::AlgoValues::Ranks(r) => {
                let want = crate::graph::reference::pagerank(&g, 3);
                for v in 0..100 {
                    assert!((r[v] - want[v]).abs() < 1e-6);
                }
            }
            _ => panic!(),
        }
        assert_eq!(out.supersteps, 3);
    }

    #[test]
    fn weighted_sssp_needs_more_memory_than_hashmin() {
        // The paper's Table 5 vs Table 7 asymmetry: SSSP stores edge
        // weights, doubling adjacency bytes.
        let g = generator::uniform(100, 1000, false, 3);
        let hm = footprint_per_machine(&g, Algo::HashMin, 4);
        let ss = footprint_per_machine(&g, Algo::Sssp { source: 0 }, 4);
        assert!(ss > hm);
    }
}
