//! HaLoop analog: iterative MapReduce with loop-aware caching.
//!
//! Cost structure (§2.2, §6): each iteration is a MapReduce job — map over
//! the cached graph partition (full rescan from local disk), shuffle the
//! messages (disk-buffered sort + network), reduce into new vertex values
//! written back to the DFS.  Job startup overhead per iteration is the
//! Hadoop tax that makes HaLoop the slowest distributed system in every
//! table.

use super::{adj_bytes, trace, Algo, BaselineRun, MSG_BYTES, STATE_BYTES};
use crate::config::ClusterProfile;
use crate::error::{Error, Result};
use crate::graph::Graph;
use crate::net::Switch;
use crate::util::diskio::DiskBw;
use crate::util::timer::timed;
use std::sync::Arc;

/// MapReduce job startup+teardown per iteration, scaled via latency.
pub fn job_overhead_secs(profile: &ClusterProfile) -> f64 {
    profile.latency_us as f64 * 1e-6 * 3000.0
}

pub fn disk_need_per_machine(g: &Graph, algo: Algo, n: usize) -> u64 {
    // cached partition + map spill + shuffle segments + reduce output
    (adj_bytes(g, algo) * 2 + 3 * g.num_edges() as u64 * MSG_BYTES) / n as u64
}

pub fn run(g: &Graph, algo: Algo, profile: &ClusterProfile) -> Result<BaselineRun> {
    let n = profile.machines;
    let need = disk_need_per_machine(g, algo, n);
    if need > profile.disk_budget {
        return Err(Error::InsufficientDisk {
            need_mb: need as f64 / (1024.0 * 1024.0),
            budget_mb: profile.disk_budget as f64 / (1024.0 * 1024.0),
        });
    }

    let (values, steps) = trace(g, algo);
    let adj = adj_bytes(g, algo);
    let text = adj * 3 / 2;
    let v_bytes = g.num_vertices() as u64 * STATE_BYTES;
    let switch = Switch::new(profile.net_bytes_per_sec, profile.latency_us);
    let overhead = job_overhead_secs(profile);
    let disks: Vec<Option<Arc<DiskBw>>> = (0..n)
        .map(|_| profile.disk_bytes_per_sec.map(DiskBw::new))
        .collect();

    let (compute_secs, ()) = timed(|| {
        for st in &steps {
            let msg_bytes = st.msgs * MSG_BYTES;
            std::thread::scope(|s| {
                for d in disks.iter() {
                    let switch = switch.clone();
                    let d = d.clone();
                    s.spawn(move || {
                        let per = |b: u64| (b / n as u64) as usize;
                        if let Some(d) = &d {
                            // map: rescan cached partition + spill sorted runs
                            d.charge(per(text + msg_bytes));
                        }
                        // shuffle cross-machine segments
                        switch.transmit(per(msg_bytes * (n as u64 - 1) / n as u64));
                        if let Some(d) = &d {
                            // reduce: merge runs + write new vertex values
                            d.charge(per(msg_bytes + 2 * v_bytes));
                        }
                    });
                }
            });
            // analyze:allow(sleep-slicing): baseline simulator — models
            // HaLoop's fixed per-iteration job-launch overhead; baselines
            // run standalone with no JobAbort latch to observe.
            std::thread::sleep(std::time::Duration::from_secs_f64(overhead));
        }
    });

    Ok(BaselineRun {
        system: "HaLoop",
        preprocess_secs: 0.0,
        load_secs: 0.0, // rescans the DFS every iteration — no load phase
        compute_secs,
        supersteps: steps.len() as u64,
        values,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;

    #[test]
    fn rescans_graph_every_iteration() {
        let g = generator::chain(30).with_unit_weights();
        let mut p = ClusterProfile::test(2);
        p.disk_bytes_per_sec = Some(50.0 * 1024.0 * 1024.0);
        p.latency_us = 0;
        let out = run(&g, Algo::Sssp { source: 0 }, &p).unwrap();
        // every one of the ~31 supersteps rescans text/n bytes per machine
        let text = adj_bytes(&g, Algo::Sssp { source: 0 }) * 3 / 2;
        let min = out.supersteps as f64 * (text / 4) as f64 / (50.0 * 1024.0 * 1024.0);
        assert!(out.compute_secs >= 0.5 * min);
    }

    #[test]
    fn values_match_reference() {
        let g = generator::uniform(60, 200, false, 7);
        let out = run(&g, Algo::HashMin, &ClusterProfile::test(2)).unwrap();
        match out.values {
            super::super::AlgoValues::Labels(l) => {
                assert_eq!(l, crate::graph::reference::components(&g));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn job_overhead_scales_with_latency() {
        let mut p = ClusterProfile::test(2);
        p.latency_us = 300;
        let a = job_overhead_secs(&p);
        p.latency_us = 80;
        let b = job_overhead_secs(&p);
        assert!(a > b);
    }
}
