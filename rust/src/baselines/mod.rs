//! Comparator systems (§6): in-memory Pregel+ and the out-of-core systems
//! the paper benchmarks against.  Each baseline computes *exact* algorithm
//! results (shared superstep tracer below) while paying its own system's
//! I/O / network / sorting cost structure against the same simulated
//! substrates (per-machine [`crate::util::diskio::DiskBw`] disks, the
//! shared [`crate::net::Switch`]) — which is precisely what the paper's
//! tables compare.
//!
//! | Module | Models | Cost structure |
//! |---|---|---|
//! | [`inmem`] | Pregel+ | all in RAM; compute *then* transmit (no overlap); refuses when over the RAM budget |
//! | [`pregelix`] | Pregelix | per superstep: external message sort + join scan + group-by, plus a fixed per-superstep dataflow overhead |
//! | [`haloop`] | HaLoop | per iteration: rescan the whole graph from DFS + MapReduce shuffle |
//! | [`graphchi`] | GraphChi | single PC; shard preprocessing; every iteration loads whole shards even for one active vertex |
//! | [`xstream`] | X-Stream | single PC; no preprocessing; every iteration streams **all** edges |
//!
//! The GraphD rows the baselines are compared against run through the
//! fluent session API ([`crate::session`]) via [`crate::bench::run_graphd`].

pub mod graphchi;
pub mod haloop;
pub mod inmem;
pub mod pregelix;
pub mod xstream;

use crate::graph::{reference, Graph};

/// Which algorithm a baseline runs.
#[derive(Clone, Copy, Debug)]
pub enum Algo {
    PageRank { supersteps: u64 },
    HashMin,
    Sssp { source: u32 },
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::PageRank { .. } => "pagerank",
            Algo::HashMin => "hashmin",
            Algo::Sssp { .. } => "sssp",
        }
    }

    /// Bytes per adjacency item this algorithm streams (weights for SSSP).
    pub fn item_size(&self) -> u64 {
        match self {
            Algo::Sssp { .. } => 8,
            _ => 4,
        }
    }
}

/// Exact results, indexed by dense vertex id.
#[derive(Clone, Debug)]
pub enum AlgoValues {
    Ranks(Vec<f32>),
    Labels(Vec<u32>),
    Dists(Vec<f32>),
}

/// Activity profile of one superstep — what each system's cost model is
/// driven by.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTrace {
    /// Vertices that compute this superstep.
    pub frontier_vertices: u64,
    /// Adjacency items those vertices scan.
    pub frontier_edges: u64,
    /// Messages generated.
    pub msgs: u64,
}

/// A timed baseline run (one table row fragment).
#[derive(Clone, Debug)]
pub struct BaselineRun {
    pub system: &'static str,
    pub preprocess_secs: f64,
    pub load_secs: f64,
    pub compute_secs: f64,
    pub supersteps: u64,
    pub values: AlgoValues,
}

/// Exact per-superstep activity trace + final values, shared by all
/// baselines (they differ only in the *cost* of executing it).
pub fn trace(g: &Graph, algo: Algo) -> (AlgoValues, Vec<StepTrace>) {
    match algo {
        Algo::PageRank { supersteps } => {
            let ne = g.num_edges() as u64;
            let nv = g.num_vertices() as u64;
            let steps = (0..supersteps)
                .map(|_| StepTrace {
                    frontier_vertices: nv,
                    frontier_edges: ne,
                    msgs: ne,
                })
                .collect();
            (AlgoValues::Ranks(reference::pagerank(g, supersteps)), steps)
        }
        Algo::HashMin => {
            let n = g.num_vertices();
            let mut label: Vec<u32> = (0..n as u32).collect();
            let mut steps = Vec::new();
            // superstep 0: everyone announces
            steps.push(StepTrace {
                frontier_vertices: n as u64,
                frontier_edges: g.num_edges() as u64,
                msgs: g.num_edges() as u64,
            });
            loop {
                let mut next = label.clone();
                for v in 0..n as u32 {
                    for &u in g.neighbors(v) {
                        if label[u as usize] < next[v as usize] {
                            next[v as usize] = label[u as usize];
                        }
                    }
                }
                let changed: Vec<u32> = (0..n as u32)
                    .filter(|&v| next[v as usize] != label[v as usize])
                    .collect();
                label = next;
                let fe: u64 = changed.iter().map(|&v| g.degree(v) as u64).sum();
                steps.push(StepTrace {
                    frontier_vertices: changed.len() as u64,
                    frontier_edges: fe,
                    msgs: fe,
                });
                if changed.is_empty() {
                    break;
                }
            }
            (AlgoValues::Labels(label), steps)
        }
        Algo::Sssp { source } => {
            let n = g.num_vertices();
            let mut dist = vec![f32::INFINITY; n];
            dist[source as usize] = 0.0;
            let mut in_next = vec![false; n];
            let mut frontier: Vec<u32> = vec![source];
            let mut steps = Vec::new();
            while !frontier.is_empty() {
                let fe: u64 = frontier.iter().map(|&v| g.degree(v) as u64).sum();
                steps.push(StepTrace {
                    frontier_vertices: frontier.len() as u64,
                    frontier_edges: fe,
                    msgs: fe,
                });
                let mut next: Vec<u32> = Vec::new();
                for &v in &frontier {
                    let ws = g.weights_of(v);
                    for (i, &u) in g.neighbors(v).iter().enumerate() {
                        let w = ws.map_or(1.0, |ws| ws[i]);
                        let nd = dist[v as usize] + w;
                        if nd < dist[u as usize] {
                            dist[u as usize] = nd;
                            if !in_next[u as usize] {
                                in_next[u as usize] = true;
                                next.push(u);
                            }
                        }
                    }
                }
                for &u in &next {
                    in_next[u as usize] = false;
                }
                frontier = next;
            }
            // final quiescence superstep (no messages)
            steps.push(StepTrace::default());
            (AlgoValues::Dists(dist), steps)
        }
    }
}

/// Estimated binary size of the graph partition data (adjacency items).
pub fn adj_bytes(g: &Graph, algo: Algo) -> u64 {
    g.num_edges() as u64 * algo.item_size()
}

/// Per-vertex state bytes (id, value, active, degree — Eq. 1).
pub const STATE_BYTES: u64 = 16;

/// Message record bytes (target + payload).
pub const MSG_BYTES: u64 = 8;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;

    #[test]
    fn trace_pagerank_constant_frontier() {
        let g = generator::uniform(50, 200, true, 1);
        let (vals, steps) = trace(&g, Algo::PageRank { supersteps: 4 });
        assert_eq!(steps.len(), 4);
        for s in &steps {
            assert_eq!(s.frontier_vertices, 50);
            assert_eq!(s.msgs, g.num_edges() as u64);
        }
        match vals {
            AlgoValues::Ranks(r) => assert_eq!(r.len(), 50),
            _ => panic!("wrong values"),
        }
    }

    #[test]
    fn trace_sssp_frontier_shrinks_to_zero() {
        let g = generator::chain(20).with_unit_weights();
        let (vals, steps) = trace(&g, Algo::Sssp { source: 0 });
        // chain: 20 frontier steps (one vertex each) + quiescence
        assert_eq!(steps.len(), 21);
        assert!(steps.iter().take(19).all(|s| s.frontier_vertices == 1));
        assert_eq!(steps.last().unwrap().msgs, 0);
        match vals {
            AlgoValues::Dists(d) => assert_eq!(d[19], 19.0),
            _ => panic!(),
        }
    }

    #[test]
    fn trace_sssp_weighted_matches_dijkstra() {
        let g = generator::random_weights(generator::uniform(60, 240, true, 4), 5);
        let (vals, _) = trace(&g, Algo::Sssp { source: 0 });
        let want = reference::sssp(&g, 0);
        match vals {
            AlgoValues::Dists(d) => {
                for v in 0..60 {
                    if want[v].is_finite() {
                        assert!((d[v] - want[v]).abs() < 1e-3, "v={v}");
                    } else {
                        assert!(d[v].is_infinite());
                    }
                }
            }
            _ => panic!(),
        }
    }

    #[test]
    fn trace_hashmin_matches_reference_components() {
        let g = generator::uniform(80, 150, false, 3);
        let (vals, steps) = trace(&g, Algo::HashMin);
        assert!(steps.len() >= 2);
        assert_eq!(steps.last().unwrap().msgs, 0, "ends quiescent");
        match vals {
            AlgoValues::Labels(l) => {
                assert_eq!(l, reference::components(&g));
            }
            _ => panic!(),
        }
    }
}
