//! Length-prefixed wire framing for the TCP transport backend.
//!
//! Every byte that crosses a real socket — data-plane batches, the
//! handshake, the control plane's barrier and abort traffic — travels as
//! one [`FrameKind`]-tagged frame with a fixed 24-byte header:
//!
//! ```text
//! offset  size  field
//!      0     4  magic  (0x47444631, "GDF1" — catches desynced streams)
//!      4     1  kind   (FrameKind discriminant)
//!      5     3  reserved (zero on encode, ignored on decode)
//!      8     4  src    (sending machine rank, u32 LE)
//!     12     8  step   (superstep / barrier sequence / attempt, u64 LE)
//!     20     4  len    (payload byte length, u32 LE, ≤ MAX_FRAME_LEN)
//! ```
//!
//! The codec is total: truncated, corrupted, or oversized input decodes to
//! a typed [`Error::Io`]-family error, never a panic — a malformed peer
//! must surface as a job failure with a cause, not take the process down.
//! The pure [`encode_frame`]/[`decode_frame`] pair is what the property
//! tests round-trip; [`write_frame`]/[`read_frame_into`] are the streaming
//! forms the per-peer socket threads use (reads land in `msg::BufPool`
//! blocks so received payloads recycle like every other spine buffer).

use crate::error::{Error, Result};
use std::io::{Read, Write};

/// Frame-header magic ("GDF1"): the first sanity check on every read.
pub const MAGIC: u32 = 0x4744_4631;

/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 24;

/// Upper bound on a single frame's payload.  Generously above the spine's
/// buffer caps (`msg::DEFAULT_MAX_BUF_BYTES` is 16 MB); a length field past
/// this is a corrupted or hostile stream, not a big batch.
pub const MAX_FRAME_LEN: usize = 64 << 20;

/// What a frame carries — the data plane mirrors [`super::Payload`], the
/// rest is handshake and control traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Message records for superstep `step` ([`super::Payload::Data`]).
    Data = 1,
    /// End tag: sender exhausted its OMS towards us for `step`.
    End = 2,
    /// Vertex records during graph loading ([`super::Payload::Load`]).
    Load = 3,
    /// End of the loading phase from this sender.
    LoadEnd = 4,
    /// Handshake: `src` = rank, `step` = attempt; payload carries the
    /// sender's data-plane address and its local resume proposal.
    Hello = 5,
    /// Handshake reply (leader → follower): the full rank → data-address
    /// roster plus the cluster-agreed resume superstep.
    Roster = 6,
    /// Control plane, follower → leader: a serialized barrier deposit
    /// (`step` = barrier sequence; payload starts with the barrier id).
    BarrierReport = 7,
    /// Control plane, leader → followers: the serialized leader result for
    /// a barrier round.
    BarrierDecision = 8,
    /// Control plane: a serialized [`crate::worker::sync::AbortCause`] —
    /// the `JobAbort` latch's remote trip path.
    Abort = 9,
    /// Clean shutdown notice: subsequent EOF from this peer is expected,
    /// not a death.
    Goodbye = 10,
}

impl FrameKind {
    fn from_u8(b: u8) -> Option<Self> {
        Some(match b {
            1 => FrameKind::Data,
            2 => FrameKind::End,
            3 => FrameKind::Load,
            4 => FrameKind::LoadEnd,
            5 => FrameKind::Hello,
            6 => FrameKind::Roster,
            7 => FrameKind::BarrierReport,
            8 => FrameKind::BarrierDecision,
            9 => FrameKind::Abort,
            10 => FrameKind::Goodbye,
            _ => return None,
        })
    }
}

/// A decoded frame header: `(kind, src, step, payload_len)`.
pub type Header = (FrameKind, u32, u64, usize);

fn bad(what: impl Into<String>) -> Error {
    Error::Io(std::io::Error::new(
        std::io::ErrorKind::InvalidData,
        what.into(),
    ))
}

fn short(what: impl Into<String>) -> Error {
    Error::Io(std::io::Error::new(
        std::io::ErrorKind::UnexpectedEof,
        what.into(),
    ))
}

/// Encode a frame header into its fixed 24-byte form.
pub fn encode_header(kind: FrameKind, src: u32, step: u64, len: usize) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    h[4] = kind as u8;
    // h[5..8] reserved, zero
    h[8..12].copy_from_slice(&src.to_le_bytes());
    h[12..20].copy_from_slice(&step.to_le_bytes());
    h[20..24].copy_from_slice(&(len as u32).to_le_bytes());
    h
}

/// Decode a 24-byte frame header.  Typed errors, never panics: a wrong
/// magic, unknown kind, or oversized length is an
/// [`std::io::ErrorKind::InvalidData`] wrapped in [`Error::Io`].
pub fn decode_header(h: &[u8; HEADER_LEN]) -> Result<Header> {
    let magic = u32::from_le_bytes([h[0], h[1], h[2], h[3]]);
    if magic != MAGIC {
        return Err(bad(format!(
            "bad frame magic {magic:#010x} (want {MAGIC:#010x}): peer stream desynced or corrupt"
        )));
    }
    let kind = FrameKind::from_u8(h[4])
        .ok_or_else(|| bad(format!("unknown frame kind {}", h[4])))?;
    let src = u32::from_le_bytes([h[8], h[9], h[10], h[11]]);
    let step = u64::from_le_bytes([
        h[12], h[13], h[14], h[15], h[16], h[17], h[18], h[19],
    ]);
    let len = u32::from_le_bytes([h[20], h[21], h[22], h[23]]) as usize;
    if len > MAX_FRAME_LEN {
        return Err(bad(format!(
            "frame length {len} exceeds MAX_FRAME_LEN ({MAX_FRAME_LEN}): corrupt length prefix"
        )));
    }
    Ok((kind, src, step, len))
}

/// Pure whole-frame encode: header + payload as one buffer (the property
/// tests' round-trip subject; the socket paths use [`write_frame`]).
pub fn encode_frame(kind: FrameKind, src: u32, step: u64, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&encode_header(kind, src, step, payload.len()));
    out.extend_from_slice(payload);
    out
}

/// Pure whole-frame decode: parse one frame off the front of `buf`,
/// returning the header and the payload slice.  Truncation (buffer shorter
/// than the header, or than the advertised payload) is a typed
/// [`std::io::ErrorKind::UnexpectedEof`] error.
pub fn decode_frame(buf: &[u8]) -> Result<(Header, &[u8])> {
    if buf.len() < HEADER_LEN {
        return Err(short(format!(
            "truncated frame header: {} of {HEADER_LEN} bytes",
            buf.len()
        )));
    }
    let mut h = [0u8; HEADER_LEN];
    h.copy_from_slice(&buf[..HEADER_LEN]);
    let (kind, src, step, len) = decode_header(&h)?;
    let rest = &buf[HEADER_LEN..];
    if rest.len() < len {
        return Err(short(format!(
            "truncated frame payload: {} of {len} bytes",
            rest.len()
        )));
    }
    Ok(((kind, src, step, len), &rest[..len]))
}

/// Write one frame (header + payload) to `w`.  One `write_all` for the
/// header and one for the payload: the payload buffer goes onto the wire
/// as-is, so a checked-out `BufPool` block is transmitted without a copy.
pub fn write_frame(w: &mut impl Write, kind: FrameKind, src: u32, step: u64, payload: &[u8]) -> Result<()> {
    w.write_all(&encode_header(kind, src, step, payload.len()))?;
    if !payload.is_empty() {
        w.write_all(payload)?;
    }
    Ok(())
}

/// Read one frame from `r`, depositing the payload into `payload` (cleared
/// and resized — pass a recycled `BufPool` block to keep received payloads
/// on the pool economy).  Returns `Ok(None)` on EOF *at a frame boundary*
/// (the clean-close case); EOF mid-header or mid-payload is a typed
/// [`std::io::ErrorKind::UnexpectedEof`] error — the peer died mid-frame.
pub fn read_frame_into(
    r: &mut impl Read,
    payload: &mut Vec<u8>,
) -> Result<Option<(FrameKind, u32, u64)>> {
    let mut h = [0u8; HEADER_LEN];
    // Hand-rolled first read so "no more frames" and "died mid-frame" are
    // distinguishable: read_exact collapses both into UnexpectedEof.
    let mut got = 0usize;
    while got < HEADER_LEN {
        match r.read(&mut h[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(short(format!(
                    "peer closed mid-header: {got} of {HEADER_LEN} bytes"
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(Error::Io(e)),
        }
    }
    let (kind, src, step, len) = decode_header(&h)?;
    payload.clear();
    payload.resize(len, 0);
    if len > 0 {
        r.read_exact(&mut payload[..]).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                short(format!("peer closed mid-payload: wanted {len} bytes"))
            } else {
                Error::Io(e)
            }
        })?;
    }
    Ok(Some((kind, src, step)))
}

/// Serialize an abort cause for the control plane's [`FrameKind::Abort`]
/// frame: `machine u32 | superstep u64 | unit_len u8 | unit | cause`.
pub fn encode_cause(machine: u32, unit: &str, superstep: u64, cause: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(13 + unit.len() + cause.len());
    out.extend_from_slice(&machine.to_le_bytes());
    out.extend_from_slice(&superstep.to_le_bytes());
    out.push(unit.len().min(255) as u8);
    out.extend_from_slice(&unit.as_bytes()[..unit.len().min(255)]);
    out.extend_from_slice(cause.as_bytes());
    out
}

/// Decode an abort-cause payload back into `(machine, unit, superstep,
/// cause)`.  The unit name is interned to the engine's `&'static` set —
/// [`crate::worker::sync::AbortCause::unit`] is `&'static str`, so an
/// unknown name (version skew across processes) lands on `"net"`.
pub fn decode_cause(b: &[u8]) -> Result<(u32, &'static str, u64, String)> {
    if b.len() < 13 {
        return Err(short("truncated abort-cause payload"));
    }
    let machine = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    let superstep = u64::from_le_bytes([b[4], b[5], b[6], b[7], b[8], b[9], b[10], b[11]]);
    let ulen = b[12] as usize;
    if b.len() < 13 + ulen {
        return Err(short("truncated abort-cause unit name"));
    }
    let unit = intern_unit(std::str::from_utf8(&b[13..13 + ulen]).unwrap_or("net"));
    let cause = String::from_utf8_lossy(&b[13 + ulen..]).into_owned();
    Ok((machine, unit, superstep, cause))
}

/// Map a wire unit name onto the engine's `&'static` unit-name set.
pub fn intern_unit(s: &str) -> &'static str {
    match s {
        "U_c" => "U_c",
        "U_s" => "U_s",
        "U_r" => "U_r",
        "load" => "load",
        "recode" => "recode",
        _ => "net",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite;

    #[test]
    fn header_roundtrip_all_kinds() {
        for kind in [
            FrameKind::Data,
            FrameKind::End,
            FrameKind::Load,
            FrameKind::LoadEnd,
            FrameKind::Hello,
            FrameKind::Roster,
            FrameKind::BarrierReport,
            FrameKind::BarrierDecision,
            FrameKind::Abort,
            FrameKind::Goodbye,
        ] {
            let h = encode_header(kind, 3, 7, 99);
            let (k, src, step, len) = decode_header(&h).unwrap();
            assert_eq!((k, src, step, len), (kind, 3, 7, 99));
        }
    }

    #[test]
    fn bad_magic_unknown_kind_oversized_len_are_typed_errors() {
        let mut h = encode_header(FrameKind::Data, 0, 0, 0);
        h[0] ^= 0xFF;
        assert!(matches!(decode_header(&h), Err(Error::Io(_))));

        let mut h = encode_header(FrameKind::Data, 0, 0, 0);
        h[4] = 200;
        assert!(matches!(decode_header(&h), Err(Error::Io(_))));

        let mut h = encode_header(FrameKind::Data, 0, 0, 0);
        h[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_header(&h).unwrap_err();
        assert!(err.to_string().contains("MAX_FRAME_LEN"), "{err}");
    }

    #[test]
    fn decode_frame_truncation_is_unexpected_eof() {
        let f = encode_frame(FrameKind::Data, 1, 2, &[1, 2, 3, 4]);
        for cut in 0..f.len() {
            let err = decode_frame(&f[..cut]).unwrap_err();
            match err {
                Error::Io(e) => {
                    assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof, "cut={cut}")
                }
                other => panic!("cut={cut}: want Error::Io, got {other}"),
            }
        }
        let ((k, src, step, len), body) = decode_frame(&f).unwrap();
        assert_eq!((k, src, step, len), (FrameKind::Data, 1, 2, 4));
        assert_eq!(body, &[1, 2, 3, 4]);
    }

    #[test]
    fn stream_roundtrip_reuses_payload_buffer() {
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::Data, 2, 9, &[7; 10]).unwrap();
        write_frame(&mut wire, FrameKind::End, 2, 9, &[]).unwrap();
        write_frame(&mut wire, FrameKind::Goodbye, 2, 0, &[]).unwrap();
        let mut r = &wire[..];
        let mut buf = vec![0xAAu8; 64]; // dirty recycled block
        assert_eq!(
            read_frame_into(&mut r, &mut buf).unwrap(),
            Some((FrameKind::Data, 2, 9))
        );
        assert_eq!(buf, vec![7u8; 10]);
        assert_eq!(
            read_frame_into(&mut r, &mut buf).unwrap(),
            Some((FrameKind::End, 2, 9))
        );
        assert!(buf.is_empty());
        assert_eq!(
            read_frame_into(&mut r, &mut buf).unwrap(),
            Some((FrameKind::Goodbye, 2, 0))
        );
        assert_eq!(read_frame_into(&mut r, &mut buf).unwrap(), None, "clean EOF");
    }

    #[test]
    fn stream_eof_mid_frame_is_typed_error() {
        let f = encode_frame(FrameKind::Data, 0, 0, &[1, 2, 3]);
        // Mid-header.
        let mut r = &f[..10];
        let mut buf = Vec::new();
        assert!(matches!(
            read_frame_into(&mut r, &mut buf),
            Err(Error::Io(_))
        ));
        // Mid-payload.
        let mut r = &f[..HEADER_LEN + 1];
        assert!(matches!(
            read_frame_into(&mut r, &mut buf),
            Err(Error::Io(_))
        ));
    }

    #[test]
    fn cause_roundtrip_interns_units() {
        let b = encode_cause(3, "U_s", 12, "injected fault: transient network send failure");
        let (m, u, s, c) = decode_cause(&b).unwrap();
        assert_eq!((m, u, s), (3, "U_s", 12));
        assert!(c.contains("transient"));
        // Unknown unit names land on "net", never a dangling reference.
        let b = encode_cause(0, "U_x", 0, "x");
        assert_eq!(decode_cause(&b).unwrap().1, "net");
    }

    #[test]
    fn prop_frame_roundtrip_arbitrary_payloads() {
        proptest_lite::run(200, |g| {
            let kind = match g.usize_in(0, 10) {
                0 => FrameKind::Data,
                1 => FrameKind::End,
                2 => FrameKind::Load,
                3 => FrameKind::LoadEnd,
                4 => FrameKind::Hello,
                5 => FrameKind::Roster,
                6 => FrameKind::BarrierReport,
                7 => FrameKind::BarrierDecision,
                8 => FrameKind::Abort,
                _ => FrameKind::Goodbye,
            };
            let src = g.u32_below(1 << 16);
            let step = g.u64();
            let payload: Vec<u8> = g
                .vec_u32(0, 2048, 256)
                .into_iter()
                .map(|v| v as u8)
                .collect();
            let wire = encode_frame(kind, src, step, &payload);
            prop_assert!(
                g,
                wire.len() == HEADER_LEN + payload.len(),
                "wire len {} != header + {}",
                wire.len(),
                payload.len()
            );
            let ((k, s2, st, len), body) = match decode_frame(&wire) {
                Ok(v) => v,
                Err(e) => {
                    g.fail(format!("decode failed on valid frame: {e}"));
                    return;
                }
            };
            prop_assert!(g, k == kind, "kind {k:?} != {kind:?}");
            prop_assert!(g, s2 == src && st == step, "src/step mismatch");
            prop_assert!(g, len == payload.len() && body == &payload[..], "payload mismatch");
        });
    }

    #[test]
    fn prop_corrupted_frames_never_panic() {
        proptest_lite::run(300, |g| {
            let payload: Vec<u8> = g
                .vec_u32(0, 256, 256)
                .into_iter()
                .map(|v| v as u8)
                .collect();
            let mut wire = encode_frame(FrameKind::Data, g.u32_below(8), g.u64(), &payload);
            // Corrupt one byte, truncate, or both — decode must return
            // Ok or a typed error, never panic.
            if g.bool(0.7) && !wire.is_empty() {
                let at = g.usize_in(0, wire.len());
                wire[at] ^= 1 + (g.u32_below(255) as u8);
            }
            if g.bool(0.5) {
                let keep = g.usize_in(0, wire.len() + 1);
                wire.truncate(keep);
            }
            match decode_frame(&wire) {
                Ok(((k, _, _, len), body)) => {
                    // A surviving decode must at least be self-consistent.
                    prop_assert!(g, body.len() == len, "inconsistent len after decode");
                    prop_assert!(g, FrameKind::from_u8(k as u8) == Some(k), "bad kind survived");
                }
                Err(Error::Io(_)) => {}
                Err(other) => {
                    g.fail(format!("non-Io error from frame decode: {other}"));
                }
            }
            // Streaming form on the same bytes: same contract.
            let mut r = &wire[..];
            let mut buf = Vec::new();
            match read_frame_into(&mut r, &mut buf) {
                Ok(_) | Err(Error::Io(_)) => {}
                Err(other) => {
                    g.fail(format!("non-Io error from read_frame_into: {other}"));
                }
            }
        });
    }
}
