//! Cluster network: a pluggable transport with two backends.
//!
//! The engine talks to the network through one pair of endpoint types —
//! [`NetSender`] / [`NetReceiver`] — built by whichever backend a
//! [`Transport`] was connected with (`-c transport=sim|tcp`, see
//! [`TransportKind`]):
//!
//! * **[`sim`]** (default, the seed backend): all `n` machines are threads
//!   in this process; batches cross per-destination std `mpsc` channels and
//!   a shared [`Switch`] models the paper's contended Gigabit medium
//!   (§3.3.1) by blocking senders for the simulated wire time.  Every
//!   existing test and bench runs here.
//! * **[`tcp`]**: each machine is its own OS process; batches are framed
//!   ([`frame`]) over `std::net::TcpStream` by per-peer writer/reader
//!   threads that put checked-out `msg::BufPool` blocks straight onto the
//!   wire and recycle received blocks back into the pool.  A control
//!   channel beside the data sockets carries the distributed barrier
//!   rounds and the `JobAbort` latch's remote trips, so
//!   [`crate::error::Error::JobFailed`] keeps its machine/unit/superstep
//!   attribution across process boundaries.
//!
//! The endpoint types are backend-agnostic on purpose: under tcp the
//! per-peer writer threads drain the same `mpsc` queues a sim receiver
//! would, and the reader threads feed decoded frames into the same
//! receiver queue — so `worker/units.rs` is bit-for-bit the same code on
//! both backends, and equivalence is a test (`tests/transport.rs`), not a
//! hope.
//!
//! **Failure observation.**  When a [`crate::worker::sync::JobAbort`] is
//! attached at build time, every potentially-unbounded wait in this module
//! observes it: [`NetReceiver::recv`] polls the abort flag while blocked (a
//! dead sender can never deliver the end tags it owes us),
//! [`NetSender::send`] surfaces the abort cause instead of panicking when
//! the peer hung up, and [`Switch::transmit`] breaks out of long simulated
//! transmissions once the job is dead — so no unit can outlive a poisoned
//! job inside the network layer.

use crate::error::{Error, Result};
use crate::worker::sync::JobAbort;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

pub mod frame;
pub mod sim;
pub mod tcp;

pub use sim::{build, Switch};

/// How often blocked channel/switch waits re-check the abort flag.
pub(crate) const ABORT_POLL: Duration = Duration::from_millis(10);

/// Which transport backend a job runs on (`-c transport=sim|tcp`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process simulator: machines are threads, the [`Switch`] models
    /// wire time.  The default, and the only backend benches/tables use.
    #[default]
    Sim,
    /// Multi-process TCP: this process runs *one* machine and exchanges
    /// framed batches with its peers over real sockets (see [`tcp`]).
    Tcp,
}

impl TransportKind {
    /// Parse the config-string form (`sim` | `tcp`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "sim" => Ok(TransportKind::Sim),
            "tcp" => Ok(TransportKind::Tcp),
            other => Err(Error::Config(format!(
                "bad value '{other}' for 'transport' (want sim | tcp)"
            ))),
        }
    }

    /// The config-string name.
    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Sim => "sim",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// A connected transport: the endpoint pairs this process owns plus the
/// backend's shared byte ledger.  Under [`TransportKind::Sim`] that is all
/// `n` machines (threads) and the modeled switch; under
/// [`TransportKind::Tcp`] it is exactly one machine (this process's rank)
/// and a ledger-mode switch, plus the live [`tcp::TcpCluster`].
pub struct Transport {
    /// One `(sender, receiver)` pair per machine this process runs, in
    /// machine order.
    pub endpoints: Vec<(NetSender, NetReceiver)>,
    /// The backend's byte ledger (wire vs local split for metrics).
    pub switch: Arc<Switch>,
    /// The TCP cluster handle (handshake results, control plane, clean
    /// shutdown); `None` under sim.
    pub cluster: Option<Arc<tcp::TcpCluster>>,
}

impl Transport {
    /// Connect the simulator backend: `n` in-process machines over the
    /// modeled switch (identical to [`build`], boxed for symmetry).
    pub fn sim(
        n: usize,
        bytes_per_sec: f64,
        latency_us: u64,
        local_fast: bool,
        abort: Option<Arc<JobAbort>>,
    ) -> Transport {
        let (endpoints, switch) = build(n, bytes_per_sec, latency_us, local_fast, abort);
        Transport {
            endpoints,
            switch,
            cluster: None,
        }
    }

    /// Connect the TCP backend: handshake with the coordinator, establish
    /// the full data mesh, and return this rank's single endpoint pair.
    /// Blocks until every peer is connected (bounded by the handshake
    /// timeout) — see [`tcp::TcpCluster::connect`].
    pub fn tcp(
        opts: tcp::TcpOpts,
        pool: Arc<crate::msg::BufPool>,
        abort: Arc<JobAbort>,
        tracer: &Arc<crate::trace::Tracer>,
    ) -> Result<Transport> {
        let (endpoint, switch, cluster) = tcp::TcpCluster::connect(opts, pool, abort, tracer)?;
        Ok(Transport {
            endpoints: vec![endpoint],
            switch,
            cluster: Some(cluster),
        })
    }
}

/// What a network batch carries.
#[derive(Debug)]
pub enum Payload {
    /// Message records for superstep `step`.
    Data(Vec<u8>),
    /// End tag: the sender has exhausted its OMS towards us for `step`.
    End,
    /// Vertex records during graph loading (§3.4).
    Load(Vec<u8>),
    /// End of loading phase from this sender.
    LoadEnd,
}

/// A framed batch on the wire.
#[derive(Debug)]
pub struct Batch {
    /// Sending machine.
    pub src: usize,
    /// Superstep (or recoding phase) the batch belongs to.
    pub step: u64,
    /// What the batch carries.
    pub payload: Payload,
}

impl Batch {
    /// Bytes the batch occupies on the wire: a 16-byte frame + the data.
    /// (The TCP backend's physical frame header is 24 bytes — see
    /// [`frame`] — but the *metric* stays this backend-independent value
    /// so sim and tcp runs report comparable byte counts.)
    pub fn wire_bytes(&self) -> usize {
        16 + match &self.payload {
            Payload::Data(d) | Payload::Load(d) => d.len(),
            Payload::End | Payload::LoadEnd => 0,
        }
    }
}

/// Sending half of a machine's endpoint.  Clonable: U_s owns one clone,
/// U_c takes another for the stall-mode ablation and the loading phase.
/// Real-time enqueue order across clones is preserved by the mpsc queue,
/// so the FIFO property §4 relies on still holds.
#[derive(Clone)]
pub struct NetSender {
    /// This endpoint's machine index.
    pub me: usize,
    switch: Arc<Switch>,
    txs: Vec<Sender<Batch>>,
    sent_bytes: u64,
    local_bytes: u64,
    /// Deliver `dst == me` batches without touching the switch (the
    /// local-delivery fast path): a machine talking to itself crosses no
    /// physical medium, so it pays zero simulated wire time.
    local_fast: bool,
    /// Job-abort latch: a hung-up peer reports the abort cause instead of
    /// an opaque channel error.
    abort: Option<Arc<JobAbort>>,
}

impl NetSender {
    /// Simulate transmission through the shared switch, then deliver —
    /// except batches to `self` with the fast path on, which skip the
    /// switch entirely and are only *counted* (as local bytes).  Under the
    /// TCP backend the switch is a pure ledger (no sleep) and "deliver"
    /// enqueues to the destination's per-peer writer thread, which frames
    /// the buffer onto the socket.
    /// Errors if the destination has hung up: with the job's abort latch
    /// tripped this surfaces the original failure cause (typed
    /// [`Error::JobFailed`]); without one, a hung-up peer is a corrupt
    /// cluster state in its own right.
    pub fn send(&mut self, dst: usize, step: u64, payload: Payload) -> Result<()> {
        let b = Batch {
            src: self.me,
            step,
            payload,
        };
        let bytes = b.wire_bytes();
        if self.local_fast && dst == self.me {
            self.switch.account_local(bytes);
            self.local_bytes += bytes as u64;
        } else {
            self.switch.transmit(bytes);
            self.sent_bytes += bytes as u64;
        }
        if self.txs[dst].send(b).is_err() {
            if let Some(c) = self.abort.as_ref().and_then(|a| a.cause()) {
                return Err(c.to_error());
            }
            return Err(Error::CorruptStream(format!(
                "peer receiver hung up: {} -> {dst} step {step}",
                self.me
            )));
        }
        Ok(())
    }

    /// Number of machines in the network (including this one).
    pub fn peers(&self) -> usize {
        self.txs.len()
    }

    /// Is the local-delivery fast path active on this endpoint?
    pub fn local_fast(&self) -> bool {
        self.local_fast
    }

    /// Bytes this endpoint pushed through the switch.
    pub fn sent_bytes(&self) -> u64 {
        self.sent_bytes
    }

    /// Bytes this endpoint delivered to itself, bypassing the switch.
    pub fn local_sent_bytes(&self) -> u64 {
        self.local_bytes
    }
}

/// Receiving half of a machine's endpoint (owned by U_r).
pub struct NetReceiver {
    /// This endpoint's machine index.
    pub me: usize,
    rx: Receiver<Batch>,
    abort: Option<Arc<JobAbort>>,
}

impl NetReceiver {
    /// Blocking receive.  With the job's abort latch attached, the block
    /// is sliced so a tripped abort surfaces as its typed error — the end
    /// tags a dead machine owes us will never arrive, and this is the wait
    /// every surviving U_r wedges in without it.
    pub fn recv(&self) -> Result<Batch> {
        let Some(a) = &self.abort else {
            return self
                .rx
                .recv()
                .map_err(|_| Error::CorruptStream("all senders hung up".into()));
        };
        loop {
            // Hot path: one atomic flag read per batch; the cause Mutex is
            // only touched once the latch actually tripped.
            if a.aborted() {
                if let Some(c) = a.cause() {
                    return Err(c.to_error());
                }
            }
            match self.rx.recv_timeout(ABORT_POLL) {
                Ok(b) => return Ok(b),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(match a.cause() {
                        Some(c) => c.to_error(),
                        None => Error::CorruptStream("all senders hung up".into()),
                    })
                }
            }
        }
    }

    /// Receive with a timeout.  `Ok(Some(batch))` on delivery, `Ok(None)`
    /// when `d` elapsed with nothing arriving, and `Err` with the same
    /// typed causes as [`NetReceiver::recv`] when the job aborted or every
    /// sender hung up — so callers can tell "nothing yet" from "nothing
    /// ever again", instead of the old bare `Option` that silently
    /// swallowed the abort cause.
    pub fn recv_timeout(&self, d: Duration) -> Result<Option<Batch>> {
        let deadline = std::time::Instant::now() + d;
        loop {
            if let Some(a) = &self.abort {
                if a.aborted() {
                    if let Some(c) = a.cause() {
                        return Err(c.to_error());
                    }
                }
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let slice = (deadline - now).min(ABORT_POLL);
            match self.rx.recv_timeout(slice) {
                Ok(b) => return Ok(Some(b)),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(match self.abort.as_ref().and_then(|a| a.cause()) {
                        Some(c) => c.to_error(),
                        None => Error::CorruptStream("all senders hung up".into()),
                    })
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn fifo_per_pair() {
        let (mut eps, _) = build(2, 1e12, 0, false, None);
        let (_, rx1) = eps.pop().unwrap();
        let (mut tx0, _rx0) = eps.pop().unwrap();
        for i in 0..100u64 {
            tx0.send(1, i, Payload::Data(vec![i as u8])).unwrap();
        }
        for i in 0..100u64 {
            let b = rx1.recv().unwrap();
            assert_eq!(b.step, i);
            assert_eq!(b.src, 0);
        }
    }

    #[test]
    fn cross_clone_order_preserved_by_enqueue_time() {
        let (mut eps, _) = build(2, 1e12, 0, false, None);
        let (_, rx1) = eps.pop().unwrap();
        let (tx, _rx0) = eps.pop().unwrap();
        let mut a = tx.clone();
        let mut b = tx;
        a.send(1, 1, Payload::Data(vec![])).unwrap();
        b.send(1, 2, Payload::Data(vec![])).unwrap();
        a.send(1, 3, Payload::End).unwrap();
        assert_eq!(rx1.recv().unwrap().step, 1);
        assert_eq!(rx1.recv().unwrap().step, 2);
        assert_eq!(rx1.recv().unwrap().step, 3);
    }

    #[test]
    fn switch_throttles_rate() {
        // 1 MB at 10 MB/s must take >= ~90ms.
        let sw = Switch::new(10.0 * 1024.0 * 1024.0, 0);
        let t = Instant::now();
        sw.transmit(1024 * 1024);
        assert!(t.elapsed() >= Duration::from_millis(90), "{:?}", t.elapsed());
        assert_eq!(sw.total_bytes(), 1024 * 1024);
    }

    #[test]
    fn switch_serializes_contending_senders() {
        // Two threads sending 500 KB each through a 10 MB/s switch: total
        // wall time must reflect the *sum* (shared medium), ~100ms, not 50.
        let sw = Switch::new(10.0 * 1024.0 * 1024.0, 0);
        let t = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..2 {
                let sw = &sw;
                s.spawn(move || sw.transmit(512 * 1024));
            }
        });
        assert!(t.elapsed() >= Duration::from_millis(85), "{:?}", t.elapsed());
    }

    #[test]
    fn ledger_switch_accounts_without_sleeping() {
        let sw = Switch::ledger(None);
        let t = Instant::now();
        sw.transmit(64 << 20);
        assert!(t.elapsed() < Duration::from_millis(50), "{:?}", t.elapsed());
        assert_eq!(sw.total_bytes(), 64 << 20);
    }

    #[test]
    fn loopback_delivery() {
        let (mut eps, _) = build(1, 1e12, 0, false, None);
        let (mut tx, rx) = eps.pop().unwrap();
        tx.send(0, 3, Payload::End).unwrap();
        let b = rx.recv().unwrap();
        assert!(matches!(b.payload, Payload::End));
        assert_eq!(b.step, 3);
    }

    #[test]
    fn local_fast_path_bypasses_switch() {
        // A slow switch that would take ~100ms for this batch: the local
        // fast path must deliver instantly and charge zero wire bytes.
        let (mut eps, switch) = build(1, 10.0 * 1024.0 * 1024.0, 0, true, None);
        let (mut tx, rx) = eps.pop().unwrap();
        let t = Instant::now();
        tx.send(0, 0, Payload::Data(vec![0; 1024 * 1024])).unwrap();
        assert!(t.elapsed() < Duration::from_millis(50), "{:?}", t.elapsed());
        let b = rx.recv().unwrap();
        assert!(matches!(b.payload, Payload::Data(_)));
        assert_eq!(switch.total_bytes(), 0, "no wire traffic for dst == me");
        assert_eq!(switch.local_bytes(), 1024 * 1024 + 16);
        assert_eq!(tx.sent_bytes(), 0);
        assert_eq!(tx.local_sent_bytes(), 1024 * 1024 + 16);
        assert!(tx.local_fast());
    }

    #[test]
    fn remote_batches_still_transit_with_fast_path_on() {
        let (mut eps, switch) = build(2, 1e12, 0, true, None);
        let (_, rx1) = eps.pop().unwrap();
        let (mut tx0, _rx0) = eps.pop().unwrap();
        tx0.send(1, 0, Payload::Data(vec![0; 84])).unwrap();
        assert_eq!(rx1.recv().unwrap().step, 0);
        assert_eq!(switch.total_bytes(), 100);
        assert_eq!(switch.local_bytes(), 0);
    }

    #[test]
    fn recv_unblocks_on_abort_with_typed_cause() {
        use crate::worker::sync::AbortCause;
        let abort = JobAbort::new();
        let (mut eps, _) = build(2, 1e12, 0, false, Some(abort.clone()));
        let (_, rx1) = eps.pop().unwrap();
        // Keep machine 0's sender alive so the channel never disconnects:
        // the only way out of the blocked recv is the abort flag.
        let (_tx0, _rx0) = eps.pop().unwrap();
        let t = std::thread::spawn(move || rx1.recv());
        std::thread::sleep(Duration::from_millis(30));
        abort.trip(AbortCause {
            machine: 1,
            unit: "U_c",
            superstep: 2,
            cause: "boom".into(),
        });
        let err = t.join().unwrap().unwrap_err();
        assert!(matches!(
            err,
            Error::JobFailed { machine: 1, superstep: 2, .. }
        ));
    }

    #[test]
    fn recv_timeout_distinguishes_timeout_from_abort() {
        use crate::worker::sync::AbortCause;
        let abort = JobAbort::new();
        let (mut eps, _) = build(2, 1e12, 0, false, Some(abort.clone()));
        let (_, rx1) = eps.pop().unwrap();
        let (mut tx0, _rx0) = eps.pop().unwrap();
        // Nothing sent yet: a short wait is a timeout, not an error.
        assert!(matches!(
            rx1.recv_timeout(Duration::from_millis(20)),
            Ok(None)
        ));
        // A delivered batch arrives as Ok(Some(..)).
        tx0.send(1, 5, Payload::End).unwrap();
        let got = rx1.recv_timeout(Duration::from_millis(200)).unwrap();
        assert_eq!(got.map(|b| b.step), Some(5));
        // After the abort trips, the cause surfaces as the typed error —
        // the old bare-Option form returned None here, indistinguishable
        // from an innocent timeout.
        abort.trip(AbortCause {
            machine: 0,
            unit: "U_s",
            superstep: 7,
            cause: "boom".into(),
        });
        let err = rx1.recv_timeout(Duration::from_millis(200)).unwrap_err();
        assert!(matches!(
            err,
            Error::JobFailed { machine: 0, superstep: 7, .. }
        ));
    }

    #[test]
    fn wire_bytes_includes_frame() {
        let b = Batch {
            src: 0,
            step: 0,
            payload: Payload::Data(vec![0; 100]),
        };
        assert_eq!(b.wire_bytes(), 116);
        let e = Batch {
            src: 0,
            step: 0,
            payload: Payload::End,
        };
        assert_eq!(e.wire_bytes(), 16);
    }

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("sim").unwrap(), TransportKind::Sim);
        assert_eq!(TransportKind::parse("tcp").unwrap(), TransportKind::Tcp);
        assert!(TransportKind::parse("udp").is_err());
        assert_eq!(TransportKind::default().name(), "sim");
    }
}
