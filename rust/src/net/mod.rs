//! Simulated cluster network.
//!
//! The paper's insight (§3.3.1) is that on a commodity Gigabit cluster the
//! *shared switch* is the bottleneck: all `n·(n−1)` pairs contend for it,
//! so per-pair throughput is far below disk streaming bandwidth.  We model
//! exactly that: a [`Switch`] serializes transmissions through a shared
//! medium at `net_bytes_per_sec` (plus a per-batch latency), and machines
//! exchange batches over per-destination FIFO channels (std `mpsc`
//! preserves per-sender order, giving the FIFO property §4 relies on).
//!
//! Sending a batch *blocks for the simulated transmission time* — that is
//! what makes "hide disk I/O inside communication" measurable in this
//! reproduction.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Shared-medium bandwidth model: transmissions reserve back-to-back slots.
pub struct Switch {
    rate: f64,
    latency: Duration,
    next_free: Mutex<Instant>,
    bytes: Mutex<u64>,
}

impl Switch {
    pub fn new(bytes_per_sec: f64, latency_us: u64) -> Arc<Self> {
        Arc::new(Self {
            rate: bytes_per_sec.max(1.0),
            latency: Duration::from_micros(latency_us),
            next_free: Mutex::new(Instant::now()),
            bytes: Mutex::new(0),
        })
    }

    /// Block for the simulated transmission time of `bytes` through the
    /// shared medium (serialized with all other transmissions).
    pub fn transmit(&self, bytes: usize) {
        let dur = Duration::from_secs_f64(bytes as f64 / self.rate) + self.latency;
        let until = {
            let mut nf = self.next_free.lock().unwrap();
            let start = (*nf).max(Instant::now());
            *nf = start + dur;
            *nf
        };
        *self.bytes.lock().unwrap() += bytes as u64;
        let now = Instant::now();
        if until > now {
            std::thread::sleep(until - now);
        }
    }

    /// Total bytes pushed through the switch.
    pub fn total_bytes(&self) -> u64 {
        *self.bytes.lock().unwrap()
    }
}

/// What a network batch carries.
#[derive(Debug)]
pub enum Payload {
    /// Message records for superstep `step`.
    Data(Vec<u8>),
    /// End tag: the sender has exhausted its OMS towards us for `step`.
    End,
    /// Vertex records during graph loading (§3.4).
    Load(Vec<u8>),
    /// End of loading phase from this sender.
    LoadEnd,
}

/// A framed batch on the wire.
#[derive(Debug)]
pub struct Batch {
    pub src: usize,
    pub step: u64,
    pub payload: Payload,
}

impl Batch {
    pub fn wire_bytes(&self) -> usize {
        16 + match &self.payload {
            Payload::Data(d) | Payload::Load(d) => d.len(),
            Payload::End | Payload::LoadEnd => 0,
        }
    }
}

/// Sending half of a machine's endpoint.  Clonable: U_s owns one clone,
/// U_c takes another for the stall-mode ablation and the loading phase.
/// Real-time enqueue order across clones is preserved by the mpsc queue,
/// so the FIFO property §4 relies on still holds.
#[derive(Clone)]
pub struct NetSender {
    pub me: usize,
    switch: Arc<Switch>,
    txs: Vec<Sender<Batch>>,
    sent_bytes: u64,
}

impl NetSender {
    /// Simulate transmission through the shared switch, then deliver.
    /// Panics if the destination has hung up (worker died — surfaced as a
    /// test failure rather than silent loss).
    pub fn send(&mut self, dst: usize, step: u64, payload: Payload) {
        let b = Batch {
            src: self.me,
            step,
            payload,
        };
        self.switch.transmit(b.wire_bytes());
        self.sent_bytes += b.wire_bytes() as u64;
        if self.txs[dst].send(b).is_err() {
            panic!(
                "peer receiver hung up: {} -> {dst} step {step} payload {:?}",
                self.me,
                "dropped"
            );
        }
    }

    pub fn peers(&self) -> usize {
        self.txs.len()
    }

    pub fn sent_bytes(&self) -> u64 {
        self.sent_bytes
    }
}

/// Receiving half of a machine's endpoint (owned by U_r).
pub struct NetReceiver {
    pub me: usize,
    rx: Receiver<Batch>,
}

impl NetReceiver {
    /// Blocking receive.
    pub fn recv(&self) -> Batch {
        self.rx.recv().expect("all senders hung up")
    }

    /// Receive with timeout (used by failure detection in ft tests).
    pub fn recv_timeout(&self, d: Duration) -> Option<Batch> {
        self.rx.recv_timeout(d).ok()
    }
}

/// Build a fully-connected simulated network of `n` machines.
pub fn build(n: usize, bytes_per_sec: f64, latency_us: u64) -> Vec<(NetSender, NetReceiver)> {
    let switch = Switch::new(bytes_per_sec, latency_us);
    let (txs, rxs): (Vec<_>, Vec<_>) = (0..n).map(|_| channel::<Batch>()).unzip();
    rxs.into_iter()
        .enumerate()
        .map(|(me, rx)| {
            (
                NetSender {
                    me,
                    switch: switch.clone(),
                    txs: txs.clone(),
                    sent_bytes: 0,
                },
                NetReceiver { me, rx },
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_per_pair() {
        let mut eps = build(2, 1e12, 0);
        let (_, rx1) = eps.pop().unwrap();
        let (mut tx0, _rx0) = eps.pop().unwrap();
        for i in 0..100u64 {
            tx0.send(1, i, Payload::Data(vec![i as u8]));
        }
        for i in 0..100u64 {
            let b = rx1.recv();
            assert_eq!(b.step, i);
            assert_eq!(b.src, 0);
        }
    }

    #[test]
    fn cross_clone_order_preserved_by_enqueue_time() {
        let mut eps = build(2, 1e12, 0);
        let (_, rx1) = eps.pop().unwrap();
        let (tx, _rx0) = eps.pop().unwrap();
        let mut a = tx.clone();
        let mut b = tx;
        a.send(1, 1, Payload::Data(vec![]));
        b.send(1, 2, Payload::Data(vec![]));
        a.send(1, 3, Payload::End);
        assert_eq!(rx1.recv().step, 1);
        assert_eq!(rx1.recv().step, 2);
        assert_eq!(rx1.recv().step, 3);
    }

    #[test]
    fn switch_throttles_rate() {
        // 1 MB at 10 MB/s must take >= ~90ms.
        let sw = Switch::new(10.0 * 1024.0 * 1024.0, 0);
        let t = Instant::now();
        sw.transmit(1024 * 1024);
        assert!(t.elapsed() >= Duration::from_millis(90), "{:?}", t.elapsed());
        assert_eq!(sw.total_bytes(), 1024 * 1024);
    }

    #[test]
    fn switch_serializes_contending_senders() {
        // Two threads sending 500 KB each through a 10 MB/s switch: total
        // wall time must reflect the *sum* (shared medium), ~100ms, not 50.
        let sw = Switch::new(10.0 * 1024.0 * 1024.0, 0);
        let t = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..2 {
                let sw = &sw;
                s.spawn(move || sw.transmit(512 * 1024));
            }
        });
        assert!(t.elapsed() >= Duration::from_millis(85), "{:?}", t.elapsed());
    }

    #[test]
    fn loopback_delivery() {
        let mut eps = build(1, 1e12, 0);
        let (mut tx, rx) = eps.pop().unwrap();
        tx.send(0, 3, Payload::End);
        let b = rx.recv();
        assert!(matches!(b.payload, Payload::End));
        assert_eq!(b.step, 3);
    }

    #[test]
    fn wire_bytes_includes_frame() {
        let b = Batch {
            src: 0,
            step: 0,
            payload: Payload::Data(vec![0; 100]),
        };
        assert_eq!(b.wire_bytes(), 116);
        let e = Batch {
            src: 0,
            step: 0,
            payload: Payload::End,
        };
        assert_eq!(e.wire_bytes(), 16);
    }
}
