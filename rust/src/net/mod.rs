//! Simulated cluster network.
//!
//! The paper's insight (§3.3.1) is that on a commodity Gigabit cluster the
//! *shared switch* is the bottleneck: all `n·(n−1)` pairs contend for it,
//! so per-pair throughput is far below disk streaming bandwidth.  We model
//! exactly that: a [`Switch`] serializes transmissions through a shared
//! medium at `net_bytes_per_sec` (plus a per-batch latency), and machines
//! exchange batches over per-destination FIFO channels (std `mpsc`
//! preserves per-sender order, giving the FIFO property §4 relies on).
//!
//! Sending a batch *blocks for the simulated transmission time* — that is
//! what makes "hide disk I/O inside communication" measurable in this
//! reproduction.

//! **Failure observation.**  When a [`crate::worker::sync::JobAbort`] is
//! attached at [`build`] time, every potentially-unbounded wait in this
//! module observes it: [`NetReceiver::recv`] polls the abort flag while
//! blocked (a dead sender can never deliver the end tags it owes us),
//! [`NetSender::send`] surfaces the abort cause instead of panicking when
//! the peer hung up, and [`Switch::transmit`] breaks out of long simulated
//! transmissions once the job is dead — so no unit can outlive a poisoned
//! job inside the network layer.

use crate::error::{Error, Result};
use crate::worker::sync::{lock_clean, JobAbort};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How often blocked channel/switch waits re-check the abort flag.
const ABORT_POLL: Duration = Duration::from_millis(10);

/// The shared medium's reservation state.  Slot reservation and byte
/// accounting live in **one** critical section so `total_bytes` can never
/// be observed torn against the reserved slots (a reader either sees a
/// transmission's slot *and* its bytes, or neither).
struct Medium {
    next_free: Instant,
    wire_bytes: u64,
}

/// Shared-medium bandwidth model: transmissions reserve back-to-back slots.
pub struct Switch {
    rate: f64,
    latency: Duration,
    medium: Mutex<Medium>,
    /// Bytes delivered machine-locally (the fast path): they never reserve
    /// a slot and never sleep — counted separately from wire traffic.
    local_bytes: AtomicU64,
    /// Job-abort latch: long simulated transmissions break out early once
    /// the job is dead (`None` = no abort observation, seed behaviour).
    abort: Option<Arc<JobAbort>>,
}

impl Switch {
    /// A shared medium transmitting at `bytes_per_sec` with a fixed
    /// per-batch latency.
    pub fn new(bytes_per_sec: f64, latency_us: u64) -> Arc<Self> {
        Self::with_abort(bytes_per_sec, latency_us, None)
    }

    /// Like [`Switch::new`], with an abort latch the simulated
    /// transmission sleeps observe.
    pub fn with_abort(
        bytes_per_sec: f64,
        latency_us: u64,
        abort: Option<Arc<JobAbort>>,
    ) -> Arc<Self> {
        Arc::new(Self {
            rate: bytes_per_sec.max(1.0),
            latency: Duration::from_micros(latency_us),
            medium: Mutex::new(Medium {
                next_free: Instant::now(),
                wire_bytes: 0,
            }),
            local_bytes: AtomicU64::new(0),
            abort,
        })
    }

    /// Block for the simulated transmission time of `bytes` through the
    /// shared medium (serialized with all other transmissions).  The sleep
    /// is always sliced into ≤[`ABORT_POLL`] naps so a poisoned job stops
    /// paying simulated wire time promptly (the byte accounting stays —
    /// the bytes were already committed to the medium); without an abort
    /// latch the slicing just re-checks the clock.
    ///
    /// This window is exactly what a U_s track's `transmit` span measures
    /// in the Chrome-trace export ([`crate::trace`]): [`NetSender::send`]
    /// blocks here synchronously, so span length = queueing + wire time.
    pub fn transmit(&self, bytes: usize) {
        let dur = Duration::from_secs_f64(bytes as f64 / self.rate) + self.latency;
        let until = {
            let mut m = lock_clean(&self.medium);
            let start = m.next_free.max(Instant::now());
            m.next_free = start + dur;
            m.wire_bytes += bytes as u64;
            m.next_free
        };
        loop {
            let now = Instant::now();
            if until <= now {
                return;
            }
            if self.abort.as_ref().is_some_and(|a| a.aborted()) {
                return;
            }
            // analyze:allow(sleep-slicing): this loop IS the sliced-wait
            // helper — each nap is bounded by ABORT_POLL and the abort
            // latch is re-checked before every slice.
            std::thread::sleep((until - now).min(ABORT_POLL));
        }
    }

    /// Account a locally-delivered batch: zero simulated wire time.
    pub fn account_local(&self, bytes: usize) {
        self.local_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Total bytes pushed through the switch (wire traffic only).
    pub fn total_bytes(&self) -> u64 {
        lock_clean(&self.medium).wire_bytes
    }

    /// Total bytes delivered machine-locally, bypassing the switch.
    pub fn local_bytes(&self) -> u64 {
        self.local_bytes.load(Ordering::Relaxed)
    }
}

/// What a network batch carries.
#[derive(Debug)]
pub enum Payload {
    /// Message records for superstep `step`.
    Data(Vec<u8>),
    /// End tag: the sender has exhausted its OMS towards us for `step`.
    End,
    /// Vertex records during graph loading (§3.4).
    Load(Vec<u8>),
    /// End of loading phase from this sender.
    LoadEnd,
}

/// A framed batch on the wire.
#[derive(Debug)]
pub struct Batch {
    /// Sending machine.
    pub src: usize,
    /// Superstep (or recoding phase) the batch belongs to.
    pub step: u64,
    /// What the batch carries.
    pub payload: Payload,
}

impl Batch {
    /// Bytes the batch occupies on the wire: a 16-byte frame + the data.
    pub fn wire_bytes(&self) -> usize {
        16 + match &self.payload {
            Payload::Data(d) | Payload::Load(d) => d.len(),
            Payload::End | Payload::LoadEnd => 0,
        }
    }
}

/// Sending half of a machine's endpoint.  Clonable: U_s owns one clone,
/// U_c takes another for the stall-mode ablation and the loading phase.
/// Real-time enqueue order across clones is preserved by the mpsc queue,
/// so the FIFO property §4 relies on still holds.
#[derive(Clone)]
pub struct NetSender {
    /// This endpoint's machine index.
    pub me: usize,
    switch: Arc<Switch>,
    txs: Vec<Sender<Batch>>,
    sent_bytes: u64,
    local_bytes: u64,
    /// Deliver `dst == me` batches without touching the switch (the
    /// local-delivery fast path): a machine talking to itself crosses no
    /// physical medium, so it pays zero simulated wire time.
    local_fast: bool,
    /// Job-abort latch: a hung-up peer reports the abort cause instead of
    /// an opaque channel error.
    abort: Option<Arc<JobAbort>>,
}

impl NetSender {
    /// Simulate transmission through the shared switch, then deliver —
    /// except batches to `self` with the fast path on, which skip the
    /// switch entirely and are only *counted* (as local bytes).
    /// Errors if the destination has hung up: with the job's abort latch
    /// tripped this surfaces the original failure cause (typed
    /// [`Error::JobFailed`]); without one, a hung-up peer is a corrupt
    /// cluster state in its own right.
    pub fn send(&mut self, dst: usize, step: u64, payload: Payload) -> Result<()> {
        let b = Batch {
            src: self.me,
            step,
            payload,
        };
        let bytes = b.wire_bytes();
        if self.local_fast && dst == self.me {
            self.switch.account_local(bytes);
            self.local_bytes += bytes as u64;
        } else {
            self.switch.transmit(bytes);
            self.sent_bytes += bytes as u64;
        }
        if self.txs[dst].send(b).is_err() {
            if let Some(c) = self.abort.as_ref().and_then(|a| a.cause()) {
                return Err(c.to_error());
            }
            return Err(Error::CorruptStream(format!(
                "peer receiver hung up: {} -> {dst} step {step}",
                self.me
            )));
        }
        Ok(())
    }

    /// Number of machines in the network (including this one).
    pub fn peers(&self) -> usize {
        self.txs.len()
    }

    /// Is the local-delivery fast path active on this endpoint?
    pub fn local_fast(&self) -> bool {
        self.local_fast
    }

    /// Bytes this endpoint pushed through the switch.
    pub fn sent_bytes(&self) -> u64 {
        self.sent_bytes
    }

    /// Bytes this endpoint delivered to itself, bypassing the switch.
    pub fn local_sent_bytes(&self) -> u64 {
        self.local_bytes
    }
}

/// Receiving half of a machine's endpoint (owned by U_r).
pub struct NetReceiver {
    /// This endpoint's machine index.
    pub me: usize,
    rx: Receiver<Batch>,
    abort: Option<Arc<JobAbort>>,
}

impl NetReceiver {
    /// Blocking receive.  With the job's abort latch attached, the block
    /// is sliced so a tripped abort surfaces as its typed error — the end
    /// tags a dead machine owes us will never arrive, and this is the wait
    /// every surviving U_r wedges in without it.
    pub fn recv(&self) -> Result<Batch> {
        let Some(a) = &self.abort else {
            return self
                .rx
                .recv()
                .map_err(|_| Error::CorruptStream("all senders hung up".into()));
        };
        loop {
            // Hot path: one atomic flag read per batch; the cause Mutex is
            // only touched once the latch actually tripped.
            if a.aborted() {
                if let Some(c) = a.cause() {
                    return Err(c.to_error());
                }
            }
            match self.rx.recv_timeout(ABORT_POLL) {
                Ok(b) => return Ok(b),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(match a.cause() {
                        Some(c) => c.to_error(),
                        None => Error::CorruptStream("all senders hung up".into()),
                    })
                }
            }
        }
    }

    /// Receive with timeout (used by failure detection in ft tests).
    pub fn recv_timeout(&self, d: Duration) -> Option<Batch> {
        self.rx.recv_timeout(d).ok()
    }
}

/// Build a fully-connected simulated network of `n` machines.
/// `local_fast` enables the local-delivery fast path (`dst == me` batches
/// bypass the switch).  `abort` attaches the job's abort latch so channel
/// and switch waits observe a dead sibling (pass `None` for abort-free
/// micro-benchmarks/tests).  Also returns the shared [`Switch`] so callers
/// can read the wire-vs-local byte split after a run.
pub fn build(
    n: usize,
    bytes_per_sec: f64,
    latency_us: u64,
    local_fast: bool,
    abort: Option<Arc<JobAbort>>,
) -> (Vec<(NetSender, NetReceiver)>, Arc<Switch>) {
    let switch = Switch::with_abort(bytes_per_sec, latency_us, abort.clone());
    let (txs, rxs): (Vec<_>, Vec<_>) = (0..n).map(|_| channel::<Batch>()).unzip();
    let endpoints = rxs
        .into_iter()
        .enumerate()
        .map(|(me, rx)| {
            (
                NetSender {
                    me,
                    switch: switch.clone(),
                    txs: txs.clone(),
                    sent_bytes: 0,
                    local_bytes: 0,
                    local_fast,
                    abort: abort.clone(),
                },
                NetReceiver {
                    me,
                    rx,
                    abort: abort.clone(),
                },
            )
        })
        .collect();
    (endpoints, switch)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_per_pair() {
        let (mut eps, _) = build(2, 1e12, 0, false, None);
        let (_, rx1) = eps.pop().unwrap();
        let (mut tx0, _rx0) = eps.pop().unwrap();
        for i in 0..100u64 {
            tx0.send(1, i, Payload::Data(vec![i as u8])).unwrap();
        }
        for i in 0..100u64 {
            let b = rx1.recv().unwrap();
            assert_eq!(b.step, i);
            assert_eq!(b.src, 0);
        }
    }

    #[test]
    fn cross_clone_order_preserved_by_enqueue_time() {
        let (mut eps, _) = build(2, 1e12, 0, false, None);
        let (_, rx1) = eps.pop().unwrap();
        let (tx, _rx0) = eps.pop().unwrap();
        let mut a = tx.clone();
        let mut b = tx;
        a.send(1, 1, Payload::Data(vec![])).unwrap();
        b.send(1, 2, Payload::Data(vec![])).unwrap();
        a.send(1, 3, Payload::End).unwrap();
        assert_eq!(rx1.recv().unwrap().step, 1);
        assert_eq!(rx1.recv().unwrap().step, 2);
        assert_eq!(rx1.recv().unwrap().step, 3);
    }

    #[test]
    fn switch_throttles_rate() {
        // 1 MB at 10 MB/s must take >= ~90ms.
        let sw = Switch::new(10.0 * 1024.0 * 1024.0, 0);
        let t = Instant::now();
        sw.transmit(1024 * 1024);
        assert!(t.elapsed() >= Duration::from_millis(90), "{:?}", t.elapsed());
        assert_eq!(sw.total_bytes(), 1024 * 1024);
    }

    #[test]
    fn switch_serializes_contending_senders() {
        // Two threads sending 500 KB each through a 10 MB/s switch: total
        // wall time must reflect the *sum* (shared medium), ~100ms, not 50.
        let sw = Switch::new(10.0 * 1024.0 * 1024.0, 0);
        let t = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..2 {
                let sw = &sw;
                s.spawn(move || sw.transmit(512 * 1024));
            }
        });
        assert!(t.elapsed() >= Duration::from_millis(85), "{:?}", t.elapsed());
    }

    #[test]
    fn loopback_delivery() {
        let (mut eps, _) = build(1, 1e12, 0, false, None);
        let (mut tx, rx) = eps.pop().unwrap();
        tx.send(0, 3, Payload::End).unwrap();
        let b = rx.recv().unwrap();
        assert!(matches!(b.payload, Payload::End));
        assert_eq!(b.step, 3);
    }

    #[test]
    fn local_fast_path_bypasses_switch() {
        // A slow switch that would take ~100ms for this batch: the local
        // fast path must deliver instantly and charge zero wire bytes.
        let (mut eps, switch) = build(1, 10.0 * 1024.0 * 1024.0, 0, true, None);
        let (mut tx, rx) = eps.pop().unwrap();
        let t = Instant::now();
        tx.send(0, 0, Payload::Data(vec![0; 1024 * 1024])).unwrap();
        assert!(t.elapsed() < Duration::from_millis(50), "{:?}", t.elapsed());
        let b = rx.recv().unwrap();
        assert!(matches!(b.payload, Payload::Data(_)));
        assert_eq!(switch.total_bytes(), 0, "no wire traffic for dst == me");
        assert_eq!(switch.local_bytes(), 1024 * 1024 + 16);
        assert_eq!(tx.sent_bytes(), 0);
        assert_eq!(tx.local_sent_bytes(), 1024 * 1024 + 16);
        assert!(tx.local_fast());
    }

    #[test]
    fn remote_batches_still_transit_with_fast_path_on() {
        let (mut eps, switch) = build(2, 1e12, 0, true, None);
        let (_, rx1) = eps.pop().unwrap();
        let (mut tx0, _rx0) = eps.pop().unwrap();
        tx0.send(1, 0, Payload::Data(vec![0; 84])).unwrap();
        assert_eq!(rx1.recv().unwrap().step, 0);
        assert_eq!(switch.total_bytes(), 100);
        assert_eq!(switch.local_bytes(), 0);
    }

    #[test]
    fn recv_unblocks_on_abort_with_typed_cause() {
        use crate::worker::sync::AbortCause;
        let abort = JobAbort::new();
        let (mut eps, _) = build(2, 1e12, 0, false, Some(abort.clone()));
        let (_, rx1) = eps.pop().unwrap();
        // Keep machine 0's sender alive so the channel never disconnects:
        // the only way out of the blocked recv is the abort flag.
        let (_tx0, _rx0) = eps.pop().unwrap();
        let t = std::thread::spawn(move || rx1.recv());
        std::thread::sleep(Duration::from_millis(30));
        abort.trip(AbortCause {
            machine: 1,
            unit: "U_c",
            superstep: 2,
            cause: "boom".into(),
        });
        let err = t.join().unwrap().unwrap_err();
        assert!(matches!(
            err,
            Error::JobFailed { machine: 1, superstep: 2, .. }
        ));
    }

    #[test]
    fn wire_bytes_includes_frame() {
        let b = Batch {
            src: 0,
            step: 0,
            payload: Payload::Data(vec![0; 100]),
        };
        assert_eq!(b.wire_bytes(), 116);
        let e = Batch {
            src: 0,
            step: 0,
            payload: Payload::End,
        };
        assert_eq!(e.wire_bytes(), 16);
    }
}
