//! The simulator backend: an in-process shared [`Switch`] with modeled
//! wire time, and per-destination std `mpsc` channels as the fabric.
//!
//! This is the seed transport every test/bench runs on (and the default
//! `-c transport=sim`): the paper's insight (§3.3.1) is that on a
//! commodity Gigabit cluster the *shared switch* is the bottleneck — all
//! `n·(n−1)` pairs contend for it, so per-pair throughput is far below
//! disk streaming bandwidth.  We model exactly that: the [`Switch`]
//! serializes transmissions through a shared medium at `net_bytes_per_sec`
//! (plus a per-batch latency), and machines exchange batches over
//! per-destination FIFO channels (std `mpsc` preserves per-sender order,
//! giving the FIFO property §4 relies on).
//!
//! Sending a batch *blocks for the simulated transmission time* — that is
//! what makes "hide disk I/O inside communication" measurable in this
//! reproduction.  The TCP backend ([`super::tcp`]) reuses the [`Switch`]
//! as a pure byte ledger (infinite rate, zero latency): real sockets do
//! their own pacing, but the wire-vs-local byte split the metrics report
//! stays one code path across backends.

use super::{Batch, NetReceiver, NetSender, ABORT_POLL};
use crate::worker::sync::{lock_clean, JobAbort};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// The shared medium's reservation state.  Slot reservation and byte
/// accounting live in **one** critical section so `total_bytes` can never
/// be observed torn against the reserved slots (a reader either sees a
/// transmission's slot *and* its bytes, or neither).
struct Medium {
    next_free: Instant,
    wire_bytes: u64,
}

/// Shared-medium bandwidth model: transmissions reserve back-to-back slots.
pub struct Switch {
    rate: f64,
    latency: Duration,
    medium: Mutex<Medium>,
    /// Bytes delivered machine-locally (the fast path): they never reserve
    /// a slot and never sleep — counted separately from wire traffic.
    local_bytes: AtomicU64,
    /// Job-abort latch: long simulated transmissions break out early once
    /// the job is dead (`None` = no abort observation, seed behaviour).
    abort: Option<Arc<JobAbort>>,
}

impl Switch {
    /// A shared medium transmitting at `bytes_per_sec` with a fixed
    /// per-batch latency.
    pub fn new(bytes_per_sec: f64, latency_us: u64) -> Arc<Self> {
        Self::with_abort(bytes_per_sec, latency_us, None)
    }

    /// Like [`Switch::new`], with an abort latch the simulated
    /// transmission sleeps observe.
    pub fn with_abort(
        bytes_per_sec: f64,
        latency_us: u64,
        abort: Option<Arc<JobAbort>>,
    ) -> Arc<Self> {
        Arc::new(Self {
            rate: bytes_per_sec.max(1.0),
            latency: Duration::from_micros(latency_us),
            medium: Mutex::new(Medium {
                next_free: Instant::now(),
                wire_bytes: 0,
            }),
            local_bytes: AtomicU64::new(0),
            abort,
        })
    }

    /// A pure byte ledger: infinite rate and zero latency, so
    /// [`Switch::transmit`] accounts and returns without sleeping.  The
    /// TCP backend uses this — the real kernel does the pacing there, but
    /// metrics still read one `Switch` regardless of backend.
    pub fn ledger(abort: Option<Arc<JobAbort>>) -> Arc<Self> {
        Self::with_abort(f64::INFINITY, 0, abort)
    }

    /// Block for the simulated transmission time of `bytes` through the
    /// shared medium (serialized with all other transmissions).  The sleep
    /// is always sliced into ≤[`ABORT_POLL`] naps so a poisoned job stops
    /// paying simulated wire time promptly (the byte accounting stays —
    /// the bytes were already committed to the medium); without an abort
    /// latch the slicing just re-checks the clock.
    ///
    /// This window is exactly what a U_s track's `transmit` span measures
    /// in the Chrome-trace export ([`crate::trace`]): [`NetSender::send`]
    /// blocks here synchronously, so span length = queueing + wire time.
    pub fn transmit(&self, bytes: usize) {
        let dur = Duration::from_secs_f64(bytes as f64 / self.rate) + self.latency;
        let until = {
            let mut m = lock_clean(&self.medium);
            let start = m.next_free.max(Instant::now());
            m.next_free = start + dur;
            m.wire_bytes += bytes as u64;
            m.next_free
        };
        loop {
            let now = Instant::now();
            if until <= now {
                return;
            }
            if self.abort.as_ref().is_some_and(|a| a.aborted()) {
                return;
            }
            // analyze:allow(sleep-slicing): this loop IS the sliced-wait
            // helper — each nap is bounded by ABORT_POLL and the abort
            // latch is re-checked before every slice.
            std::thread::sleep((until - now).min(ABORT_POLL));
        }
    }

    /// Account a locally-delivered batch: zero simulated wire time.
    pub fn account_local(&self, bytes: usize) {
        self.local_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Total bytes pushed through the switch (wire traffic only).
    pub fn total_bytes(&self) -> u64 {
        lock_clean(&self.medium).wire_bytes
    }

    /// Total bytes delivered machine-locally, bypassing the switch.
    pub fn local_bytes(&self) -> u64 {
        self.local_bytes.load(Ordering::Relaxed)
    }
}

/// Build a fully-connected simulated network of `n` machines.
/// `local_fast` enables the local-delivery fast path (`dst == me` batches
/// bypass the switch).  `abort` attaches the job's abort latch so channel
/// and switch waits observe a dead sibling (pass `None` for abort-free
/// micro-benchmarks/tests).  Also returns the shared [`Switch`] so callers
/// can read the wire-vs-local byte split after a run.
pub fn build(
    n: usize,
    bytes_per_sec: f64,
    latency_us: u64,
    local_fast: bool,
    abort: Option<Arc<JobAbort>>,
) -> (Vec<(NetSender, NetReceiver)>, Arc<Switch>) {
    let switch = Switch::with_abort(bytes_per_sec, latency_us, abort.clone());
    let (txs, rxs): (Vec<_>, Vec<_>) = (0..n).map(|_| channel::<Batch>()).unzip();
    let endpoints = rxs
        .into_iter()
        .enumerate()
        .map(|(me, rx)| {
            (
                NetSender {
                    me,
                    switch: switch.clone(),
                    txs: txs.clone(),
                    sent_bytes: 0,
                    local_bytes: 0,
                    local_fast,
                    abort: abort.clone(),
                },
                NetReceiver {
                    me,
                    rx,
                    abort: abort.clone(),
                },
            )
        })
        .collect();
    (endpoints, switch)
}
